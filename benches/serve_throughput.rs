//! Serving-runtime throughput probes: the warm session pool + dynamic
//! batcher against the naive one-engine-per-request baseline, plus a
//! pure-scheduler probe (the virtual-time planner with no simulation).
//!
//! The acceptance gate of the serving PR lives here: batch serving must
//! amortize prepare cost (graph build, validation, memo warmup) to at
//! least 2x the baseline's throughput — in practice the gap is far
//! larger, since a warm timing-only request replays memoized layer
//! records instead of re-simulating the network.
//!
//!     cargo bench --bench serve_throughput [-- <filter>] [--quick]

use vta::config::presets;
use vta::engine::{BackendKind, Engine, EvalRequest};
use vta::serve::{self, ArrivalSpec, SchedOptions, ServeOptions};
use vta::sweep::WorkloadSpec;
use vta::util::bench::Bench;
use vta::workloads;

fn main() {
    let mut b = Bench::from_env();
    let n = 64usize;
    let cfg = presets::tiny_config();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        backend: BackendKind::TsimTiming,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        graph_seed: 42,
        ..ServeOptions::default()
    };
    let ids = vec!["micro@4".to_string()];

    // Baseline: what every pre-serve client does — one engine, one
    // freshly built graph, one full simulation per request.
    let baseline_cycles = b.once("serve/one_engine_per_request", || {
        let mut total = 0u64;
        for i in 0..n as u64 {
            let graph = workloads::micro_resnet(4, 42);
            let engine = Engine::for_config(&cfg)
                .backend_kind(BackendKind::TsimTiming)
                .build()
                .unwrap();
            let eval = engine.run(&graph, &EvalRequest::seeded(i)).unwrap();
            total += eval.cycles.unwrap();
        }
        total
    });

    // The serving runtime: pool build + warmup + N batched requests.
    let served_cycles = b.once("serve/batched_runtime", || {
        let trace = serve::synth_trace(
            &ArrivalSpec::Uniform { rate_per_s: 10_000.0 },
            &ids,
            n,
            7,
        )
        .unwrap();
        let outcome = serve::run(&opts, &trace).unwrap();
        assert_eq!(outcome.report.completed, n, "nothing may be shed in the probe");
        outcome.report.total_cycles
    });

    // Both paths evaluated the same work (cycles are data-independent
    // and the graph seed matches).
    if let (Some(base), Some(served)) = (baseline_cycles, served_cycles) {
        assert_eq!(base, served, "served cycles must equal the baseline's");
    }

    // The acceptance gate: served throughput >= 2x the baseline.
    let mean = |name: &str| b.results.iter().find(|r| r.name == name).map(|r| r.mean_ns);
    if let (Some(base_ns), Some(served_ns)) =
        (mean("serve/one_engine_per_request"), mean("serve/batched_runtime"))
    {
        let speedup = base_ns / served_ns;
        println!(
            "    amortization: {speedup:.1}x ({:.0}ns/req baseline vs {:.0}ns/req served)",
            base_ns / n as f64,
            served_ns / n as f64
        );
        assert!(
            speedup >= 2.0,
            "batch serving must amortize prepare cost >= 2x the \
             one-engine-per-request baseline (got {speedup:.2}x)"
        );
    }

    // The scheduler alone: virtual-time planning cost per request, no
    // simulation. This is the hot path of every future scale-out PR.
    let big_trace = serve::synth_trace(
        &ArrivalSpec::Poisson { rate_per_s: 5_000.0 },
        &ids,
        10_000,
        9,
    )
    .unwrap();
    let service: std::collections::BTreeMap<String, u64> =
        [("micro@4".to_string(), 300u64)].into_iter().collect();
    let sched_opts = SchedOptions {
        max_batch: 8,
        max_wait_us: 2_000,
        queue_depth: 4_096,
        deadline_us: None,
        dispatch_overhead_us: 50,
    };
    b.bench("serve/schedule_10k_requests", || {
        let s = serve::schedule(&big_trace, &service, &sched_opts).unwrap();
        assert!(s.completed() > 0);
        s.batches.len()
    });

    // The fleet router: the same 10k-request trace planned across three
    // heterogeneous virtual devices (pure virtual-time planning — this
    // is the per-request cost `vta serve --fleet` adds over `schedule`).
    let devices: Vec<serve::DeviceCost> = [(300u64, 1.0f64), (150, 2.0), (75, 4.0)]
        .iter()
        .enumerate()
        .map(|(d, &(us, area))| serve::DeviceCost {
            config: format!("dev{d}"),
            service_us: [("micro@4".to_string(), us)].into_iter().collect(),
            scaled_area: area,
        })
        .collect();
    b.bench("serve/fleet_schedule_10k_requests", || {
        let fs = serve::schedule_fleet(
            &big_trace,
            &devices,
            &serve::EarliestFeasibleCheapest,
            &sched_opts,
            None,
        )
        .unwrap();
        assert!(fs.schedule.completed() > 0);
        fs.schedule.batches.len()
    });

    b.save_if_requested();
    println!("\n{} benchmarks complete", b.results.len());
}
