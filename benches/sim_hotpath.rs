//! Microbenchmarks of the stack's hot paths (custom criterion-style
//! harness; see `vta::util::bench`). These are the before/after probes
//! for the EXPERIMENTS.md §Perf optimization log; `--save-json` writes
//! the machine-readable artifact tracked as `BENCH_sim_hotpath.json`
//! (and uploaded per CI run).
//!
//! Declared `harness = false` in Cargo.toml: a plain `fn main()` binary,
//! so it builds and runs on stable cargo (no nightly `#[bench]`).
//!
//!     cargo bench --bench sim_hotpath [-- <filter>] [--quick]
//!                 [--save-json BENCH_sim_hotpath.json]

use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::compiler::residency::{self, ResidencyMode};
use vta::compiler::tps;
use vta::config::presets;
use vta::engine::BackendKind;
use vta::isa::{DepFlags, Insn};
use vta::runtime::{Session, SessionOptions};
use vta::util::bench::{black_box, Bench};
use vta::util::json::Json;
use vta::util::rng::Pcg32;
use vta::workloads;

fn main() {
    let mut b = Bench::from_env();

    // --- exec core: one large GEMM instruction (the inner loop that
    // dominates whole-network simulation) ---
    {
        use vta::exec::CoreState;
        use vta::isa::{GemmInsn, Uop};
        use vta::mem::Dram;
        let cfg = presets::default_config();
        let mut st = CoreState::new(&cfg);
        let mut dram = Dram::new(1 << 20);
        let mut rng = Pcg32::seeded(1);
        for v in st.inp.iter_mut() {
            *v = (rng.next_u32() % 15) as i8 - 7;
        }
        for v in st.wgt.iter_mut() {
            *v = (rng.next_u32() % 15) as i8 - 7;
        }
        for i in 0..256usize {
            st.uop[i] = Uop::gemm(i as u32 % 128, (i * 3) as u32 % 512, (i * 7) as u32 % 256);
        }
        let gemm = Insn::Gemm(GemmInsn {
            deps: DepFlags::NONE,
            reset: false,
            uop_bgn: 0,
            uop_end: 256,
            lp_out: 4,
            lp_in: 4,
            acc_f0: 128,
            acc_f1: 0,
            inp_f0: 0,
            inp_f1: 16,
            wgt_f0: 0,
            wgt_f1: 1,
        });
        let macs = 256u64 * 16 * cfg.macs_per_gemm_op() as u64;
        b.bench_throughput("exec/gemm_insn_4096ops", Some((macs as f64, "MACs")), || {
            st.execute(&gemm, &mut dram);
            st.acc[0]
        });
    }

    // --- exec kernel dispatch: the same 4096 GEMM ops' worth of MACs,
    // driven directly through the `exec::dot_i8` dispatcher. With
    // `--features simd` on an AVX2/SSE2 host this takes the vector
    // path; without it, the scalar path — so the probe pairs with
    // exec/gemm_insn_4096ops for an on/off A/B read of the kernel. ---
    {
        use vta::exec::dot_i8;
        let cfg = presets::default_config();
        let bi = cfg.block_in;
        let bo = cfg.block_out;
        let mut rng = Pcg32::seeded(2);
        let x = rng.i8_vec(bi * 4096);
        let w = rng.i8_vec(bi * bo);
        let macs = 4096u64 * (bi * bo) as u64;
        b.bench_throughput("exec/gemm_insn_4096ops_simd", Some((macs as f64, "MACs")), || {
            let mut acc = 0i32;
            for op in 0..4096usize {
                let xi = &x[op * bi..(op + 1) * bi];
                for r in 0..bo {
                    acc = acc.wrapping_add(dot_i8(xi, &w[r * bi..(r + 1) * bi]));
                }
            }
            acc
        });
    }

    // --- tsim end-to-end throughput: simulated cycles per wall second ---
    {
        let g = workloads::micro_resnet(16, 3);
        let cfg = presets::default_config();
        let mut rng = Pcg32::seeded(4);
        let input = rng.i8_vec(g.input_shape.elems());
        // calibrate cycles once
        let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
        s.run_graph(&g, &input).unwrap();
        let cycles = s.cycles();
        b.bench_throughput("tsim/micro_resnet", Some((cycles as f64, "sim-cycles")), || {
            let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
            s.run_graph(&g, black_box(&input)).unwrap();
            s.cycles()
        });
    }

    // --- tsim timing-only: identical timing wheel and cycle counts,
    // functional datapath skipped (the sweep fast path) ---
    {
        let g = workloads::micro_resnet(16, 3);
        let cfg = presets::default_config();
        let mut rng = Pcg32::seeded(4);
        let input = rng.i8_vec(g.input_shape.elems());
        let topts =
            SessionOptions { backend: BackendKind::TsimTiming, ..Default::default() };
        let mut s = Session::new(&cfg, topts.clone()).unwrap();
        s.run_graph(&g, &input).unwrap();
        let cycles = s.cycles();
        b.bench_throughput(
            "tsim/micro_resnet_timing_only",
            Some((cycles as f64, "sim-cycles")),
            || {
                let mut s = Session::new(&cfg, topts.clone()).unwrap();
                s.run_graph(&g, black_box(&input)).unwrap();
                s.cycles()
            },
        );

        // --- memo-warm timing-only: every layer spliced from the shared
        // LayerMemo; measures the per-point floor of a warmed sweep ---
        let memo = std::sync::Arc::new(vta::memo::LayerMemo::in_memory());
        let mopts = SessionOptions {
            backend: BackendKind::TsimTiming,
            memo: Some(memo.clone()),
            ..Default::default()
        };
        let mut warm = Session::new(&cfg, mopts.clone()).unwrap();
        warm.run_graph(&g, &input).unwrap(); // populate the memo
        b.bench_throughput(
            "tsim/micro_resnet_memo_warm",
            Some((cycles as f64, "sim-cycles")),
            || {
                let mut s = Session::new(&cfg, mopts.clone()).unwrap();
                s.run_graph(&g, black_box(&input)).unwrap();
                s.cycles()
            },
        );
    }

    // --- tsim over the new workload families: attention exercises the
    // per-head GEMM splits + host marshalling, the LSTM cell the fused
    // gate GEMM + eltwise gate chain — both off the CNN hot path that
    // the probes above pin ---
    {
        let cfg = presets::default_config();
        let fams: [(&str, Graph); 2] = [
            ("tsim/transformer_block", workloads::transformer_block(64, 4, 16, 3)),
            ("tsim/lstm_cell", workloads::lstm_cell(64, 16, 3)),
        ];
        for (name, g) in fams {
            let mut rng = Pcg32::seeded(4);
            let input = rng.i8_vec(g.input_shape.elems());
            let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
            s.run_graph(&g, &input).unwrap();
            let cycles = s.cycles();
            b.bench_throughput(name, Some((cycles as f64, "sim-cycles")), || {
                let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
                s.run_graph(&g, black_box(&input)).unwrap();
                s.cycles()
            });
        }
    }

    // --- tsim under an explicit residency plan: pairs with
    // tsim/micro_resnet for an A/B read of the planner's end-to-end
    // cost (plan construction + elided-transfer bookkeeping) against
    // the cycles it removes from the simulated DMA engine ---
    {
        let g = workloads::micro_resnet(16, 3);
        let cfg = presets::default_config();
        let mut rng = Pcg32::seeded(4);
        let input = rng.i8_vec(g.input_shape.elems());
        let ropts = SessionOptions { residency: ResidencyMode::Lru, ..Default::default() };
        let mut s = Session::new(&cfg, ropts.clone()).unwrap();
        s.run_graph(&g, &input).unwrap();
        let cycles = s.cycles();
        b.bench_throughput(
            "tsim/micro_resnet_residency",
            Some((cycles as f64, "sim-cycles")),
            || {
                let mut s = Session::new(&cfg, ropts.clone()).unwrap();
                s.run_graph(&g, black_box(&input)).unwrap();
                s.cycles()
            },
        );
    }

    // --- fsim for comparison ---
    {
        let g = workloads::micro_resnet(16, 3);
        let cfg = presets::default_config();
        let mut rng = Pcg32::seeded(4);
        let input = rng.i8_vec(g.input_shape.elems());
        b.bench("fsim/micro_resnet", || {
            let mut s = Session::new(
                &cfg,
                SessionOptions { backend: BackendKind::Fsim, ..Default::default() },
            )
            .unwrap();
            s.run_graph(&g, black_box(&input)).unwrap();
        });
    }

    // --- engine batched evaluation: 16 requests through one prepared
    // graph and one reused session (`Engine::eval_many`, the serve /
    // sweep batch path) — amortizes validation, lowering, and DRAM
    // allocation across the batch ---
    {
        use vta::engine::{Engine, EvalRequest};
        let g = workloads::micro_resnet(16, 3);
        let cfg = presets::default_config();
        let engine = Engine::for_config(&cfg).backend_kind(BackendKind::Tsim).build().unwrap();
        let prepared = engine.prepare(&g).unwrap();
        let requests: Vec<EvalRequest> = (0..16u64).map(|s| EvalRequest::seeded(s + 1)).collect();
        b.bench("engine/eval_many_batch16", || {
            engine.eval_many(&prepared, black_box(&requests)).unwrap().len()
        });
    }

    // --- ISA encode/decode round trip ---
    {
        let layout = presets::default_config().isa_layout();
        let insn = Insn::Finish(DepFlags::NONE);
        let mut g = Graph::new("x", Shape::new(16, 8, 8));
        let mut rng = Pcg32::seeded(9);
        g.add(
            "c",
            Op::Conv {
                c_out: 16,
                k: 3,
                stride: 1,
                pad: 1,
                shift: 4,
                relu: true,
                weights: rng.i8_vec(16 * 16 * 9),
            },
            vec![0],
        );
        let _ = insn;
        let word = Insn::Finish(DepFlags::NONE).encode(&layout);
        b.bench("isa/decode", || Insn::decode(black_box(word), &layout).unwrap());
    }

    // --- TPS exhaustive search (compile-time cost) ---
    {
        let cfg = presets::scaled_config(1, 32, 32, 2, 32);
        let spec = tps::resnet18_convs()[0].1;
        b.bench("tps/search_c2_block32", || tps::search(black_box(&spec), &cfg, true));
    }

    // --- compiler: full conv lowering (packets + uops + deps) ---
    {
        let cfg = presets::default_config();
        let spec = tps::resnet18_convs()[0].1;
        let tiling = tps::search(&spec, &cfg, true);
        b.bench("compiler/lower_conv_c2", || {
            use vta::compiler::builder::ProgramBuilder;
            use vta::compiler::conv::{lower_conv, ConvBases, ConvParams};
            use vta::mem::Dram;
            let mut pb = ProgramBuilder::new(&cfg);
            lower_conv(
                &mut pb,
                &ConvParams { spec, shift: 5, relu: true },
                &tiling,
                ConvBases { inp: 0, wgt: 4096, out: 65536 },
            );
            let mut dram = Dram::new(1 << 22);
            pb.finish("bench", &mut dram).insns.len()
        });
    }

    // --- residency planner: one full cross-layer plan over ResNet-18
    // (compile-time cost of the interval walk + heuristic, amortized
    // once per (graph, config, mode) by the session) ---
    {
        let cfg = presets::default_config();
        let g = workloads::resnet(18, 56, 1);
        let shapes = g.shapes();
        b.bench("compiler/residency_plan_resnet18", || {
            residency::plan(
                black_box(&cfg),
                black_box(&g),
                &shapes,
                ResidencyMode::Lru,
                true,
                true,
            )
            .unwrap()
            .elided_bytes
        });
    }

    // --- artifact store: plan + reuse over the quick Fig 13 grid —
    // the per-sweep overhead of a fully warmed store (partition the
    // key list against the store, then serve every measurement without
    // evaluating). This is the fixed cost a warm `vta sweep --store`
    // re-run pays before reporting 100% reuse. ---
    {
        use vta::store::{materialize_points, ArtifactStore};
        use vta::sweep::GridSpec;
        use vta::util::json::obj;
        let spec = GridSpec::fig13(true).to_sweep_spec();
        let residency = ResidencyMode::default();
        let jobs = spec.jobs();
        let keys: Vec<u64> = jobs.iter().map(|j| j.cache_key(residency)).collect();
        // Pre-populate: payload shape matches a measured point (config
        // body + counters) so clone/serve costs are representative, but
        // no simulation is needed to warm the store for this probe.
        let store = ArtifactStore::in_memory();
        for (job, &key) in jobs.iter().zip(&keys) {
            let payload = obj([
                ("config", job.cfg.to_json()),
                ("cycles", Json::Int((key % 1_000_000) as i64 + 1)),
                ("macs", Json::Int(1 << 20)),
            ]);
            store.put(vta::store::ArtifactKind::PointMeasurement, key, payload).unwrap();
        }
        b.bench("store/plan_and_reuse_fig13", || {
            materialize_points(&store, black_box(&keys), 1, |_| {
                unreachable!("a warmed store evaluates nothing")
            })
            .unwrap()
            .len()
        });
    }

    // --- JSON config parse (the cross-layer interchange) ---
    {
        let text = presets::default_config().to_json().to_string_pretty();
        b.bench("util/json_config_roundtrip", || {
            Json::parse(black_box(&text)).unwrap()
        });
    }

    b.save_if_requested();
    println!("\n{} benchmarks complete", b.results.len());
}
