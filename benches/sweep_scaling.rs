//! Sweep-engine scaling probes: cold sweep wall time at 1 worker vs all
//! cores (the work-stealing speedup), and the warm-cache resume path
//! (which must be near-instant: no simulation, just JSONL replay).
//!
//!     cargo bench --bench sweep_scaling [-- <filter>] [--quick]

use vta::config::presets;
use vta::engine::BackendKind;
use vta::model;
use vta::sweep::{self, SweepOptions, SweepSpec, TwoPhaseOptions, WorkloadSpec};
use vta::util::bench::Bench;

/// 16-point micro grid: big enough to expose load imbalance (scratchpad
/// scale changes per-point cost), small enough for a bench harness.
fn micro_grid() -> SweepSpec {
    let mut configs = Vec::new();
    for axi in [8usize, 16, 32, 64] {
        for scale in [1usize, 2] {
            let mut cfg = presets::tiny_config();
            cfg.name = format!("tiny-s{scale}-m{axi}");
            cfg.axi_bytes = axi;
            cfg.inp_depth *= scale;
            cfg.wgt_depth *= scale;
            cfg.acc_depth *= scale;
            configs.push(cfg);
        }
    }
    SweepSpec {
        configs,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        seeds: vec![7, 8],
        graph_seed: 42,
    }
}

fn main() {
    let mut b = Bench::from_env();
    let spec = micro_grid();
    let n_points = spec.jobs().len();
    let cores = sweep::effective_jobs(0).min(n_points);

    let serial = b.once("sweep/cold_1_worker", || {
        let o = sweep::run(&spec, &SweepOptions { jobs: 1, ..Default::default() }).unwrap();
        assert_eq!(o.simulated, n_points);
        o.front.len()
    });
    let parallel = b.once(&format!("sweep/cold_{cores}_workers"), || {
        let o = sweep::run(&spec, &SweepOptions { jobs: cores, ..Default::default() }).unwrap();
        assert_eq!(o.simulated, n_points);
        o.front.len()
    });
    if let (Some(s), Some(p)) = (serial, parallel) {
        assert_eq!(s, p, "frontier size must not depend on worker count");
    }

    // The ISSUE-2 fast path: shared layer memo + timing-only simulation.
    // Bit-identical frontier, collapsed wall clock (the before/after
    // probe EXPERIMENTS.md records).
    let memoized = b.once("sweep/cold_memo_timing_only", || {
        let o = sweep::run(
            &spec,
            &SweepOptions {
                jobs: cores,
                memo: true,
                backend: BackendKind::TsimTiming,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(o.simulated, n_points);
        assert!(o.memo_hits > 0, "2 seeds per config must reuse layers");
        o.front.len()
    });
    if let (Some(p), Some(m)) = (parallel, memoized) {
        assert_eq!(p, m, "the fast path must not change the frontier");
    }

    // ISSUE-3: the two-phase engine — the analytical model prices the
    // grid in microseconds and tsim runs only on the epsilon-band
    // survivors. Wall clock scales with the survivor count, not the
    // grid; the probe also reports the prune factor.
    let two_phase = b.once("sweep/two_phase_default_epsilon", || {
        let o = sweep::run(
            &spec,
            &SweepOptions {
                jobs: cores,
                memo: true,
                backend: BackendKind::TsimTiming,
                two_phase: Some(TwoPhaseOptions { epsilon: model::DEFAULT_PRUNE_EPSILON }),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(o.results.len() + o.pruned.len(), n_points);
        println!(
            "    two-phase: {}/{} evaluated ({:.1}x fewer tsim evaluations)",
            o.results.len(),
            n_points,
            o.prune_factor()
        );
        o.front.len()
    });
    if let (Some(m), Some(t)) = (memoized, two_phase) {
        println!("    front sizes: full {m}, two-phase {t}");
    }

    // Warm-cache resume: populate once, then measure the replay path.
    let path =
        std::env::temp_dir().join(format!("vta_sweep_bench_{}.jsonl", std::process::id()));
    let warm_opts = SweepOptions {
        jobs: cores,
        cache_path: Some(path.clone()),
        resume: true,
        ..Default::default()
    };
    sweep::run(&spec, &SweepOptions { resume: false, ..warm_opts.clone() }).unwrap();
    b.once("sweep/warm_cache_resume", || {
        let o = sweep::run(&spec, &warm_opts).unwrap();
        assert_eq!(o.simulated, 0, "warm resume must not simulate");
        o.cached
    });
    std::fs::remove_file(&path).ok();

    b.save_if_requested();
    println!("\n{} benchmarks complete ({n_points} design points)", b.results.len());
}
