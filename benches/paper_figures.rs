//! Paper-figure regeneration harness: runs every table/figure driver in
//! quick mode and reports wall time per experiment. Use the `vta repro`
//! CLI (without --quick) for the full-resolution numbers recorded in
//! EXPERIMENTS.md.
//!
//! Declared `harness = false` in Cargo.toml: a plain `fn main()` binary,
//! so it builds and runs on stable cargo (no nightly `#[bench]`).
//!
//!     cargo bench --bench paper_figures [-- <filter>]

use vta::repro;
use vta::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env();
    b.once("repro/pipelining(quick)", || {
        let r = repro::pipelining(true);
        assert!(r.speedup > 1.5, "pipelining speedup collapsed: {:.2}", r.speedup);
    });
    b.once("repro/ablation(quick)", || {
        let hw = repro::ablation(true);
        assert!(hw.last().unwrap().speedup_vs_original > 2.0);
        let sw = repro::ablation_compiler(true);
        assert!(sw.last().unwrap().speedup_vs_original > 3.0, "TPS must dominate fallback");
    });
    b.once("repro/fig2_roofline(quick)", || {
        let rows = repro::fig2(true);
        assert_eq!(rows.len(), 5);
    });
    b.once("repro/fig3_utilization(quick)", || {
        let u = repro::fig3(true, "results");
        // Quick mode (56x56) is weight-load bound; at 224x224 the full
        // run is compute-bound as in the paper (see EXPERIMENTS.md).
        assert!(u.compute > 0.15 && u.load > 0.15, "implausible utilization: {u:?}");
    });
    b.once("repro/fig10_tps", || {
        let rows = repro::fig10();
        assert!(rows.iter().all(|r| r.ratio > 3.0), "TPS must win everywhere");
    });
    b.once("repro/fig11_dbuf_bytes(quick)", || {
        let rows = repro::fig11(true);
        assert!(rows.iter().all(|r| r.reduction_pct > 0.0));
    });
    b.once("repro/fig12_dbuf_cycles(quick)", || {
        repro::fig12(true);
    });
    b.once("repro/fig13_pareto(quick)", || {
        let rows = repro::fig13(true);
        assert!(rows.iter().filter(|r| r.pareto).count() >= 2);
    });
    println!("\n{} figure harnesses complete", b.results.len());
}
