"""Layer 1 — the VTA GEMM intrinsic as a Pallas kernel.

The VTA compute core is a ``BATCH x BLOCK_IN x BLOCK_OUT`` MAC array fed
from scratchpads; its uop loops walk (accumulator, input, weight) tiles.
The TPU-idiomatic mapping (DESIGN.md §Hardware-Adaptation) expresses the
same dataflow as a grid-tiled int8->int32 matmul:

* one grid step performs the tile op ``acc[tm,tn] += x[tm,tk] @ w[tk,tn]``
  — exactly one VTA GEMM uop execution with ``tm = BATCH``,
  ``tk = BLOCK_IN``, ``tn = BLOCK_OUT`` (the MXU analog of the MAC array);
* BlockSpecs express the HBM<->VMEM schedule that VTA's LOAD instructions
  and scratchpad double buffering implement explicitly;
* the accumulator is grid-carried (revisited across the ``k`` dimension),
  mirroring VTA's accumulate-in-place scratchpad.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpret path and the
pure-jnp oracle in ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def vta_gemm(x, w, *, tile_m: int = 1, tile_k: int = 16, tile_n: int = 16):
    """Quantized matmul with the VTA tile dataflow.

    Args:
      x: ``[M, K]`` int8 (input activations, im2col'd by the caller).
      w: ``[K, N]`` int8 (weights, K-major like VTA's BLOCK_IN-major
        weight tiles).
      tile_m / tile_k / tile_n: the hardware BATCH / BLOCK_IN / BLOCK_OUT.

    Returns:
      ``[M, N]`` int32 accumulator, bit-exact with int32 reference.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % tile_m == 0, f"M={m} not a multiple of BATCH={tile_m}"
    assert k % tile_k == 0, f"K={k} not a multiple of BLOCK_IN={tile_k}"
    assert n % tile_n == 0, f"N={n} not a multiple of BLOCK_OUT={tile_n}"
    grid = (m // tile_m, n // tile_n, k // tile_k)

    def kernel(x_ref, w_ref, o_ref):
        # First visit of this (m, n) tile: zero the accumulator —
        # VTA's GEMM reset instruction.
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        # One MAC-array tile op: int8 operands, int32 accumulate.
        xi = x_ref[...].astype(jnp.int32)
        wi = w_ref[...].astype(jnp.int32)
        o_ref[...] += jax.lax.dot_general(
            xi, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_k", "tile_n"))
def vta_gemm_jit(x, w, tile_m=1, tile_k=16, tile_n=16):
    return vta_gemm(x, w, tile_m=tile_m, tile_k=tile_k, tile_n=tile_n)


def vmem_tile_bytes(tile_m: int, tile_k: int, tile_n: int) -> int:
    """Estimated VMEM working set per grid step (for the §Perf structural
    analysis): one x tile + one w tile (int8) + one int32 acc tile."""
    return tile_m * tile_k + tile_k * tile_n + 4 * tile_m * tile_n
