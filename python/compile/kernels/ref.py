"""Pure-jnp correctness oracles for the Pallas kernel and the quantized
operators. These are the build-time ground truth: pytest asserts the
Pallas kernel and the L2 model against them, and the rust stack is
verified against the AOT-compiled L2 model through PJRT.
"""

import jax
import jax.numpy as jnp


def gemm_ref(x, w):
    """int8 x int8 -> int32 matmul, the oracle for ``vta_gemm``."""
    return jax.lax.dot_general(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def requant_ref(acc, shift: int, relu: bool):
    """Hardware requantization: round-half-up shift, optional ReLU, clip
    to +-127, narrow to int8 — bit-exact with ``cpu_ref::requant`` and the
    VTA ALU sequence ADD/SHR/MAX/CLIP."""
    acc = acc.astype(jnp.int32)
    if shift > 0:
        acc = jnp.right_shift(acc + (1 << (shift - 1)), shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    return jnp.clip(acc, -127, 127).astype(jnp.int8)


def conv2d_ref(x, w, *, stride: int, pad: int, shift: int, relu: bool):
    """Quantized NCHW conv oracle via XLA's native convolution."""
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.int32,
    )
    return requant_ref(acc, shift, relu)


def add_ref(a, b, relu: bool):
    """Residual addition oracle."""
    return requant_ref(a.astype(jnp.int32) + b.astype(jnp.int32), 0, relu)


def maxpool_ref(x, *, k: int, stride: int, pad: int):
    """Max pooling with -128 border padding (the hardware pad value)."""
    return jax.lax.reduce_window(
        x,
        jnp.int8(-128),
        jax.lax.max,
        (1, 1, k, k),
        (1, 1, stride, stride),
        [(0, 0), (0, 0), (pad, pad), (pad, pad)],
    )


def global_avgpool_ref(x):
    """Global average pooling as the hardware computes it: window sum
    scaled by ``ceil(log2(h*w))`` rounding shift."""
    n, c, h, w = x.shape
    shift = max(0, (h * w - 1).bit_length())
    acc = jnp.sum(x.astype(jnp.int32), axis=(2, 3), keepdims=True)
    return requant_ref(acc, shift, False)
