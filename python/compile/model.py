"""Layer 2 — the JAX golden model of VTA's quantized computation.

Builds the accelerator's per-layer arithmetic on top of the L1 Pallas
GEMM kernel: im2col + ``vta_gemm`` + the exact ALU requantization
sequence. Lowered once by ``aot.py`` to HLO text; the rust coordinator
loads the artifacts through PJRT and checks the simulated accelerator
bit-for-bit against them. Python never runs on the request path.
"""

import jax.numpy as jnp

from .kernels.gemm import vta_gemm
from .kernels.ref import requant_ref


def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """NCHW -> ``[N*OH*OW, C*KH*KW]`` patches (zero padded borders),
    ordered (c, ky, kx) along the contraction — matching the VTA weight
    tile layout OIHWoi."""
    n, c, h, w = x.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            patch = xp[:, :, ky : ky + (oh - 1) * stride + 1 : stride,
                       kx : kx + (ow - 1) * stride + 1 : stride]
            cols.append(patch)  # [N, C, OH, OW]
    # Stack taps: [N, C, KH*KW, OH, OW] -> [N, OH, OW, C, KH*KW]
    patches = jnp.stack(cols, axis=2).reshape(n, c, kh * kw, oh, ow)
    patches = patches.transpose(0, 3, 4, 1, 2)
    return patches.reshape(n * oh * ow, c * kh * kw), oh, ow


def conv2d_vta(x, w, *, stride: int, pad: int, shift: int, relu: bool,
               tile_m: int = 1, tile_k: int = 16, tile_n: int = 16):
    """Quantized NCHW convolution through the VTA GEMM kernel.

    ``x``: [N, C, H, W] int8; ``w``: [O, C, KH, KW] int8. Channel counts
    must be multiples of the tile sizes (the compiler pads them, like the
    hardware layouts do).
    """
    n, c, h, wdim = x.shape
    o, c2, kh, kw = w.shape
    assert c == c2
    cols, oh, ow = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(o, c * kh * kw).T  # [C*KH*KW, O]
    acc = vta_gemm(cols, wmat, tile_m=tile_m, tile_k=tile_k, tile_n=tile_n)
    out = requant_ref(acc, shift, relu)  # [N*OH*OW, O]
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def dense_vta(x, w, *, shift: int, relu: bool, tile_m: int = 1,
              tile_k: int = 16, tile_n: int = 16):
    """Fully connected layer: ``x`` [N, C] int8, ``w`` [O, C] int8."""
    acc = vta_gemm(x, w.T, tile_m=tile_m, tile_k=tile_k, tile_n=tile_n)
    return requant_ref(acc, shift, relu)
