"""AOT compilation: lower the L2/L1 golden computations to HLO *text*
artifacts the rust runtime loads via PJRT.

HLO text, not serialized protos: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shapes chosen to match the rust golden tests / quickstart):
  gemm.hlo.txt          vta_gemm  x:s8[64,64]  w:s8[64,16]  -> s32[64,16]
  conv_quickstart.hlo.txt  conv2d_vta x:s8[1,16,14,14] w:s8[16,16,3,3]
                           stride 1 pad 1 shift 5 relu -> s8[1,16,14,14]
  conv_stride2.hlo.txt  conv2d_vta x:s8[1,32,12,12] w:s8[16,32,3,3]
                           stride 2 pad 1 shift 6 no-relu -> s8[1,16,6,6]
  dense.hlo.txt         dense_vta x:s8[4,64] w:s8[32,64] shift 4 -> s8[4,32]

Run via ``make artifacts`` (a no-op when outputs are newer than inputs).
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import conv2d_vta, dense_vta
from .kernels.gemm import vta_gemm

BLOCK = 16  # default VTA configuration: 1x16x16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.int8):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifacts():
    """name -> (fn, example args). Each fn returns a tuple (1-tuple)."""

    def gemm_fn(x, w):
        return (vta_gemm(x, w, tile_m=1, tile_k=BLOCK, tile_n=BLOCK),)

    def conv_q(x, w):
        return (conv2d_vta(x, w, stride=1, pad=1, shift=5, relu=True,
                           tile_m=1, tile_k=BLOCK, tile_n=BLOCK),)

    def conv_s2(x, w):
        return (conv2d_vta(x, w, stride=2, pad=1, shift=6, relu=False,
                           tile_m=1, tile_k=BLOCK, tile_n=BLOCK),)

    def dense_fn(x, w):
        return (dense_vta(x, w, shift=4, relu=False,
                          tile_m=1, tile_k=BLOCK, tile_n=BLOCK),)

    return {
        "gemm": (gemm_fn, (spec((64, 64)), spec((64, BLOCK)))),
        "conv_quickstart": (conv_q, (spec((1, BLOCK, 14, 14)), spec((BLOCK, BLOCK, 3, 3)))),
        "conv_stride2": (conv_s2, (spec((1, 32, 12, 12)), spec((BLOCK, 32, 3, 3)))),
        "dense": (dense_fn, (spec((4, 64)), spec((32, 64)))),
    }


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="emit a single artifact")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, example_args) in artifacts().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
