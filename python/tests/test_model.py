"""L2 correctness: the conv/dense golden model (Pallas-backed) against
the pure-XLA reference conv, plus requantization semantics vs the rust
contract.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.model import conv2d_vta, dense_vta, im2col
from compile.kernels import ref


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-8, 8, size=shape, dtype=np.int64).astype(np.int8))


def test_conv_quickstart_shape():
    rng = np.random.default_rng(0)
    x = rand_i8(rng, (1, 16, 14, 14))
    w = rand_i8(rng, (16, 16, 3, 3))
    out = conv2d_vta(x, w, stride=1, pad=1, shift=5, relu=True)
    assert out.shape == (1, 16, 14, 14)
    expect = ref.conv2d_ref(x, w, stride=1, pad=1, shift=5, relu=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@settings(max_examples=12, deadline=None)
@given(
    c=st.sampled_from([16, 32]),
    o=st.sampled_from([16, 32]),
    hw=st.sampled_from([6, 8, 12]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    relu=st.booleans(),
    shift=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_conv_sweep(c, o, hw, k, stride, relu, shift, seed):
    pad = 1 if k == 3 else 0
    rng = np.random.default_rng(seed)
    x = rand_i8(rng, (1, c, hw, hw))
    w = rand_i8(rng, (o, c, k, k))
    out = conv2d_vta(x, w, stride=stride, pad=pad, shift=shift, relu=relu)
    expect = ref.conv2d_ref(x, w, stride=stride, pad=pad, shift=shift, relu=relu)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_im2col_ordering_matches_weight_layout():
    # im2col contraction order must be (c, ky, kx) to match w.reshape.
    rng = np.random.default_rng(3)
    x = rand_i8(rng, (1, 4, 5, 5))
    cols, oh, ow = im2col(x, 3, 3, 1, 1)
    assert cols.shape == (25, 36)
    assert (oh, ow) == (5, 5)


def test_requant_matches_rust_contract():
    # Mirrors rust cpu_ref::requant unit tests bit-for-bit.
    acc = jnp.asarray([5, 6, -5, 1000, -1000], jnp.int32)
    out = ref.requant_ref(acc, 2, False)
    np.testing.assert_array_equal(np.asarray(out), [1, 2, -1, 127, -127])
    out = ref.requant_ref(jnp.asarray([-5], jnp.int32), 0, True)
    np.testing.assert_array_equal(np.asarray(out), [0])


def test_dense():
    rng = np.random.default_rng(4)
    x = rand_i8(rng, (4, 64))
    w = rand_i8(rng, (32, 64))
    out = dense_vta(x, w, shift=4, relu=False)
    acc = ref.gemm_ref(x, w.T)
    expect = ref.requant_ref(acc, 4, False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_maxpool_ref_neg128_padding():
    x = jnp.full((1, 1, 2, 2), -100, jnp.int8)
    out = ref.maxpool_ref(x, k=3, stride=2, pad=1)
    assert np.asarray(out).flatten().tolist() == [-100]


def test_global_avgpool_ref_shift():
    x = jnp.full((1, 1, 2, 2), 4, jnp.int8)
    out = ref.global_avgpool_ref(x)
    assert int(out[0, 0, 0, 0]) == 4  # (16+2)>>2
