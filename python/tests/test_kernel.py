"""L1 correctness: the Pallas VTA-GEMM kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and tile geometries (the hardware BATCH /
BLOCK_IN / BLOCK_OUT space) and asserts bit-exact int32 equality — this
is the CORE kernel correctness signal.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gemm import vta_gemm, vmem_tile_bytes
from compile.kernels import ref


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8))


def test_basic_16x16():
    rng = np.random.default_rng(0)
    x = rand_i8(rng, (16, 64))
    w = rand_i8(rng, (64, 16))
    out = vta_gemm(x, w, tile_m=1, tile_k=16, tile_n=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.gemm_ref(x, w)))


def test_accumulation_over_k_grid():
    # K spans multiple grid steps: exercises the grid-carried accumulator
    # (VTA's accumulate-in-place scratchpad).
    rng = np.random.default_rng(1)
    x = rand_i8(rng, (4, 128))
    w = rand_i8(rng, (128, 32))
    out = vta_gemm(x, w, tile_m=2, tile_k=16, tile_n=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.gemm_ref(x, w)))


def test_extreme_values_no_overflow():
    # All -128 * -128 over K=256: 256 * 16384 = 4.2M, well inside int32.
    x = jnp.full((8, 256), -128, jnp.int8)
    w = jnp.full((256, 16), -128, jnp.int8)
    out = vta_gemm(x, w, tile_m=1, tile_k=32, tile_n=16)
    assert int(out[0, 0]) == 256 * 128 * 128
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.gemm_ref(x, w)))


@settings(max_examples=25, deadline=None)
@given(
    mb=st.integers(1, 4),
    kb=st.integers(1, 4),
    nb=st.integers(1, 3),
    tile_m=st.sampled_from([1, 2, 4]),
    tile_k=st.sampled_from([4, 8, 16, 32]),
    tile_n=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(mb, kb, nb, tile_m, tile_k, tile_n, seed):
    """Sweep the (BATCH, BLOCK_IN, BLOCK_OUT) hardware space with random
    multiples of each tile dimension."""
    rng = np.random.default_rng(seed)
    m, k, n = mb * tile_m, kb * tile_k, nb * tile_n
    x = rand_i8(rng, (m, k))
    w = rand_i8(rng, (k, n))
    out = vta_gemm(x, w, tile_m=tile_m, tile_k=tile_k, tile_n=tile_n)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.gemm_ref(x, w)))


@pytest.mark.parametrize("bad_dim", ["m", "k", "n"])
def test_misaligned_shapes_rejected(bad_dim):
    shapes = {"m": (17, 16, 16), "k": (16, 17, 16), "n": (16, 16, 17)}
    m, k, n = shapes[bad_dim]
    x = jnp.zeros((m, k), jnp.int8)
    w = jnp.zeros((k, n), jnp.int8)
    with pytest.raises(AssertionError):
        vta_gemm(x, w, tile_m=4, tile_k=16, tile_n=16)


def test_vmem_estimate():
    # Default VTA tile: 16 + 256 + 64 bytes? tile_m=1: 1*16 + 16*16 + 4*16.
    assert vmem_tile_bytes(1, 16, 16) == 16 + 256 + 64
    # The big 1x64x64 config still fits VMEM trivially per step.
    assert vmem_tile_bytes(1, 64, 64) < 32 * 1024
