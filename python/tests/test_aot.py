"""AOT path tests: every artifact lowers, contains the expected entry
computation layout, and the HLO text is consumable (the interchange
contract with the rust PJRT loader)."""

import os
import subprocess
import sys

import jax

from compile.aot import artifacts, to_hlo_text


def test_every_artifact_lowers_to_hlo_text():
    for name, (fn, args) in artifacts().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ROOT" in text, f"{name}: no root instruction"


def test_entry_layouts_match_rust_expectations():
    # The rust golden tests feed s8 tensors of exactly these shapes.
    expect = {
        "gemm": ("s8[64,64]", "s8[64,16]", "s32[64,16]"),
        "conv_quickstart": ("s8[1,16,14,14]", "s8[16,16,3,3]", "s8[1,16,14,14]"),
        "conv_stride2": ("s8[1,32,12,12]", "s8[16,32,3,3]", "s8[1,16,6,6]"),
        "dense": ("s8[4,64]", "s8[32,64]", "s8[4,32]"),
    }
    for name, (fn, args) in artifacts().items():
        text = to_hlo_text(jax.jit(fn).lower(*args))
        header = text.splitlines()[0]
        for frag in expect[name]:
            assert frag in header, f"{name}: '{frag}' not in entry layout: {header}"


def test_aot_main_writes_files(tmp_path):
    out = str(tmp_path)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, "--only", "gemm"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, res.stderr
    assert os.path.exists(os.path.join(out, "gemm.hlo.txt"))
