//! Experiment reproduction harness: one driver per paper table/figure.
//!
//! Each driver runs the real stack (compiler + simulator) and prints the
//! same rows/series the paper reports, returning structured results so
//! tests and benches can assert on the *shape* of the reproduction
//! (who wins, by roughly what factor). See EXPERIMENTS.md for the
//! recorded paper-vs-measured outcomes.

use crate::analysis::{area, gantt, roofline};
use crate::compiler::graph::Graph;
use crate::config::{presets, VtaConfig};
use crate::engine::BackendKind;
use crate::runtime::{Session, SessionOptions};
use crate::store::ArtifactStore;
use crate::sweep;
use crate::util::fsx::atomic_write;
use crate::util::rng::Pcg32;
use crate::util::stats;
use crate::workloads;
use std::sync::Arc;

/// Run a graph on tsim under `opts`, returning the finished session.
fn run_tsim(graph: &Graph, cfg: &VtaConfig, opts: SessionOptions, seed: u64) -> Session {
    run_sim(graph, cfg, SessionOptions { backend: BackendKind::Tsim, ..opts }, seed)
}

fn run_fsim(graph: &Graph, cfg: &VtaConfig, opts: SessionOptions, seed: u64) -> Session {
    run_sim(graph, cfg, SessionOptions { backend: BackendKind::Fsim, ..opts }, seed)
}

fn run_sim(graph: &Graph, cfg: &VtaConfig, opts: SessionOptions, seed: u64) -> Session {
    let mut s = Session::new(cfg, opts).expect("repro presets are valid configs");
    let mut rng = Pcg32::seeded(seed);
    let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
    s.run_graph(graph, &input).expect("repro workloads are well-formed");
    s
}

fn resnet_hw(quick: bool) -> usize {
    if quick {
        56
    } else {
        224
    }
}

// ---------------------------------------------------------------- headline

#[derive(Debug, Clone)]
pub struct PipeliningResult {
    pub original_cycles: u64,
    pub pipelined_cycles: u64,
    pub speedup: f64,
    pub area_ratio: f64,
}

/// Headline result: fully pipelined GEMM+ALU vs the published VTA on the
/// default 1×16×16 configuration, ResNet-18 (paper: ~4.9× fewer cycles
/// with minimal area increase).
pub fn pipelining(quick: bool) -> PipeliningResult {
    let g = workloads::resnet(18, resnet_hw(quick), 1);
    let orig = run_tsim(&g, &presets::original_config(), SessionOptions::default(), 7);
    let pipe = run_tsim(&g, &presets::default_config(), SessionOptions::default(), 7);
    let result = PipeliningResult {
        original_cycles: orig.cycles(),
        pipelined_cycles: pipe.cycles(),
        speedup: orig.cycles() as f64 / pipe.cycles() as f64,
        area_ratio: area::scaled_area(&presets::default_config())
            / area::scaled_area(&presets::original_config()),
    };
    println!("== Pipelining the execution units (paper: ~4.9x, minimal area) ==");
    println!("  original  (GEMM II=4, ALU II=4/5): {:>12} cycles", result.original_cycles);
    println!("  pipelined (GEMM II=1, ALU II=1/2): {:>12} cycles", result.pipelined_cycles);
    println!("  speedup: {:.2}x   area ratio: {:.3}x", result.speedup, result.area_ratio);
    result
}

// ---------------------------------------------------------------- fig 2

/// Roofline chart (Fig 2): attainable vs measured MACs/cycle across
/// configurations with varying compute, bandwidth and scratchpads.
pub fn fig2(quick: bool) -> Vec<(VtaConfig, roofline::MeasuredPoint)> {
    let configs = vec![
        presets::default_config(),
        presets::scaled_config(1, 16, 16, 2, 32),
        presets::scaled_config(1, 32, 32, 2, 16),
        presets::scaled_config(1, 32, 32, 2, 64),
        presets::scaled_config(1, 64, 64, 2, 64),
    ];
    let g = workloads::resnet(18, resnet_hw(quick), 1);
    let mut rows = Vec::new();
    for cfg in configs {
        let s = run_tsim(&g, &cfg, SessionOptions::default(), 7);
        let report = s.perf_report().unwrap();
        rows.push((cfg.clone(), roofline::measure(&cfg.tag(), &cfg, &report)));
    }
    println!("== Roofline (Fig 2): ResNet-18 across configurations ==");
    print!("{}", roofline::render_table(&rows));
    rows
}

// ---------------------------------------------------------------- fig 3/4

/// Process-utilization visualization (Figs 3 and 4): full-workload gantt
/// plus a zoomed window, printed as ASCII and written as SVG.
pub fn fig3(quick: bool, out_dir: &str) -> gantt::Utilization {
    let g = workloads::resnet(18, resnet_hw(quick), 1);
    let cfg = presets::default_config();
    let s = run_tsim(&g, &cfg, SessionOptions { trace: true, ..Default::default() }, 7);
    let tsim = s.tsim().unwrap();
    let end = s.cycles();
    let util = gantt::utilization(&tsim.trace, 0, end);
    println!("== Process utilization (Fig 3): full ResNet-18 ==");
    print!("{}", gantt::ascii(&tsim.trace, 0, end, 100));
    println!(
        "load {:.0}% | compute {:.0}% (gemm {:.0}%, alu {:.0}%) | store {:.0}%",
        util.load * 100.0,
        util.compute * 100.0,
        util.compute_gemm * 100.0,
        util.compute_alu * 100.0,
        util.store * 100.0
    );
    // Fig 4: zoom into three layers mid-network.
    let marks = &tsim.trace.markers;
    if marks.len() >= 8 {
        let w0 = marks[4].0;
        let w1 = marks[7].0;
        println!("== Zoom (Fig 4): three layers ==");
        print!("{}", gantt::ascii(&tsim.trace, w0, w1, 100));
    }
    std::fs::create_dir_all(out_dir).ok();
    let full = gantt::svg(&tsim.trace, 0, end, 1200);
    atomic_write(format!("{out_dir}/fig3_utilization.svg").as_ref(), full.as_bytes()).ok();
    if marks.len() >= 8 {
        let zoom = gantt::svg(&tsim.trace, marks[4].0, marks[7].0, 1200);
        atomic_write(format!("{out_dir}/fig4_zoom.svg").as_ref(), zoom.as_bytes()).ok();
    }
    println!("(SVGs written to {out_dir}/)");
    util
}

// ---------------------------------------------------------------- fig 10

#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub layer: String,
    pub fallback_bytes: u64,
    pub tps_bytes: u64,
    pub ratio: f64,
}

/// TPS vs fallback DRAM traffic (Fig 10): ResNet-18 convs C2–C11 on the
/// BLOCK=32 configuration (paper: 20×–400× reduction).
pub fn fig10() -> Vec<Fig10Row> {
    let cfg = presets::scaled_config(1, 32, 32, 2, 32);
    let mut rows = Vec::new();
    println!("== TPS DRAM-byte reduction (Fig 10), BLOCK=32 ==");
    println!("{:<6} {:>14} {:>14} {:>8}", "layer", "fallback B", "TPS B", "ratio");
    for (name, spec) in crate::compiler::tps::resnet18_convs() {
        let mut bytes = [0u64; 2];
        for (i, tps) in [false, true].into_iter().enumerate() {
            let mut g = Graph::new(&name, crate::compiler::layout::Shape::new(spec.c_in, spec.h, spec.w));
            let mut rng = Pcg32::seeded(77);
            g.add(
                "conv",
                crate::compiler::graph::Op::Conv {
                    c_out: spec.c_out,
                    k: spec.kh,
                    stride: spec.sh,
                    pad: spec.ph,
                    shift: crate::compiler::cpu_ref::default_shift(spec.c_in * spec.kh * spec.kw),
                    relu: true,
                    weights: rng.i8_vec(spec.c_out * spec.c_in * spec.kh * spec.kw),
                },
                vec![0],
            );
            let s = run_fsim(&g, &cfg, SessionOptions { tps, ..Default::default() }, 9);
            let c = s.layer_stats.last().unwrap();
            // Count data loads (inp+wgt+acc), as the paper's DRAM-traffic
            // metric does.
            bytes[i] = c.dram_rd;
        }
        let ratio = bytes[0] as f64 / bytes[1] as f64;
        println!("{:<6} {:>14} {:>14} {:>8.1}", name, bytes[0], bytes[1], ratio);
        rows.push(Fig10Row { layer: name, fallback_bytes: bytes[0], tps_bytes: bytes[1], ratio });
    }
    let gm = stats::geomean(&rows.iter().map(|r| r.ratio).collect::<Vec<_>>());
    println!("geomean ratio: {gm:.1}x");
    rows
}

// ---------------------------------------------------------------- fig 11

#[derive(Debug, Clone)]
pub struct Fig11Row {
    pub net: String,
    pub config: String,
    pub bytes_redundant: u64,
    pub bytes_reuse: u64,
    pub reduction_pct: f64,
}

/// Double-buffering redundant-load elimination: DRAM bytes into the inp
/// and wgt scratchpads, original vs improved virtual threading (Fig 11,
/// paper: ≈50% total reduction).
pub fn fig11(quick: bool) -> Vec<Fig11Row> {
    let depths: &[usize] = if quick { &[18, 34] } else { &[18, 34, 50, 101] };
    let configs =
        [presets::default_config(), presets::scaled_config(1, 32, 32, 2, 8)];
    let mut rows = Vec::new();
    println!("== Double-buffer load reduction (Fig 11) ==");
    println!("{:<10} {:<16} {:>14} {:>14} {:>7}", "net", "config", "redundant B", "reuse B", "red%");
    for depth in depths {
        let g = workloads::resnet(*depth, resnet_hw(quick), 1);
        for cfg in &configs {
            let mut bytes = [0u64; 2];
            for (i, reuse) in [false, true].into_iter().enumerate() {
                let s = run_fsim(
                    &g,
                    cfg,
                    SessionOptions { dbuf_reuse: reuse, ..Default::default() },
                    9,
                );
                let c = s.counters_inp_wgt();
                bytes[i] = c;
            }
            let red = 100.0 * (1.0 - bytes[1] as f64 / bytes[0] as f64);
            println!(
                "{:<10} {:<16} {:>14} {:>14} {:>6.1}%",
                g.name,
                cfg.tag(),
                bytes[0],
                bytes[1],
                red
            );
            rows.push(Fig11Row {
                net: g.name.clone(),
                config: cfg.tag(),
                bytes_redundant: bytes[0],
                bytes_reuse: bytes[1],
                reduction_pct: red,
            });
        }
    }
    rows
}

// ---------------------------------------------------------------- fig 12

#[derive(Debug, Clone)]
pub struct Fig12Row {
    pub net: String,
    pub config: String,
    pub cycles_redundant: u64,
    pub cycles_reuse: u64,
    /// Positive = improvement.
    pub reduction_pct: f64,
}

/// Cycle-count impact of the double-buffering fix (Fig 12): small nets
/// on small (compute-bound) configs may regress slightly; large nets on
/// compute-heavy configs gain ~10%.
pub fn fig12(quick: bool) -> Vec<Fig12Row> {
    let depths: &[usize] = if quick { &[18, 50] } else { &[18, 34, 50, 101] };
    let configs = [
        presets::default_config(),                 // 256 MACs
        presets::scaled_config(1, 32, 32, 2, 16),  // 1024 MACs
        presets::scaled_config(1, 64, 64, 2, 32),  // 4096 MACs
    ];
    let mut rows = Vec::new();
    println!("== Double-buffer cycle impact (Fig 12) ==");
    println!("{:<10} {:<18} {:>12} {:>12} {:>7}", "net", "config", "redundant", "reuse", "red%");
    for depth in depths {
        let g = workloads::resnet(*depth, resnet_hw(quick), 1);
        for cfg in &configs {
            let mut cycles = [0u64; 2];
            for (i, reuse) in [false, true].into_iter().enumerate() {
                let s = run_tsim(
                    &g,
                    cfg,
                    SessionOptions { dbuf_reuse: reuse, ..Default::default() },
                    9,
                );
                cycles[i] = s.cycles();
            }
            let red = 100.0 * (1.0 - cycles[1] as f64 / cycles[0] as f64);
            println!(
                "{:<10} {:<18} {:>12} {:>12} {:>6.1}%",
                g.name,
                cfg.tag(),
                cycles[0],
                cycles[1],
                red
            );
            rows.push(Fig12Row {
                net: g.name.clone(),
                config: cfg.tag(),
                cycles_redundant: cycles[0],
                cycles_reuse: cycles[1],
                reduction_pct: red,
            });
        }
    }
    rows
}

// ---------------------------------------------------------------- fig 13

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub config: String,
    pub block: usize,
    pub cycles: u64,
    pub scaled_area: f64,
    pub pareto: bool,
}

/// The design-space sweep (Fig 13): cycle count vs scaled area for
/// ResNet-18 over MAC shape × memory width × scratchpad scaling. Paper:
/// ~12× area buys a further ~11.5× cycle reduction past the pipelined
/// default, in three MAC-shape clusters.
///
/// Runs on the parallel sweep engine with one worker per core; the
/// engine is deterministic, so rows and frontier are identical to the
/// historical serial loop for any worker count (see `rust/tests/
/// sweep_engine.rs`).
pub fn fig13(quick: bool) -> Vec<Fig13Row> {
    fig13_jobs(quick, 0)
}

/// Fig 13 with an explicit worker count (`0` = one per core).
pub fn fig13_jobs(quick: bool, jobs: usize) -> Vec<Fig13Row> {
    fig13_with_store(quick, jobs, None)
}

/// Fig 13 against an artifact store (`vta repro fig13 --store`): every
/// measured point becomes (or reuses) a store `PointMeasurement`, so
/// sweeps, the figure, and serve warmups share one measurement pool —
/// a figure re-run after a sweep of the same grid simulates nothing.
pub fn fig13_with_store(
    quick: bool,
    jobs: usize,
    store: Option<Arc<ArtifactStore>>,
) -> Vec<Fig13Row> {
    let spec = sweep::GridSpec::fig13(quick).to_sweep_spec();
    println!("== Design-space sweep (Fig 13): ResNet-18 ==");
    // Stream progress as points land (the full grid runs for hours);
    // the row table below is re-printed in grid order at the end.
    // The figure consumes only cycles/area, so run the memoized
    // timing-only backend — bit-identical metrics (the invariant
    // rust/tests/sweep_engine.rs asserts), at a fraction of the wall
    // clock: repeated layer shapes across the grid simulate once.
    let opts = sweep::SweepOptions {
        jobs,
        progress: true,
        memo: true,
        backend: BackendKind::TsimTiming,
        store,
        ..Default::default()
    };
    let outcome = sweep::run(&spec, &opts).expect("fig13 sweep failed (store I/O?)");
    println!("{:<22} {:>6} {:>12} {:>10}", "config", "block", "cycles", "area");
    let mut rows = Vec::new();
    for (i, r) in outcome.results.iter().enumerate() {
        println!(
            "{:<22} {:>6} {:>12} {:>10.2}",
            r.config.tag(),
            r.config.block_in,
            r.cycles,
            r.scaled_area
        );
        rows.push(Fig13Row {
            config: r.config.tag(),
            block: r.config.block_in,
            cycles: r.cycles,
            scaled_area: r.scaled_area,
            pareto: outcome.front.contains(i),
        });
    }
    let best = rows.iter().filter(|r| r.pareto).map(|r| r.config.clone()).collect::<Vec<_>>();
    println!("pareto frontier: {}", best.join(", "));
    rows
}

/// Fig 13 on the two-phase engine: phase 1 scores the whole grid with
/// the analytical cycle model (`crate::model`) and keeps only the
/// epsilon-band neighborhood of the predicted frontier; phase 2 runs
/// real tsim (memo + timing-only fast path) on the survivors. Every
/// returned row is tsim-measured — pruned points are never simulated
/// and never reported, so the frontier cannot contain model estimates.
/// Returns survivor rows in grid order with Pareto marks.
pub fn fig13_two_phase(quick: bool, jobs: usize, epsilon: f64) -> Vec<Fig13Row> {
    let spec = sweep::GridSpec::fig13(quick).to_sweep_spec();
    let total = spec.jobs().len();
    println!("== Design-space sweep (Fig 13, two-phase): ResNet-18 ==");
    let opts = sweep::SweepOptions {
        jobs,
        progress: true,
        memo: true,
        backend: BackendKind::TsimTiming,
        two_phase: Some(sweep::TwoPhaseOptions { epsilon }),
        ..Default::default()
    };
    let outcome = sweep::run(&spec, &opts).expect("in-memory sweep performs no I/O");
    println!(
        "phase 1: {} grid points scored, {} pruned, {} evaluated by tsim \
         ({:.1}x fewer evaluations, epsilon {:.2})",
        total,
        outcome.pruned.len(),
        outcome.results.len(),
        outcome.prune_factor(),
        epsilon
    );
    println!("{:<22} {:>6} {:>12} {:>12} {:>10}", "config", "block", "cycles", "predicted", "area");
    let mut rows = Vec::new();
    for (i, r) in outcome.results.iter().enumerate() {
        println!(
            "{:<22} {:>6} {:>12} {:>12} {:>10.2}",
            r.config.tag(),
            r.config.block_in,
            r.cycles,
            r.predicted_cycles.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            r.scaled_area
        );
        rows.push(Fig13Row {
            config: r.config.tag(),
            block: r.config.block_in,
            cycles: r.cycles,
            scaled_area: r.scaled_area,
            pareto: outcome.front.contains(i),
        });
    }
    let best = rows.iter().filter(|r| r.pareto).map(|r| r.config.clone()).collect::<Vec<_>>();
    println!("pareto frontier (100% tsim-measured): {}", best.join(", "));
    rows
}

/// Mark points on the (area ↓, cycles ↓) Pareto frontier.
pub fn mark_pareto(rows: &mut [Fig13Row]) {
    for i in 0..rows.len() {
        let dominated = rows.iter().enumerate().any(|(j, other)| {
            j != i
                && other.cycles <= rows[i].cycles
                && other.scaled_area <= rows[i].scaled_area
                && (other.cycles < rows[i].cycles || other.scaled_area < rows[i].scaled_area)
        });
        rows[i].pareto = !dominated;
    }
}

impl Session {
    /// DRAM bytes loaded into the input + weight scratchpads (the Fig 11
    /// metric).
    pub fn counters_inp_wgt(&self) -> u64 {
        let c = self.exec_counters();
        c.load_bytes_inp + c.load_bytes_wgt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_marking() {
        let mut rows = vec![
            Fig13Row { config: "a".into(), block: 16, cycles: 100, scaled_area: 1.0, pareto: false },
            Fig13Row { config: "b".into(), block: 16, cycles: 50, scaled_area: 2.0, pareto: false },
            Fig13Row { config: "c".into(), block: 16, cycles: 120, scaled_area: 1.5, pareto: false },
            Fig13Row { config: "d".into(), block: 16, cycles: 50, scaled_area: 3.0, pareto: false },
        ];
        mark_pareto(&mut rows);
        assert!(rows[0].pareto);
        assert!(rows[1].pareto);
        assert!(!rows[2].pareto, "dominated by a");
        assert!(!rows[3].pareto, "dominated by b");
    }
}

// ---------------------------------------------------------------- ablation

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub cycles: u64,
    pub speedup_vs_original: f64,
}

/// Ablation of the paper's incremental enhancements (§IV-A applied them
/// greedily: GEMM pipelining first, then ALU, then the memory system):
/// each row enables one more feature on top of the published VTA.
pub fn ablation(quick: bool) -> Vec<AblationRow> {
    let g = workloads::resnet(18, resnet_hw(quick), 1);
    let base = presets::original_config();
    let steps: Vec<(&str, VtaConfig)> = vec![
        ("original (II=4/5, 1 tag)", base.clone()),
        ("+ pipelined GEMM (II=1)", VtaConfig { gemm_pipelined: true, ..base.clone() }),
        (
            "+ pipelined ALU (II=1/2)",
            VtaConfig { gemm_pipelined: true, alu_pipelined: true, ..base.clone() },
        ),
        (
            "+ VME outstanding reqs (8 tags)",
            VtaConfig {
                gemm_pipelined: true,
                alu_pipelined: true,
                vme_inflight: 8,
                ..base.clone()
            },
        ),
        (
            "+ wide memory (32B/cyc)",
            VtaConfig {
                gemm_pipelined: true,
                alu_pipelined: true,
                vme_inflight: 8,
                axi_bytes: 32,
                ..base
            },
        ),
    ];
    let mut rows = Vec::new();
    println!("== Ablation: incremental §IV-A enhancements (ResNet-18) ==");
    let mut original = 0u64;
    for (label, cfg) in steps {
        let s = run_tsim(&g, &cfg, SessionOptions::default(), 7);
        let cycles = s.cycles();
        if original == 0 {
            original = cycles;
        }
        let speedup = original as f64 / cycles as f64;
        println!("{:<34} {:>12} cycles   {:>5.2}x", label, cycles, speedup);
        rows.push(AblationRow { label: label.to_string(), cycles, speedup_vs_original: speedup });
    }
    rows
}

/// Compiler-feature ablation: TPS and double-buffer reuse toggled
/// independently on the default config (the DESIGN.md design-choice
/// matrix).
pub fn ablation_compiler(quick: bool) -> Vec<AblationRow> {
    let g = workloads::resnet(18, resnet_hw(quick), 1);
    let cfg = presets::default_config();
    let combos = [
        ("fallback schedule, no reuse", false, false),
        ("fallback schedule, reuse", false, true),
        ("TPS, no reuse", true, false),
        ("TPS + reuse (shipping)", true, true),
    ];
    let mut rows = Vec::new();
    println!("== Ablation: compiler features (ResNet-18, default config) ==");
    let mut worst = 0u64;
    for (label, tps, reuse) in combos {
        let s = run_tsim(
            &g,
            &cfg,
            SessionOptions { tps, dbuf_reuse: reuse, ..Default::default() },
            7,
        );
        let cycles = s.cycles();
        if worst == 0 {
            worst = cycles;
        }
        let speedup = worst as f64 / cycles as f64;
        println!("{:<34} {:>12} cycles   {:>5.2}x", label, cycles, speedup);
        rows.push(AblationRow { label: label.to_string(), cycles, speedup_vs_original: speedup });
    }
    rows
}
