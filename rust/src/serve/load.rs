//! Open-loop load generation for the serving runtime: synthetic arrival
//! processes (seeded, so every run is exactly reproducible) and recorded
//! request traces (JSONL, one request per line).
//!
//! The generator is *open-loop*: arrival times are drawn from the
//! process up front and never react to service times — the standard
//! methodology for measuring tail latency (a closed loop would
//! self-throttle exactly when the system is slowest, hiding the queue).
//! A synthetic trace is just a `Vec<Request>`; [`write_trace`] /
//! [`read_trace`] round-trip it through JSONL so a synthetic run can be
//! archived and replayed (`vta serve --replay`), and external traces can
//! be produced by any tool that writes the same three fields.

use crate::engine::VtaError;
use crate::util::fsx::atomic_write;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// One inference request: who arrives when, against which pooled
/// workload, with which input seed. The request's identity is its index
/// in the trace (arrival order breaks timestamp ties).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Arrival timestamp in virtual microseconds.
    pub t_us: u64,
    /// Workload id (`WorkloadSpec::id`), the session-pool key.
    pub workload: String,
    /// Input-data seed for this request's evaluation.
    pub seed: u64,
}

/// A synthetic arrival process, parsed from the CLI's
/// `--arrival <kind>:<rate>` syntax.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalSpec {
    /// Poisson arrivals (exponential inter-arrival times) at `rate`
    /// requests per second — the standard open-system traffic model.
    Poisson { rate_per_s: f64 },
    /// Deterministic arrivals at a fixed `1/rate` spacing.
    Uniform { rate_per_s: f64 },
}

impl ArrivalSpec {
    /// Parse `poisson:<rate>` or `uniform:<rate>` (rate in requests per
    /// second, must be positive and finite). Every rejection is a typed
    /// [`VtaError::InvalidRequest`] quoting the offending spec, so the
    /// CLI surfaces exactly what was typed.
    pub fn parse(s: &str) -> Result<ArrivalSpec, VtaError> {
        let (kind, rate) = s.split_once(':').ok_or_else(|| {
            VtaError::InvalidRequest(format!(
                "arrival spec '{s}' must be <kind>:<rate>, e.g. poisson:500"
            ))
        })?;
        let rate_per_s: f64 = rate.parse().map_err(|_| {
            VtaError::InvalidRequest(format!(
                "arrival spec '{s}': rate '{rate}' is not a number"
            ))
        })?;
        if !rate_per_s.is_finite() || rate_per_s <= 0.0 {
            return Err(VtaError::InvalidRequest(format!(
                "arrival spec '{s}': rate must be positive and finite, got {rate_per_s}"
            )));
        }
        match kind {
            "poisson" => Ok(ArrivalSpec::Poisson { rate_per_s }),
            "uniform" => Ok(ArrivalSpec::Uniform { rate_per_s }),
            other => Err(VtaError::InvalidRequest(format!(
                "arrival spec '{s}': unknown process '{other}' (expected poisson or uniform)"
            ))),
        }
    }

    pub fn rate_per_s(&self) -> f64 {
        match *self {
            ArrivalSpec::Poisson { rate_per_s } | ArrivalSpec::Uniform { rate_per_s } => {
                rate_per_s
            }
        }
    }
}

/// Generate `requests` arrivals from the process, spread across the
/// given workload ids (uniformly at random for a mixed pool), with
/// per-request input seeds — all drawn from one seeded PCG32 stream, so
/// the trace is a pure function of `(spec, workloads, requests, seed)`.
pub fn synth_trace(
    spec: &ArrivalSpec,
    workloads: &[String],
    requests: usize,
    seed: u64,
) -> Result<Vec<Request>, VtaError> {
    if workloads.is_empty() {
        return Err(VtaError::InvalidRequest(
            "cannot generate load without at least one workload".into(),
        ));
    }
    let mut rng = Pcg32::seeded(seed);
    let mut trace = Vec::with_capacity(requests);
    let mut t = 0.0f64;
    let mean_gap_us = 1e6 / spec.rate_per_s();
    for _ in 0..requests {
        let gap = match spec {
            // Inverse-CDF exponential sample; 1 - f64() is in (0, 1],
            // so ln never sees zero.
            ArrivalSpec::Poisson { .. } => -(1.0 - rng.f64()).ln() * mean_gap_us,
            ArrivalSpec::Uniform { .. } => mean_gap_us,
        };
        t += gap;
        trace.push(Request {
            t_us: t as u64,
            workload: rng.choose(workloads).clone(),
            seed: rng.next_u64(),
        });
    }
    Ok(trace)
}

/// Write a trace as JSONL: `{"seed":…,"t_us":…,"workload":"…"}` per
/// line (keys sorted — the codec's deterministic-object property).
/// `seed` is a full-range `u64` serialized through JSON's signed i64
/// (seeds ≥ 2^63 appear negative on disk); [`read_trace`] reverses the
/// reinterpretation bit-exactly. Atomic ([`atomic_write`]): a crash
/// mid-write never leaves a truncated trace to replay.
pub fn write_trace(path: &Path, trace: &[Request]) -> Result<(), VtaError> {
    let mut out = String::new();
    for r in trace {
        let line = obj([
            ("t_us", Json::Int(r.t_us as i64)),
            ("workload", Json::Str(r.workload.clone())),
            ("seed", Json::Int(r.seed as i64)),
        ]);
        out.push_str(&line.to_string_compact());
        out.push('\n');
    }
    atomic_write(path, out.as_bytes())?;
    Ok(())
}

/// Read a JSONL trace. Every non-empty line must carry a nonnegative
/// `t_us` timestamp and a `workload`; `seed` defaults to the 0-based
/// line index and, when present, is reinterpreted bit-exactly from the
/// signed on-disk form (see [`write_trace`]). Requests are sorted by
/// arrival time (stably, so equal timestamps keep file order) —
/// replaying an archived trace is deterministic regardless of how it
/// was recorded. A trace file that cannot be opened is an
/// [`VtaError::InvalidRequest`] naming the path (the `--replay` token
/// was wrong), not a bare I/O error.
pub fn read_trace(path: &Path) -> Result<Vec<Request>, VtaError> {
    let file = std::fs::File::open(path).map_err(|e| {
        VtaError::InvalidRequest(format!("cannot read trace '{}': {e}", path.display()))
    })?;
    let reader = BufReader::new(file);
    let mut trace = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| {
            VtaError::InvalidRequest(format!("trace line {}: {e}", lineno + 1))
        })?;
        let t_us = match j.get("t_us").and_then(|v| v.as_i64()) {
            Some(t) if t >= 0 => t as u64,
            Some(t) => {
                return Err(VtaError::InvalidRequest(format!(
                    "trace line {}: t_us must be a nonnegative timestamp, got {t}",
                    lineno + 1
                )))
            }
            None => {
                return Err(VtaError::InvalidRequest(format!(
                    "trace line {}: missing t_us",
                    lineno + 1
                )))
            }
        };
        let workload = j
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or_else(|| {
                VtaError::InvalidRequest(format!(
                    "trace line {}: missing workload",
                    lineno + 1
                ))
            })?
            .to_string();
        // Seeds are strict: a present seed must be an exact 64-bit
        // integer (reinterpreted bit-exactly from the signed on-disk
        // form). Anything else — a float that overflowed i64, a string
        // — is rejected rather than silently substituted, so replays
        // of external traces are reproducible or loudly refused.
        let seed = match j.get("seed") {
            None => lineno as u64,
            Some(Json::Int(v)) => *v as u64,
            Some(other) => {
                return Err(VtaError::InvalidRequest(format!(
                    "trace line {}: seed must be a 64-bit integer, got {other:?}",
                    lineno + 1
                )))
            }
        };
        trace.push(Request { t_us, workload, seed });
    }
    trace.sort_by_key(|r| r.t_us);
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn arrival_spec_parses_and_rejects() {
        assert_eq!(
            ArrivalSpec::parse("poisson:500").unwrap(),
            ArrivalSpec::Poisson { rate_per_s: 500.0 }
        );
        assert_eq!(
            ArrivalSpec::parse("uniform:2.5").unwrap(),
            ArrivalSpec::Uniform { rate_per_s: 2.5 }
        );
        for bad in ["poisson", "poisson:zero", "poisson:-1", "poisson:0", "burst:9"] {
            let err = ArrivalSpec::parse(bad).unwrap_err();
            assert!(
                matches!(err, VtaError::InvalidRequest(_)),
                "'{bad}' must be rejected with a typed error, got {err:?}"
            );
            assert!(
                err.to_string().contains(bad),
                "the error for '{bad}' must quote the offending spec: {err}"
            );
        }
    }

    #[test]
    fn missing_trace_file_error_names_the_path() {
        let err = read_trace(Path::new("/nonexistent/replay.jsonl")).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        assert!(err.to_string().contains("/nonexistent/replay.jsonl"), "got {err}");
    }

    #[test]
    fn synth_trace_is_seed_deterministic_and_ordered() {
        let spec = ArrivalSpec::Poisson { rate_per_s: 1000.0 };
        let w = ids(&["micro@4", "micro@8"]);
        let a = synth_trace(&spec, &w, 64, 42).unwrap();
        let b = synth_trace(&spec, &w, 64, 42).unwrap();
        assert_eq!(a, b, "same seed, same trace");
        assert!(a.windows(2).all(|p| p[0].t_us <= p[1].t_us), "arrivals sorted");
        let c = synth_trace(&spec, &w, 64, 43).unwrap();
        assert_ne!(a, c, "different seed, different trace");
        assert!(a.iter().any(|r| r.workload == "micro@4"));
        assert!(a.iter().any(|r| r.workload == "micro@8"));
    }

    #[test]
    fn uniform_trace_has_fixed_gaps() {
        let spec = ArrivalSpec::Uniform { rate_per_s: 1000.0 };
        let trace = synth_trace(&spec, &ids(&["micro@4"]), 8, 7).unwrap();
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.t_us, 1000 * (i as u64 + 1));
        }
    }

    #[test]
    fn empty_workload_list_rejected() {
        let spec = ArrivalSpec::Uniform { rate_per_s: 1.0 };
        assert!(matches!(
            synth_trace(&spec, &[], 4, 1),
            Err(VtaError::InvalidRequest(_))
        ));
    }

    #[test]
    fn trace_jsonl_roundtrips() {
        let spec = ArrivalSpec::Poisson { rate_per_s: 500.0 };
        let trace = synth_trace(&spec, &ids(&["micro@4"]), 16, 9).unwrap();
        let path = std::env::temp_dir()
            .join(format!("vta_serve_trace_{}.jsonl", std::process::id()));
        write_trace(&path, &trace).unwrap();
        let back = read_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, trace);
    }

    #[test]
    fn malformed_trace_lines_are_typed_errors() {
        let path = std::env::temp_dir()
            .join(format!("vta_serve_badtrace_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"workload\":\"micro@4\"}\n").unwrap();
        let err = read_trace(&path).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        std::fs::write(&path, "not json\n").unwrap();
        let err = read_trace(&path).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        std::fs::write(&path, "{\"t_us\":-100,\"workload\":\"micro@4\"}\n").unwrap();
        let err = read_trace(&path).unwrap_err();
        assert!(
            matches!(err, VtaError::InvalidRequest(_)),
            "negative timestamps must be rejected, got {err:?}"
        );
        // A non-integer seed (here: a u64 too big for i64, which the
        // JSON parser demotes to a float) is rejected, not mangled.
        std::fs::write(
            &path,
            "{\"t_us\":1,\"workload\":\"micro@4\",\"seed\":18446744073709551615}\n",
        )
        .unwrap();
        let err = read_trace(&path).unwrap_err();
        assert!(
            matches!(err, VtaError::InvalidRequest(_)),
            "non-integer seeds must be rejected, got {err:?}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn full_range_seeds_roundtrip_and_missing_seeds_use_line_index() {
        let path = std::env::temp_dir()
            .join(format!("vta_serve_seeds_{}.jsonl", std::process::id()));
        // A seed >= 2^63 survives the signed on-disk form bit-exactly.
        let big = Request { t_us: 5, workload: "micro@4".into(), seed: u64::MAX - 1 };
        write_trace(&path, std::slice::from_ref(&big)).unwrap();
        assert_eq!(read_trace(&path).unwrap(), vec![big]);
        // Missing seeds default to the 0-based line index, blank lines
        // included in the count.
        std::fs::write(
            &path,
            "{\"t_us\":1,\"workload\":\"a\"}\n\n{\"t_us\":2,\"workload\":\"a\"}\n",
        )
        .unwrap();
        let trace = read_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace[0].seed, 0);
        assert_eq!(trace[1].seed, 2, "line index, not parsed-request count");
    }
}
