//! Batch-serving runtime (`vta serve`): multi-tenant dynamic request
//! batching over the [`Engine`](crate::engine::Engine) API.
//!
//! Everything below PR 4 evaluates one request at a time: one engine,
//! one graph, one answer. This module is the serving loop on top — the
//! piece a production deployment of the paper's stack would put between
//! user traffic and the accelerator:
//!
//! ```text
//!   load generator ──> bounded queue ──> dynamic batcher ──> virtual device
//!   (poisson/uniform      (shed on        (per-workload         (serial, priced
//!    or --replay trace)    overflow)       max_batch/max_wait)    by warm cycles)
//!                                               │
//!                                               v
//!                              SessionPool: warm PreparedShared per
//!                              (config, workload, backend) + shared memo
//!                                               │ batches
//!                                               v
//!                              worker pool (util::pool) evaluates
//!                              batches in parallel — wall clock only
//! ```
//!
//! The three pieces:
//!
//! * [`SessionPool`] (`pool`) — N warm prepared graphs keyed by
//!   `(config, workload, backend)`, built once via
//!   [`Engine::prepare_shared`](crate::engine::Engine::prepare_shared)
//!   with one shared [`LayerMemo`](crate::memo::LayerMemo) across the
//!   pool. A warmup evaluation per entry primes the memo and — because
//!   VTA cycle counts are data-independent — pins the exact per-request
//!   service time.
//! * [`schedule`] (`sched`) — the deterministic virtual-time scheduler:
//!   bounded admission, per-workload batch coalescing up to
//!   `max_batch`/`max_wait_us`, per-request deadlines, and a serial
//!   virtual accelerator that prices batches from the pool's warm cycle
//!   counts. Load shedding is typed and counted, never silent.
//! * [`load`] — seeded open-loop arrival generation
//!   ([`ArrivalSpec`]: `poisson:<rate>` / `uniform:<rate>`) and JSONL
//!   trace record/replay ([`read_trace`]/[`write_trace`]).
//!
//! # Determinism contract
//!
//! The schedule — batch compositions, rejections, expirations, queue
//! depths, every latency — is a pure function of
//! `(trace, pool service times, scheduler options)`. Worker threads
//! only parallelize the already-fixed batches' evaluations, so
//! [`ServeReport::to_json`] is **byte-identical across `--jobs 1` and
//! `--jobs N`** (wall-clock numbers live outside the report in
//! [`ServeOutcome`]). `rust/tests/serve_runtime.rs` pins this, and the
//! CI smoke `cmp`s the report JSON of a 1-worker and a 4-worker run.
//!
//! # What batching buys
//!
//! In virtual time, each dispatch pays `dispatch_overhead_us` once per
//! batch — the classic launch-overhead amortization. In wall-clock
//! time, the pool amortizes the whole prepare pipeline (graph build
//! with synthetic weights, validation, shape propagation, memo warmup)
//! across every request: `benches/serve_throughput.rs` measures served
//! throughput against a one-engine-per-request baseline and asserts the
//! ≥ 2× amortization gate.

pub mod load;
pub mod pool;
pub mod sched;

pub use load::{read_trace, synth_trace, write_trace, ArrivalSpec, Request};
pub use pool::{PoolEntry, PoolKey, SessionPool};
pub use sched::{schedule, Batch, SchedOptions, Schedule};

use crate::config::VtaConfig;
use crate::engine::{BackendKind, EvalRequest, VtaError};
use crate::sweep::WorkloadSpec;
use crate::util::hash::Fnv;
use crate::util::json::{obj, Json};
use crate::util::stats;
use std::collections::BTreeMap;

/// Everything a serving run needs. `jobs` affects wall clock only; all
/// other fields shape the (deterministic) schedule and report.
#[derive(Clone)]
pub struct ServeOptions {
    /// Hardware configuration shared by every pooled entry.
    pub cfg: VtaConfig,
    /// Fidelity rung serving requests (must produce cycles: tsim,
    /// timing, or model — fsim is rejected).
    pub backend: BackendKind,
    /// Workloads to pool; requests address them by `WorkloadSpec::id`.
    pub workloads: Vec<WorkloadSpec>,
    /// Synthetic-weight seed for the pooled graphs.
    pub graph_seed: u64,
    /// Share a layer memo across the pool (tsim backends; on by
    /// default — serving *is* the memo's best case).
    pub memo: bool,
    /// Worker threads for batch execution (0 = auto). Never changes the
    /// report.
    pub jobs: usize,
    /// Max requests coalesced per batch.
    pub max_batch: usize,
    /// Batching window (bounds the co-batching delay; see `sched`).
    pub max_wait_us: u64,
    /// Bounded-queue depth; arrivals beyond it are shed.
    pub queue_depth: usize,
    /// Optional per-request deadline (arrival → batch start).
    pub deadline_us: Option<u64>,
    /// Accelerator clock for the cycles → virtual-µs conversion.
    pub clock_mhz: u64,
    /// Fixed virtual cost per dispatched batch (what batching
    /// amortizes in virtual time).
    pub dispatch_overhead_us: u64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            cfg: crate::config::presets::default_config(),
            backend: BackendKind::TsimTiming,
            workloads: vec![WorkloadSpec::Micro { block: 16 }],
            graph_seed: 1,
            memo: true,
            jobs: 0,
            max_batch: 8,
            max_wait_us: 2_000,
            queue_depth: 256,
            deadline_us: None,
            clock_mhz: 100,
            dispatch_overhead_us: 50,
        }
    }
}

impl ServeOptions {
    fn sched_options(&self) -> SchedOptions {
        SchedOptions {
            max_batch: self.max_batch,
            max_wait_us: self.max_wait_us,
            queue_depth: self.queue_depth,
            deadline_us: self.deadline_us,
            dispatch_overhead_us: self.dispatch_overhead_us,
        }
    }
}

/// Per-workload line of the report: what one request costs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadCost {
    pub cycles_per_request: u64,
    pub service_us: u64,
}

/// The serving run's metrics. Every field is derived from the virtual
/// schedule, so the JSON is byte-identical across worker counts; wall
/// clock lives in [`ServeOutcome`] instead.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub config: String,
    pub backend: BackendKind,
    pub clock_mhz: u64,
    pub workloads: BTreeMap<String, WorkloadCost>,
    pub submitted: usize,
    pub admitted: usize,
    pub completed: usize,
    pub rejected_queue_full: usize,
    pub expired_deadline: usize,
    /// Batches that dispatched at least one request.
    pub batches_dispatched: usize,
    pub mean_batch_occupancy: f64,
    pub max_batch_occupancy: usize,
    pub max_queue_depth: usize,
    pub mean_queue_depth: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub latency_max_us: u64,
    /// First arrival → last completion, virtual µs.
    pub makespan_us: u64,
    /// Completed requests per virtual second.
    pub throughput_rps: f64,
    /// Accelerator cycles actually evaluated (Σ over completions).
    pub total_cycles: u64,
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// FNV-1a over every batch's composition and timing — two runs with
    /// equal digests made identical scheduling decisions.
    pub schedule_digest: u64,
}

impl ServeReport {
    /// Deterministic JSON (sorted keys, no wall-clock or worker-count
    /// fields) — the artifact `vta serve --out` writes and CI diffs
    /// across worker counts.
    pub fn to_json(&self) -> Json {
        let workloads: Vec<Json> = self
            .workloads
            .iter()
            .map(|(id, c)| {
                obj([
                    ("workload", Json::Str(id.clone())),
                    ("cycles_per_request", Json::Int(c.cycles_per_request as i64)),
                    ("service_us", Json::Int(c.service_us as i64)),
                ])
            })
            .collect();
        obj([
            ("schema", Json::Int(1)),
            ("config", Json::Str(self.config.clone())),
            ("backend", Json::Str(self.backend.cli_name().to_string())),
            ("clock_mhz", Json::Int(self.clock_mhz as i64)),
            ("workloads", Json::Array(workloads)),
            ("submitted", Json::Int(self.submitted as i64)),
            ("admitted", Json::Int(self.admitted as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("rejected_queue_full", Json::Int(self.rejected_queue_full as i64)),
            ("expired_deadline", Json::Int(self.expired_deadline as i64)),
            ("batches_dispatched", Json::Int(self.batches_dispatched as i64)),
            ("mean_batch_occupancy", Json::Float(self.mean_batch_occupancy)),
            ("max_batch_occupancy", Json::Int(self.max_batch_occupancy as i64)),
            ("max_queue_depth", Json::Int(self.max_queue_depth as i64)),
            ("mean_queue_depth", Json::Float(self.mean_queue_depth)),
            ("latency_p50_us", Json::Float(self.latency_p50_us)),
            ("latency_p95_us", Json::Float(self.latency_p95_us)),
            ("latency_p99_us", Json::Float(self.latency_p99_us)),
            ("latency_mean_us", Json::Float(self.latency_mean_us)),
            ("latency_max_us", Json::Int(self.latency_max_us as i64)),
            ("makespan_us", Json::Int(self.makespan_us as i64)),
            ("throughput_rps", Json::Float(self.throughput_rps)),
            ("total_cycles", Json::Int(self.total_cycles as i64)),
            ("memo_hits", Json::Int(self.memo_hits as i64)),
            ("memo_misses", Json::Int(self.memo_misses as i64)),
            ("schedule_digest", Json::Str(format!("{:016x}", self.schedule_digest))),
        ])
    }
}

/// What [`run`] hands back: the deterministic report, the full batch
/// schedule (for inspection and tests), and the wall-clock facts that
/// deliberately stay out of the report.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// The dispatched schedule, close order (includes all-expired
    /// batches with empty `requests`).
    pub batches: Vec<Batch>,
    /// Wall-clock nanoseconds of the batch-execution phase.
    pub wall_ns: u64,
    /// Worker threads used for execution.
    pub workers: usize,
}

/// Serve a request trace end-to-end: build + warm the pool, compute the
/// virtual-time schedule, execute the batches across the worker pool,
/// and assemble the report. Fails with a typed [`VtaError`] on
/// malformed options, traces, or capability mismatches — load shedding
/// and deadline expiry are *counted outcomes*, not errors.
pub fn run(opts: &ServeOptions, trace: &[Request]) -> Result<ServeOutcome, VtaError> {
    let pool = SessionPool::build(opts)?;
    let schedule = sched::schedule(trace, &pool.service_map(), &opts.sched_options())?;

    // Execute the fixed schedule. Workers change wall clock only: slot
    // `b` always holds batch `b`'s result.
    let workers = crate::sweep::effective_jobs(opts.jobs).min(schedule.batches.len().max(1));
    let wall_start = std::time::Instant::now();
    let batch_results: Vec<Result<u64, VtaError>> =
        crate::util::pool::run_indexed(workers, schedule.batches.len(), |b| {
            let batch = &schedule.batches[b];
            let entry = pool
                .get(&batch.workload)
                .expect("the scheduler only dispatches pooled workloads");
            let mut cycles = 0u64;
            for &r in &batch.requests {
                let eval = entry
                    .engine
                    .eval_shared(&entry.prepared, &EvalRequest::seeded(trace[r].seed))?;
                cycles += eval.cycles.expect("pool backends produce cycles");
            }
            Ok(cycles)
        });
    let wall_ns = wall_start.elapsed().as_nanos() as u64;
    let mut total_cycles = 0u64;
    for r in batch_results {
        total_cycles += r?;
    }

    let report = assemble_report(opts, &pool, &schedule, trace, total_cycles);
    Ok(ServeOutcome { report, batches: schedule.batches, wall_ns, workers })
}

fn assemble_report(
    opts: &ServeOptions,
    pool: &SessionPool,
    schedule: &Schedule,
    trace: &[Request],
    total_cycles: u64,
) -> ServeReport {
    let mut latencies: Vec<f64> =
        schedule.latencies_us.iter().map(|&(_, l)| l as f64).collect();
    // One sort serves every percentile; an empty run reports 0, not
    // NaN (NaN is null in JSON).
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        if latencies.is_empty() {
            0.0
        } else {
            stats::percentile_sorted(&latencies, p)
        }
    };
    let completed = schedule.completed();
    let dispatched: Vec<&Batch> =
        schedule.batches.iter().filter(|b| b.occupancy() > 0).collect();
    let first_arrival = trace.iter().map(|r| r.t_us).min().unwrap_or(0);
    let makespan_us = schedule.makespan_end_us().saturating_sub(first_arrival);
    let (memo_hits, memo_misses) = pool.memo_stats();
    ServeReport {
        config: opts.cfg.tag(),
        backend: opts.backend,
        clock_mhz: opts.clock_mhz,
        workloads: pool
            .entries()
            .iter()
            .map(|e| {
                (
                    e.key.workload.clone(),
                    WorkloadCost {
                        cycles_per_request: e.cycles_per_request,
                        service_us: e.service_us,
                    },
                )
            })
            .collect(),
        submitted: trace.len(),
        admitted: schedule.admitted,
        completed,
        rejected_queue_full: schedule.rejected_queue_full.len(),
        expired_deadline: schedule.expired(),
        batches_dispatched: dispatched.len(),
        mean_batch_occupancy: if dispatched.is_empty() {
            0.0
        } else {
            completed as f64 / dispatched.len() as f64
        },
        max_batch_occupancy: dispatched.iter().map(|b| b.occupancy()).max().unwrap_or(0),
        max_queue_depth: schedule.max_queue_depth,
        mean_queue_depth: if schedule.admitted == 0 {
            0.0
        } else {
            schedule.depth_sum as f64 / schedule.admitted as f64
        },
        latency_p50_us: pct(50.0),
        latency_p95_us: pct(95.0),
        latency_p99_us: pct(99.0),
        latency_mean_us: if latencies.is_empty() { 0.0 } else { stats::mean(&latencies) },
        latency_max_us: schedule.latencies_us.iter().map(|&(_, l)| l).max().unwrap_or(0),
        makespan_us,
        throughput_rps: completed as f64 / (makespan_us.max(1) as f64 / 1e6),
        total_cycles,
        memo_hits,
        memo_misses,
        schedule_digest: schedule_digest(&schedule.batches),
    }
}

/// FNV-1a fingerprint of the full schedule: batch identities, members,
/// expirations, and virtual timing. Equal digests ⇒ identical
/// scheduling decisions (the determinism tests' one-number summary).
pub fn schedule_digest(batches: &[Batch]) -> u64 {
    let mut h = Fnv::new();
    for b in batches {
        h.write_u64(b.id as u64);
        h.write_str(&b.workload);
        h.write_u64(b.open_us);
        h.write_u64(b.ready_us);
        h.write_u64(b.start_us);
        h.write_u64(b.done_us);
        h.write_u64(b.requests.len() as u64);
        for &r in &b.requests {
            h.write_u64(r as u64);
        }
        h.write_u64(b.expired.len() as u64);
        for &r in &b.expired {
            h.write_u64(r as u64);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn micro_opts() -> ServeOptions {
        ServeOptions {
            cfg: presets::tiny_config(),
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            ..ServeOptions::default()
        }
    }

    #[test]
    fn serve_completes_a_small_trace() {
        let opts = micro_opts();
        let spec = ArrivalSpec::Poisson { rate_per_s: 200.0 };
        let trace = synth_trace(&spec, &["micro@4".to_string()], 16, 7).unwrap();
        let outcome = run(&opts, &trace).unwrap();
        let r = &outcome.report;
        assert_eq!(r.submitted, 16);
        assert_eq!(r.completed, 16, "generous queue + no deadline: all complete");
        assert_eq!((r.rejected_queue_full, r.expired_deadline), (0, 0));
        assert!(r.throughput_rps > 0.0);
        assert!(r.latency_p50_us <= r.latency_p95_us);
        assert!(r.latency_p95_us <= r.latency_p99_us);
        assert!(r.latency_p99_us <= r.latency_max_us as f64);
        // Every completion evaluated the warm graph exactly.
        let per_req = r.workloads["micro@4"].cycles_per_request;
        assert_eq!(r.total_cycles, 16 * per_req);
        assert!(r.memo_hits > 0, "served requests hit the warm memo");
    }

    #[test]
    fn report_json_lists_every_counter() {
        let opts = micro_opts();
        let trace =
            synth_trace(&ArrivalSpec::Uniform { rate_per_s: 100.0 }, &["micro@4".into()], 4, 1)
                .unwrap();
        let outcome = run(&opts, &trace).unwrap();
        let j = outcome.report.to_json();
        for key in [
            "schema",
            "completed",
            "rejected_queue_full",
            "expired_deadline",
            "latency_p99_us",
            "throughput_rps",
            "schedule_digest",
            "mean_batch_occupancy",
        ] {
            assert!(j.get(key).is_some(), "report JSON missing '{key}'");
        }
    }

    #[test]
    fn empty_trace_produces_zeroed_report() {
        let outcome = run(&micro_opts(), &[]).unwrap();
        assert_eq!(outcome.report.completed, 0);
        assert_eq!(outcome.report.throughput_rps, 0.0);
        assert!(outcome.batches.is_empty());
    }
}
