//! Batch-serving runtime (`vta serve`): multi-tenant dynamic request
//! batching over the [`Engine`](crate::engine::Engine) API.
//!
//! Everything below PR 4 evaluates one request at a time: one engine,
//! one graph, one answer. This module is the serving loop on top — the
//! piece a production deployment of the paper's stack would put between
//! user traffic and the accelerator:
//!
//! ```text
//!   load generator ──> bounded queue ──> dynamic batcher ──> virtual device
//!   (poisson/uniform      (shed on        (per-workload         (serial, priced
//!    or --replay trace)    overflow)       max_batch/max_wait)    by warm cycles)
//!                                               │
//!                                               v
//!                              SessionPool: warm PreparedShared per
//!                              (config, workload, backend) + shared memo
//!                                               │ batches
//!                                               v
//!                              worker pool (util::pool) evaluates
//!                              batches in parallel — wall clock only
//! ```
//!
//! The pieces:
//!
//! * [`SessionPool`] (`pool`) — N warm prepared graphs keyed by
//!   `(config, workload, backend)`, built once via
//!   [`Engine::prepare_shared`](crate::engine::Engine::prepare_shared)
//!   with one shared [`LayerMemo`](crate::memo::LayerMemo) across the
//!   pool. A warmup evaluation per entry primes the memo and — because
//!   VTA cycle counts are data-independent — pins the exact per-request
//!   service time.
//! * [`schedule`] (`sched`) — the deterministic virtual-time scheduler:
//!   bounded admission, per-workload batch coalescing up to
//!   `max_batch`/`max_wait_us`, per-request deadlines, and a serial
//!   virtual accelerator that prices batches from the pool's warm cycle
//!   counts. Load shedding is typed and counted, never silent.
//! * [`load`] — seeded open-loop arrival generation
//!   ([`ArrivalSpec`]: `poisson:<rate>` / `uniform:<rate>`) and JSONL
//!   trace record/replay ([`read_trace`]/[`write_trace`]).
//! * [`fleet`] — the heterogeneous scale-out path (`vta serve
//!   --fleet`): N virtual devices instantiated at different Pareto
//!   points of the area/performance curve, a pluggable [`RoutePolicy`]
//!   assigning each admitted request a device by deadline slack and
//!   warm cost, simulated autoscaling priced by
//!   [`scaled_area`](crate::analysis::area::scaled_area), and a
//!   cost-vs-SLO [`frontier`] over candidate fleet compositions.
//!
//! # Determinism contract
//!
//! The schedule — batch compositions, rejections, expirations, queue
//! depths, every latency — is a pure function of
//! `(trace, pool service times, scheduler options)`. Worker threads
//! only parallelize the already-fixed batches' evaluations, so
//! [`ServeReport::to_json`] is **byte-identical across `--jobs 1` and
//! `--jobs N`** (wall-clock numbers live outside the report in
//! [`ServeOutcome`]). The same contract covers [`FleetReport`]:
//! routing and autoscaling decisions are part of the virtual-time
//! model, never of execution. `rust/tests/serve_runtime.rs` and
//! `rust/tests/fleet_serving.rs` pin this, and the CI smokes `cmp` the
//! report JSON of a 1-worker and a 4-worker run.
//!
//! # Construction and schema
//!
//! [`ServeOptions`] can be filled as a struct literal (every consumer
//! routes it through [`ServeOptions::validate`]) or assembled with the
//! validating [`ServeOptions::builder`], which surfaces contradictory
//! settings as typed [`VtaError::InvalidRequest`] at build time.
//! Report JSON carries a `schema_version` (see
//! [`SERVE_SCHEMA_VERSION`]); the strict [`ServeReport::from_json`]
//! rejects unknown, missing, or version-mismatched fields, matching
//! the `ExecCounters::from_json` contract.
//!
//! # What batching buys
//!
//! In virtual time, each dispatch pays `dispatch_overhead_us` once per
//! batch — the classic launch-overhead amortization. In wall-clock
//! time, the pool amortizes the whole prepare pipeline (graph build
//! with synthetic weights, validation, shape propagation, memo warmup)
//! across every request: `benches/serve_throughput.rs` measures served
//! throughput against a one-engine-per-request baseline and asserts the
//! ≥ 2× amortization gate.

pub mod fleet;
pub mod load;
pub mod pool;
pub mod sched;

pub use fleet::{
    configs_from_sweep, frontier, run_fleet, schedule_fleet, AutoscaleOptions, CheapestFirst,
    DeviceCost, DeviceReport, EarliestFeasibleCheapest, Fleet, FleetOptions, FleetOutcome,
    FleetReport, FleetSchedule, FrontierEntry, FrontierOutcome, LaneView, LeastLoaded,
    RoutePolicy, RoutePolicyKind, FLEET_SCHEMA_VERSION,
};
pub use load::{read_trace, synth_trace, write_trace, ArrivalSpec, Request};
pub use pool::{PoolEntry, PoolKey, SessionPool};
pub use sched::{schedule, Batch, SchedOptions, Schedule};

use crate::config::VtaConfig;
use crate::engine::{BackendKind, EvalRequest, VtaError};
use crate::sweep::WorkloadSpec;
use crate::util::hash::Fnv;
use crate::util::json::{obj, Json};
use crate::util::stats;
use std::collections::BTreeMap;

/// Version stamped into [`ServeReport::to_json`] (`schema_version`) and
/// required verbatim by [`ServeReport::from_json`]. Bump on any field
/// change.
pub const SERVE_SCHEMA_VERSION: u32 = 2;

/// Everything a serving run needs. `jobs` affects wall clock only; all
/// other fields shape the (deterministic) schedule and report.
///
/// Construct as a struct literal (validated by every consumer via
/// [`ServeOptions::validate`]) or through the checked
/// [`ServeOptions::builder`].
#[derive(Clone)]
pub struct ServeOptions {
    /// Hardware configuration shared by every pooled entry.
    pub cfg: VtaConfig,
    /// Fidelity rung serving requests (must produce cycles: tsim,
    /// timing, or model — fsim is rejected).
    pub backend: BackendKind,
    /// Workloads to pool; requests address them by `WorkloadSpec::id`.
    pub workloads: Vec<WorkloadSpec>,
    /// Synthetic-weight seed for the pooled graphs.
    pub graph_seed: u64,
    /// Share a layer memo across the pool (tsim backends; on by
    /// default — serving *is* the memo's best case).
    pub memo: bool,
    /// Worker threads for batch execution (0 = auto). Never changes the
    /// report.
    pub jobs: usize,
    /// Max requests coalesced per batch.
    pub max_batch: usize,
    /// Batching window (bounds the co-batching delay; see `sched`).
    pub max_wait_us: u64,
    /// Bounded-queue depth; arrivals beyond it are shed.
    pub queue_depth: usize,
    /// Optional per-request deadline (arrival → batch start).
    pub deadline_us: Option<u64>,
    /// Accelerator clock for the cycles → virtual-µs conversion.
    pub clock_mhz: u64,
    /// Fixed virtual cost per dispatched batch (what batching
    /// amortizes in virtual time).
    pub dispatch_overhead_us: u64,
    /// Cross-layer scratchpad residency heuristic every pooled session
    /// runs under (default LRU). Timing/counters only — outputs are
    /// bit-identical at every setting.
    pub residency: crate::compiler::residency::ResidencyMode,
    /// Artifact store shared with the sweep (`None` = standalone). When
    /// set, the pool's layer memo is store-backed, warmup consumes any
    /// matching sweep `PointMeasurement` (cycles are data-independent,
    /// so any seed's measurement prices this entry), and fresh warmups
    /// are persisted for the next run. Never changes the report.
    pub store: Option<std::sync::Arc<crate::store::ArtifactStore>>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            cfg: crate::config::presets::default_config(),
            backend: BackendKind::TsimTiming,
            workloads: vec![WorkloadSpec::Micro { block: 16 }],
            graph_seed: 1,
            memo: true,
            jobs: 0,
            max_batch: 8,
            max_wait_us: 2_000,
            queue_depth: 256,
            deadline_us: None,
            clock_mhz: 100,
            dispatch_overhead_us: 50,
            residency: crate::compiler::residency::ResidencyMode::default(),
            store: None,
        }
    }
}

impl ServeOptions {
    /// Start a validating builder seeded with [`ServeOptions::default`];
    /// [`ServeOptionsBuilder::build`] surfaces zero or contradictory
    /// fields as typed errors before any pool or schedule work runs.
    pub fn builder() -> ServeOptionsBuilder {
        ServeOptionsBuilder { opts: ServeOptions::default() }
    }

    /// The full option check every consumer runs — struct literals and
    /// builder output go through the same gate. Typed failures:
    /// [`VtaError::Config`] for an invalid hardware configuration,
    /// [`VtaError::Unsupported`] for a backend that cannot price
    /// requests (fsim), [`VtaError::InvalidRequest`] for everything
    /// else (empty/duplicate workloads, zero-sized scheduler knobs, a
    /// zero deadline).
    pub fn validate(&self) -> Result<(), VtaError> {
        self.cfg.validate()?;
        if self.workloads.is_empty() {
            return Err(VtaError::InvalidRequest(
                "the session pool needs at least one workload".into(),
            ));
        }
        let mut seen: Vec<String> = Vec::with_capacity(self.workloads.len());
        for spec in &self.workloads {
            let id = spec.id();
            if seen.contains(&id) {
                return Err(VtaError::InvalidRequest(format!(
                    "workload '{id}' appears twice in the pool"
                )));
            }
            seen.push(id);
        }
        if self.max_batch == 0 {
            return Err(VtaError::InvalidRequest("max_batch must be at least 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(VtaError::InvalidRequest("queue_depth must be at least 1".into()));
        }
        if self.clock_mhz == 0 {
            return Err(VtaError::InvalidRequest(
                "clock_mhz must be positive (it converts cycles to virtual time)".into(),
            ));
        }
        if self.deadline_us == Some(0) {
            return Err(VtaError::InvalidRequest(
                "a zero deadline expires every request at dispatch; omit it for no deadline"
                    .into(),
            ));
        }
        let caps = self.backend.instantiate().capabilities();
        if !caps.produces_cycles {
            return Err(VtaError::Unsupported(format!(
                "serving schedules in virtual time and backend '{}' produces no cycles \
                 (use tsim, timing, or model)",
                self.backend
            )));
        }
        Ok(())
    }

    fn sched_options(&self) -> SchedOptions {
        SchedOptions {
            max_batch: self.max_batch,
            max_wait_us: self.max_wait_us,
            queue_depth: self.queue_depth,
            deadline_us: self.deadline_us,
            dispatch_overhead_us: self.dispatch_overhead_us,
        }
    }
}

/// Validating builder for [`ServeOptions`], mirroring the
/// `Engine::for_config(..).build()?` shape: setters fix fields,
/// [`ServeOptionsBuilder::build`] runs [`ServeOptions::validate`] and
/// returns the checked options or a typed [`VtaError`].
#[derive(Clone)]
pub struct ServeOptionsBuilder {
    opts: ServeOptions,
}

impl ServeOptionsBuilder {
    pub fn cfg(mut self, cfg: VtaConfig) -> Self {
        self.opts.cfg = cfg;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.opts.backend = backend;
        self
    }

    /// Replace the pooled workload set.
    pub fn workloads(mut self, workloads: Vec<WorkloadSpec>) -> Self {
        self.opts.workloads = workloads;
        self
    }

    pub fn graph_seed(mut self, graph_seed: u64) -> Self {
        self.opts.graph_seed = graph_seed;
        self
    }

    pub fn memo(mut self, memo: bool) -> Self {
        self.opts.memo = memo;
        self
    }

    pub fn jobs(mut self, jobs: usize) -> Self {
        self.opts.jobs = jobs;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.opts.max_batch = max_batch;
        self
    }

    pub fn max_wait_us(mut self, max_wait_us: u64) -> Self {
        self.opts.max_wait_us = max_wait_us;
        self
    }

    pub fn queue_depth(mut self, queue_depth: usize) -> Self {
        self.opts.queue_depth = queue_depth;
        self
    }

    pub fn deadline_us(mut self, deadline_us: Option<u64>) -> Self {
        self.opts.deadline_us = deadline_us;
        self
    }

    pub fn clock_mhz(mut self, clock_mhz: u64) -> Self {
        self.opts.clock_mhz = clock_mhz;
        self
    }

    pub fn dispatch_overhead_us(mut self, dispatch_overhead_us: u64) -> Self {
        self.opts.dispatch_overhead_us = dispatch_overhead_us;
        self
    }

    /// Cross-layer residency heuristic for every pooled session.
    pub fn residency(mut self, mode: crate::compiler::residency::ResidencyMode) -> Self {
        self.opts.residency = mode;
        self
    }

    /// Share an artifact store with the sweep (warmup reuse + persisted
    /// layer memo).
    pub fn store(mut self, store: Option<std::sync::Arc<crate::store::ArtifactStore>>) -> Self {
        self.opts.store = store;
        self
    }

    /// Validate and hand back the options ([`ServeOptions::validate`]).
    pub fn build(self) -> Result<ServeOptions, VtaError> {
        self.opts.validate()?;
        Ok(self.opts)
    }
}

/// Per-workload line of the report: what one request costs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadCost {
    pub cycles_per_request: u64,
    pub service_us: u64,
}

/// The serving run's metrics. Every field is derived from the virtual
/// schedule, so the JSON is byte-identical across worker counts; wall
/// clock lives in [`ServeOutcome`] instead.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub config: String,
    pub backend: BackendKind,
    pub clock_mhz: u64,
    pub workloads: BTreeMap<String, WorkloadCost>,
    pub submitted: usize,
    pub admitted: usize,
    pub completed: usize,
    pub rejected_queue_full: usize,
    pub expired_deadline: usize,
    /// Batches that dispatched at least one request.
    pub batches_dispatched: usize,
    pub mean_batch_occupancy: f64,
    pub max_batch_occupancy: usize,
    pub max_queue_depth: usize,
    pub mean_queue_depth: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub latency_max_us: u64,
    /// First arrival → last completion, virtual µs.
    pub makespan_us: u64,
    /// Completed requests per virtual second.
    pub throughput_rps: f64,
    /// Accelerator cycles actually evaluated (Σ over completions).
    pub total_cycles: u64,
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// FNV-1a over every batch's composition and timing — two runs with
    /// equal digests made identical scheduling decisions.
    pub schedule_digest: u64,
}

impl ServeReport {
    /// Every key [`ServeReport::to_json`] writes; [`from_json`]
    /// requires exactly this set — nothing missing, nothing unknown.
    ///
    /// [`from_json`]: ServeReport::from_json
    pub const JSON_FIELDS: [&'static str; 26] = [
        "schema_version",
        "config",
        "backend",
        "clock_mhz",
        "workloads",
        "submitted",
        "admitted",
        "completed",
        "rejected_queue_full",
        "expired_deadline",
        "batches_dispatched",
        "mean_batch_occupancy",
        "max_batch_occupancy",
        "max_queue_depth",
        "mean_queue_depth",
        "latency_p50_us",
        "latency_p95_us",
        "latency_p99_us",
        "latency_mean_us",
        "latency_max_us",
        "makespan_us",
        "throughput_rps",
        "total_cycles",
        "memo_hits",
        "memo_misses",
        "schedule_digest",
    ];

    /// Keys of each entry in the `workloads` array.
    pub const WORKLOAD_JSON_FIELDS: [&'static str; 3] =
        ["workload", "cycles_per_request", "service_us"];

    /// Deterministic JSON (sorted workloads, no wall-clock or
    /// worker-count fields) — the artifact `vta serve --out` writes and
    /// CI diffs across worker counts. Carries
    /// [`SERVE_SCHEMA_VERSION`] as `schema_version`.
    pub fn to_json(&self) -> Json {
        let workloads: Vec<Json> = self
            .workloads
            .iter()
            .map(|(id, c)| {
                obj([
                    ("workload", Json::Str(id.clone())),
                    ("cycles_per_request", Json::Int(c.cycles_per_request as i64)),
                    ("service_us", Json::Int(c.service_us as i64)),
                ])
            })
            .collect();
        obj([
            ("schema_version", Json::Int(SERVE_SCHEMA_VERSION as i64)),
            ("config", Json::Str(self.config.clone())),
            ("backend", Json::Str(self.backend.cli_name().to_string())),
            ("clock_mhz", Json::Int(self.clock_mhz as i64)),
            ("workloads", Json::Array(workloads)),
            ("submitted", Json::Int(self.submitted as i64)),
            ("admitted", Json::Int(self.admitted as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("rejected_queue_full", Json::Int(self.rejected_queue_full as i64)),
            ("expired_deadline", Json::Int(self.expired_deadline as i64)),
            ("batches_dispatched", Json::Int(self.batches_dispatched as i64)),
            ("mean_batch_occupancy", Json::Float(self.mean_batch_occupancy)),
            ("max_batch_occupancy", Json::Int(self.max_batch_occupancy as i64)),
            ("max_queue_depth", Json::Int(self.max_queue_depth as i64)),
            ("mean_queue_depth", Json::Float(self.mean_queue_depth)),
            ("latency_p50_us", Json::Float(self.latency_p50_us)),
            ("latency_p95_us", Json::Float(self.latency_p95_us)),
            ("latency_p99_us", Json::Float(self.latency_p99_us)),
            ("latency_mean_us", Json::Float(self.latency_mean_us)),
            ("latency_max_us", Json::Int(self.latency_max_us as i64)),
            ("makespan_us", Json::Int(self.makespan_us as i64)),
            ("throughput_rps", Json::Float(self.throughput_rps)),
            ("total_cycles", Json::Int(self.total_cycles as i64)),
            ("memo_hits", Json::Int(self.memo_hits as i64)),
            ("memo_misses", Json::Int(self.memo_misses as i64)),
            ("schedule_digest", Json::Str(format!("{:016x}", self.schedule_digest))),
        ])
    }

    /// Strict inverse of [`ServeReport::to_json`]: `None` unless the
    /// object holds **exactly** [`ServeReport::JSON_FIELDS`] (same for
    /// each workload entry) and `schema_version` matches
    /// [`SERVE_SCHEMA_VERSION`]. Floats round-trip exactly (shortest
    /// round-trip formatting on write).
    pub fn from_json(j: &Json) -> Option<ServeReport> {
        let map = j.as_object()?;
        if map.len() != Self::JSON_FIELDS.len()
            || !Self::JSON_FIELDS.iter().all(|f| map.contains_key(*f))
        {
            return None;
        }
        if j.get("schema_version")?.as_i64()? != SERVE_SCHEMA_VERSION as i64 {
            return None;
        }
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        let float = |name: &str| j.get(name).and_then(|v| v.as_f64());
        let mut workloads = BTreeMap::new();
        for w in j.get("workloads")?.as_array()? {
            let wmap = w.as_object()?;
            if wmap.len() != Self::WORKLOAD_JSON_FIELDS.len()
                || !Self::WORKLOAD_JSON_FIELDS.iter().all(|f| wmap.contains_key(*f))
            {
                return None;
            }
            workloads.insert(
                w.get("workload")?.as_str()?.to_string(),
                WorkloadCost {
                    cycles_per_request: w.get("cycles_per_request")?.as_i64()? as u64,
                    service_us: w.get("service_us")?.as_i64()? as u64,
                },
            );
        }
        Some(ServeReport {
            config: j.get("config")?.as_str()?.to_string(),
            backend: BackendKind::parse(j.get("backend")?.as_str()?).ok()?,
            clock_mhz: int("clock_mhz")?,
            workloads,
            submitted: int("submitted")? as usize,
            admitted: int("admitted")? as usize,
            completed: int("completed")? as usize,
            rejected_queue_full: int("rejected_queue_full")? as usize,
            expired_deadline: int("expired_deadline")? as usize,
            batches_dispatched: int("batches_dispatched")? as usize,
            mean_batch_occupancy: float("mean_batch_occupancy")?,
            max_batch_occupancy: int("max_batch_occupancy")? as usize,
            max_queue_depth: int("max_queue_depth")? as usize,
            mean_queue_depth: float("mean_queue_depth")?,
            latency_p50_us: float("latency_p50_us")?,
            latency_p95_us: float("latency_p95_us")?,
            latency_p99_us: float("latency_p99_us")?,
            latency_mean_us: float("latency_mean_us")?,
            latency_max_us: int("latency_max_us")?,
            makespan_us: int("makespan_us")?,
            throughput_rps: float("throughput_rps")?,
            total_cycles: int("total_cycles")?,
            memo_hits: int("memo_hits")?,
            memo_misses: int("memo_misses")?,
            schedule_digest: u64::from_str_radix(j.get("schedule_digest")?.as_str()?, 16)
                .ok()?,
        })
    }
}

/// What [`run`] hands back: the deterministic report, the full batch
/// schedule (for inspection and tests), and the wall-clock facts that
/// deliberately stay out of the report.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// The dispatched schedule, close order (includes all-expired
    /// batches with empty `requests`).
    pub batches: Vec<Batch>,
    /// Wall-clock nanoseconds of the batch-execution phase.
    pub wall_ns: u64,
    /// Worker threads used for execution.
    pub workers: usize,
}

/// Serve a request trace end-to-end: build + warm the pool, compute the
/// virtual-time schedule, execute the batches across the worker pool,
/// and assemble the report. Fails with a typed [`VtaError`] on
/// malformed options, traces, or capability mismatches — load shedding
/// and deadline expiry are *counted outcomes*, not errors.
pub fn run(opts: &ServeOptions, trace: &[Request]) -> Result<ServeOutcome, VtaError> {
    let pool = SessionPool::build(opts)?;
    let schedule = sched::schedule(trace, &pool.service_map(), &opts.sched_options())?;

    // Execute the fixed schedule. Workers change wall clock only: slot
    // `b` always holds batch `b`'s result.
    let workers = crate::sweep::effective_jobs(opts.jobs).min(schedule.batches.len().max(1));
    let wall_start = std::time::Instant::now();
    let batch_results: Vec<Result<u64, VtaError>> =
        crate::util::pool::run_indexed(workers, schedule.batches.len(), |b| {
            let batch = &schedule.batches[b];
            let entry = pool
                .get(&batch.workload)
                .expect("the scheduler only dispatches pooled workloads");
            // One batched evaluation per dispatched batch: the engine
            // reuses a single session across the batch's requests
            // (bit-identical to per-request eval_shared, so the report
            // is unchanged — only the wall clock improves).
            let requests: Vec<EvalRequest> =
                batch.requests.iter().map(|&r| EvalRequest::seeded(trace[r].seed)).collect();
            let evals = entry.engine.eval_many_shared(&entry.prepared, &requests)?;
            Ok(evals
                .iter()
                .map(|e| e.cycles.expect("pool backends produce cycles"))
                .sum::<u64>())
        });
    let wall_ns = wall_start.elapsed().as_nanos() as u64;
    let mut total_cycles = 0u64;
    for r in batch_results {
        total_cycles += r?;
    }

    let report = assemble_report(opts, &pool, &schedule, trace, total_cycles);
    Ok(ServeOutcome { report, batches: schedule.batches, wall_ns, workers })
}

/// Latency percentiles over a set of completed requests, computed the
/// same way for single-device and fleet reports. An empty run reports
/// 0, not NaN (NaN is null in JSON).
pub(crate) struct LatencySummary {
    pub(crate) p50: f64,
    pub(crate) p95: f64,
    pub(crate) p99: f64,
    pub(crate) mean: f64,
    pub(crate) max_us: u64,
}

pub(crate) fn summarize_latencies(latencies_us: &[(usize, u64)]) -> LatencySummary {
    let mut sorted: Vec<f64> = latencies_us.iter().map(|&(_, l)| l as f64).collect();
    // One sort serves every percentile.
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct =
        |p: f64| if sorted.is_empty() { 0.0 } else { stats::percentile_sorted(&sorted, p) };
    LatencySummary {
        p50: pct(50.0),
        p95: pct(95.0),
        p99: pct(99.0),
        mean: if sorted.is_empty() { 0.0 } else { stats::mean(&sorted) },
        max_us: latencies_us.iter().map(|&(_, l)| l).max().unwrap_or(0),
    }
}

fn assemble_report(
    opts: &ServeOptions,
    pool: &SessionPool,
    schedule: &Schedule,
    trace: &[Request],
    total_cycles: u64,
) -> ServeReport {
    let lat = summarize_latencies(&schedule.latencies_us);
    let completed = schedule.completed();
    let dispatched: Vec<&Batch> =
        schedule.batches.iter().filter(|b| b.occupancy() > 0).collect();
    let first_arrival = trace.iter().map(|r| r.t_us).min().unwrap_or(0);
    let makespan_us = schedule.makespan_end_us().saturating_sub(first_arrival);
    let (memo_hits, memo_misses) = pool.memo_stats();
    ServeReport {
        config: opts.cfg.tag(),
        backend: opts.backend,
        clock_mhz: opts.clock_mhz,
        workloads: pool
            .entries()
            .iter()
            .map(|e| {
                (
                    e.key.workload.clone(),
                    WorkloadCost {
                        cycles_per_request: e.cycles_per_request,
                        service_us: e.service_us,
                    },
                )
            })
            .collect(),
        submitted: trace.len(),
        admitted: schedule.admitted,
        completed,
        rejected_queue_full: schedule.rejected_queue_full.len(),
        expired_deadline: schedule.expired(),
        batches_dispatched: dispatched.len(),
        mean_batch_occupancy: if dispatched.is_empty() {
            0.0
        } else {
            completed as f64 / dispatched.len() as f64
        },
        max_batch_occupancy: dispatched.iter().map(|b| b.occupancy()).max().unwrap_or(0),
        max_queue_depth: schedule.max_queue_depth,
        mean_queue_depth: if schedule.admitted == 0 {
            0.0
        } else {
            schedule.depth_sum as f64 / schedule.admitted as f64
        },
        latency_p50_us: lat.p50,
        latency_p95_us: lat.p95,
        latency_p99_us: lat.p99,
        latency_mean_us: lat.mean,
        latency_max_us: lat.max_us,
        makespan_us,
        throughput_rps: completed as f64 / (makespan_us.max(1) as f64 / 1e6),
        total_cycles,
        memo_hits,
        memo_misses,
        schedule_digest: schedule_digest(&schedule.batches),
    }
}

/// FNV-1a fingerprint of the full schedule: batch identities, devices,
/// members, expirations, and virtual timing. Equal digests ⇒ identical
/// scheduling decisions (the determinism tests' one-number summary).
pub fn schedule_digest(batches: &[Batch]) -> u64 {
    let mut h = Fnv::new();
    for b in batches {
        h.write_u64(b.id as u64);
        h.write_u64(b.device as u64);
        h.write_str(&b.workload);
        h.write_u64(b.open_us);
        h.write_u64(b.ready_us);
        h.write_u64(b.start_us);
        h.write_u64(b.done_us);
        h.write_u64(b.requests.len() as u64);
        for &r in &b.requests {
            h.write_u64(r as u64);
        }
        h.write_u64(b.expired.len() as u64);
        for &r in &b.expired {
            h.write_u64(r as u64);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn micro_opts() -> ServeOptions {
        ServeOptions {
            cfg: presets::tiny_config(),
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            ..ServeOptions::default()
        }
    }

    #[test]
    fn serve_completes_a_small_trace() {
        let opts = micro_opts();
        let spec = ArrivalSpec::Poisson { rate_per_s: 200.0 };
        let trace = synth_trace(&spec, &["micro@4".to_string()], 16, 7).unwrap();
        let outcome = run(&opts, &trace).unwrap();
        let r = &outcome.report;
        assert_eq!(r.submitted, 16);
        assert_eq!(r.completed, 16, "generous queue + no deadline: all complete");
        assert_eq!((r.rejected_queue_full, r.expired_deadline), (0, 0));
        assert!(r.throughput_rps > 0.0);
        assert!(r.latency_p50_us <= r.latency_p95_us);
        assert!(r.latency_p95_us <= r.latency_p99_us);
        assert!(r.latency_p99_us <= r.latency_max_us as f64);
        // Every completion evaluated the warm graph exactly.
        let per_req = r.workloads["micro@4"].cycles_per_request;
        assert_eq!(r.total_cycles, 16 * per_req);
        assert!(r.memo_hits > 0, "served requests hit the warm memo");
    }

    #[test]
    fn report_json_lists_every_counter() {
        let opts = micro_opts();
        let trace =
            synth_trace(&ArrivalSpec::Uniform { rate_per_s: 100.0 }, &["micro@4".into()], 4, 1)
                .unwrap();
        let outcome = run(&opts, &trace).unwrap();
        let j = outcome.report.to_json();
        for key in ServeReport::JSON_FIELDS {
            assert!(j.get(key).is_some(), "report JSON missing '{key}'");
        }
        assert_eq!(
            j.get("schema_version").and_then(|v| v.as_i64()),
            Some(SERVE_SCHEMA_VERSION as i64)
        );
    }

    #[test]
    fn report_json_roundtrips_strictly() {
        let opts = micro_opts();
        let trace =
            synth_trace(&ArrivalSpec::Poisson { rate_per_s: 400.0 }, &["micro@4".into()], 12, 3)
                .unwrap();
        let report = run(&opts, &trace).unwrap().report;
        let j = report.to_json();
        // Exact round trip, through text and back.
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(ServeReport::from_json(&reparsed), Some(report.clone()));
        // Unknown field → rejected.
        if let Json::Object(mut map) = j.clone() {
            map.insert("wall_ns".into(), Json::Int(1));
            assert_eq!(ServeReport::from_json(&Json::Object(map)), None);
        }
        // Missing field → rejected.
        if let Json::Object(mut map) = j.clone() {
            map.remove("completed");
            assert_eq!(ServeReport::from_json(&Json::Object(map)), None);
        }
        // Wrong schema version → rejected.
        if let Json::Object(mut map) = j {
            map.insert("schema_version".into(), Json::Int(1));
            assert_eq!(ServeReport::from_json(&Json::Object(map)), None);
        }
    }

    #[test]
    fn builder_validates_at_build_time() {
        let built = ServeOptions::builder()
            .cfg(presets::tiny_config())
            .workloads(vec![WorkloadSpec::Micro { block: 4 }])
            .max_batch(4)
            .queue_depth(32)
            .build()
            .unwrap();
        assert_eq!(built.max_batch, 4);
        assert_eq!(built.queue_depth, 32);

        let err = ServeOptions::builder().max_batch(0).build().unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        let err = ServeOptions::builder().workloads(vec![]).build().unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        let err = ServeOptions::builder().deadline_us(Some(0)).build().unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        let err = ServeOptions::builder().backend(BackendKind::Fsim).build().unwrap_err();
        assert!(matches!(err, VtaError::Unsupported(_)), "got {err:?}");
        let err = ServeOptions::builder()
            .workloads(vec![
                WorkloadSpec::Micro { block: 4 },
                WorkloadSpec::Micro { block: 4 },
            ])
            .build()
            .unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
    }

    #[test]
    fn struct_literal_path_runs_the_same_validation() {
        // The old construction style still works and still hits the
        // builder's checks (via `validate` inside the pool build).
        let mut opts = micro_opts();
        opts.deadline_us = Some(0);
        let err = run(&opts, &[]).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
    }

    #[test]
    fn empty_trace_produces_zeroed_report() {
        let outcome = run(&micro_opts(), &[]).unwrap();
        assert_eq!(outcome.report.completed, 0);
        assert_eq!(outcome.report.throughput_rps, 0.0);
        assert!(outcome.batches.is_empty());
    }
}
