//! SLO-aware heterogeneous serving fleet (`vta serve --fleet`).
//!
//! A [`Fleet`] is N *virtual devices*, each a `(VtaConfig, warm
//! SessionPool)` pair instantiated at a different point of the
//! area/performance curve — typically Pareto points from a design-space
//! sweep ([`configs_from_sweep`]) or an explicit config list. Every
//! device prices each pooled workload at warmup (VTA cycle counts are
//! data-independent), so the fleet scheduler knows, before anything
//! runs, exactly what a request costs on every device.
//!
//! # Routing
//!
//! [`schedule_fleet`] extends the single-device virtual-time scheduler
//! (`serve::sched`) to one `Lane` per device replica. Each admitted
//! arrival is routed by a pluggable [`RoutePolicy`] over [`LaneView`]s
//! — per-lane snapshots of queue depth, warm per-request cost, device
//! area, and an optimistic completion estimate. The default
//! [`EarliestFeasibleCheapest`] policy picks the cheapest (smallest
//! scaled-area) device estimated to finish within the request's
//! deadline, falling back to the earliest-finishing lane when none is
//! feasible; [`LeastLoaded`] and [`CheapestFirst`] are the pluggable
//! alternatives. The completion estimate ignores co-batching (it
//! assumes the request dispatches alone), so it is a routing heuristic,
//! not a guarantee — the scheduler's start-time deadline rule still
//! decides expiry.
//!
//! # Work shedding and autoscaling
//!
//! The driver only offers lanes with admission headroom
//! (`depth < queue_depth`), so a full device spills its overflow onto
//! its peers — cross-replica shedding is structural, not a policy
//! concern. A request every active lane refuses is shed and counted
//! `rejected_queue_full`, exactly as in the single-device path.
//! Optional simulated autoscaling ([`AutoscaleOptions`]) walks fixed
//! virtual-time boundaries: a device whose total backlog exceeds
//! `scale_up_depth × active_replicas` spawns one replica lane (up to
//! `max_replicas`); an underloaded device retires its highest-indexed
//! idle replica, never its last. Replica-seconds are priced by
//! [`scaled_area`] into the report's `area_us` integral.
//!
//! # Determinism and the frontier
//!
//! Routing and autoscaling are part of the virtual-time model: a
//! [`FleetReport`] is a pure function of `(trace, device costs,
//! options)` and its JSON is byte-identical across `--jobs 1/N`
//! (`rust/tests/fleet_serving.rs` pins this). [`frontier`] runs every
//! single-device candidate plus the combined fleet over the same trace
//! and marks the `(peak_area, p99 latency)` Pareto survivors — the
//! cost-vs-SLO report `vta serve --fleet` prints.

use super::load::Request;
use super::pool::{shared_graphs, SessionPool};
use super::sched::{self, Batch, Lane, SchedOptions, Schedule};
use super::{schedule_digest, summarize_latencies, ServeOptions};
use crate::analysis::area::scaled_area;
use crate::config::{presets, VtaConfig};
use crate::engine::{BackendKind, EvalRequest, VtaError};
use crate::sweep::{ParetoFront, PointResult};
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Version stamped into [`FleetReport::to_json`] and
/// [`FrontierOutcome::to_json`] as `schema_version`; the strict
/// [`FleetReport::from_json`] requires it verbatim. Bump on any field
/// change.
pub const FLEET_SCHEMA_VERSION: u32 = 1;

/// What the scheduler knows about one device *kind*: its config tag,
/// its warm per-request service times, and its area price. Built from a
/// real [`Fleet`] by [`Fleet::device_costs`], or by hand for
/// scheduler-level tests — routing never needs an engine.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceCost {
    /// Config tag ([`VtaConfig::tag`]) — the device's identity in
    /// reports.
    pub config: String,
    /// Workload id → warm per-request virtual service time
    /// ([`SessionPool::service_map`]).
    pub service_us: BTreeMap<String, u64>,
    /// Area price of one replica, relative to the default config
    /// ([`scaled_area`]).
    pub scaled_area: f64,
}

/// One routable lane, as a [`RoutePolicy`] sees it at an arrival. The
/// driver only offers lanes with admission headroom, so any offered
/// lane can accept the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneView {
    /// Lane id; `route` returns one of the offered ids.
    pub lane: usize,
    /// Device kind backing this lane (index into the fleet's devices).
    pub device: usize,
    /// Area price of this lane's device.
    pub scaled_area: f64,
    /// Warm per-request service time of the arriving request's workload
    /// on this device.
    pub service_us: u64,
    /// Requests waiting or in flight on this lane.
    pub depth: usize,
    /// Optimistic completion estimate: the lane frees up, pays the
    /// dispatch overhead, and runs the request alone (co-batching and
    /// the open-batch window are ignored).
    pub est_done_us: u64,
}

/// A deterministic routing rule: pick one lane for each admitted
/// arrival.
///
/// The contract, pinned by `rust/tests/fleet_serving.rs`:
///
/// * `lanes` is never empty and every offered lane has admission
///   headroom (the driver sheds the request itself when no lane does);
/// * the return value must be the `lane` id of an *offered* view —
///   anything else sheds the request (counted `rejected_queue_full`),
///   keeping the schedule total rather than panicking on a buggy
///   policy;
/// * the decision may depend only on the arguments — no clocks, no
///   randomness — or fleet reports lose their cross-worker-count
///   byte-identity.
pub trait RoutePolicy: Send + Sync {
    /// Short stable name, recorded in [`FleetReport::policy`].
    fn name(&self) -> &'static str;

    /// Choose a lane for a request arriving at `now_us` with an
    /// optional relative deadline of `deadline_us`.
    fn route(&self, now_us: u64, deadline_us: Option<u64>, lanes: &[LaneView]) -> usize;
}

/// Default policy: the cheapest device estimated to finish within the
/// deadline; ties break toward the earlier finisher, then the lower
/// lane id. With no deadline every lane is feasible, so this routes to
/// the cheapest device outright; when *no* lane is feasible it degrades
/// to earliest-finishing (minimize lateness).
#[derive(Debug, Clone, Copy, Default)]
pub struct EarliestFeasibleCheapest;

impl RoutePolicy for EarliestFeasibleCheapest {
    fn name(&self) -> &'static str {
        "earliest"
    }

    fn route(&self, now_us: u64, deadline_us: Option<u64>, lanes: &[LaneView]) -> usize {
        let feasible = |v: &&LaneView| match deadline_us {
            Some(d) => v.est_done_us <= now_us.saturating_add(d),
            None => true,
        };
        let cheapest_feasible = lanes.iter().filter(feasible).min_by(|a, b| {
            a.scaled_area
                .total_cmp(&b.scaled_area)
                .then(a.est_done_us.cmp(&b.est_done_us))
                .then(a.lane.cmp(&b.lane))
        });
        match cheapest_feasible {
            Some(v) => v.lane,
            None => {
                lanes
                    .iter()
                    .min_by(|a, b| a.est_done_us.cmp(&b.est_done_us).then(a.lane.cmp(&b.lane)))
                    .expect("the driver never offers an empty lane set")
                    .lane
            }
        }
    }
}

/// Route to the shallowest queue; ties break toward the earlier
/// finisher, then the lower lane id. Deadline-blind load balancing.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&self, _now_us: u64, _deadline_us: Option<u64>, lanes: &[LaneView]) -> usize {
        lanes
            .iter()
            .min_by(|a, b| {
                a.depth
                    .cmp(&b.depth)
                    .then(a.est_done_us.cmp(&b.est_done_us))
                    .then(a.lane.cmp(&b.lane))
            })
            .expect("the driver never offers an empty lane set")
            .lane
    }
}

/// Route to the lowest-area device unconditionally (the cost-greedy
/// baseline the frontier compares against); ties break toward the
/// lower lane id.
#[derive(Debug, Clone, Copy, Default)]
pub struct CheapestFirst;

impl RoutePolicy for CheapestFirst {
    fn name(&self) -> &'static str {
        "cheapest"
    }

    fn route(&self, _now_us: u64, _deadline_us: Option<u64>, lanes: &[LaneView]) -> usize {
        lanes
            .iter()
            .min_by(|a, b| a.scaled_area.total_cmp(&b.scaled_area).then(a.lane.cmp(&b.lane)))
            .expect("the driver never offers an empty lane set")
            .lane
    }
}

/// The built-in routing policies, as a CLI-parseable enum
/// (`vta serve --fleet --route <name>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicyKind {
    EarliestFeasibleCheapest,
    LeastLoaded,
    CheapestFirst,
}

impl RoutePolicyKind {
    /// Parse a CLI token; the error names the offending token.
    pub fn parse(s: &str) -> Result<RoutePolicyKind, VtaError> {
        match s {
            "earliest" | "efc" | "earliest-feasible-cheapest" => {
                Ok(RoutePolicyKind::EarliestFeasibleCheapest)
            }
            "least-loaded" | "least_loaded" => Ok(RoutePolicyKind::LeastLoaded),
            "cheapest" | "cheapest-first" => Ok(RoutePolicyKind::CheapestFirst),
            _ => Err(VtaError::InvalidRequest(format!(
                "unknown route policy '{s}' (expected earliest, least-loaded, or cheapest)"
            ))),
        }
    }

    /// Canonical CLI name (`parse` round-trips it); matches the
    /// instantiated policy's [`RoutePolicy::name`].
    pub fn cli_name(self) -> &'static str {
        match self {
            RoutePolicyKind::EarliestFeasibleCheapest => "earliest",
            RoutePolicyKind::LeastLoaded => "least-loaded",
            RoutePolicyKind::CheapestFirst => "cheapest",
        }
    }

    pub fn instantiate(self) -> Box<dyn RoutePolicy> {
        match self {
            RoutePolicyKind::EarliestFeasibleCheapest => Box::new(EarliestFeasibleCheapest),
            RoutePolicyKind::LeastLoaded => Box::new(LeastLoaded),
            RoutePolicyKind::CheapestFirst => Box::new(CheapestFirst),
        }
    }
}

impl std::fmt::Display for RoutePolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.cli_name())
    }
}

/// Simulated autoscaling knobs. The scaler walks fixed virtual-time
/// boundaries (`interval_us` apart) and takes at most one action per
/// device per boundary: spawn one replica when the device's total
/// backlog exceeds `scale_up_depth × active_replicas` (up to
/// `max_replicas`), otherwise retire the highest-indexed idle replica
/// when more than one is active.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AutoscaleOptions {
    /// Virtual µs between autoscaling decisions (≥ 1).
    pub interval_us: u64,
    /// Replica cap per device kind (≥ 1).
    pub max_replicas: usize,
    /// Backlog-per-replica threshold that triggers a scale-up (≥ 1).
    pub scale_up_depth: usize,
}

impl Default for AutoscaleOptions {
    fn default() -> AutoscaleOptions {
        AutoscaleOptions { interval_us: 5_000, max_replicas: 4, scale_up_depth: 4 }
    }
}

impl AutoscaleOptions {
    pub fn validate(&self) -> Result<(), VtaError> {
        if self.interval_us == 0 {
            return Err(VtaError::InvalidRequest(
                "autoscale interval_us must be at least 1".into(),
            ));
        }
        if self.max_replicas == 0 {
            return Err(VtaError::InvalidRequest(
                "autoscale max_replicas must be at least 1".into(),
            ));
        }
        if self.scale_up_depth == 0 {
            return Err(VtaError::InvalidRequest(
                "autoscale scale_up_depth must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// One lane's lifetime: which device kind it replicates and when the
/// autoscaler spawned/retired it (virtual µs). Lane 0..N-1 are the
/// initial replicas (spawned at 0, one per device); autoscaled replicas
/// append after them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneAssignment {
    /// Device kind (index into the fleet's devices / `DeviceCost`s).
    pub device: usize,
    pub spawned_us: u64,
    /// `None` while the lane is still active at trace end.
    pub retired_us: Option<u64>,
}

/// Everything [`schedule_fleet`] decided: the merged virtual-time
/// [`Schedule`] (each [`Batch::device`] is a lane id), the lane → device
/// map, and the autoscaler's area accounting.
#[derive(Debug)]
pub struct FleetSchedule {
    pub schedule: Schedule,
    /// Lane id → its assignment ([`Batch::device`] indexes this).
    pub lanes: Vec<LaneAssignment>,
    /// Largest Σ scaled-area over simultaneously active lanes.
    pub peak_area: f64,
    /// Per device kind: most replicas simultaneously active.
    pub peak_replicas: Vec<usize>,
}

/// One live lane plus its lifetime record.
struct LaneState {
    meta: LaneAssignment,
    lane: Lane,
}

/// The fleet driver's mutable state: lanes plus the autoscaler's
/// per-device accounting.
struct FleetState {
    lanes: Vec<LaneState>,
    /// Active replicas per device kind.
    active: Vec<usize>,
    peak_replicas: Vec<usize>,
    current_area: f64,
    peak_area: f64,
}

impl FleetState {
    fn new(devices: &[DeviceCost]) -> FleetState {
        let lanes: Vec<LaneState> = devices
            .iter()
            .enumerate()
            .map(|(d, _)| LaneState {
                meta: LaneAssignment { device: d, spawned_us: 0, retired_us: None },
                lane: Lane::new(d),
            })
            .collect();
        let current_area: f64 = devices.iter().map(|d| d.scaled_area).sum();
        FleetState {
            lanes,
            active: vec![1; devices.len()],
            peak_replicas: vec![1; devices.len()],
            current_area,
            peak_area: current_area,
        }
    }

    /// Advance every active lane's virtual clock to `now`.
    fn advance(
        &mut self,
        now: u64,
        trace: &[Request],
        devices: &[DeviceCost],
        opts: &SchedOptions,
        out: &mut Schedule,
    ) {
        for ls in &mut self.lanes {
            if ls.meta.retired_us.is_none() {
                ls.lane.advance(now, trace, &devices[ls.meta.device].service_us, opts, out);
            }
        }
    }

    /// Lanes a router may pick for `workload` at `now`: active, with
    /// admission headroom. Cross-replica shedding falls out of this
    /// filter — a full lane's traffic can only go to its peers.
    fn views(
        &self,
        workload: &str,
        now: u64,
        devices: &[DeviceCost],
        opts: &SchedOptions,
    ) -> Vec<LaneView> {
        self.lanes
            .iter()
            .enumerate()
            .filter(|(_, ls)| ls.meta.retired_us.is_none() && ls.lane.depth() < opts.queue_depth)
            .map(|(id, ls)| {
                let device = ls.meta.device;
                let service_us = devices[device].service_us[workload];
                let est_done_us = ls
                    .lane
                    .free_us()
                    .max(now)
                    .saturating_add(opts.dispatch_overhead_us)
                    .saturating_add(service_us);
                LaneView {
                    lane: id,
                    device,
                    scaled_area: devices[device].scaled_area,
                    service_us,
                    depth: ls.lane.depth(),
                    est_done_us,
                }
            })
            .collect()
    }

    /// One autoscaling decision per device kind at boundary `t` (the
    /// lanes are already advanced to `t`): spawn one replica if
    /// overloaded and under the cap, else retire the highest-indexed
    /// idle replica if underloaded and more than one is active.
    fn autoscale_step(&mut self, t: u64, devices: &[DeviceCost], auto: &AutoscaleOptions) {
        for d in 0..devices.len() {
            let backlog: usize = self
                .lanes
                .iter()
                .filter(|ls| ls.meta.device == d && ls.meta.retired_us.is_none())
                .map(|ls| ls.lane.depth())
                .sum();
            let overloaded = backlog > auto.scale_up_depth * self.active[d];
            if overloaded && self.active[d] < auto.max_replicas {
                let id = self.lanes.len();
                self.lanes.push(LaneState {
                    meta: LaneAssignment { device: d, spawned_us: t, retired_us: None },
                    lane: Lane::new(id),
                });
                self.active[d] += 1;
                self.peak_replicas[d] = self.peak_replicas[d].max(self.active[d]);
                self.current_area += devices[d].scaled_area;
                self.peak_area = self.peak_area.max(self.current_area);
            } else if !overloaded && self.active[d] > 1 {
                let idle = self
                    .lanes
                    .iter()
                    .enumerate()
                    .rev()
                    .find(|(_, ls)| {
                        ls.meta.device == d
                            && ls.meta.retired_us.is_none()
                            && ls.lane.depth() == 0
                            && ls.lane.free_us() <= t
                    })
                    .map(|(i, _)| i);
                if let Some(i) = idle {
                    self.lanes[i].meta.retired_us = Some(t);
                    self.active[d] -= 1;
                    self.current_area -= devices[d].scaled_area;
                }
            }
        }
    }
}

/// Compute a fleet schedule: one `Lane` per device replica, arrivals
/// routed by `policy`, optional simulated autoscaling. Pure and total —
/// the same inputs always produce the same [`FleetSchedule`] — and
/// built on the exact event machinery of the single-device
/// [`schedule`](super::schedule): with one device, no deadline
/// pressure, and no autoscaler it makes identical decisions.
pub fn schedule_fleet(
    trace: &[Request],
    devices: &[DeviceCost],
    policy: &dyn RoutePolicy,
    opts: &SchedOptions,
    autoscale: Option<&AutoscaleOptions>,
) -> Result<FleetSchedule, VtaError> {
    sched::check_options(opts)?;
    if devices.is_empty() {
        return Err(VtaError::InvalidRequest("a fleet needs at least one device".into()));
    }
    for d in devices {
        sched::check_trace(trace, &d.service_us)?;
    }
    if let Some(a) = autoscale {
        a.validate()?;
    }

    let mut order: Vec<usize> = (0..trace.len()).collect();
    order.sort_by_key(|&i| (trace[i].t_us, i));

    let mut state = FleetState::new(devices);
    let mut out = Schedule::default();
    let mut next_batch_id = 0usize;
    let mut next_step = autoscale.map(|a| a.interval_us);

    for &i in &order {
        let now = trace[i].t_us;
        // Autoscaling boundaries fire in event order, interleaved with
        // arrivals: lanes advance to each boundary before it decides.
        if let Some(auto) = autoscale {
            while let Some(t) = next_step.filter(|&t| t <= now) {
                state.advance(t, trace, devices, opts, &mut out);
                state.autoscale_step(t, devices, auto);
                let following = t.saturating_add(auto.interval_us);
                // A saturated clock has no further boundaries.
                next_step = (following > t).then_some(following);
            }
        }
        state.advance(now, trace, devices, opts, &mut out);
        let views = state.views(&trace[i].workload, now, devices, opts);
        if views.is_empty() {
            out.rejected_queue_full.push(i);
            continue;
        }
        let choice = policy.route(now, opts.deadline_us, &views);
        if !views.iter().any(|v| v.lane == choice) {
            out.rejected_queue_full.push(i);
            continue;
        }
        let ls = &mut state.lanes[choice];
        let svc = &devices[ls.meta.device].service_us;
        ls.lane.admit(i, now, trace, svc, opts, &mut out, &mut next_batch_id);
    }
    for ls in &mut state.lanes {
        ls.lane.flush(trace, &devices[ls.meta.device].service_us, opts, &mut out);
    }
    Ok(FleetSchedule {
        schedule: out,
        lanes: state.lanes.into_iter().map(|ls| ls.meta).collect(),
        peak_area: state.peak_area,
        peak_replicas: state.peak_replicas,
    })
}

/// One device kind of a built fleet: its config, identity tag, area
/// price, and warm session pool.
pub struct FleetDevice {
    pub cfg: VtaConfig,
    /// [`VtaConfig::tag`] — the device's identity in reports.
    pub tag: String,
    pub scaled_area: f64,
    pub pool: SessionPool,
}

/// N warm virtual devices over one shared set of workload graphs.
pub struct Fleet {
    devices: Vec<FleetDevice>,
}

impl Fleet {
    /// Build and warm one [`SessionPool`] per device config. The
    /// expensive graph build + shape propagation run once
    /// ([`shared_graphs`]); each device pays only its own config
    /// validation and warmup.
    pub fn build(opts: &FleetOptions) -> Result<Fleet, VtaError> {
        opts.validate()?;
        let graphs = shared_graphs(&opts.base.workloads, opts.base.graph_seed)?;
        let mut devices = Vec::with_capacity(opts.configs.len());
        for cfg in &opts.configs {
            let pool = SessionPool::build_for(cfg, &opts.base, &graphs)?;
            devices.push(FleetDevice {
                cfg: cfg.clone(),
                tag: cfg.tag(),
                scaled_area: scaled_area(cfg),
                pool,
            });
        }
        Ok(Fleet { devices })
    }

    pub fn devices(&self) -> &[FleetDevice] {
        &self.devices
    }

    /// The scheduler-facing view of every device.
    pub fn device_costs(&self) -> Vec<DeviceCost> {
        self.devices
            .iter()
            .map(|d| DeviceCost {
                config: d.tag.clone(),
                service_us: d.pool.service_map(),
                scaled_area: d.scaled_area,
            })
            .collect()
    }
}

/// The default three-device fleet: one geometry (1×16×16) at three
/// memory/scratchpad scaling points, spanning the area axis. Tags:
/// `1x16x16-axi8`, `1x16x16-axi16`, `1x16x16-axi64`.
pub fn default_fleet_configs() -> Vec<VtaConfig> {
    vec![
        presets::scaled_config(1, 16, 16, 1, 8),
        presets::scaled_config(1, 16, 16, 2, 16),
        presets::scaled_config(1, 16, 16, 4, 64),
    ]
}

/// Everything a fleet run needs: the base serving options (workloads,
/// backend, scheduler knobs — `base.cfg` is unused, each device brings
/// its own), the device configs, the routing policy, and optional
/// autoscaling.
#[derive(Clone)]
pub struct FleetOptions {
    pub base: ServeOptions,
    /// One entry per device kind; tags must be distinct.
    pub configs: Vec<VtaConfig>,
    pub policy: RoutePolicyKind,
    /// `None` = fixed one-replica-per-device fleet.
    pub autoscale: Option<AutoscaleOptions>,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            base: ServeOptions::default(),
            configs: default_fleet_configs(),
            policy: RoutePolicyKind::EarliestFeasibleCheapest,
            autoscale: None,
        }
    }
}

impl FleetOptions {
    /// The full option check ([`ServeOptions::validate`] plus the
    /// fleet-specific rules): at least one valid device config,
    /// pairwise-distinct tags, valid autoscale knobs.
    pub fn validate(&self) -> Result<(), VtaError> {
        self.base.validate()?;
        if self.configs.is_empty() {
            return Err(VtaError::InvalidRequest("a fleet needs at least one device".into()));
        }
        let mut tags: Vec<String> = Vec::with_capacity(self.configs.len());
        for cfg in &self.configs {
            cfg.validate()?;
            let tag = cfg.tag();
            if tags.contains(&tag) {
                return Err(VtaError::InvalidRequest(format!(
                    "fleet device tag '{tag}' appears twice (device identity is the config \
                     tag, which ignores scratchpad scale — vary batch, block, or axi_bytes)"
                )));
            }
            tags.push(tag);
        }
        if let Some(a) = &self.autoscale {
            a.validate()?;
        }
        Ok(())
    }
}

/// Per-device line of a [`FleetReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceReport {
    /// Config tag.
    pub config: String,
    /// Area price of one replica.
    pub scaled_area: f64,
    /// Most replicas simultaneously active.
    pub peak_replicas: usize,
    /// Replicas ever spawned (initial + autoscaled).
    pub lanes_spawned: usize,
    /// Requests the router sent here (completed + expired).
    pub routed: usize,
    pub completed: usize,
    pub expired_deadline: usize,
    pub batches_dispatched: usize,
    pub total_cycles: u64,
    /// Σ over this device's lanes of `scaled_area × active time` —
    /// replica-µs priced by area.
    pub area_us: f64,
}

impl DeviceReport {
    /// Every key of a device entry; [`DeviceReport::from_json`]
    /// requires exactly this set.
    pub const JSON_FIELDS: [&'static str; 10] = [
        "config",
        "scaled_area",
        "peak_replicas",
        "lanes_spawned",
        "routed",
        "completed",
        "expired_deadline",
        "batches_dispatched",
        "total_cycles",
        "area_us",
    ];

    pub fn to_json(&self) -> Json {
        obj([
            ("config", Json::Str(self.config.clone())),
            ("scaled_area", Json::Float(self.scaled_area)),
            ("peak_replicas", Json::Int(self.peak_replicas as i64)),
            ("lanes_spawned", Json::Int(self.lanes_spawned as i64)),
            ("routed", Json::Int(self.routed as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("expired_deadline", Json::Int(self.expired_deadline as i64)),
            ("batches_dispatched", Json::Int(self.batches_dispatched as i64)),
            ("total_cycles", Json::Int(self.total_cycles as i64)),
            ("area_us", Json::Float(self.area_us)),
        ])
    }

    /// Strict inverse of [`DeviceReport::to_json`] (exact field set).
    pub fn from_json(j: &Json) -> Option<DeviceReport> {
        let map = j.as_object()?;
        if map.len() != Self::JSON_FIELDS.len()
            || !Self::JSON_FIELDS.iter().all(|f| map.contains_key(*f))
        {
            return None;
        }
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some(DeviceReport {
            config: j.get("config")?.as_str()?.to_string(),
            scaled_area: j.get("scaled_area")?.as_f64()?,
            peak_replicas: int("peak_replicas")? as usize,
            lanes_spawned: int("lanes_spawned")? as usize,
            routed: int("routed")? as usize,
            completed: int("completed")? as usize,
            expired_deadline: int("expired_deadline")? as usize,
            batches_dispatched: int("batches_dispatched")? as usize,
            total_cycles: int("total_cycles")?,
            area_us: j.get("area_us")?.as_f64()?,
        })
    }
}

/// The fleet run's metrics. Like [`ServeReport`](super::ServeReport),
/// every field derives from the virtual schedule, so the JSON is
/// byte-identical across worker counts; wall clock lives in
/// [`FleetOutcome`].
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// Routing policy name ([`RoutePolicy::name`]).
    pub policy: String,
    pub backend: BackendKind,
    pub clock_mhz: u64,
    /// One line per device kind, fleet order.
    pub devices: Vec<DeviceReport>,
    pub submitted: usize,
    pub admitted: usize,
    pub completed: usize,
    pub rejected_queue_full: usize,
    pub expired_deadline: usize,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub latency_max_us: u64,
    /// First arrival → last completion, virtual µs.
    pub makespan_us: u64,
    /// Completed requests per virtual second.
    pub throughput_rps: f64,
    pub total_cycles: u64,
    /// Largest Σ scaled-area over simultaneously active replicas — the
    /// frontier's provisioning-cost axis.
    pub peak_area: f64,
    /// Σ replica-µs priced by area (the autoscaler's energy-style
    /// integral).
    pub area_us: f64,
    pub memo_hits: u64,
    pub memo_misses: u64,
    pub schedule_digest: u64,
}

impl FleetReport {
    /// Every key [`FleetReport::to_json`] writes;
    /// [`FleetReport::from_json`] requires exactly this set.
    pub const JSON_FIELDS: [&'static str; 23] = [
        "schema_version",
        "policy",
        "backend",
        "clock_mhz",
        "devices",
        "submitted",
        "admitted",
        "completed",
        "rejected_queue_full",
        "expired_deadline",
        "latency_p50_us",
        "latency_p95_us",
        "latency_p99_us",
        "latency_mean_us",
        "latency_max_us",
        "makespan_us",
        "throughput_rps",
        "total_cycles",
        "peak_area",
        "area_us",
        "memo_hits",
        "memo_misses",
        "schedule_digest",
    ];

    /// Deterministic JSON (no wall-clock or worker-count fields);
    /// carries [`FLEET_SCHEMA_VERSION`] as `schema_version`.
    pub fn to_json(&self) -> Json {
        obj([
            ("schema_version", Json::Int(FLEET_SCHEMA_VERSION as i64)),
            ("policy", Json::Str(self.policy.clone())),
            ("backend", Json::Str(self.backend.cli_name().to_string())),
            ("clock_mhz", Json::Int(self.clock_mhz as i64)),
            ("devices", Json::Array(self.devices.iter().map(|d| d.to_json()).collect())),
            ("submitted", Json::Int(self.submitted as i64)),
            ("admitted", Json::Int(self.admitted as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("rejected_queue_full", Json::Int(self.rejected_queue_full as i64)),
            ("expired_deadline", Json::Int(self.expired_deadline as i64)),
            ("latency_p50_us", Json::Float(self.latency_p50_us)),
            ("latency_p95_us", Json::Float(self.latency_p95_us)),
            ("latency_p99_us", Json::Float(self.latency_p99_us)),
            ("latency_mean_us", Json::Float(self.latency_mean_us)),
            ("latency_max_us", Json::Int(self.latency_max_us as i64)),
            ("makespan_us", Json::Int(self.makespan_us as i64)),
            ("throughput_rps", Json::Float(self.throughput_rps)),
            ("total_cycles", Json::Int(self.total_cycles as i64)),
            ("peak_area", Json::Float(self.peak_area)),
            ("area_us", Json::Float(self.area_us)),
            ("memo_hits", Json::Int(self.memo_hits as i64)),
            ("memo_misses", Json::Int(self.memo_misses as i64)),
            ("schedule_digest", Json::Str(format!("{:016x}", self.schedule_digest))),
        ])
    }

    /// Strict inverse of [`FleetReport::to_json`]: `None` unless the
    /// object holds **exactly** [`FleetReport::JSON_FIELDS`] (same for
    /// each device entry) and `schema_version` matches
    /// [`FLEET_SCHEMA_VERSION`] — the `ExecCounters::from_json`
    /// contract.
    pub fn from_json(j: &Json) -> Option<FleetReport> {
        let map = j.as_object()?;
        if map.len() != Self::JSON_FIELDS.len()
            || !Self::JSON_FIELDS.iter().all(|f| map.contains_key(*f))
        {
            return None;
        }
        if j.get("schema_version")?.as_i64()? != FLEET_SCHEMA_VERSION as i64 {
            return None;
        }
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        let float = |name: &str| j.get(name).and_then(|v| v.as_f64());
        let mut devices = Vec::new();
        for d in j.get("devices")?.as_array()? {
            devices.push(DeviceReport::from_json(d)?);
        }
        Some(FleetReport {
            policy: j.get("policy")?.as_str()?.to_string(),
            backend: BackendKind::parse(j.get("backend")?.as_str()?).ok()?,
            clock_mhz: int("clock_mhz")?,
            devices,
            submitted: int("submitted")? as usize,
            admitted: int("admitted")? as usize,
            completed: int("completed")? as usize,
            rejected_queue_full: int("rejected_queue_full")? as usize,
            expired_deadline: int("expired_deadline")? as usize,
            latency_p50_us: float("latency_p50_us")?,
            latency_p95_us: float("latency_p95_us")?,
            latency_p99_us: float("latency_p99_us")?,
            latency_mean_us: float("latency_mean_us")?,
            latency_max_us: int("latency_max_us")?,
            makespan_us: int("makespan_us")?,
            throughput_rps: float("throughput_rps")?,
            total_cycles: int("total_cycles")?,
            peak_area: float("peak_area")?,
            area_us: float("area_us")?,
            memo_hits: int("memo_hits")?,
            memo_misses: int("memo_misses")?,
            schedule_digest: u64::from_str_radix(j.get("schedule_digest")?.as_str()?, 16)
                .ok()?,
        })
    }
}

/// What [`run_fleet`] hands back: the deterministic report, the merged
/// batch schedule and lane map (for inspection and tests), and the
/// wall-clock facts that deliberately stay out of the report.
pub struct FleetOutcome {
    pub report: FleetReport,
    /// The dispatched schedule, close order; [`Batch::device`] indexes
    /// `lanes`.
    pub batches: Vec<Batch>,
    pub lanes: Vec<LaneAssignment>,
    /// Wall-clock nanoseconds of the batch-execution phase.
    pub wall_ns: u64,
    /// Worker threads used for execution.
    pub workers: usize,
}

/// Serve a trace on a heterogeneous fleet end-to-end: build + warm one
/// pool per device over shared graphs, compute the routed virtual-time
/// schedule, execute every batch on its device's warm pool across the
/// worker pool, and assemble the report.
pub fn run_fleet(opts: &FleetOptions, trace: &[Request]) -> Result<FleetOutcome, VtaError> {
    let fleet = Fleet::build(opts)?;
    let devices = fleet.device_costs();
    let policy = opts.policy.instantiate();
    let fs = schedule_fleet(
        trace,
        &devices,
        policy.as_ref(),
        &opts.base.sched_options(),
        opts.autoscale.as_ref(),
    )?;

    // Execute the fixed schedule. Workers change wall clock only: slot
    // `b` always holds batch `b`'s cycles.
    let jobs = crate::sweep::effective_jobs(opts.base.jobs);
    let workers = jobs.min(fs.schedule.batches.len().max(1));
    let wall_start = std::time::Instant::now();
    let batch_results: Vec<Result<u64, VtaError>> =
        crate::util::pool::run_indexed(workers, fs.schedule.batches.len(), |b| {
            let batch = &fs.schedule.batches[b];
            let device = fs.lanes[batch.device].device;
            let entry = fleet.devices[device]
                .pool
                .get(&batch.workload)
                .expect("the scheduler only dispatches pooled workloads");
            // One batched evaluation per dispatched batch (one session
            // reused across its requests); bit-identical to the
            // per-request loop, so routing reports are unchanged.
            let requests: Vec<EvalRequest> =
                batch.requests.iter().map(|&r| EvalRequest::seeded(trace[r].seed)).collect();
            let evals = entry.engine.eval_many_shared(&entry.prepared, &requests)?;
            Ok(evals
                .iter()
                .map(|e| e.cycles.expect("pool backends produce cycles"))
                .sum::<u64>())
        });
    let wall_ns = wall_start.elapsed().as_nanos() as u64;
    let mut batch_cycles = Vec::with_capacity(batch_results.len());
    for r in batch_results {
        batch_cycles.push(r?);
    }

    let report = assemble_fleet_report(opts, &fleet, &fs, trace, &batch_cycles);
    Ok(FleetOutcome { report, batches: fs.schedule.batches, lanes: fs.lanes, wall_ns, workers })
}

fn assemble_fleet_report(
    opts: &FleetOptions,
    fleet: &Fleet,
    fs: &FleetSchedule,
    trace: &[Request],
    batch_cycles: &[u64],
) -> FleetReport {
    let n = fleet.devices.len();
    let mut routed = vec![0usize; n];
    let mut dev_completed = vec![0usize; n];
    let mut dev_expired = vec![0usize; n];
    let mut dev_batches = vec![0usize; n];
    let mut dev_cycles = vec![0u64; n];
    for (b, batch) in fs.schedule.batches.iter().enumerate() {
        let d = fs.lanes[batch.device].device;
        routed[d] += batch.requests.len() + batch.expired.len();
        dev_completed[d] += batch.requests.len();
        dev_expired[d] += batch.expired.len();
        if batch.occupancy() > 0 {
            dev_batches[d] += 1;
        }
        dev_cycles[d] += batch_cycles[b];
    }

    // Replica-µs: each lane is priced from spawn to retirement (or to
    // the horizon — last completion or last arrival — while active).
    let first_arrival = trace.iter().map(|r| r.t_us).min().unwrap_or(0);
    let last_arrival = trace.iter().map(|r| r.t_us).max().unwrap_or(0);
    let horizon = fs.schedule.makespan_end_us().max(last_arrival);
    let mut lanes_spawned = vec![0usize; n];
    let mut dev_area_us = vec![0.0f64; n];
    for lane in &fs.lanes {
        lanes_spawned[lane.device] += 1;
        let until = lane.retired_us.unwrap_or(horizon).min(horizon);
        let active_us = until.saturating_sub(lane.spawned_us) as f64;
        dev_area_us[lane.device] += fleet.devices[lane.device].scaled_area * active_us;
    }

    let mut memo_hits = 0u64;
    let mut memo_misses = 0u64;
    for dev in &fleet.devices {
        let (h, m) = dev.pool.memo_stats();
        memo_hits += h;
        memo_misses += m;
    }

    let devices: Vec<DeviceReport> = fleet
        .devices
        .iter()
        .enumerate()
        .map(|(d, dev)| DeviceReport {
            config: dev.tag.clone(),
            scaled_area: dev.scaled_area,
            peak_replicas: fs.peak_replicas[d],
            lanes_spawned: lanes_spawned[d],
            routed: routed[d],
            completed: dev_completed[d],
            expired_deadline: dev_expired[d],
            batches_dispatched: dev_batches[d],
            total_cycles: dev_cycles[d],
            area_us: dev_area_us[d],
        })
        .collect();

    let lat = summarize_latencies(&fs.schedule.latencies_us);
    let completed = fs.schedule.completed();
    let makespan_us = fs.schedule.makespan_end_us().saturating_sub(first_arrival);
    FleetReport {
        policy: opts.policy.cli_name().to_string(),
        backend: opts.base.backend,
        clock_mhz: opts.base.clock_mhz,
        devices,
        submitted: trace.len(),
        admitted: fs.schedule.admitted,
        completed,
        rejected_queue_full: fs.schedule.rejected_queue_full.len(),
        expired_deadline: fs.schedule.expired(),
        latency_p50_us: lat.p50,
        latency_p95_us: lat.p95,
        latency_p99_us: lat.p99,
        latency_mean_us: lat.mean,
        latency_max_us: lat.max_us,
        makespan_us,
        throughput_rps: completed as f64 / (makespan_us.max(1) as f64 / 1e6),
        total_cycles: batch_cycles.iter().sum(),
        peak_area: fs.peak_area,
        area_us: dev_area_us.iter().sum(),
        memo_hits,
        memo_misses,
        schedule_digest: schedule_digest(&fs.schedule.batches),
    }
}

/// One candidate of the cost-vs-SLO frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierEntry {
    /// A single device's tag, or `fleet(N)` for the combined fleet.
    pub label: String,
    /// Device tags of this candidate, fleet order.
    pub configs: Vec<String>,
    pub report: FleetReport,
    /// On the `(peak_area, p99 latency)` Pareto frontier over the
    /// candidates.
    pub pareto: bool,
}

/// The frontier over every candidate fleet composition, same trace.
pub struct FrontierOutcome {
    pub entries: Vec<FrontierEntry>,
    /// Wall-clock nanoseconds for the whole frontier run (stays out of
    /// [`FrontierOutcome::to_json`]).
    pub wall_ns: u64,
}

impl FrontierOutcome {
    /// Deterministic JSON: `schema_version` plus one entry per
    /// candidate, each embedding its full [`FleetReport::to_json`] —
    /// byte-identical across worker counts, like every report here.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                obj([
                    ("label", Json::Str(e.label.clone())),
                    (
                        "configs",
                        Json::Array(e.configs.iter().map(|c| Json::Str(c.clone())).collect()),
                    ),
                    ("pareto", Json::Bool(e.pareto)),
                    ("report", e.report.to_json()),
                ])
            })
            .collect();
        obj([
            ("schema_version", Json::Int(FLEET_SCHEMA_VERSION as i64)),
            ("entries", Json::Array(entries)),
        ])
    }
}

/// Run the cost-vs-SLO frontier: every single-device candidate in
/// `opts.configs`, plus the combined fleet when there is more than one,
/// all over the same trace and scheduler knobs. Entries on the
/// `(peak_area, rounded p99 latency)` Pareto frontier (both minimized)
/// are marked `pareto` — the fleet earns its place only by dominating
/// on cost or SLO.
pub fn frontier(opts: &FleetOptions, trace: &[Request]) -> Result<FrontierOutcome, VtaError> {
    opts.validate()?;
    let wall_start = std::time::Instant::now();
    let mut candidates: Vec<(String, Vec<VtaConfig>)> =
        opts.configs.iter().map(|c| (c.tag(), vec![c.clone()])).collect();
    if opts.configs.len() > 1 {
        candidates.push((format!("fleet({})", opts.configs.len()), opts.configs.clone()));
    }
    let mut entries = Vec::with_capacity(candidates.len());
    let mut front = ParetoFront::new();
    for (i, (label, configs)) in candidates.into_iter().enumerate() {
        let sub = FleetOptions { configs, ..opts.clone() };
        let outcome = run_fleet(&sub, trace)?;
        front.insert(outcome.report.peak_area, outcome.report.latency_p99_us.round() as u64, i);
        entries.push(FrontierEntry {
            label,
            configs: outcome.report.devices.iter().map(|d| d.config.clone()).collect(),
            report: outcome.report,
            pareto: false,
        });
    }
    for (i, e) in entries.iter_mut().enumerate() {
        e.pareto = front.contains(i);
    }
    Ok(FrontierOutcome { entries, wall_ns: wall_start.elapsed().as_nanos() as u64 })
}

/// Seed a fleet from a sweep's JSONL result cache: keep each config
/// tag's best (fewest-cycle) measured point, take the
/// `(scaled_area, cycles)` Pareto survivors, and return up to
/// `max_devices` configs in ascending-area order. Unparseable lines are
/// skipped (the cache may mix schema versions); a cache that yields no
/// readable point at all is a typed error.
pub fn configs_from_sweep(path: &Path, max_devices: usize) -> Result<Vec<VtaConfig>, VtaError> {
    if max_devices == 0 {
        return Err(VtaError::InvalidRequest(
            "a fleet needs at least one device (max_devices is 0)".into(),
        ));
    }
    let text = std::fs::read_to_string(path).map_err(VtaError::Io)?;
    let mut best: BTreeMap<String, (u64, VtaConfig)> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { continue };
        let Some(p) = PointResult::from_json(&j) else { continue };
        let tag = p.config.tag();
        match best.get(&tag) {
            Some(&(cycles, _)) if cycles <= p.cycles => {}
            _ => {
                best.insert(tag, (p.cycles, p.config));
            }
        }
    }
    if best.is_empty() {
        return Err(VtaError::InvalidRequest(format!(
            "sweep cache '{}' holds no readable design points",
            path.display()
        )));
    }
    let points: Vec<(u64, VtaConfig)> = best.into_values().collect();
    let mut front = ParetoFront::new();
    for (i, (cycles, cfg)) in points.iter().enumerate() {
        front.insert(scaled_area(cfg), *cycles, i);
    }
    let picked: Vec<VtaConfig> =
        front.points().into_iter().take(max_devices).map(|p| points[p.id].1.clone()).collect();
    Ok(picked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::WorkloadSpec;

    fn req(t_us: u64, workload: &str) -> Request {
        Request { t_us, workload: workload.to_string(), seed: t_us }
    }

    fn device(config: &str, service: u64, area: f64) -> DeviceCost {
        DeviceCost {
            config: config.to_string(),
            service_us: [("w".to_string(), service)].into_iter().collect(),
            scaled_area: area,
        }
    }

    fn sched_opts(max_batch: usize, queue_depth: usize) -> SchedOptions {
        SchedOptions {
            max_batch,
            max_wait_us: 0,
            queue_depth,
            deadline_us: None,
            dispatch_overhead_us: 0,
        }
    }

    fn view(lane: usize, area: f64, depth: usize, est_done_us: u64) -> LaneView {
        LaneView { lane, device: lane, scaled_area: area, service_us: 10, depth, est_done_us }
    }

    #[test]
    fn earliest_feasible_cheapest_prefers_cheap_feasible_lanes() {
        let lanes = [view(0, 1.0, 0, 100), view(1, 4.0, 0, 40), view(2, 2.0, 0, 45)];
        let p = EarliestFeasibleCheapest;
        // Deadline 50: lanes 1 and 2 are feasible; 2 is cheaper.
        assert_eq!(p.route(0, Some(50), &lanes), 2);
        // No deadline: everything is feasible; 0 is cheapest.
        assert_eq!(p.route(0, None, &lanes), 0);
        // Nothing feasible: minimize lateness (earliest estimate).
        assert_eq!(p.route(0, Some(10), &lanes), 1);
    }

    #[test]
    fn least_loaded_and_cheapest_first_pick_as_named() {
        let lanes = [view(0, 1.0, 2, 100), view(1, 4.0, 0, 40), view(2, 2.0, 1, 45)];
        assert_eq!(LeastLoaded.route(0, None, &lanes), 1);
        assert_eq!(CheapestFirst.route(0, None, &lanes), 0);
    }

    #[test]
    fn route_policy_kind_parses_and_round_trips() {
        for kind in [
            RoutePolicyKind::EarliestFeasibleCheapest,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::CheapestFirst,
        ] {
            assert_eq!(RoutePolicyKind::parse(kind.cli_name()).unwrap(), kind);
            assert_eq!(kind.instantiate().name(), kind.cli_name());
        }
        let err = RoutePolicyKind::parse("round-robin").unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        assert!(err.to_string().contains("round-robin"), "error must name the token: {err}");
    }

    #[test]
    fn full_lanes_spill_to_peers_then_shed() {
        // queue_depth 1, two devices: the second request spills to the
        // second lane, the rest shed. One device alone sheds three.
        let devices = [device("a", 100, 1.0), device("b", 100, 1.0)];
        let trace: Vec<Request> = (0..4).map(|_| req(0, "w")).collect();
        let opts = sched_opts(1, 1);
        let fleet = schedule_fleet(&trace, &devices, &LeastLoaded, &opts, None).unwrap();
        assert_eq!(fleet.schedule.admitted, 2, "one request per lane");
        assert_eq!(fleet.schedule.rejected_queue_full.len(), 2);
        assert_eq!(fleet.schedule.completed(), 2);
        let single = schedule_fleet(&trace, &devices[..1], &LeastLoaded, &opts, None).unwrap();
        assert_eq!(single.schedule.rejected_queue_full.len(), 3);
        assert!(
            fleet.schedule.completed() > single.schedule.completed(),
            "a second device must absorb spilled work"
        );
    }

    #[test]
    fn schedule_fleet_is_deterministic() {
        let devices = [device("a", 120, 1.0), device("b", 60, 2.0)];
        let trace: Vec<Request> = (0..64).map(|i| req(i * 37 % 1000, "w")).collect();
        let opts = sched_opts(4, 16);
        let auto = AutoscaleOptions { interval_us: 200, max_replicas: 3, scale_up_depth: 2 };
        let a = schedule_fleet(&trace, &devices, &EarliestFeasibleCheapest, &opts, Some(&auto))
            .unwrap();
        let b = schedule_fleet(&trace, &devices, &EarliestFeasibleCheapest, &opts, Some(&auto))
            .unwrap();
        assert_eq!(schedule_digest(&a.schedule.batches), schedule_digest(&b.schedule.batches));
        assert_eq!(a.lanes, b.lanes);
        assert_eq!(a.peak_replicas, b.peak_replicas);
    }

    #[test]
    fn autoscaler_spawns_replicas_under_backlog() {
        // Service 1000us vs arrivals every 100us: backlog builds fast,
        // so the scaler must spawn extra replicas of the one device.
        let devices = [device("a", 1000, 2.0)];
        let trace: Vec<Request> = (0..20).map(|i| req(i * 100, "w")).collect();
        let opts = sched_opts(1, 1024);
        let auto = AutoscaleOptions { interval_us: 1000, max_replicas: 3, scale_up_depth: 1 };
        let fs = schedule_fleet(&trace, &devices, &LeastLoaded, &opts, Some(&auto)).unwrap();
        assert!(fs.lanes.len() > 1, "backlog must trigger a spawn");
        assert!(fs.peak_replicas[0] > 1);
        assert!(fs.peak_area > 2.0, "replicas are priced by scaled area");
        assert!(fs.lanes[1].spawned_us > 0, "autoscaled lanes spawn at boundaries");
        // Loss-free accounting still holds across replicas.
        assert_eq!(fs.schedule.completed() + fs.schedule.rejected_queue_full.len(), trace.len());
    }

    #[test]
    fn empty_device_set_and_bad_autoscale_are_typed_errors() {
        let trace = [req(0, "w")];
        let err = schedule_fleet(&trace, &[], &LeastLoaded, &sched_opts(1, 1), None).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        let devices = [device("a", 100, 1.0)];
        let auto = AutoscaleOptions { interval_us: 0, ..AutoscaleOptions::default() };
        let err = schedule_fleet(&trace, &devices, &LeastLoaded, &sched_opts(1, 1), Some(&auto))
            .unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
    }

    #[test]
    fn fleet_options_reject_duplicate_tags() {
        let opts = FleetOptions {
            configs: vec![
                presets::scaled_config(1, 16, 16, 1, 8),
                // Same tag as above: spad_scale is not part of the tag.
                presets::scaled_config(1, 16, 16, 2, 8),
            ],
            ..FleetOptions::default()
        };
        let err = opts.validate().unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        assert!(err.to_string().contains("1x16x16-axi8"), "error names the tag: {err}");
    }

    #[test]
    fn run_fleet_serves_across_two_devices() {
        let opts = FleetOptions {
            base: ServeOptions {
                cfg: presets::tiny_config(),
                workloads: vec![WorkloadSpec::Micro { block: 4 }],
                max_batch: 1,
                max_wait_us: 0,
                queue_depth: 1,
                ..ServeOptions::default()
            },
            configs: vec![presets::tiny_config(), presets::scaled_config(1, 4, 4, 2, 32)],
            policy: RoutePolicyKind::LeastLoaded,
            autoscale: None,
        };
        // Simultaneous arrivals + queue_depth 1 force both devices into
        // service.
        let trace: Vec<Request> = (0..6)
            .map(|i| Request { t_us: 0, workload: "micro@4".into(), seed: i })
            .collect();
        let outcome = run_fleet(&opts, &trace).unwrap();
        let r = &outcome.report;
        assert_eq!(r.devices.len(), 2);
        assert_eq!(r.submitted, 6);
        assert_eq!(
            r.completed + r.rejected_queue_full + r.expired_deadline,
            r.submitted,
            "every request is completed, shed, or expired"
        );
        assert_eq!(r.devices.iter().map(|d| d.routed).sum::<usize>(), r.admitted);
        assert!(r.devices.iter().all(|d| d.completed > 0), "both devices served work");
        let cycles: u64 = r.devices.iter().map(|d| d.total_cycles).sum();
        assert_eq!(cycles, r.total_cycles);
        assert!(r.peak_area > 0.0 && r.area_us > 0.0);
    }

    fn temp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("vta_fleet_test_{}_{name}.jsonl", std::process::id()))
    }

    fn point(cfg: VtaConfig, cycles: u64) -> PointResult {
        let area = scaled_area(&cfg);
        PointResult {
            config: cfg,
            workload: "micro@4".into(),
            seed: 0,
            graph_seed: 1,
            cycles,
            macs: 1,
            dram_rd: 1,
            dram_wr: 1,
            insns: 1,
            scaled_area: area,
            predicted_cycles: None,
            measured: true,
            residency: crate::compiler::residency::ResidencyMode::Lru,
        }
    }

    #[test]
    fn configs_from_sweep_keeps_pareto_survivors_in_area_order() {
        let path = temp_path("pareto");
        let tiny = presets::tiny_config();
        let large = presets::scaled_config(1, 64, 64, 2, 64);
        let mid = presets::scaled_config(1, 32, 32, 2, 32);
        // tiny dominates mid (cheaper and faster); large is fastest.
        let lines = [
            point(tiny.clone(), 10_000),
            point(tiny.clone(), 12_000), // worse duplicate of the same tag
            point(mid, 20_000),
            point(large.clone(), 1_000),
        ]
        .iter()
        .map(|p| p.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n");
        std::fs::write(&path, lines).unwrap();
        let cfgs = configs_from_sweep(&path, 8).unwrap();
        std::fs::remove_file(&path).ok();
        let tags: Vec<String> = cfgs.iter().map(|c| c.tag()).collect();
        assert_eq!(tags, vec![tiny.tag(), large.tag()], "area-ordered Pareto survivors");
        // max_devices truncates from the cheap end.
        std::fs::write(&path, point(tiny.clone(), 10_000).to_json().to_string_compact())
            .unwrap();
        let one = configs_from_sweep(&path, 1).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn configs_from_sweep_error_paths() {
        let err = configs_from_sweep(Path::new("/nonexistent/cache.jsonl"), 2).unwrap_err();
        assert!(matches!(err, VtaError::Io(_)), "got {err:?}");
        let err = configs_from_sweep(Path::new("whatever.jsonl"), 0).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        let path = temp_path("garbage");
        std::fs::write(&path, "not json\n{\"schema\": -1}\n").unwrap();
        let err = configs_from_sweep(&path, 2).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
        assert!(err.to_string().contains("garbage"), "error names the cache file: {err}");
    }
}
