//! The session pool: warm prepared graphs, one per
//! `(config, workload, backend)` key.
//!
//! Building an entry does everything that should happen *once* per
//! served graph rather than once per request: build the workload graph
//! (synthetic-weight generation is the single most expensive prepare
//! step for the big ResNets), validate it and propagate shapes
//! ([`Engine::prepare_shared`]), wire the shared fast-path caches (one
//! [`LayerMemo`] across the whole pool for tsim backends, one
//! prediction cache for the analytical backend), and run one **warmup
//! evaluation**. The warmup serves two purposes:
//!
//! * it primes the memo, so every later request for the entry replays
//!   cached per-layer results instead of re-simulating;
//! * it pins the entry's per-request cost: VTA cycle counts are
//!   data-independent (the layer-memo invariant), so one measurement is
//!   *the* service time of every future request, which is what lets the
//!   scheduler plan in virtual time before any request runs.
//!
//! A fleet builds one pool per device config over the *same* workload
//! graphs: [`shared_graphs`] runs the graph build + shape propagation
//! once, and [`SessionPool::build_for`] instantiates each device's pool
//! from those shared prepares
//! ([`Engine::prepare_shared_with_shapes`]) — shapes depend only on
//! the graph, so only the config-level checks and the warmup are paid
//! per device.
//!
//! Backends that produce no cycles (fsim) cannot price requests and are
//! rejected with [`VtaError::Unsupported`] at pool build (via
//! [`ServeOptions::validate`]).

use super::ServeOptions;
use crate::analysis::area;
use crate::compiler::graph::Graph;
use crate::compiler::layout::Shape;
use crate::config::VtaConfig;
use crate::engine::backends::PredictionCache;
use crate::engine::{
    AnalyticalBackend, BackendKind, Engine, EvalRequest, PreparedShared, VtaError,
};
use crate::memo::LayerMemo;
use crate::store::{ArtifactKind, ArtifactStore};
use crate::sweep::{PointResult, SweepJob, WorkloadSpec};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Identity of a pooled entry. One `ServeOptions` fixes the config and
/// backend for the whole pool, so within a pool the workload id alone
/// discriminates — the full key exists so reports and multi-pool
/// callers (the fleet) stay unambiguous.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct PoolKey {
    /// Configuration tag (`VtaConfig::tag`).
    pub config: String,
    /// Workload id (`WorkloadSpec::id`).
    pub workload: String,
    /// Fidelity rung serving this entry.
    pub backend: BackendKind,
}

/// One warm `(engine, prepared graph)` pair plus its measured cost.
pub struct PoolEntry {
    pub key: PoolKey,
    /// Engine with the pool's shared memo/prediction cache composed in.
    pub engine: Engine,
    /// The shared prepared graph every request evaluates against.
    pub prepared: PreparedShared,
    /// Cycles one request costs on this entry (warmup-measured;
    /// data-independent, so exact for every request).
    pub cycles_per_request: u64,
    /// `cycles_per_request` at the pool's clock, in virtual µs (≥ 1).
    pub service_us: u64,
    /// Whether the pricing came from a stored sweep measurement instead
    /// of a fresh warmup simulation ([`ServeOptions::store`]).
    pub warmed_from_store: bool,
}

/// One workload's graph built once for a whole fleet: the graph plus
/// its propagated per-node shapes. Shape propagation depends only on
/// the graph — never on the device config — so every device pool can
/// reuse both.
pub struct SharedGraph {
    pub graph: Arc<Graph>,
    pub shapes: Arc<Vec<Shape>>,
}

/// Build each workload's graph + shapes once, keyed by workload id, for
/// sharing across device pools ([`SessionPool::build_for`]).
pub fn shared_graphs(
    workloads: &[WorkloadSpec],
    graph_seed: u64,
) -> Result<BTreeMap<String, SharedGraph>, VtaError> {
    let mut out = BTreeMap::new();
    for spec in workloads {
        let graph = Arc::new(spec.build(graph_seed));
        let shapes = Arc::new(graph.try_shapes().map_err(VtaError::Graph)?);
        out.insert(spec.id(), SharedGraph { graph, shapes });
    }
    Ok(out)
}

/// The warm-session pool behind the serving runtime.
pub struct SessionPool {
    entries: Vec<PoolEntry>,
    by_workload: BTreeMap<String, usize>,
    memo: Option<Arc<LayerMemo>>,
}

impl SessionPool {
    /// Build and warm every entry for `opts.cfg`. Typed failures come
    /// from [`ServeOptions::validate`] plus whatever config/graph
    /// validation reports.
    pub fn build(opts: &ServeOptions) -> Result<SessionPool, VtaError> {
        opts.validate()?;
        let graphs = shared_graphs(&opts.workloads, opts.graph_seed)?;
        Self::build_for(&opts.cfg, opts, &graphs)
    }

    /// Build and warm a pool for an explicit device config over
    /// pre-built workload graphs — the fleet path, where N device
    /// configs serve the same workloads and the expensive graph build +
    /// shape propagation ([`shared_graphs`]) happen once, not once per
    /// device. `opts.cfg` is ignored in favor of `cfg`; everything else
    /// (backend, memo, clock) applies to this device's pool.
    pub fn build_for(
        cfg: &VtaConfig,
        opts: &ServeOptions,
        graphs: &BTreeMap<String, SharedGraph>,
    ) -> Result<SessionPool, VtaError> {
        opts.validate()?;
        let caps = opts.backend.instantiate().capabilities();
        // One memo (or prediction cache) spans the pool: repeated layer
        // shapes across entries warm each other, exactly as in a sweep.
        // With a shared artifact store the memo loads the sweep's
        // per-layer `Program` records, so warmups replay instead of
        // re-simulating even on a cold serve process.
        let memo = (opts.memo && caps.supports_memo).then(|| {
            Arc::new(match &opts.store {
                Some(s) => LayerMemo::store_backed(s.clone()),
                None => LayerMemo::in_memory(),
            })
        });
        let predictions =
            (opts.backend == BackendKind::Analytical).then(PredictionCache::default);
        // Only measured artifacts may price entries: the analytical
        // backend's cycles are model estimates, which a stored tsim
        // measurement would not reproduce.
        let store = opts.store.as_ref().filter(|_| opts.backend != BackendKind::Analytical);
        let cfg_json = cfg.to_json().to_string_compact();

        let mut entries: Vec<PoolEntry> = Vec::with_capacity(opts.workloads.len());
        let mut by_workload = BTreeMap::new();
        for spec in &opts.workloads {
            let id = spec.id();
            let shared = graphs.get(&id).ok_or_else(|| {
                VtaError::InvalidRequest(format!(
                    "no shared graph was built for pooled workload '{id}'"
                ))
            })?;
            let mut builder = Engine::for_config(cfg).residency(opts.residency);
            builder = match &predictions {
                Some(cache) => builder.backend(AnalyticalBackend::with_cache(cache.clone())),
                None => builder.backend_kind(opts.backend),
            };
            if let Some(m) = &memo {
                builder = builder.memo(m.clone());
            }
            let engine = builder.build()?;
            let prepared = engine
                .prepare_shared_with_shapes(shared.graph.clone(), shared.shapes.clone())?;
            // Warm pricing through the store: any measured sweep point of
            // this exact (config, workload, graph_seed, residency) prices
            // the entry — cycles are data-independent, so the input seed
            // is irrelevant and the cheapest match wins.
            let stored_cycles = store.and_then(|s| {
                s.find_map(ArtifactKind::PointMeasurement, |_, payload| {
                    let p = PointResult::from_json(payload)?;
                    (p.measured
                        && p.workload == id
                        && p.graph_seed == opts.graph_seed
                        && p.residency == opts.residency
                        && p.config.to_json().to_string_compact() == cfg_json)
                        .then_some(p.cycles)
                })
            });
            let warmed_from_store = stored_cycles.is_some();
            let cycles_per_request = match stored_cycles {
                Some(cycles) => cycles,
                None => {
                    let warm = engine.eval_shared(&prepared, &EvalRequest::seeded(0))?;
                    let cycles =
                        warm.cycles.expect("produces_cycles was checked at validation");
                    if let Some(s) = store {
                        // Persist the warmup as the seed-0 measurement a
                        // sweep of this point would produce, under the
                        // sweep's own key — the next sweep or serve run
                        // reuses it. Best-effort, like the memo spill.
                        let job = SweepJob {
                            index: 0,
                            cfg: cfg.clone(),
                            workload: spec.clone(),
                            seed: 0,
                            graph_seed: opts.graph_seed,
                        };
                        let result = PointResult {
                            config: cfg.clone(),
                            workload: id.clone(),
                            seed: 0,
                            graph_seed: opts.graph_seed,
                            cycles,
                            macs: warm.counters.macs,
                            dram_rd: warm.counters.load_bytes_total(),
                            dram_wr: warm.counters.store_bytes,
                            insns: warm.counters.insn_count,
                            scaled_area: area::scaled_area(cfg),
                            predicted_cycles: None,
                            measured: true,
                            residency: opts.residency,
                        };
                        s.put(
                            ArtifactKind::PointMeasurement,
                            job.cache_key(opts.residency),
                            result.to_json(),
                        )
                        .ok();
                    }
                    cycles
                }
            };
            let service_us = (cycles_per_request / opts.clock_mhz).max(1);
            by_workload.insert(id.clone(), entries.len());
            entries.push(PoolEntry {
                key: PoolKey { config: cfg.tag(), workload: id, backend: opts.backend },
                engine,
                prepared,
                cycles_per_request,
                service_us,
                warmed_from_store,
            });
        }
        Ok(SessionPool { entries, by_workload, memo })
    }

    /// Entry serving `workload`, if pooled.
    pub fn get(&self, workload: &str) -> Option<&PoolEntry> {
        self.by_workload.get(workload).map(|&i| &self.entries[i])
    }

    pub fn entries(&self) -> &[PoolEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Workload id → per-request virtual service time (the scheduler's
    /// pricing input).
    pub fn service_map(&self) -> BTreeMap<String, u64> {
        self.entries
            .iter()
            .map(|e| (e.key.workload.clone(), e.service_us))
            .collect()
    }

    /// `(hits, misses)` of the pool-wide layer memo, warmup included
    /// (`(0, 0)` for memo-less backends).
    pub fn memo_stats(&self) -> (u64, u64) {
        self.memo
            .as_ref()
            .map(|m| (m.hits(), m.misses()))
            .unwrap_or((0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::sweep::WorkloadSpec;

    fn tiny_opts(backend: BackendKind) -> ServeOptions {
        ServeOptions {
            cfg: presets::tiny_config(),
            backend,
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            ..ServeOptions::default()
        }
    }

    #[test]
    fn pool_warms_and_prices_entries() {
        let pool = SessionPool::build(&tiny_opts(BackendKind::TsimTiming)).unwrap();
        assert_eq!(pool.len(), 1);
        let entry = pool.get("micro@4").expect("pooled workload");
        assert!(entry.cycles_per_request > 0);
        assert!(entry.service_us >= 1);
        assert_eq!(entry.key.backend, BackendKind::TsimTiming);
        // Warmup populated the shared memo.
        let (_, misses) = pool.memo_stats();
        assert!(misses > 0, "warmup must simulate (and record) each layer once");
        // A served request after warmup is all memo hits.
        let eval = entry
            .engine
            .eval_shared(&entry.prepared, &EvalRequest::seeded(1))
            .unwrap();
        assert_eq!(eval.cycles, Some(entry.cycles_per_request), "cycles are data-independent");
        let (hits, misses_after) = pool.memo_stats();
        assert!(hits > 0, "warm entries serve from the memo");
        assert_eq!(misses_after, misses, "no layer re-simulates after warmup");
    }

    #[test]
    fn store_prices_warmup_without_simulation() {
        let store = Arc::new(ArtifactStore::in_memory());
        let mut opts = tiny_opts(BackendKind::TsimTiming);
        opts.store = Some(store.clone());
        let pool = SessionPool::build(&opts).unwrap();
        let first = pool.get("micro@4").unwrap();
        assert!(!first.warmed_from_store, "a cold store cannot price the entry");
        assert_eq!(store.len(ArtifactKind::PointMeasurement), 1, "warmup persisted");
        // Rebuild against the same store: the persisted warmup prices
        // the entry with zero simulation, byte-identically.
        let pool2 = SessionPool::build(&opts).unwrap();
        let entry = pool2.get("micro@4").unwrap();
        assert!(entry.warmed_from_store);
        assert_eq!(entry.cycles_per_request, first.cycles_per_request);
        assert_eq!(entry.service_us, first.service_us);
    }

    #[test]
    fn fsim_pool_rejected_as_unsupported() {
        let err = SessionPool::build(&tiny_opts(BackendKind::Fsim)).unwrap_err();
        assert!(matches!(err, VtaError::Unsupported(_)), "got {err:?}");
    }

    #[test]
    fn empty_and_duplicate_workloads_rejected() {
        let mut opts = tiny_opts(BackendKind::TsimTiming);
        opts.workloads.clear();
        assert!(matches!(
            SessionPool::build(&opts),
            Err(VtaError::InvalidRequest(_))
        ));
        opts.workloads =
            vec![WorkloadSpec::Micro { block: 4 }, WorkloadSpec::Micro { block: 4 }];
        assert!(matches!(
            SessionPool::build(&opts),
            Err(VtaError::InvalidRequest(_))
        ));
    }

    #[test]
    fn zero_clock_rejected() {
        let mut opts = tiny_opts(BackendKind::TsimTiming);
        opts.clock_mhz = 0;
        assert!(matches!(
            SessionPool::build(&opts),
            Err(VtaError::InvalidRequest(_))
        ));
    }

    #[test]
    fn analytical_pool_builds_without_memo() {
        let pool = SessionPool::build(&tiny_opts(BackendKind::Analytical)).unwrap();
        assert_eq!(pool.memo_stats(), (0, 0));
        assert!(pool.get("micro@4").unwrap().cycles_per_request > 0);
    }

    #[test]
    fn device_pools_share_prepared_graphs() {
        // The fleet path: two device configs over one shared graph
        // build. Both pools evaluate the very same graph object; only
        // the config-level work is repeated.
        let opts = tiny_opts(BackendKind::TsimTiming);
        let graphs = shared_graphs(&opts.workloads, opts.graph_seed).unwrap();
        let small = SessionPool::build_for(&presets::tiny_config(), &opts, &graphs).unwrap();
        let wide =
            SessionPool::build_for(&presets::scaled_config(1, 4, 4, 2, 32), &opts, &graphs)
                .unwrap();
        let (a, b) = (small.get("micro@4").unwrap(), wide.get("micro@4").unwrap());
        assert!(
            Arc::ptr_eq(a.prepared.graph(), b.prepared.graph()),
            "device pools must share the workload graph, not rebuild it"
        );
        assert_ne!(a.key.config, b.key.config, "distinct devices, distinct config tags");
        assert!(a.cycles_per_request > 0 && b.cycles_per_request > 0);
        // The memo still works through the shared-prepare path.
        let (_, misses) = small.memo_stats();
        assert!(misses > 0, "warmup recorded layers through the shared prepare");
    }
}
