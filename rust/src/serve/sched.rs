//! Dynamic-batching scheduler: a deterministic discrete-event model of
//! the serving queue in *virtual time*.
//!
//! All scheduling decisions — batch composition, queue depths, deadline
//! expiry, per-request latency — are computed in virtual microseconds
//! from three inputs only: the arrival trace, the per-request service
//! times the session pool measured at warmup (VTA cycle counts are
//! data-independent, so one warm evaluation per pooled workload pins
//! the cost of every future request exactly), and the scheduler
//! options. Worker threads never appear in this model; they only
//! parallelize the *execution* of batches the schedule already fixed.
//! That split is what makes a `ServeReport` byte-identical across
//! `--jobs 1` and `--jobs N` (pinned by `rust/tests/serve_runtime.rs`).
//!
//! # Batching semantics
//!
//! Requests for the same pooled workload coalesce into a batch. A batch
//! *opens* at its first request's arrival and *closes* (becomes ready
//! to dispatch) at the earlier of:
//!
//! * **full** — it reaches `max_batch` members (ready immediately), or
//! * **window expiry** — `max_wait_us` elapses from its open time.
//!
//! `max_wait_us` therefore bounds the co-batching delay any admitted
//! request can suffer: it waits at most `max_wait_us` for peers, plus
//! the device backlog ahead of it — which the bounded queue caps — so
//! the batching window is a direct p99-latency knob (see DESIGN.md
//! §Serving runtime for the queueing model).
//!
//! # Device model
//!
//! Closed batches execute in ready order on one serial virtual
//! accelerator: `start = max(ready, device_free)`,
//! `done = start + dispatch_overhead_us + Σ service_us(member)`. The
//! per-dispatch overhead is what batching amortizes in virtual time
//! (the wall-clock amortization — prepare/validation/memo reuse — is
//! measured separately by `benches/serve_throughput.rs`).
//!
//! # Lanes
//!
//! The queue + open batches + serial accelerator triple is factored
//! into a `Lane` so the same event machinery serves two drivers: the
//! single-device [`schedule`] below runs one lane, and the fleet
//! scheduler (`serve::fleet::schedule_fleet`) runs one lane per device
//! replica, routing each admitted arrival to a lane chosen by a
//! `RoutePolicy`. Every dispatched [`Batch`] records the lane that ran
//! it in `Batch::device` (always 0 for single-device schedules).
//!
//! # Admission and rejection
//!
//! The submission queue is bounded: a request arriving while
//! `queue_depth` requests are waiting or in flight is rejected (counted
//! `rejected_queue_full`) — load shedding, not an error. A request
//! whose per-request deadline (`arrival + deadline_us`) has already
//! passed when its batch starts is dropped at dispatch (counted
//! `expired_deadline`) without consuming device time. Malformed input —
//! a request naming a workload the pool does not hold, or nonsensical
//! options — is a typed [`VtaError::InvalidRequest`] instead.

use super::load::Request;
use crate::engine::VtaError;
use std::collections::{BTreeMap, VecDeque};

/// Scheduler knobs (the `vta serve` flags of the same names).
#[derive(Debug, Clone)]
pub struct SchedOptions {
    /// Maximum requests coalesced into one batch (≥ 1).
    pub max_batch: usize,
    /// Batching window: how long an open batch may wait for peers.
    pub max_wait_us: u64,
    /// Bound on requests waiting or in flight; arrivals beyond it are
    /// shed (≥ 1). In a fleet this bounds each lane separately.
    pub queue_depth: usize,
    /// Per-request deadline from arrival to batch start; `None` = no
    /// deadlines.
    pub deadline_us: Option<u64>,
    /// Fixed virtual cost charged once per dispatched batch.
    pub dispatch_overhead_us: u64,
}

/// One dispatched batch of same-workload requests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    /// Open order (stable across runs; close order can differ from it).
    pub id: usize,
    /// Lane (virtual device replica) that dispatched this batch; always
    /// 0 for single-device schedules.
    pub device: usize,
    /// The pooled workload every member runs against.
    pub workload: String,
    /// Arrival of the first member.
    pub open_us: u64,
    /// When the batch became dispatchable (full, or window expired).
    pub ready_us: u64,
    /// When the virtual device started it (`max(ready, device free)`).
    pub start_us: u64,
    /// `start + overhead + Σ service` (== `start_us` for all-expired
    /// batches, which consume no device time).
    pub done_us: u64,
    /// Members executed, as indices into the request trace.
    pub requests: Vec<usize>,
    /// Members dropped at dispatch because their deadline had passed.
    pub expired: Vec<usize>,
}

impl Batch {
    /// Executed occupancy (expired members don't count).
    pub fn occupancy(&self) -> usize {
        self.requests.len()
    }
}

/// Everything the scheduling pass decided, in virtual time.
#[derive(Debug, Default)]
pub struct Schedule {
    /// Batches in close (dispatch) order.
    pub batches: Vec<Batch>,
    /// Trace indices shed at admission (queue full).
    pub rejected_queue_full: Vec<usize>,
    /// `(trace index, done - arrival)` for every completed request.
    pub latencies_us: Vec<(usize, u64)>,
    /// Requests admitted past the queue bound.
    pub admitted: usize,
    /// Largest lane depth observed at any admission (incl. the
    /// admitted request).
    pub max_queue_depth: usize,
    /// Σ depth-at-admission — `/ admitted` is the mean depth.
    pub depth_sum: u64,
}

impl Schedule {
    /// Requests that ran to completion.
    pub fn completed(&self) -> usize {
        self.latencies_us.len()
    }

    /// Requests dropped at dispatch for a passed deadline.
    pub fn expired(&self) -> usize {
        self.batches.iter().map(|b| b.expired.len()).sum()
    }

    /// Virtual completion time of the last *completed* request (0 when
    /// nothing ran). All-expired batches are excluded: their `done_us`
    /// is just the dispatch instant, not a completion.
    pub fn makespan_end_us(&self) -> u64 {
        self.batches
            .iter()
            .filter(|b| !b.requests.is_empty())
            .map(|b| b.done_us)
            .max()
            .unwrap_or(0)
    }
}

/// An open (still collecting) batch.
struct OpenBatch {
    id: usize,
    open_us: u64,
    members: Vec<usize>,
}

/// The serial virtual accelerator plus the finished-work bookkeeping
/// that admission control needs.
struct Device {
    free_us: u64,
    /// `(done_us, members)` of in-flight batches, nondecreasing in
    /// `done_us` (the device is serial).
    in_flight: VecDeque<(u64, usize)>,
    /// Running Σ members over `in_flight` — admission reads the backlog
    /// in O(1) instead of re-summing the deque per arrival.
    busy: usize,
}

/// One virtual device replica: a bounded admission queue, the open
/// batches collecting behind it, and the serial accelerator that runs
/// them. [`schedule`] drives a single lane; the fleet scheduler drives
/// one per replica, all writing into one shared [`Schedule`].
pub(crate) struct Lane {
    /// Lane index stamped into every batch this lane dispatches.
    id: usize,
    open: BTreeMap<String, OpenBatch>,
    device: Device,
    /// Running Σ members over `open` (the O(1) half of admission depth).
    waiting: usize,
}

impl Lane {
    pub(crate) fn new(id: usize) -> Lane {
        Lane {
            id,
            open: BTreeMap::new(),
            device: Device { free_us: 0, in_flight: VecDeque::new(), busy: 0 },
            waiting: 0,
        }
    }

    /// Waiting (open batches) + in flight: the admission depth the
    /// bounded queue compares against `queue_depth`.
    pub(crate) fn depth(&self) -> usize {
        self.device.busy + self.waiting
    }

    /// When the serial accelerator behind this lane frees up.
    pub(crate) fn free_us(&self) -> u64 {
        self.device.free_us
    }

    /// Advance this lane's virtual clock to `now`: close every batch
    /// whose window expired by `now`, in (close time, open order) —
    /// i.e. real event — order, then retire finished work so admission
    /// sees the true backlog.
    pub(crate) fn advance(
        &mut self,
        now: u64,
        trace: &[Request],
        service_us: &BTreeMap<String, u64>,
        opts: &SchedOptions,
        out: &mut Schedule,
    ) {
        while let Some(key) = self
            .open
            .iter()
            .filter(|(_, b)| b.open_us.saturating_add(opts.max_wait_us) <= now)
            .min_by_key(|(_, b)| (b.open_us.saturating_add(opts.max_wait_us), b.id))
            .map(|(k, _)| k.clone())
        {
            let b = self.open.remove(&key).unwrap();
            let ready = b.open_us.saturating_add(opts.max_wait_us);
            self.waiting -= b.members.len();
            self.close_batch(b, key, ready, trace, service_us, opts, out);
        }
        while self.device.in_flight.front().is_some_and(|&(done, _)| done <= now) {
            let (_, n) = self.device.in_flight.pop_front().unwrap();
            self.device.busy -= n;
        }
    }

    /// Admit trace request `i` arriving at `now`: record the depth
    /// accounting, join (or open) its workload's batch, dispatch when
    /// full. The caller has already bounded admission
    /// (`depth() < queue_depth`) and advanced the lane to `now`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn admit(
        &mut self,
        i: usize,
        now: u64,
        trace: &[Request],
        service_us: &BTreeMap<String, u64>,
        opts: &SchedOptions,
        out: &mut Schedule,
        next_batch_id: &mut usize,
    ) {
        out.admitted += 1;
        out.max_queue_depth = out.max_queue_depth.max(self.depth() + 1);
        out.depth_sum += self.depth() as u64 + 1;
        let key = trace[i].workload.clone();
        let entry = self.open.entry(key.clone()).or_insert_with(|| {
            let id = *next_batch_id;
            *next_batch_id += 1;
            OpenBatch { id, open_us: now, members: Vec::new() }
        });
        entry.members.push(i);
        self.waiting += 1;
        if entry.members.len() >= opts.max_batch {
            let b = self.open.remove(&key).unwrap();
            self.waiting -= b.members.len();
            self.close_batch(b, key, now, trace, service_us, opts, out);
        }
    }

    /// The trace ended: close the still-open batches at their window
    /// expiries, in the same event order `advance` uses.
    pub(crate) fn flush(
        &mut self,
        trace: &[Request],
        service_us: &BTreeMap<String, u64>,
        opts: &SchedOptions,
        out: &mut Schedule,
    ) {
        let mut rest: Vec<(String, OpenBatch)> =
            std::mem::take(&mut self.open).into_iter().collect();
        rest.sort_by_key(|(_, b)| (b.open_us.saturating_add(opts.max_wait_us), b.id));
        for (key, b) in rest {
            let ready = b.open_us.saturating_add(opts.max_wait_us);
            self.waiting -= b.members.len();
            self.close_batch(b, key, ready, trace, service_us, opts, out);
        }
    }

    /// Dispatch one closed batch on the virtual device: drop expired
    /// members, charge the service time, record completions.
    #[allow(clippy::too_many_arguments)]
    fn close_batch(
        &mut self,
        batch: OpenBatch,
        workload: String,
        ready_us: u64,
        trace: &[Request],
        service_us: &BTreeMap<String, u64>,
        opts: &SchedOptions,
        out: &mut Schedule,
    ) {
        let start_us = self.device.free_us.max(ready_us);
        let mut requests = Vec::with_capacity(batch.members.len());
        let mut expired = Vec::new();
        for i in batch.members {
            let missed = opts
                .deadline_us
                .is_some_and(|d| trace[i].t_us.saturating_add(d) < start_us);
            if missed {
                expired.push(i);
            } else {
                requests.push(i);
            }
        }
        let done_us = if requests.is_empty() {
            start_us // nothing dispatched; the device stays free
        } else {
            // Saturating throughout: `schedule` stays total (no panic, no
            // wraparound) even for arrival times near u64::MAX.
            let service = opts
                .dispatch_overhead_us
                .saturating_add(service_us[&workload].saturating_mul(requests.len() as u64));
            self.device.free_us = start_us.saturating_add(service);
            self.device.in_flight.push_back((self.device.free_us, requests.len()));
            self.device.busy += requests.len();
            self.device.free_us
        };
        for &i in &requests {
            out.latencies_us.push((i, done_us.saturating_sub(trace[i].t_us)));
        }
        out.batches.push(Batch {
            id: batch.id,
            device: self.id,
            workload,
            open_us: batch.open_us,
            ready_us,
            start_us,
            done_us,
            requests,
            expired,
        });
    }
}

/// Shared option validation for the single-device and fleet schedulers.
pub(crate) fn check_options(opts: &SchedOptions) -> Result<(), VtaError> {
    if opts.max_batch == 0 {
        return Err(VtaError::InvalidRequest("max_batch must be at least 1".into()));
    }
    if opts.queue_depth == 0 {
        return Err(VtaError::InvalidRequest("queue_depth must be at least 1".into()));
    }
    Ok(())
}

/// Every trace request must name a workload the service map prices.
pub(crate) fn check_trace(
    trace: &[Request],
    service_us: &BTreeMap<String, u64>,
) -> Result<(), VtaError> {
    for (i, r) in trace.iter().enumerate() {
        if !service_us.contains_key(&r.workload) {
            return Err(VtaError::InvalidRequest(format!(
                "request {i} names workload '{}' which the session pool does not hold",
                r.workload
            )));
        }
    }
    Ok(())
}

/// Compute the full schedule for a trace. Pure and total: no clocks, no
/// threads — the same inputs always produce the same `Schedule`.
/// `service_us` maps every pooled workload id to its per-request
/// virtual service time; a request naming an unknown workload is a
/// typed error (the trace does not fit the pool).
pub fn schedule(
    trace: &[Request],
    service_us: &BTreeMap<String, u64>,
    opts: &SchedOptions,
) -> Result<Schedule, VtaError> {
    check_options(opts)?;
    check_trace(trace, service_us)?;
    // Arrival order: by timestamp, trace order breaking ties.
    let mut order: Vec<usize> = (0..trace.len()).collect();
    order.sort_by_key(|&i| (trace[i].t_us, i));

    let mut lane = Lane::new(0);
    let mut out = Schedule::default();
    let mut next_batch_id = 0usize;

    for &i in &order {
        let now = trace[i].t_us;
        lane.advance(now, trace, service_us, opts, &mut out);
        if lane.depth() >= opts.queue_depth {
            out.rejected_queue_full.push(i);
            continue;
        }
        lane.admit(i, now, trace, service_us, opts, &mut out, &mut next_batch_id);
    }
    lane.flush(trace, service_us, opts, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t_us: u64, workload: &str) -> Request {
        Request { t_us, workload: workload.to_string(), seed: t_us }
    }

    fn service(pairs: &[(&str, u64)]) -> BTreeMap<String, u64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn opts(max_batch: usize, max_wait_us: u64) -> SchedOptions {
        SchedOptions {
            max_batch,
            max_wait_us,
            queue_depth: 1024,
            deadline_us: None,
            dispatch_overhead_us: 10,
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let trace = [req(0, "w"), req(1, "w"), req(2, "w")];
        let s = schedule(&trace, &service(&[("w", 100)]), &opts(3, 1_000_000)).unwrap();
        assert_eq!(s.batches.len(), 1);
        let b = &s.batches[0];
        assert_eq!((b.ready_us, b.start_us), (2, 2), "full at the third arrival");
        assert_eq!(b.done_us, 2 + 10 + 3 * 100);
        assert_eq!(b.occupancy(), 3);
        assert_eq!(b.device, 0, "single-device schedules run on lane 0");
        assert_eq!(s.completed(), 3);
    }

    #[test]
    fn window_expiry_closes_partial_batches() {
        // One lonely request: the window, not max_batch, dispatches it.
        let trace = [req(5, "w")];
        let s = schedule(&trace, &service(&[("w", 100)]), &opts(8, 200)).unwrap();
        assert_eq!(s.batches.len(), 1);
        assert_eq!(s.batches[0].ready_us, 205);
        assert_eq!(s.batches[0].done_us, 205 + 10 + 100);
        // Latency = window wait + overhead + service.
        assert_eq!(s.latencies_us[0].1, 200 + 10 + 100);
    }

    #[test]
    fn max_wait_bounds_cobatching_delay() {
        // Sparse arrivals never fill max_batch; each waits exactly the
        // window (device is idle), so latency ≤ wait + overhead + svc.
        let trace: Vec<Request> = (0..8).map(|i| req(i * 10_000, "w")).collect();
        let o = opts(64, 500);
        let s = schedule(&trace, &service(&[("w", 100)]), &o).unwrap();
        assert_eq!(s.completed(), 8);
        for &(_, lat) in &s.latencies_us {
            assert!(lat <= 500 + 10 + 100, "latency {lat} exceeds the window bound");
        }
    }

    #[test]
    fn device_serializes_batches_and_backlog_accumulates() {
        // Two batches of one workload, ready back-to-back; the second
        // starts when the first finishes, not at its ready time.
        let trace = [req(0, "w"), req(1, "w"), req(2, "w"), req(3, "w")];
        let s = schedule(&trace, &service(&[("w", 1000)]), &opts(2, 1_000_000)).unwrap();
        assert_eq!(s.batches.len(), 2);
        assert_eq!(s.batches[0].start_us, 1);
        assert_eq!(s.batches[1].ready_us, 3);
        assert_eq!(s.batches[1].start_us, s.batches[0].done_us);
    }

    #[test]
    fn mixed_workloads_batch_separately() {
        let trace = [req(0, "a"), req(1, "b"), req(2, "a"), req(3, "b")];
        let s = schedule(&trace, &service(&[("a", 10), ("b", 10)]), &opts(2, 1_000)).unwrap();
        assert_eq!(s.batches.len(), 2);
        for b in &s.batches {
            assert_eq!(b.occupancy(), 2, "batches never mix workloads");
            let w: Vec<&str> =
                b.requests.iter().map(|&i| trace[i].workload.as_str()).collect();
            assert!(w.iter().all(|x| *x == b.workload));
        }
    }

    #[test]
    fn bounded_queue_sheds_overload() {
        // Service far slower than arrivals and a tiny queue: most of
        // the burst is shed, nothing is lost silently.
        let trace: Vec<Request> = (0..32).map(|i| req(i, "w")).collect();
        let mut o = opts(1, 0);
        o.queue_depth = 2;
        let s = schedule(&trace, &service(&[("w", 1_000_000)]), &o).unwrap();
        assert!(!s.rejected_queue_full.is_empty());
        assert_eq!(
            s.admitted + s.rejected_queue_full.len(),
            32,
            "every request is admitted or shed, never dropped silently"
        );
        assert!(s.max_queue_depth <= 2);
    }

    #[test]
    fn passed_deadlines_expire_at_dispatch() {
        // A long backlog forms; later requests' deadlines pass before
        // their batches start.
        let trace: Vec<Request> = (0..8).map(|i| req(i, "w")).collect();
        let mut o = opts(1, 0);
        o.deadline_us = Some(50);
        let s = schedule(&trace, &service(&[("w", 1000)]), &o).unwrap();
        assert!(s.expired() > 0, "backlogged requests must expire");
        assert_eq!(s.completed() + s.expired(), 8);
        // Expired members consume no device time: completions all
        // started within their deadline.
        for b in &s.batches {
            for &i in &b.requests {
                assert!(b.start_us <= trace[i].t_us + 50);
            }
        }
    }

    #[test]
    fn all_expired_trailing_batch_does_not_extend_makespan() {
        // 8 requests at t=0 fill a batch and complete at 810; a
        // straggler at t=900 waits out its 2000us window, expires at
        // dispatch (start 2900 > 900 + 1000), and must not count as
        // the last completion.
        let mut trace: Vec<Request> = (0..8).map(|_| req(0, "w")).collect();
        trace.push(req(900, "w"));
        let mut o = opts(8, 2000);
        o.deadline_us = Some(1000);
        let s = schedule(&trace, &service(&[("w", 100)]), &o).unwrap();
        assert_eq!(s.completed(), 8);
        assert_eq!(s.expired(), 1);
        assert_eq!(s.makespan_end_us(), 810, "expired dispatches are not completions");
    }

    #[test]
    fn unknown_workload_is_a_typed_error() {
        let trace = [req(0, "ghost")];
        let err = schedule(&trace, &service(&[("w", 1)]), &opts(1, 0)).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
    }

    #[test]
    fn zero_sized_options_are_typed_errors() {
        let trace = [req(0, "w")];
        let svc = service(&[("w", 1)]);
        let mut o = opts(0, 0);
        assert!(matches!(
            schedule(&trace, &svc, &o),
            Err(VtaError::InvalidRequest(_))
        ));
        o.max_batch = 1;
        o.queue_depth = 0;
        assert!(matches!(
            schedule(&trace, &svc, &o),
            Err(VtaError::InvalidRequest(_))
        ));
    }

    #[test]
    fn schedule_is_a_pure_function_of_inputs() {
        let trace: Vec<Request> =
            (0..64).map(|i| req(i * 37 % 1000, if i % 3 == 0 { "a" } else { "b" })).collect();
        let svc = service(&[("a", 120), ("b", 80)]);
        let o = opts(4, 300);
        let s1 = schedule(&trace, &svc, &o).unwrap();
        let s2 = schedule(&trace, &svc, &o).unwrap();
        assert_eq!(s1.batches, s2.batches);
        assert_eq!(s1.latencies_us, s2.latencies_us);
    }
}
