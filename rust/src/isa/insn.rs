//! Instruction structures and bit-accurate encode/decode.
//!
//! Encoding is parameterized by [`IsaLayout`] — the same binary program is
//! *not* portable between configurations, exactly as in VTA where the JSON
//! config fixes field widths for every target. Encode/decode are exact
//! inverses (property-tested) and both simulators consume the *decoded*
//! form, so any encoding bug shows up as an fsim/tsim divergence.

use super::{AluOp, BufferId, DepFlags, Opcode};
use crate::config::{IsaLayout, INSN_BITS};
use crate::util::bitfield::{BitReader, BitWriter};

/// LOAD/STORE: 2-D strided DMA between DRAM and a scratchpad, with
/// zero/valued padding inserted around the transferred block.
///
/// All sizes are in scratchpad *tiles* (the buffer's element granularity).
/// `dram_base` is also tile-granular.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemInsn {
    pub opcode: Opcode, // Load or Store
    pub deps: DepFlags,
    pub buffer: BufferId,
    pub sram_base: u32,
    pub dram_base: u32,
    /// Rows to transfer.
    pub y_size: u32,
    /// Tiles per row.
    pub x_size: u32,
    /// DRAM tiles between consecutive row starts.
    pub x_stride: u32,
    pub y_pad0: u32,
    pub y_pad1: u32,
    pub x_pad0: u32,
    pub x_pad1: u32,
    /// Fill value for padded tiles — new in this work; `-128` enables
    /// max-pooling over padded borders, `0` is the conv default.
    pub pad_value: i8,
}

impl MemInsn {
    /// Tiles written to SRAM including padding.
    pub fn sram_tiles(&self) -> u64 {
        (self.y_pad0 + self.y_size + self.y_pad1) as u64
            * (self.x_pad0 + self.x_size + self.x_pad1) as u64
    }

    /// Tiles actually transferred from/to DRAM.
    pub fn dram_tiles(&self) -> u64 {
        self.y_size as u64 * self.x_size as u64
    }
}

/// GEMM: a two-level loop nest over a uop sequence. Each uop supplies
/// scratchpad base indices; the loop factors advance them per iteration:
///
/// ```text
/// for i0 in 0..lp_out:
///   for i1 in 0..lp_in:
///     for u in uop_bgn..uop_end:
///       acc[u.acc + i0*acc_f0 + i1*acc_f1]
///         (+)= inp[u.inp + i0*inp_f0 + i1*inp_f1]
///            · wgtᵀ[u.wgt + i0*wgt_f0 + i1*wgt_f1]
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmInsn {
    pub deps: DepFlags,
    /// Reset mode: zero the destination accumulator tiles instead of
    /// performing MACs.
    pub reset: bool,
    pub uop_bgn: u32,
    pub uop_end: u32,
    pub lp_out: u32,
    pub lp_in: u32,
    pub acc_f0: u32,
    pub acc_f1: u32,
    pub inp_f0: u32,
    pub inp_f1: u32,
    pub wgt_f0: u32,
    pub wgt_f1: u32,
}

impl GemmInsn {
    /// Number of uop executions (tile-matmuls) this instruction performs.
    pub fn total_ops(&self) -> u64 {
        self.lp_out as u64 * self.lp_in as u64 * (self.uop_end - self.uop_bgn) as u64
    }
}

/// ALU: same loop structure as GEMM but over accumulator tiles, with a
/// vector op per element; src is a second accumulator index or an
/// immediate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AluInsn {
    pub deps: DepFlags,
    pub reset: bool,
    pub op: AluOp,
    pub uop_bgn: u32,
    pub uop_end: u32,
    pub lp_out: u32,
    pub lp_in: u32,
    pub dst_f0: u32,
    pub dst_f1: u32,
    pub src_f0: u32,
    pub src_f1: u32,
    pub use_imm: bool,
    pub imm: i32,
}

impl AluInsn {
    pub fn total_ops(&self) -> u64 {
        self.lp_out as u64 * self.lp_in as u64 * (self.uop_end - self.uop_bgn) as u64
    }
}

/// A decoded VTA instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Insn {
    Mem(MemInsn),
    Gemm(GemmInsn),
    Alu(AluInsn),
    Finish(DepFlags),
}

#[derive(Debug, Clone, PartialEq)]
pub struct DecodeError {
    pub message: String,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "instruction decode: {}", self.message)
    }
}

impl std::error::Error for DecodeError {}

impl Insn {
    pub fn opcode(&self) -> Opcode {
        match self {
            Insn::Mem(m) => m.opcode,
            Insn::Gemm(_) => Opcode::Gemm,
            Insn::Alu(_) => Opcode::Alu,
            Insn::Finish(_) => Opcode::Finish,
        }
    }

    pub fn deps(&self) -> DepFlags {
        match self {
            Insn::Mem(m) => m.deps,
            Insn::Gemm(g) => g.deps,
            Insn::Alu(a) => a.deps,
            Insn::Finish(d) => *d,
        }
    }

    pub fn deps_mut(&mut self) -> &mut DepFlags {
        match self {
            Insn::Mem(m) => &mut m.deps,
            Insn::Gemm(g) => &mut g.deps,
            Insn::Alu(a) => &mut a.deps,
            Insn::Finish(d) => d,
        }
    }

    /// Encode into the 128-bit instruction word under `layout`.
    ///
    /// Panics if a field exceeds its configured width — the runtime is
    /// responsible for never emitting such instructions (and its tests
    /// assert that), mirroring hardware where the field would silently
    /// wrap.
    pub fn encode(&self, layout: &IsaLayout) -> u128 {
        let mut w = BitWriter::new();
        match self {
            Insn::Mem(m) => {
                w.push(m.opcode as u64, 3)
                    .push(m.deps.to_bits(), 4)
                    .push(m.buffer as u64, 3)
                    .push(m.sram_base as u64, layout.sram_bits)
                    .push(m.dram_base as u64, layout.dram_bits)
                    .push(m.y_size as u64, layout.mem_size_bits)
                    .push(m.x_size as u64, layout.mem_size_bits)
                    .push(m.x_stride as u64, layout.mem_size_bits)
                    .push(m.y_pad0 as u64, layout.pad_bits)
                    .push(m.y_pad1 as u64, layout.pad_bits)
                    .push(m.x_pad0 as u64, layout.pad_bits)
                    .push(m.x_pad1 as u64, layout.pad_bits)
                    .push((m.pad_value as u8) as u64, layout.pad_val_bits);
            }
            Insn::Gemm(g) => {
                w.push(Opcode::Gemm as u64, 3)
                    .push(g.deps.to_bits(), 4)
                    .push(g.reset as u64, 1)
                    .push(g.uop_bgn as u64, layout.uop_idx_bits)
                    .push(g.uop_end as u64, layout.uop_end_bits())
                    .push(g.lp_out as u64, layout.loop_bits)
                    .push(g.lp_in as u64, layout.loop_bits)
                    .push(g.acc_f0 as u64, layout.acc_idx_bits)
                    .push(g.acc_f1 as u64, layout.acc_idx_bits)
                    .push(g.inp_f0 as u64, layout.inp_idx_bits)
                    .push(g.inp_f1 as u64, layout.inp_idx_bits)
                    .push(g.wgt_f0 as u64, layout.wgt_idx_bits)
                    .push(g.wgt_f1 as u64, layout.wgt_idx_bits);
            }
            Insn::Alu(a) => {
                w.push(Opcode::Alu as u64, 3)
                    .push(a.deps.to_bits(), 4)
                    .push(a.reset as u64, 1)
                    .push(a.uop_bgn as u64, layout.uop_idx_bits)
                    .push(a.uop_end as u64, layout.uop_end_bits())
                    .push(a.lp_out as u64, layout.loop_bits)
                    .push(a.lp_in as u64, layout.loop_bits)
                    .push(a.dst_f0 as u64, layout.acc_idx_bits)
                    .push(a.dst_f1 as u64, layout.acc_idx_bits)
                    .push(a.src_f0 as u64, layout.acc_idx_bits)
                    .push(a.src_f1 as u64, layout.acc_idx_bits)
                    .push(a.op as u64, layout.alu_op_bits)
                    .push(a.use_imm as u64, 1)
                    .push_signed(a.imm as i64, layout.imm_bits);
            }
            Insn::Finish(deps) => {
                w.push(Opcode::Finish as u64, 3).push(deps.to_bits(), 4);
            }
        }
        debug_assert!(w.bits_used() <= INSN_BITS);
        w.finish()
    }

    /// Decode a 128-bit instruction word under `layout`.
    pub fn decode(word: u128, layout: &IsaLayout) -> Result<Insn, DecodeError> {
        let mut r = BitReader::new(word);
        let opcode = Opcode::from_bits(r.pull(3))
            .ok_or_else(|| DecodeError { message: "bad opcode".into() })?;
        let deps = DepFlags::from_bits(r.pull(4));
        match opcode {
            Opcode::Load | Opcode::Store => {
                let buffer = BufferId::from_bits(r.pull(3))
                    .ok_or_else(|| DecodeError { message: "bad buffer id".into() })?;
                Ok(Insn::Mem(MemInsn {
                    opcode,
                    deps,
                    buffer,
                    sram_base: r.pull(layout.sram_bits) as u32,
                    dram_base: r.pull(layout.dram_bits) as u32,
                    y_size: r.pull(layout.mem_size_bits) as u32,
                    x_size: r.pull(layout.mem_size_bits) as u32,
                    x_stride: r.pull(layout.mem_size_bits) as u32,
                    y_pad0: r.pull(layout.pad_bits) as u32,
                    y_pad1: r.pull(layout.pad_bits) as u32,
                    x_pad0: r.pull(layout.pad_bits) as u32,
                    x_pad1: r.pull(layout.pad_bits) as u32,
                    pad_value: r.pull(layout.pad_val_bits) as u8 as i8,
                }))
            }
            Opcode::Gemm => Ok(Insn::Gemm(GemmInsn {
                deps,
                reset: r.pull(1) != 0,
                uop_bgn: r.pull(layout.uop_idx_bits) as u32,
                uop_end: r.pull(layout.uop_end_bits()) as u32,
                lp_out: r.pull(layout.loop_bits) as u32,
                lp_in: r.pull(layout.loop_bits) as u32,
                acc_f0: r.pull(layout.acc_idx_bits) as u32,
                acc_f1: r.pull(layout.acc_idx_bits) as u32,
                inp_f0: r.pull(layout.inp_idx_bits) as u32,
                inp_f1: r.pull(layout.inp_idx_bits) as u32,
                wgt_f0: r.pull(layout.wgt_idx_bits) as u32,
                wgt_f1: r.pull(layout.wgt_idx_bits) as u32,
            })),
            Opcode::Alu => Ok(Insn::Alu(AluInsn {
                deps,
                reset: r.pull(1) != 0,
                uop_bgn: r.pull(layout.uop_idx_bits) as u32,
                uop_end: r.pull(layout.uop_end_bits()) as u32,
                lp_out: r.pull(layout.loop_bits) as u32,
                lp_in: r.pull(layout.loop_bits) as u32,
                dst_f0: r.pull(layout.acc_idx_bits) as u32,
                dst_f1: r.pull(layout.acc_idx_bits) as u32,
                src_f0: r.pull(layout.acc_idx_bits) as u32,
                src_f1: r.pull(layout.acc_idx_bits) as u32,
                op: AluOp::from_bits(r.pull(layout.alu_op_bits))
                    .ok_or_else(|| DecodeError { message: "bad alu op".into() })?,
                use_imm: r.pull(1) != 0,
                imm: r.pull_signed(layout.imm_bits) as i32,
            })),
            Opcode::Finish => Ok(Insn::Finish(deps)),
        }
    }

    /// Serialize an instruction stream to bytes (DRAM image format:
    /// 16 bytes per instruction, little-endian).
    pub fn stream_to_bytes(insns: &[Insn], layout: &IsaLayout) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(insns.len() * 16);
        for insn in insns {
            bytes.extend_from_slice(&insn.encode(layout).to_le_bytes());
        }
        bytes
    }

    /// Parse an instruction stream from a DRAM image.
    pub fn stream_from_bytes(bytes: &[u8], layout: &IsaLayout) -> Result<Vec<Insn>, DecodeError> {
        if bytes.len() % 16 != 0 {
            return Err(DecodeError {
                message: format!("stream length {} not a multiple of 16", bytes.len()),
            });
        }
        bytes
            .chunks_exact(16)
            .map(|c| {
                let word = u128::from_le_bytes(c.try_into().unwrap());
                Insn::decode(word, layout)
            })
            .collect()
    }

    /// One-line disassembly (debug traces, gantt tooltips).
    pub fn disasm(&self) -> String {
        match self {
            Insn::Mem(m) => format!(
                "{:?} {:?} sram={} dram={} y={} x={} stride={} pad=[{},{},{},{}]@{}",
                m.opcode,
                m.buffer,
                m.sram_base,
                m.dram_base,
                m.y_size,
                m.x_size,
                m.x_stride,
                m.y_pad0,
                m.y_pad1,
                m.x_pad0,
                m.x_pad1,
                m.pad_value
            ),
            Insn::Gemm(g) => format!(
                "GEMM{} uops=[{},{}) loops={}x{} acc=({},{}) inp=({},{}) wgt=({},{})",
                if g.reset { ".rst" } else { "" },
                g.uop_bgn,
                g.uop_end,
                g.lp_out,
                g.lp_in,
                g.acc_f0,
                g.acc_f1,
                g.inp_f0,
                g.inp_f1,
                g.wgt_f0,
                g.wgt_f1
            ),
            Insn::Alu(a) => format!(
                "ALU.{:?}{} uops=[{},{}) loops={}x{} dst=({},{}) src=({},{}) imm={}({})",
                a.op,
                if a.reset { ".rst" } else { "" },
                a.uop_bgn,
                a.uop_end,
                a.lp_out,
                a.lp_in,
                a.dst_f0,
                a.dst_f1,
                a.src_f0,
                a.src_f1,
                a.imm,
                if a.use_imm { "imm" } else { "reg" }
            ),
            Insn::Finish(_) => "FINISH".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn layout() -> IsaLayout {
        presets::default_config().isa_layout()
    }

    fn sample_mem() -> Insn {
        Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE.pop_next().push_next(),
            buffer: BufferId::Inp,
            sram_base: 17,
            dram_base: 123456,
            y_size: 14,
            x_size: 15,
            x_stride: 56,
            y_pad0: 1,
            y_pad1: 1,
            x_pad0: 1,
            x_pad1: 1,
            pad_value: -128,
        })
    }

    fn sample_gemm() -> Insn {
        Insn::Gemm(GemmInsn {
            deps: DepFlags::NONE.pop_prev(),
            reset: false,
            uop_bgn: 3,
            uop_end: 12,
            lp_out: 7,
            lp_in: 9,
            acc_f0: 14,
            acc_f1: 1,
            inp_f0: 14,
            inp_f1: 0,
            wgt_f0: 0,
            wgt_f1: 1,
        })
    }

    fn sample_alu() -> Insn {
        Insn::Alu(AluInsn {
            deps: DepFlags::NONE.push_next(),
            reset: false,
            op: AluOp::Clip,
            uop_bgn: 0,
            uop_end: 4,
            lp_out: 8,
            lp_in: 2,
            dst_f0: 16,
            dst_f1: 1,
            src_f0: 16,
            src_f1: 1,
            use_imm: true,
            imm: -127,
        })
    }

    #[test]
    fn roundtrip_all_forms() {
        let l = layout();
        for insn in [sample_mem(), sample_gemm(), sample_alu(), Insn::Finish(DepFlags::NONE)] {
            let word = insn.encode(&l);
            let back = Insn::decode(word, &l).unwrap();
            assert_eq!(back, insn, "roundtrip failed: {}", insn.disasm());
        }
    }

    #[test]
    fn negative_values_roundtrip() {
        let l = layout();
        if let Insn::Mem(mut m) = sample_mem() {
            m.pad_value = -1;
            let back = Insn::decode(Insn::Mem(m).encode(&l), &l).unwrap();
            assert_eq!(back, Insn::Mem(m));
        }
        if let Insn::Alu(mut a) = sample_alu() {
            a.imm = -32768;
            let back = Insn::decode(Insn::Alu(a).encode(&l), &l).unwrap();
            assert_eq!(back, Insn::Alu(a));
        }
    }

    #[test]
    fn bad_opcode_rejected() {
        let l = layout();
        // opcode 7 is unused
        assert!(Insn::decode(7u128, &l).is_err());
    }

    #[test]
    fn stream_roundtrip() {
        let l = layout();
        let insns = vec![sample_mem(), sample_gemm(), sample_alu(), Insn::Finish(DepFlags::NONE)];
        let bytes = Insn::stream_to_bytes(&insns, &l);
        assert_eq!(bytes.len(), 64);
        let back = Insn::stream_from_bytes(&bytes, &l).unwrap();
        assert_eq!(back, insns);
    }

    #[test]
    fn stream_bad_length_rejected() {
        let l = layout();
        assert!(Insn::stream_from_bytes(&[0u8; 17], &l).is_err());
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn field_overflow_panics() {
        let l = layout();
        // acc_depth 2048 -> 11 bits; 4096 doesn't fit.
        let mut g = match sample_gemm() {
            Insn::Gemm(g) => g,
            _ => unreachable!(),
        };
        g.acc_f0 = 4096;
        Insn::Gemm(g).encode(&l);
    }

    #[test]
    fn layouts_differ_between_configs() {
        // The same instruction encodes differently under different
        // configurations — binaries are config-specific by design.
        let small = presets::tiny_config().isa_layout();
        let big = presets::default_config().isa_layout();
        let insn = sample_gemm();
        assert_ne!(insn.encode(&small), insn.encode(&big));
        assert_eq!(Insn::decode(insn.encode(&small), &small).unwrap(), insn);
    }

    #[test]
    fn total_ops() {
        if let Insn::Gemm(g) = sample_gemm() {
            assert_eq!(g.total_ops(), 7 * 9 * 9);
        }
        if let Insn::Alu(a) = sample_alu() {
            assert_eq!(a.total_ops(), 8 * 2 * 4);
        }
    }
}
