//! VTA instruction set architecture (§II-B).
//!
//! Five instructions — LOAD, STORE, GEMM, ALU, FINISH — encoded in a fixed
//! 128 bits with configuration-dependent field widths, plus micro-ops
//! (uops). Extensions from the paper relative to upstream VTA:
//!
//! * variable field widths driven by [`IsaLayout`](crate::config::IsaLayout),
//! * LOAD carries an explicit 8-bit pad value (max-pooling support),
//! * new ALU opcodes: `MUL` (element-wise 8-bit multiply for depthwise
//!   convolution), `CLIP` (ResNet requantization pattern), `MOV`,
//! * uop width extended beyond 32 bits when scratchpad indices demand it.

pub mod insn;
pub mod uop;

pub use insn::{AluInsn, GemmInsn, Insn, MemInsn};
pub use uop::Uop;

/// Top-level opcodes (3 bits). Values match upstream VTA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    Load = 0,
    Store = 1,
    Gemm = 2,
    Finish = 3,
    Alu = 4,
}

impl Opcode {
    pub fn from_bits(v: u64) -> Option<Opcode> {
        match v {
            0 => Some(Opcode::Load),
            1 => Some(Opcode::Store),
            2 => Some(Opcode::Gemm),
            3 => Some(Opcode::Finish),
            4 => Some(Opcode::Alu),
            _ => None,
        }
    }
}

/// Scratchpad / memory-type selector for LOAD/STORE (3 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum BufferId {
    Uop = 0,
    Wgt = 1,
    Inp = 2,
    Acc = 3,
    Out = 4,
    /// 8-bit view of the accumulator: LOAD widens int8 DRAM data into
    /// int32 accumulator entries. Used to feed residual adds, pooling and
    /// depthwise convolution (upstream VTA's `ACC_8BIT` memory type).
    Acc8 = 5,
}

impl BufferId {
    pub fn from_bits(v: u64) -> Option<BufferId> {
        match v {
            0 => Some(BufferId::Uop),
            1 => Some(BufferId::Wgt),
            2 => Some(BufferId::Inp),
            3 => Some(BufferId::Acc),
            4 => Some(BufferId::Out),
            5 => Some(BufferId::Acc8),
            _ => None,
        }
    }

    pub const ALL: [BufferId; 6] = [
        BufferId::Uop,
        BufferId::Wgt,
        BufferId::Inp,
        BufferId::Acc,
        BufferId::Out,
        BufferId::Acc8,
    ];
}

/// ALU micro-operations (4-bit field). MIN/MAX/ADD/SHR match upstream
/// VTA; MUL, CLIP and MOV are the paper's additions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum AluOp {
    Min = 0,
    Max = 1,
    Add = 2,
    /// Arithmetic shift right by immediate; negative immediate shifts
    /// left (upstream VTA convention).
    Shr = 3,
    /// Element-wise multiply, truncating operands to 8 bits — the new
    /// instruction enabling depthwise convolution on the ALU (§IV-D3).
    Mul = 4,
    /// dst = clamp(dst, -imm, imm) — the new single-instruction form of
    /// the MIN+MAX requantization pattern common in ResNets.
    Clip = 5,
    /// dst = src (or immediate) — used to seed pooling reductions.
    Mov = 6,
}

impl AluOp {
    pub fn from_bits(v: u64) -> Option<AluOp> {
        match v {
            0 => Some(AluOp::Min),
            1 => Some(AluOp::Max),
            2 => Some(AluOp::Add),
            3 => Some(AluOp::Shr),
            4 => Some(AluOp::Mul),
            5 => Some(AluOp::Clip),
            6 => Some(AluOp::Mov),
            _ => None,
        }
    }

    /// Whether the op reads a second scratchpad operand when `use_imm`
    /// is false (everything except pure-immediate forms).
    pub fn is_binary(&self) -> bool {
        true
    }
}

/// The four dependency-token bits carried by every instruction (§II-A).
/// `prev`/`next` refer to the neighbouring module in the
/// load → compute → store chain from the executing module's viewpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DepFlags {
    pub pop_prev: bool,
    pub pop_next: bool,
    pub push_prev: bool,
    pub push_next: bool,
}

impl DepFlags {
    pub const NONE: DepFlags =
        DepFlags { pop_prev: false, pop_next: false, push_prev: false, push_next: false };

    pub fn to_bits(self) -> u64 {
        (self.pop_prev as u64)
            | (self.pop_next as u64) << 1
            | (self.push_prev as u64) << 2
            | (self.push_next as u64) << 3
    }

    pub fn from_bits(v: u64) -> DepFlags {
        DepFlags {
            pop_prev: v & 1 != 0,
            pop_next: v & 2 != 0,
            push_prev: v & 4 != 0,
            push_next: v & 8 != 0,
        }
    }

    pub fn pop_prev(mut self) -> Self {
        self.pop_prev = true;
        self
    }

    pub fn pop_next(mut self) -> Self {
        self.pop_next = true;
        self
    }

    pub fn push_prev(mut self) -> Self {
        self.push_prev = true;
        self
    }

    pub fn push_next(mut self) -> Self {
        self.push_next = true;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_roundtrip() {
        for op in [Opcode::Load, Opcode::Store, Opcode::Gemm, Opcode::Finish, Opcode::Alu] {
            assert_eq!(Opcode::from_bits(op as u64), Some(op));
        }
        assert_eq!(Opcode::from_bits(7), None);
    }

    #[test]
    fn buffer_roundtrip() {
        for b in BufferId::ALL {
            assert_eq!(BufferId::from_bits(b as u64), Some(b));
        }
        assert_eq!(BufferId::from_bits(6), None);
    }

    #[test]
    fn aluop_roundtrip() {
        for op in [
            AluOp::Min,
            AluOp::Max,
            AluOp::Add,
            AluOp::Shr,
            AluOp::Mul,
            AluOp::Clip,
            AluOp::Mov,
        ] {
            assert_eq!(AluOp::from_bits(op as u64), Some(op));
        }
        assert_eq!(AluOp::from_bits(9), None);
    }

    #[test]
    fn depflags_bits() {
        let d = DepFlags::NONE.pop_prev().push_next();
        assert_eq!(d.to_bits(), 0b1001);
        assert_eq!(DepFlags::from_bits(0b1001), d);
        assert_eq!(DepFlags::from_bits(0), DepFlags::NONE);
        assert_eq!(DepFlags::from_bits(0b1111).to_bits(), 0b1111);
    }
}
