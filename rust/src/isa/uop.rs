//! Micro-operations (§II-B).
//!
//! A uop supplies the per-step scratchpad base indices inside a GEMM/ALU
//! loop nest. GEMM uops carry (acc, inp, wgt); ALU uops reuse the same
//! storage as (dst, src, _). Upstream VTA packs uops into 32 bits; this
//! work widens them when larger scratchpads need more index bits
//! ("Wider uops can support wider fields, allowing larger scratchpads,
//! but also require additional storage and memory bandwidth").

use crate::config::IsaLayout;
use crate::util::bitfield::{BitReader, BitWriter};

/// A decoded micro-op. For GEMM the fields are (acc, inp, wgt) indices;
/// for ALU, `acc` is the destination and `inp` the source (wgt unused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Uop {
    pub acc: u32,
    pub inp: u32,
    pub wgt: u32,
}

impl Uop {
    pub fn gemm(acc: u32, inp: u32, wgt: u32) -> Uop {
        Uop { acc, inp, wgt }
    }

    /// ALU uop: destination and source accumulator indices.
    pub fn alu(dst: u32, src: u32) -> Uop {
        Uop { acc: dst, inp: src, wgt: 0 }
    }

    pub fn dst(&self) -> u32 {
        self.acc
    }

    pub fn src(&self) -> u32 {
        self.inp
    }

    /// Encode into the configuration's uop width. Fields are packed
    /// little-endian: acc, inp, wgt.
    pub fn encode(&self, layout: &IsaLayout) -> u64 {
        let mut w = BitWriter::new();
        w.push(self.acc as u64, layout.acc_idx_bits)
            .push(self.inp as u64, layout.inp_idx_bits)
            .push(self.wgt as u64, layout.wgt_idx_bits);
        debug_assert!(w.bits_used() <= layout.uop_bits);
        w.finish() as u64
    }

    pub fn decode(word: u64, layout: &IsaLayout) -> Uop {
        let mut r = BitReader::new(word as u128);
        Uop {
            acc: r.pull(layout.acc_idx_bits) as u32,
            inp: r.pull(layout.inp_idx_bits) as u32,
            wgt: r.pull(layout.wgt_idx_bits) as u32,
        }
    }

    /// Serialize a uop sequence to its DRAM image (uop_bytes per entry,
    /// little-endian).
    pub fn stream_to_bytes(uops: &[Uop], layout: &IsaLayout) -> Vec<u8> {
        let ub = layout.uop_bytes();
        let mut bytes = Vec::with_capacity(uops.len() * ub);
        for u in uops {
            bytes.extend_from_slice(&u.encode(layout).to_le_bytes()[..ub]);
        }
        bytes
    }

    pub fn stream_from_bytes(bytes: &[u8], layout: &IsaLayout) -> Vec<Uop> {
        let ub = layout.uop_bytes();
        bytes
            .chunks_exact(ub)
            .map(|c| {
                let mut raw = [0u8; 8];
                raw[..ub].copy_from_slice(c);
                Uop::decode(u64::from_le_bytes(raw), layout)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn roundtrip_default_layout() {
        let l = presets::default_config().isa_layout();
        for u in [Uop::gemm(0, 0, 0), Uop::gemm(2047, 2047, 1023), Uop::alu(100, 7)] {
            assert_eq!(Uop::decode(u.encode(&l), &l), u);
        }
    }

    #[test]
    fn default_layout_is_32bit_like_upstream() {
        let l = presets::default_config().isa_layout();
        assert_eq!(l.uop_bytes(), 4);
        // Max encodable uop fits in 32 bits.
        let u = Uop::gemm(2047, 2047, 1023);
        assert!(u.encode(&l) < (1u64 << 32));
    }

    #[test]
    fn stream_roundtrip() {
        let l = presets::default_config().isa_layout();
        let uops: Vec<Uop> = (0..17).map(|i| Uop::gemm(i, i * 2 % 2048, i % 1024)).collect();
        let bytes = Uop::stream_to_bytes(&uops, &l);
        assert_eq!(bytes.len(), 17 * 4);
        assert_eq!(Uop::stream_from_bytes(&bytes, &l), uops);
    }

    #[test]
    fn wide_uop_roundtrip() {
        // A big config forces uops beyond 32 bits.
        let cfg = presets::scaled_config(1, 64, 64, 8, 64);
        let l = cfg.isa_layout();
        assert!(l.uop_bits > 32);
        let u = Uop::gemm(cfg.acc_depth as u32 - 1, cfg.inp_depth as u32 - 1, cfg.wgt_depth as u32 - 1);
        assert_eq!(Uop::decode(u.encode(&l), &l), u);
    }
}
