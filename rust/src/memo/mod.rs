//! Layer-memoized simulation cache.
//!
//! VTA's decoupled access-execute design makes a layer's cycle count a
//! pure function of (hardware configuration, layer op, chosen tiling):
//! timing depends only on instruction fields (transfer sizes, loop
//! extents), never on tensor data or DRAM addresses. A design-space
//! sweep therefore re-derives the same per-layer results millions of
//! times — ResNet's residual stages repeat identical layer shapes within
//! one network, ResNet-18/34/50 share most conv shapes across networks,
//! and every extra input seed repeats the whole network verbatim.
//!
//! This module collapses that: a [`LayerSig`](sig::LayerSig) hash keys a
//! [`LayerMemo`] of per-layer [`LayerRecord`]s (cycles, program insn/uop
//! counts, and the full [`ExecCounters`] delta). The runtime consults
//! the memo before compiling/simulating a layer and splices hits into
//! the session, so per-layer reports and whole-network totals are
//! bit-identical to an unmemoized run (property-tested in
//! `rust/tests/memo_correctness.rs`).
//!
//! Cache layers:
//! * **in-process**: a `Mutex<HashMap>` shared by all sweep workers
//!   (hits cross worker threads, workloads, and seeds within a run);
//! * **on-disk spill** (optional): append-only JSONL next to the sweep
//!   [`ResultCache`](crate::sweep::ResultCache), so resumed sweeps warm
//!   up instantly. Records carry [`SIM_SCHEMA_VERSION`]; records from an
//!   older simulator schema are rejected at load instead of silently
//!   mixed with new-semantics results.

pub mod sig;

pub use sig::LayerSig;

use crate::exec::ExecCounters;
use crate::store::{ArtifactKind, ArtifactStore};
use crate::util::json::{obj, Json};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufRead, BufReader, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Version of the simulator/memo semantics. Bump whenever a change can
/// alter cycle counts or counters (timing model, compiler schedules,
/// counter definitions): the version is hashed into every layer
/// signature *and* every sweep result-cache key, so stale caches miss
/// cleanly instead of mixing incompatible results.
///
/// v1 = the PR-1 sweep cache (implicit, unversioned keys);
/// v2 = this scheme (layer memo + explicit schema fields);
/// v3 = residency planner: signatures carry per-layer residency bits,
///      [`ExecCounters`] grew `resident_tile_hits` / `dma_bytes_elided`,
///      and elided transfers changed tsim DMA timing;
/// v4 = workload families: attention/LSTM operator signatures
///      (softmax/eltmul/sub/unary tags) and the accumulator
///      [`Precision`](crate::config::Precision) mode joined the config
///      hash (narrow accumulation changes functional payloads).
pub const SIM_SCHEMA_VERSION: u32 = 4;

/// Everything the runtime needs to splice a cached layer into a session
/// without simulating it: cycles consumed, program shape (for
/// `LayerStat`), and the exact execution-counter delta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerRecord {
    pub cycles: u64,
    /// Instructions in the lowered program (`Program::insns.len()`).
    pub prog_insns: u32,
    /// Uops staged for the program (`Program::uop_count`).
    pub prog_uops: u32,
    /// Counter delta the layer's execution produces.
    pub exec: ExecCounters,
}

impl LayerRecord {
    pub fn to_json(&self, sig: LayerSig) -> Json {
        obj([
            ("schema", Json::Int(SIM_SCHEMA_VERSION as i64)),
            ("sig", Json::Str(format!("{:016x}", sig.0))),
            ("cycles", Json::Int(self.cycles as i64)),
            ("prog_insns", Json::Int(self.prog_insns as i64)),
            ("prog_uops", Json::Int(self.prog_uops as i64)),
            ("exec", self.exec.to_json()),
        ])
    }

    /// Parse one spill line; `None` on any malformed field *or* a schema
    /// version other than [`SIM_SCHEMA_VERSION`].
    pub fn from_json(j: &Json) -> Option<(LayerSig, LayerRecord)> {
        if j.get("schema")?.as_i64()? != SIM_SCHEMA_VERSION as i64 {
            return None;
        }
        let sig = LayerSig(u64::from_str_radix(j.get("sig")?.as_str()?, 16).ok()?);
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some((
            sig,
            LayerRecord {
                cycles: int("cycles")?,
                prog_insns: int("prog_insns")? as u32,
                prog_uops: int("prog_uops")? as u32,
                exec: ExecCounters::from_json(j.get("exec")?)?,
            },
        ))
    }
}

/// The shared layer-result cache. Thread-safe: sweep workers hold one
/// instance behind an `Arc` and consult it concurrently. The map and
/// the spill file take separate locks so a worker's lookup never waits
/// behind another worker's disk write.
#[derive(Debug)]
pub struct LayerMemo {
    map: Mutex<HashMap<u64, LayerRecord>>,
    /// Append-only JSONL spill; dropped (cache degrades to in-memory)
    /// after the first write error.
    spill: Mutex<Option<File>>,
    /// Artifact-store backing (`Program` records); replaces the private
    /// spill file when the sweep runs against a store.
    store: Option<Arc<ArtifactStore>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Valid records recovered from an existing spill file.
    pub loaded: usize,
    /// Malformed lines rejected during load (truncated writes).
    pub skipped: usize,
    /// Well-formed records rejected for carrying an older
    /// [`SIM_SCHEMA_VERSION`].
    pub skipped_stale: usize,
}

impl LayerMemo {
    /// Cache without a backing file.
    pub fn in_memory() -> LayerMemo {
        LayerMemo {
            map: Mutex::new(HashMap::new()),
            spill: Mutex::new(None),
            store: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            loaded: 0,
            skipped: 0,
            skipped_stale: 0,
        }
    }

    /// Memo backed by the artifact store: existing
    /// [`ArtifactKind::Program`] records are loaded and new layers land
    /// as store artifacts (keyed by the layer signature) instead of a
    /// private spill file.
    pub fn store_backed(store: Arc<ArtifactStore>) -> LayerMemo {
        let mut map = HashMap::new();
        let mut loaded = 0;
        for (key, payload) in store.records(ArtifactKind::Program) {
            if let Some((sig, rec)) = LayerRecord::from_json(&payload) {
                debug_assert_eq!(sig.0, key);
                map.insert(sig.0, rec);
                loaded += 1;
            }
        }
        let (_, skipped, skipped_stale) = store.kind_counts(ArtifactKind::Program);
        LayerMemo {
            map: Mutex::new(map),
            spill: Mutex::new(None),
            store: Some(store),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            loaded,
            skipped,
            skipped_stale,
        }
    }

    /// Open a file-backed memo. With `resume`, current-schema records
    /// are loaded and new ones appended; without, the file is truncated.
    pub fn open(path: &Path, resume: bool) -> io::Result<LayerMemo> {
        let mut map = HashMap::new();
        let mut loaded = 0;
        let mut skipped = 0;
        let mut skipped_stale = 0;
        if resume && path.exists() {
            let reader = BufReader::new(File::open(path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let parsed = Json::parse(&line).ok();
                match parsed.as_ref().and_then(LayerRecord::from_json) {
                    Some((sig, rec)) => {
                        map.insert(sig.0, rec);
                        loaded += 1;
                    }
                    // A well-formed record whose only defect is an
                    // integer schema stamp ≠ current is *stale*, not
                    // corrupt — the distinction feeds migration hints.
                    None => match parsed.and_then(|j| j.get("schema").and_then(|v| v.as_i64())) {
                        Some(v) if v > 0 && v != SIM_SCHEMA_VERSION as i64 => skipped_stale += 1,
                        _ => skipped += 1,
                    },
                }
            }
        }
        let spill = if resume {
            OpenOptions::new().create(true).append(true).open(path)?
        } else {
            OpenOptions::new().create(true).write(true).truncate(true).open(path)?
        };
        Ok(LayerMemo {
            map: Mutex::new(map),
            spill: Mutex::new(Some(spill)),
            store: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            loaded,
            skipped,
            skipped_stale,
        })
    }

    /// Look a layer up; counts toward the hit/miss statistics.
    pub fn get(&self, sig: LayerSig) -> Option<LayerRecord> {
        let found = self.map.lock().unwrap().get(&sig.0).copied();
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Record a simulated layer. Spill writes are best-effort: an I/O
    /// error silently downgrades the memo to in-memory-only (the sweep's
    /// correctness never depends on the spill). The map lock is released
    /// before the disk write, so concurrent lookups never stall on I/O.
    pub fn insert(&self, sig: LayerSig, rec: LayerRecord) {
        // First writer wins; concurrent workers may race to simulate the
        // same layer, but determinism makes their records identical.
        if self.map.lock().unwrap().insert(sig.0, rec).is_some() {
            return;
        }
        if let Some(store) = &self.store {
            // Same best-effort discipline as the spill: a store write
            // error costs persistence, never correctness.
            store.put(ArtifactKind::Program, sig.0, rec.to_json(sig)).ok();
            return;
        }
        let mut spill = self.spill.lock().unwrap();
        let mut write_failed = false;
        if let Some(file) = spill.as_mut() {
            let mut line = rec.to_json(sig).to_string_compact();
            line.push('\n');
            write_failed = file.write_all(line.as_bytes()).and_then(|_| file.flush()).is_err();
        }
        if write_failed {
            *spill = None;
        }
    }

    /// Distinct layers cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn sample_rec(cycles: u64) -> LayerRecord {
        LayerRecord {
            cycles,
            prog_insns: 12,
            prog_uops: 34,
            exec: ExecCounters {
                insn_count: 12,
                gemm_ops: 5,
                macs: 1280,
                alu_ops: 3,
                alu_elems: 48,
                load_bytes_inp: 256,
                load_bytes_wgt: 512,
                load_bytes_acc: 64,
                load_bytes_uop: 16,
                store_bytes: 128,
                pad_tiles: 9,
                resident_tile_hits: 6,
                dma_bytes_elided: 384,
            },
        }
    }

    fn temp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("vta_memo_test_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn in_memory_roundtrip_and_stats() {
        let memo = LayerMemo::in_memory();
        let sig = LayerSig(0xdead_beef_0123_4567);
        assert_eq!(memo.get(sig), None);
        memo.insert(sig, sample_rec(1000));
        assert_eq!(memo.get(sig), Some(sample_rec(1000)));
        assert_eq!((memo.hits(), memo.misses()), (1, 1));
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn record_json_roundtrip() {
        let sig = LayerSig(0x0000_00ff_ffff_0001);
        let rec = sample_rec(987_654_321);
        let text = rec.to_json(sig).to_string_compact();
        let (s2, r2) = LayerRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!((s2, r2), (sig, rec));
    }

    #[test]
    fn old_schema_records_rejected() {
        let sig = LayerSig(42);
        let mut j = sample_rec(5).to_json(sig);
        if let Json::Object(map) = &mut j {
            map.insert("schema".into(), Json::Int(SIM_SCHEMA_VERSION as i64 - 1));
        }
        assert_eq!(LayerRecord::from_json(&j), None, "stale schema must not load");
    }

    #[test]
    fn spill_resume_recovers_and_truncate_discards() {
        let path = temp_path("resume");
        {
            let memo = LayerMemo::open(&path, false).unwrap();
            memo.insert(LayerSig(1), sample_rec(10));
            memo.insert(LayerSig(2), sample_rec(20));
        }
        let memo = LayerMemo::open(&path, true).unwrap();
        assert_eq!((memo.loaded, memo.skipped), (2, 0));
        assert_eq!(memo.get(LayerSig(2)).unwrap().cycles, 20);
        let cold = LayerMemo::open(&path, false).unwrap();
        assert_eq!(cold.loaded, 0);
        assert!(cold.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn store_backed_memo_shares_program_artifacts() {
        let store = Arc::new(ArtifactStore::in_memory());
        {
            let memo = LayerMemo::store_backed(store.clone());
            memo.insert(LayerSig(9), sample_rec(90));
        }
        // A fresh memo over the same store warms up from it — the
        // cross-run analogue of the spill file, shared with serve.
        let memo = LayerMemo::store_backed(store.clone());
        assert_eq!((memo.loaded, memo.skipped, memo.skipped_stale), (1, 0, 0));
        assert_eq!(memo.get(LayerSig(9)).unwrap().cycles, 90);
        assert_eq!(store.len(ArtifactKind::Program), 1);
    }

    #[test]
    fn duplicate_insert_keeps_first_record_once() {
        let path = temp_path("dup");
        {
            let memo = LayerMemo::open(&path, false).unwrap();
            memo.insert(LayerSig(7), sample_rec(70));
            memo.insert(LayerSig(7), sample_rec(70));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate inserts must not duplicate spill lines");
        std::fs::remove_file(&path).ok();
    }
}
