//! Layer-signature hashing: the memo key.
//!
//! A signature is a stable 64-bit FNV-1a hash over everything the
//! simulated result of one layer can depend on:
//!
//! * [`SIM_SCHEMA_VERSION`](super::SIM_SCHEMA_VERSION) — so entries from
//!   an older simulator/compiler semantics can never be confused with
//!   current ones;
//! * the perf-relevant [`VtaConfig`] fields (everything except the
//!   cosmetic `name`): tile geometry and scratchpad depths determine the
//!   compiled program, AXI/latency/queue parameters the timing;
//! * an op-kind tag plus the op's own parameters (shapes, kernel,
//!   stride, padding, requantization shift, ReLU);
//! * for convolutions, the chosen [`Tiling`] — the schedule, including
//!   the improved-double-buffering flag, is part of the program
//!   identity (so `--no-tps` / `--no-dbuf` runs key separately);
//! * the layer's residency bits
//!   ([`NodePlan::sig_bits`](crate::compiler::residency::NodePlan::sig_bits)):
//!   a layer executed against hot (elided-load) inputs or with an
//!   elided store has different DMA counters and cycles than the cold
//!   variant, so the two must never share a memo entry. Bits of 0 are
//!   exactly the `--residency off` program, which keeps off-mode and
//!   all-cold plans sharing entries.
//!
//! Deliberately excluded: DRAM base addresses (instructions encode them
//! but neither timing nor byte counters depend on them), tensor data
//! (VTA timing is data-independent), and the session's `timing_only`
//! flag (both modes produce identical cycles and counters — the
//! invariant `rust/tests/memo_correctness.rs` enforces).

use super::SIM_SCHEMA_VERSION;
use crate::compiler::depthwise::DepthwiseParams;
use crate::compiler::eltwise::PoolParams;
use crate::compiler::tps::{ConvSpec, Tiling};
use crate::config::VtaConfig;
use crate::util::hash::Fnv;

/// A layer's memo key. Stable across processes and machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerSig(pub u64);

/// Op-kind tags keep equal parameter lists of different ops from
/// colliding (e.g. a pool and a depthwise layer with identical numeric
/// fields).
const TAG_CONV: u8 = 1; // also dense and attention-head GEMMs (1x1 conv specs)
const TAG_DEPTHWISE: u8 = 2;
const TAG_POOL: u8 = 3;
const TAG_ADD: u8 = 4;
const TAG_SOFTMAX: u8 = 5;
const TAG_ELTMUL: u8 = 6;
const TAG_SUB: u8 = 7;
const TAG_UNARY: u8 = 8;

/// Hash the schema version and the perf-relevant configuration fields.
fn config_hasher(cfg: &VtaConfig) -> Fnv {
    // Exhaustive destructuring on purpose: adding a `VtaConfig` field
    // breaks this line, forcing a decision on whether it is
    // perf-relevant (and a SIM_SCHEMA_VERSION bump if layer timing
    // changes) instead of silently excluding it from the memo key.
    let VtaConfig {
        name: _,
        batch,
        block_in,
        block_out,
        uop_depth,
        inp_depth,
        wgt_depth,
        acc_depth,
        axi_bytes,
        dram_latency,
        vme_inflight,
        gemm_pipelined,
        alu_pipelined,
        cmd_queue_depth,
        dep_queue_depth,
        precision,
    } = cfg;
    let mut h = Fnv::new();
    h.write_u32(SIM_SCHEMA_VERSION);
    for v in [batch, block_in, block_out, uop_depth, inp_depth, wgt_depth, acc_depth] {
        h.write_u64(*v as u64);
    }
    for v in [axi_bytes, vme_inflight, cmd_queue_depth, dep_queue_depth] {
        h.write_u64(*v as u64);
    }
    h.write_u64(*dram_latency);
    h.write_bool(*gemm_pipelined);
    h.write_bool(*alu_pipelined);
    // Precision changes functional payloads (narrow wraps the GEMM
    // accumulator), so narrow/wide entries must never share a sig.
    h.write_u8(*precision as u8);
    h
}

/// Signature of a convolution (or dense — the spec *is* the identity)
/// lowered with `tiling` under residency bits `res_bits`.
pub fn conv_sig(
    cfg: &VtaConfig,
    spec: &ConvSpec,
    shift: u32,
    relu: bool,
    tiling: &Tiling,
    res_bits: u8,
) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_CONV);
    for v in [spec.c_in, spec.c_out, spec.h, spec.w, spec.kh, spec.kw] {
        h.write_u64(v as u64);
    }
    for v in [spec.sh, spec.sw, spec.ph, spec.pw] {
        h.write_u64(v as u64);
    }
    h.write_u32(shift);
    h.write_bool(relu);
    for v in [tiling.th_o, tiling.tw_o, tiling.tco_o, tiling.tci_o] {
        h.write_u64(v as u64);
    }
    h.write_bool(tiling.reuse_inp);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a depthwise-convolution layer.
pub fn depthwise_sig(cfg: &VtaConfig, p: &DepthwiseParams, res_bits: u8) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_DEPTHWISE);
    for v in [p.c_tiles, p.h, p.w, p.k, p.stride, p.pad] {
        h.write_u64(v as u64);
    }
    h.write_u32(p.shift);
    h.write_bool(p.relu);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a pooling layer (max or average — `is_max`/`shift`
/// distinguish them, covering `GlobalAvgPool` as well).
pub fn pool_sig(cfg: &VtaConfig, p: &PoolParams, res_bits: u8) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_POOL);
    for v in [p.c_tiles, p.h, p.w, p.k, p.stride, p.pad] {
        h.write_u64(v as u64);
    }
    h.write_bool(p.is_max);
    h.write_u32(p.shift);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a residual-add layer over `tiles` activation tiles.
pub fn add_sig(cfg: &VtaConfig, tiles: usize, relu: bool, res_bits: u8) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_ADD);
    h.write_u64(tiles as u64);
    h.write_bool(relu);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a softmax-approx ALU layer over a `(c_tiles, h, w)`
/// tiled activation.
pub fn softmax_sig(
    cfg: &VtaConfig,
    c_tiles: usize,
    h_dim: usize,
    w_dim: usize,
    shift: u32,
    res_bits: u8,
) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_SOFTMAX);
    for v in [c_tiles, h_dim, w_dim] {
        h.write_u64(v as u64);
    }
    h.write_u32(shift);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of an eltwise-multiply layer over `tiles` activation tiles.
pub fn eltmul_sig(cfg: &VtaConfig, tiles: usize, shift: u32, relu: bool, res_bits: u8) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_ELTMUL);
    h.write_u64(tiles as u64);
    h.write_u32(shift);
    h.write_bool(relu);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a clipped-subtract layer (layernorm stage 2) over
/// `tiles` activation tiles.
pub fn sub_sig(cfg: &VtaConfig, tiles: usize, res_bits: u8) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_SUB);
    h.write_u64(tiles as u64);
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

/// Signature of a pointwise immediate-ALU pipeline (hard-sigmoid /
/// hard-tanh) over `tiles` activation tiles. The op pipeline itself is
/// part of the identity.
pub fn unary_sig(
    cfg: &VtaConfig,
    tiles: usize,
    ops: &[(crate::isa::AluOp, i32)],
    res_bits: u8,
) -> LayerSig {
    let mut h = config_hasher(cfg);
    h.write_u8(TAG_UNARY);
    h.write_u64(tiles as u64);
    h.write_u64(ops.len() as u64);
    for &(op, imm) in ops {
        h.write_u8(op as u8);
        h.write_u64(imm as u32 as u64);
    }
    h.write_u8(res_bits);
    LayerSig(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn spec() -> ConvSpec {
        ConvSpec { c_in: 16, c_out: 32, h: 8, w: 8, kh: 3, kw: 3, sh: 1, sw: 1, ph: 1, pw: 1 }
    }

    fn tiling() -> Tiling {
        Tiling { th_o: 2, tw_o: 1, tco_o: 1, tci_o: 1, reuse_inp: true }
    }

    #[test]
    fn conv_sig_is_stable_and_ignores_config_name() {
        let cfg = presets::default_config();
        let a = conv_sig(&cfg, &spec(), 5, true, &tiling(), 0);
        assert_eq!(a, conv_sig(&cfg, &spec(), 5, true, &tiling(), 0));
        let mut renamed = cfg.clone();
        renamed.name = "something-else".into();
        assert_eq!(a, conv_sig(&renamed, &spec(), 5, true, &tiling(), 0), "name is cosmetic");
    }

    #[test]
    fn conv_sig_discriminates_perf_fields() {
        let cfg = presets::default_config();
        let base = conv_sig(&cfg, &spec(), 5, true, &tiling(), 0);
        let mut axi = cfg.clone();
        axi.axi_bytes = 64;
        assert_ne!(base, conv_sig(&axi, &spec(), 5, true, &tiling(), 0));
        let mut pipe = cfg.clone();
        pipe.gemm_pipelined = false;
        assert_ne!(base, conv_sig(&pipe, &spec(), 5, true, &tiling(), 0));
        let mut s2 = spec();
        s2.h = 16;
        assert_ne!(base, conv_sig(&cfg, &s2, 5, true, &tiling(), 0));
        assert_ne!(base, conv_sig(&cfg, &spec(), 6, true, &tiling(), 0));
        assert_ne!(base, conv_sig(&cfg, &spec(), 5, false, &tiling(), 0));
        let mut t2 = tiling();
        t2.reuse_inp = false;
        assert_ne!(base, conv_sig(&cfg, &spec(), 5, true, &t2, 0));
    }

    #[test]
    fn residency_bits_are_part_of_the_identity() {
        // A hot-input or elided-store lowering must never share a memo
        // entry with the cold variant: its DMA counters and cycles
        // differ.
        let cfg = presets::default_config();
        for bits in 1u8..=7 {
            assert_ne!(
                conv_sig(&cfg, &spec(), 5, true, &tiling(), 0),
                conv_sig(&cfg, &spec(), 5, true, &tiling(), bits)
            );
            assert_ne!(add_sig(&cfg, 2, false, 0), add_sig(&cfg, 2, false, bits));
        }
        let dw = DepthwiseParams {
            c_tiles: 2,
            h: 8,
            w: 8,
            k: 3,
            stride: 1,
            pad: 1,
            shift: 0,
            relu: false,
        };
        assert_ne!(depthwise_sig(&cfg, &dw, 0), depthwise_sig(&cfg, &dw, 1));
        let pl = PoolParams {
            c_tiles: 2,
            h: 8,
            w: 8,
            k: 3,
            stride: 1,
            pad: 1,
            is_max: true,
            shift: 0,
        };
        assert_ne!(pool_sig(&cfg, &pl, 0), pool_sig(&cfg, &pl, 5));
    }

    #[test]
    fn op_kinds_do_not_collide() {
        // A pool and a depthwise layer with numerically identical fields
        // must hash apart (the tag byte).
        let cfg = presets::tiny_config();
        let dw = DepthwiseParams {
            c_tiles: 2,
            h: 8,
            w: 8,
            k: 3,
            stride: 1,
            pad: 1,
            shift: 0,
            relu: false,
        };
        let pl = PoolParams {
            c_tiles: 2,
            h: 8,
            w: 8,
            k: 3,
            stride: 1,
            pad: 1,
            is_max: false,
            shift: 0,
        };
        assert_ne!(depthwise_sig(&cfg, &dw, 0), pool_sig(&cfg, &pl, 0));
        assert_ne!(add_sig(&cfg, 2, false, 0), pool_sig(&cfg, &pl, 0));
        // The ALU-program tags introduced for the transformer/LSTM
        // families hash apart from each other and from add.
        assert_ne!(add_sig(&cfg, 2, false, 0), eltmul_sig(&cfg, 2, 0, false, 0));
        assert_ne!(sub_sig(&cfg, 2, 0), eltmul_sig(&cfg, 2, 0, false, 0));
        assert_ne!(
            unary_sig(&cfg, 2, &crate::compiler::eltwise::HARD_SIGMOID_OPS, 0),
            unary_sig(&cfg, 2, &crate::compiler::eltwise::HARD_TANH_OPS, 0)
        );
        assert_ne!(softmax_sig(&cfg, 2, 8, 1, 2, 0), softmax_sig(&cfg, 2, 8, 1, 3, 0));
    }

    #[test]
    fn precision_is_part_of_the_identity() {
        // Narrow accumulation changes functional payloads, so narrow
        // and wide configs must never share a memo entry.
        let mut narrow = presets::tiny_config();
        narrow.precision = crate::config::Precision::Narrow;
        let wide = presets::tiny_config();
        assert_ne!(
            conv_sig(&wide, &spec(), 5, true, &tiling(), 0),
            conv_sig(&narrow, &spec(), 5, true, &tiling(), 0)
        );
    }
}
