//! Inter-layer scratchpad residency planning.
//!
//! The per-layer lowering in this crate is load-everything / compute /
//! store-everything: every producer writes its activation to DRAM and
//! every consumer immediately DMAs it back, so scratchpad capacity
//! beyond one layer's working set buys no DMA reduction. This module
//! adds the missing cross-layer pass: after TPS tiling is fixed, a
//! *residency plan* decides for each producer→consumer edge whether the
//! producer's output stays hot in the scratchpads (the store+load pair
//! is elided from the DMA cost), is spilled to DRAM (the old behavior),
//! or is recomputed at the consumer (DTR-style rematerialization, for
//! cheap element-wise producers only).
//!
//! The plan is **pure**: `plan()` depends only on the configuration,
//! the graph, its shapes, and the tiling policy, so the runtime, the
//! memoizer, and the analytical model all derive the *same* plan
//! independently — which is what keeps memo signatures and two-phase
//! sweep pruning sound (see DESIGN.md §Residency planner).
//!
//! ## Plan IR
//!
//! One [`NodePlan`] per graph node: `resident_inputs[k]` means the
//! consumer's loads of input `k` are elided (the data is hot —
//! either kept resident or just rematerialized); `recompute` lists
//! producers to re-run immediately before this node; `output_elided`
//! means the node's own store traffic is elided (every consumer takes
//! the output hot, so it never needs to be in DRAM). Partial residency
//! is allowed: if only some consumers take an output hot, the store is
//! paid once (write-through) and only the hot consumers elide their
//! loads.
//!
//! ## Capacity model
//!
//! Residency is budgeted against the input scratchpad
//! (`inp_depth x inp_tile_bytes`), the buffer activations are loaded
//! through. At each execution position the planner reserves the
//! executing layer's own working set (for convolutions: the TPS
//! block × its double-buffer slots; element-wise layers stream through
//! the accumulator and reserve nothing) and keeps producer outputs
//! resident in the remainder, evicting by the active
//! [`ResidencyHeuristic`] when the budget overflows. An evicted
//! buffer's remaining consumer edges become spills — or recomputes
//! under [`DtrRecompute`] when the producer is a residual add.
//!
//! ## Elision semantics
//!
//! Eliding never changes what a program computes: the exec core still
//! performs every load and store functionally, and only redirects the
//! byte counters (`dma_bytes_elided`, `resident_tile_hits`) and gives
//! tsim zero-occupancy DMA for elided transfers. Functional digests
//! are therefore identical with residency on or off *by construction*.

use super::graph::{Graph, Op};
use super::layout::Shape;
use super::tps;
use crate::config::{ConfigError, VtaConfig};
use std::collections::VecDeque;

/// Which residency heuristic drives the plan (CLI `--residency`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ResidencyMode {
    /// Every edge spills through DRAM — the pre-planner behavior.
    Off,
    /// Least-recently-used eviction over the static execution order.
    #[default]
    Lru,
    /// Belady's offline-optimal eviction (furthest next use on the
    /// known static trace), clamped to never spill more than LRU.
    Belady,
    /// LRU eviction, but evicted residual-add outputs are recomputed
    /// at their consumers instead of spilled (DTR-style).
    Dtr,
}

impl ResidencyMode {
    /// CLI / cache-key token.
    pub fn cli_name(self) -> &'static str {
        match self {
            ResidencyMode::Off => "off",
            ResidencyMode::Lru => "lru",
            ResidencyMode::Belady => "belady",
            ResidencyMode::Dtr => "dtr",
        }
    }

    pub fn parse(s: &str) -> Option<ResidencyMode> {
        match s {
            "off" => Some(ResidencyMode::Off),
            "lru" => Some(ResidencyMode::Lru),
            "belady" => Some(ResidencyMode::Belady),
            "dtr" => Some(ResidencyMode::Dtr),
            _ => None,
        }
    }
}

/// How one producer→consumer edge is served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeDecision {
    /// Through DRAM: producer stores, consumer loads (the default).
    Spill,
    /// The producer's output is still hot; the consumer's load is
    /// elided.
    Resident,
    /// The producer is re-run right before the consumer; the rerun
    /// leaves the output hot and the consumer's load is elided.
    Recompute,
}

/// Residency decisions for one graph node.
#[derive(Debug, Clone)]
pub struct NodePlan {
    /// Per input slot: is the consumer's load of that input elided?
    /// (true for both `Resident` and `Recompute` edges).
    pub resident_inputs: Vec<bool>,
    /// Is this node's own store traffic elided? Only when *every*
    /// consumer takes the output hot and the node is not the graph
    /// output.
    pub output_elided: bool,
    /// Producer node indices to re-run immediately before this node
    /// (DTR rematerialization).
    pub recompute: Vec<usize>,
}

impl NodePlan {
    fn empty(n_inputs: usize) -> NodePlan {
        NodePlan { resident_inputs: vec![false; n_inputs], output_elided: false, recompute: vec![] }
    }

    /// The residency bits folded into this layer's memo signature:
    /// bit0 = input 0 hot, bit1 = input 1 hot (residual adds), bit2 =
    /// output elided. A layer lowered against hot inputs is a
    /// different program identity than a cold one.
    pub fn sig_bits(&self) -> u8 {
        let mut b = 0u8;
        if self.resident_inputs.first() == Some(&true) {
            b |= 1;
        }
        if self.resident_inputs.get(1) == Some(&true) {
            b |= 2;
        }
        if self.output_elided {
            b |= 4;
        }
        b
    }

    /// The edge decision for one input slot whose producer is node
    /// `producer`.
    pub fn edge(&self, slot: usize, producer: usize) -> EdgeDecision {
        match self.resident_inputs.get(slot) {
            Some(&true) if self.recompute.contains(&producer) => EdgeDecision::Recompute,
            Some(&true) => EdgeDecision::Resident,
            _ => EdgeDecision::Spill,
        }
    }
}

/// Residency bits of a DTR rerun: inputs cold (re-loaded from DRAM),
/// output elided (left hot for the consumer).
pub const RECOMPUTE_SIG_BITS: u8 = 0b100;

/// The full cross-layer plan.
#[derive(Debug, Clone)]
pub struct ResidencyPlan {
    pub mode: ResidencyMode,
    /// One entry per graph node (index-aligned with `graph.nodes`).
    pub nodes: Vec<NodePlan>,
    /// Planner's estimate of DMA bytes elided (hot edges + elided
    /// stores).
    pub elided_bytes: u64,
    /// Planner's estimate of bytes still spilled on *eligible* edges
    /// (plus write-through stores of partially-hot outputs). The
    /// Belady ≤ LRU property is stated over this metric.
    pub spilled_bytes: u64,
}

impl ResidencyPlan {
    /// The all-spill plan (`--residency off`, and the plan every
    /// pre-residency memo entry is implicitly keyed under: its sig
    /// bits are 0 everywhere).
    pub fn off(graph: &Graph) -> ResidencyPlan {
        ResidencyPlan {
            mode: ResidencyMode::Off,
            nodes: graph.nodes.iter().map(|n| NodePlan::empty(n.inputs.len())).collect(),
            elided_bytes: 0,
            spilled_bytes: 0,
        }
    }

    pub fn sig_bits(&self, node: usize) -> u8 {
        self.nodes[node].sig_bits()
    }

    /// Producers rematerialized anywhere in the plan.
    pub fn recomputed_producers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.nodes.iter().flat_map(|n| n.recompute.iter().copied()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Snapshot of one resident buffer, as seen by a heuristic when an
/// eviction is needed.
#[derive(Debug, Clone, Copy)]
pub struct BufferState {
    /// Producer node index.
    pub node: usize,
    pub bytes: u64,
    /// Execution position of the most recent access.
    pub last_use: usize,
    /// Execution position of the next access on the static trace
    /// (`None` once dead).
    pub next_use: Option<usize>,
}

/// Eviction policy: given the resident buffers, pick the victim. The
/// planner owns all bookkeeping; heuristics are pure victim selectors
/// plus the spill-vs-recompute choice.
pub trait ResidencyHeuristic {
    fn name(&self) -> &'static str;

    /// Index into `resident` of the buffer to evict. `resident` is
    /// never empty.
    fn victim(&self, resident: &[BufferState]) -> usize;

    /// Whether an evicted *recomputable* producer's remaining uses
    /// become `Recompute` instead of `Spill`.
    fn recompute_on_evict(&self) -> bool {
        false
    }
}

/// Least-recently-used.
pub struct Lru;

impl ResidencyHeuristic for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim(&self, resident: &[BufferState]) -> usize {
        resident
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.last_use)
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Belady's offline-optimal policy: evict the buffer whose next use is
/// furthest in the future. The execution order is static, so the full
/// access trace is known at plan time.
pub struct BeladyOnTrace;

impl ResidencyHeuristic for BeladyOnTrace {
    fn name(&self) -> &'static str {
        "belady"
    }

    fn victim(&self, resident: &[BufferState]) -> usize {
        resident
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| b.next_use.unwrap_or(usize::MAX))
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// DTR-style: LRU eviction order, but evicted cheap producers are
/// rematerialized at their consumers instead of spilled.
pub struct DtrRecompute;

impl ResidencyHeuristic for DtrRecompute {
    fn name(&self) -> &'static str {
        "dtr"
    }

    fn victim(&self, resident: &[BufferState]) -> usize {
        Lru.victim(resident)
    }

    fn recompute_on_evict(&self) -> bool {
        true
    }
}

/// Is node `i` executed on VTA? (Ineligible nodes — the input
/// placeholder and channel-light CPU-fallback convolutions — can
/// neither keep an output hot nor take an input hot.)
pub fn on_vta(cfg: &VtaConfig, graph: &Graph, shapes: &[Shape], i: usize) -> bool {
    match &graph.nodes[i].op {
        Op::Input => false,
        Op::Conv { .. } => shapes[graph.nodes[i].inputs[0]].c >= cfg.block_in,
        Op::Dense { .. }
        | Op::Depthwise { .. }
        | Op::MaxPool { .. }
        | Op::GlobalAvgPool
        | Op::Add { .. } => true,
        // Attention / LSTM operators stay out of the residency plan for
        // now: several run on the host (or split per head into staged
        // sub-launches), so their operands must hit DRAM. Conservative —
        // stores around them are simply never elided.
        Op::AttnScores { .. }
        | Op::SoftmaxApprox { .. }
        | Op::HeadTranspose { .. }
        | Op::AttnMix { .. }
        | Op::LayerNormApprox
        | Op::ChanSlice { .. }
        | Op::EltMul { .. }
        | Op::HardSigmoid
        | Op::HardTanh => false,
    }
}

/// Only residual adds are recomputable: they are cheap (one ALU pass,
/// no GEMM) and carry no weights. Weight-bearing producers (conv,
/// dense, depthwise) are never rematerialized — a rerun would re-DMA
/// the whole weight tensor, defeating the point.
pub fn recomputable(graph: &Graph, i: usize) -> bool {
    matches!(graph.nodes[i].op, Op::Add { .. })
}

/// Compute the residency plan. Pure: depends only on the arguments, so
/// every layer of the stack (runtime, memo, analytical model) derives
/// an identical plan. `use_tps` / `dbuf_reuse` must match the session's
/// tiling policy — the conv working set depends on the tiling.
///
/// Errors with [`ConfigError::Infeasible`] when a convolution has no
/// feasible tiling on `cfg` (surfaced instead of panicking so sweeps
/// can report the config as infeasible rather than dropping it).
pub fn plan(
    cfg: &VtaConfig,
    graph: &Graph,
    shapes: &[Shape],
    mode: ResidencyMode,
    use_tps: bool,
    dbuf_reuse: bool,
) -> Result<ResidencyPlan, ConfigError> {
    match mode {
        ResidencyMode::Off => {
            // Still surface infeasible tilings (the walk is what checks
            // them elsewhere), so `off` and `lru` reject the same
            // configs.
            check_feasible(cfg, graph, shapes, use_tps, dbuf_reuse)?;
            Ok(ResidencyPlan::off(graph))
        }
        ResidencyMode::Lru => walk(cfg, graph, shapes, &Lru, mode, use_tps, dbuf_reuse),
        ResidencyMode::Dtr => walk(cfg, graph, shapes, &DtrRecompute, mode, use_tps, dbuf_reuse),
        ResidencyMode::Belady => {
            // Belady is optimal for unit-size buffers; with
            // variable-size activations the greedy walk can lose to
            // LRU, so clamp: return whichever plan spills less. This
            // makes "Belady never spills more than LRU" a structural
            // guarantee, not an empirical one.
            let b = walk(cfg, graph, shapes, &BeladyOnTrace, mode, use_tps, dbuf_reuse)?;
            let l = walk(cfg, graph, shapes, &Lru, mode, use_tps, dbuf_reuse)?;
            Ok(if b.spilled_bytes <= l.spilled_bytes { b } else { ResidencyPlan { mode, ..l } })
        }
    }
}

fn check_feasible(
    cfg: &VtaConfig,
    graph: &Graph,
    shapes: &[Shape],
    use_tps: bool,
    dbuf_reuse: bool,
) -> Result<(), ConfigError> {
    for i in 1..graph.nodes.len() {
        if matches!(graph.nodes[i].op, Op::Conv { .. } | Op::Dense { .. })
            && on_vta(cfg, graph, shapes, i)
        {
            let spec = graph.conv_spec(i, shapes);
            tps::select_tiling(&spec, cfg, use_tps, dbuf_reuse)?;
        }
    }
    Ok(())
}

/// One resident buffer in the capacity walk.
struct ResidentBuf {
    node: usize,
    bytes: u64,
    last_use: usize,
    /// Remaining eligible consumer positions, ascending.
    future: VecDeque<usize>,
}

#[allow(clippy::too_many_arguments)]
fn walk(
    cfg: &VtaConfig,
    graph: &Graph,
    shapes: &[Shape],
    h: &dyn ResidencyHeuristic,
    mode: ResidencyMode,
    use_tps: bool,
    dbuf_reuse: bool,
) -> Result<ResidencyPlan, ConfigError> {
    let n = graph.nodes.len();
    let block = cfg.block_in;
    let tile_bytes = cfg.inp_tile_bytes() as u64;
    let cap = cfg.inp_depth as u64 * tile_bytes;
    // Activation footprint, matching `Session::alloc_activation`.
    let bytes = |i: usize| shapes[i].tiles(block) as u64 * tile_bytes;
    let vta: Vec<bool> = (0..n).map(|i| on_vta(cfg, graph, shapes, i)).collect();
    let mut uses: Vec<Vec<usize>> = vec![vec![]; n];
    for (i, node) in graph.nodes.iter().enumerate() {
        for &p in &node.inputs {
            uses[p].push(i);
        }
    }
    let mut nodes: Vec<NodePlan> =
        graph.nodes.iter().map(|nd| NodePlan::empty(nd.inputs.len())).collect();

    let mut set: Vec<ResidentBuf> = Vec::new();
    let mut resident_total = 0u64;

    // Evict until `resident_total <= limit`, preferring victims that do
    // not feed the current position. An evicted recomputable producer's
    // strictly-later uses become recomputes under a DTR heuristic;
    // uses at the current position (we are evicting to make room for
    // it) always spill.
    let mut evict_to = |set: &mut Vec<ResidentBuf>,
                        resident_total: &mut u64,
                        nodes: &mut Vec<NodePlan>,
                        limit: u64,
                        now: usize,
                        exclude: &[usize]| {
        while *resident_total > limit && !set.is_empty() {
            let mut pool: Vec<usize> =
                (0..set.len()).filter(|&i| !exclude.contains(&set[i].node)).collect();
            if pool.is_empty() {
                pool = (0..set.len()).collect();
            }
            let states: Vec<BufferState> = pool
                .iter()
                .map(|&i| BufferState {
                    node: set[i].node,
                    bytes: set[i].bytes,
                    last_use: set[i].last_use,
                    next_use: set[i].future.front().copied(),
                })
                .collect();
            let victim = pool[h.victim(&states)];
            let buf = set.remove(victim);
            *resident_total -= buf.bytes;
            if h.recompute_on_evict() && recomputable(graph, buf.node) {
                for &c in buf.future.iter().filter(|&&c| c > now) {
                    for (slot, &p) in graph.nodes[c].inputs.iter().enumerate() {
                        if p == buf.node {
                            nodes[c].resident_inputs[slot] = true;
                        }
                    }
                    if !nodes[c].recompute.contains(&buf.node) {
                        nodes[c].recompute.push(buf.node);
                    }
                }
            }
            // Non-recompute remaining uses stay Spill (the default).
        }
    };

    for t in 1..n {
        // The executing layer's own scratchpad working set, plus the
        // footprint of any rematerializations scheduled before it.
        let w = match &graph.nodes[t].op {
            Op::Conv { .. } | Op::Dense { .. } if vta[t] => {
                let spec = graph.conv_spec(t, shapes);
                let tiling = tps::select_tiling(&spec, cfg, use_tps, dbuf_reuse)?;
                let g = tiling.geom(&spec, cfg);
                (tiling.inp_slots() * g.inp_block_tiles) as u64 * tile_bytes
            }
            // Element-wise / pooling layers stream through the
            // accumulator scratchpad; CPU-fallback layers use none.
            _ => 0,
        };
        let w_recompute: u64 = nodes[t].recompute.iter().map(|&p| bytes(p)).sum();
        let budget = cap.saturating_sub(w + w_recompute);
        evict_to(&mut set, &mut resident_total, &mut nodes, budget, t, &graph.nodes[t].inputs);

        // Classify this node's input edges against the surviving set.
        for (slot, &p) in graph.nodes[t].inputs.iter().enumerate() {
            if !(vta[p] && vta[t]) {
                continue; // ineligible edge: always a spill
            }
            if let Some(pos) = set.iter().position(|r| r.node == p) {
                nodes[t].resident_inputs[slot] = true;
                set[pos].last_use = t;
                while set[pos].future.front() == Some(&t) {
                    set[pos].future.pop_front();
                }
            }
        }
        // Drop buffers with no remaining uses.
        set.retain(|r| {
            if r.future.is_empty() {
                resident_total -= r.bytes;
                false
            } else {
                true
            }
        });

        // Try to keep this node's own output hot (never the graph
        // output — the host reads it from DRAM).
        if vta[t] && t != n - 1 {
            let future: VecDeque<usize> =
                uses[t].iter().copied().filter(|&c| vta[c]).collect();
            if !future.is_empty() {
                let b = bytes(t);
                if b <= cap {
                    evict_to(&mut set, &mut resident_total, &mut nodes, cap - b, t, &[]);
                    resident_total += b;
                    set.push(ResidentBuf { node: t, bytes: b, last_use: t, future });
                } else if h.recompute_on_evict() && recomputable(graph, t) {
                    // Too big to ever be resident: rematerialize at
                    // each consumer instead.
                    for &c in &uses[t] {
                        if !vta[c] {
                            continue;
                        }
                        for (slot, &p) in graph.nodes[c].inputs.iter().enumerate() {
                            if p == t {
                                nodes[c].resident_inputs[slot] = true;
                            }
                        }
                        if !nodes[c].recompute.contains(&t) {
                            nodes[c].recompute.push(t);
                        }
                    }
                }
            }
        }
    }

    // Finalize store elision and the byte metrics.
    let mut elided = 0u64;
    let mut spilled = 0u64;
    for p in 1..n {
        if !vta[p] || uses[p].is_empty() || p == n - 1 {
            continue;
        }
        let has_eligible_edge = uses[p].iter().any(|&c| vta[c]);
        if !has_eligible_edge {
            continue;
        }
        let all_hot = uses[p].iter().all(|&c| {
            vta[c]
                && graph.nodes[c]
                    .inputs
                    .iter()
                    .enumerate()
                    .filter(|&(_, &q)| q == p)
                    .all(|(slot, _)| nodes[c].resident_inputs[slot])
        });
        nodes[p].output_elided = all_hot;
        if all_hot {
            elided += bytes(p);
        } else {
            spilled += bytes(p); // write-through store still paid
        }
    }
    for (c, node) in graph.nodes.iter().enumerate() {
        for (slot, &p) in node.inputs.iter().enumerate() {
            if !(vta[p] && vta[c]) {
                continue;
            }
            if nodes[c].resident_inputs[slot] {
                elided += bytes(p);
            } else {
                spilled += bytes(p);
            }
        }
    }

    Ok(ResidencyPlan { mode, nodes, elided_bytes: elided, spilled_bytes: spilled })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::graph::rand_weights;
    use crate::config::presets;
    use crate::util::rng::Pcg32;
    use crate::workloads;

    fn plan_for(g: &Graph, cfg: &VtaConfig, mode: ResidencyMode) -> ResidencyPlan {
        plan(cfg, g, &g.shapes(), mode, true, true).unwrap()
    }

    /// conv → conv chain, one tile wide: trivially fits on tiny.
    fn chain(block: usize) -> Graph {
        let mut rng = Pcg32::seeded(7);
        let mut g = Graph::new("chain", Shape::new(block, 8, 8));
        let c1 = g.add(
            "c1",
            Op::Conv {
                c_out: block,
                k: 3,
                stride: 1,
                pad: 1,
                shift: 4,
                relu: true,
                weights: rand_weights(&mut rng, block * block * 9),
            },
            vec![0],
        );
        let c2 = g.add(
            "c2",
            Op::Conv {
                c_out: block,
                k: 3,
                stride: 1,
                pad: 1,
                shift: 4,
                relu: true,
                weights: rand_weights(&mut rng, block * block * 9),
            },
            vec![c1],
        );
        g.add("add", Op::Add { relu: false }, vec![c2, c1]);
        g
    }

    #[test]
    fn off_mode_elides_nothing() {
        let cfg = presets::tiny_config();
        let g = chain(cfg.block_in);
        let p = plan_for(&g, &cfg, ResidencyMode::Off);
        assert_eq!(p.elided_bytes, 0);
        assert!(p.nodes.iter().all(|n| n.sig_bits() == 0 && n.recompute.is_empty()));
    }

    #[test]
    fn lru_keeps_chain_hot_when_it_fits() {
        let cfg = presets::tiny_config();
        let g = chain(cfg.block_in);
        let p = plan_for(&g, &cfg, ResidencyMode::Lru);
        // c1 feeds c2 and add; c2 feeds add. Everything fits → all hot.
        assert!(p.nodes[2].resident_inputs[0], "c1→c2 should be resident");
        assert!(p.nodes[3].resident_inputs.iter().all(|&b| b), "both add inputs hot");
        assert!(p.nodes[1].output_elided && p.nodes[2].output_elided);
        assert!(!p.nodes[3].output_elided, "graph output is host-read");
        assert!(p.elided_bytes > 0);
    }

    #[test]
    fn input_placeholder_and_cpu_convs_are_never_hot() {
        let cfg = presets::default_config();
        let g = workloads::micro_resnet(cfg.block_in, 1);
        let shapes = g.shapes();
        let p = plan_for(&g, &cfg, ResidencyMode::Lru);
        for (i, node) in g.nodes.iter().enumerate() {
            for (slot, &src) in node.inputs.iter().enumerate() {
                if !on_vta(&cfg, &g, &shapes, src) {
                    assert!(
                        !p.nodes[i].resident_inputs[slot],
                        "edge {}→{} from ineligible producer marked hot",
                        g.nodes[src].name, node.name
                    );
                }
            }
        }
        // conv1 (3 input channels) is the CPU fallback.
        assert!(!on_vta(&cfg, &g, &shapes, 1));
        assert!(!p.nodes[1].output_elided);
    }

    #[test]
    fn capacity_pressure_forces_spills() {
        // Shrink the input scratchpad until residency is impossible:
        // the plan must degrade to spills, never overcommit.
        let mut cfg = presets::tiny_config();
        let g = chain(cfg.block_in);
        let shapes = g.shapes();
        let full = plan(&cfg, &g, &shapes, ResidencyMode::Lru, true, true).unwrap();
        assert!(full.spilled_bytes == 0 || full.elided_bytes > 0);
        cfg.inp_depth = 64; // 8x8 activation = 64 tiles: one buffer max
        let tight = plan(&cfg, &g, &shapes, ResidencyMode::Lru, true, true).unwrap();
        assert!(tight.spilled_bytes > 0, "tight config must spill");
        assert!(tight.spilled_bytes > full.spilled_bytes);
    }

    #[test]
    fn dtr_recomputes_only_adds() {
        for g in [
            workloads::micro_resnet(16, 1),
            workloads::micro_mobilenet(16, 1),
            workloads::resnet(18, 32, 1),
        ] {
            let mut cfg = presets::default_config();
            cfg.inp_depth = 64; // force evictions
            let p = plan_for(&g, &cfg, ResidencyMode::Dtr);
            for q in p.recomputed_producers() {
                assert!(
                    recomputable(&g, q),
                    "{}: recompute of weight-bearing node {}",
                    g.name, g.nodes[q].name
                );
            }
        }
    }

    #[test]
    fn belady_never_spills_more_than_lru() {
        let cfg = presets::default_config();
        let g = workloads::micro_resnet(cfg.block_in, 1);
        let shapes = g.shapes();
        for depth in [64usize, 128, 256, 2048] {
            let mut c = cfg.clone();
            c.inp_depth = depth;
            let b = plan(&c, &g, &shapes, ResidencyMode::Belady, true, true).unwrap();
            let l = plan(&c, &g, &shapes, ResidencyMode::Lru, true, true).unwrap();
            assert!(
                b.spilled_bytes <= l.spilled_bytes,
                "depth {depth}: belady {} > lru {}",
                b.spilled_bytes,
                l.spilled_bytes
            );
        }
    }

    #[test]
    fn plan_is_deterministic() {
        let cfg = presets::default_config();
        let g = workloads::micro_resnet(cfg.block_in, 1);
        let shapes = g.shapes();
        for mode in [ResidencyMode::Lru, ResidencyMode::Belady, ResidencyMode::Dtr] {
            let a = plan(&cfg, &g, &shapes, mode, true, true).unwrap();
            let b = plan(&cfg, &g, &shapes, mode, true, true).unwrap();
            assert_eq!(a.elided_bytes, b.elided_bytes);
            assert_eq!(a.spilled_bytes, b.spilled_bytes);
            for (x, y) in a.nodes.iter().zip(&b.nodes) {
                assert_eq!(x.sig_bits(), y.sig_bits());
                assert_eq!(x.recompute, y.recompute);
            }
        }
    }

    #[test]
    fn infeasible_config_is_a_typed_error() {
        let mut cfg = presets::tiny_config();
        cfg.inp_depth = 1;
        cfg.wgt_depth = 1;
        cfg.acc_depth = 1;
        let g = chain(cfg.block_in);
        let err = plan(&cfg, &g, &g.shapes(), ResidencyMode::Lru, true, true).unwrap_err();
        assert!(matches!(err, ConfigError::Infeasible { .. }), "got {err:?}");
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [ResidencyMode::Off, ResidencyMode::Lru, ResidencyMode::Belady, ResidencyMode::Dtr]
        {
            assert_eq!(ResidencyMode::parse(m.cli_name()), Some(m));
        }
        assert_eq!(ResidencyMode::parse("belody"), None);
        assert_eq!(ResidencyMode::default(), ResidencyMode::Lru);
    }
}
