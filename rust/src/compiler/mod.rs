//! The compiler stack (§II-C, §IV-D): graph IR, schedules for every layer
//! kind, Tiling Parameter Search, virtual-thread double buffering with
//! redundant-load elimination, and the packet/dependency machinery that
//! realizes TVM's decoupled access-execute lowering on this ISA.

pub mod builder;
pub mod conv;
pub mod cpu_ref;
pub mod depthwise;
pub mod eltwise;
pub mod graph;
pub mod layout;
pub mod packet;
pub mod residency;
pub mod tps;
