//! ALU-only layer schedules: max/average pooling, residual addition —
//! the layers the paper newly enabled on the accelerator (§IV-E: "We
//! created VTA schedules for average and max pooling layers by utilizing
//! the ALU unit"), so full ResNets run "from the 2nd convolution layer
//! ... to the final fully-connected layer".
//!
//! All of these flow int8 activations through the 8-bit accumulator view
//! (`Acc8` loads, executed by the compute module like upstream VTA's ACC
//! loads), compute on the ALU, and store from the OUT scratchpad. Max
//! pooling exploits the new pad-value LOAD feature (-128 borders).

use super::builder::ProgramBuilder;
use super::packet::{PMod, Packet, Region};
use crate::isa::{AluInsn, AluOp, BufferId, DepFlags, GemmInsn, Insn, MemInsn, Opcode, Uop};

/// 2-D pooling descriptor over a `[c][h][w]`-tiled activation (channel
/// tiles of the configured BLOCK).
#[derive(Debug, Clone, Copy)]
pub struct PoolParams {
    /// Channel tiles.
    pub c_tiles: usize,
    pub h: usize,
    pub w: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// true = max pooling; false = sum + shift (average).
    pub is_max: bool,
    /// Shift applied to the sum for average pooling (0 for max).
    pub shift: u32,
}

impl PoolParams {
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.k) / self.stride + 1
    }
}

/// Lower a pooling layer. Processes one channel tile × a chunk of output
/// rows per iteration, double buffered across iterations.
pub fn lower_pool(b: &mut ProgramBuilder, p: &PoolParams, inp_base: u32, out_base: u32) {
    let cfg = b.cfg.clone();
    let (oh, ow) = (p.oh(), p.ow());
    let iw_c = (ow - 1) * p.stride + p.k;
    // Choose the output-row chunk so in+out blocks double buffer in acc.
    let mut oh_c = oh;
    loop {
        let ih_c = (oh_c - 1) * p.stride + p.k;
        let block = ih_c * iw_c + oh_c * ow;
        if 2 * block <= cfg.acc_depth || oh_c == 1 {
            break;
        }
        oh_c = oh_c.div_ceil(2);
    }
    let ih_c_max = (oh_c - 1) * p.stride + p.k;
    let slot_tiles = (ih_c_max * iw_c + oh_c * ow) as u32;
    let pad_value = if p.is_max { -128 } else { 0 };
    let mut iter = 0u32;

    for ct in 0..p.c_tiles {
        let mut oy0 = 0;
        while oy0 < oh {
            let rows = oh_c.min(oh - oy0);
            let ih_c = (rows - 1) * p.stride + p.k;
            let slot = (iter % 2) * slot_tiles;
            iter += 1;
            let in_b = slot;
            let out_b = slot + (ih_c_max * iw_c) as u32;

            // ---- load the input rows (Acc8, with pad fill) ----
            // The block covers global rows [y_start, y_start+ih_c) and
            // cols [-pad, -pad+iw_c); out-of-image tiles become pad fill.
            let y_start = (oy0 * p.stride) as i64 - p.pad as i64;
            let y_pad0 = (-y_start).max(0) as u32;
            let y_pad1 = ((y_start + ih_c as i64) - p.h as i64).max(0) as u32;
            let x_start = -(p.pad as i64);
            let x_pad0 = (-x_start).max(0) as u32;
            let x_pad1 = ((x_start + iw_c as i64) - p.w as i64).max(0) as u32;
            let load = Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: in_b,
                dram_base: inp_base
                    + ((ct * p.h) as i64 + y_start + y_pad0 as i64) as u32 * p.w as u32,
                y_size: ih_c as u32 - y_pad0 - y_pad1,
                x_size: iw_c as u32 - x_pad0 - x_pad1,
                x_stride: p.w as u32,
                y_pad0,
                y_pad1,
                x_pad0,
                x_pad1,
                pad_value,
            });
            b.push(
                Packet::new(PMod::Compute, vec![load]).write(Region::new(
                    BufferId::Acc,
                    in_b,
                    in_b + (ih_c * iw_c) as u32,
                )),
            );

            // ---- reduce over the window taps ----
            let mut insns = Vec::new();
            if !p.is_max {
                // Zero the output block, then accumulate all taps.
                let seq: Vec<Uop> =
                    (0..ow as u32).map(|x| Uop::alu(out_b + x, out_b + x)).collect();
                let (bgn, end) = b.uop_seq(seq);
                insns.push(Insn::Gemm(GemmInsn {
                    deps: DepFlags::NONE,
                    reset: true,
                    uop_bgn: bgn,
                    uop_end: end,
                    lp_out: rows as u32,
                    lp_in: 1,
                    acc_f0: ow as u32,
                    acc_f1: 0,
                    inp_f0: 0,
                    inp_f1: 0,
                    wgt_f0: 0,
                    wgt_f1: 0,
                }));
            }
            for ky in 0..p.k {
                for kx in 0..p.k {
                    let op = if p.is_max {
                        if ky == 0 && kx == 0 {
                            AluOp::Mov
                        } else {
                            AluOp::Max
                        }
                    } else {
                        AluOp::Add
                    };
                    let seq: Vec<Uop> = (0..ow)
                        .map(|x| {
                            Uop::alu(
                                out_b + x as u32,
                                in_b + (ky * iw_c + x * p.stride + kx) as u32,
                            )
                        })
                        .collect();
                    let (bgn, end) = b.uop_seq(seq);
                    insns.push(Insn::Alu(AluInsn {
                        deps: DepFlags::NONE,
                        reset: false,
                        op,
                        uop_bgn: bgn,
                        uop_end: end,
                        lp_out: rows as u32,
                        lp_in: 1,
                        dst_f0: ow as u32,
                        dst_f1: 0,
                        src_f0: (p.stride * iw_c) as u32,
                        src_f1: 0,
                        use_imm: false,
                        imm: 0,
                    }));
                }
            }
            // Average pooling: rounding shift.
            if !p.is_max && p.shift > 0 {
                let seq: Vec<Uop> =
                    (0..ow as u32).map(|x| Uop::alu(out_b + x, out_b + x)).collect();
                let (bgn, end) = b.uop_seq(seq);
                let imm_alu = |op: AluOp, imm: i32| {
                    Insn::Alu(AluInsn {
                        deps: DepFlags::NONE,
                        reset: false,
                        op,
                        uop_bgn: bgn,
                        uop_end: end,
                        lp_out: rows as u32,
                        lp_in: 1,
                        dst_f0: ow as u32,
                        dst_f1: 0,
                        src_f0: ow as u32,
                        src_f1: 0,
                        use_imm: true,
                        imm,
                    })
                };
                insns.push(imm_alu(AluOp::Add, 1 << (p.shift - 1)));
                insns.push(imm_alu(AluOp::Shr, p.shift as i32));
                insns.push(imm_alu(AluOp::Clip, 127));
            }
            let out_tiles = (rows * ow) as u32;
            b.push(
                Packet::new(PMod::Compute, insns)
                    .read(Region::new(BufferId::Acc, in_b, in_b + (ih_c * iw_c) as u32))
                    .write(Region::new(BufferId::Acc, out_b, out_b + out_tiles))
                    .write(Region::new(BufferId::Out, out_b, out_b + out_tiles)),
            );

            // ---- store ----
            let store = Insn::Mem(MemInsn {
                opcode: Opcode::Store,
                deps: DepFlags::NONE,
                buffer: BufferId::Out,
                sram_base: out_b,
                dram_base: out_base + ((ct * oh + oy0) * ow) as u32,
                y_size: rows as u32,
                x_size: ow as u32,
                x_stride: ow as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            });
            b.push(
                Packet::new(PMod::Store, vec![store])
                    .read(Region::new(BufferId::Out, out_b, out_b + out_tiles)),
            );
            oy0 += rows;
        }
    }
}

/// Shift-based softmax approximation along the spatial `h` axis of one
/// `[c_tiles][h][w]`-tiled activation, lane-wise per channel: per
/// (lane, `w` column) `m = max_y x`, `t = min(31, (m - x) >> shift)`,
/// `out = 127 >> t`. One channel tile per iteration, three scratchpad
/// regions (input, running max, output) staged simultaneously — the
/// caller guarantees `2*h*w + w <= acc_depth` and `h` fits one ALU
/// loop (see `graph::softmax_on_vta`). Single-slot (no double
/// buffering): the reduction makes the whole tile one dependency chain
/// anyway.
pub fn lower_softmax(
    b: &mut ProgramBuilder,
    c_tiles: usize,
    h: usize,
    w: usize,
    shift: u32,
    inp_base: u32,
    out_base: u32,
) {
    let hw = (h * w) as u32;
    let in_b = 0u32;
    let m_b = hw;
    let out_b = hw + w as u32;
    let span = out_b + hw; // whole staged region, for packet deps
    for ct in 0..c_tiles as u32 {
        let load = Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer: BufferId::Acc8,
            sram_base: in_b,
            dram_base: inp_base + ct * hw,
            y_size: 1,
            x_size: hw,
            x_stride: hw,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Compute, vec![load])
                .write(Region::new(BufferId::Acc, in_b, in_b + hw)),
        );

        let cols = |base: u32, src: u32| -> Vec<Uop> {
            (0..w as u32).map(|x| Uop::alu(base + x, src + x)).collect()
        };
        let alu = |op: AluOp, (bgn, end): (u32, u32), lp_out: u32, dst_f0: u32, src_f0: u32,
                   use_imm: bool, imm: i32| {
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE,
                reset: false,
                op,
                uop_bgn: bgn,
                uop_end: end,
                lp_out,
                lp_in: 1,
                dst_f0,
                dst_f1: 0,
                src_f0,
                src_f1: 0,
                use_imm,
                imm,
            })
        };
        let w32 = w as u32;
        let u_m0 = b.uop_seq(cols(m_b, in_b)); // m <- x row 0 (and Max rows)
        let u_x = b.uop_seq(cols(in_b, in_b)); // x in place (imm ops)
        let u_xm = b.uop_seq(cols(in_b, m_b)); // x <- x (+) m
        let u_o = b.uop_seq(cols(out_b, out_b)); // out in place (imm ops)
        let u_ox = b.uop_seq(cols(out_b, in_b)); // out <- out >> x
        let mut insns = vec![alu(AluOp::Mov, u_m0, 1, 0, 0, false, 0)];
        if h > 1 {
            // Reduce the remaining rows into the running max.
            let u_m = b.uop_seq(cols(m_b, in_b + w32));
            insns.push(alu(AluOp::Max, u_m, h as u32 - 1, 0, w32, false, 0));
        }
        insns.push(alu(AluOp::Mul, u_x, h as u32, w32, w32, true, -1)); // x = -x (exact in acc)
        insns.push(alu(AluOp::Add, u_xm, h as u32, w32, 0, false, 0)); // x = m - x >= 0
        insns.push(alu(AluOp::Shr, u_x, h as u32, w32, w32, true, shift as i32));
        insns.push(alu(AluOp::Min, u_x, h as u32, w32, w32, true, 31)); // Shr masks src & 31
        insns.push(alu(AluOp::Mov, u_o, h as u32, w32, w32, true, 127));
        insns.push(alu(AluOp::Shr, u_ox, h as u32, w32, w32, false, 0)); // out = 127 >> t
        b.push(
            Packet::new(PMod::Compute, insns)
                .read(Region::new(BufferId::Acc, in_b, m_b + w32))
                .write(Region::new(BufferId::Acc, in_b, span))
                .write(Region::new(BufferId::Out, in_b, span)),
        );

        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: out_b,
            dram_base: out_base + ct * hw,
            y_size: 1,
            x_size: hw,
            x_stride: hw,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Store, vec![store])
                .read(Region::new(BufferId::Out, out_b, out_b + hw)),
        );
    }
}

/// Elementwise requantized product of two identically-shaped tiled
/// activations: `out = requant(a*b, shift, relu)` — the paper's 8-bit
/// eltwise-multiply ISA increment. Same chunked double-buffered
/// schedule as [`lower_add`]; both operands arrive as int8 so the `Mul`
/// (which truncates its operands to int8) computes the exact product in
/// the int32 accumulator.
pub fn lower_eltmul(
    b: &mut ProgramBuilder,
    total_tiles: usize,
    a_base: u32,
    b_base: u32,
    out_base: u32,
    shift: u32,
    relu: bool,
) {
    let cfg = b.cfg.clone();
    let max_loop = (1usize << b.layout.loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1);
    let mut off = 0usize;
    let mut iter = 0u32;
    while off < total_tiles {
        let n = chunk.min(total_tiles - off);
        let slot = (iter % 2) * (2 * chunk) as u32;
        iter += 1;
        let a_slot = slot;
        let b_slot = slot + chunk as u32;

        let load = |sram: u32, dram: u32| {
            Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: sram,
                dram_base: dram,
                y_size: 1,
                x_size: n as u32,
                x_stride: n as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            })
        };
        b.push(
            Packet::new(
                PMod::Compute,
                vec![load(a_slot, a_base + off as u32), load(b_slot, b_base + off as u32)],
            )
            .write(Region::new(BufferId::Acc, a_slot, a_slot + n as u32))
            .write(Region::new(BufferId::Acc, b_slot, b_slot + n as u32)),
        );

        let (bgn, end) = b.uop_seq(vec![Uop::alu(a_slot, b_slot)]);
        let alu = |op: AluOp, use_imm: bool, imm: i32| {
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE,
                reset: false,
                op,
                uop_bgn: bgn,
                uop_end: end,
                lp_out: n as u32,
                lp_in: 1,
                dst_f0: 1,
                dst_f1: 0,
                src_f0: 1,
                src_f1: 0,
                use_imm,
                imm,
            })
        };
        let mut insns = vec![alu(AluOp::Mul, false, 0)];
        if shift > 0 {
            insns.push(alu(AluOp::Add, true, 1 << (shift - 1)));
            insns.push(alu(AluOp::Shr, true, shift as i32));
        }
        if relu {
            insns.push(alu(AluOp::Max, true, 0));
        }
        insns.push(alu(AluOp::Clip, true, 127));
        b.push(
            Packet::new(PMod::Compute, insns)
                .read(Region::new(BufferId::Acc, a_slot, b_slot + n as u32))
                .write(Region::new(BufferId::Acc, a_slot, a_slot + n as u32))
                .write(Region::new(BufferId::Out, a_slot, a_slot + n as u32)),
        );

        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: a_slot,
            dram_base: out_base + off as u32,
            y_size: 1,
            x_size: n as u32,
            x_stride: n as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Store, vec![store])
                .read(Region::new(BufferId::Out, a_slot, a_slot + n as u32)),
        );
        off += n;
    }
}

/// Elementwise clipped subtraction `out = clamp(x - mu, -127, 127)` —
/// the second stage of the layernorm approximation (`mu` is the
/// mean broadcast across channels by the all-ones GEMM stage). The
/// negation runs as `Mul imm -1` on `mu`, exact in the int32
/// accumulator because `mu` is already requantized to [-127, 127].
pub fn lower_sub(
    b: &mut ProgramBuilder,
    total_tiles: usize,
    x_base: u32,
    mu_base: u32,
    out_base: u32,
) {
    let cfg = b.cfg.clone();
    let max_loop = (1usize << b.layout.loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1);
    let mut off = 0usize;
    let mut iter = 0u32;
    while off < total_tiles {
        let n = chunk.min(total_tiles - off);
        let slot = (iter % 2) * (2 * chunk) as u32;
        iter += 1;
        let x_slot = slot;
        let mu_slot = slot + chunk as u32;

        let load = |sram: u32, dram: u32| {
            Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: sram,
                dram_base: dram,
                y_size: 1,
                x_size: n as u32,
                x_stride: n as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            })
        };
        b.push(
            Packet::new(
                PMod::Compute,
                vec![load(x_slot, x_base + off as u32), load(mu_slot, mu_base + off as u32)],
            )
            .write(Region::new(BufferId::Acc, x_slot, x_slot + n as u32))
            .write(Region::new(BufferId::Acc, mu_slot, mu_slot + n as u32)),
        );

        let (neg_bgn, neg_end) = b.uop_seq(vec![Uop::alu(mu_slot, mu_slot)]);
        let (bgn, end) = b.uop_seq(vec![Uop::alu(x_slot, mu_slot)]);
        let alu = |op: AluOp, (bgn, end): (u32, u32), use_imm: bool, imm: i32| {
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE,
                reset: false,
                op,
                uop_bgn: bgn,
                uop_end: end,
                lp_out: n as u32,
                lp_in: 1,
                dst_f0: 1,
                dst_f1: 0,
                src_f0: 1,
                src_f1: 0,
                use_imm,
                imm,
            })
        };
        let insns = vec![
            alu(AluOp::Mul, (neg_bgn, neg_end), true, -1),
            alu(AluOp::Add, (bgn, end), false, 0),
            alu(AluOp::Clip, (bgn, end), true, 127),
        ];
        b.push(
            Packet::new(PMod::Compute, insns)
                .read(Region::new(BufferId::Acc, x_slot, mu_slot + n as u32))
                .write(Region::new(BufferId::Acc, x_slot, mu_slot + n as u32))
                .write(Region::new(BufferId::Out, x_slot, mu_slot + n as u32)),
        );

        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: x_slot,
            dram_base: out_base + off as u32,
            y_size: 1,
            x_size: n as u32,
            x_stride: n as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Store, vec![store])
                .read(Region::new(BufferId::Out, x_slot, x_slot + n as u32)),
        );
        off += n;
    }
}

/// Pointwise immediate-only ALU pipeline over one tiled activation —
/// the hard-sigmoid / hard-tanh gate nonlinearities: load a chunk,
/// apply each `(op, imm)` in order, store. Chunked and double buffered
/// like [`lower_add`].
pub fn lower_unary(
    b: &mut ProgramBuilder,
    total_tiles: usize,
    inp_base: u32,
    out_base: u32,
    ops: &[(AluOp, i32)],
) {
    let cfg = b.cfg.clone();
    let max_loop = (1usize << b.layout.loop_bits) - 1;
    let chunk = (cfg.acc_depth / 2).min(total_tiles).min(max_loop).max(1);
    let mut off = 0usize;
    let mut iter = 0u32;
    while off < total_tiles {
        let n = chunk.min(total_tiles - off);
        let slot = (iter % 2) * chunk as u32;
        iter += 1;

        let load = Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer: BufferId::Acc8,
            sram_base: slot,
            dram_base: inp_base + off as u32,
            y_size: 1,
            x_size: n as u32,
            x_stride: n as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Compute, vec![load])
                .write(Region::new(BufferId::Acc, slot, slot + n as u32)),
        );

        let (bgn, end) = b.uop_seq(vec![Uop::alu(slot, slot)]);
        let insns: Vec<Insn> = ops
            .iter()
            .map(|&(op, imm)| {
                Insn::Alu(AluInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    op,
                    uop_bgn: bgn,
                    uop_end: end,
                    lp_out: n as u32,
                    lp_in: 1,
                    dst_f0: 1,
                    dst_f1: 0,
                    src_f0: 1,
                    src_f1: 0,
                    use_imm: true,
                    imm,
                })
            })
            .collect();
        b.push(
            Packet::new(PMod::Compute, insns)
                .read(Region::new(BufferId::Acc, slot, slot + n as u32))
                .write(Region::new(BufferId::Acc, slot, slot + n as u32))
                .write(Region::new(BufferId::Out, slot, slot + n as u32)),
        );

        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: slot,
            dram_base: out_base + off as u32,
            y_size: 1,
            x_size: n as u32,
            x_stride: n as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Store, vec![store])
                .read(Region::new(BufferId::Out, slot, slot + n as u32)),
        );
        off += n;
    }
}

/// The `(op, imm)` pipeline for the `HardSigmoid` graph op:
/// `clamp((x >> 1) + 32, 0, 96)`.
pub const HARD_SIGMOID_OPS: [(AluOp, i32); 4] =
    [(AluOp::Shr, 1), (AluOp::Add, 32), (AluOp::Max, 0), (AluOp::Min, 96)];

/// The `(op, imm)` pipeline for the `HardTanh` graph op:
/// `clamp(x, -64, 64)`.
pub const HARD_TANH_OPS: [(AluOp, i32); 1] = [(AluOp::Clip, 64)];

/// Residual addition over two identically-shaped tiled activations:
/// `out = clip(a + b)` with optional ReLU. Processes `chunk` tiles per
/// iteration, double buffered.
pub fn lower_add(
    b: &mut ProgramBuilder,
    total_tiles: usize,
    a_base: u32,
    b_base: u32,
    out_base: u32,
    relu: bool,
) {
    let cfg = b.cfg.clone();
    let max_loop = (1usize << b.layout.loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1);
    let mut off = 0usize;
    let mut iter = 0u32;
    while off < total_tiles {
        let n = chunk.min(total_tiles - off);
        let slot = (iter % 2) * (2 * chunk) as u32;
        iter += 1;
        let a_slot = slot;
        let b_slot = slot + chunk as u32;

        let load = |sram: u32, dram: u32| {
            Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: sram,
                dram_base: dram,
                y_size: 1,
                x_size: n as u32,
                x_stride: n as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            })
        };
        b.push(
            Packet::new(
                PMod::Compute,
                vec![load(a_slot, a_base + off as u32), load(b_slot, b_base + off as u32)],
            )
            .write(Region::new(BufferId::Acc, a_slot, a_slot + n as u32))
            .write(Region::new(BufferId::Acc, b_slot, b_slot + n as u32)),
        );

        // Single-uop ALU with lp_out walking the tiles: dst += src.
        let (bgn, end) = b.uop_seq(vec![Uop::alu(a_slot, b_slot)]);
        let alu = |op: AluOp, use_imm: bool, imm: i32| {
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE,
                reset: false,
                op,
                uop_bgn: bgn,
                uop_end: end,
                lp_out: n as u32,
                lp_in: 1,
                dst_f0: 1,
                dst_f1: 0,
                src_f0: 1,
                src_f1: 0,
                use_imm,
                imm,
            })
        };
        let mut insns = vec![alu(AluOp::Add, false, 0)];
        if relu {
            insns.push(alu(AluOp::Max, true, 0));
        }
        insns.push(alu(AluOp::Clip, true, 127));
        b.push(
            Packet::new(PMod::Compute, insns)
                .read(Region::new(BufferId::Acc, a_slot, b_slot + n as u32))
                .write(Region::new(BufferId::Acc, a_slot, a_slot + n as u32))
                .write(Region::new(BufferId::Out, a_slot, a_slot + n as u32)),
        );

        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: a_slot,
            dram_base: out_base + off as u32,
            y_size: 1,
            x_size: n as u32,
            x_stride: n as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        b.push(
            Packet::new(PMod::Store, vec![store])
                .read(Region::new(BufferId::Out, a_slot, a_slot + n as u32)),
        );
        off += n;
    }
}
