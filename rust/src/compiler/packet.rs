//! Packets and dependency-token insertion (§II-C).
//!
//! The compiler lowers each layer to an ordered list of [`Packet`]s — a
//! group of instructions destined for one hardware module, annotated with
//! the scratchpad regions it reads and writes. The paper's TVM stack does
//! the same thing implicitly ("The compiler manages this fine-grained
//! parallelism by analyzing subsequent load, compute and store nodes in
//! the IR to determine the local buffer addresses being used"): token
//! `push`/`pop` bits are inserted *only* where a true region conflict
//! exists between modules, which is exactly what makes double buffering
//! effective — a load into the idle half of a scratchpad carries no
//! dependency on the compute using the other half, so the two overlap.

use crate::isa::{BufferId, Insn};

/// Which execution module consumes a packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PMod {
    Load,
    Compute,
    Store,
}

/// A half-open scratchpad tile range `[lo, hi)` in one buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub buffer: BufferId,
    pub lo: u32,
    pub hi: u32,
}

impl Region {
    pub fn new(buffer: BufferId, lo: u32, hi: u32) -> Region {
        debug_assert!(lo <= hi);
        // Acc8 is an alias of the accumulator address space.
        let buffer = if buffer == BufferId::Acc8 { BufferId::Acc } else { buffer };
        Region { buffer, lo, hi }
    }

    pub fn overlaps(&self, other: &Region) -> bool {
        self.buffer == other.buffer && self.lo < other.hi && other.lo < self.hi
    }
}

#[derive(Debug, Clone)]
pub struct Packet {
    pub module: PMod,
    pub insns: Vec<Insn>,
    pub reads: Vec<Region>,
    pub writes: Vec<Region>,
}

impl Packet {
    pub fn new(module: PMod, insns: Vec<Insn>) -> Packet {
        Packet { module, insns, reads: Vec::new(), writes: Vec::new() }
    }

    pub fn read(mut self, r: Region) -> Packet {
        self.reads.push(r);
        self
    }

    pub fn write(mut self, r: Region) -> Packet {
        self.writes.push(r);
        self
    }

    /// RAW / WAR / WAW conflict with an earlier packet `self` -> `later`.
    pub fn conflicts_with(&self, later: &Packet) -> bool {
        // self.writes vs later.(reads|writes)
        for w in &self.writes {
            if later.reads.iter().chain(later.writes.iter()).any(|r| r.overlaps(w)) {
                return true;
            }
        }
        // self.reads vs later.writes (WAR)
        for r in &self.reads {
            if later.writes.iter().any(|w| w.overlaps(r)) {
                return true;
            }
        }
        false
    }
}

/// Insert dependency-token bits into the packet stream.
///
/// Per adjacent module pair we track established synchronization points
/// `(producer_idx, consumer_idx)`: because each module executes its
/// packets in order, a token from producer `p` popped by consumer `c`
/// orders *every* packet `<= p` on the producer module before every
/// packet `>= c` on the consumer module. New conflicts already implied by
/// an existing sync are skipped — this is what keeps the instruction
/// stream free of the extraneous bits the paper warns about ("Setting
/// extraneous dependency bits can result in longer cycle counts or even
/// deadlock").
pub fn insert_deps(packets: &mut [Packet]) {
    // syncs[(from, to)] = list of (producer_idx, consumer_idx)
    let mut syncs: Vec<((PMod, PMod), (usize, usize))> = Vec::new();
    for i in 0..packets.len() {
        let my_mod = packets[i].module;
        for other in [PMod::Load, PMod::Compute, PMod::Store] {
            if other == my_mod || !adjacent(other, my_mod) {
                continue;
            }
            // Packets on `other` at index <= bound are already ordered
            // before packet i by some existing sync.
            let bound = syncs
                .iter()
                .filter(|((f, t), (_, c))| *f == other && *t == my_mod && *c <= i)
                .map(|(_, (p, _))| *p as i64)
                .max()
                .unwrap_or(-1);
            // Find the closest earlier conflicting packet on `other`.
            let mut j = i as i64 - 1;
            while j > bound {
                let jj = j as usize;
                if packets[jj].module == other && packets[jj].conflicts_with(&packets[i]) {
                    set_push(&mut packets[jj], other, my_mod);
                    set_pop(&mut packets[i], other, my_mod);
                    syncs.push(((other, my_mod), (jj, i)));
                    break;
                }
                j -= 1;
            }
        }
    }
}

/// Modules wired by a dependency queue (load<->compute, compute<->store).
fn adjacent(a: PMod, b: PMod) -> bool {
    matches!(
        (a, b),
        (PMod::Load, PMod::Compute)
            | (PMod::Compute, PMod::Load)
            | (PMod::Compute, PMod::Store)
            | (PMod::Store, PMod::Compute)
    )
}

/// Set the push bit on the *last* instruction of the producer packet for
/// the queue from `from` to `to`.
fn set_push(packet: &mut Packet, from: PMod, to: PMod) {
    let insn = packet.insns.last_mut().expect("empty packet");
    let deps = insn.deps_mut();
    match (from, to) {
        // prev/next are relative to the *executing* (from) module.
        (PMod::Load, PMod::Compute) => deps.push_next = true,
        (PMod::Compute, PMod::Load) => deps.push_prev = true,
        (PMod::Compute, PMod::Store) => deps.push_next = true,
        (PMod::Store, PMod::Compute) => deps.push_prev = true,
        _ => unreachable!(),
    }
}

/// Set the pop bit on the *first* instruction of the consumer packet.
fn set_pop(packet: &mut Packet, from: PMod, to: PMod) {
    let insn = packet.insns.first_mut().expect("empty packet");
    let deps = insn.deps_mut();
    match (from, to) {
        (PMod::Load, PMod::Compute) => deps.pop_prev = true,
        (PMod::Compute, PMod::Load) => deps.pop_next = true,
        (PMod::Compute, PMod::Store) => deps.pop_prev = true,
        (PMod::Store, PMod::Compute) => deps.pop_next = true,
        _ => unreachable!(),
    }
}

/// Flatten packets into the final instruction stream (fetch order =
/// program order).
pub fn flatten(packets: Vec<Packet>) -> Vec<Insn> {
    packets.into_iter().flat_map(|p| p.insns).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{DepFlags, GemmInsn, MemInsn, Opcode};

    fn load_insn(buffer: BufferId) -> Insn {
        Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        })
    }

    fn gemm_insn() -> Insn {
        Insn::Gemm(GemmInsn {
            deps: DepFlags::NONE,
            reset: false,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            acc_f0: 0,
            acc_f1: 0,
            inp_f0: 0,
            inp_f1: 0,
            wgt_f0: 0,
            wgt_f1: 0,
        })
    }

    fn store_insn() -> Insn {
        Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        })
    }

    #[test]
    fn raw_dependency_gets_tokens() {
        let mut packets = vec![
            Packet::new(PMod::Load, vec![load_insn(BufferId::Inp)])
                .write(Region::new(BufferId::Inp, 0, 4)),
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .read(Region::new(BufferId::Inp, 0, 4))
                .write(Region::new(BufferId::Acc, 0, 1)),
        ];
        insert_deps(&mut packets);
        assert!(packets[0].insns[0].deps().push_next);
        assert!(packets[1].insns[0].deps().pop_prev);
    }

    #[test]
    fn disjoint_regions_need_no_tokens() {
        // Double buffering: the load into the other half is independent.
        let mut packets = vec![
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .read(Region::new(BufferId::Inp, 0, 4)),
            Packet::new(PMod::Load, vec![load_insn(BufferId::Inp)])
                .write(Region::new(BufferId::Inp, 4, 8)),
        ];
        insert_deps(&mut packets);
        assert_eq!(packets[0].insns[0].deps(), DepFlags::NONE);
        assert_eq!(packets[1].insns[0].deps(), DepFlags::NONE);
    }

    #[test]
    fn war_dependency_blocks_overwrite() {
        // Compute reads half A; a later load overwrites half A -> WAR.
        let mut packets = vec![
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .read(Region::new(BufferId::Inp, 0, 4)),
            Packet::new(PMod::Load, vec![load_insn(BufferId::Inp)])
                .write(Region::new(BufferId::Inp, 0, 4)),
        ];
        insert_deps(&mut packets);
        assert!(packets[0].insns[0].deps().push_prev);
        assert!(packets[1].insns[0].deps().pop_next);
    }

    #[test]
    fn transitive_sync_not_duplicated() {
        // L0 -> C1 (token). C2 also reads L0's region, but same-module
        // ordering C1 < C2 already covers it: no second token.
        let mut packets = vec![
            Packet::new(PMod::Load, vec![load_insn(BufferId::Inp)])
                .write(Region::new(BufferId::Inp, 0, 4)),
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .read(Region::new(BufferId::Inp, 0, 4)),
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .read(Region::new(BufferId::Inp, 0, 4)),
        ];
        insert_deps(&mut packets);
        assert!(packets[0].insns[0].deps().push_next);
        assert!(packets[1].insns[0].deps().pop_prev);
        assert!(!packets[2].insns[0].deps().pop_prev, "redundant token");
    }

    #[test]
    fn store_chain_tokens() {
        let mut packets = vec![
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .write(Region::new(BufferId::Out, 0, 4)),
            Packet::new(PMod::Store, vec![store_insn()])
                .read(Region::new(BufferId::Out, 0, 4)),
            // Next compute overwrites the same OUT half -> must wait for
            // the store (WAR through st->cmp queue).
            Packet::new(PMod::Compute, vec![gemm_insn()])
                .write(Region::new(BufferId::Out, 0, 4)),
        ];
        insert_deps(&mut packets);
        assert!(packets[0].insns[0].deps().push_next);
        assert!(packets[1].insns[0].deps().pop_prev);
        assert!(packets[1].insns[0].deps().push_prev);
        assert!(packets[2].insns[0].deps().pop_next);
    }

    #[test]
    fn acc8_aliases_acc() {
        let r1 = Region::new(BufferId::Acc8, 0, 4);
        let r2 = Region::new(BufferId::Acc, 2, 6);
        assert!(r1.overlaps(&r2));
    }

    #[test]
    fn flatten_preserves_order() {
        let packets = vec![
            Packet::new(PMod::Load, vec![load_insn(BufferId::Inp), load_insn(BufferId::Wgt)]),
            Packet::new(PMod::Compute, vec![gemm_insn()]),
        ];
        let insns = flatten(packets);
        assert_eq!(insns.len(), 3);
        assert_eq!(insns[2].opcode(), crate::isa::Opcode::Gemm);
    }
}
