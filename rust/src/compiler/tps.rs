//! Tiling Parameter Search (TPS) — §IV-D1 and Appendix A.
//!
//! For a convolution and a VTA configuration, TPS picks the loop tiling
//! that minimizes DRAM byte transfer subject to scratchpad-capacity
//! constraints, replacing AutoTVM/Ansor's measured cost models with a
//! closed-form analytical one ("we express the bytes transferred from
//! DRAM to scratchpads as an analytical cost function of the tiling
//! parameters"). The space is enumerated exhaustively over divisor
//! tilings, exactly as the paper's "TPS algorithm exhaustively enumerates
//! all the configurations in the tiling parameter space".
//!
//! The *fallback* schedule — TVM-VTA's default, which "guarantees
//! compilability ... by ensuring minimal use of local scratchpad at the
//! expense of high DRAM byte transfer" — is the Fig 10 baseline.

use super::layout::conv_out_dim;
use crate::config::VtaConfig;

/// A convolution workload (NCHW, pre-tiling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub sh: usize,
    pub sw: usize,
    pub ph: usize,
    pub pw: usize,
}

impl ConvSpec {
    pub fn oh(&self) -> usize {
        conv_out_dim(self.h, self.kh, self.ph, self.sh)
    }

    pub fn ow(&self) -> usize {
        conv_out_dim(self.w, self.kw, self.pw, self.sw)
    }

    /// Input-channel tiles under `block_in`.
    pub fn di(&self, cfg: &VtaConfig) -> usize {
        self.c_in.div_ceil(cfg.block_in)
    }

    /// Output-channel tiles under `block_out`.
    pub fn dout(&self, cfg: &VtaConfig) -> usize {
        self.c_out.div_ceil(cfg.block_out)
    }

    /// Total MACs (on padded channel counts, as the hardware executes).
    pub fn macs(&self, cfg: &VtaConfig) -> u64 {
        (cfg.batch
            * self.di(cfg)
            * cfg.block_in
            * self.dout(cfg)
            * cfg.block_out
            * self.oh()
            * self.ow()
            * self.kh
            * self.kw) as u64
    }
}

/// A tiling point: the number of outer chunks along each loop dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Output-height chunks (paper's `th_o`).
    pub th_o: usize,
    /// Output-width chunks (`tw_o`).
    pub tw_o: usize,
    /// Output-channel-tile chunks (`tco_o`).
    pub tco_o: usize,
    /// Input-channel-tile chunks (`tci_o`).
    pub tci_o: usize,
    /// Improved double buffering (§IV-D2): reuse the input block across
    /// output-channel chunks instead of reloading it per chunk.
    pub reuse_inp: bool,
}

/// Derived per-chunk geometry (maximum chunk sizes; edge chunks may be
/// smaller).
#[derive(Debug, Clone, Copy)]
pub struct TileGeom {
    pub oh_i: usize,
    pub ow_i: usize,
    pub co_i: usize,
    pub ci_i: usize,
    pub ih_i: usize,
    pub iw_i: usize,
    pub inp_block_tiles: usize,
    pub wgt_block_tiles: usize,
    pub acc_block_tiles: usize,
    pub gemm_uops: usize,
}

impl Tiling {
    pub fn geom(&self, spec: &ConvSpec, cfg: &VtaConfig) -> TileGeom {
        let oh_i = spec.oh().div_ceil(self.th_o);
        let ow_i = spec.ow().div_ceil(self.tw_o);
        let co_i = spec.dout(cfg).div_ceil(self.tco_o);
        let ci_i = spec.di(cfg).div_ceil(self.tci_o);
        let ih_i = (oh_i - 1) * spec.sh + spec.kh;
        let iw_i = (ow_i - 1) * spec.sw + spec.kw;
        TileGeom {
            oh_i,
            ow_i,
            co_i,
            ci_i,
            ih_i,
            iw_i,
            inp_block_tiles: ci_i * ih_i * iw_i,
            wgt_block_tiles: co_i * ci_i * spec.kh * spec.kw,
            acc_block_tiles: co_i * oh_i * ow_i,
            gemm_uops: oh_i * ow_i * ci_i * spec.kw,
        }
    }

    /// Input-scratchpad slots this schedule occupies: 2 when the input
    /// block is double-buffered (more than one block loaded over the
    /// layer), 1 otherwise. Exposed for the residency planner, whose
    /// capacity budget must subtract the executing layer's own
    /// working set (`inp_slots x inp_block_tiles`).
    pub fn inp_slots(&self) -> usize {
        let n_spatial = self.th_o * self.tw_o;
        if n_spatial * self.tci_o * (if self.reuse_inp { 1 } else { self.tco_o }) > 1 {
            2
        } else {
            1
        }
    }

    /// Scratchpad feasibility (Appendix A's `u_* >= 0` constraints), with
    /// double-buffered (2-slot) blocks whenever more than one block is
    /// loaded, plus uop-buffer and ISA field-width constraints.
    pub fn feasible(&self, spec: &ConvSpec, cfg: &VtaConfig) -> bool {
        let g = self.geom(spec, cfg);
        let layout = cfg.isa_layout();
        let n_spatial = self.th_o * self.tw_o;
        let inp_slots = self.inp_slots();
        let wgt_slots = if n_spatial * self.tco_o * self.tci_o > 1 { 2 } else { 1 };
        let acc_slots = if n_spatial * self.tco_o > 1 { 2 } else { 1 };
        if inp_slots * g.inp_block_tiles > cfg.inp_depth {
            return false;
        }
        if wgt_slots * g.wgt_block_tiles > cfg.wgt_depth {
            return false;
        }
        if acc_slots * g.acc_block_tiles > cfg.acc_depth {
            return false;
        }
        // Uop stream: up to 2 slot-variants of the GEMM sequence plus the
        // per-row ALU/reset sequences (2 variants of ow_i each), plus
        // ragged-edge variants; ×2 safety margin on the dominant term.
        let uop_budget = 2 * g.gemm_uops + 4 * g.ow_i;
        if 2 * uop_budget > cfg.uop_depth {
            return false;
        }
        // Loop extents and index factors must fit their ISA fields.
        let max_loop = (1usize << layout.loop_bits) - 1;
        if g.co_i > max_loop || spec.kh > max_loop || g.oh_i > max_loop {
            return false;
        }
        let max_acc = 1usize << layout.acc_idx_bits;
        let max_inp = 1usize << layout.inp_idx_bits;
        let max_wgt = 1usize << layout.wgt_idx_bits;
        if g.oh_i * g.ow_i >= max_acc || g.ih_i * g.iw_i >= max_inp {
            return false;
        }
        if g.ci_i * spec.kh * spec.kw >= max_wgt {
            return false;
        }
        true
    }

    /// Analytical DRAM byte cost (Appendix A eq. 2, specialized to this
    /// schedule; closed-form over ragged chunks).
    pub fn dram_bytes(&self, spec: &ConvSpec, cfg: &VtaConfig) -> u64 {
        let di = spec.di(cfg);
        let dout = spec.dout(cfg);
        let (oh, ow) = (spec.oh(), spec.ow());
        // Σ over y-chunks of input rows loaded (halo overlap included):
        // Σ ((oh_chunk - 1)*sh + kh) = sh*(OH - th_o) + th_o*kh.
        let sum_ih = (spec.sh * (oh - self.th_o) + self.th_o * spec.kh) as u64;
        let sum_iw = (spec.sw * (ow - self.tw_o) + self.tw_o * spec.kw) as u64;
        let inp_factor = if self.reuse_inp { 1 } else { self.tco_o } as u64;
        let l_inp = di as u64 * sum_ih * sum_iw * inp_factor * cfg.inp_tile_bytes() as u64;
        // Full weight set reloaded once per spatial chunk.
        let l_wgt = (self.th_o * self.tw_o) as u64
            * (dout * di * spec.kh * spec.kw) as u64
            * cfg.wgt_tile_bytes() as u64;
        let l_out = (dout * oh * ow) as u64 * cfg.out_tile_bytes() as u64;
        // Appendix A's cost counts the data scratchpads only (l_inp,
        // l_wgt, l_acc); uop traffic is a feasibility concern, not cost.
        l_inp + l_wgt + l_out
    }
}

/// The divisors of `n` (ascending) — the candidate chunk counts.
pub fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// The TVM-VTA fallback schedule: minimal scratchpad use (a single
/// output position and a single channel tile each way per inner block),
/// maximal DRAM traffic — weights are re-fetched for every output
/// position and inputs for every output-channel chunk, which is what
/// produces the orders-of-magnitude gap of Fig 10.
pub fn fallback(spec: &ConvSpec, cfg: &VtaConfig) -> Tiling {
    Tiling {
        th_o: spec.oh(),
        tw_o: spec.ow(),
        tco_o: spec.dout(cfg),
        tci_o: spec.di(cfg),
        reuse_inp: false,
    }
}

/// Exhaustive TPS search: minimize DRAM bytes over divisor tilings.
/// Cost ties break toward virtual-thread-capable tilings (tco_o >= 2,
/// which enables the double-buffered co-chunk pairs the paper's schedule
/// template always uses), then toward fewer chunks.
///
/// Panics when no tiling (not even the fallback) fits — callers on
/// untrusted configurations use [`try_search`] / [`select_tiling`],
/// which surface the typed [`ConfigError::Infeasible`] instead.
pub fn search(spec: &ConvSpec, cfg: &VtaConfig, reuse_inp: bool) -> Tiling {
    try_search(spec, cfg, reuse_inp).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible TPS search: like [`search`] but an infeasible space is a
/// typed error, not a panic — the path `sweep::run` uses so
/// tiny-scratchpad grid points are *reported* as infeasible rather
/// than silently dropped.
pub fn try_search(
    spec: &ConvSpec,
    cfg: &VtaConfig,
    reuse_inp: bool,
) -> Result<Tiling, crate::config::ConfigError> {
    let mut best: Option<((u64, usize, usize), Tiling)> = None;
    for &th_o in &divisors(spec.oh()) {
        for &tw_o in &divisors(spec.ow()) {
            for &tco_o in &divisors(spec.dout(cfg)) {
                for &tci_o in &divisors(spec.di(cfg)) {
                    let t = Tiling { th_o, tw_o, tco_o, tci_o, reuse_inp };
                    if !t.feasible(spec, cfg) {
                        continue;
                    }
                    let cost = t.dram_bytes(spec, cfg);
                    let no_vthread = usize::from(tco_o < 2);
                    let chunks = th_o * tw_o * tco_o * tci_o;
                    let rank = (cost, no_vthread, chunks);
                    if best.as_ref().map(|(r, _)| rank < *r).unwrap_or(true) {
                        best = Some((rank, t));
                    }
                }
            }
        }
    }
    match best {
        Some((_, t)) => Ok(t),
        None => {
            let fb = fallback(spec, cfg);
            if fb.feasible(spec, cfg) {
                Ok(fb)
            } else {
                Err(crate::config::ConfigError::Infeasible {
                    reason: format!("no feasible tiling for {spec:?} on {}", cfg.name),
                })
            }
        }
    }
}

/// The session's tiling policy, shared with the residency planner so
/// both derive identical schedules (and therefore identical memo
/// signatures): the tiling is always *searched* under the
/// improved-reuse cost model when TPS is on (the fallback schedule
/// otherwise), and `dbuf_reuse` then sets only the double-buffer
/// thread-injection flag — matching the paper's Fig 11/12 experiment,
/// which flips the IR pass while keeping the schedule.
pub fn select_tiling(
    spec: &ConvSpec,
    cfg: &VtaConfig,
    use_tps: bool,
    dbuf_reuse: bool,
) -> Result<Tiling, crate::config::ConfigError> {
    let mut t = if use_tps {
        try_search(spec, cfg, true)?
    } else {
        let fb = fallback(spec, cfg);
        if !fb.feasible(spec, cfg) {
            return Err(crate::config::ConfigError::Infeasible {
                reason: format!("fallback tiling for {spec:?} overflows scratchpads on {}", cfg.name),
            });
        }
        fb
    };
    t.reuse_inp = dbuf_reuse;
    Ok(t)
}

/// Chunk bounds helper: start offset and size of chunk `idx` when `dim`
/// is split into `chunks` near-equal parts (ceil-sized leading chunks).
pub fn chunk_bounds(dim: usize, chunks: usize, idx: usize) -> (usize, usize) {
    let size = dim.div_ceil(chunks);
    let start = idx * size;
    let len = size.min(dim.saturating_sub(start));
    (start, len)
}

/// ResNet-18 convolution layers C2–C11 as enumerated in Fig 10 (the
/// distinct conv shapes from conv2_x through conv5_x plus downsamples).
pub fn resnet18_convs() -> Vec<(String, ConvSpec)> {
    let conv = |c_in, c_out, hw, k, s, p| ConvSpec {
        c_in,
        c_out,
        h: hw,
        w: hw,
        kh: k,
        kw: k,
        sh: s,
        sw: s,
        ph: p,
        pw: p,
    };
    vec![
        ("C2".to_string(), conv(64, 64, 56, 3, 1, 1)),
        ("C3".to_string(), conv(64, 128, 56, 3, 2, 1)),
        ("C4".to_string(), conv(64, 128, 56, 1, 2, 0)),
        ("C5".to_string(), conv(128, 128, 28, 3, 1, 1)),
        ("C6".to_string(), conv(128, 256, 28, 3, 2, 1)),
        ("C7".to_string(), conv(128, 256, 28, 1, 2, 0)),
        ("C8".to_string(), conv(256, 256, 14, 3, 1, 1)),
        ("C9".to_string(), conv(256, 512, 14, 3, 2, 1)),
        ("C10".to_string(), conv(256, 512, 14, 1, 2, 0)),
        ("C11".to_string(), conv(512, 512, 7, 3, 1, 1)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn c2() -> ConvSpec {
        resnet18_convs()[0].1
    }

    #[test]
    fn out_dims_and_tiles() {
        let cfg = presets::default_config();
        let spec = c2();
        assert_eq!(spec.oh(), 56);
        assert_eq!(spec.di(&cfg), 4);
        assert_eq!(spec.dout(&cfg), 4);
        assert_eq!(spec.macs(&cfg), 64 * 64 * 56 * 56 * 9);
    }

    #[test]
    fn fallback_always_feasible_on_presets() {
        for cfg in presets::all() {
            for (name, spec) in resnet18_convs() {
                let fb = fallback(&spec, &cfg);
                assert!(fb.feasible(&spec, &cfg), "{name} infeasible on {}", cfg.name);
            }
        }
    }

    #[test]
    fn tps_beats_fallback_substantially() {
        // Fig 10: 20x-400x byte reduction on BLOCK=32.
        let cfg = presets::scaled_config(1, 32, 32, 2, 32);
        for (name, spec) in resnet18_convs() {
            let fb = fallback(&spec, &cfg).dram_bytes(&spec, &cfg);
            let best = search(&spec, &cfg, true);
            let opt = best.dram_bytes(&spec, &cfg);
            let ratio = fb as f64 / opt as f64;
            assert!(ratio > 5.0, "{name}: ratio only {ratio:.1} (fb={fb} opt={opt})");
        }
    }

    #[test]
    fn search_result_feasible() {
        let cfg = presets::default_config();
        let t = search(&c2(), &cfg, true);
        assert!(t.feasible(&c2(), &cfg));
    }

    #[test]
    fn reuse_reduces_input_bytes() {
        let cfg = presets::default_config();
        let spec = c2();
        let t_no = Tiling { th_o: 4, tw_o: 1, tco_o: 4, tci_o: 1, reuse_inp: false };
        let t_yes = Tiling { reuse_inp: true, ..t_no };
        assert!(t_yes.dram_bytes(&spec, &cfg) < t_no.dram_bytes(&spec, &cfg));
    }

    #[test]
    fn chunk_bounds_cover_dim() {
        for (dim, chunks) in [(56, 4), (7, 3), (10, 4), (1, 1)] {
            let mut total = 0;
            for i in 0..chunks {
                let (start, len) = chunk_bounds(dim, chunks, i);
                assert_eq!(start, total);
                total += len;
            }
            assert_eq!(total, dim);
        }
    }

    #[test]
    fn try_search_reports_infeasible_as_typed_error() {
        let mut cfg = presets::tiny_config();
        cfg.inp_depth = 1;
        cfg.wgt_depth = 1;
        cfg.acc_depth = 1;
        let err = try_search(&c2(), &cfg, true).unwrap_err();
        assert!(matches!(err, crate::config::ConfigError::Infeasible { .. }), "got {err:?}");
        assert!(select_tiling(&c2(), &cfg, false, true).is_err(), "fallback path too");
        // A feasible config still searches to the same tiling.
        let ok = presets::default_config();
        assert_eq!(try_search(&c2(), &ok, true).unwrap(), search(&c2(), &ok, true));
    }

    #[test]
    fn divisors_correct() {
        assert_eq!(divisors(56), vec![1, 2, 4, 7, 8, 14, 28, 56]);
        assert_eq!(divisors(1), vec![1]);
    }

    #[test]
    fn closed_form_halo_sum_matches_enumeration() {
        // Verify Σ_chunks ((oh_c-1)*sh + kh) == sh*(OH-th_o) + th_o*kh
        // for exact-divisor chunkings.
        let spec = ConvSpec { c_in: 64, c_out: 64, h: 56, w: 56, kh: 3, kw: 3, sh: 2, sw: 2, ph: 1, pw: 1 };
        let oh = spec.oh();
        for &th_o in &divisors(oh) {
            let mut total = 0usize;
            for i in 0..th_o {
                let (_, len) = chunk_bounds(oh, th_o, i);
                total += (len - 1) * spec.sh + spec.kh;
            }
            assert_eq!(total, spec.sh * (oh - th_o) + th_o * spec.kh);
        }
    }
}
