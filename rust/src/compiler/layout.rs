//! Tensor layouts and packing.
//!
//! VTA computes on *tiles*: activations live in DRAM as
//! `[C/BLOCK][H][W]` tiles of `[BATCH][BLOCK]` int8 (TVM's NCHWnc), conv
//! weights as `[O/BLOCK][I/BLOCK][KH][KW]` tiles of `[BLOCK][BLOCK]`
//! (OIHWoi), and depthwise weights as `[C/BLOCK][KH][KW]` tiles of
//! `[BATCH][BLOCK]` broadcast rows. Channel counts are zero-padded up to
//! a multiple of BLOCK. This module converts between flat NCHW tensors
//! and the tiled DRAM images, and provides the shape bookkeeping used by
//! the schedules.

/// Activation shape (per-device batch is the hardware BATCH parameter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Channels (logical, pre-padding).
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize) -> Shape {
        Shape { c, h, w }
    }

    /// Channel tiles after padding to `block`.
    pub fn c_tiles(&self, block: usize) -> usize {
        self.c.div_ceil(block)
    }

    /// Total tiles in the tiled layout.
    pub fn tiles(&self, block: usize) -> usize {
        self.c_tiles(block) * self.h * self.w
    }

    pub fn elems(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// Pack an NCHW activation (n = `batch`) into the tiled VTA image.
/// Input is `[batch][c][h][w]` row-major; output is
/// `[c/block][h][w][batch][block]` with zero padding in the channel tail.
pub fn pack_activation(data: &[i8], batch: usize, shape: Shape, block: usize) -> Vec<i8> {
    assert_eq!(data.len(), batch * shape.elems(), "activation size mismatch");
    let cb = shape.c_tiles(block);
    let mut out = vec![0i8; cb * shape.h * shape.w * batch * block];
    for n in 0..batch {
        for c in 0..shape.c {
            let (ct, ci) = (c / block, c % block);
            for y in 0..shape.h {
                for x in 0..shape.w {
                    let src = ((n * shape.c + c) * shape.h + y) * shape.w + x;
                    let tile = (ct * shape.h + y) * shape.w + x;
                    let dst = (tile * batch + n) * block + ci;
                    out[dst] = data[src];
                }
            }
        }
    }
    out
}

/// Inverse of [`pack_activation`].
pub fn unpack_activation(tiled: &[i8], batch: usize, shape: Shape, block: usize) -> Vec<i8> {
    let cb = shape.c_tiles(block);
    assert_eq!(tiled.len(), cb * shape.h * shape.w * batch * block);
    let mut out = vec![0i8; batch * shape.elems()];
    for n in 0..batch {
        for c in 0..shape.c {
            let (ct, ci) = (c / block, c % block);
            for y in 0..shape.h {
                for x in 0..shape.w {
                    let tile = (ct * shape.h + y) * shape.w + x;
                    let src = (tile * batch + n) * block + ci;
                    let dst = ((n * shape.c + c) * shape.h + y) * shape.w + x;
                    out[dst] = tiled[src];
                }
            }
        }
    }
    out
}

/// Pack OIHW conv weights into `[O/bo][I/bi][KH][KW]` tiles of
/// `[bo][bi]`, zero-padded on both channel dimensions.
pub fn pack_conv_weights(
    data: &[i8],
    o: usize,
    i: usize,
    kh: usize,
    kw: usize,
    bo: usize,
    bi: usize,
) -> Vec<i8> {
    let mut out = Vec::new();
    pack_conv_weights_into(&mut out, data, o, i, kh, kw, bo, bi);
    out
}

/// [`pack_conv_weights`] into a caller-owned buffer, reusing its
/// capacity. The buffer is cleared and zero-filled first, so the result
/// is byte-identical to the allocating variant; repeated layers stop
/// paying an allocation per pack (§Perf: the runtime's weight-staging
/// arena, [`crate::runtime::Session`]).
#[allow(clippy::too_many_arguments)]
pub fn pack_conv_weights_into(
    out: &mut Vec<i8>,
    data: &[i8],
    o: usize,
    i: usize,
    kh: usize,
    kw: usize,
    bo: usize,
    bi: usize,
) {
    assert_eq!(data.len(), o * i * kh * kw, "weight size mismatch");
    let ob = o.div_ceil(bo);
    let ib = i.div_ceil(bi);
    out.clear();
    out.resize(ob * ib * kh * kw * bo * bi, 0);
    for oc in 0..o {
        let (ot, oi) = (oc / bo, oc % bo);
        for ic in 0..i {
            let (it, ii) = (ic / bi, ic % bi);
            for ky in 0..kh {
                for kx in 0..kw {
                    let src = ((oc * i + ic) * kh + ky) * kw + kx;
                    let tile = ((ot * ib + it) * kh + ky) * kw + kx;
                    let dst = (tile * bo + oi) * bi + ii;
                    out[dst] = data[src];
                }
            }
        }
    }
}

/// Pack depthwise weights `[C][KH][KW]` into `[C/block][KH][KW]` tiles of
/// `[batch][block]` — each tile row repeats the per-channel tap weights
/// so the ALU's element-wise MUL sees the right operand in every lane.
pub fn pack_depthwise_weights(
    data: &[i8],
    c: usize,
    kh: usize,
    kw: usize,
    batch: usize,
    block: usize,
) -> Vec<i8> {
    let mut out = Vec::new();
    pack_depthwise_weights_into(&mut out, data, c, kh, kw, batch, block);
    out
}

/// [`pack_depthwise_weights`] into a caller-owned buffer (cleared and
/// zero-filled first; byte-identical output, no per-call allocation).
pub fn pack_depthwise_weights_into(
    out: &mut Vec<i8>,
    data: &[i8],
    c: usize,
    kh: usize,
    kw: usize,
    batch: usize,
    block: usize,
) {
    assert_eq!(data.len(), c * kh * kw, "depthwise weight size mismatch");
    let cb = c.div_ceil(block);
    out.clear();
    out.resize(cb * kh * kw * batch * block, 0);
    for ch in 0..c {
        let (ct, ci) = (ch / block, ch % block);
        for ky in 0..kh {
            for kx in 0..kw {
                let src = (ch * kh + ky) * kw + kx;
                let tile = (ct * kh + ky) * kw + kx;
                for n in 0..batch {
                    out[(tile * batch + n) * block + ci] = data[src];
                }
            }
        }
    }
}

/// Conv output spatial size (paper Appendix A, eq. 1).
pub fn conv_out_dim(in_dim: usize, k: usize, pad: usize, stride: usize) -> usize {
    (in_dim + 2 * pad - k) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn activation_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let shape = Shape::new(5, 3, 4); // non-multiple channel count
        let batch = 2;
        let data = rng.i8_vec(batch * shape.elems());
        let tiled = pack_activation(&data, batch, shape, 4);
        assert_eq!(tiled.len(), 2 * 3 * 4 * 2 * 4);
        let back = unpack_activation(&tiled, batch, shape, 4);
        assert_eq!(back, data);
    }

    #[test]
    fn activation_channel_padding_zeroed() {
        let shape = Shape::new(3, 1, 1);
        let data = vec![1i8, 2, 3];
        let tiled = pack_activation(&data, 1, shape, 4);
        assert_eq!(tiled, vec![1, 2, 3, 0]);
    }

    #[test]
    fn activation_tile_order_matches_schedule_assumption() {
        // tile index = (ct*H + y)*W + x; tile content [batch][block]
        let shape = Shape::new(4, 2, 2);
        let batch = 1;
        let block = 4;
        let data: Vec<i8> = (0..16).map(|v| v as i8).collect();
        let tiled = pack_activation(&data, batch, shape, block);
        // tile (y=0,x=1) should contain channels 0..4 at spatial (0,1):
        // NCHW values 1, 5, 9, 13
        assert_eq!(&tiled[4..8], &[1, 5, 9, 13]);
    }

    #[test]
    fn conv_weights_tile_content() {
        // o=i=2, bo=bi=2, kh=kw=1: single tile [o][i].
        let data = vec![1i8, 2, 3, 4]; // w[o][i] = [[1,2],[3,4]]
        let tiled = pack_conv_weights(&data, 2, 2, 1, 1, 2, 2);
        assert_eq!(tiled, vec![1, 2, 3, 4]);
    }

    #[test]
    fn conv_weights_padding() {
        // o=1, i=1 padded into 2x2 tile.
        let data = vec![7i8];
        let tiled = pack_conv_weights(&data, 1, 1, 1, 1, 2, 2);
        assert_eq!(tiled, vec![7, 0, 0, 0]);
    }

    #[test]
    fn depthwise_weights_broadcast_rows() {
        let data = vec![5i8, -3]; // 2 channels, 1x1 tap
        let tiled = pack_depthwise_weights(&data, 2, 1, 1, 2, 2);
        // tile [batch=2][block=2]: both batch rows identical
        assert_eq!(tiled, vec![5, -3, 5, -3]);
    }

    #[test]
    fn into_variants_match_with_dirty_buffer() {
        let mut rng = Pcg32::seeded(3);
        let conv = rng.i8_vec(5 * 3 * 3 * 3); // o=5 i=3 k=3 (odd sizes)
        let dw = rng.i8_vec(5 * 3 * 3);
        let mut buf = vec![77i8; 9999]; // stale garbage must not leak
        pack_conv_weights_into(&mut buf, &conv, 5, 3, 3, 3, 4, 4);
        assert_eq!(buf, pack_conv_weights(&conv, 5, 3, 3, 3, 4, 4));
        pack_depthwise_weights_into(&mut buf, &dw, 5, 3, 3, 2, 4);
        assert_eq!(buf, pack_depthwise_weights(&dw, 5, 3, 3, 2, 4));
    }

    #[test]
    fn out_dims() {
        assert_eq!(conv_out_dim(56, 3, 1, 1), 56);
        assert_eq!(conv_out_dim(56, 3, 1, 2), 28);
        assert_eq!(conv_out_dim(56, 1, 0, 1), 56);
        assert_eq!(conv_out_dim(7, 7, 0, 1), 1);
        assert_eq!(conv_out_dim(224, 7, 3, 2), 112);
        assert_eq!(conv_out_dim(112, 3, 1, 2), 56);
    }
}
