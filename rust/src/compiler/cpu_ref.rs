//! Bit-exact CPU reference implementations of every quantized operator.
//!
//! These mirror the *hardware* semantics precisely (int32 accumulate,
//! round-half-up via `+ (1 << (shift-1))` then arithmetic shift, clip to
//! ±127, truncating int8 narrowing) so that fsim, tsim, this reference
//! and the JAX/Pallas golden model must all agree to the bit. Also used
//! to execute CPU-fallback layers (the channel-light first convolution
//! runs on the CPU, §IV-E).

use super::tps::ConvSpec;

/// Requantize an int32 accumulator value: round-half-up shift, optional
/// ReLU, clip to [-127, 127].
pub fn requant(acc: i32, shift: u32, relu: bool) -> i8 {
    let mut v = if shift > 0 { (acc + (1 << (shift - 1))) >> shift } else { acc };
    if relu {
        v = v.max(0);
    }
    v.clamp(-127, 127) as i8
}

/// int8 conv2d, NCHW x OIHW -> NCHW. `n` is the batch.
pub fn conv2d(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    spec: &ConvSpec,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let (oh, ow) = (spec.oh(), spec.ow());
    assert_eq!(inp.len(), n * spec.c_in * spec.h * spec.w);
    assert_eq!(wgt.len(), spec.c_out * spec.c_in * spec.kh * spec.kw);
    let mut out = vec![0i8; n * spec.c_out * oh * ow];
    for b in 0..n {
        for oc in 0..spec.c_out {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ic in 0..spec.c_in {
                        for ky in 0..spec.kh {
                            let iy = (oy * spec.sh + ky) as i64 - spec.ph as i64;
                            if iy < 0 || iy >= spec.h as i64 {
                                continue;
                            }
                            for kx in 0..spec.kw {
                                let ix = (ox * spec.sw + kx) as i64 - spec.pw as i64;
                                if ix < 0 || ix >= spec.w as i64 {
                                    continue;
                                }
                                let iv = inp[((b * spec.c_in + ic) * spec.h + iy as usize)
                                    * spec.w
                                    + ix as usize] as i32;
                                let wv = wgt[((oc * spec.c_in + ic) * spec.kh + ky) * spec.kw
                                    + kx] as i32;
                                acc += iv * wv;
                            }
                        }
                    }
                    out[((b * spec.c_out + oc) * oh + oy) * ow + ox] =
                        requant(acc, shift, relu);
                }
            }
        }
    }
    out
}

/// int8 depthwise conv, NCHW x CHW(taps) -> NCHW.
#[allow(clippy::too_many_arguments)]
pub fn depthwise(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    assert_eq!(inp.len(), n * c * h * w);
    assert_eq!(wgt.len(), c * kh * kw);
    let mut out = vec![0i8; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as i64 - pad as i64;
                        if iy < 0 || iy >= h as i64 {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as i64 - pad as i64;
                            if ix < 0 || ix >= w as i64 {
                                continue;
                            }
                            let iv =
                                inp[((b * c + ch) * h + iy as usize) * w + ix as usize] as i32;
                            let wv = wgt[(ch * kh + ky) * kw + kx] as i32;
                            acc += iv * wv;
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = requant(acc, shift, relu);
                }
            }
        }
    }
    out
}

/// int8 max pooling. Padded border contributes -128 (the new LOAD pad
/// value the hardware uses).
pub fn maxpool(
    inp: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Vec<i8> {
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut out = vec![0i8; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = i8::MIN;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * stride + ky) as i64 - pad as i64;
                            let ix = (ox * stride + kx) as i64 - pad as i64;
                            let v = if iy < 0 || iy >= h as i64 || ix < 0 || ix >= w as i64 {
                                -128
                            } else {
                                inp[((b * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            m = m.max(v);
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    out
}

/// Global average pooling as the hardware computes it: the window sum is
/// scaled by a power-of-two shift (`ceil(log2(h*w))`) with round-half-up
/// — a hardware-friendly approximation of mean (documented in DESIGN.md).
pub fn global_avgpool(inp: &[i8], n: usize, c: usize, h: usize, w: usize) -> Vec<i8> {
    let shift = crate::util::bitfield::clog2((h * w) as u64);
    let mut out = vec![0i8; n * c];
    for b in 0..n {
        for ch in 0..c {
            let mut acc = 0i32;
            for i in 0..h * w {
                acc += inp[(b * c + ch) * h * w + i] as i32;
            }
            out[b * c + ch] = requant(acc, shift, false);
        }
    }
    out
}

/// Residual addition: `clip(a + b)` with optional ReLU (no shift).
pub fn add(a: &[i8], b: &[i8], relu: bool) -> Vec<i8> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| requant(x as i32 + y as i32, 0, relu))
        .collect()
}

/// Dense (fully connected): `[n][c_in]` x `[c_out][c_in]` -> `[n][c_out]`.
pub fn dense(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    c_in: usize,
    c_out: usize,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let spec = ConvSpec {
        c_in,
        c_out,
        h: 1,
        w: 1,
        kh: 1,
        kw: 1,
        sh: 1,
        sw: 1,
        ph: 0,
        pw: 0,
    };
    conv2d(inp, wgt, n, &spec, shift, relu)
}

/// Default requantization shift for a layer accumulating `n_accum`
/// products of our synthetic data (values ~U[-8,8)): targets an output
/// std around 64 so outputs exercise the full int8 range without
/// saturating everywhere.
pub fn default_shift(n_accum: usize) -> u32 {
    // acc std ≈ (4.6)^2 * sqrt(n) ≈ 21*sqrt(n); shift ≈ log2(std/64).
    let std = 21.0 * (n_accum as f64).sqrt();
    (std / 64.0).log2().round().max(0.0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn requant_rounding_half_up() {
        assert_eq!(requant(5, 2, false), 1); // (5+2)>>2 = 1
        assert_eq!(requant(6, 2, false), 2); // (6+2)>>2 = 2
        assert_eq!(requant(-5, 2, false), -1); // (-5+2)>>2 = -3>>2 = -1
        assert_eq!(requant(1000, 0, false), 127);
        assert_eq!(requant(-1000, 0, false), -127);
        assert_eq!(requant(-5, 0, true), 0);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel, single channel, weight=1, shift=0: identity.
        let spec = ConvSpec { c_in: 1, c_out: 1, h: 3, w: 3, kh: 1, kw: 1, sh: 1, sw: 1, ph: 0, pw: 0 };
        let inp: Vec<i8> = (1..=9).collect();
        let out = conv2d(&inp, &[1], 1, &spec, 0, false);
        assert_eq!(out, inp);
    }

    #[test]
    fn conv_padding_and_stride() {
        // 3x3 sum kernel over a 3x3 image of ones with pad 1 stride 2:
        // corners see 4 ones, so output = [[4,4],[4,4]] at stride 2... the
        // center-adjacent sums differ; compute one by hand: oy=ox=0 sees
        // rows/cols -1..1 -> 4 valid ones.
        let spec = ConvSpec { c_in: 1, c_out: 1, h: 3, w: 3, kh: 3, kw: 3, sh: 2, sw: 2, ph: 1, pw: 1 };
        let inp = vec![1i8; 9];
        let out = conv2d(&inp, &[1i8; 9], 1, &spec, 0, false);
        assert_eq!(spec.oh(), 2);
        assert_eq!(out, vec![4, 4, 4, 4]);
    }

    #[test]
    fn maxpool_uses_neg128_padding() {
        let inp = vec![-100i8; 4]; // 1x1x2x2
        let out = maxpool(&inp, 1, 1, 2, 2, 3, 2, 1);
        // All windows include real -100s which beat the -128 pad.
        assert!(out.iter().all(|&v| v == -100));
    }

    #[test]
    fn global_avgpool_shift() {
        // 2x2 window, values 4,4,4,4: sum=16, shift=2 -> (16+2)>>2 = 4.
        let out = global_avgpool(&[4, 4, 4, 4], 1, 1, 2, 2);
        assert_eq!(out, vec![4]);
    }

    #[test]
    fn add_clips() {
        assert_eq!(add(&[100], &[100], false), vec![127]);
        assert_eq!(add(&[-100], &[-100], false), vec![-127]);
        assert_eq!(add(&[-5], &[2], true), vec![0]);
        assert_eq!(add(&[3], &[4], false), vec![7]);
    }

    #[test]
    fn depthwise_per_channel() {
        // 2 channels, 1x1 taps [2, 3]: channel i scaled by tap i.
        let inp = vec![1i8, 2, 3, 4]; // c0=[1,2], c1=[3,4] (h=1,w=2)
        let out = depthwise(&inp, &[2, 3], 1, 2, 1, 2, 1, 1, 1, 0, 0, false);
        assert_eq!(out, vec![2, 4, 9, 12]);
    }

    #[test]
    fn dense_matches_manual() {
        // inp [1,2], w = [[1,1],[2,-1]] -> [3, 0]
        let out = dense(&[1, 2], &[1, 1, 2, -1], 1, 2, 2, 0, false);
        assert_eq!(out, vec![3, 0]);
    }

    #[test]
    fn default_shift_reasonable() {
        assert!(default_shift(64 * 9) >= 3);
        assert!(default_shift(64 * 9) <= 6);
        assert_eq!(default_shift(1), 0);
        let mut rng = Pcg32::seeded(1);
        // Statistical check: conv output under default shift is neither
        // all-zero nor all-saturated.
        let spec = ConvSpec { c_in: 16, c_out: 8, h: 8, w: 8, kh: 3, kw: 3, sh: 1, sw: 1, ph: 1, pw: 1 };
        let inp = rng.i8_vec(16 * 64);
        let wgt = rng.i8_vec(8 * 16 * 9);
        let out = conv2d(&inp, &wgt, 1, &spec, default_shift(16 * 9), true);
        let sat = out.iter().filter(|&&v| v == 127).count();
        let zero = out.iter().filter(|&&v| v == 0).count();
        assert!(sat < out.len() / 2, "too saturated: {sat}/{}", out.len());
        assert!(zero < out.len(), "all zero");
    }
}
