//! Bit-exact CPU reference implementations of every quantized operator.
//!
//! These mirror the *hardware* semantics precisely (int32 accumulate,
//! round-half-up via `+ (1 << (shift-1))` then arithmetic shift, clip to
//! ±127, truncating int8 narrowing) so that fsim, tsim, this reference
//! and the JAX/Pallas golden model must all agree to the bit. Also used
//! to execute CPU-fallback layers (the channel-light first convolution
//! runs on the CPU, §IV-E).

use super::tps::ConvSpec;

/// Requantize an int32 accumulator value: round-half-up shift, optional
/// ReLU, clip to [-127, 127].
pub fn requant(acc: i32, shift: u32, relu: bool) -> i8 {
    let mut v = if shift > 0 { (acc + (1 << (shift - 1))) >> shift } else { acc };
    if relu {
        v = v.max(0);
    }
    v.clamp(-127, 127) as i8
}

/// int8 conv2d, NCHW x OIHW -> NCHW. `n` is the batch.
pub fn conv2d(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    spec: &ConvSpec,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let (oh, ow) = (spec.oh(), spec.ow());
    assert_eq!(inp.len(), n * spec.c_in * spec.h * spec.w);
    assert_eq!(wgt.len(), spec.c_out * spec.c_in * spec.kh * spec.kw);
    let mut out = vec![0i8; n * spec.c_out * oh * ow];
    for b in 0..n {
        for oc in 0..spec.c_out {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ic in 0..spec.c_in {
                        for ky in 0..spec.kh {
                            let iy = (oy * spec.sh + ky) as i64 - spec.ph as i64;
                            if iy < 0 || iy >= spec.h as i64 {
                                continue;
                            }
                            for kx in 0..spec.kw {
                                let ix = (ox * spec.sw + kx) as i64 - spec.pw as i64;
                                if ix < 0 || ix >= spec.w as i64 {
                                    continue;
                                }
                                let iv = inp[((b * spec.c_in + ic) * spec.h + iy as usize)
                                    * spec.w
                                    + ix as usize] as i32;
                                let wv = wgt[((oc * spec.c_in + ic) * spec.kh + ky) * spec.kw
                                    + kx] as i32;
                                acc += iv * wv;
                            }
                        }
                    }
                    out[((b * spec.c_out + oc) * oh + oy) * ow + ox] =
                        requant(acc, shift, relu);
                }
            }
        }
    }
    out
}

/// int8 depthwise conv, NCHW x CHW(taps) -> NCHW.
#[allow(clippy::too_many_arguments)]
pub fn depthwise(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    assert_eq!(inp.len(), n * c * h * w);
    assert_eq!(wgt.len(), c * kh * kw);
    let mut out = vec![0i8; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as i64 - pad as i64;
                        if iy < 0 || iy >= h as i64 {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as i64 - pad as i64;
                            if ix < 0 || ix >= w as i64 {
                                continue;
                            }
                            let iv =
                                inp[((b * c + ch) * h + iy as usize) * w + ix as usize] as i32;
                            let wv = wgt[(ch * kh + ky) * kw + kx] as i32;
                            acc += iv * wv;
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = requant(acc, shift, relu);
                }
            }
        }
    }
    out
}

/// int8 max pooling. Padded border contributes -128 (the new LOAD pad
/// value the hardware uses).
pub fn maxpool(
    inp: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Vec<i8> {
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut out = vec![0i8; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = i8::MIN;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * stride + ky) as i64 - pad as i64;
                            let ix = (ox * stride + kx) as i64 - pad as i64;
                            let v = if iy < 0 || iy >= h as i64 || ix < 0 || ix >= w as i64 {
                                -128
                            } else {
                                inp[((b * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            m = m.max(v);
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    out
}

/// Global average pooling as the hardware computes it: the window sum is
/// scaled by a power-of-two shift (`ceil(log2(h*w))`) with round-half-up
/// — a hardware-friendly approximation of mean (documented in DESIGN.md).
pub fn global_avgpool(inp: &[i8], n: usize, c: usize, h: usize, w: usize) -> Vec<i8> {
    let shift = crate::util::bitfield::clog2((h * w) as u64);
    let mut out = vec![0i8; n * c];
    for b in 0..n {
        for ch in 0..c {
            let mut acc = 0i32;
            for i in 0..h * w {
                acc += inp[(b * c + ch) * h * w + i] as i32;
            }
            out[b * c + ch] = requant(acc, shift, false);
        }
    }
    out
}

/// Residual addition: `clip(a + b)` with optional ReLU (no shift).
pub fn add(a: &[i8], b: &[i8], relu: bool) -> Vec<i8> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| requant(x as i32 + y as i32, 0, relu))
        .collect()
}

/// Dense (fully connected): `[n][c_in]` x `[c_out][c_in]` -> `[n][c_out]`.
pub fn dense(
    inp: &[i8],
    wgt: &[i8],
    n: usize,
    c_in: usize,
    c_out: usize,
    shift: u32,
    relu: bool,
) -> Vec<i8> {
    let spec = ConvSpec {
        c_in,
        c_out,
        h: 1,
        w: 1,
        kh: 1,
        kw: 1,
        sh: 1,
        sw: 1,
        ph: 0,
        pw: 0,
    };
    conv2d(inp, wgt, n, &spec, shift, relu)
}

/// Per-head attention scores: Q and K are `[n][c][seq]` (w = 1),
/// `out[b][hd*S + s1][s2] = requant(Σ_d q[hd*Dh+d, s1]·k[hd*Dh+d, s2])`
/// with `Dh = c / heads`. Output `[n][heads*seq][seq]`.
pub fn attn_scores(
    q: &[i8],
    k: &[i8],
    n: usize,
    c: usize,
    seq: usize,
    heads: usize,
    shift: u32,
) -> Vec<i8> {
    assert_eq!(q.len(), n * c * seq);
    assert_eq!(k.len(), q.len());
    let dh = c / heads;
    let mut out = vec![0i8; n * heads * seq * seq];
    for b in 0..n {
        for hd in 0..heads {
            for s1 in 0..seq {
                for s2 in 0..seq {
                    let mut acc = 0i32;
                    for d in 0..dh {
                        let ch = (b * c + hd * dh + d) * seq;
                        acc += q[ch + s1] as i32 * k[ch + s2] as i32;
                    }
                    out[((b * heads + hd) * seq + s1) * seq + s2] = requant(acc, shift, false);
                }
            }
        }
    }
    out
}

/// Shift-based softmax approximation along spatial `h`, independently
/// per (channel lane, `w` column): `m = max_y x[y]`,
/// `t = min(31, (m - x[y]) >> shift)`, `out[y] = 127 >> t`. Monotone in
/// the input with range [0, 127]; the ALU program computes the same
/// values in the int32 accumulator (the `Mul imm -1` negation is exact
/// there, including for -128).
pub fn softmax_approx(inp: &[i8], n: usize, c: usize, h: usize, w: usize, shift: u32) -> Vec<i8> {
    assert_eq!(inp.len(), n * c * h * w);
    let mut out = vec![0i8; inp.len()];
    for bc in 0..n * c {
        for x in 0..w {
            let at = |y: usize| inp[(bc * h + y) * w + x];
            let m = (0..h).map(at).max().expect("h > 0") as i32;
            for y in 0..h {
                let t = ((m - at(y) as i32) >> shift).min(31);
                out[(bc * h + y) * w + x] = (127i32 >> t) as i8;
            }
        }
    }
    out
}

/// Per-head transpose of `[n][heads*bc][h]` (w = 1):
/// `out[b][hd*h + j][i] = in[b][hd*bc + i][j]`.
pub fn head_transpose(inp: &[i8], n: usize, c: usize, h: usize, heads: usize) -> Vec<i8> {
    assert_eq!(inp.len(), n * c * h);
    let bc = c / heads;
    let mut out = vec![0i8; inp.len()];
    for b in 0..n {
        for hd in 0..heads {
            for i in 0..bc {
                for j in 0..h {
                    out[(b * c + hd * h + j) * bc + i] = inp[(b * c + hd * bc + i) * h + j];
                }
            }
        }
    }
    out
}

/// Attention value mix: `probs` is `[n][heads*vs][ps]` (transposed
/// scores), `v` is `[n][vc][vs]`;
/// `out[b][hd*dh + d][s1] = requant(Σ_s2 v[hd*dh+d, s2]·
/// probs[hd*vs+s2, s1])` with `dh = vc / heads`. Output `[n][vc][ps]`.
#[allow(clippy::too_many_arguments)]
pub fn attn_mix(
    probs: &[i8],
    v: &[i8],
    n: usize,
    vc: usize,
    vs: usize,
    ps: usize,
    heads: usize,
    shift: u32,
) -> Vec<i8> {
    assert_eq!(probs.len(), n * heads * vs * ps);
    assert_eq!(v.len(), n * vc * vs);
    let dh = vc / heads;
    let mut out = vec![0i8; n * vc * ps];
    for b in 0..n {
        for hd in 0..heads {
            for d in 0..dh {
                for s1 in 0..ps {
                    let mut acc = 0i32;
                    for s2 in 0..vs {
                        acc += v[(b * vc + hd * dh + d) * vs + s2] as i32
                            * probs[((b * heads + hd) * vs + s2) * ps + s1] as i32;
                    }
                    out[(b * vc + hd * dh + d) * ps + s1] = requant(acc, shift, false);
                }
            }
        }
    }
    out
}

/// Shift-based layernorm approximation over the channel dim (`c` must
/// be a power of two): `mu = requant(Σ_c x, log2 c)` per position, then
/// `out = clamp(x - mu, -127, 127)`.
pub fn layernorm_approx(inp: &[i8], n: usize, c: usize, h: usize, w: usize) -> Vec<i8> {
    assert_eq!(inp.len(), n * c * h * w);
    let shift = crate::util::bitfield::clog2(c as u64);
    let mut out = vec![0i8; inp.len()];
    for b in 0..n {
        for y in 0..h * w {
            let mut sum = 0i32;
            for ch in 0..c {
                sum += inp[(b * c + ch) * h * w + y] as i32;
            }
            let mu = requant(sum, shift, false) as i32;
            for ch in 0..c {
                let i = (b * c + ch) * h * w + y;
                out[i] = (inp[i] as i32 - mu).clamp(-127, 127) as i8;
            }
        }
    }
    out
}

/// Channel-range copy `[start, start+len)` of an `[n][c][h*w]` tensor.
pub fn chan_slice(
    inp: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    start: usize,
    len: usize,
) -> Vec<i8> {
    assert_eq!(inp.len(), n * c * h * w);
    let hw = h * w;
    let mut out = Vec::with_capacity(n * len * hw);
    for b in 0..n {
        let base = (b * c + start) * hw;
        out.extend_from_slice(&inp[base..base + len * hw]);
    }
    out
}

/// Elementwise requantized product: `requant(a·b, shift, relu)` — the
/// paper's 8-bit eltwise multiply.
pub fn elt_mul(a: &[i8], b: &[i8], shift: u32, relu: bool) -> Vec<i8> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| requant(x as i32 * y as i32, shift, relu)).collect()
}

/// Piecewise-linear sigmoid: `clamp((x >> 1) + 32, 0, 96)` (arithmetic
/// shift, matching the ALU `Shr`).
pub fn hard_sigmoid(inp: &[i8]) -> Vec<i8> {
    inp.iter().map(|&v| (((v as i32) >> 1) + 32).clamp(0, 96) as i8).collect()
}

/// Piecewise-linear tanh: `clamp(x, -64, 64)`.
pub fn hard_tanh(inp: &[i8]) -> Vec<i8> {
    inp.iter().map(|&v| (v as i32).clamp(-64, 64) as i8).collect()
}

/// Default requantization shift for a layer accumulating `n_accum`
/// products of our synthetic data (values ~U[-8,8)): targets an output
/// std around 64 so outputs exercise the full int8 range without
/// saturating everywhere.
pub fn default_shift(n_accum: usize) -> u32 {
    // acc std ≈ (4.6)^2 * sqrt(n) ≈ 21*sqrt(n); shift ≈ log2(std/64).
    let std = 21.0 * (n_accum as f64).sqrt();
    (std / 64.0).log2().round().max(0.0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn requant_rounding_half_up() {
        assert_eq!(requant(5, 2, false), 1); // (5+2)>>2 = 1
        assert_eq!(requant(6, 2, false), 2); // (6+2)>>2 = 2
        assert_eq!(requant(-5, 2, false), -1); // (-5+2)>>2 = -3>>2 = -1
        assert_eq!(requant(1000, 0, false), 127);
        assert_eq!(requant(-1000, 0, false), -127);
        assert_eq!(requant(-5, 0, true), 0);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel, single channel, weight=1, shift=0: identity.
        let spec = ConvSpec { c_in: 1, c_out: 1, h: 3, w: 3, kh: 1, kw: 1, sh: 1, sw: 1, ph: 0, pw: 0 };
        let inp: Vec<i8> = (1..=9).collect();
        let out = conv2d(&inp, &[1], 1, &spec, 0, false);
        assert_eq!(out, inp);
    }

    #[test]
    fn conv_padding_and_stride() {
        // 3x3 sum kernel over a 3x3 image of ones with pad 1 stride 2:
        // corners see 4 ones, so output = [[4,4],[4,4]] at stride 2... the
        // center-adjacent sums differ; compute one by hand: oy=ox=0 sees
        // rows/cols -1..1 -> 4 valid ones.
        let spec = ConvSpec { c_in: 1, c_out: 1, h: 3, w: 3, kh: 3, kw: 3, sh: 2, sw: 2, ph: 1, pw: 1 };
        let inp = vec![1i8; 9];
        let out = conv2d(&inp, &[1i8; 9], 1, &spec, 0, false);
        assert_eq!(spec.oh(), 2);
        assert_eq!(out, vec![4, 4, 4, 4]);
    }

    #[test]
    fn maxpool_uses_neg128_padding() {
        let inp = vec![-100i8; 4]; // 1x1x2x2
        let out = maxpool(&inp, 1, 1, 2, 2, 3, 2, 1);
        // All windows include real -100s which beat the -128 pad.
        assert!(out.iter().all(|&v| v == -100));
    }

    #[test]
    fn global_avgpool_shift() {
        // 2x2 window, values 4,4,4,4: sum=16, shift=2 -> (16+2)>>2 = 4.
        let out = global_avgpool(&[4, 4, 4, 4], 1, 1, 2, 2);
        assert_eq!(out, vec![4]);
    }

    #[test]
    fn add_clips() {
        assert_eq!(add(&[100], &[100], false), vec![127]);
        assert_eq!(add(&[-100], &[-100], false), vec![-127]);
        assert_eq!(add(&[-5], &[2], true), vec![0]);
        assert_eq!(add(&[3], &[4], false), vec![7]);
    }

    #[test]
    fn depthwise_per_channel() {
        // 2 channels, 1x1 taps [2, 3]: channel i scaled by tap i.
        let inp = vec![1i8, 2, 3, 4]; // c0=[1,2], c1=[3,4] (h=1,w=2)
        let out = depthwise(&inp, &[2, 3], 1, 2, 1, 2, 1, 1, 1, 0, 0, false);
        assert_eq!(out, vec![2, 4, 9, 12]);
    }

    #[test]
    fn dense_matches_manual() {
        // inp [1,2], w = [[1,1],[2,-1]] -> [3, 0]
        let out = dense(&[1, 2], &[1, 1, 2, -1], 1, 2, 2, 0, false);
        assert_eq!(out, vec![3, 0]);
    }

    #[test]
    fn softmax_peak_and_floor() {
        // Single column: the max gets 127, values far below the max
        // (after the shift) collapse toward 0, and order is preserved.
        let out = softmax_approx(&[40, 50, -100, 46], 1, 1, 4, 1, 2);
        // t = (m - x) >> 2 capped at 31: [2, 0, 31, 1] -> 127 >> t.
        assert_eq!(out, vec![31, 127, 0, 63]);
    }

    #[test]
    fn attn_scores_single_head_manual() {
        // 2 dims, 2 positions, 1 head: plain Q^T K.
        // q = [[1,2],[3,4]] (d x s), k = [[1,0],[0,1]].
        let q = [1, 2, 3, 4];
        let k = [1, 0, 0, 1];
        let out = attn_scores(&q, &k, 1, 2, 2, 1, 0);
        // out[s1][s2] = sum_d q[d][s1]*k[d][s2]
        assert_eq!(out, vec![1, 3, 2, 4]);
    }

    #[test]
    fn head_transpose_round_trips() {
        let mut rng = Pcg32::seeded(5);
        let x = rng.i8_vec(8 * 4); // heads=2, bc=4, h=4
        let t = head_transpose(&x, 1, 8, 4, 2);
        assert_eq!(head_transpose(&t, 1, 8, 4, 2), x);
    }

    #[test]
    fn attn_mix_identity_probs() {
        // Identity probs (transposed one-hot) reproduce V.
        let v = [1i8, 2, 3, 4]; // vc=2, vs=2
        let probs = [1, 0, 0, 1]; // heads=1, vs=2, ps=2
        assert_eq!(attn_mix(&probs, &v, 1, 2, 2, 2, 1, 0), v);
    }

    #[test]
    fn layernorm_centers_and_clips() {
        // c=4, one position: mean of [10,20,30,40] = requant(100,2) = 25.
        let out = layernorm_approx(&[10, 20, 30, 40], 1, 4, 1, 1);
        assert_eq!(out, vec![-15, -5, 5, 15]);
        // Saturating case still clips to ±127.
        let out = layernorm_approx(&[127, 127, -128, -128], 1, 4, 1, 1);
        assert!(out.iter().all(|&v| (-127..=127).contains(&v)));
    }

    #[test]
    fn gate_math_matches_manual() {
        assert_eq!(chan_slice(&[1, 2, 3, 4, 5, 6], 1, 3, 1, 2, 1, 2), vec![3, 4, 5, 6]);
        assert_eq!(elt_mul(&[10, -10], &[13, 13], 3, false), vec![16, -16]);
        assert_eq!(elt_mul(&[127], &[127], 0, false), vec![127]);
        assert_eq!(hard_sigmoid(&[-128, -64, 0, 64, 127]), vec![0, 0, 32, 64, 95]);
        assert_eq!(hard_tanh(&[-128, -10, 70]), vec![-64, -10, 64]);
    }

    #[test]
    fn default_shift_reasonable() {
        assert!(default_shift(64 * 9) >= 3);
        assert!(default_shift(64 * 9) <= 6);
        assert_eq!(default_shift(1), 0);
        let mut rng = Pcg32::seeded(1);
        // Statistical check: conv output under default shift is neither
        // all-zero nor all-saturated.
        let spec = ConvSpec { c_in: 16, c_out: 8, h: 8, w: 8, kh: 3, kw: 3, sh: 1, sw: 1, ph: 1, pw: 1 };
        let inp = rng.i8_vec(16 * 64);
        let wgt = rng.i8_vec(8 * 16 * 9);
        let out = conv2d(&inp, &wgt, 1, &spec, default_shift(16 * 9), true);
        let sat = out.iter().filter(|&&v| v == 127).count();
        let zero = out.iter().filter(|&&v| v == 0).count();
        assert!(sat < out.len() / 2, "too saturated: {sat}/{}", out.len());
        assert!(zero < out.len(), "all zero");
    }
}
