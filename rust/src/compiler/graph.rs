//! Neural-network graph IR.
//!
//! The paper's stack ingests Relay graphs; here the equivalent role is a
//! small DAG IR with the quantized operators the evaluation needs
//! (convolution, depthwise convolution, dense, pooling, residual add).
//! Weights are attached to nodes directly (synthetic int8, seeded — see
//! DESIGN.md §Substitutions). The IR also executes on the CPU reference
//! ops, which is both the fallback path for channel-light layers and a
//! whole-network golden model.

use super::cpu_ref;
use super::layout::Shape;
use super::tps::ConvSpec;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Standard convolution, OIHW weights, square kernel/stride/pad.
    Conv { c_out: usize, k: usize, stride: usize, pad: usize, shift: u32, relu: bool, weights: Vec<i8> },
    /// Depthwise convolution, CHW (per-channel taps) weights.
    Depthwise { k: usize, stride: usize, pad: usize, shift: u32, relu: bool, weights: Vec<i8> },
    /// Fully connected over a (c,1,1) input.
    Dense { units: usize, shift: u32, relu: bool, weights: Vec<i8> },
    MaxPool { k: usize, stride: usize, pad: usize },
    GlobalAvgPool,
    /// Residual addition of two equal-shape inputs, then optional ReLU.
    Add { relu: bool },
    /// Per-head attention scores over `(d_model, seq, 1)` Q and K
    /// tensors (channel = model dim, spatial h = sequence position):
    /// `out[hd*S + s1, s2] = requant(Σ_d q[hd*Dh+d, s1]·k[hd*Dh+d, s2],
    /// shift)`. Output `(heads*S, S, 1)`; channel = query position,
    /// spatial = key position. Lowered as one GEMM per head with the
    /// Q slice re-read as weights (data-dependent, hence batch-1 on
    /// the accelerator — see [`attn_on_vta`]).
    AttnScores { heads: usize, shift: u32 },
    /// Shift-based softmax approximation along spatial h, per channel
    /// lane: `m = max_y x[c,y]`, `t = min(31, (m − x) >> shift)`,
    /// `out = 127 >> t` — monotone in the input, range `[0, 127]`,
    /// built entirely from Max/Mul/Add/Shr/Mov ALU ops.
    SoftmaxApprox { shift: u32 },
    /// Per-head transpose of a `(heads*Bc, H, 1)` tensor:
    /// `out[hd*H + j, i] = in[hd*Bc + i, j]`, output
    /// `(heads*H, Bc, 1)`. A zero-cost CPU marshal between the two
    /// attention GEMMs (the scratchpads have no transposed access
    /// path).
    HeadTranspose { heads: usize },
    /// Attention value mix: inputs `[probs_t, v]` where `probs_t` is
    /// the [`Op::HeadTranspose`] of the score probabilities and `v` is
    /// `(d_model, seq, 1)`:
    /// `out[hd*Dh+d, s1] = requant(Σ_s2 v[hd*Dh+d, s2]·
    /// probs_t[hd*S+s2, s1], shift)`. Output matches `v`'s shape.
    AttnMix { heads: usize, shift: u32 },
    /// Shift-based layernorm approximation over the channel dim (which
    /// must be a power of two so the mean is an exact shift):
    /// `mu[y,x] = requant(Σ_c x[c,y,x], log2 C)`,
    /// `out = clamp(x − mu, −127, 127)` — centers each position
    /// without the (hardware-free) variance divide.
    LayerNormApprox,
    /// Channel-range view `[start, start+len)` of the input — how the
    /// fused LSTM gate GEMM output is split into its four gates.
    ChanSlice { start: usize, len: usize },
    /// Elementwise product of two equal-shape tensors, requantized:
    /// `out = requant(a·b, shift, relu)` (the paper's 8-bit eltwise
    /// multiply ISA increment).
    EltMul { shift: u32, relu: bool },
    /// Piecewise-linear sigmoid on the i8 domain:
    /// `out = clamp((x >> 1) + 32, 0, 96)` (Shr/Add/Max/Min
    /// immediates; the shift is arithmetic, matching the ALU).
    HardSigmoid,
    /// Piecewise-linear tanh on the i8 domain: `out = clamp(x, ±64)`
    /// (a single Clip immediate).
    HardTanh,
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv { .. } => "conv",
            Op::Depthwise { .. } => "depthwise",
            Op::Dense { .. } => "dense",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool => "avgpool",
            Op::Add { .. } => "add",
            Op::AttnScores { .. } => "attn_scores",
            Op::SoftmaxApprox { .. } => "softmax_approx",
            Op::HeadTranspose { .. } => "head_transpose",
            Op::AttnMix { .. } => "attn_mix",
            Op::LayerNormApprox => "layernorm_approx",
            Op::ChanSlice { .. } => "chan_slice",
            Op::EltMul { .. } => "elt_mul",
            Op::HardSigmoid => "hard_sigmoid",
            Op::HardTanh => "hard_tanh",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: Op,
    /// Indices of producer nodes (one, except `Add` which takes two).
    pub inputs: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub input_shape: Shape,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str, input_shape: Shape) -> Graph {
        Graph {
            name: name.to_string(),
            input_shape,
            nodes: vec![Node { name: "input".into(), op: Op::Input, inputs: vec![] }],
        }
    }

    /// Append a node; returns its index.
    pub fn add(&mut self, name: &str, op: Op, inputs: Vec<usize>) -> usize {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "forward reference in graph");
        }
        self.nodes.push(Node { name: name.to_string(), op, inputs });
        self.nodes.len() - 1
    }

    /// Structural validation: node arity and edge direction, operator
    /// geometry (kernel vs. padded input, strides, dense/pool input
    /// shapes, a sanity cap on every dimension so no arithmetic can
    /// overflow), and weight-tensor sizes. Returns a description of the
    /// first defect. Runtimes call this before execution so malformed
    /// graphs are rejected with an error instead of panicking mid-run;
    /// a graph that passes cannot make [`Graph::shapes`] or the
    /// staging/lowering paths fault on its structure.
    pub fn validate(&self) -> Result<(), String> {
        self.try_shapes().map(|_| ())
    }

    /// Per-node output shapes. Panics on a malformed graph — callers on
    /// untrusted input go through [`Graph::validate`] (or the engine,
    /// which does) first; both share [`Graph::try_shapes`], so the
    /// validated rules and the executed rules cannot drift.
    pub fn shapes(&self) -> Vec<Shape> {
        self.try_shapes().expect("malformed graph (run Graph::validate first)")
    }

    /// Fallible shape propagation — the single source of truth behind
    /// [`Graph::validate`] and [`Graph::shapes`].
    pub fn try_shapes(&self) -> Result<Vec<Shape>, String> {
        // Any single dimension (channel, spatial, kernel, stride, pad)
        // above this is a malformed graph, not a workload — the cap
        // keeps every downstream sum within `usize` on all supported
        // targets (products go through `weight_len`).
        const DIM_LIMIT: usize = 1 << 20;
        fn windowed(s: Shape, k: usize, stride: usize, pad: usize) -> Result<Shape, String> {
            if k == 0 || stride == 0 {
                return Err(format!("kernel {k} / stride {stride} must be positive"));
            }
            if k > DIM_LIMIT || stride > DIM_LIMIT || pad > DIM_LIMIT {
                return Err(format!("kernel {k} / stride {stride} / pad {pad} implausibly large"));
            }
            if s.h + 2 * pad < k || s.w + 2 * pad < k {
                return Err(format!(
                    "kernel {k} exceeds padded input {}x{} (pad {pad})",
                    s.h, s.w
                ));
            }
            Ok(Shape::new(
                s.c,
                (s.h + 2 * pad - k) / stride + 1,
                (s.w + 2 * pad - k) / stride + 1,
            ))
        }
        // Checked product for expected weight-tensor lengths.
        fn weight_len(dims: &[usize]) -> Result<usize, String> {
            dims.iter().try_fold(1usize, |acc, &d| {
                acc.checked_mul(d).ok_or_else(|| "weight tensor size overflows".to_string())
            })
        }
        if self.nodes.is_empty() || !matches!(self.nodes[0].op, Op::Input) {
            return Err("graph must start with its input node".into());
        }
        let s0 = self.input_shape;
        if s0.c == 0 || s0.h == 0 || s0.w == 0 || s0.c > DIM_LIMIT || s0.h > DIM_LIMIT
            || s0.w > DIM_LIMIT
        {
            return Err(format!("implausible input shape {s0:?}"));
        }
        let mut shapes: Vec<Shape> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let fail = |msg: String| Err(format!("node '{}': {msg}", node.name));
            let arity = match node.op {
                Op::Input => 0,
                Op::Add { .. } | Op::AttnScores { .. } | Op::AttnMix { .. }
                | Op::EltMul { .. } => 2,
                _ => 1,
            };
            if node.inputs.len() != arity {
                return fail(format!("{} inputs, operator expects {arity}", node.inputs.len()));
            }
            if node.inputs.iter().any(|&j| j >= i) {
                return fail("references itself or a later node".into());
            }
            let shape = match &node.op {
                Op::Input => {
                    if i != 0 {
                        return fail("input placeholder in graph interior".into());
                    }
                    s0
                }
                Op::Conv { c_out, k, stride, pad, weights, .. } => {
                    let s = shapes[node.inputs[0]];
                    if *c_out == 0 || *c_out > DIM_LIMIT {
                        return fail(format!("implausible output channel count {c_out}"));
                    }
                    let w = match windowed(s, *k, *stride, *pad) {
                        Ok(out) => Shape::new(*c_out, out.h, out.w),
                        Err(msg) => return fail(msg),
                    };
                    match weight_len(&[*c_out, s.c, *k, *k]) {
                        Ok(want) if weights.len() == want => {}
                        Ok(want) => {
                            return fail(format!("{} weights, conv needs {want}", weights.len()))
                        }
                        Err(msg) => return fail(msg),
                    }
                    w
                }
                Op::Depthwise { k, stride, pad, weights, .. } => {
                    let s = shapes[node.inputs[0]];
                    let w = match windowed(s, *k, *stride, *pad) {
                        Ok(out) => out,
                        Err(msg) => return fail(msg),
                    };
                    match weight_len(&[s.c, *k, *k]) {
                        Ok(want) if weights.len() == want => {}
                        Ok(want) => {
                            return fail(format!(
                                "{} weights, depthwise needs {want}",
                                weights.len()
                            ))
                        }
                        Err(msg) => return fail(msg),
                    }
                    w
                }
                Op::Dense { units, weights, .. } => {
                    let s = shapes[node.inputs[0]];
                    if (s.h, s.w) != (1, 1) {
                        return fail(format!("dense expects a (c,1,1) input, got {s:?}"));
                    }
                    if *units == 0 || *units > DIM_LIMIT {
                        return fail(format!("implausible unit count {units}"));
                    }
                    match weight_len(&[*units, s.c]) {
                        Ok(want) if weights.len() == want => {}
                        Ok(want) => {
                            return fail(format!("{} weights, dense needs {want}", weights.len()))
                        }
                        Err(msg) => return fail(msg),
                    }
                    Shape::new(*units, 1, 1)
                }
                Op::MaxPool { k, stride, pad } => {
                    let s = shapes[node.inputs[0]];
                    match windowed(s, *k, *stride, *pad) {
                        Ok(out) => out,
                        Err(msg) => return fail(msg),
                    }
                }
                Op::GlobalAvgPool => {
                    let s = shapes[node.inputs[0]];
                    if s.h != s.w {
                        return fail(format!("global pool expects a square input, got {s:?}"));
                    }
                    Shape::new(s.c, 1, 1)
                }
                Op::Add { .. } => {
                    let a = shapes[node.inputs[0]];
                    let b = shapes[node.inputs[1]];
                    if a != b {
                        return fail(format!("add of unequal shapes {a:?} vs {b:?}"));
                    }
                    a
                }
                Op::AttnScores { heads, shift } => {
                    let q = shapes[node.inputs[0]];
                    let k = shapes[node.inputs[1]];
                    if q != k {
                        return fail(format!("attn_scores of unequal shapes {q:?} vs {k:?}"));
                    }
                    if q.w != 1 {
                        return fail(format!("attn_scores expects a (c,seq,1) input, got {q:?}"));
                    }
                    if *heads == 0 || q.c % heads != 0 {
                        return fail(format!("{} channels not divisible into {heads} heads", q.c));
                    }
                    if *shift > 31 {
                        return fail(format!("shift {shift} exceeds the 5-bit ALU shift range"));
                    }
                    match weight_len(&[*heads, q.h]) {
                        Ok(oc) if oc <= DIM_LIMIT => Shape::new(oc, q.h, 1),
                        Ok(oc) => return fail(format!("implausible score channel count {oc}")),
                        Err(msg) => return fail(msg),
                    }
                }
                Op::SoftmaxApprox { shift } => {
                    if *shift > 31 {
                        return fail(format!("shift {shift} exceeds the 5-bit ALU shift range"));
                    }
                    shapes[node.inputs[0]]
                }
                Op::HeadTranspose { heads } => {
                    let s = shapes[node.inputs[0]];
                    if s.w != 1 {
                        return fail(format!("head_transpose expects a (c,h,1) input, got {s:?}"));
                    }
                    if *heads == 0 || s.c % heads != 0 {
                        return fail(format!("{} channels not divisible into {heads} heads", s.c));
                    }
                    match weight_len(&[*heads, s.h]) {
                        Ok(oc) if oc <= DIM_LIMIT => Shape::new(oc, s.c / heads, 1),
                        Ok(oc) => {
                            return fail(format!("implausible transposed channel count {oc}"))
                        }
                        Err(msg) => return fail(msg),
                    }
                }
                Op::AttnMix { heads, shift } => {
                    let p = shapes[node.inputs[0]];
                    let v = shapes[node.inputs[1]];
                    if p.w != 1 || v.w != 1 {
                        return fail(format!(
                            "attn_mix expects (c,seq,1) inputs, got {p:?} and {v:?}"
                        ));
                    }
                    if *heads == 0 || v.c % heads != 0 {
                        return fail(format!("{} channels not divisible into {heads} heads", v.c));
                    }
                    if p.c % heads != 0 || p.c / heads != v.h {
                        return fail(format!(
                            "probs channels {} must be heads {heads} x value seq {}",
                            p.c, v.h
                        ));
                    }
                    if *shift > 31 {
                        return fail(format!("shift {shift} exceeds the 5-bit ALU shift range"));
                    }
                    Shape::new(v.c, p.h, 1)
                }
                Op::LayerNormApprox => {
                    let s = shapes[node.inputs[0]];
                    if !s.c.is_power_of_two() {
                        return fail(format!(
                            "layernorm_approx needs a power-of-two channel count, got {}",
                            s.c
                        ));
                    }
                    s
                }
                Op::ChanSlice { start, len } => {
                    let s = shapes[node.inputs[0]];
                    if *len == 0 {
                        return fail("empty channel slice".into());
                    }
                    match start.checked_add(*len) {
                        Some(end) if end <= s.c => Shape::new(*len, s.h, s.w),
                        _ => {
                            return fail(format!(
                                "slice [{start}, {start}+{len}) exceeds {} channels",
                                s.c
                            ))
                        }
                    }
                }
                Op::EltMul { shift, .. } => {
                    let a = shapes[node.inputs[0]];
                    let b = shapes[node.inputs[1]];
                    if a != b {
                        return fail(format!("elt_mul of unequal shapes {a:?} vs {b:?}"));
                    }
                    if *shift > 31 {
                        return fail(format!("shift {shift} exceeds the 5-bit ALU shift range"));
                    }
                    a
                }
                Op::HardSigmoid | Op::HardTanh => shapes[node.inputs[0]],
            };
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// The conv spec of a `Conv` node given its input shape.
    pub fn conv_spec(&self, idx: usize, shapes: &[Shape]) -> ConvSpec {
        match &self.nodes[idx].op {
            Op::Conv { c_out, k, stride, pad, .. } => {
                let s = shapes[self.nodes[idx].inputs[0]];
                ConvSpec {
                    c_in: s.c,
                    c_out: *c_out,
                    h: s.h,
                    w: s.w,
                    kh: *k,
                    kw: *k,
                    sh: *stride,
                    sw: *stride,
                    ph: *pad,
                    pw: *pad,
                }
            }
            Op::Dense { units, .. } => {
                let s = shapes[self.nodes[idx].inputs[0]];
                ConvSpec {
                    c_in: s.c,
                    c_out: *units,
                    h: 1,
                    w: 1,
                    kh: 1,
                    kw: 1,
                    sh: 1,
                    sw: 1,
                    ph: 0,
                    pw: 0,
                }
            }
            other => panic!("conv_spec on non-conv node {other:?}"),
        }
    }

    /// The per-head GEMM spec of an `AttnScores`/`AttnMix` node: the
    /// 1x1 "conv" one head executes on the GEMM core (c_in = reduction
    /// dim, c_out = per-head output channels, h = output positions).
    pub fn attn_head_spec(&self, idx: usize, shapes: &[Shape]) -> ConvSpec {
        let unit =
            ConvSpec { c_in: 0, c_out: 0, h: 0, w: 1, kh: 1, kw: 1, sh: 1, sw: 1, ph: 0, pw: 0 };
        match &self.nodes[idx].op {
            Op::AttnScores { heads, .. } => {
                let q = shapes[self.nodes[idx].inputs[0]];
                ConvSpec { c_in: q.c / heads, c_out: q.h, h: q.h, ..unit }
            }
            Op::AttnMix { heads, .. } => {
                let p = shapes[self.nodes[idx].inputs[0]];
                let v = shapes[self.nodes[idx].inputs[1]];
                ConvSpec { c_in: v.h, c_out: v.c / heads, h: p.h, ..unit }
            }
            other => panic!("attn_head_spec on non-attention node {other:?}"),
        }
    }

    /// Execute the whole graph with the CPU reference ops (the rust-side
    /// golden model). `input` is `[batch][c][h][w]`.
    pub fn run_cpu(&self, input: &[i8], batch: usize) -> Vec<i8> {
        let shapes = self.shapes();
        let mut outputs: Vec<Option<Vec<i8>>> = vec![None; self.nodes.len()];
        outputs[0] = Some(input.to_vec());
        for (i, node) in self.nodes.iter().enumerate().skip(1) {
            let get = |j: usize| outputs[j].as_ref().expect("producer not computed");
            let out = match &node.op {
                Op::Input => unreachable!(),
                Op::Conv { shift, relu, weights, .. } => {
                    let spec = self.conv_spec(i, &shapes);
                    cpu_ref::conv2d(get(node.inputs[0]), weights, batch, &spec, *shift, *relu)
                }
                Op::Depthwise { k, stride, pad, shift, relu, weights } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::depthwise(
                        get(node.inputs[0]),
                        weights,
                        batch,
                        s.c,
                        s.h,
                        s.w,
                        *k,
                        *k,
                        *stride,
                        *pad,
                        *shift,
                        *relu,
                    )
                }
                Op::Dense { units, shift, relu, weights } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::dense(get(node.inputs[0]), weights, batch, s.c, *units, *shift, *relu)
                }
                Op::MaxPool { k, stride, pad } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::maxpool(get(node.inputs[0]), batch, s.c, s.h, s.w, *k, *stride, *pad)
                }
                Op::GlobalAvgPool => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::global_avgpool(get(node.inputs[0]), batch, s.c, s.h, s.w)
                }
                Op::Add { relu } => {
                    cpu_ref::add(get(node.inputs[0]), get(node.inputs[1]), *relu)
                }
                Op::AttnScores { heads, shift } => {
                    let q = shapes[node.inputs[0]];
                    cpu_ref::attn_scores(
                        get(node.inputs[0]),
                        get(node.inputs[1]),
                        batch,
                        q.c,
                        q.h,
                        *heads,
                        *shift,
                    )
                }
                Op::SoftmaxApprox { shift } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::softmax_approx(get(node.inputs[0]), batch, s.c, s.h, s.w, *shift)
                }
                Op::HeadTranspose { heads } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::head_transpose(get(node.inputs[0]), batch, s.c, s.h, *heads)
                }
                Op::AttnMix { heads, shift } => {
                    let p = shapes[node.inputs[0]];
                    let v = shapes[node.inputs[1]];
                    cpu_ref::attn_mix(
                        get(node.inputs[0]),
                        get(node.inputs[1]),
                        batch,
                        v.c,
                        v.h,
                        p.h,
                        *heads,
                        *shift,
                    )
                }
                Op::LayerNormApprox => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::layernorm_approx(get(node.inputs[0]), batch, s.c, s.h, s.w)
                }
                Op::ChanSlice { start, len } => {
                    let s = shapes[node.inputs[0]];
                    cpu_ref::chan_slice(get(node.inputs[0]), batch, s.c, s.h, s.w, *start, *len)
                }
                Op::EltMul { shift, relu } => {
                    cpu_ref::elt_mul(get(node.inputs[0]), get(node.inputs[1]), *shift, *relu)
                }
                Op::HardSigmoid => cpu_ref::hard_sigmoid(get(node.inputs[0])),
                Op::HardTanh => cpu_ref::hard_tanh(get(node.inputs[0])),
            };
            outputs[i] = Some(out);
        }
        outputs.pop().unwrap().unwrap()
    }

    /// Total GEMM-unit MACs a hardware config executes for this graph
    /// (padded channels; CPU-fallback and ALU layers excluded).
    pub fn vta_macs(&self, cfg: &crate::config::VtaConfig) -> u64 {
        let shapes = self.shapes();
        let mut total = 0u64;
        for (i, node) in self.nodes.iter().enumerate() {
            match &node.op {
                Op::Conv { .. } | Op::Dense { .. } => {
                    let spec = self.conv_spec(i, &shapes);
                    if spec.c_in >= cfg.block_in {
                        total += spec.macs(cfg);
                    }
                }
                Op::AttnScores { heads, .. } | Op::AttnMix { heads, .. } => {
                    let spec = self.attn_head_spec(i, &shapes);
                    if attn_on_vta(cfg, &spec) {
                        total += *heads as u64 * spec.macs(cfg);
                    }
                }
                Op::LayerNormApprox => {
                    let s = shapes[node.inputs[0]];
                    let spec = layernorm_mean_spec(s);
                    if spec.c_in >= cfg.block_in {
                        total += spec.macs(cfg);
                    }
                }
                _ => {}
            }
        }
        total
    }
}

/// The all-ones C -> C 1x1 "conv" that computes the layernorm channel
/// mean (every output channel carries the same mean, so the eltwise
/// subtract stage can read it lane-aligned).
pub fn layernorm_mean_spec(s: Shape) -> ConvSpec {
    ConvSpec { c_in: s.c, c_out: s.c, h: s.h, w: s.w, kh: 1, kw: 1, sh: 1, sw: 1, ph: 0, pw: 0 }
}

/// Whether an attention head GEMM runs on the accelerator for `cfg`.
/// Requires batch 1 (the weights are the data-dependent Q/probs slice,
/// read back per inference) and tile-aligned head slices on both sides
/// so each head's channel sub-range is a whole number of scratchpad
/// tiles (unaligned c_out would spill padded tiles into the next
/// head's DRAM slice). Must stay a pure function of (cfg, spec): every
/// backend and the analytical model key off the same decision.
pub fn attn_on_vta(cfg: &crate::config::VtaConfig, spec: &ConvSpec) -> bool {
    cfg.batch == 1
        && spec.c_in >= cfg.block_in
        && spec.c_in % cfg.block_in == 0
        && spec.c_out % cfg.block_out == 0
}

/// Whether the softmax-approx ALU program for a `(c, h, w)` tensor fits
/// the configured scratchpads: per channel tile it stages the inputs,
/// the running max (one tile per w column) and the output
/// simultaneously, reduces over `h` in one ALU loop, and addresses
/// `8 * w` uops.
pub fn softmax_on_vta(cfg: &crate::config::VtaConfig, s: Shape) -> bool {
    let max_loop = (1usize << cfg.isa_layout().loop_bits) - 1;
    2 * s.h * s.w + s.w <= cfg.acc_depth && s.h <= max_loop && 8 * s.w <= cfg.uop_depth
}

/// Random conv weights helper for workload construction.
pub fn rand_weights(rng: &mut Pcg32, len: usize) -> Vec<i8> {
    rng.i8_vec(len)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut rng = Pcg32::seeded(1);
        let mut g = Graph::new("tiny", Shape::new(4, 8, 8));
        let c1 = g.add(
            "conv1",
            Op::Conv {
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
                shift: 4,
                relu: true,
                weights: rand_weights(&mut rng, 8 * 4 * 9),
            },
            vec![0],
        );
        let p = g.add("pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![c1]);
        let gap = g.add("gap", Op::GlobalAvgPool, vec![p]);
        g.add(
            "fc",
            Op::Dense { units: 10, shift: 3, relu: false, weights: rand_weights(&mut rng, 10 * 8) },
            vec![gap],
        );
        g
    }

    #[test]
    fn validate_accepts_real_and_rejects_malformed() {
        assert!(tiny_graph().validate().is_ok());
        // Wrong arity: Add with one operand.
        let mut g = Graph::new("bad-add", Shape::new(4, 4, 4));
        g.add("add", Op::Add { relu: false }, vec![0]);
        assert!(g.validate().is_err());
        // Kernel larger than the padded input.
        let mut g = Graph::new("bad-k", Shape::new(4, 2, 2));
        g.add(
            "conv",
            Op::Conv {
                c_out: 4,
                k: 5,
                stride: 1,
                pad: 0,
                shift: 0,
                relu: false,
                weights: vec![0; 4 * 4 * 25],
            },
            vec![0],
        );
        assert!(g.validate().is_err());
        // Wrong weight-tensor size.
        let mut g = Graph::new("bad-w", Shape::new(4, 4, 4));
        g.add(
            "conv",
            Op::Conv {
                c_out: 4,
                k: 1,
                stride: 1,
                pad: 0,
                shift: 0,
                relu: false,
                weights: vec![0; 15],
            },
            vec![0],
        );
        assert!(g.validate().is_err());
        // Absurd padding is an error, never an arithmetic panic.
        let mut g = Graph::new("bad-pad", Shape::new(4, 4, 4));
        g.add("pool", Op::MaxPool { k: 2, stride: 1, pad: usize::MAX / 2 }, vec![0]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn shapes_inferred() {
        let g = tiny_graph();
        let shapes = g.shapes();
        assert_eq!(shapes[1], Shape::new(8, 8, 8));
        assert_eq!(shapes[2], Shape::new(8, 4, 4));
        assert_eq!(shapes[3], Shape::new(8, 1, 1));
        assert_eq!(shapes[4], Shape::new(10, 1, 1));
    }

    #[test]
    fn cpu_execution_produces_output() {
        let g = tiny_graph();
        let mut rng = Pcg32::seeded(2);
        let input = rng.i8_vec(4 * 8 * 8);
        let out = g.run_cpu(&input, 1);
        assert_eq!(out.len(), 10);
        assert!(out.iter().any(|&v| v != 0), "degenerate output");
    }

    #[test]
    fn residual_add_shape_check() {
        let mut g = Graph::new("res", Shape::new(4, 4, 4));
        let a = g.add(
            "c1",
            Op::Conv { c_out: 4, k: 1, stride: 1, pad: 0, shift: 0, relu: false, weights: vec![1; 16] },
            vec![0],
        );
        let add = g.add("add", Op::Add { relu: true }, vec![a, 0]);
        let shapes = g.shapes();
        assert_eq!(shapes[add], Shape::new(4, 4, 4));
    }

    #[test]
    fn vta_macs_excludes_thin_convs() {
        let mut rng = Pcg32::seeded(3);
        let cfg = crate::config::presets::default_config();
        let mut g = Graph::new("thin", Shape::new(3, 8, 8));
        g.add(
            "conv1",
            Op::Conv {
                c_out: 16,
                k: 3,
                stride: 1,
                pad: 1,
                shift: 3,
                relu: false,
                weights: rand_weights(&mut rng, 16 * 3 * 9),
            },
            vec![0],
        );
        // 3-channel conv runs on CPU: no VTA MACs.
        assert_eq!(g.vta_macs(&cfg), 0);
    }
}
