//! Layer-program builder — the compiler-facing half of the JIT runtime
//! (§II-C).
//!
//! Mirrors the VTA runtime's API surface: schedules *push* uop sequences
//! (deduplicated through a cache, one of the paper's "runtime
//! enhancements to lower uop count") and instruction packets; `finish`
//! stages the uop stream into DRAM, prepends the uop-load instruction,
//! runs dependency-token insertion and flattens everything into the final
//! instruction stream for one accelerator kernel launch.

use super::packet::{flatten, insert_deps, PMod, Packet, Region};
use crate::config::{IsaLayout, VtaConfig};
use crate::isa::{BufferId, DepFlags, Insn, MemInsn, Opcode, Uop};
use crate::mem::Dram;
use std::collections::HashMap;

/// A fully lowered layer program, ready to run on any target.
#[derive(Debug, Clone)]
pub struct Program {
    pub label: String,
    pub insns: Vec<Insn>,
    /// Number of uops staged in DRAM for this program.
    pub uop_count: usize,
}

pub struct ProgramBuilder {
    pub cfg: VtaConfig,
    pub layout: IsaLayout,
    packets: Vec<Packet>,
    uops: Vec<Uop>,
    cache: HashMap<Vec<Uop>, (u32, u32)>,
    pub cache_hits: u64,
}

impl ProgramBuilder {
    pub fn new(cfg: &VtaConfig) -> ProgramBuilder {
        ProgramBuilder {
            cfg: cfg.clone(),
            layout: cfg.isa_layout(),
            packets: Vec::new(),
            uops: Vec::new(),
            cache: HashMap::new(),
            cache_hits: 0,
        }
    }

    /// Register a uop sequence, deduplicating identical sequences, and
    /// return its `[bgn, end)` range in the uop buffer.
    pub fn uop_seq(&mut self, seq: Vec<Uop>) -> (u32, u32) {
        assert!(!seq.is_empty(), "empty uop sequence");
        if let Some(&range) = self.cache.get(&seq) {
            self.cache_hits += 1;
            return range;
        }
        let bgn = self.uops.len() as u32;
        let end = bgn + seq.len() as u32;
        assert!(
            (end as usize) <= self.cfg.uop_depth,
            "uop buffer overflow: {} uops > depth {} (tiling should have \
             been rejected by TPS feasibility)",
            end,
            self.cfg.uop_depth
        );
        self.uops.extend_from_slice(&seq);
        self.cache.insert(seq, (bgn, end));
        (bgn, end)
    }

    pub fn push(&mut self, packet: Packet) {
        debug_assert!(!packet.insns.is_empty());
        self.packets.push(packet);
    }

    pub fn uop_len(&self) -> usize {
        self.uops.len()
    }

    /// Stage uops to DRAM, prepend the uop load, insert dependency
    /// tokens, append FINISH, and flatten to the final stream.
    pub fn finish(mut self, label: &str, dram: &mut Dram) -> Program {
        let uop_count = self.uops.len();
        let mut all = Vec::with_capacity(self.packets.len() + 2);
        if uop_count > 0 {
            let ub = self.layout.uop_bytes();
            let bytes = Uop::stream_to_bytes(&self.uops, &self.layout);
            let region = dram.alloc(bytes.len(), ub);
            dram.write(region.addr, &bytes);
            // The uop buffer is loaded by the compute module; chunk the
            // load if a huge stream exceeds the x_size field.
            let max_x = (1u32 << self.layout.mem_size_bits) - 1;
            let mut off = 0u32;
            let mut insns = Vec::new();
            while off < uop_count as u32 {
                let n = (uop_count as u32 - off).min(max_x);
                insns.push(Insn::Mem(MemInsn {
                    opcode: Opcode::Load,
                    deps: DepFlags::NONE,
                    buffer: BufferId::Uop,
                    sram_base: off,
                    dram_base: region.tile_base(ub) + off,
                    y_size: 1,
                    x_size: n,
                    x_stride: n,
                    y_pad0: 0,
                    y_pad1: 0,
                    x_pad0: 0,
                    x_pad1: 0,
                    pad_value: 0,
                }));
                off += n;
            }
            all.push(Packet::new(PMod::Compute, insns).write(Region::new(
                BufferId::Uop,
                0,
                uop_count as u32,
            )));
        }
        all.append(&mut self.packets);
        insert_deps(&mut all);
        let mut insns = flatten(all);
        insns.push(Insn::Finish(DepFlags::NONE));
        Program { label: label.to_string(), insns, uop_count }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn uop_dedup() {
        let cfg = presets::tiny_config();
        let mut b = ProgramBuilder::new(&cfg);
        let seq = vec![Uop::gemm(0, 0, 0), Uop::gemm(1, 1, 1)];
        let r1 = b.uop_seq(seq.clone());
        let r2 = b.uop_seq(seq);
        assert_eq!(r1, r2);
        assert_eq!(b.cache_hits, 1);
        assert_eq!(b.uop_len(), 2);
        let r3 = b.uop_seq(vec![Uop::gemm(2, 0, 0)]);
        assert_eq!(r3, (2, 3));
    }

    #[test]
    fn finish_prepends_uop_load_and_appends_finish() {
        let cfg = presets::tiny_config();
        let mut dram = Dram::new(1 << 16);
        let mut b = ProgramBuilder::new(&cfg);
        b.uop_seq(vec![Uop::gemm(0, 0, 0)]);
        let prog = b.finish("test", &mut dram);
        match &prog.insns[0] {
            Insn::Mem(m) => {
                assert_eq!(m.buffer, BufferId::Uop);
                assert_eq!(m.x_size, 1);
            }
            other => panic!("expected uop load, got {other:?}"),
        }
        assert!(matches!(prog.insns.last(), Some(Insn::Finish(_))));
        assert_eq!(prog.uop_count, 1);
    }

    #[test]
    #[should_panic(expected = "uop buffer overflow")]
    fn uop_overflow_caught() {
        let cfg = presets::tiny_config(); // depth 512
        let mut b = ProgramBuilder::new(&cfg);
        b.uop_seq((0..600).map(|i| Uop::gemm(i % 256, 0, 0)).collect());
    }
}
