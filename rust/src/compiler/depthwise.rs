//! Depthwise convolution on the ALU (§IV-D3).
//!
//! VTA's GEMM core sums over input channels, which depthwise convolution
//! must not do — so, as in the paper, the schedule routes through the
//! ALU using the new element-wise 8-bit MUL opcode: per tap,
//! `TMP = MOV(input patch)`, `TMP *= MUL(weight tap)`, `OUT += TMP`,
//! followed by the standard requantization sequence. Each channel tile's
//! weights occupy one accumulator tile per tap (broadcast rows), loaded
//! through the Acc8 view.

use super::builder::ProgramBuilder;
use super::packet::{PMod, Packet, Region};
use crate::isa::{AluInsn, AluOp, BufferId, DepFlags, GemmInsn, Insn, MemInsn, Opcode, Uop};

#[derive(Debug, Clone, Copy)]
pub struct DepthwiseParams {
    /// Channel tiles (channels / BLOCK).
    pub c_tiles: usize,
    pub h: usize,
    pub w: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub shift: u32,
    pub relu: bool,
}

impl DepthwiseParams {
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.k) / self.stride + 1
    }
}

/// Lower a depthwise layer. `wgt_base` points at the packed
/// `[c_tiles][k][k]` weight tiles (Acc8 layout).
pub fn lower_depthwise(
    b: &mut ProgramBuilder,
    p: &DepthwiseParams,
    inp_base: u32,
    wgt_base: u32,
    out_base: u32,
) {
    let cfg = b.cfg.clone();
    let (oh, ow) = (p.oh(), p.ow());
    let iw_c = (ow - 1) * p.stride + p.k;
    let taps = p.k * p.k;
    // Row chunk: IN + WGT + TMP + OUT must double buffer in acc.
    let mut oh_c = oh;
    loop {
        let ih_c = (oh_c - 1) * p.stride + p.k;
        let block = ih_c * iw_c + taps + 2 * oh_c * ow;
        if 2 * block <= cfg.acc_depth || oh_c == 1 {
            break;
        }
        oh_c = oh_c.div_ceil(2);
    }
    let ih_c_max = (oh_c - 1) * p.stride + p.k;
    let slot_tiles = (ih_c_max * iw_c + taps + 2 * oh_c * ow) as u32;
    let mut iter = 0u32;

    for ct in 0..p.c_tiles {
        let mut oy0 = 0;
        while oy0 < oh {
            let rows = oh_c.min(oh - oy0);
            let ih_c = (rows - 1) * p.stride + p.k;
            let slot = (iter % 2) * slot_tiles;
            iter += 1;
            let in_b = slot;
            let wgt_b = slot + (ih_c_max * iw_c) as u32;
            let tmp_b = wgt_b + taps as u32;
            let out_b = tmp_b + (oh_c * ow) as u32;

            // ---- loads: input patch rows + this channel tile's taps ----
            let y_start = (oy0 * p.stride) as i64 - p.pad as i64;
            let y_pad0 = (-y_start).max(0) as u32;
            let y_pad1 = ((y_start + ih_c as i64) - p.h as i64).max(0) as u32;
            let x_start = -(p.pad as i64);
            let x_pad0 = (-x_start).max(0) as u32;
            let x_pad1 = ((x_start + iw_c as i64) - p.w as i64).max(0) as u32;
            let inp_load = Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: in_b,
                dram_base: inp_base
                    + ((ct * p.h) as i64 + y_start + y_pad0 as i64) as u32 * p.w as u32,
                y_size: ih_c as u32 - y_pad0 - y_pad1,
                x_size: iw_c as u32 - x_pad0 - x_pad1,
                x_stride: p.w as u32,
                y_pad0,
                y_pad1,
                x_pad0,
                x_pad1,
                pad_value: 0,
            });
            let wgt_load = Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Acc8,
                sram_base: wgt_b,
                dram_base: wgt_base + (ct * taps) as u32,
                y_size: 1,
                x_size: taps as u32,
                x_stride: taps as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            });
            b.push(
                Packet::new(PMod::Compute, vec![inp_load, wgt_load])
                    .write(Region::new(BufferId::Acc, in_b, in_b + (ih_c * iw_c) as u32))
                    .write(Region::new(BufferId::Acc, wgt_b, wgt_b + taps as u32)),
            );

            // ---- zero OUT, then accumulate MOV/MUL/ADD per tap ----
            let mut insns = Vec::new();
            let reset_seq: Vec<Uop> =
                (0..ow as u32).map(|x| Uop::alu(out_b + x, out_b + x)).collect();
            let (rb, re) = b.uop_seq(reset_seq);
            insns.push(Insn::Gemm(GemmInsn {
                deps: DepFlags::NONE,
                reset: true,
                uop_bgn: rb,
                uop_end: re,
                lp_out: rows as u32,
                lp_in: 1,
                acc_f0: ow as u32,
                acc_f1: 0,
                inp_f0: 0,
                inp_f1: 0,
                wgt_f0: 0,
                wgt_f1: 0,
            }));
            for ky in 0..p.k {
                for kx in 0..p.k {
                    let tap = (ky * p.k + kx) as u32;
                    // TMP = input patch at this tap
                    let mov_seq: Vec<Uop> = (0..ow)
                        .map(|x| {
                            Uop::alu(
                                tmp_b + x as u32,
                                in_b + (ky * iw_c + x * p.stride + kx) as u32,
                            )
                        })
                        .collect();
                    let (mb, me) = b.uop_seq(mov_seq);
                    insns.push(Insn::Alu(AluInsn {
                        deps: DepFlags::NONE,
                        reset: false,
                        op: AluOp::Mov,
                        uop_bgn: mb,
                        uop_end: me,
                        lp_out: rows as u32,
                        lp_in: 1,
                        dst_f0: ow as u32,
                        dst_f1: 0,
                        src_f0: (p.stride * iw_c) as u32,
                        src_f1: 0,
                        use_imm: false,
                        imm: 0,
                    }));
                    // TMP *= weight tap (same src tile for every element)
                    let mul_seq: Vec<Uop> =
                        (0..ow as u32).map(|x| Uop::alu(tmp_b + x, wgt_b + tap)).collect();
                    let (ub, ue) = b.uop_seq(mul_seq);
                    insns.push(Insn::Alu(AluInsn {
                        deps: DepFlags::NONE,
                        reset: false,
                        op: AluOp::Mul,
                        uop_bgn: ub,
                        uop_end: ue,
                        lp_out: rows as u32,
                        lp_in: 1,
                        dst_f0: ow as u32,
                        dst_f1: 0,
                        src_f0: 0,
                        src_f1: 0,
                        use_imm: false,
                        imm: 0,
                    }));
                    // OUT += TMP
                    let add_seq: Vec<Uop> =
                        (0..ow as u32).map(|x| Uop::alu(out_b + x, tmp_b + x)).collect();
                    let (ab, ae) = b.uop_seq(add_seq);
                    insns.push(Insn::Alu(AluInsn {
                        deps: DepFlags::NONE,
                        reset: false,
                        op: AluOp::Add,
                        uop_bgn: ab,
                        uop_end: ae,
                        lp_out: rows as u32,
                        lp_in: 1,
                        dst_f0: ow as u32,
                        dst_f1: 0,
                        src_f0: ow as u32,
                        src_f1: 0,
                        use_imm: false,
                        imm: 0,
                    }));
                }
            }
            // ---- requantize OUT ----
            let imm_alu = |b: &mut ProgramBuilder, op: AluOp, imm: i32| {
                let seq: Vec<Uop> =
                    (0..ow as u32).map(|x| Uop::alu(out_b + x, out_b + x)).collect();
                let (bgn, end) = b.uop_seq(seq);
                Insn::Alu(AluInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    op,
                    uop_bgn: bgn,
                    uop_end: end,
                    lp_out: rows as u32,
                    lp_in: 1,
                    dst_f0: ow as u32,
                    dst_f1: 0,
                    src_f0: ow as u32,
                    src_f1: 0,
                    use_imm: true,
                    imm,
                })
            };
            if p.shift > 0 {
                insns.push(imm_alu(b, AluOp::Add, 1 << (p.shift - 1)));
                insns.push(imm_alu(b, AluOp::Shr, p.shift as i32));
            }
            if p.relu {
                insns.push(imm_alu(b, AluOp::Max, 0));
            }
            insns.push(imm_alu(b, AluOp::Clip, 127));

            let out_tiles = (rows * ow) as u32;
            b.push(
                Packet::new(PMod::Compute, insns)
                    .read(Region::new(BufferId::Acc, in_b, wgt_b + taps as u32))
                    .write(Region::new(BufferId::Acc, tmp_b, out_b + out_tiles))
                    .write(Region::new(BufferId::Out, out_b, out_b + out_tiles)),
            );

            // ---- store ----
            let store = Insn::Mem(MemInsn {
                opcode: Opcode::Store,
                deps: DepFlags::NONE,
                buffer: BufferId::Out,
                sram_base: out_b,
                dram_base: out_base + ((ct * oh + oy0) * ow) as u32,
                y_size: rows as u32,
                x_size: ow as u32,
                x_stride: ow as u32,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            });
            b.push(
                Packet::new(PMod::Store, vec![store])
                    .read(Region::new(BufferId::Out, out_b, out_b + out_tiles)),
            );
            oy0 += rows;
        }
    }
}
