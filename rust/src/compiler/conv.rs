//! Convolution (and dense) lowering to VTA instruction packets.
//!
//! Schedule structure (per Appendix A, with the §IV-D2 improved double
//! buffering): spatial chunks × output-channel chunk *groups* of two
//! virtual threads × input-channel chunks. Each virtual thread owns a
//! static half of the accumulator and weight scratchpads (TVM's vthread
//! model); the input block is either loaded once per ci-chunk and shared
//! by both threads (`reuse_inp`, the improved behaviour) or redundantly
//! loaded per thread (the original TVM behaviour Fig 11/12 measure
//! against).
//!
//! Requantization follows the hardware-friendly pattern the paper's new
//! CLIP instruction accelerates: `ADD (1<<(shift-1))` (round half-up),
//! `SHR shift`, optional `MAX 0` (ReLU), `CLIP 127`.

use super::builder::ProgramBuilder;
use super::packet::{PMod, Packet, Region};
use super::tps::{chunk_bounds, ConvSpec, Tiling};
use crate::isa::{AluInsn, AluOp, BufferId, DepFlags, GemmInsn, Insn, MemInsn, Opcode, Uop};

#[derive(Debug, Clone, Copy)]
pub struct ConvParams {
    pub spec: ConvSpec,
    /// Requantization shift (result is `(acc + round) >> shift`).
    pub shift: u32,
    pub relu: bool,
}

/// DRAM tile bases for the layer's tensors.
#[derive(Debug, Clone, Copy)]
pub struct ConvBases {
    /// Input activation base (in units of input tiles).
    pub inp: u32,
    /// Weight base (in units of weight tiles).
    pub wgt: u32,
    /// Output activation base (in units of output tiles).
    pub out: u32,
}

/// Emit the full packet stream for one convolution layer.
pub fn lower_conv(b: &mut ProgramBuilder, p: &ConvParams, t: &Tiling, bases: ConvBases) {
    let cfg = b.cfg.clone();
    let spec = p.spec;
    let g = t.geom(&spec, &cfg);
    let (oh, ow) = (spec.oh(), spec.ow());
    let (di, dout) = (spec.di(&cfg), spec.dout(&cfg));

    // Ring-slot counts (2 = double buffered).
    let inp_slots = (cfg.inp_depth / g.inp_block_tiles).min(2).max(1);
    let wgt_slots = (cfg.wgt_depth / g.wgt_block_tiles).min(2).max(1);
    let acc_slots = (cfg.acc_depth / g.acc_block_tiles).min(2).max(1);
    // Virtual-thread group width: two co-chunks in flight when both the
    // accumulator and weight scratchpads can hold two blocks.
    let vthreads = if t.tco_o >= 2 && acc_slots >= 2 && wgt_slots >= 2 { 2 } else { 1 };

    // Resident-block tracking (§IV-D2 improved double buffering): when
    // `reuse_inp` is set, a load whose target slot already holds exactly
    // the needed block is elided — the improved thread-injection pass
    // "automatically identif[ies] the redundant loads in alternative
    // memory load threads" and reuses the data. The original pass
    // (reuse_inp = false) reloads per use context, as upstream TVM did.
    let mut inp_resident: std::collections::HashMap<u32, (usize, usize, usize)> =
        std::collections::HashMap::new();
    let mut wgt_resident: std::collections::HashMap<u32, (usize, usize)> =
        std::collections::HashMap::new();

    for yt in 0..t.th_o {
        let (oy0, oh_c) = chunk_bounds(oh, t.th_o, yt);
        if oh_c == 0 {
            continue;
        }
        let ih_c = (oh_c - 1) * spec.sh + spec.kh;
        for xt in 0..t.tw_o {
            let (ox0, ow_c) = chunk_bounds(ow, t.tw_o, xt);
            if ow_c == 0 {
                continue;
            }
            let iw_c = (ow_c - 1) * spec.sw + spec.kw;
            let mut cot = 0;
            while cot < t.tco_o {
                let group: Vec<usize> = (cot..(cot + vthreads).min(t.tco_o)).collect();
                // Per-thread chunk geometry (uniform for divisor tilings).
                let chunks: Vec<(usize, usize)> =
                    group.iter().map(|&c| chunk_bounds(dout, t.tco_o, c)).collect();
                if chunks.iter().all(|&(_, n)| n == 0) {
                    break;
                }

                // ---- reset accumulators ----
                for (v, &(_, co_c)) in chunks.iter().enumerate() {
                    if co_c == 0 {
                        continue;
                    }
                    let acc_base = (v % acc_slots) as u32 * g.acc_block_tiles as u32;
                    emit_reset(b, acc_base, co_c, oh_c, ow_c);
                }

                // ---- accumulate over input-channel chunks ----
                for cit in 0..t.tci_o {
                    let (ci0, ci_c) = chunk_bounds(di, t.tci_o, cit);
                    if ci_c == 0 {
                        continue;
                    }
                    // Improved double buffering: one shared input load,
                    // elided entirely when the block is already resident.
                    let shared_inp = if t.reuse_inp {
                        let slot = (cit % inp_slots) as u32 * g.inp_block_tiles as u32;
                        let key = (oy0, ox0, ci0);
                        if inp_resident.get(&slot) != Some(&key) {
                            emit_inp_load(
                                b, &spec, bases.inp, slot, oy0, oh_c, ox0, ow_c, ci0, ci_c,
                            );
                            inp_resident.insert(slot, key);
                        }
                        Some(slot)
                    } else {
                        None
                    };
                    for (v, &(co0, co_c)) in chunks.iter().enumerate() {
                        if co_c == 0 {
                            continue;
                        }
                        let inp_slot = match shared_inp {
                            Some(s) => s,
                            None => {
                                // Original behaviour: redundant per-thread
                                // load of the same input chunk (§IV-D2).
                                let slot =
                                    (v % inp_slots) as u32 * g.inp_block_tiles as u32;
                                emit_inp_load(
                                    b, &spec, bases.inp, slot, oy0, oh_c, ox0, ow_c, ci0,
                                    ci_c,
                                );
                                slot
                            }
                        };
                        let wgt_slot = (v % wgt_slots) as u32 * g.wgt_block_tiles as u32;
                        let wgt_key = (co0, ci0);
                        if !(t.reuse_inp && wgt_resident.get(&wgt_slot) == Some(&wgt_key)) {
                            emit_wgt_load(b, &spec, bases.wgt, wgt_slot, di, co0, co_c, ci0, ci_c);
                            wgt_resident.insert(wgt_slot, wgt_key);
                        }
                        let acc_base = (v % acc_slots) as u32 * g.acc_block_tiles as u32;
                        emit_gemm(
                            b, &spec, acc_base, inp_slot, wgt_slot, co_c, oh_c, ow_c, ci_c,
                            ih_c, iw_c,
                        );
                    }
                }

                // ---- requantize + store each thread's output ----
                for (v, &(co0, co_c)) in chunks.iter().enumerate() {
                    if co_c == 0 {
                        continue;
                    }
                    let acc_base = (v % acc_slots) as u32 * g.acc_block_tiles as u32;
                    emit_requant(b, p, acc_base, co_c, oh_c, ow_c);
                    emit_store(
                        b, acc_base, bases.out, co0, co_c, oy0, oh_c, ox0, ow_c, oh, ow,
                    );
                }
                cot += vthreads;
            }
        }
    }
}

fn emit_reset(b: &mut ProgramBuilder, acc_base: u32, co_c: usize, oh_c: usize, ow_c: usize) {
    let seq: Vec<Uop> = (0..ow_c as u32).map(|x| Uop::alu(acc_base + x, acc_base + x)).collect();
    let (bgn, end) = b.uop_seq(seq);
    let tiles = (co_c * oh_c * ow_c) as u32;
    let insn = Insn::Gemm(GemmInsn {
        deps: DepFlags::NONE,
        reset: true,
        uop_bgn: bgn,
        uop_end: end,
        lp_out: co_c as u32,
        lp_in: oh_c as u32,
        acc_f0: (oh_c * ow_c) as u32,
        acc_f1: ow_c as u32,
        inp_f0: 0,
        inp_f1: 0,
        wgt_f0: 0,
        wgt_f1: 0,
    });
    b.push(
        Packet::new(PMod::Compute, vec![insn])
            .write(Region::new(BufferId::Acc, acc_base, acc_base + tiles)),
    );
}

#[allow(clippy::too_many_arguments)]
fn emit_inp_load(
    b: &mut ProgramBuilder,
    spec: &ConvSpec,
    inp_base: u32,
    slot: u32,
    oy0: usize,
    oh_c: usize,
    ox0: usize,
    ow_c: usize,
    ci0: usize,
    ci_c: usize,
) {
    let ih_c = (oh_c - 1) * spec.sh + spec.kh;
    let iw_c = (ow_c - 1) * spec.sw + spec.kw;
    // Input rows/cols covered by this chunk, in global (padded) coords.
    let y_start = (oy0 * spec.sh) as i64 - spec.ph as i64;
    let x_start = (ox0 * spec.sw) as i64 - spec.pw as i64;
    let y_pad0 = (-y_start).max(0) as u32;
    let x_pad0 = (-x_start).max(0) as u32;
    let y_pad1 = ((y_start + ih_c as i64) - spec.h as i64).max(0) as u32;
    let x_pad1 = ((x_start + iw_c as i64) - spec.w as i64).max(0) as u32;
    let y_size = ih_c as u32 - y_pad0 - y_pad1;
    let x_size = iw_c as u32 - x_pad0 - x_pad1;
    let mut insns = Vec::with_capacity(ci_c);
    for ci in 0..ci_c {
        let dram_row = y_start + y_pad0 as i64;
        let dram_col = x_start + x_pad0 as i64;
        let dram_base = inp_base as i64
            + (((ci0 + ci) * spec.h) as i64 + dram_row) * spec.w as i64
            + dram_col;
        insns.push(Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer: BufferId::Inp,
            sram_base: slot + (ci * ih_c * iw_c) as u32,
            dram_base: dram_base as u32,
            y_size,
            x_size,
            x_stride: spec.w as u32,
            y_pad0,
            y_pad1,
            x_pad0,
            x_pad1,
            pad_value: 0,
        }));
    }
    let tiles = (ci_c * ih_c * iw_c) as u32;
    b.push(
        Packet::new(PMod::Load, insns)
            .write(Region::new(BufferId::Inp, slot, slot + tiles)),
    );
}

#[allow(clippy::too_many_arguments)]
fn emit_wgt_load(
    b: &mut ProgramBuilder,
    spec: &ConvSpec,
    wgt_base: u32,
    slot: u32,
    di: usize,
    co0: usize,
    co_c: usize,
    ci0: usize,
    ci_c: usize,
) {
    let k = spec.kh * spec.kw;
    let insn = Insn::Mem(MemInsn {
        opcode: Opcode::Load,
        deps: DepFlags::NONE,
        buffer: BufferId::Wgt,
        sram_base: slot,
        dram_base: wgt_base + ((co0 * di + ci0) * k) as u32,
        y_size: co_c as u32,
        x_size: (ci_c * k) as u32,
        x_stride: (di * k) as u32,
        y_pad0: 0,
        y_pad1: 0,
        x_pad0: 0,
        x_pad1: 0,
        pad_value: 0,
    });
    let tiles = (co_c * ci_c * k) as u32;
    b.push(
        Packet::new(PMod::Load, vec![insn])
            .write(Region::new(BufferId::Wgt, slot, slot + tiles)),
    );
}

#[allow(clippy::too_many_arguments)]
fn emit_gemm(
    b: &mut ProgramBuilder,
    spec: &ConvSpec,
    acc_base: u32,
    inp_slot: u32,
    wgt_slot: u32,
    co_c: usize,
    oh_c: usize,
    ow_c: usize,
    ci_c: usize,
    ih_c: usize,
    iw_c: usize,
) {
    let _ = ih_c;
    let mut seq = Vec::with_capacity(oh_c * ow_c * ci_c * spec.kw);
    for y in 0..oh_c {
        for x in 0..ow_c {
            for ci in 0..ci_c {
                for kx in 0..spec.kw {
                    seq.push(Uop::gemm(
                        acc_base + (y * ow_c + x) as u32,
                        inp_slot
                            + (ci * ih_c * iw_c + y * spec.sh * iw_c + x * spec.sw + kx) as u32,
                        wgt_slot + (ci * spec.kh * spec.kw + kx) as u32,
                    ));
                }
            }
        }
    }
    let (bgn, end) = b.uop_seq(seq);
    let insn = Insn::Gemm(GemmInsn {
        deps: DepFlags::NONE,
        reset: false,
        uop_bgn: bgn,
        uop_end: end,
        lp_out: co_c as u32,
        lp_in: spec.kh as u32,
        acc_f0: (oh_c * ow_c) as u32,
        acc_f1: 0,
        inp_f0: 0,
        inp_f1: iw_c as u32,
        wgt_f0: (ci_c * spec.kh * spec.kw) as u32,
        wgt_f1: spec.kw as u32,
    });
    let acc_tiles = (co_c * oh_c * ow_c) as u32;
    let inp_tiles = (ci_c * ih_c * iw_c) as u32;
    let wgt_tiles = (co_c * ci_c * spec.kh * spec.kw) as u32;
    b.push(
        Packet::new(PMod::Compute, vec![insn])
            .read(Region::new(BufferId::Inp, inp_slot, inp_slot + inp_tiles))
            .read(Region::new(BufferId::Wgt, wgt_slot, wgt_slot + wgt_tiles))
            .write(Region::new(BufferId::Acc, acc_base, acc_base + acc_tiles)),
    );
}

/// Requantization ALU sequence over one thread's accumulator block.
fn emit_requant(
    b: &mut ProgramBuilder,
    p: &ConvParams,
    acc_base: u32,
    co_c: usize,
    oh_c: usize,
    ow_c: usize,
) {
    let seq: Vec<Uop> = (0..ow_c as u32).map(|x| Uop::alu(acc_base + x, acc_base + x)).collect();
    let (bgn, end) = b.uop_seq(seq);
    let alu = |op: AluOp, imm: i32| {
        Insn::Alu(AluInsn {
            deps: DepFlags::NONE,
            reset: false,
            op,
            uop_bgn: bgn,
            uop_end: end,
            lp_out: co_c as u32,
            lp_in: oh_c as u32,
            dst_f0: (oh_c * ow_c) as u32,
            dst_f1: ow_c as u32,
            src_f0: (oh_c * ow_c) as u32,
            src_f1: ow_c as u32,
            use_imm: true,
            imm,
        })
    };
    let mut insns = Vec::new();
    if p.shift > 0 {
        insns.push(alu(AluOp::Add, 1 << (p.shift - 1)));
        insns.push(alu(AluOp::Shr, p.shift as i32));
    }
    if p.relu {
        insns.push(alu(AluOp::Max, 0));
    }
    insns.push(alu(AluOp::Clip, 127));
    let tiles = (co_c * oh_c * ow_c) as u32;
    b.push(
        Packet::new(PMod::Compute, insns)
            .read(Region::new(BufferId::Acc, acc_base, acc_base + tiles))
            .write(Region::new(BufferId::Acc, acc_base, acc_base + tiles))
            .write(Region::new(BufferId::Out, acc_base, acc_base + tiles)),
    );
}

#[allow(clippy::too_many_arguments)]
fn emit_store(
    b: &mut ProgramBuilder,
    acc_base: u32,
    out_base: u32,
    co0: usize,
    co_c: usize,
    oy0: usize,
    oh_c: usize,
    ox0: usize,
    ow_c: usize,
    oh: usize,
    ow: usize,
) {
    let mut insns = Vec::with_capacity(co_c);
    for co in 0..co_c {
        insns.push(Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: acc_base + (co * oh_c * ow_c) as u32,
            dram_base: out_base + (((co0 + co) * oh + oy0) * ow + ox0) as u32,
            y_size: oh_c as u32,
            x_size: ow_c as u32,
            x_stride: ow as u32,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        }));
    }
    let tiles = (co_c * oh_c * ow_c) as u32;
    b.push(
        Packet::new(PMod::Store, insns)
            .read(Region::new(BufferId::Out, acc_base, acc_base + tiles)),
    );
}
