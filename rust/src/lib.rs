//! # vta-stack — a highly configurable hardware/software stack for DNN
//! inference acceleration
//!
//! Reproduction of Banerjee et al. (Intel Labs, 2021): the enhanced
//! TVM/VTA inference stack, built as a three-layer Rust + JAX + Pallas
//! system. This crate is the Rust layer: the VTA cycle-accurate simulator
//! (*tsim*), behavioral simulator (*fsim*), the compiler (tiling parameter
//! search, double buffering, full-network schedules), the JIT runtime, the
//! analysis tooling (roofline, utilization, area), the parallel
//! design-space-exploration engine (*sweep*: work-stealing workers, a
//! resumable on-disk result cache, incremental Pareto extraction), and a
//! PJRT-based golden verification path against the JAX/Pallas model
//! compiled AOT to HLO (behind the `pjrt` cargo feature).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod analysis;
pub mod compiler;
pub mod config;
pub mod engine;
pub mod exec;
pub mod floorplan;
pub mod fsim;
pub mod isa;
pub mod mem;
pub mod memo;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod sweep;
pub mod util;
pub mod workloads;
pub mod sim;
pub mod trace;

pub use engine::VtaError;
