//! # vta-stack — a highly configurable hardware/software stack for DNN
//! inference acceleration
//!
//! Reproduction of Banerjee et al. (Intel Labs, 2021): the enhanced
//! TVM/VTA inference stack, built as a three-layer Rust + JAX + Pallas
//! system. This crate is the Rust layer — simulators, compiler, runtime,
//! analysis, a parallel design-space-exploration engine, and a
//! batch-serving runtime, all dependency-free (the offline-first
//! substrate in [`util`] supplies JSON, CLI parsing, PRNG, stats,
//! benchmarking, property testing, and the thread pool).
//!
//! ## Module map
//!
//! Hardware model:
//!
//! | module | what it is |
//! |---|---|
//! | [`config`] | the single JSON hardware description driving everything (§II-B) |
//! | [`isa`] | the 128-bit instruction set with config-derived field widths |
//! | [`exec`] | bit-accurate instruction semantics shared by both simulators |
//! | [`sim`] | *tsim*, the cycle-accurate simulator (queues, VME, tracing) |
//! | [`fsim`] | the behavioral simulator — the functional reference |
//! | [`mem`] | the DRAM model (tile-granular flat byte space) |
//! | [`floorplan`] | physical floorplan generation + checks (§IV-B) |
//!
//! Compiler and runtime:
//!
//! | module | what it is |
//! |---|---|
//! | [`compiler`] | graph IR, TPS tiling search, per-layer lowering, layouts |
//! | [`runtime`] | the JIT session: DRAM staging, per-layer launch, CPU fallback |
//! | [`workloads`] | ResNet-18/34/50/101, MobileNet-1.0, micro test nets |
//!
//! Evaluation, exploration, and serving:
//!
//! | module | what it is |
//! |---|---|
//! | [`engine`] | **the front door**: one `Engine`, many `Backend`s, one fidelity ladder |
//! | [`memo`] | layer-memoized simulation cache (per-layer results, shared + spillable) |
//! | [`model`] | analytical per-layer cycle model (phase 1 of the two-phase sweep) |
//! | [`sweep`] | parallel design-space exploration: work stealing, resumable cache, Pareto |
//! | [`serve`] | batch-serving runtime: session pool, dynamic batching, load generation |
//! | [`store`] | content-addressed artifact store + op-graph planner (one cache discipline) |
//! | [`analysis`] | roofline, gantt/utilization, scaled-area model |
//! | [`repro`] | one driver per paper figure/table |
//! | [`trace`] | dynamic trace-based cross-simulator validation (§III-C) |
//! | [`util`] | the std-only substrate (JSON, CLI, PRNG, stats, bench, pool) |
//!
//! ## The fidelity ladder
//!
//! Every way of answering "what does workload W cost on configuration
//! C?" is a [`Backend`](engine::Backend) behind one
//! [`Engine`](engine::Engine), ranked by how much of the machine it
//! exercises:
//!
//! ```text
//!   Analytical  <  TimingOnly      <  CycleAccurate    <  Functional
//!   (model:        (timing: real      (tsim: + full       (fsim: pure
//!    closed-form    timing wheel,      datapath,           behavioral
//!    estimate,      exact cycles,      exact outputs)      reference)
//!    microseconds)  no tensors)
//! ```
//!
//! Rungs that share a product agree bit-for-bit (pinned by
//! `rust/tests/backend_parity.rs`), so clients pick a rung by cost,
//! never by fear of divergence. The sweep escalates Analytical →
//! tsim (the two-phase engine); the serving runtime prices requests at
//! any cycle-producing rung.
//!
//! ## Quick start
//!
//! Evaluate a workload on a configuration at a chosen fidelity (this
//! example runs as a doctest — `cargo test --doc`):
//!
//! ```
//! use vta::config::presets;
//! use vta::engine::{BackendKind, Engine, EvalRequest};
//! use vta::workloads;
//!
//! let cfg = presets::tiny_config(); // 1x4x4 test geometry, fast
//! let graph = workloads::micro_resnet(cfg.block_in, 1);
//! let engine = Engine::for_config(&cfg)
//!     .backend_kind(BackendKind::TsimTiming) // pick a fidelity rung
//!     .build()?;
//! let eval = engine.run(&graph, &EvalRequest::seeded(7))?;
//! assert!(eval.cycles.unwrap() > 0);
//! # Ok::<(), vta::VtaError>(())
//! ```
//!
//! Serve a stream of requests against warm prepared graphs with
//! dynamic batching (see [`serve`] for the full model):
//!
//! ```
//! use vta::config::presets;
//! use vta::serve::{self, ArrivalSpec, ServeOptions};
//! use vta::sweep::WorkloadSpec;
//!
//! let opts = ServeOptions {
//!     cfg: presets::tiny_config(),
//!     workloads: vec![WorkloadSpec::Micro { block: 4 }],
//!     ..ServeOptions::default()
//! };
//! let spec = ArrivalSpec::parse("poisson:500")?;
//! let trace = serve::synth_trace(&spec, &["micro@4".to_string()], 8, 7)?;
//! let outcome = serve::run(&opts, &trace)?;
//! assert_eq!(outcome.report.completed, 8);
//! # Ok::<(), vta::VtaError>(())
//! ```
//!
//! See `DESIGN.md` for the architecture (engine contract, sweep,
//! memo, two-phase model, serving runtime) and `EXPERIMENTS.md` for the
//! paper-vs-measured results. The `vta` binary fronts the same stack;
//! README.md carries the CLI reference.

pub mod analysis;
pub mod compiler;
pub mod config;
pub mod engine;
pub mod exec;
pub mod floorplan;
pub mod fsim;
pub mod isa;
pub mod mem;
pub mod memo;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod store;
pub mod sweep;
pub mod trace;
pub mod util;
pub mod workloads;

pub use engine::VtaError;

// Compile and run the README's Rust examples with the crate's doctests
// (`cargo test --doc`), so the front-page quick start can never rot.
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub struct ReadmeDoctests;
