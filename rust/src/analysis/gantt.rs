//! Process-utilization visualization (Figs 3 and 4).
//!
//! Renders an [`ActivityTrace`] as the paper's three-bar chart — *load*,
//! *compute*, *store* — with GEMM vs ALU activity distinguished within
//! the compute bar ("The red sections of compute correspond to GEMM
//! activity and the green sections to ALU activity") and layer-boundary
//! markers (the `vcr_finish` red ticks of Fig 4). ASCII for terminals,
//! SVG for reports.

use crate::sim::activity::{Activity, ActivityTrace, Interval, Module};

/// Utilization summary per module over a window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    pub load: f64,
    pub compute: f64,
    pub store: f64,
    pub compute_gemm: f64,
    pub compute_alu: f64,
}

pub fn utilization(trace: &ActivityTrace, start: u64, end: u64) -> Utilization {
    let span = (end - start).max(1) as f64;
    let busy = |m: Module| {
        trace
            .intervals
            .iter()
            .filter(|iv| iv.module == m)
            .map(|iv| overlap(iv, start, end))
            .sum::<u64>() as f64
            / span
    };
    let kind = |a: Activity| {
        trace
            .intervals
            .iter()
            .filter(|iv| iv.activity == a)
            .map(|iv| overlap(iv, start, end))
            .sum::<u64>() as f64
            / span
    };
    Utilization {
        load: busy(Module::Load),
        compute: busy(Module::Compute),
        store: busy(Module::Store),
        compute_gemm: kind(Activity::Gemm),
        compute_alu: kind(Activity::Alu),
    }
}

fn overlap(iv: &Interval, start: u64, end: u64) -> u64 {
    iv.end.min(end).saturating_sub(iv.start.max(start))
}

/// ASCII gantt: one row per module, `width` character bins.
/// Compute bins show `G` (GEMM), `A` (ALU), `m` (uop/acc DMA); load and
/// store show `#`. Layer markers are drawn on a separate rail as `|`.
pub fn ascii(trace: &ActivityTrace, start: u64, end: u64, width: usize) -> String {
    let span = (end.saturating_sub(start)).max(1);
    let bin_of = |cycle: u64| -> usize {
        (((cycle.saturating_sub(start)) as u128 * width as u128 / span as u128) as usize)
            .min(width - 1)
    };
    let mut rows: Vec<(String, Vec<char>)> = vec![
        ("load   ".into(), vec![' '; width]),
        ("compute".into(), vec![' '; width]),
        ("store  ".into(), vec![' '; width]),
    ];
    for iv in &trace.intervals {
        if iv.end <= start || iv.start >= end {
            continue;
        }
        let row = match iv.module {
            Module::Load => 0,
            Module::Compute => 1,
            Module::Store => 2,
            Module::Fetch => continue,
        };
        let ch = match iv.activity {
            Activity::Gemm => 'G',
            Activity::Alu => 'A',
            Activity::LoadUop | Activity::LoadAcc => 'm',
            _ => '#',
        };
        let b0 = bin_of(iv.start.max(start));
        let b1 = bin_of((iv.end - 1).min(end - 1));
        for b in b0..=b1 {
            // GEMM/ALU coloring wins over generic fill within a bin.
            let cell = &mut rows[row].1[b];
            if *cell == ' ' || (*cell == '#' && ch != '#') || (*cell == 'm' && (ch == 'G' || ch == 'A')) {
                *cell = ch;
            }
        }
    }
    let mut marker_rail = vec![' '; width];
    for (cycle, _) in &trace.markers {
        if *cycle >= start && *cycle < end {
            marker_rail[bin_of(*cycle)] = '|';
        }
    }
    let mut out = String::new();
    out.push_str(&format!("cycles [{start}, {end})\n"));
    out.push_str(&format!("layers  {}\n", marker_rail.iter().collect::<String>()));
    for (label, cells) in rows {
        out.push_str(&format!("{label} {}\n", cells.iter().collect::<String>()));
    }
    out
}

/// Minimal SVG rendering of the same chart (self-contained file).
pub fn svg(trace: &ActivityTrace, start: u64, end: u64, width_px: u32) -> String {
    let span = (end.saturating_sub(start)).max(1) as f64;
    let row_h = 28.0;
    let x_of = |c: u64| (c.saturating_sub(start)) as f64 / span * width_px as f64;
    let mut body = String::new();
    for iv in &trace.intervals {
        if iv.end <= start || iv.start >= end {
            continue;
        }
        let row = match iv.module {
            Module::Load => 0.0,
            Module::Compute => 1.0,
            Module::Store => 2.0,
            Module::Fetch => continue,
        };
        let color = match iv.activity {
            Activity::Gemm => "#d62728",    // red, as in Fig 3
            Activity::Alu => "#2ca02c",     // green
            Activity::LoadUop | Activity::LoadAcc => "#9467bd",
            Activity::StoreDma => "#1f77b4",
            _ => "#7f7f7f",
        };
        let x = x_of(iv.start.max(start));
        let w = (x_of(iv.end.min(end)) - x).max(0.5);
        body.push_str(&format!(
            r#"<rect x="{x:.1}" y="{:.1}" width="{w:.1}" height="{:.1}" fill="{color}"/>"#,
            row * row_h + 14.0,
            row_h - 6.0
        ));
        body.push('\n');
    }
    for (cycle, label) in &trace.markers {
        if *cycle >= start && *cycle < end {
            let x = x_of(*cycle);
            body.push_str(&format!(
                r#"<line x1="{x:.1}" y1="8" x2="{x:.1}" y2="{:.1}" stroke="red"/><text x="{x:.1}" y="7" font-size="6">{}</text>"#,
                3.0 * row_h + 14.0,
                xml_escape(label)
            ));
            body.push('\n');
        }
    }
    let h = 3.0 * row_h + 20.0;
    format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{width_px}" height="{h:.0}">
<text x="2" y="{:.0}" font-size="10">load</text>
<text x="2" y="{:.0}" font-size="10">compute</text>
<text x="2" y="{:.0}" font-size="10">store</text>
{body}</svg>
"#,
        row_h * 0.5 + 14.0,
        row_h * 1.5 + 14.0,
        row_h * 2.5 + 14.0,
    )
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> ActivityTrace {
        let mut t = ActivityTrace::new(true);
        t.record(Module::Load, Activity::LoadDma, 0, 40);
        t.record(Module::Compute, Activity::Gemm, 30, 90);
        t.record(Module::Compute, Activity::Alu, 90, 100);
        t.record(Module::Store, Activity::StoreDma, 95, 110);
        t.mark(100, "layer0");
        t
    }

    #[test]
    fn utilization_fractions() {
        let t = sample_trace();
        let u = utilization(&t, 0, 110);
        assert!((u.load - 40.0 / 110.0).abs() < 1e-9);
        assert!((u.compute - 70.0 / 110.0).abs() < 1e-9);
        assert!((u.compute_gemm - 60.0 / 110.0).abs() < 1e-9);
        assert!((u.compute_alu - 10.0 / 110.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_window_clips() {
        let t = sample_trace();
        let u = utilization(&t, 0, 40);
        assert!((u.load - 1.0).abs() < 1e-9);
        assert!((u.compute - 10.0 / 40.0).abs() < 1e-9);
        assert_eq!(u.store, 0.0);
    }

    #[test]
    fn ascii_renders_rows_and_markers() {
        let t = sample_trace();
        let s = ascii(&t, 0, 110, 55);
        assert!(s.contains("load"));
        assert!(s.contains('G'));
        assert!(s.contains('A'));
        assert!(s.contains('|'));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    fn svg_well_formed_ish() {
        let t = sample_trace();
        let s = svg(&t, 0, 110, 400);
        assert!(s.starts_with("<svg"));
        assert!(s.trim_end().ends_with("</svg>"));
        assert!(s.matches("<rect").count() >= 4);
        assert!(s.contains("#d62728")); // GEMM red
    }
}
