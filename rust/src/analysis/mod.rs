//! Performance-analysis tooling (§III-A): the roofline model (Fig 2),
//! process-utilization visualization (Figs 3/4), and the scaled-area
//! model behind the Fig 13 design-space sweep.

pub mod area;
pub mod gantt;
pub mod roofline;
