//! Scaled-area model (Fig 13's x-axis).
//!
//! The paper reports *scaled* (relative) area from physical synthesis;
//! we substitute an analytic model calibrated to its qualitative
//! findings: "Scratchpad size is the main contributor to scaled area",
//! with the MAC array and memory interface as secondary terms. Areas are
//! normalized so the default 1×16×16 configuration is 1.0.

use crate::config::{Precision, VtaConfig};

/// Area-model coefficients in arbitrary units. SRAM is per *bit*; an
/// 8-bit MAC (multiplier + 32-bit adder slice) costs roughly 60 SRAM
/// bits worth of standard cells; the AXI/VME interface scales with the
/// data-path width; fixed covers fetch/decode/queues.
#[derive(Debug, Clone, Copy)]
pub struct AreaModel {
    pub sram_bit: f64,
    pub mac: f64,
    pub axi_byte: f64,
    pub vme_tag: f64,
    pub fixed: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel { sram_bit: 1.0, mac: 60.0, axi_byte: 2000.0, vme_tag: 500.0, fixed: 100_000.0 }
    }
}

impl AreaModel {
    /// Absolute area in model units.
    pub fn area_units(&self, cfg: &VtaConfig) -> f64 {
        let mut sram_bits = cfg.scratchpad_bytes() as f64 * 8.0;
        let mut mac_cost = self.mac;
        if cfg.precision == Precision::Narrow {
            // Narrow (16-bit) accumulation: the accumulator scratchpad
            // stores half-width words, and the adder slice of each MAC
            // shrinks (the 8×8 multiplier is unchanged, so the saving
            // is the adder's share of the standard-cell budget).
            sram_bits -= (cfg.acc_depth * cfg.acc_tile_bytes()) as f64 * 8.0 / 2.0;
            mac_cost *= 0.75;
        }
        let macs = cfg.macs_per_gemm_op() as f64;
        // ALU lanes: one 32-bit lane per block_out element.
        let alu = (cfg.batch * cfg.block_out) as f64 * 30.0;
        sram_bits * self.sram_bit
            + macs * mac_cost
            + alu
            + cfg.axi_bytes as f64 * self.axi_byte
            + cfg.vme_inflight as f64 * self.vme_tag
            + self.fixed
    }

    /// Area relative to the default configuration (the paper's "scaled
    /// area").
    pub fn scaled_area(&self, cfg: &VtaConfig) -> f64 {
        let base = self.area_units(&crate::config::presets::default_config());
        self.area_units(cfg) / base
    }
}

/// Convenience: scaled area under the default model. The normalization
/// base (the default configuration's area) is computed once per process
/// — this sits on the sweep engine's per-point path, where rebuilding
/// the default config and its ISA layout for every design point is
/// measurable at large grid sizes.
pub fn scaled_area(cfg: &VtaConfig) -> f64 {
    static BASE: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    let base = *BASE.get_or_init(|| {
        AreaModel::default().area_units(&crate::config::presets::default_config())
    });
    AreaModel::default().area_units(cfg) / base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn default_config_is_unity() {
        assert!((scaled_area(&presets::default_config()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn memoized_base_matches_model() {
        // The cached-base fast path must be bit-identical to the
        // uncached AreaModel::scaled_area.
        for cfg in presets::all() {
            assert_eq!(scaled_area(&cfg), AreaModel::default().scaled_area(&cfg), "{}", cfg.name);
        }
    }

    #[test]
    fn pipelining_costs_no_area_in_model() {
        // The paper: ~4.9x fewer cycles "with minimal area increase".
        let a = scaled_area(&presets::default_config());
        let b = scaled_area(&presets::original_config());
        // vme_inflight differs slightly; must be within a couple percent.
        assert!((a - b).abs() / a < 0.02, "{a} vs {b}");
    }

    #[test]
    fn scratchpads_dominate() {
        let m = AreaModel::default();
        let cfg = presets::default_config();
        let sram = cfg.scratchpad_bytes() as f64 * 8.0 * m.sram_bit;
        assert!(sram / m.area_units(&cfg) > 0.7, "SRAM should dominate area");
    }

    #[test]
    fn fig13_span_about_12x() {
        // Largest swept config ~12x the default area (paper: "~12x
        // greater area" at the fast end).
        let big = presets::scaled_config(1, 64, 64, 4, 64);
        let ratio = scaled_area(&big);
        assert!(
            (6.0..25.0).contains(&ratio),
            "big-config area ratio {ratio:.1} outside plausible Fig 13 span"
        );
    }

    #[test]
    fn narrow_accumulation_saves_area() {
        for base in [presets::default_config(), presets::scaled_config(1, 64, 64, 4, 64)] {
            let mut narrow = base.clone();
            narrow.precision = Precision::Narrow;
            let (aw, an) = (scaled_area(&base), scaled_area(&narrow));
            assert!(an < aw, "{}: narrow {an} must undercut wide {aw}", base.name);
            // The saving is bounded by the ACC scratchpad's share plus
            // the MAC trim — never more than half the total.
            assert!(an > 0.5 * aw, "{}: implausibly large saving", base.name);
        }
    }

    #[test]
    fn area_monotone_in_block() {
        let a16 = scaled_area(&presets::scaled_config(1, 16, 16, 2, 8));
        let a32 = scaled_area(&presets::scaled_config(1, 32, 32, 2, 8));
        let a64 = scaled_area(&presets::scaled_config(1, 64, 64, 2, 8));
        assert!(a16 < a32 && a32 < a64);
    }
}
