//! Roofline model (Fig 2): a log-log chart of Ops/Cycle vs Ops/Byte.
//!
//! "The horizontal dashed lines represent compute bounds based on the
//! number of simultaneously operable compute units. The diagonal dashed
//! lines correspond to memory bandwidth limit." Ops are MACs; the
//! bandwidth diagonal's intercept with Ops/Byte = 8 corresponds to the
//! interface width in bits/cycle, exactly as the paper annotates.

use crate::config::VtaConfig;
use crate::sim::PerfReport;

/// One roofline (a config's compute ceiling + bandwidth diagonal).
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Peak MACs/cycle — the MAC array size (compute bound).
    pub peak_ops_per_cycle: f64,
    /// DRAM bytes/cycle — the memory interface width.
    pub bytes_per_cycle: f64,
}

impl Roofline {
    pub fn of(cfg: &VtaConfig) -> Roofline {
        Roofline {
            peak_ops_per_cycle: cfg.macs_per_gemm_op() as f64,
            bytes_per_cycle: cfg.axi_bytes as f64,
        }
    }

    /// Attainable Ops/Cycle at a given operational intensity (Ops/Byte).
    pub fn attainable(&self, ops_per_byte: f64) -> f64 {
        (self.bytes_per_cycle * ops_per_byte).min(self.peak_ops_per_cycle)
    }

    /// The ridge point: intensity at which compute becomes the bound.
    pub fn ridge_ops_per_byte(&self) -> f64 {
        self.peak_ops_per_cycle / self.bytes_per_cycle
    }

    /// Whether a measured point is compute-bound under this roofline.
    pub fn compute_bound(&self, ops_per_byte: f64) -> bool {
        ops_per_byte >= self.ridge_ops_per_byte()
    }

    /// Lower bound on the cycles any schedule needs for `macs` MACs and
    /// `dram_bytes` of DRAM traffic: the compute ceiling vs the
    /// bandwidth diagonal, whichever binds. Shared by the analytical
    /// sweep model (`crate::model`), which clamps its per-layer
    /// estimates to this bound, so Fig 2's chart and the phase-1 pruner
    /// agree on what the hardware ceilings allow.
    pub fn bound_cycles(&self, macs: u64, dram_bytes: u64) -> u64 {
        let compute = (macs as f64 / self.peak_ops_per_cycle).ceil() as u64;
        let memory = (dram_bytes as f64 / self.bytes_per_cycle).ceil() as u64;
        compute.max(memory)
    }
}

/// A measured kernel/workload point on the chart.
#[derive(Debug, Clone)]
pub struct MeasuredPoint {
    pub label: String,
    pub ops_per_byte: f64,
    pub ops_per_cycle: f64,
    /// Fraction of the attainable performance at this intensity.
    pub efficiency: f64,
}

pub fn measure(label: &str, cfg: &VtaConfig, report: &PerfReport) -> MeasuredPoint {
    let roof = Roofline::of(cfg);
    let x = report.macs_per_byte();
    let y = report.macs_per_cycle();
    MeasuredPoint {
        label: label.to_string(),
        ops_per_byte: x,
        ops_per_cycle: y,
        efficiency: y / roof.attainable(x).max(1e-9),
    }
}

/// Render the Fig 2-style table: one row per config with the ceiling,
/// diagonal, ridge and measured points.
pub fn render_table(rows: &[(VtaConfig, MeasuredPoint)]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<26} {:>9} {:>10} {:>9} {:>11} {:>12} {:>6}\n",
        "config", "peak op/c", "bytes/c", "ridge", "ops/byte", "ops/cycle", "eff%"
    ));
    for (cfg, p) in rows {
        let roof = Roofline::of(cfg);
        out.push_str(&format!(
            "{:<26} {:>9.0} {:>10.0} {:>9.1} {:>11.2} {:>12.2} {:>6.1}\n",
            cfg.tag(),
            roof.peak_ops_per_cycle,
            roof.bytes_per_cycle,
            roof.ridge_ops_per_byte(),
            p.ops_per_byte,
            p.ops_per_cycle,
            p.efficiency * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn default_roofline_values() {
        let r = Roofline::of(&presets::default_config());
        assert_eq!(r.peak_ops_per_cycle, 256.0);
        assert_eq!(r.bytes_per_cycle, 8.0);
        assert_eq!(r.ridge_ops_per_byte(), 32.0);
    }

    #[test]
    fn attainable_clamps() {
        let r = Roofline::of(&presets::default_config());
        assert_eq!(r.attainable(1.0), 8.0); // memory bound
        assert_eq!(r.attainable(1000.0), 256.0); // compute bound
        assert!(r.compute_bound(64.0));
        assert!(!r.compute_bound(4.0));
    }

    #[test]
    fn bound_cycles_takes_the_binding_ceiling() {
        let r = Roofline::of(&presets::default_config()); // 256 MACs/c, 8 B/c
        assert_eq!(r.bound_cycles(2560, 0), 10); // compute-bound
        assert_eq!(r.bound_cycles(0, 80), 10); // memory-bound
        assert_eq!(r.bound_cycles(2560, 800), 100); // memory binds
        assert_eq!(r.bound_cycles(0, 0), 0);
    }

    #[test]
    fn paper_bandwidth_annotation() {
        // "the intercept with the vertical line Ops/Byte = 8 corresponds
        // to the bandwidth in Bits/Cycle": at 8 ops/byte the diagonal
        // reads bytes_per_cycle*8 = bits/cycle.
        let r = Roofline::of(&presets::default_config());
        assert_eq!(r.attainable(8.0), 64.0); // 64-bit AXI
    }
}
