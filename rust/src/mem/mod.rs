//! DRAM model shared by both simulator targets and the JIT runtime.
//!
//! A flat byte space with a bump allocator. Memory instructions address
//! DRAM at *tile* granularity (address = `dram_base * tile_bytes`), so
//! tensor allocations are tile-aligned. Also provides typed read/write
//! helpers used by the runtime to stage inputs and collect outputs.

/// Default DRAM capacity: 256 MiB — comfortably holds ResNet-101 with
/// double-buffered activations.
pub const DEFAULT_DRAM_BYTES: usize = 256 << 20;

#[derive(Debug, Clone)]
pub struct Dram {
    bytes: Vec<u8>,
    next: usize,
}

/// A DRAM allocation handle (byte address + length).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramRegion {
    pub addr: usize,
    pub len: usize,
}

impl DramRegion {
    /// Tile-granular base address for memory instructions.
    pub fn tile_base(&self, tile_bytes: usize) -> u32 {
        debug_assert_eq!(self.addr % tile_bytes, 0, "region not tile-aligned");
        (self.addr / tile_bytes) as u32
    }
}

impl Dram {
    pub fn new(capacity: usize) -> Dram {
        Dram { bytes: vec![0; capacity], next: 0 }
    }

    pub fn with_default_capacity() -> Dram {
        Dram::new(DEFAULT_DRAM_BYTES)
    }

    pub fn capacity(&self) -> usize {
        self.bytes.len()
    }

    pub fn allocated(&self) -> usize {
        self.next
    }

    /// Bump-allocate `len` bytes aligned to `align` (power of two).
    pub fn alloc(&mut self, len: usize, align: usize) -> DramRegion {
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        let addr = (self.next + align - 1) & !(align - 1);
        assert!(
            addr + len <= self.bytes.len(),
            "DRAM exhausted: need {} bytes at {}, capacity {}",
            len,
            addr,
            self.bytes.len()
        );
        self.next = addr + len;
        DramRegion { addr, len }
    }

    /// Reset the allocator (keeps capacity; zeroes nothing).
    pub fn reset(&mut self) {
        self.next = 0;
    }

    /// Reset the allocator *and* zero everything that was allocated, so
    /// the memory is byte-identical to a freshly constructed `Dram`.
    /// Only the allocated prefix is touched — on a 256 MiB default
    /// arena that is the difference between microseconds and a full
    /// memset per batched evaluation
    /// ([`crate::runtime::Session::reset_for_reuse`]).
    pub fn reset_zeroed(&mut self) {
        self.bytes[..self.next].fill(0);
        self.next = 0;
    }

    // ---- typed access ----

    pub fn read(&self, addr: usize, len: usize) -> &[u8] {
        &self.bytes[addr..addr + len]
    }

    pub fn write(&mut self, addr: usize, data: &[u8]) {
        self.bytes[addr..addr + data.len()].copy_from_slice(data);
    }

    pub fn write_i8(&mut self, region: DramRegion, data: &[i8]) {
        assert!(data.len() <= region.len);
        let raw: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        self.write(region.addr, raw);
    }

    pub fn read_i8(&self, region: DramRegion) -> Vec<i8> {
        self.read(region.addr, region.len).iter().map(|&b| b as i8).collect()
    }

    pub fn write_i32(&mut self, region: DramRegion, data: &[i32]) {
        assert!(data.len() * 4 <= region.len);
        let mut addr = region.addr;
        for v in data {
            self.bytes[addr..addr + 4].copy_from_slice(&v.to_le_bytes());
            addr += 4;
        }
    }

    pub fn read_i32(&self, region: DramRegion) -> Vec<i32> {
        assert_eq!(region.len % 4, 0);
        self.read(region.addr, region.len)
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_respects_alignment() {
        let mut d = Dram::new(1 << 16);
        let a = d.alloc(10, 64);
        assert_eq!(a.addr % 64, 0);
        let b = d.alloc(100, 256);
        assert_eq!(b.addr % 256, 0);
        assert!(b.addr >= a.addr + a.len);
    }

    #[test]
    #[should_panic(expected = "DRAM exhausted")]
    fn alloc_exhaustion_panics() {
        let mut d = Dram::new(128);
        d.alloc(256, 1);
    }

    #[test]
    fn i8_roundtrip() {
        let mut d = Dram::new(4096);
        let r = d.alloc(16, 16);
        let data: Vec<i8> = (-8..8).collect();
        d.write_i8(r, &data);
        assert_eq!(d.read_i8(r), data);
    }

    #[test]
    fn i32_roundtrip() {
        let mut d = Dram::new(4096);
        let r = d.alloc(32, 64);
        let data = vec![i32::MIN, -1, 0, 1, i32::MAX, 42, -42, 7];
        d.write_i32(r, &data);
        assert_eq!(d.read_i32(r), data);
    }

    #[test]
    fn tile_base() {
        let mut d = Dram::new(1 << 16);
        let r = d.alloc(256, 256);
        assert_eq!(r.tile_base(256) as usize * 256, r.addr);
    }

    #[test]
    fn reset_zeroed_matches_fresh() {
        let mut d = Dram::new(1024);
        let r = d.alloc(16, 16);
        d.write_i8(r, &[1, 2, 3, -4]);
        d.reset_zeroed();
        assert_eq!(d.allocated(), 0);
        let r2 = d.alloc(16, 16);
        assert_eq!(r2, r, "allocator restarts at the same addresses");
        assert_eq!(d.read_i8(r2), vec![0i8; 16], "old contents wiped");
    }

    #[test]
    fn reset_reclaims() {
        let mut d = Dram::new(1024);
        d.alloc(512, 1);
        d.reset();
        let r = d.alloc(1024, 1);
        assert_eq!(r.addr, 0);
    }
}
