//! Analytical per-layer cycle model — the phase-1 scorer of the
//! two-phase design-space sweep (DESIGN.md §Two-phase sweep).
//!
//! For a `(VtaConfig, layer)` pair the model predicts the tsim cycle
//! count in microseconds of host time instead of seconds of simulation,
//! by mirroring the arithmetic the simulator applies to the lowered
//! program — without compiling or simulating anything:
//!
//! * **DMA / bandwidth term**: DRAM byte counts come from the same
//!   closed forms TPS uses ([`Tiling::dram_bytes`]'s halo sums), divided
//!   by the AXI width, plus one beat of burst-quantization overhead per
//!   DMA row (each `y_size` row is a separate burst in the VME);
//! * **compute term**: GEMM/ALU busy cycles from the exact loop shapes
//!   the lowering emits (`uops × lp_out × lp_in`), at the configuration's
//!   initiation intervals (II = 1/4 GEMM, 1/2/4/5 ALU) plus the pipeline
//!   fill per instruction ([`GEMM_PIPE_FILL`](crate::sim::GEMM_PIPE_FILL) /
//!   [`ALU_PIPE_FILL`](crate::sim::ALU_PIPE_FILL));
//! * **token-pipeline overlap**: the load, compute and store stages run
//!   concurrently under dependency tokens, so a double-buffered layer
//!   costs ≈ `max(read-channel, compute, write-channel)` plus a
//!   *serialization correction* (DRAM latency exposure, first-block fill
//!   and last-block drain). A layer whose tiling cannot double buffer
//!   (single scratchpad slots) degrades to `read + compute`.
//!
//! Every estimate is clamped from below by the configuration's roofline
//! ([`Roofline::bound_cycles`]) — the model and the Fig 2 analysis share
//! one bandwidth-vs-compute bound.
//!
//! Two properties the sweep relies on (enforced by
//! `rust/tests/model_calibration.rs`):
//!
//! * monotonicity — widening the memory interface or enabling
//!   execution-unit pipelining never *increases* an estimate;
//! * calibration — per-layer estimates track tsim within the error band
//!   documented in DESIGN.md (measure it for your workload with
//!   [`calib::calibrate_graph`]; [`CALIBRATION_SANITY_RATIO`] is the hard
//!   CI bound, [`DEFAULT_PRUNE_EPSILON`] the band the default pruning
//!   tolerance covers).

pub mod calib;

use crate::analysis::roofline::Roofline;
use crate::compiler::depthwise::DepthwiseParams;
use crate::compiler::eltwise::{PoolParams, HARD_SIGMOID_OPS, HARD_TANH_OPS};
use crate::compiler::graph::{attn_on_vta, layernorm_mean_spec, softmax_on_vta, Graph, Op};
use crate::compiler::residency::{self, ResidencyMode, RECOMPUTE_SIG_BITS};
use crate::compiler::tps::{self, ConvSpec, Tiling};
use crate::config::{ConfigError, VtaConfig, INSN_BYTES};
use crate::memo::sig;
use crate::sim::{ALU_PIPE_FILL, GEMM_PIPE_FILL};
use crate::util::bitfield::clog2;
use std::collections::HashMap;

/// Default epsilon for the sweep's predicted-pareto pruning band
/// (`--prune-epsilon`). Derived from the model error bound: if every
/// prediction is within a multiplicative factor ρ of the measured value
/// (`pred ∈ [true/ρ, true·ρ]`), pruning with `ε ≥ ρ² − 1` can never
/// drop a true front point (soundness argument in DESIGN.md). The
/// default covers ρ = √2 ≈ ±41% relative error — conservative against
/// the calibration harness's measured band; widen it for workloads
/// where [`calib::CalibrationReport::suggested_epsilon`] says so.
pub const DEFAULT_PRUNE_EPSILON: f64 = 1.0;

/// Hard sanity bound on the per-layer prediction/measurement ratio that
/// CI enforces (`rust/tests/model_calibration.rs`). Well above the
/// expected band: its job is to catch model regressions (a wrong loop
/// shape, a dropped term), not to certify pruning soundness — the sweep
/// acceptance test self-calibrates ε from measured error instead.
pub const CALIBRATION_SANITY_RATIO: f64 = 8.0;

/// Epsilon that makes epsilon-band pruning sound for a measured
/// multiplicative error ratio `rho` (`pred ∈ [true/ρ, true·ρ]`):
/// `ε = ρ² − 1`. See DESIGN.md §Two-phase sweep for the derivation.
pub fn epsilon_for_ratio(rho: f64) -> f64 {
    (rho * rho - 1.0).max(0.0)
}

/// One layer's predicted cost, split by pipeline stage.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerEstimate {
    /// Read-channel occupancy: input/weight/uop/acc DMA + insn fetch.
    pub read_cycles: u64,
    /// Compute-module busy cycles (GEMM + ALU + its own DMA waits).
    pub compute_cycles: u64,
    /// Write-channel occupancy (stores).
    pub write_cycles: u64,
    /// Serialization correction: latency exposure, fill and drain.
    pub serial_cycles: u64,
    /// Tiling cannot double buffer: load and compute alternate instead
    /// of overlapping, so the stages add rather than max.
    pub serialized: bool,
}

impl LayerEstimate {
    /// Collapse the stage estimates into one cycle count:
    /// max-of-stages under token-pipeline overlap (sum when the tiling
    /// forbids double buffering) plus the serialization correction.
    pub fn cycles(&self) -> u64 {
        let base = if self.serialized {
            self.read_cycles + self.compute_cycles
        } else {
            self.read_cycles.max(self.compute_cycles)
        };
        base.max(self.write_cycles) + self.serial_cycles
    }
}

/// GEMM initiation interval (mirrors `sim::step_compute`).
fn gemm_ii(cfg: &VtaConfig) -> u64 {
    if cfg.gemm_pipelined {
        1
    } else {
        4
    }
}

/// ALU initiation interval (mirrors `sim::step_compute`).
fn alu_ii(cfg: &VtaConfig, use_imm: bool) -> u64 {
    match (cfg.alu_pipelined, use_imm) {
        (true, true) => 1,
        (true, false) => 2,
        (false, true) => 4,
        (false, false) => 5,
    }
}

/// Requantization ALU instructions per accumulator block
/// (`emit_requant`: ADD+SHR when shift > 0, MAX for ReLU, always CLIP).
fn requant_insns(shift: u32, relu: bool) -> u64 {
    u64::from(shift > 0) * 2 + u64::from(relu) + 1
}

/// Predicted cycles of a convolution (or dense: a 1×1 conv spec)
/// lowered with `tiling` — mirrors `compiler::conv::lower_conv`.
///
/// `res_bits` are the layer's residency bits
/// ([`NodePlan::sig_bits`](crate::compiler::residency::NodePlan::sig_bits)):
/// a hot input (bit 0) drops the input-DMA byte and row terms, an
/// elided store (bit 2) drops the write channel. Only DMA terms move —
/// compute work is identical in every residency variant, which is what
/// keeps the model's calibration band intact.
pub fn conv_estimate(
    cfg: &VtaConfig,
    spec: &ConvSpec,
    shift: u32,
    relu: bool,
    t: &Tiling,
    res_bits: u8,
) -> LayerEstimate {
    let hot_in = res_bits & 1 != 0;
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let g = t.geom(spec, cfg);
    let (oh, ow) = (spec.oh() as u64, spec.ow() as u64);
    let (di, dout) = (spec.di(cfg) as u64, spec.dout(cfg) as u64);
    let (th, tw, tco, tci) = (t.th_o as u64, t.tw_o as u64, t.tco_o as u64, t.tci_o as u64);
    let (kh, kw) = (spec.kh as u64, spec.kw as u64);

    // Halo-inclusive rows/cols summed over spatial chunks (the TPS
    // closed form): Σ ((oh_c − 1)·sh + kh) = sh·(OH − th) + th·kh.
    let sum_ih = spec.sh as u64 * oh.saturating_sub(th) + th * kh;
    let sum_iw = spec.sw as u64 * ow.saturating_sub(tw) + tw * kw;

    // Ring-slot structure, exactly as the lowering decides it (double
    // buffering needs 2 slots per scratchpad; a layer without any
    // double-buffered operand buffer serializes load against compute).
    let inp_slots = (cfg.inp_depth / g.inp_block_tiles).clamp(1, 2);
    let wgt_slots = (cfg.wgt_depth / g.wgt_block_tiles).clamp(1, 2);
    let inp_factor = if t.reuse_inp { 1 } else { tco };

    // ---- read channel: DMA bytes + one quantization beat per row ----
    let inp_tile = cfg.inp_tile_bytes() as u64;
    let wgt_tile = cfg.wgt_tile_bytes() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;
    let inp_bytes = if hot_in { 0 } else { di * sum_ih * sum_iw * inp_factor * inp_tile };
    let inp_rows = if hot_in { 0 } else { di * tw * sum_ih * inp_factor };
    let wgt_bytes = th * tw * dout * di * kh * kw * wgt_tile;
    let wgt_rows = th * tw * tci * dout;
    // Uop stream (deduplicated by the builder): the TPS feasibility
    // budget — up to 2 slot variants of the GEMM sequence plus the
    // per-row ALU/reset sequences.
    let uop_count = (2 * g.gemm_uops as u64 + 4 * g.ow_i as u64).min(cfg.uop_depth as u64);
    let uop_bytes = uop_count * cfg.isa_layout().uop_bytes() as u64;
    let n_alu_per = requant_insns(shift, relu);
    let n_insns = th * tw * (tco + di * inp_factor + 2 * tco * tci + tco * n_alu_per + dout) + 4;
    let fetch_bytes = n_insns * INSN_BYTES as u64;
    let read_cycles =
        (inp_bytes + wgt_bytes + uop_bytes + fetch_bytes).div_ceil(w) + inp_rows + wgt_rows;

    // ---- compute: loop shapes from the emitted instructions ----
    let gemm_ops = dout * oh * ow * di * kh * kw; // Σ total_ops over GEMM insns
    let reset_ops = dout * oh * ow;
    let n_gemm = th * tw * tco * tci;
    let n_reset = th * tw * tco;
    let alu_ops = n_alu_per * dout * oh * ow * cfg.batch as u64; // all use_imm
    let n_alu = th * tw * tco * n_alu_per;
    let uop_dma = lat + uop_bytes.div_ceil(w);
    let compute_cycles = (n_gemm + n_reset) * GEMM_PIPE_FILL
        + (gemm_ops + reset_ops) * gemm_ii(cfg)
        + n_alu * ALU_PIPE_FILL
        + alu_ops * alu_ii(cfg, true)
        + uop_dma;

    // ---- write channel (zero-occupancy when the store is elided) ----
    let write_cycles =
        if elide_out { 0 } else { (dout * oh * ow * out_tile).div_ceil(w) + tw * dout * oh };

    // ---- serialization correction: fill the first input/weight block
    // before compute starts; drain the last output block after. ----
    let first_inp = if hot_in { 0 } else { g.inp_block_tiles as u64 * inp_tile };
    let first_block = (first_inp + g.wgt_block_tiles as u64 * wgt_tile).div_ceil(w);
    let last_block =
        if elide_out { 0 } else { (g.acc_block_tiles as u64 * out_tile).div_ceil(w) };
    let serial_cycles = 2 * lat + first_block + last_block;

    let mut est = LayerEstimate {
        read_cycles,
        compute_cycles,
        write_cycles,
        serial_cycles,
        serialized: inp_slots < 2 && wgt_slots < 2,
    };
    // Shared bandwidth-vs-compute bound (Fig 2's roofline): neither
    // stage may be predicted below what the hardware ceilings allow.
    let roof = Roofline::of(cfg);
    est.read_cycles = est.read_cycles.max(roof.bound_cycles(0, inp_bytes + wgt_bytes));
    est.compute_cycles = est.compute_cycles.max(roof.bound_cycles(spec.macs(cfg), 0));
    est
}

/// Predicted cycles of a depthwise layer — mirrors
/// `compiler::depthwise::lower_depthwise` (MOV/MUL/ADD per tap on the
/// ALU; all DMA runs on the compute module, so it serializes). With a
/// hot input (`res_bits` bit 0) the activation-patch DMA drops out —
/// the per-iteration tap loads stay, their DRAM region is never
/// residency-elided.
pub fn depthwise_estimate(cfg: &VtaConfig, p: &DepthwiseParams, res_bits: u8) -> LayerEstimate {
    let hot_in = res_bits & 1 != 0;
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let (oh, ow) = (p.oh() as u64, p.ow() as u64);
    let iw_c = ((p.ow() - 1) * p.stride + p.k) as u64;
    let taps = (p.k * p.k) as u64;
    // Row-chunk sizing, exactly as the lowering chooses it.
    let mut oh_c = p.oh();
    loop {
        let ih_c = (oh_c - 1) * p.stride + p.k;
        let block = ih_c * iw_c as usize + taps as usize + 2 * oh_c * p.ow();
        if 2 * block <= cfg.acc_depth || oh_c == 1 {
            break;
        }
        oh_c = oh_c.div_ceil(2);
    }
    let n_chunks = p.oh().div_ceil(oh_c) as u64;
    let ct = p.c_tiles as u64;
    let iters = ct * n_chunks;
    let sum_ih = p.stride as u64 * oh.saturating_sub(n_chunks) + n_chunks * p.k as u64;
    let acc8_tile = cfg.acc_tile_elems() as u64; // Acc8 view: 1 byte/elem
    let out_tile = cfg.out_tile_bytes() as u64;

    let n_req = requant_insns(p.shift, p.relu);
    let n_insns = iters * (2 + 1 + 3 * taps + n_req + 1) + 4;
    let inp_bytes = if hot_in { 0 } else { ct * sum_ih * iw_c * acc8_tile };
    let inp_rows = if hot_in { 0 } else { ct * sum_ih };
    let read_bytes = inp_bytes + ct * n_chunks * taps * acc8_tile;
    let read_rows = inp_rows + ct * n_chunks;
    let dma_beats = (read_bytes + n_insns * INSN_BYTES as u64).div_ceil(w) + read_rows;

    let uop_count = (2 * (3 * taps + n_req + 1) * ow).min(cfg.uop_depth as u64);
    let uop_bytes = uop_count * cfg.isa_layout().uop_bytes() as u64;
    let elems = ct * oh * ow * cfg.batch as u64;
    // All layer DMA (input patches + taps) runs on the compute module:
    // it serializes with the ALU work, so it lands in compute_cycles.
    let compute_cycles = iters * GEMM_PIPE_FILL
        + ct * oh * ow * gemm_ii(cfg) // reset
        + iters * (3 * taps + n_req) * ALU_PIPE_FILL
        + 3 * taps * elems * alu_ii(cfg, false)
        + n_req * elems * alu_ii(cfg, true)
        + dma_beats
        // Patch + tap loads each expose latency; an elided patch load
        // completes without touching DRAM.
        + (2 - u64::from(hot_in)) * iters * lat
        + lat
        + uop_bytes.div_ceil(w);

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out {
            0
        } else {
            (ct * oh * ow * out_tile).div_ceil(w) + ct * oh
        },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of a pooling layer — mirrors
/// `compiler::eltwise::lower_pool`. Residency bits as in
/// [`conv_estimate`].
pub fn pool_estimate(cfg: &VtaConfig, p: &PoolParams, res_bits: u8) -> LayerEstimate {
    let hot_in = res_bits & 1 != 0;
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let (oh, ow) = (p.oh() as u64, p.ow() as u64);
    let iw_c = ((p.ow() - 1) * p.stride + p.k) as u64;
    let taps = (p.k * p.k) as u64;
    let mut oh_c = p.oh();
    loop {
        let ih_c = (oh_c - 1) * p.stride + p.k;
        let block = ih_c * iw_c as usize + oh_c * p.ow();
        if 2 * block <= cfg.acc_depth || oh_c == 1 {
            break;
        }
        oh_c = oh_c.div_ceil(2);
    }
    let n_chunks = p.oh().div_ceil(oh_c) as u64;
    let ct = p.c_tiles as u64;
    let iters = ct * n_chunks;
    let sum_ih = p.stride as u64 * oh.saturating_sub(n_chunks) + n_chunks * p.k as u64;
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    // Average pooling adds a reset pass and the rounding-shift sequence.
    let n_req = if !p.is_max && p.shift > 0 { 3 } else { 0 };
    let n_reset = u64::from(!p.is_max);
    let n_insns = iters * (1 + n_reset + taps + n_req + 1) + 4;
    let read_bytes = if hot_in { 0 } else { ct * sum_ih * iw_c * acc8_tile };
    let read_rows = if hot_in { 0 } else { ct * sum_ih };
    let dma_beats = (read_bytes + n_insns * INSN_BYTES as u64).div_ceil(w) + read_rows;

    let uop_count = (2 * (taps + n_req + 1) * ow).min(cfg.uop_depth as u64);
    let uop_bytes = uop_count * cfg.isa_layout().uop_bytes() as u64;
    let elems = ct * oh * ow * cfg.batch as u64;
    let compute_cycles = iters * n_reset * GEMM_PIPE_FILL
        + n_reset * ct * oh * ow * gemm_ii(cfg)
        + iters * (taps + n_req) * ALU_PIPE_FILL
        + taps * elems * alu_ii(cfg, false)
        + n_req * elems * alu_ii(cfg, true)
        + dma_beats
        + u64::from(!hot_in) * iters * lat
        + lat
        + uop_bytes.div_ceil(w);

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out {
            0
        } else {
            (ct * oh * ow * out_tile).div_ceil(w) + ct * oh
        },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of a residual add over `total_tiles` activation
/// tiles — mirrors `compiler::eltwise::lower_add`. Bits 0 and 1 of
/// `res_bits` elide the two operand loads independently; bit 2 elides
/// the store.
pub fn add_estimate(cfg: &VtaConfig, total_tiles: usize, relu: bool, res_bits: u8) -> LayerEstimate {
    let cold_ops = 2 - u64::from(res_bits & 1 != 0) - u64::from(res_bits & 2 != 0);
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let tiles = total_tiles as u64;
    let max_loop = (1usize << cfg.isa_layout().loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1) as u64;
    let iters = tiles.div_ceil(chunk);
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    let n_alu_per = 2 + u64::from(relu); // ADD, [MAX], CLIP
    let n_insns = iters * (2 + n_alu_per + 1) + 4;
    let dma_beats = (cold_ops * tiles * acc8_tile + n_insns * INSN_BYTES as u64).div_ceil(w)
        + cold_ops * iters;
    let elems = tiles * cfg.batch as u64;
    let compute_cycles = iters * n_alu_per * ALU_PIPE_FILL
        + elems * alu_ii(cfg, false) // ADD (two-operand)
        + (n_alu_per - 1) * elems * alu_ii(cfg, true) // MAX/CLIP (immediate)
        + dma_beats
        + cold_ops * iters * lat
        + lat;

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out { 0 } else { (tiles * out_tile).div_ceil(w) + iters },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of a shift-softmax over `c_tiles` single-slot
/// iterations of an `h`×`w` map — mirrors
/// `compiler::eltwise::lower_softmax` (one Acc8 load, the 8-instruction
/// MAX-reduce / negate / shift / exp2-table sequence, one store per
/// channel-tile iteration).
pub fn softmax_estimate(
    cfg: &VtaConfig,
    c_tiles: usize,
    h: usize,
    w: usize,
    res_bits: u8,
) -> LayerEstimate {
    let hot_in = res_bits & 1 != 0;
    let elide_out = res_bits & 4 != 0;
    let wd = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let (ct, hw) = (c_tiles as u64, (h * w) as u64);
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    // MOV/MAX/MUL/ADD/SHR/MIN/MOV/SHR; the MAX reduce drops out at h=1.
    let n_alu_per = 7 + u64::from(h > 1);
    let n_insns = ct * (2 + n_alu_per) + 4;
    let read_bytes = if hot_in { 0 } else { ct * hw * acc8_tile };
    let read_rows = if hot_in { 0 } else { ct };
    let dma_beats = (read_bytes + n_insns * INSN_BYTES as u64).div_ceil(wd) + read_rows;

    let uop_count = (2 * hw + 2 * w as u64).min(cfg.uop_depth as u64);
    let uop_bytes = uop_count * cfg.isa_layout().uop_bytes() as u64;
    let elems = ct * hw * cfg.batch as u64;
    let compute_cycles = ct * n_alu_per * ALU_PIPE_FILL
        + 3 * elems * alu_ii(cfg, false) // MOV row0 + MAX reduce + ADD + two-op SHR
        + 4 * elems * alu_ii(cfg, true) // MUL -1, SHR shift, MIN 31, MOV 127
        + dma_beats
        + u64::from(!hot_in) * ct * lat
        + lat
        + uop_bytes.div_ceil(wd);

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out { 0 } else { (ct * hw * out_tile).div_ceil(wd) + ct },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of an elementwise multiply — mirrors
/// `compiler::eltwise::lower_eltmul` (same chunked double-buffered loop
/// as [`add_estimate`], with a MUL and the rounding-shift requant
/// sequence instead of the ADD).
pub fn eltmul_estimate(
    cfg: &VtaConfig,
    total_tiles: usize,
    shift: u32,
    relu: bool,
    res_bits: u8,
) -> LayerEstimate {
    let cold_ops = 2 - u64::from(res_bits & 1 != 0) - u64::from(res_bits & 2 != 0);
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let tiles = total_tiles as u64;
    let max_loop = (1usize << cfg.isa_layout().loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1) as u64;
    let iters = tiles.div_ceil(chunk);
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    let n_alu_per = 2 + 2 * u64::from(shift > 0) + u64::from(relu); // MUL, [ADD+SHR], [MAX], CLIP
    let n_insns = iters * (2 + n_alu_per + 1) + 4;
    let dma_beats = (cold_ops * tiles * acc8_tile + n_insns * INSN_BYTES as u64).div_ceil(w)
        + cold_ops * iters;
    let elems = tiles * cfg.batch as u64;
    let compute_cycles = iters * n_alu_per * ALU_PIPE_FILL
        + elems * alu_ii(cfg, false) // MUL (two-operand)
        + (n_alu_per - 1) * elems * alu_ii(cfg, true) // requant (immediate)
        + dma_beats
        + cold_ops * iters * lat
        + lat;

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out { 0 } else { (tiles * out_tile).div_ceil(w) + iters },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of the layernorm-approx subtract stage — mirrors
/// `compiler::eltwise::lower_sub` (negate the broadcast mean, two-op
/// ADD, CLIP).
pub fn sub_estimate(cfg: &VtaConfig, total_tiles: usize, res_bits: u8) -> LayerEstimate {
    let cold_ops = 2 - u64::from(res_bits & 1 != 0) - u64::from(res_bits & 2 != 0);
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let tiles = total_tiles as u64;
    let max_loop = (1usize << cfg.isa_layout().loop_bits) - 1;
    let chunk = (cfg.acc_depth / 4).min(total_tiles).min(max_loop).max(1) as u64;
    let iters = tiles.div_ceil(chunk);
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    let n_alu_per = 3u64; // MUL -1, ADD, CLIP
    let n_insns = iters * (2 + n_alu_per + 1) + 4;
    let dma_beats = (cold_ops * tiles * acc8_tile + n_insns * INSN_BYTES as u64).div_ceil(w)
        + cold_ops * iters;
    let elems = tiles * cfg.batch as u64;
    let compute_cycles = iters * n_alu_per * ALU_PIPE_FILL
        + elems * alu_ii(cfg, false) // ADD (two-operand)
        + 2 * elems * alu_ii(cfg, true) // MUL -1, CLIP (immediate)
        + dma_beats
        + cold_ops * iters * lat
        + lat;

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out { 0 } else { (tiles * out_tile).div_ceil(w) + iters },
        serial_cycles: lat,
        serialized: false,
    }
}

/// Predicted cycles of an immediate-only unary ALU chain (hard-sigmoid,
/// hard-tanh) of `n_ops` instructions per chunk — mirrors
/// `compiler::eltwise::lower_unary`.
pub fn unary_estimate(
    cfg: &VtaConfig,
    total_tiles: usize,
    n_ops: usize,
    res_bits: u8,
) -> LayerEstimate {
    let hot_in = res_bits & 1 != 0;
    let elide_out = res_bits & 4 != 0;
    let w = cfg.axi_bytes as u64;
    let lat = cfg.dram_latency;
    let tiles = total_tiles as u64;
    let max_loop = (1usize << cfg.isa_layout().loop_bits) - 1;
    let chunk = (cfg.acc_depth / 2).min(total_tiles).min(max_loop).max(1) as u64;
    let iters = tiles.div_ceil(chunk);
    let acc8_tile = cfg.acc_tile_elems() as u64;
    let out_tile = cfg.out_tile_bytes() as u64;

    let n_alu_per = n_ops as u64;
    let n_insns = iters * (1 + n_alu_per + 1) + 4;
    let cold = u64::from(!hot_in);
    let dma_beats =
        (cold * tiles * acc8_tile + n_insns * INSN_BYTES as u64).div_ceil(w) + cold * iters;
    let elems = tiles * cfg.batch as u64;
    let compute_cycles = iters * n_alu_per * ALU_PIPE_FILL
        + n_alu_per * elems * alu_ii(cfg, true)
        + dma_beats
        + cold * iters * lat
        + lat;

    LayerEstimate {
        read_cycles: 0,
        compute_cycles,
        write_cycles: if elide_out { 0 } else { (tiles * out_tile).div_ceil(w) + iters },
        serial_cycles: lat,
        serialized: false,
    }
}

/// One layer's prediction inside a [`GraphPrediction`].
#[derive(Debug, Clone)]
pub struct LayerPrediction {
    pub name: String,
    pub kind: &'static str,
    pub cycles: u64,
}

/// Whole-network prediction: the sum of per-layer estimates (layers run
/// back-to-back as one kernel launch each, so session cycles add).
#[derive(Debug, Clone)]
pub struct GraphPrediction {
    pub cycles: u64,
    pub layers: Vec<LayerPrediction>,
}

/// Predict a whole network on a configuration. Mirrors
/// [`Session::run_graph`](crate::runtime::Session)'s dispatch under the
/// default session options (TPS tilings, improved double buffering, LRU
/// residency): channel-light convolutions fall back to the CPU and
/// predict 0 cycles, exactly as the sweep's evaluation path counts
/// them. Panics on a configuration whose minimal tiling overflows the
/// scratchpads — use [`try_predict_graph`] where infeasibility is a
/// reportable outcome rather than a bug.
pub fn predict_graph(cfg: &VtaConfig, graph: &Graph) -> GraphPrediction {
    predict_graph_cached(cfg, graph, &mut HashMap::new())
}

/// [`predict_graph`] with an external per-layer cache, keyed by the
/// layer-memo signature ([`crate::memo::sig`]) — the same identity the
/// simulator's layer memo uses, so repeated shapes across a grid are
/// estimated once. Residency bits are part of the signature, so a hot
/// and a cold instance of the same shape occupy separate entries.
pub fn predict_graph_cached(
    cfg: &VtaConfig,
    graph: &Graph,
    cache: &mut HashMap<u64, u64>,
) -> GraphPrediction {
    try_predict_graph_cached(cfg, graph, ResidencyMode::default(), cache)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible, residency-aware prediction: an infeasible configuration is
/// a typed [`ConfigError::Infeasible`] instead of a panic.
pub fn try_predict_graph(
    cfg: &VtaConfig,
    graph: &Graph,
    mode: ResidencyMode,
) -> Result<GraphPrediction, ConfigError> {
    try_predict_graph_cached(cfg, graph, mode, &mut HashMap::new())
}

/// [`try_predict_graph`] with an external cache.
///
/// Soundness note for the two-phase sweep (DESIGN.md §Residency
/// planner): the prediction subtracts exactly the DMA byte terms the
/// plan elides and nothing else, and the planner itself is a pure
/// function of `(cfg, graph, mode)` shared with the runtime — so the
/// model-vs-tsim error band, and therefore the ε-pruning argument,
/// is unchanged by residency.
pub fn try_predict_graph_cached(
    cfg: &VtaConfig,
    graph: &Graph,
    mode: ResidencyMode,
    cache: &mut HashMap<u64, u64>,
) -> Result<GraphPrediction, ConfigError> {
    let block = cfg.block_in;
    let shapes = graph.shapes();
    // Same planner invocation as `Session::run_graph` under the default
    // tiling options (tps = true, dbuf_reuse = true).
    let plan = residency::plan(cfg, graph, &shapes, mode, true, true)?;
    let mut layers = Vec::with_capacity(graph.nodes.len().saturating_sub(1));
    let mut total = 0u64;
    for (i, node) in graph.nodes.iter().enumerate().skip(1) {
        let in_shape = shapes[node.inputs[0]];
        let out_shape = shapes[i];
        let bits = plan.sig_bits(i);
        let mut cycles = match &node.op {
            Op::Input => unreachable!("input nodes are index 0 only"),
            Op::Conv { shift, relu, .. } => {
                let spec = graph.conv_spec(i, &shapes);
                if spec.c_in < block {
                    0 // CPU fallback: contributes no accelerator cycles
                } else {
                    conv_cached(cfg, &spec, *shift, *relu, bits, cache)?
                }
            }
            Op::Dense { shift, relu, .. } => {
                let spec = graph.conv_spec(i, &shapes);
                conv_cached(cfg, &spec, *shift, *relu, bits, cache)?
            }
            Op::Depthwise { k, stride, pad, shift, relu, .. } => {
                let p = DepthwiseParams {
                    c_tiles: in_shape.c_tiles(block),
                    h: in_shape.h,
                    w: in_shape.w,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                    shift: *shift,
                    relu: *relu,
                };
                *cache
                    .entry(sig::depthwise_sig(cfg, &p, bits).0)
                    .or_insert_with(|| depthwise_estimate(cfg, &p, bits).cycles())
            }
            Op::MaxPool { k, stride, pad } => {
                let p = PoolParams {
                    c_tiles: in_shape.c_tiles(block),
                    h: in_shape.h,
                    w: in_shape.w,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                    is_max: true,
                    shift: 0,
                };
                *cache
                    .entry(sig::pool_sig(cfg, &p, bits).0)
                    .or_insert_with(|| pool_estimate(cfg, &p, bits).cycles())
            }
            Op::GlobalAvgPool => {
                let p = PoolParams {
                    c_tiles: in_shape.c_tiles(block),
                    h: in_shape.h,
                    w: in_shape.w,
                    k: in_shape.h,
                    stride: 1,
                    pad: 0,
                    is_max: false,
                    shift: clog2((in_shape.h * in_shape.w) as u64),
                };
                *cache
                    .entry(sig::pool_sig(cfg, &p, bits).0)
                    .or_insert_with(|| pool_estimate(cfg, &p, bits).cycles())
            }
            Op::Add { relu } => {
                let tiles = out_shape.tiles(block);
                *cache
                    .entry(sig::add_sig(cfg, tiles, *relu, bits).0)
                    .or_insert_with(|| add_estimate(cfg, tiles, *relu, bits).cycles())
            }
            // Attention GEMMs run one conv per head (the runtime's
            // `run_attn_on_vta`); all heads share the same shape, so one
            // cached per-head estimate scales by `heads`.
            Op::AttnScores { heads, shift } | Op::AttnMix { heads, shift } => {
                let spec = graph.attn_head_spec(i, &shapes);
                if attn_on_vta(cfg, &spec) {
                    *heads as u64 * conv_cached(cfg, &spec, *shift, false, bits, cache)?
                } else {
                    0 // CPU fallback
                }
            }
            Op::SoftmaxApprox { shift } => {
                if softmax_on_vta(cfg, in_shape) {
                    let ct = in_shape.c_tiles(block);
                    *cache
                        .entry(sig::softmax_sig(cfg, ct, in_shape.h, in_shape.w, *shift, bits).0)
                        .or_insert_with(|| {
                            softmax_estimate(cfg, ct, in_shape.h, in_shape.w, bits).cycles()
                        })
                } else {
                    0
                }
            }
            // Pure data-marshalling layers always run on the host.
            Op::HeadTranspose { .. } | Op::ChanSlice { .. } => 0,
            Op::LayerNormApprox => {
                if in_shape.c >= block {
                    let spec = layernorm_mean_spec(in_shape);
                    let mean =
                        conv_cached(cfg, &spec, clog2(in_shape.c as u64), false, bits, cache)?;
                    let tiles = out_shape.tiles(block);
                    mean + *cache
                        .entry(sig::sub_sig(cfg, tiles, bits).0)
                        .or_insert_with(|| sub_estimate(cfg, tiles, bits).cycles())
                } else {
                    0
                }
            }
            Op::EltMul { shift, relu } => {
                let tiles = out_shape.tiles(block);
                *cache
                    .entry(sig::eltmul_sig(cfg, tiles, *shift, *relu, bits).0)
                    .or_insert_with(|| eltmul_estimate(cfg, tiles, *shift, *relu, bits).cycles())
            }
            Op::HardSigmoid | Op::HardTanh => {
                let ops: &[(crate::isa::AluOp, i32)] = if matches!(node.op, Op::HardSigmoid) {
                    &HARD_SIGMOID_OPS
                } else {
                    &HARD_TANH_OPS
                };
                let tiles = out_shape.tiles(block);
                *cache
                    .entry(sig::unary_sig(cfg, tiles, ops, bits).0)
                    .or_insert_with(|| unary_estimate(cfg, tiles, ops.len(), bits).cycles())
            }
        };
        // DTR reruns bill to the consumer that triggered them, exactly
        // as the runtime folds rerun cycles into the consumer's
        // `LayerStat`.
        for &p in &plan.nodes[i].recompute {
            let Op::Add { relu } = &graph.nodes[p].op else {
                unreachable!("only residual adds are recomputable")
            };
            let tiles = shapes[p].tiles(block);
            cycles += *cache
                .entry(sig::add_sig(cfg, tiles, *relu, RECOMPUTE_SIG_BITS).0)
                .or_insert_with(|| add_estimate(cfg, tiles, *relu, RECOMPUTE_SIG_BITS).cycles());
        }
        total += cycles;
        layers.push(LayerPrediction { name: node.name.clone(), kind: node.op.kind(), cycles });
    }
    Ok(GraphPrediction { cycles: total, layers })
}

/// Conv/dense estimate under the runtime's default tiling policy (TPS
/// search + improved double buffering), cached by layer signature.
fn conv_cached(
    cfg: &VtaConfig,
    spec: &ConvSpec,
    shift: u32,
    relu: bool,
    res_bits: u8,
    cache: &mut HashMap<u64, u64>,
) -> Result<u64, ConfigError> {
    // Mirror Session::tiling_for under SessionOptions::default():
    // tps = true, dbuf_reuse = true.
    let t = tps::select_tiling(spec, cfg, true, true)?;
    Ok(*cache
        .entry(sig::conv_sig(cfg, spec, shift, relu, &t, res_bits).0)
        .or_insert_with(|| conv_estimate(cfg, spec, shift, relu, &t, res_bits).cycles()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workloads;

    fn c2() -> ConvSpec {
        tps::resnet18_convs()[0].1
    }

    #[test]
    fn conv_estimate_positive_and_roofline_bounded() {
        let cfg = presets::default_config();
        let t = tps::search(&c2(), &cfg, true);
        let est = conv_estimate(&cfg, &c2(), 8, true, &t, 0);
        let roof = Roofline::of(&cfg);
        assert!(est.cycles() > 0);
        assert!(
            est.compute_cycles >= roof.bound_cycles(c2().macs(&cfg), 0),
            "compute term must respect the compute ceiling"
        );
    }

    #[test]
    fn estimate_monotone_in_axi_width() {
        let spec = c2();
        for axi in [8usize, 16, 32] {
            let narrow = presets::scaled_config(1, 32, 32, 2, axi);
            let wide = presets::scaled_config(1, 32, 32, 2, axi * 2);
            let t = tps::search(&spec, &narrow, true);
            // Tiling search ignores axi width, so the same tiling applies.
            assert_eq!(t, tps::search(&spec, &wide, true));
            assert!(
                conv_estimate(&wide, &spec, 8, true, &t, 0).cycles()
                    <= conv_estimate(&narrow, &spec, 8, true, &t, 0).cycles(),
                "wider memory must never increase the estimate (axi {axi})"
            );
        }
    }

    #[test]
    fn estimate_monotone_in_pipelining() {
        let spec = c2();
        let fast = presets::default_config();
        let mut slow = fast.clone();
        slow.gemm_pipelined = false;
        slow.alu_pipelined = false;
        let t = tps::search(&spec, &fast, true);
        assert!(
            conv_estimate(&fast, &spec, 8, true, &t, 0).cycles()
                < conv_estimate(&slow, &spec, 8, true, &t, 0).cycles(),
            "pipelined units must predict strictly fewer cycles on a compute-heavy conv"
        );
    }

    #[test]
    fn predict_graph_sums_layers_and_skips_cpu_fallback() {
        let cfg = presets::tiny_config();
        let g = workloads::micro_resnet(4, 42);
        let p = predict_graph(&cfg, &g);
        assert_eq!(p.layers.len(), g.nodes.len() - 1);
        assert_eq!(p.cycles, p.layers.iter().map(|l| l.cycles).sum::<u64>());
        // conv1 has 3 input channels < BLOCK=4: CPU fallback, 0 cycles.
        assert_eq!(p.layers[0].name, "conv1");
        assert_eq!(p.layers[0].cycles, 0);
        // Everything accelerated predicts nonzero.
        assert!(p.layers.iter().skip(1).all(|l| l.cycles > 0), "{:?}", p.layers);
    }

    #[test]
    fn predict_graph_cached_is_identical_and_hits() {
        let cfg = presets::tiny_config();
        let g = workloads::micro_resnet(4, 42);
        let cold = predict_graph(&cfg, &g);
        let mut cache = HashMap::new();
        let first = predict_graph_cached(&cfg, &g, &mut cache);
        let filled = cache.len();
        let second = predict_graph_cached(&cfg, &g, &mut cache);
        assert_eq!(cold.cycles, first.cycles);
        assert_eq!(first.cycles, second.cycles);
        assert_eq!(cache.len(), filled, "second pass must be served from the cache");
        assert!(
            filled < g.nodes.len() - 1,
            "CPU-fallback layers must not consume cache entries (and repeated \
             shapes share one)"
        );
    }

    #[test]
    fn residency_bits_subtract_only_dma_terms() {
        let cfg = presets::default_config();
        let t = tps::search(&c2(), &cfg, true);
        let cold = conv_estimate(&cfg, &c2(), 8, true, &t, 0);
        let hot = conv_estimate(&cfg, &c2(), 8, true, &t, 1);
        let both = conv_estimate(&cfg, &c2(), 8, true, &t, 0b101);
        assert_eq!(hot.compute_cycles, cold.compute_cycles, "compute must be untouched");
        assert!(hot.read_cycles < cold.read_cycles, "hot input must shed read DMA");
        assert_eq!(hot.write_cycles, cold.write_cycles);
        assert_eq!(both.write_cycles, 0, "elided store occupies no write channel");
        assert!(both.cycles() <= hot.cycles() && hot.cycles() <= cold.cycles());

        let a_cold = add_estimate(&cfg, 64, true, 0);
        let a_hot = add_estimate(&cfg, 64, true, 0b011);
        assert!(a_hot.compute_cycles < a_cold.compute_cycles, "operand DMA rides compute");
        assert_eq!(a_hot.write_cycles, a_cold.write_cycles);
    }

    #[test]
    fn residency_prediction_never_exceeds_off_and_infeasible_is_typed() {
        let cfg = presets::default_config();
        let g = workloads::micro_resnet(cfg.block_in, 42);
        let plan =
            residency::plan(&cfg, &g, &g.shapes(), ResidencyMode::Lru, true, true).unwrap();
        assert!(plan.elided_bytes > 0, "micro_resnet must elide under the default config");

        let off = try_predict_graph(&cfg, &g, ResidencyMode::Off).unwrap();
        let lru = try_predict_graph(&cfg, &g, ResidencyMode::Lru).unwrap();
        let dtr = try_predict_graph(&cfg, &g, ResidencyMode::Dtr).unwrap();
        assert!(
            lru.cycles < off.cycles,
            "planned residency must subtract DMA work (lru {} vs off {})",
            lru.cycles,
            off.cycles
        );
        for (l, o) in lru.layers.iter().zip(&off.layers) {
            assert!(l.cycles <= o.cycles, "{}: lru layer above off", l.name);
        }
        assert!(dtr.cycles <= off.cycles);
        // The infallible entry point mirrors the session default (LRU).
        assert_eq!(predict_graph(&cfg, &g).cycles, lru.cycles);

        let mut bad = cfg.clone();
        bad.inp_depth = 1;
        bad.wgt_depth = 1;
        bad.acc_depth = 1;
        assert!(matches!(
            try_predict_graph(&bad, &g, ResidencyMode::Lru),
            Err(ConfigError::Infeasible { .. })
        ));
    }

    #[test]
    fn transformer_and_lstm_predict_nonzero() {
        let cfg = presets::default_config();
        let t = predict_graph(&cfg, &workloads::transformer_block(64, 4, 16, 1));
        assert!(t.cycles > 0);
        let scores = t.layers.iter().find(|l| l.kind == "attn_scores").unwrap();
        assert!(scores.cycles > 0, "attention GEMMs must be priced on the default config");
        let sm = t.layers.iter().find(|l| l.kind == "softmax_approx").unwrap();
        assert!(sm.cycles > 0, "softmax fits the default acc scratchpad");
        let l = predict_graph(&cfg, &workloads::lstm_cell(64, 16, 1));
        assert!(l.cycles > 0);
        assert!(l.layers.iter().filter(|x| x.kind == "elt_mul").all(|x| x.cycles > 0));
        // Host-side marshalling layers contribute no accelerator cycles.
        assert!(l.layers.iter().filter(|x| x.kind == "chan_slice").all(|x| x.cycles == 0));
    }

    #[test]
    fn precision_mode_does_not_change_cycle_predictions() {
        // Narrow accumulation shortens the adder, not the pipeline: the
        // cycle model is precision-blind by design (DESIGN.md §Workload
        // families & precision axis) — only area moves.
        let wide = presets::default_config();
        let mut narrow = wide.clone();
        narrow.precision = crate::config::Precision::Narrow;
        for g in [workloads::transformer_block(64, 4, 16, 1), workloads::micro_resnet(16, 7)] {
            assert_eq!(predict_graph(&wide, &g).cycles, predict_graph(&narrow, &g).cycles);
        }
    }

    #[test]
    fn epsilon_derivation() {
        assert_eq!(epsilon_for_ratio(1.0), 0.0);
        assert!((epsilon_for_ratio(2.0) - 3.0).abs() < 1e-12);
        // The default covers ratios up to sqrt(1 + epsilon).
        let covered = (1.0 + DEFAULT_PRUNE_EPSILON).sqrt();
        assert!(covered > 1.4, "default must cover at least ±40% error, covers {covered}");
    }
}
