//! Model calibration against tsim — measures the analytical model's
//! error band so the sweep's pruning epsilon can be chosen soundly.
//!
//! The comparison target is the simulator's own per-layer accounting:
//! [`Session::layer_stats`](crate::runtime::Session) cycles from a
//! timing-only run (bit-identical to functional simulation, a fraction
//! of the wall clock), i.e. exactly the numbers the sweep's
//! [`PerfReport`](crate::sim::PerfReport)/`ModuleStats` pipeline
//! aggregates. `rust/tests/model_calibration.rs` runs this harness over
//! the preset configurations × workload layers; EXPERIMENTS.md records
//! the measured band per PR.

use super::{epsilon_for_ratio, predict_graph};
use crate::compiler::graph::Graph;
use crate::config::VtaConfig;
use crate::engine::BackendKind;
use crate::memo::SIM_SCHEMA_VERSION;
use crate::runtime::{Session, SessionOptions};
use crate::store::{ArtifactKind, ArtifactStore};
use crate::sweep::stable_hash64;
use crate::util::json::{obj, Json};

/// One predicted-vs-measured pair (a layer, or a whole network when
/// `label` ends in `/total`).
#[derive(Debug, Clone)]
pub struct CalibPoint {
    pub label: String,
    pub predicted: u64,
    pub measured: u64,
}

impl CalibPoint {
    /// Multiplicative error ratio ρ = max(pred/meas, meas/pred) ≥ 1.
    pub fn ratio(&self) -> f64 {
        let (p, m) = (self.predicted.max(1) as f64, self.measured.max(1) as f64);
        (p / m).max(m / p)
    }
}

/// Aggregated calibration results.
#[derive(Debug, Clone, Default)]
pub struct CalibrationReport {
    pub points: Vec<CalibPoint>,
}

impl CalibrationReport {
    /// Worst multiplicative error ratio over all points (1.0 if empty).
    pub fn max_ratio(&self) -> f64 {
        self.points.iter().map(|p| p.ratio()).fold(1.0, f64::max)
    }

    /// Geometric-mean error ratio (the typical miss, robust to outliers).
    pub fn geomean_ratio(&self) -> f64 {
        if self.points.is_empty() {
            return 1.0;
        }
        let s: f64 = self.points.iter().map(|p| p.ratio().ln()).sum();
        (s / self.points.len() as f64).exp()
    }

    /// The smallest pruning epsilon that is provably sound for the
    /// measured error band (ε = ρ² − 1; DESIGN.md §Two-phase sweep).
    pub fn suggested_epsilon(&self) -> f64 {
        epsilon_for_ratio(self.max_ratio())
    }

    /// Serialize for the artifact store's `Calibration` kind. The
    /// schema stamp is [`SIM_SCHEMA_VERSION`]: calibration pairs model
    /// predictions with simulator measurements, so any simulator-
    /// semantics bump invalidates them.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                obj([
                    ("label", Json::Str(p.label.clone())),
                    ("predicted", Json::Int(p.predicted as i64)),
                    ("measured", Json::Int(p.measured as i64)),
                ])
            })
            .collect();
        obj([
            ("schema", Json::Int(SIM_SCHEMA_VERSION as i64)),
            ("points", Json::Array(points)),
        ])
    }

    /// Parse a stored report; `None` on any malformed field or a schema
    /// version other than [`SIM_SCHEMA_VERSION`].
    pub fn from_json(j: &Json) -> Option<CalibrationReport> {
        if j.get("schema")?.as_i64()? != SIM_SCHEMA_VERSION as i64 {
            return None;
        }
        let points = j
            .get("points")?
            .as_array()?
            .iter()
            .map(|p| {
                Some(CalibPoint {
                    label: p.get("label")?.as_str()?.to_string(),
                    predicted: p.get("predicted")?.as_i64()? as u64,
                    measured: p.get("measured")?.as_i64()? as u64,
                })
            })
            .collect::<Option<Vec<CalibPoint>>>()?;
        Some(CalibrationReport { points })
    }

    /// Human-readable table: one row per point plus the summary.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<34} {:>12} {:>12} {:>7}\n",
            "layer", "predicted", "measured", "ratio"
        ));
        for p in &self.points {
            out.push_str(&format!(
                "{:<34} {:>12} {:>12} {:>7.2}\n",
                p.label,
                p.predicted,
                p.measured,
                p.ratio()
            ));
        }
        out.push_str(&format!(
            "max ratio {:.2}  geomean {:.2}  sound epsilon >= {:.2}\n",
            self.max_ratio(),
            self.geomean_ratio(),
            self.suggested_epsilon()
        ));
        out
    }
}

/// Calibrate one `(config, graph)` pair: simulate the network once
/// (timing-only tsim — cycle counts are data-independent, so no input
/// tensor is needed), predict it with the analytical model, and pair
/// every accelerated layer plus the network total. CPU-fallback layers
/// (0 cycles on both sides) are excluded.
pub fn calibrate_graph(cfg: &VtaConfig, graph: &Graph) -> CalibrationReport {
    let mut session = Session::new(
        cfg,
        SessionOptions { backend: BackendKind::TsimTiming, ..SessionOptions::default() },
    )
    .expect("calibration runs on validated configs");
    // Timing-only sessions never read tensor data; an empty input skips
    // generation and staging entirely.
    session.run_graph(graph, &[]).expect("calibration graphs are well-formed");

    let prediction = predict_graph(cfg, graph);
    assert_eq!(
        session.layer_stats.len(),
        prediction.layers.len(),
        "model must walk the same layer list as the runtime"
    );
    let mut points = Vec::new();
    for (stat, pred) in session.layer_stats.iter().zip(&prediction.layers) {
        if stat.on_cpu {
            assert_eq!(pred.cycles, 0, "model must mirror the CPU-fallback rule");
            continue;
        }
        points.push(CalibPoint {
            label: format!("{}/{}", cfg.tag(), stat.name),
            predicted: pred.cycles,
            measured: stat.cycles,
        });
    }
    points.push(CalibPoint {
        label: format!("{}/{}/total", cfg.tag(), graph.name),
        predicted: prediction.cycles,
        measured: session.cycles(),
    });
    CalibrationReport { points }
}

/// Artifact-store key of one `(config, graph)` calibration: FNV-1a of
/// the canonical `calibrate|s<sim-schema>|<config JSON>|<graph name>`
/// string (the config's serialized form is deterministic).
pub fn calibration_key(cfg: &VtaConfig, graph: &Graph) -> u64 {
    stable_hash64(&format!(
        "calibrate|s{SIM_SCHEMA_VERSION}|{}|{}",
        cfg.to_json().to_string_compact(),
        graph.name
    ))
}

/// [`calibrate_graph`] through the artifact store: return the stored
/// [`ArtifactKind::Calibration`] report when one exists, else calibrate
/// (one timing-only simulation + one model walk) and persist the result.
pub fn calibrate_graph_with_store(
    cfg: &VtaConfig,
    graph: &Graph,
    store: &ArtifactStore,
) -> std::io::Result<CalibrationReport> {
    let key = calibration_key(cfg, graph);
    if let Some(report) =
        store.get(ArtifactKind::Calibration, key).as_ref().and_then(CalibrationReport::from_json)
    {
        return Ok(report);
    }
    let report = calibrate_graph(cfg, graph);
    store.put(ArtifactKind::Calibration, key, report.to_json())?;
    Ok(report)
}

/// Merge reports (e.g. across the preset grid).
pub fn merge(reports: impl IntoIterator<Item = CalibrationReport>) -> CalibrationReport {
    let mut all = CalibrationReport::default();
    for r in reports {
        all.points.extend(r.points);
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(p: u64, m: u64) -> CalibPoint {
        CalibPoint { label: "t".into(), predicted: p, measured: m }
    }

    #[test]
    fn ratio_is_symmetric_and_at_least_one() {
        assert_eq!(point(100, 100).ratio(), 1.0);
        assert_eq!(point(200, 100).ratio(), 2.0);
        assert_eq!(point(100, 200).ratio(), 2.0);
        assert_eq!(point(0, 0).ratio(), 1.0, "both-zero pairs are exact");
    }

    #[test]
    fn report_aggregates() {
        let r = CalibrationReport { points: vec![point(100, 100), point(300, 100)] };
        assert_eq!(r.max_ratio(), 3.0);
        assert!((r.geomean_ratio() - 3.0f64.sqrt()).abs() < 1e-12);
        assert!((r.suggested_epsilon() - 8.0).abs() < 1e-12);
        assert!(r.render_table().contains("max ratio 3.00"));
    }
}
