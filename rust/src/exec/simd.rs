//! Explicit x86_64 SIMD kernels for the exec hot loops (`--features
//! simd`).
//!
//! Contract: every kernel here is bit-identical to its scalar reference
//! in the parent module ([`dot_i8_scalar`], [`alu_tile_imm_scalar`]) for
//! every input the simulators produce. The exactness argument:
//!
//! * an i8·i8 product always fits in i16, and `pmaddwd`'s pairwise sum
//!   of two such products always fits in i32, so no intermediate is ever
//!   rounded or saturated;
//! * i32 addition is associative and commutative modulo 2^32, so the
//!   vector reassociation of the reduction cannot change the wrapping
//!   sum;
//! * the ALU immediate ops map 1:1 onto lane-wise vector ops (`pminsd`,
//!   `pmaxsd`, `paddd`, `psrad`/`pslld` with a uniform runtime count,
//!   `pmulld` after an in-lane sign-extended byte narrow, and clamp as
//!   max-then-min).
//!
//! Dispatch is by runtime feature detection (`is_x86_feature_detected!`,
//! which caches in an atomic): AVX2 when present; for the dot product
//! the SSE2 x86_64 baseline otherwise; for the ALU loop the scalar
//! reference otherwise (SSE2 lacks `pminsd`/`pmulld`, and the ALU loop
//! is far off the GEMM-dominated critical path). The differential fuzz
//! suite (`rust/tests/simd_event_parity.rs`) asserts scalar/SIMD
//! equality on random inputs, and the parity/digest integration tests
//! run with the feature both on and off in CI.

use super::{alu_eval, alu_tile_imm_scalar, dot_i8_scalar};
use crate::isa::AluOp;
use core::arch::x86_64::*;

#[inline]
fn avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

/// Runtime-dispatched int8 dot product (see [`super::dot_i8`]).
#[inline]
pub(super) fn dot_i8(x: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    if x.len() < 16 {
        return dot_i8_scalar(x, w);
    }
    // SAFETY: SSE2 is part of the x86_64 baseline; the AVX2 path only
    // runs after runtime detection.
    unsafe {
        if avx2() {
            dot_i8_avx2(x, w)
        } else {
            dot_i8_sse2(x, w)
        }
    }
}

/// Runtime-dispatched ALU immediate-mode tile loop (see
/// [`super::alu_tile_imm`]).
#[inline]
pub(super) fn alu_tile_imm(op: AluOp, imm: i32, acc_t: &mut [i32], out_t: &mut [i8]) {
    // Clip with a negative bound panics in the scalar reference (empty
    // clamp range); defer to it so behavior stays identical.
    if acc_t.len() < 8 || (op == AluOp::Clip && imm < 0) || !avx2() {
        return alu_tile_imm_scalar(op, imm, acc_t, out_t);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { alu_acc_imm_avx2(op, imm, acc_t) };
    // Narrow into OUT after the fact — equivalent to the interleaved
    // scalar writes because each OUT element depends only on the final
    // ACC element. This trivial loop autovectorizes on its own.
    for (ov, av) in out_t.iter_mut().zip(acc_t.iter()) {
        *ov = *av as i8;
    }
}

/// # Safety
/// Caller must have verified AVX2 support at runtime.
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(x: &[i8], w: &[i8]) -> i32 {
    let n = x.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    // 16 int8 lanes per iteration: widen to i16 (exact), multiply and
    // pairwise-add with vpmaddwd (exact in i32), accumulate in 8 i32
    // lanes.
    while i + 16 <= n {
        let xv = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
        let wv = _mm_loadu_si128(w.as_ptr().add(i) as *const __m128i);
        let prod = _mm256_madd_epi16(_mm256_cvtepi8_epi16(xv), _mm256_cvtepi8_epi16(wv));
        acc = _mm256_add_epi32(acc, prod);
        i += 16;
    }
    let mut sum = hsum_epi32_128(_mm_add_epi32(
        _mm256_castsi256_si128(acc),
        _mm256_extracti128_si256::<1>(acc),
    ));
    while i < n {
        sum = sum.wrapping_add((x[i] as i16 * w[i] as i16) as i32);
        i += 1;
    }
    sum
}

/// # Safety
/// SSE2 only — unconditionally available on x86_64, but the raw loads
/// still require the slices to be valid (guaranteed by the safe
/// wrapper's bounds).
unsafe fn dot_i8_sse2(x: &[i8], w: &[i8]) -> i32 {
    let n = x.len();
    let mut acc = _mm_setzero_si128();
    let mut i = 0;
    while i + 16 <= n {
        let xv = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
        let wv = _mm_loadu_si128(w.as_ptr().add(i) as *const __m128i);
        // Sign-extend each i8 half to i16: duplicate every byte into
        // both halves of an i16 lane, then arithmetic-shift the copy
        // down — the SSE2 idiom for pmovsxbw.
        let xlo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(xv, xv));
        let xhi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(xv, xv));
        let wlo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(wv, wv));
        let whi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(wv, wv));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(xlo, wlo));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(xhi, whi));
        i += 16;
    }
    let mut sum = hsum_epi32_128(acc);
    while i < n {
        sum = sum.wrapping_add((x[i] as i16 * w[i] as i16) as i32);
        i += 1;
    }
    sum
}

/// Horizontal wrapping sum of 4 i32 lanes.
///
/// # Safety
/// SSE2 only (x86_64 baseline).
unsafe fn hsum_epi32_128(v: __m128i) -> i32 {
    let s = _mm_add_epi32(v, _mm_shuffle_epi32::<0b01_00_11_10>(v));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
    _mm_cvtsi128_si32(s)
}

/// Lane-wise `alu_eval(op, acc[i], imm)` over the accumulator tile.
///
/// # Safety
/// Caller must have verified AVX2 support at runtime, and (for `Clip`)
/// that `imm >= 0`.
#[target_feature(enable = "avx2")]
unsafe fn alu_acc_imm_avx2(op: AluOp, imm: i32, acc_t: &mut [i32]) {
    let n = acc_t.len();
    let ptr = acc_t.as_mut_ptr();
    let iv = _mm256_set1_epi32(imm);
    // Uniform operands hoisted out of the loop: runtime shift counts
    // (psrad/pslld take a count register), the byte-narrowed multiply
    // operand, and the clamp's lower bound.
    let shr = _mm_cvtsi32_si128(imm & 31);
    let shl = _mm_cvtsi32_si128(imm.wrapping_neg() & 31);
    let mul = _mm256_set1_epi32(imm as i8 as i32);
    let clip_lo = _mm256_set1_epi32(imm.wrapping_neg());
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_si256(ptr.add(i) as *const __m256i);
        let r = match op {
            AluOp::Min => _mm256_min_epi32(v, iv),
            AluOp::Max => _mm256_max_epi32(v, iv),
            AluOp::Add => _mm256_add_epi32(v, iv),
            AluOp::Shr => {
                // Negative immediate shifts left (upstream VTA
                // convention), mirroring `alu_eval`.
                if imm >= 0 {
                    _mm256_sra_epi32(v, shr)
                } else {
                    _mm256_sll_epi32(v, shl)
                }
            }
            // 8-bit truncating multiply: in-lane sign-extend of the low
            // byte ((x << 24) >> 24), then a wrapping 32-bit multiply.
            AluOp::Mul => {
                _mm256_mullo_epi32(_mm256_srai_epi32::<24>(_mm256_slli_epi32::<24>(v)), mul)
            }
            AluOp::Clip => _mm256_min_epi32(_mm256_max_epi32(v, clip_lo), iv),
            AluOp::Mov => iv,
        };
        _mm256_storeu_si256(ptr.add(i) as *mut __m256i, r);
        i += 8;
    }
    for e in &mut acc_t[i..] {
        *e = alu_eval(op, *e, imm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn dot_matches_scalar_on_all_lengths() {
        let mut rng = Pcg32::seeded(99);
        for len in 0..80 {
            let x = rng.i8_vec(len);
            let w = rng.i8_vec(len);
            assert_eq!(dot_i8(&x, &w), dot_i8_scalar(&x, &w), "len={len}");
        }
    }

    #[test]
    fn alu_imm_matches_scalar() {
        let mut rng = Pcg32::seeded(7);
        let ops = [
            AluOp::Min,
            AluOp::Max,
            AluOp::Add,
            AluOp::Shr,
            AluOp::Mul,
            AluOp::Clip,
            AluOp::Mov,
        ];
        for len in [0usize, 1, 7, 8, 9, 16, 33] {
            for &op in &ops {
                for imm in [-130, -31, -1, 0, 1, 5, 127, 1 << 20] {
                    let imm = if op == AluOp::Clip { imm.abs() } else { imm };
                    let acc0: Vec<i32> =
                        (0..len).map(|_| rng.next_u32() as i32).collect();
                    let mut acc_a = acc0.clone();
                    let mut acc_b = acc0.clone();
                    let mut out_a = vec![0i8; len];
                    let mut out_b = vec![0i8; len];
                    alu_tile_imm(op, imm, &mut acc_a, &mut out_a);
                    alu_tile_imm_scalar(op, imm, &mut acc_b, &mut out_b);
                    assert_eq!(acc_a, acc_b, "op={op:?} imm={imm} len={len}");
                    assert_eq!(out_a, out_b, "op={op:?} imm={imm} len={len}");
                }
            }
        }
    }
}
