//! Shared functional execution core: the bit-accurate semantics of every
//! VTA instruction over the scratchpads and DRAM.
//!
//! Both simulator targets consume this module — *fsim* executes
//! instructions back-to-back, *tsim* schedules the same state transitions
//! under a cycle-accurate timing model. Sharing the datapath semantics
//! mirrors the paper's methodology where fsim is the behavioral reference
//! whose architectural states are compared against tsim traces (§III-C).

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd;

use crate::config::VtaConfig;
use crate::config::IsaLayout;
use crate::isa::{AluInsn, AluOp, BufferId, GemmInsn, Insn, MemInsn, Opcode, Uop};
use crate::mem::Dram;
use crate::util::hash::Fnv;
use crate::util::json::{obj, Json};

/// Byte/operation counters. LOAD byte counters per buffer feed the
/// Fig 10/11 DRAM-traffic experiments directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecCounters {
    pub insn_count: u64,
    pub gemm_ops: u64,
    pub macs: u64,
    pub alu_ops: u64,
    pub alu_elems: u64,
    pub load_bytes_inp: u64,
    pub load_bytes_wgt: u64,
    pub load_bytes_acc: u64,
    pub load_bytes_uop: u64,
    pub store_bytes: u64,
    pub pad_tiles: u64,
    /// DRAM tiles a load took from a residency-plan-resident region
    /// (the consumer hit hot data instead of paying the DMA).
    pub resident_tile_hits: u64,
    /// Bytes of DMA traffic elided by the residency plan (both the
    /// loads counted by `resident_tile_hits` and elided stores).
    /// Deliberately *not* part of `load_bytes_*`/`store_bytes`: those
    /// stay "bytes actually moved", so Fig 10/11-style traffic numbers
    /// shrink when residency is on.
    pub dma_bytes_elided: u64,
}

impl ExecCounters {
    pub fn load_bytes_total(&self) -> u64 {
        self.load_bytes_inp + self.load_bytes_wgt + self.load_bytes_acc + self.load_bytes_uop
    }

    pub fn dram_bytes_total(&self) -> u64 {
        self.load_bytes_total() + self.store_bytes
    }

    /// Field-wise accumulate — how the runtime splices a memoized
    /// layer's counter delta into a session (see `crate::memo`).
    /// The exhaustive destructure (here and in [`ExecCounters::to_json`])
    /// makes adding a counter field a compile error in every per-field
    /// list rather than a silently dropped counter.
    pub fn accumulate(&mut self, other: &ExecCounters) {
        let ExecCounters {
            insn_count,
            gemm_ops,
            macs,
            alu_ops,
            alu_elems,
            load_bytes_inp,
            load_bytes_wgt,
            load_bytes_acc,
            load_bytes_uop,
            store_bytes,
            pad_tiles,
            resident_tile_hits,
            dma_bytes_elided,
        } = *other;
        self.insn_count += insn_count;
        self.gemm_ops += gemm_ops;
        self.macs += macs;
        self.alu_ops += alu_ops;
        self.alu_elems += alu_elems;
        self.load_bytes_inp += load_bytes_inp;
        self.load_bytes_wgt += load_bytes_wgt;
        self.load_bytes_acc += load_bytes_acc;
        self.load_bytes_uop += load_bytes_uop;
        self.store_bytes += store_bytes;
        self.pad_tiles += pad_tiles;
        self.resident_tile_hits += resident_tile_hits;
        self.dma_bytes_elided += dma_bytes_elided;
    }

    /// Field-wise difference `self - before` (per-layer deltas; counters
    /// are monotonic, so this never underflows on a valid snapshot pair).
    pub fn minus(&self, before: &ExecCounters) -> ExecCounters {
        ExecCounters {
            insn_count: self.insn_count - before.insn_count,
            gemm_ops: self.gemm_ops - before.gemm_ops,
            macs: self.macs - before.macs,
            alu_ops: self.alu_ops - before.alu_ops,
            alu_elems: self.alu_elems - before.alu_elems,
            load_bytes_inp: self.load_bytes_inp - before.load_bytes_inp,
            load_bytes_wgt: self.load_bytes_wgt - before.load_bytes_wgt,
            load_bytes_acc: self.load_bytes_acc - before.load_bytes_acc,
            load_bytes_uop: self.load_bytes_uop - before.load_bytes_uop,
            store_bytes: self.store_bytes - before.store_bytes,
            pad_tiles: self.pad_tiles - before.pad_tiles,
            resident_tile_hits: self.resident_tile_hits - before.resident_tile_hits,
            dma_bytes_elided: self.dma_bytes_elided - before.dma_bytes_elided,
        }
    }

    /// JSON form (the layer-memo spill record field). Lives next to
    /// [`ExecCounters::accumulate`]/[`ExecCounters::minus`] so every
    /// per-field list stays in this one impl.
    pub fn to_json(&self) -> Json {
        let ExecCounters {
            insn_count,
            gemm_ops,
            macs,
            alu_ops,
            alu_elems,
            load_bytes_inp,
            load_bytes_wgt,
            load_bytes_acc,
            load_bytes_uop,
            store_bytes,
            pad_tiles,
            resident_tile_hits,
            dma_bytes_elided,
        } = *self;
        obj([
            ("insn_count", Json::Int(insn_count as i64)),
            ("gemm_ops", Json::Int(gemm_ops as i64)),
            ("macs", Json::Int(macs as i64)),
            ("alu_ops", Json::Int(alu_ops as i64)),
            ("alu_elems", Json::Int(alu_elems as i64)),
            ("load_bytes_inp", Json::Int(load_bytes_inp as i64)),
            ("load_bytes_wgt", Json::Int(load_bytes_wgt as i64)),
            ("load_bytes_acc", Json::Int(load_bytes_acc as i64)),
            ("load_bytes_uop", Json::Int(load_bytes_uop as i64)),
            ("store_bytes", Json::Int(store_bytes as i64)),
            ("pad_tiles", Json::Int(pad_tiles as i64)),
            ("resident_tile_hits", Json::Int(resident_tile_hits as i64)),
            ("dma_bytes_elided", Json::Int(dma_bytes_elided as i64)),
        ])
    }

    /// The exact key set [`ExecCounters::to_json`] emits, in field
    /// order. Public so serialization tests can mutate records
    /// field-by-field.
    pub const JSON_FIELDS: [&'static str; 13] = [
        "insn_count",
        "gemm_ops",
        "macs",
        "alu_ops",
        "alu_elems",
        "load_bytes_inp",
        "load_bytes_wgt",
        "load_bytes_acc",
        "load_bytes_uop",
        "store_bytes",
        "pad_tiles",
        "resident_tile_hits",
        "dma_bytes_elided",
    ];

    /// Inverse of [`ExecCounters::to_json`]; `None` on any missing,
    /// non-integer, or **unknown** field. Rejecting unknown keys makes
    /// the roundtrip lossless: a record that carries more than this
    /// struct can represent (e.g. a counter added by a future schema)
    /// is refused instead of silently dropped, so
    /// `from_json(to_json(c)) == Some(c)` and nothing else parses
    /// (property-tested in `rust/tests/prop_invariants.rs`).
    pub fn from_json(j: &Json) -> Option<ExecCounters> {
        let map = j.as_object()?;
        if map.len() != Self::JSON_FIELDS.len()
            || !Self::JSON_FIELDS.iter().all(|f| map.contains_key(*f))
        {
            return None;
        }
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some(ExecCounters {
            insn_count: int("insn_count")?,
            gemm_ops: int("gemm_ops")?,
            macs: int("macs")?,
            alu_ops: int("alu_ops")?,
            alu_elems: int("alu_elems")?,
            load_bytes_inp: int("load_bytes_inp")?,
            load_bytes_wgt: int("load_bytes_wgt")?,
            load_bytes_acc: int("load_bytes_acc")?,
            load_bytes_uop: int("load_bytes_uop")?,
            store_bytes: int("store_bytes")?,
            pad_tiles: int("pad_tiles")?,
            resident_tile_hits: int("resident_tile_hits")?,
            dma_bytes_elided: int("dma_bytes_elided")?,
        })
    }
}

/// The architectural state of the VTA core: uop buffer and the four data
/// scratchpads.
#[derive(Debug, Clone)]
pub struct CoreState {
    pub cfg: VtaConfig,
    pub layout: IsaLayout,
    pub uop: Vec<Uop>,
    pub inp: Vec<i8>,
    pub wgt: Vec<i8>,
    pub acc: Vec<i32>,
    pub out: Vec<i8>,
    pub counters: ExecCounters,
    /// Timing-only mode: [`CoreState::execute`] maintains every counter
    /// exactly as in functional mode (they are pure functions of the
    /// instruction fields) but skips all datapath effects — scratchpad
    /// and DRAM contents stay stale, and [`CoreState::buffer_digest`] is
    /// unavailable. Cycle counts are unaffected: VTA timing never reads
    /// tensor data (the invariant `rust/tests/memo_correctness.rs`
    /// enforces).
    pub timing_only: bool,
    /// Residency-plan elided DRAM byte ranges `[start, end)`. A memory
    /// transfer wholly contained in one range is *elided*: executed
    /// functionally as always (digests cannot change), but its bytes
    /// are redirected into `dma_bytes_elided` / `resident_tile_hits`
    /// instead of the `load_bytes_*` / `store_bytes` traffic counters,
    /// and tsim gives it zero DMA occupancy. The runtime sets this per
    /// layer from the [`crate::compiler::residency`] plan.
    pub elided: Vec<(u64, u64)>,
}

impl CoreState {
    pub fn new(cfg: &VtaConfig) -> CoreState {
        let layout = cfg.isa_layout();
        CoreState {
            uop: vec![Uop::default(); cfg.uop_depth],
            inp: vec![0; cfg.inp_depth * cfg.inp_tile_elems()],
            wgt: vec![0; cfg.wgt_depth * cfg.wgt_tile_elems()],
            acc: vec![0; cfg.acc_depth * cfg.acc_tile_elems()],
            out: vec![0; cfg.acc_depth * cfg.acc_tile_elems()],
            counters: ExecCounters::default(),
            layout,
            cfg: cfg.clone(),
            timing_only: false,
            elided: Vec::new(),
        }
    }

    /// Replace the elided-transfer ranges (byte addresses, `[start,
    /// end)`). Counters must stay pure functions of the instruction
    /// stream and this set — never of tensor data — so timing-only and
    /// functional runs agree under any plan.
    pub fn set_elided_ranges(&mut self, ranges: Vec<(u64, u64)>) {
        self.elided = ranges;
    }

    /// Is this transfer's whole DRAM byte span inside one elided
    /// range? Pure-padding transfers (no DRAM tiles) never elide.
    /// Public so tsim can give elided transfers zero DMA occupancy
    /// with the exact same predicate the counters use.
    pub fn transfer_elided(&self, m: &MemInsn, tile_bytes: usize) -> bool {
        if self.elided.is_empty() || m.dram_tiles() == 0 {
            return false;
        }
        let tb = tile_bytes as u64;
        let start = m.dram_base as u64 * tb;
        let end = (m.dram_base as u64
            + (m.y_size as u64 - 1) * m.x_stride as u64
            + m.x_size as u64)
            * tb;
        self.elided.iter().any(|&(s, e)| start >= s && end <= e)
    }

    /// Zero the architectural state in place, keeping every allocation:
    /// after a reset the state is indistinguishable from
    /// `CoreState::new(&cfg)` (the batched-evaluation invariant —
    /// [`crate::runtime::Session::reset_for_reuse`] relies on it).
    pub fn reset(&mut self) {
        self.uop.fill(Uop::default());
        self.inp.fill(0);
        self.wgt.fill(0);
        self.acc.fill(0);
        self.out.fill(0);
        self.counters = ExecCounters::default();
        self.elided.clear();
    }

    /// Execute one instruction's full architectural effect.
    pub fn execute(&mut self, insn: &Insn, dram: &mut Dram) {
        self.counters.insn_count += 1;
        match insn {
            Insn::Mem(m) if m.opcode == Opcode::Load => self.exec_load(m, dram),
            Insn::Mem(m) => self.exec_store(m, dram),
            Insn::Gemm(g) => self.exec_gemm(g),
            Insn::Alu(a) => self.exec_alu(a),
            Insn::Finish(_) => {}
        }
    }

    /// Tile byte width of a buffer (DRAM transfer granularity).
    pub fn tile_bytes(&self, buffer: BufferId) -> usize {
        match buffer {
            BufferId::Uop => self.layout.uop_bytes(),
            BufferId::Inp => self.cfg.inp_tile_bytes(),
            BufferId::Wgt => self.cfg.wgt_tile_bytes(),
            BufferId::Acc => self.cfg.acc_tile_bytes(),
            // 8-bit accumulator view: one byte per element in DRAM.
            BufferId::Acc8 => self.cfg.acc_tile_elems(),
            BufferId::Out => self.cfg.out_tile_bytes(),
        }
    }

    /// Scratchpad depth (tiles) of a buffer.
    pub fn buffer_depth(&self, buffer: BufferId) -> usize {
        match buffer {
            BufferId::Uop => self.cfg.uop_depth,
            BufferId::Inp => self.cfg.inp_depth,
            BufferId::Wgt => self.cfg.wgt_depth,
            BufferId::Acc | BufferId::Acc8 | BufferId::Out => self.cfg.acc_depth,
        }
    }

    // ---- LOAD ----

    fn exec_load(&mut self, m: &MemInsn, dram: &Dram) {
        let tile_bytes = self.tile_bytes(m.buffer);
        let depth = self.buffer_depth(m.buffer);
        let rows = (m.y_pad0 + m.y_size + m.y_pad1) as usize;
        let cols = (m.x_pad0 + m.x_size + m.x_pad1) as usize;
        assert!(
            m.sram_base as usize + rows * cols <= depth,
            "LOAD {:?} overflows scratchpad: base {} + {}x{} tiles > depth {}",
            m.buffer,
            m.sram_base,
            rows,
            cols,
            depth
        );
        // Counters are pure functions of the instruction fields and so
        // are maintained identically in timing-only mode; the padded
        // tile count is `sram_tiles - dram_tiles` by construction.
        self.counters.pad_tiles += m.sram_tiles() - m.dram_tiles();
        let dram_bytes = m.dram_tiles() * tile_bytes as u64;
        if self.transfer_elided(m, tile_bytes) {
            // Residency hit: the data is hot, no DMA is paid. Still
            // executed functionally below — elision is a counter and
            // timing property only.
            self.counters.resident_tile_hits += m.dram_tiles();
            self.counters.dma_bytes_elided += dram_bytes;
        } else {
            match m.buffer {
                BufferId::Inp => self.counters.load_bytes_inp += dram_bytes,
                BufferId::Wgt => self.counters.load_bytes_wgt += dram_bytes,
                BufferId::Acc | BufferId::Acc8 => self.counters.load_bytes_acc += dram_bytes,
                BufferId::Uop => self.counters.load_bytes_uop += dram_bytes,
                BufferId::Out => {}
            }
        }
        if self.timing_only {
            return;
        }
        let mut sram = m.sram_base as usize;
        for y in 0..rows {
            let interior_row =
                y >= m.y_pad0 as usize && y < (m.y_pad0 + m.y_size) as usize;
            for x in 0..cols {
                let interior =
                    interior_row && x >= m.x_pad0 as usize && x < (m.x_pad0 + m.x_size) as usize;
                if interior {
                    let dy = y - m.y_pad0 as usize;
                    let dx = x - m.x_pad0 as usize;
                    let dram_tile =
                        m.dram_base as usize + dy * m.x_stride as usize + dx;
                    let bytes = dram.read(dram_tile * tile_bytes, tile_bytes);
                    self.fill_tile(m.buffer, sram, Some(bytes), 0);
                } else {
                    self.fill_tile(m.buffer, sram, None, m.pad_value);
                }
                sram += 1;
            }
        }
    }

    /// Write one scratchpad tile from raw DRAM bytes (`Some`) or fill
    /// with the pad value (`None`).
    fn fill_tile(&mut self, buffer: BufferId, index: usize, bytes: Option<&[u8]>, pad: i8) {
        match buffer {
            BufferId::Uop => {
                let u = match bytes {
                    Some(b) => {
                        let mut raw = [0u8; 8];
                        raw[..b.len()].copy_from_slice(b);
                        Uop::decode(u64::from_le_bytes(raw), &self.layout)
                    }
                    None => Uop::default(),
                };
                self.uop[index] = u;
            }
            BufferId::Inp => {
                let n = self.cfg.inp_tile_elems();
                let dst = &mut self.inp[index * n..(index + 1) * n];
                match bytes {
                    Some(b) => dst.copy_from_slice(bytes_as_i8(b)),
                    None => dst.fill(pad),
                }
            }
            BufferId::Wgt => {
                let n = self.cfg.wgt_tile_elems();
                let dst = &mut self.wgt[index * n..(index + 1) * n];
                match bytes {
                    Some(b) => dst.copy_from_slice(bytes_as_i8(b)),
                    None => dst.fill(pad),
                }
            }
            BufferId::Acc => {
                let n = self.cfg.acc_tile_elems();
                let dst = &mut self.acc[index * n..(index + 1) * n];
                match bytes {
                    Some(b) => {
                        for (d, s) in dst.iter_mut().zip(b.chunks_exact(4)) {
                            *d = i32::from_le_bytes(s.try_into().unwrap());
                        }
                    }
                    None => dst.fill(pad as i32),
                }
            }
            BufferId::Acc8 => {
                // Widening load: int8 DRAM bytes -> int32 accumulator.
                let n = self.cfg.acc_tile_elems();
                let dst = &mut self.acc[index * n..(index + 1) * n];
                match bytes {
                    Some(b) => {
                        for (d, s) in dst.iter_mut().zip(b) {
                            *d = *s as i8 as i32;
                        }
                    }
                    None => dst.fill(pad as i32),
                }
            }
            BufferId::Out => {
                let n = self.cfg.acc_tile_elems();
                let dst = &mut self.out[index * n..(index + 1) * n];
                match bytes {
                    Some(b) => dst.copy_from_slice(bytes_as_i8(b)),
                    None => dst.fill(pad),
                }
            }
        }
    }

    // ---- STORE ----

    fn exec_store(&mut self, m: &MemInsn, dram: &mut Dram) {
        assert_eq!(m.buffer, BufferId::Out, "STORE only reads the OUT scratchpad");
        let tile_bytes = self.cfg.out_tile_bytes();
        let n = self.cfg.acc_tile_elems();
        let depth = self.cfg.acc_depth;
        assert!(
            m.sram_base as usize + m.dram_tiles() as usize <= depth,
            "STORE overflows OUT scratchpad"
        );
        if self.transfer_elided(m, tile_bytes) {
            // Elided store: every consumer takes this output hot, so
            // the DRAM write-back is free (still performed
            // functionally below).
            self.counters.dma_bytes_elided += m.dram_tiles() * tile_bytes as u64;
        } else {
            self.counters.store_bytes += m.dram_tiles() * tile_bytes as u64;
        }
        if self.timing_only {
            return;
        }
        let mut sram = m.sram_base as usize;
        for y in 0..m.y_size as usize {
            for x in 0..m.x_size as usize {
                let dram_tile = m.dram_base as usize + y * m.x_stride as usize + x;
                let src = &self.out[sram * n..(sram + 1) * n];
                dram.write(dram_tile * tile_bytes, i8s_as_bytes(src));
                sram += 1;
            }
        }
    }

    // ---- GEMM ----

    fn exec_gemm(&mut self, g: &GemmInsn) {
        self.counters.gemm_ops += g.total_ops();
        if !g.reset {
            self.counters.macs += g.total_ops() * self.cfg.macs_per_gemm_op() as u64;
        }
        if self.timing_only {
            return;
        }
        let (batch, bi, bo) = (self.cfg.batch, self.cfg.block_in, self.cfg.block_out);
        let narrow = self.cfg.precision == crate::config::Precision::Narrow;
        let acc_n = batch * bo;
        let inp_n = batch * bi;
        let wgt_n = bo * bi;
        // §Perf: the uop window is sliced once instead of a bound-checked
        // `self.uop[uidx]` per iteration, operand tiles are fixed-length
        // subslices, and the dot product multiplies in i16 (`dot_i8`) —
        // this loop is the whole-simulation hot spot.
        let CoreState { uop, inp, wgt, acc, .. } = self;
        let uops = &uop[g.uop_bgn as usize..g.uop_end as usize];
        for i0 in 0..g.lp_out as usize {
            for i1 in 0..g.lp_in as usize {
                for u in uops {
                    let acc_idx = u.acc as usize
                        + i0 * g.acc_f0 as usize
                        + i1 * g.acc_f1 as usize;
                    let acc_t = &mut acc[acc_idx * acc_n..][..acc_n];
                    if g.reset {
                        acc_t.fill(0);
                        continue;
                    }
                    let inp_idx = u.inp as usize
                        + i0 * g.inp_f0 as usize
                        + i1 * g.inp_f1 as usize;
                    let wgt_idx = u.wgt as usize
                        + i0 * g.wgt_f0 as usize
                        + i1 * g.wgt_f1 as usize;
                    let inp_t = &inp[inp_idx * inp_n..][..inp_n];
                    let wgt_t = &wgt[wgt_idx * wgt_n..][..wgt_n];
                    // acc[b][o] += Σ_i inp[b][i] * wgt[o][i]
                    for b in 0..batch {
                        let inp_row = &inp_t[b * bi..][..bi];
                        let acc_row = &mut acc_t[b * bo..][..bo];
                        if narrow {
                            // Narrow precision: the accumulator register
                            // is 16 bits wide and wraps on every tile
                            // update (cycles are unchanged — the
                            // datapath is the same length, just
                            // narrower).
                            for (a, wgt_row) in acc_row.iter_mut().zip(wgt_t.chunks_exact(bi)) {
                                *a = a.wrapping_add(dot_i8(inp_row, wgt_row)) as i16 as i32;
                            }
                        } else {
                            for (a, wgt_row) in acc_row.iter_mut().zip(wgt_t.chunks_exact(bi)) {
                                *a = a.wrapping_add(dot_i8(inp_row, wgt_row));
                            }
                        }
                    }
                }
            }
        }
    }

    // ---- ALU ----

    fn exec_alu(&mut self, a: &AluInsn) {
        let n = self.cfg.acc_tile_elems();
        self.counters.alu_ops += a.total_ops();
        self.counters.alu_elems += a.total_ops() * n as u64;
        if self.timing_only {
            return;
        }
        // §Perf: the per-element mode branches (reset / immediate /
        // in-place / two-operand) are hoisted out of the element loop,
        // which then runs over disjoint tile slices — bounds checks
        // elide and the loop autovectorizes. Every ALU result is also
        // narrowed into the OUT scratchpad (8-bit truncation, as in
        // upstream VTA's fsim).
        let CoreState { uop, acc, out, .. } = self;
        let uops = &uop[a.uop_bgn as usize..a.uop_end as usize];
        for i0 in 0..a.lp_out as usize {
            for i1 in 0..a.lp_in as usize {
                for u in uops {
                    let dst =
                        u.dst() as usize + i0 * a.dst_f0 as usize + i1 * a.dst_f1 as usize;
                    let out_t = &mut out[dst * n..][..n];
                    if a.reset {
                        acc[dst * n..][..n].fill(0);
                        out_t.fill(0);
                        continue;
                    }
                    if a.use_imm {
                        let acc_t = &mut acc[dst * n..][..n];
                        alu_tile_imm(a.op, a.imm, acc_t, out_t);
                        continue;
                    }
                    let src =
                        u.src() as usize + i0 * a.src_f0 as usize + i1 * a.src_f1 as usize;
                    if src == dst {
                        // In-place: each element's rhs is its own
                        // pre-update value, matching the element-at-a-
                        // time read-before-write semantics.
                        let acc_t = &mut acc[dst * n..][..n];
                        for (av, ov) in acc_t.iter_mut().zip(out_t.iter_mut()) {
                            let r = alu_eval(a.op, *av, *av);
                            *av = r;
                            *ov = r as i8;
                        }
                    } else {
                        let (dst_t, src_t) = tile_pair_mut(acc, dst, src, n);
                        for ((av, ov), &sv) in
                            dst_t.iter_mut().zip(out_t.iter_mut()).zip(src_t)
                        {
                            let r = alu_eval(a.op, *av, sv);
                            *av = r;
                            *ov = r as i8;
                        }
                    }
                }
            }
        }
    }

    /// FNV-1a digest of one buffer's contents — the trace-manager hook
    /// for dynamic trace-based validation (§III-C). Unavailable in
    /// timing-only mode, where buffer contents are intentionally stale.
    pub fn buffer_digest(&self, buffer: BufferId) -> u64 {
        assert!(
            !self.timing_only,
            "buffer digests are undefined in timing-only mode (functional effects skipped)"
        );
        let mut h = Fnv::new();
        match buffer {
            BufferId::Uop => {
                for u in &self.uop {
                    h.write_u32(u.acc);
                    h.write_u32(u.inp);
                    h.write_u32(u.wgt);
                }
            }
            BufferId::Inp => h.write_i8s(&self.inp),
            BufferId::Wgt => h.write_i8s(&self.wgt),
            BufferId::Acc | BufferId::Acc8 => {
                for v in &self.acc {
                    h.write_u32(*v as u32);
                }
            }
            BufferId::Out => h.write_i8s(&self.out),
        }
        h.finish()
    }
}

/// Reinterpret raw DRAM bytes as int8 — the inverse of
/// [`Dram::write_i8`]'s cast. `i8` and `u8` share size and layout, so
/// the view is free and lets `fill_tile` use `copy_from_slice` (memcpy)
/// instead of a per-element cast loop.
#[inline]
fn bytes_as_i8(b: &[u8]) -> &[i8] {
    unsafe { std::slice::from_raw_parts(b.as_ptr() as *const i8, b.len()) }
}

/// The opposite view, for bulk STOREs from the OUT scratchpad.
#[inline]
fn i8s_as_bytes(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}

/// Split-borrow two *distinct* accumulator tiles: `dst` mutably, `src`
/// shared. Tiles are index-granular (`n` elements at `idx * n`), so
/// different indices never overlap.
fn tile_pair_mut(acc: &mut [i32], dst: usize, src: usize, n: usize) -> (&mut [i32], &[i32]) {
    debug_assert_ne!(dst, src);
    if dst < src {
        let (lo, hi) = acc.split_at_mut(src * n);
        (&mut lo[dst * n..][..n], &hi[..n])
    } else {
        let (lo, hi) = acc.split_at_mut(dst * n);
        (&mut hi[..n], &lo[src * n..][..n])
    }
}

/// int8 dot product in fixed 16-lane blocks with i16 products (an
/// i8·i8 product always fits in i16): the shape LLVM lowers to the
/// widening multiply-accumulate idiom (`pmaddwd` on x86, `smlal` on
/// AArch64) — roughly twice the vector throughput of an i32-product
/// formulation, since each multiply is half as wide.
///
/// This is the always-compiled scalar reference. [`dot_i8`] dispatches
/// to the explicit SIMD kernels under `--features simd`; the two must be
/// bit-identical for every input (products are exact in i16, `pmaddwd`
/// pair sums are exact in i32, and i32 addition is associative modulo
/// 2^32) — asserted over random inputs by
/// `rust/tests/simd_event_parity.rs`.
#[inline]
pub fn dot_i8_scalar(x: &[i8], w: &[i8]) -> i32 {
    let mut sum = 0i32;
    let mut xc = x.chunks_exact(16);
    let mut wc = w.chunks_exact(16);
    for (xb, wb) in (&mut xc).zip(&mut wc) {
        let xb: &[i8; 16] = xb.try_into().unwrap();
        let wb: &[i8; 16] = wb.try_into().unwrap();
        let mut s = 0i32;
        for k in 0..16 {
            s += (xb[k] as i16 * wb[k] as i16) as i32;
        }
        sum += s;
    }
    for (&a, &b) in xc.remainder().iter().zip(wc.remainder()) {
        sum += (a as i16 * b as i16) as i32;
    }
    sum
}

/// int8 dot product — the GEMM inner kernel. With `--features simd` on
/// x86_64 this dispatches at runtime (`is_x86_feature_detected!`, cached
/// by std) to an explicit AVX2 `vpmaddwd` kernel or the SSE2 x86_64
/// baseline; otherwise it is the scalar reference. All paths return
/// bit-identical results — see [`dot_i8_scalar`].
#[inline]
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn dot_i8(x: &[i8], w: &[i8]) -> i32 {
    simd::dot_i8(x, w)
}

/// int8 dot product — the GEMM inner kernel (scalar build; see
/// [`dot_i8_scalar`] and the `simd` feature).
#[inline]
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn dot_i8(x: &[i8], w: &[i8]) -> i32 {
    dot_i8_scalar(x, w)
}

/// ALU immediate-mode element loop over one accumulator tile: applies
/// `op` with the uniform immediate to every `acc_t` element and narrows
/// each result into `out_t` (8-bit truncation). Always-compiled scalar
/// reference for [`alu_tile_imm`].
#[inline]
pub fn alu_tile_imm_scalar(op: AluOp, imm: i32, acc_t: &mut [i32], out_t: &mut [i8]) {
    for (av, ov) in acc_t.iter_mut().zip(out_t.iter_mut()) {
        let r = alu_eval(op, *av, imm);
        *av = r;
        *ov = r as i8;
    }
}

/// ALU immediate-mode element loop — dispatches to the AVX2 kernel when
/// `--features simd` is on and the CPU supports it, else the scalar
/// reference. Bit-identical either way (the SIMD contract above).
#[inline]
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn alu_tile_imm(op: AluOp, imm: i32, acc_t: &mut [i32], out_t: &mut [i8]) {
    simd::alu_tile_imm(op, imm, acc_t, out_t)
}

/// ALU immediate-mode element loop (scalar build; see
/// [`alu_tile_imm_scalar`] and the `simd` feature).
#[inline]
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn alu_tile_imm(op: AluOp, imm: i32, acc_t: &mut [i32], out_t: &mut [i8]) {
    alu_tile_imm_scalar(op, imm, acc_t, out_t)
}

/// ALU datapath (shared by exec + golden tests). All int32, wrapping.
pub fn alu_eval(op: AluOp, dst: i32, src: i32) -> i32 {
    match op {
        AluOp::Min => dst.min(src),
        AluOp::Max => dst.max(src),
        AluOp::Add => dst.wrapping_add(src),
        AluOp::Shr => {
            // Negative immediate shifts left (upstream VTA convention).
            if src >= 0 {
                dst >> (src & 31)
            } else {
                dst << ((-src) & 31)
            }
        }
        // New (§IV-D3): 8-bit element-wise multiply for depthwise conv —
        // operands are narrowed to int8 before the multiply, matching the
        // 8×8 multiplier the instruction adds in hardware.
        AluOp::Mul => (dst as i8 as i32).wrapping_mul(src as i8 as i32),
        // New: single-instruction clamp to [-imm, imm].
        AluOp::Clip => dst.clamp(-src, src),
        AluOp::Mov => src,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::isa::DepFlags;
    use crate::util::rng::Pcg32;

    fn setup() -> (CoreState, Dram) {
        let cfg = presets::tiny_config();
        (CoreState::new(&cfg), Dram::new(1 << 20))
    }

    fn load_insn(buffer: BufferId, sram: u32, dram: u32, x_size: u32) -> Insn {
        Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer,
            sram_base: sram,
            dram_base: dram,
            y_size: 1,
            x_size,
            x_stride: x_size,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        })
    }

    #[test]
    fn load_inp_roundtrips_dram() {
        let (mut st, mut dram) = setup();
        let tile = st.cfg.inp_tile_bytes();
        let r = dram.alloc(4 * tile, tile);
        let data: Vec<i8> = (0..(4 * tile) as i32).map(|v| (v % 17 - 8) as i8).collect();
        dram.write_i8(r, &data);
        st.execute(&load_insn(BufferId::Inp, 2, r.tile_base(tile), 4), &mut dram);
        assert_eq!(&st.inp[2 * tile..6 * tile], &data[..]);
        assert_eq!(st.counters.load_bytes_inp, (4 * tile) as u64);
    }

    #[test]
    fn load_padding_uses_pad_value() {
        let (mut st, mut dram) = setup();
        let tile = st.cfg.inp_tile_bytes();
        let r = dram.alloc(tile, tile);
        dram.write_i8(r, &vec![1i8; tile]);
        let insn = Insn::Mem(MemInsn {
            opcode: Opcode::Load,
            deps: DepFlags::NONE,
            buffer: BufferId::Inp,
            sram_base: 0,
            dram_base: r.tile_base(tile),
            y_size: 1,
            x_size: 1,
            x_stride: 1,
            y_pad0: 1,
            y_pad1: 0,
            x_pad0: 1,
            x_pad1: 1,
            pad_value: -128,
        });
        st.execute(&insn, &mut dram);
        // Layout: row 0 = 3 pad tiles, row 1 = pad, data, pad.
        let n = st.cfg.inp_tile_elems();
        assert!(st.inp[0..3 * n].iter().all(|&v| v == -128));
        assert!(st.inp[3 * n..4 * n].iter().all(|&v| v == -128));
        assert!(st.inp[4 * n..5 * n].iter().all(|&v| v == 1));
        assert!(st.inp[5 * n..6 * n].iter().all(|&v| v == -128));
        assert_eq!(st.counters.pad_tiles, 5);
    }

    #[test]
    fn gemm_matches_reference_matmul() {
        let (mut st, mut dram) = setup();
        let cfg = st.cfg.clone();
        let mut rng = Pcg32::seeded(11);
        // One tile matmul: inp[0], wgt[0] -> acc[0].
        let inp = rng.i8_vec(cfg.inp_tile_elems());
        let wgt = rng.i8_vec(cfg.wgt_tile_elems());
        let ti = dram.alloc(cfg.inp_tile_bytes(), cfg.inp_tile_bytes());
        let tw = dram.alloc(cfg.wgt_tile_bytes(), cfg.wgt_tile_bytes());
        dram.write_i8(ti, &inp);
        dram.write_i8(tw, &wgt);
        st.execute(&load_insn(BufferId::Inp, 0, ti.tile_base(cfg.inp_tile_bytes()), 1), &mut dram);
        st.execute(&load_insn(BufferId::Wgt, 0, tw.tile_base(cfg.wgt_tile_bytes()), 1), &mut dram);
        st.uop[0] = Uop::gemm(0, 0, 0);
        let gemm = GemmInsn {
            deps: DepFlags::NONE,
            reset: false,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            acc_f0: 0,
            acc_f1: 0,
            inp_f0: 0,
            inp_f1: 0,
            wgt_f0: 0,
            wgt_f1: 0,
        };
        st.execute(&Insn::Gemm(gemm), &mut dram);
        for b in 0..cfg.batch {
            for o in 0..cfg.block_out {
                let expect: i32 = (0..cfg.block_in)
                    .map(|i| {
                        inp[b * cfg.block_in + i] as i32 * wgt[o * cfg.block_in + i] as i32
                    })
                    .sum();
                assert_eq!(st.acc[b * cfg.block_out + o], expect);
            }
        }
        assert_eq!(st.counters.macs, cfg.macs_per_gemm_op() as u64);
    }

    #[test]
    fn gemm_reset_zeroes() {
        let (mut st, mut dram) = setup();
        st.acc[0..st.cfg.acc_tile_elems()].fill(77);
        st.uop[0] = Uop::gemm(0, 0, 0);
        let gemm = GemmInsn {
            deps: DepFlags::NONE,
            reset: true,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            acc_f0: 0,
            acc_f1: 0,
            inp_f0: 0,
            inp_f1: 0,
            wgt_f0: 0,
            wgt_f1: 0,
        };
        st.execute(&Insn::Gemm(gemm), &mut dram);
        assert!(st.acc[..st.cfg.acc_tile_elems()].iter().all(|&v| v == 0));
        assert_eq!(st.counters.macs, 0);
    }

    #[test]
    fn gemm_loop_factors_walk_indices() {
        // 2x1 loop with acc_f0=1 writes two different acc tiles.
        let (mut st, mut dram) = setup();
        st.uop[0] = Uop::gemm(0, 0, 0);
        st.inp.fill(1);
        st.wgt.fill(1);
        let gemm = GemmInsn {
            deps: DepFlags::NONE,
            reset: false,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 2,
            lp_in: 1,
            acc_f0: 1,
            acc_f1: 0,
            inp_f0: 0,
            inp_f1: 0,
            wgt_f0: 0,
            wgt_f1: 0,
        };
        st.execute(&Insn::Gemm(gemm), &mut dram);
        let n = st.cfg.acc_tile_elems();
        let bi = st.cfg.block_in as i32;
        assert!(st.acc[..n].iter().all(|&v| v == bi));
        assert!(st.acc[n..2 * n].iter().all(|&v| v == bi));
        assert!(st.acc[2 * n..3 * n].iter().all(|&v| v == 0));
    }

    #[test]
    fn alu_ops_semantics() {
        assert_eq!(alu_eval(AluOp::Min, 3, -5), -5);
        assert_eq!(alu_eval(AluOp::Max, 3, -5), 3);
        assert_eq!(alu_eval(AluOp::Add, 3, -5), -2);
        assert_eq!(alu_eval(AluOp::Shr, -16, 2), -4);
        assert_eq!(alu_eval(AluOp::Shr, 5, -3), 40); // negative = shift left
        assert_eq!(alu_eval(AluOp::Mul, 300, 2), (300i32 as i8 as i32) * 2); // 8-bit truncation
        assert_eq!(alu_eval(AluOp::Mul, -3, 7), -21);
        assert_eq!(alu_eval(AluOp::Clip, 200, 127), 127);
        assert_eq!(alu_eval(AluOp::Clip, -200, 127), -127);
        assert_eq!(alu_eval(AluOp::Clip, 50, 127), 50);
        assert_eq!(alu_eval(AluOp::Mov, 1, 9), 9);
    }

    #[test]
    fn alu_writes_acc_and_out() {
        let (mut st, mut dram) = setup();
        let n = st.cfg.acc_tile_elems();
        st.acc[..n].fill(300);
        st.uop[0] = Uop::alu(0, 0);
        let alu = AluInsn {
            deps: DepFlags::NONE,
            reset: false,
            op: AluOp::Clip,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            dst_f0: 0,
            dst_f1: 0,
            src_f0: 0,
            src_f1: 0,
            use_imm: true,
            imm: 127,
        };
        st.execute(&Insn::Alu(alu), &mut dram);
        assert!(st.acc[..n].iter().all(|&v| v == 127));
        assert!(st.out[..n].iter().all(|&v| v == 127));
    }

    #[test]
    fn store_writes_out_to_dram() {
        let (mut st, mut dram) = setup();
        let n = st.cfg.acc_tile_elems();
        let tile = st.cfg.out_tile_bytes();
        for (i, v) in st.out[..2 * n].iter_mut().enumerate() {
            *v = i as i8;
        }
        let r = dram.alloc(2 * tile, tile);
        let store = Insn::Mem(MemInsn {
            opcode: Opcode::Store,
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base: 0,
            dram_base: r.tile_base(tile),
            y_size: 1,
            x_size: 2,
            x_stride: 2,
            y_pad0: 0,
            y_pad1: 0,
            x_pad0: 0,
            x_pad1: 0,
            pad_value: 0,
        });
        st.execute(&store, &mut dram);
        let read = dram.read_i8(r);
        let expect: Vec<i8> = (0..2 * n as i32).map(|v| v as i8).collect();
        assert_eq!(read, expect);
        assert_eq!(st.counters.store_bytes, (2 * tile) as u64);
    }

    #[test]
    fn uop_load_decodes() {
        let (mut st, mut dram) = setup();
        let l = st.layout.clone();
        let uops = vec![Uop::gemm(1, 2, 3), Uop::gemm(4, 5, 6)];
        let bytes = Uop::stream_to_bytes(&uops, &l);
        let r = dram.alloc(bytes.len(), l.uop_bytes());
        dram.write(r.addr, &bytes);
        st.execute(
            &load_insn(BufferId::Uop, 10, r.tile_base(l.uop_bytes()), 2),
            &mut dram,
        );
        assert_eq!(st.uop[10], uops[0]);
        assert_eq!(st.uop[11], uops[1]);
    }

    #[test]
    fn digest_changes_with_state() {
        let (mut st, mut dram) = setup();
        let before = st.buffer_digest(BufferId::Acc);
        st.uop[0] = Uop::alu(0, 0);
        let alu = AluInsn {
            deps: DepFlags::NONE,
            reset: false,
            op: AluOp::Mov,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            dst_f0: 0,
            dst_f1: 0,
            src_f0: 0,
            src_f1: 0,
            use_imm: true,
            imm: 5,
        };
        st.execute(&Insn::Alu(alu), &mut dram);
        assert_ne!(st.buffer_digest(BufferId::Acc), before);
    }

    #[test]
    fn alu_two_operand_src_tile_and_in_place() {
        let (mut st, mut dram) = setup();
        let n = st.cfg.acc_tile_elems();
        // dst tile 0, src tile 2 (distinct): element-wise Add.
        for e in 0..n {
            st.acc[e] = e as i32;
            st.acc[2 * n + e] = 100 + e as i32;
        }
        st.uop[0] = Uop::alu(0, 2);
        let alu = AluInsn {
            deps: DepFlags::NONE,
            reset: false,
            op: AluOp::Add,
            uop_bgn: 0,
            uop_end: 1,
            lp_out: 1,
            lp_in: 1,
            dst_f0: 0,
            dst_f1: 0,
            src_f0: 0,
            src_f1: 0,
            use_imm: false,
            imm: 0,
        };
        st.execute(&Insn::Alu(alu), &mut dram);
        for e in 0..n {
            assert_eq!(st.acc[e], 100 + 2 * e as i32);
            assert_eq!(st.out[e], (100 + 2 * e as i32) as i8);
        }
        // In-place (dst == src): each element doubles from its
        // pre-update value.
        let (mut st2, mut dram2) = setup();
        for e in 0..n {
            st2.acc[e] = 3 + e as i32;
        }
        st2.uop[0] = Uop::alu(0, 0);
        st2.execute(&Insn::Alu(alu), &mut dram2);
        for e in 0..n {
            assert_eq!(st2.acc[e], 2 * (3 + e as i32));
        }
    }

    #[test]
    fn timing_only_counters_match_functional() {
        // The same instruction sequence must leave identical counters in
        // functional and timing-only mode — the memo-splicing invariant.
        let cfg = presets::tiny_config();
        let rng = Pcg32::seeded(21);
        let run = |timing_only: bool| -> ExecCounters {
            let mut st = CoreState::new(&cfg);
            st.timing_only = timing_only;
            let mut dram = Dram::new(1 << 20);
            let tile = cfg.inp_tile_bytes();
            let r = dram.alloc(4 * tile, tile);
            dram.write_i8(r, &rng.clone().i8_vec(4 * tile));
            st.execute(&load_insn(BufferId::Inp, 0, r.tile_base(tile), 4), &mut dram);
            let wtile = cfg.wgt_tile_bytes();
            let rw = dram.alloc(wtile, wtile);
            st.execute(&load_insn(BufferId::Wgt, 0, rw.tile_base(wtile), 1), &mut dram);
            st.uop[0] = Uop::gemm(0, 0, 0);
            st.execute(
                &Insn::Gemm(GemmInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    uop_bgn: 0,
                    uop_end: 1,
                    lp_out: 2,
                    lp_in: 2,
                    acc_f0: 1,
                    acc_f1: 0,
                    inp_f0: 0,
                    inp_f1: 0,
                    wgt_f0: 0,
                    wgt_f1: 0,
                }),
                &mut dram,
            );
            st.execute(
                &Insn::Alu(AluInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    op: AluOp::Clip,
                    uop_bgn: 0,
                    uop_end: 1,
                    lp_out: 1,
                    lp_in: 1,
                    dst_f0: 0,
                    dst_f1: 0,
                    src_f0: 0,
                    src_f1: 0,
                    use_imm: true,
                    imm: 127,
                }),
                &mut dram,
            );
            let out_tile = cfg.out_tile_bytes();
            let ro = dram.alloc(out_tile, out_tile);
            st.execute(
                &Insn::Mem(MemInsn {
                    opcode: Opcode::Store,
                    deps: DepFlags::NONE,
                    buffer: BufferId::Out,
                    sram_base: 0,
                    dram_base: ro.tile_base(out_tile),
                    y_size: 1,
                    x_size: 1,
                    x_stride: 1,
                    y_pad0: 0,
                    y_pad1: 0,
                    x_pad0: 0,
                    x_pad1: 0,
                    pad_value: 0,
                }),
                &mut dram,
            );
            st.counters
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn elided_transfers_redirect_counters_not_data() {
        // A load/store inside an elided range must execute its full
        // functional effect while counting into the elided counters
        // instead of the traffic counters.
        let (mut st, mut dram) = setup();
        let tile = st.cfg.inp_tile_bytes();
        let r = dram.alloc(4 * tile, tile);
        let data: Vec<i8> = (0..(4 * tile) as i32).map(|v| (v % 13 - 6) as i8).collect();
        dram.write_i8(r, &data);
        st.set_elided_ranges(vec![(r.addr as u64, (r.addr + r.len) as u64)]);
        st.execute(&load_insn(BufferId::Inp, 0, r.tile_base(tile), 4), &mut dram);
        assert_eq!(&st.inp[..4 * tile], &data[..], "functional effect unchanged");
        assert_eq!(st.counters.load_bytes_inp, 0);
        assert_eq!(st.counters.resident_tile_hits, 4);
        assert_eq!(st.counters.dma_bytes_elided, (4 * tile) as u64);
        // A load outside the range pays as usual.
        let r2 = dram.alloc(2 * tile, tile);
        dram.write_i8(r2, &data[..2 * tile]);
        st.execute(&load_insn(BufferId::Inp, 4, r2.tile_base(tile), 2), &mut dram);
        assert_eq!(st.counters.load_bytes_inp, (2 * tile) as u64);
        // Elided store: data lands in DRAM, bytes land in elided.
        let out_tile = st.cfg.out_tile_bytes();
        let n = st.cfg.acc_tile_elems();
        st.out[..n].fill(9);
        let ro = dram.alloc(out_tile, out_tile);
        st.set_elided_ranges(vec![(ro.addr as u64, (ro.addr + ro.len) as u64)]);
        st.execute(
            &Insn::Mem(MemInsn {
                opcode: Opcode::Store,
                deps: DepFlags::NONE,
                buffer: BufferId::Out,
                sram_base: 0,
                dram_base: ro.tile_base(out_tile),
                y_size: 1,
                x_size: 1,
                x_stride: 1,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            }),
            &mut dram,
        );
        assert_eq!(st.counters.store_bytes, 0);
        assert!(dram.read_i8(ro).iter().all(|&v| v == 9), "store still writes through");
        // Reset clears the elided set with the rest of the state.
        st.reset();
        assert!(st.elided.is_empty());
    }

    #[test]
    #[should_panic(expected = "timing-only")]
    fn timing_only_digest_panics() {
        let cfg = presets::tiny_config();
        let mut st = CoreState::new(&cfg);
        st.timing_only = true;
        st.buffer_digest(BufferId::Acc);
    }

    #[test]
    #[should_panic(expected = "overflows scratchpad")]
    fn load_overflow_panics() {
        let (mut st, mut dram) = setup();
        let depth = st.cfg.inp_depth as u32;
        let _r = dram.alloc(1 << 16, 64);
        st.execute(&load_insn(BufferId::Inp, depth - 1, 0, 4), &mut dram);
    }
}
