//! Floorplanning library (§IV-B "Flexible Floorplans").
//!
//! The paper built a Python library for generating physical floorplans
//! of VTA configurations: "definition of layout objects with design
//! sub-hierarchy name, width, height, and orientation ... capability to
//! instantiate arrays of floorplan instances and flip individual objects
//! ... Result visualization and overlap/spacing, unique instance name
//! checks". This module is that library, plus the paper's ACC-centric
//! VTA floorplan generator (Fig 7b): a tile per accumulator slice
//! containing its GEMM lane and the WGT scratchpad portion feeding it,
//! with INP/UOP/instruction distribution left at the periphery.

use crate::config::VtaConfig;
use std::collections::BTreeSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Orient {
    R0,
    R90,
    /// Mirrored about the Y axis ("flip individual objects").
    MX,
    MY,
}

/// A placed rectangle in the floorplan (leaf = macro, e.g. an SRAM).
#[derive(Debug, Clone)]
pub struct Instance {
    pub name: String,
    pub x: f64,
    pub y: f64,
    pub w: f64,
    pub h: f64,
    pub orient: Orient,
    /// Hierarchy path ("core/acc_tile3/wgt_mem").
    pub hier: String,
}

impl Instance {
    /// Effective bounding box (R90 swaps width/height).
    pub fn bbox(&self) -> (f64, f64, f64, f64) {
        let (w, h) = match self.orient {
            Orient::R90 => (self.h, self.w),
            _ => (self.w, self.h),
        };
        (self.x, self.y, self.x + w, self.y + h)
    }

    pub fn overlaps(&self, other: &Instance) -> bool {
        let (ax0, ay0, ax1, ay1) = self.bbox();
        let (bx0, by0, bx1, by1) = other.bbox();
        ax0 < bx1 && bx0 < ax1 && ay0 < by1 && by0 < ay1
    }
}

#[derive(Debug, Clone, Default)]
pub struct Floorplan {
    pub name: String,
    pub instances: Vec<Instance>,
    /// Die bounds (0,0)..(w,h).
    pub die_w: f64,
    pub die_h: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum FloorplanError {
    Overlap(String, String),
    DuplicateName(String),
    OutOfDie(String),
}

impl std::fmt::Display for FloorplanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FloorplanError::Overlap(a, b) => write!(f, "instances '{a}' and '{b}' overlap"),
            FloorplanError::DuplicateName(n) => write!(f, "duplicate instance name '{n}'"),
            FloorplanError::OutOfDie(n) => write!(f, "instance '{n}' outside the die"),
        }
    }
}

impl Floorplan {
    pub fn new(name: &str, die_w: f64, die_h: f64) -> Floorplan {
        Floorplan { name: name.to_string(), die_w, die_h, instances: Vec::new() }
    }

    pub fn place(&mut self, name: &str, hier: &str, x: f64, y: f64, w: f64, h: f64, orient: Orient) {
        self.instances.push(Instance {
            name: name.to_string(),
            hier: hier.to_string(),
            x,
            y,
            w,
            h,
            orient,
        });
    }

    /// Instantiate a grid array of identical objects ("capability to
    /// instantiate arrays of floorplan instances"), optionally flipping
    /// alternate columns (common for abutted power rails).
    #[allow(clippy::too_many_arguments)]
    pub fn place_array(
        &mut self,
        base_name: &str,
        hier: &str,
        x0: f64,
        y0: f64,
        w: f64,
        h: f64,
        nx: usize,
        ny: usize,
        pitch_x: f64,
        pitch_y: f64,
        flip_alternate: bool,
    ) {
        for j in 0..ny {
            for i in 0..nx {
                let orient = if flip_alternate && i % 2 == 1 { Orient::MY } else { Orient::R0 };
                self.place(
                    &format!("{base_name}_{j}_{i}"),
                    hier,
                    x0 + i as f64 * pitch_x,
                    y0 + j as f64 * pitch_y,
                    w,
                    h,
                    orient,
                );
            }
        }
    }

    /// The paper's checks: unique instance names, no overlapping macros,
    /// everything inside the die.
    pub fn check(&self) -> Result<(), FloorplanError> {
        let mut names = BTreeSet::new();
        for inst in &self.instances {
            if !names.insert(inst.name.clone()) {
                return Err(FloorplanError::DuplicateName(inst.name.clone()));
            }
            let (x0, y0, x1, y1) = inst.bbox();
            if x0 < -1e-9 || y0 < -1e-9 || x1 > self.die_w + 1e-9 || y1 > self.die_h + 1e-9 {
                return Err(FloorplanError::OutOfDie(inst.name.clone()));
            }
        }
        for i in 0..self.instances.len() {
            for j in i + 1..self.instances.len() {
                if self.instances[i].overlaps(&self.instances[j]) {
                    return Err(FloorplanError::Overlap(
                        self.instances[i].name.clone(),
                        self.instances[j].name.clone(),
                    ));
                }
            }
        }
        Ok(())
    }

    /// Macro-area utilization of the die.
    pub fn utilization(&self) -> f64 {
        let used: f64 = self.instances.iter().map(|i| i.w * i.h).sum();
        used / (self.die_w * self.die_h)
    }

    /// ASCII visualization ("Result visualization").
    pub fn ascii(&self, cols: usize, rows: usize) -> String {
        let mut grid = vec![vec!['.'; cols]; rows];
        for (idx, inst) in self.instances.iter().enumerate() {
            let ch = char::from(b'A' + (idx % 26) as u8);
            let (x0, y0, x1, y1) = inst.bbox();
            let c0 = (x0 / self.die_w * cols as f64) as usize;
            let c1 = ((x1 / self.die_w * cols as f64).ceil() as usize).min(cols);
            let r0 = (y0 / self.die_h * rows as f64) as usize;
            let r1 = ((y1 / self.die_h * rows as f64).ceil() as usize).min(rows);
            for r in r0..r1 {
                for c in c0..c1 {
                    grid[r][c] = ch;
                }
            }
        }
        let mut out = format!("floorplan '{}' ({}x{})\n", self.name, self.die_w, self.die_h);
        for row in grid.iter().rev() {
            out.push_str(&row.iter().collect::<String>());
            out.push('\n');
        }
        out
    }
}

/// SRAM macro geometry: area proportional to bits, aspect ratio ~2:1.
fn sram_dims(bytes: usize) -> (f64, f64) {
    let area = bytes as f64 * 8.0; // 1 unit^2 per bit
    let h = (area / 2.0).sqrt();
    (2.0 * h, h)
}

/// Generate the ACC-centric VTA floorplan of Fig 7b: a row-major array
/// of accumulator tiles, each containing the ACC slice, its GEMM lane
/// and the WGT scratchpad portion feeding that slice ("It makes sense to
/// place a portion of WGT scratchpad close to respective ACC module"),
/// with INP/UOP/OUT memories and the instruction path at the periphery.
pub fn vta_floorplan(cfg: &VtaConfig) -> Floorplan {
    // One tile per BLOCK_OUT lane group; paper groups "as many units as
    // needed to complete computation in one cycle".
    let n_tiles = cfg.block_out.min(16);
    let acc_bytes = cfg.acc_depth * cfg.acc_tile_bytes() / n_tiles;
    let wgt_bytes = cfg.wgt_depth * cfg.wgt_tile_bytes() / n_tiles;
    let (acc_w, acc_h) = sram_dims(acc_bytes);
    let (wgt_w, wgt_h) = sram_dims(wgt_bytes);
    let mac_h = (cfg.batch * cfg.block_in) as f64 * 2.0;
    let tile_w = acc_w.max(wgt_w) + 4.0;
    let tile_h = acc_h + wgt_h + mac_h + 6.0;

    let nx = (n_tiles as f64).sqrt().ceil() as usize;
    let ny = n_tiles.div_ceil(nx);
    let (inp_w, inp_h) = sram_dims(cfg.inp_depth * cfg.inp_tile_bytes());
    let (uop_w, uop_h) = sram_dims(cfg.uop_depth * cfg.isa_layout().uop_bytes());
    let (out_w, out_h) = sram_dims(cfg.acc_depth * cfg.out_tile_bytes());

    let core_w = nx as f64 * tile_w;
    let periph_h = inp_h.max(uop_h).max(out_h) + 4.0;
    let die_w = core_w.max(inp_w + uop_w + out_w + 8.0) + 8.0;
    let die_h = ny as f64 * tile_h + periph_h + 8.0;

    let mut fp = Floorplan::new(&format!("vta-{}", cfg.tag()), die_w, die_h);
    // Peripheral row: INP, UOP, OUT memories + instruction path.
    fp.place("inp_mem", "core/inp", 2.0, 2.0, inp_w, inp_h, Orient::R0);
    fp.place("uop_mem", "core/uop", 4.0 + inp_w, 2.0, uop_w, uop_h, Orient::R0);
    fp.place("out_mem", "core/out", 6.0 + inp_w + uop_w, 2.0, out_w, out_h, Orient::R0);
    // ACC-centric tiles.
    for t in 0..n_tiles {
        let ix = t % nx;
        let iy = t / nx;
        let x0 = 4.0 + ix as f64 * tile_w;
        let y0 = periph_h + 4.0 + iy as f64 * tile_h;
        let hier = format!("core/acc_tile{t}");
        fp.place(&format!("acc_mem{t}"), &hier, x0, y0, acc_w, acc_h, Orient::R0);
        fp.place(
            &format!("gemm_lane{t}"),
            &hier,
            x0,
            y0 + acc_h + 2.0,
            acc_w.max(wgt_w),
            mac_h,
            if t % 2 == 1 { Orient::MY } else { Orient::R0 },
        );
        fp.place(
            &format!("wgt_mem{t}"),
            &hier,
            x0,
            y0 + acc_h + mac_h + 4.0,
            wgt_w,
            wgt_h,
            Orient::R0,
        );
    }
    fp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn overlap_detection() {
        let mut fp = Floorplan::new("t", 100.0, 100.0);
        fp.place("a", "h", 0.0, 0.0, 10.0, 10.0, Orient::R0);
        fp.place("b", "h", 5.0, 5.0, 10.0, 10.0, Orient::R0);
        assert!(matches!(fp.check(), Err(FloorplanError::Overlap(_, _))));
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut fp = Floorplan::new("t", 100.0, 100.0);
        fp.place("a", "h", 0.0, 0.0, 10.0, 10.0, Orient::R0);
        fp.place("a", "h", 20.0, 0.0, 10.0, 10.0, Orient::R0);
        assert!(matches!(fp.check(), Err(FloorplanError::DuplicateName(_))));
    }

    #[test]
    fn out_of_die_rejected() {
        let mut fp = Floorplan::new("t", 10.0, 10.0);
        fp.place("a", "h", 5.0, 5.0, 10.0, 10.0, Orient::R0);
        assert!(matches!(fp.check(), Err(FloorplanError::OutOfDie(_))));
    }

    #[test]
    fn r90_swaps_bbox() {
        let i = Instance {
            name: "x".into(),
            hier: "h".into(),
            x: 0.0,
            y: 0.0,
            w: 4.0,
            h: 2.0,
            orient: Orient::R90,
        };
        assert_eq!(i.bbox(), (0.0, 0.0, 2.0, 4.0));
    }

    #[test]
    fn array_placement_unique_and_clean() {
        let mut fp = Floorplan::new("t", 100.0, 100.0);
        fp.place_array("m", "h", 0.0, 0.0, 8.0, 8.0, 4, 3, 10.0, 10.0, true);
        assert_eq!(fp.instances.len(), 12);
        fp.check().unwrap();
        // Alternate columns flipped.
        assert_eq!(fp.instances[1].orient, Orient::MY);
        assert_eq!(fp.instances[2].orient, Orient::R0);
    }

    #[test]
    fn vta_floorplans_check_clean_for_presets() {
        for cfg in presets::all() {
            let fp = vta_floorplan(&cfg);
            fp.check().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
            assert!(fp.utilization() > 0.05, "{}: unreasonably sparse", cfg.name);
            assert!(fp.utilization() < 1.0);
        }
    }

    #[test]
    fn ascii_visualization_nonempty() {
        let fp = vta_floorplan(&presets::default_config());
        let art = fp.ascii(60, 20);
        assert!(art.lines().count() == 21);
        assert!(art.contains('A'));
    }
}
