//! On-disk, resumable result cache for the sweep engine.
//!
//! Append-only JSONL (`util/json` codec): one completed design point per
//! line, written and flushed as workers finish, so a killed sweep loses
//! at most the in-flight points. Records are keyed by a stable FNV-1a
//! hash of the canonical `(config JSON, workload id, seed, graph seed)`
//! string — the config's serialized form is deterministic (BTreeMap
//! keys), so keys survive process restarts and cross-machine moves.
//!
//! Loading tolerates a truncated or corrupt line (the kill-mid-write
//! case): such lines are counted in [`ResultCache::skipped`] and their
//! points simply re-simulate on resume.
//!
//! Records and keys carry two schema versions —
//! [`SWEEP_SCHEMA_VERSION`](super::SWEEP_SCHEMA_VERSION) (the record
//! format, e.g. v3's `predicted_cycles` field) and
//! [`SIM_SCHEMA_VERSION`](crate::memo::SIM_SCHEMA_VERSION) (the
//! simulator semantics) — so a cache written under either an older
//! format or older simulation semantics is rejected at load (every line
//! counts as skipped) *and* misses by key, and stale results are
//! re-simulated rather than silently mixed with new ones. Every stored
//! `cycles` value is tsim-measured: the two-phase engine never writes a
//! model estimate into the cache (pruned points produce no records).

use super::{PointResult, RecordParse};
use crate::store::{ArtifactKind, ArtifactStore};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufRead, BufReader, Write};
use std::path::Path;
use std::sync::Arc;

pub struct ResultCache {
    seen: BTreeMap<u64, PointResult>,
    file: Option<File>,
    store: Option<Arc<ArtifactStore>>,
    /// Valid records recovered from an existing cache file.
    pub loaded: usize,
    /// Unparsable lines ignored during load (truncated final write).
    pub skipped: usize,
    /// Well-formed records rejected for carrying an older schema
    /// version (surfaced so warm runs can warn instead of silently
    /// re-simulating the whole grid).
    pub skipped_stale: usize,
}

impl ResultCache {
    /// Cache without a backing file (results kept only in memory).
    pub fn in_memory() -> ResultCache {
        ResultCache {
            seen: BTreeMap::new(),
            file: None,
            store: None,
            loaded: 0,
            skipped: 0,
            skipped_stale: 0,
        }
    }

    /// Cache backed by the artifact store: existing
    /// [`ArtifactKind::PointMeasurement`] records are loaded (always —
    /// the store is one shared pool, so `resume` does not apply) and new
    /// results land as store artifacts instead of a private JSONL file.
    pub fn store_backed(store: Arc<ArtifactStore>) -> ResultCache {
        let mut seen = BTreeMap::new();
        let mut loaded = 0;
        for (key, payload) in store.records(ArtifactKind::PointMeasurement) {
            if let Some(result) = PointResult::from_json(&payload) {
                seen.insert(key, result);
                loaded += 1;
            }
        }
        let (_, skipped, skipped_stale) = store.kind_counts(ArtifactKind::PointMeasurement);
        ResultCache { seen, file: None, store: Some(store), loaded, skipped, skipped_stale }
    }

    /// Open a file-backed cache. With `resume`, existing records are
    /// loaded and new ones appended; without, the file is truncated and
    /// the sweep starts cold.
    pub fn open(path: &Path, resume: bool) -> io::Result<ResultCache> {
        let mut seen = BTreeMap::new();
        let mut loaded = 0;
        let mut skipped = 0;
        let mut skipped_stale = 0;
        if resume && path.exists() {
            let reader = BufReader::new(File::open(path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match Json::parse(&line) {
                    Ok(j) => match PointResult::classify(&j) {
                        RecordParse::Valid(result) => {
                            seen.insert(result.cache_key(), *result);
                            loaded += 1;
                        }
                        RecordParse::Stale { .. } => skipped_stale += 1,
                        RecordParse::Malformed => skipped += 1,
                    },
                    Err(_) => skipped += 1,
                }
            }
        }
        let file = if resume {
            OpenOptions::new().create(true).append(true).open(path)?
        } else {
            OpenOptions::new().create(true).write(true).truncate(true).open(path)?
        };
        Ok(ResultCache { seen, file: Some(file), store: None, loaded, skipped, skipped_stale })
    }

    pub fn get(&self, key: u64) -> Option<&PointResult> {
        self.seen.get(&key)
    }

    pub fn contains(&self, key: u64) -> bool {
        self.seen.contains_key(&key)
    }

    pub fn len(&self) -> usize {
        self.seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }

    /// Record a completed point: one JSONL line (or one store
    /// artifact), flushed immediately so a kill after this call never
    /// loses the result.
    pub fn insert(&mut self, result: &PointResult) -> io::Result<()> {
        if let Some(store) = &self.store {
            store.put(ArtifactKind::PointMeasurement, result.cache_key(), result.to_json())?;
        } else if let Some(file) = &mut self.file {
            let mut line = result.to_json().to_string_compact();
            line.push('\n');
            file.write_all(line.as_bytes())?;
            file.flush()?;
        }
        self.seen.insert(result.cache_key(), result.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use std::path::PathBuf;

    fn sample(seed: u64) -> PointResult {
        PointResult {
            config: presets::tiny_config(),
            workload: "micro@4".to_string(),
            seed,
            graph_seed: 42,
            cycles: 1000 + seed,
            macs: 5000,
            dram_rd: 640,
            dram_wr: 320,
            insns: 12,
            scaled_area: 0.25,
            predicted_cycles: Some(900 + seed),
            measured: true,
            residency: crate::compiler::residency::ResidencyMode::Lru,
        }
    }

    fn temp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("vta_cache_test_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn in_memory_roundtrip() {
        let mut c = ResultCache::in_memory();
        let r = sample(1);
        c.insert(&r).unwrap();
        assert_eq!(c.get(r.cache_key()), Some(&r));
        assert!(!c.contains(sample(2).cache_key()));
    }

    #[test]
    fn file_backed_resume_recovers_records() {
        let path = temp_path("resume");
        {
            let mut c = ResultCache::open(&path, false).unwrap();
            c.insert(&sample(1)).unwrap();
            c.insert(&sample(2)).unwrap();
        }
        let c = ResultCache::open(&path, true).unwrap();
        assert_eq!(c.loaded, 2);
        assert_eq!(c.skipped, 0);
        assert_eq!(c.get(sample(1).cache_key()).unwrap().cycles, 1001);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_tail_line_is_skipped() {
        let path = temp_path("truncated");
        {
            let mut c = ResultCache::open(&path, false).unwrap();
            c.insert(&sample(1)).unwrap();
        }
        // Simulate a kill mid-write: append half a record.
        let mut text = std::fs::read_to_string(&path).unwrap();
        let full = text.clone();
        text.push_str(&full[..full.len() / 2].replace('\n', " "));
        std::fs::write(&path, &text).unwrap();
        let c = ResultCache::open(&path, true).unwrap();
        assert_eq!(c.loaded, 1);
        assert_eq!(c.skipped, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn store_backed_cache_shares_point_artifacts() {
        let store = Arc::new(ArtifactStore::in_memory());
        let r = sample(1);
        {
            let mut c = ResultCache::store_backed(store.clone());
            c.insert(&r).unwrap();
        }
        // A second cache over the same store sees the record: this is
        // how sweep, repro, and serve share measurements.
        let c = ResultCache::store_backed(store.clone());
        assert_eq!(c.loaded, 1);
        assert_eq!(c.get(r.cache_key()), Some(&r));
        assert_eq!(store.len(ArtifactKind::PointMeasurement), 1);
    }

    #[test]
    fn stale_schema_lines_are_counted_separately() {
        let path = temp_path("stale");
        {
            let mut c = ResultCache::open(&path, false).unwrap();
            c.insert(&sample(1)).unwrap();
            c.insert(&sample(2)).unwrap();
        }
        // Age one record's schema stamp; it must load as stale, not
        // malformed (the distinction drives the CLI's migration hint).
        let text = std::fs::read_to_string(&path).unwrap();
        let current = format!("\"schema\":{}", crate::sweep::SWEEP_SCHEMA_VERSION);
        let (first, rest) = text.split_once('\n').unwrap();
        let aged = format!("{}\n{rest}", first.replace(&current, "\"schema\":2"));
        std::fs::write(&path, aged).unwrap();
        let c = ResultCache::open(&path, true).unwrap();
        assert_eq!(c.loaded, 1);
        assert_eq!(c.skipped, 0);
        assert_eq!(c.skipped_stale, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_without_resume_truncates() {
        let path = temp_path("truncate");
        {
            let mut c = ResultCache::open(&path, false).unwrap();
            c.insert(&sample(1)).unwrap();
        }
        let c = ResultCache::open(&path, false).unwrap();
        assert_eq!(c.loaded, 0);
        assert!(c.is_empty());
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        std::fs::remove_file(&path).ok();
    }
}
