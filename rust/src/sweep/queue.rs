//! Work-stealing job queue for the sweep workers.
//!
//! The implementation moved to [`crate::util::pool`] when the serving
//! runtime (`crate::serve`) started sharing it; this module keeps the
//! historical `sweep::queue::JobQueue` path alive for the sweep engine
//! and its tests. See the pool module for the design rationale
//! (round-robin striping, opposite-end stealing, `Mutex` per deque).

pub use crate::util::pool::JobQueue;
