//! Parallel design-space-exploration engine (the paper's Fig 13
//! workflow, generalized and made a first-class subsystem).
//!
//! A sweep is a (configuration × workload × seed) grid. The engine:
//!
//! * expands the grid into jobs and shards them across `std::thread`
//!   workers through a work-stealing deque ([`queue::JobQueue`]);
//! * evaluates each point by running the full stack — graph build,
//!   compile, cycle-accurate tsim — exactly as the serial drivers do, so
//!   a parallel sweep is bit-identical to a serial one;
//! * streams finished points into an on-disk resumable JSONL cache
//!   ([`cache::ResultCache`]) keyed by a stable hash of the point, so a
//!   killed sweep resumes where it stopped and warm re-runs are instant;
//! * maintains the (scaled area, cycles) Pareto frontier incrementally
//!   ([`pareto::ParetoFront`]) as results land;
//! * shares a [`LayerMemo`](crate::memo::LayerMemo) across all workers
//!   ([`SweepOptions::memo`]): a layer's cycle count is a pure function
//!   of (config, op, tiling), so repeated layer shapes — within one
//!   network, across ResNet depths, and across input seeds — simulate
//!   once per unique signature instead of once per grid cell. Combined
//!   with [`SweepOptions::timing_only`] this collapses the Fig 13 grid
//!   from O(cells × layers) simulations to O(unique (config, layer))
//!   with bit-identical cycles and counters (see
//!   `rust/tests/sweep_engine.rs`).
//!
//! Determinism: simulation is seeded and single-threaded per point, the
//! result vector is indexed by job order (grid order), and the frontier
//! is an order-independent set — so the outcome is byte-identical
//! regardless of `--jobs`, of cache warmth, and of the memo/timing-only
//! fast paths (memo records are deterministic, so whichever worker
//! simulates a layer first records the same values).

pub mod cache;
pub mod grid;
pub mod pareto;
pub mod queue;

pub use cache::ResultCache;
pub use grid::{GridSpec, WorkloadSpec};
pub use pareto::{ParetoFront, ParetoPoint};

use crate::analysis::area;
use crate::compiler::graph::Graph;
use crate::config::VtaConfig;
use crate::memo::{LayerMemo, SIM_SCHEMA_VERSION};
use crate::runtime::{Session, SessionOptions};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;
use queue::JobQueue;
use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

/// Stable 64-bit cache-key hash (FNV-1a via `util::hash`): stable
/// across processes, which `std::hash` explicitly is not.
pub fn stable_hash64(s: &str) -> u64 {
    crate::util::hash::fnv1a64(s)
}

/// Canonical identity string of a design point; its hash is the cache
/// key. The config's JSON form is deterministic (sorted keys). The
/// simulator schema version leads the string, so caches written under
/// older simulation semantics miss cleanly instead of being silently
/// mixed with new results (their records are additionally rejected at
/// load — see [`PointResult::from_json`]).
fn key_string(cfg: &VtaConfig, workload: &str, seed: u64, graph_seed: u64) -> String {
    format!(
        "v{SIM_SCHEMA_VERSION}|{}|{}|{}|{}",
        cfg.to_json().to_string_compact(),
        workload,
        seed,
        graph_seed
    )
}

/// The grid a sweep covers: every valid config × workload × seed.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub configs: Vec<VtaConfig>,
    pub workloads: Vec<WorkloadSpec>,
    /// Input-data seeds; one job per seed.
    pub seeds: Vec<u64>,
    /// Synthetic-weight seed shared by all jobs.
    pub graph_seed: u64,
}

impl SweepSpec {
    /// Expand into the job list, skipping configurations that fail
    /// `validate()` (exactly as the serial Fig 13 loop did). Job index =
    /// position here = row order of every report.
    pub fn jobs(&self) -> Vec<SweepJob> {
        let mut jobs = Vec::new();
        for cfg in &self.configs {
            if cfg.validate().is_err() {
                continue;
            }
            for workload in &self.workloads {
                for &seed in &self.seeds {
                    jobs.push(SweepJob {
                        index: jobs.len(),
                        cfg: cfg.clone(),
                        workload: workload.clone(),
                        seed,
                        graph_seed: self.graph_seed,
                    });
                }
            }
        }
        jobs
    }
}

/// One design point to evaluate.
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub index: usize,
    pub cfg: VtaConfig,
    pub workload: WorkloadSpec,
    pub seed: u64,
    pub graph_seed: u64,
}

impl SweepJob {
    pub fn cache_key(&self) -> u64 {
        stable_hash64(&key_string(&self.cfg, &self.workload.id(), self.seed, self.graph_seed))
    }
}

/// A completed design point: the full configuration plus the measured
/// metrics, self-contained so the cache file is the sweep's artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct PointResult {
    pub config: VtaConfig,
    /// Workload id (`WorkloadSpec::id`).
    pub workload: String,
    pub seed: u64,
    pub graph_seed: u64,
    pub cycles: u64,
    pub macs: u64,
    pub dram_rd: u64,
    pub dram_wr: u64,
    pub insns: u64,
    pub scaled_area: f64,
}

impl PointResult {
    pub fn cache_key(&self) -> u64 {
        stable_hash64(&key_string(&self.config, &self.workload, self.seed, self.graph_seed))
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("schema", Json::Int(SIM_SCHEMA_VERSION as i64)),
            ("config", self.config.to_json()),
            ("workload", Json::Str(self.workload.clone())),
            ("seed", Json::Int(self.seed as i64)),
            ("graph_seed", Json::Int(self.graph_seed as i64)),
            ("cycles", Json::Int(self.cycles as i64)),
            ("macs", Json::Int(self.macs as i64)),
            ("dram_rd", Json::Int(self.dram_rd as i64)),
            ("dram_wr", Json::Int(self.dram_wr as i64)),
            ("insns", Json::Int(self.insns as i64)),
            ("area", Json::Float(self.scaled_area)),
        ])
    }

    /// Parse one cache line; `None` on any malformed field *or* a
    /// schema version other than [`SIM_SCHEMA_VERSION`] (records from
    /// an older simulator semantics are rejected, not mixed in).
    pub fn from_json(j: &Json) -> Option<PointResult> {
        if j.get("schema")?.as_i64()? != SIM_SCHEMA_VERSION as i64 {
            return None;
        }
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some(PointResult {
            config: VtaConfig::from_json(j.get("config")?).ok()?,
            workload: j.get("workload")?.as_str()?.to_string(),
            seed: int("seed")?,
            graph_seed: int("graph_seed")?,
            cycles: int("cycles")?,
            macs: int("macs")?,
            dram_rd: int("dram_rd")?,
            dram_wr: int("dram_wr")?,
            insns: int("insns")?,
            scaled_area: j.get("area")?.as_f64()?,
        })
    }
}

/// Per-point evaluation options (the sweep fast paths).
#[derive(Debug, Clone, Default)]
pub struct EvalOptions {
    /// Timing-only simulation: cycles and counters are bit-identical,
    /// functional datapath effects are skipped (see
    /// [`SessionOptions::timing_only`]).
    pub timing_only: bool,
    /// Shared layer-memo cache (see [`crate::memo`]).
    pub memo: Option<Arc<LayerMemo>>,
}

/// Evaluate one design point by running the full stack on tsim — the
/// same path as the serial `repro` drivers (graph weights from
/// `graph_seed`, input data from `seed`), so results are comparable and
/// cacheable across entry points.
pub fn evaluate(job: &SweepJob) -> PointResult {
    evaluate_with_graph(job, &job.workload.build(job.graph_seed))
}

/// [`evaluate`] against a pre-built graph. The engine builds each
/// distinct workload's graph once and shares it read-only across
/// workers — synthetic weights depend only on `(workload, graph_seed)`,
/// and regenerating ResNet-18's ~11M weights per design point (one copy
/// per concurrent worker) would dominate small-config sweeps.
pub fn evaluate_with_graph(job: &SweepJob, graph: &Graph) -> PointResult {
    evaluate_with_graph_opts(job, graph, &EvalOptions::default())
}

/// [`evaluate_with_graph`] under explicit evaluation options. All modes
/// produce bit-identical `PointResult`s (the memo/timing-only
/// invariants, asserted by `rust/tests/sweep_engine.rs`).
pub fn evaluate_with_graph_opts(
    job: &SweepJob,
    graph: &Graph,
    eval: &EvalOptions,
) -> PointResult {
    let opts = SessionOptions {
        timing_only: eval.timing_only,
        memo: eval.memo.clone(),
        ..SessionOptions::default()
    };
    let mut session = Session::new(&job.cfg, opts);
    let mut rng = Pcg32::seeded(job.seed);
    let input = rng.i8_vec(job.cfg.batch * graph.input_shape.elems());
    session.run_graph(graph, &input);
    let counters = session.exec_counters();
    PointResult {
        config: job.cfg.clone(),
        workload: job.workload.id(),
        seed: job.seed,
        graph_seed: job.graph_seed,
        cycles: session.cycles(),
        macs: counters.macs,
        dram_rd: counters.load_bytes_total(),
        dram_wr: counters.store_bytes,
        insns: counters.insn_count,
        scaled_area: area::scaled_area(&job.cfg),
    }
}

/// Execution options for a sweep run.
#[derive(Debug, Clone, Default)]
pub struct SweepOptions {
    /// Worker threads; 0 = one per available core.
    pub jobs: usize,
    /// JSONL cache file; `None` keeps results in memory only.
    pub cache_path: Option<PathBuf>,
    /// Load existing cache records and append, instead of truncating.
    pub resume: bool,
    /// Print a line as each point completes.
    pub progress: bool,
    /// Share per-layer simulation results across all points and workers
    /// (see [`crate::memo`]). With a file-backed cache the memo spills
    /// to `<cache stem>.layers.jsonl` next to it, honoring `resume`.
    /// Results are bit-identical either way.
    pub memo: bool,
    /// Timing-only simulation: skip functional datapath effects (the
    /// sweep only consumes cycles/counters, which are bit-identical).
    pub timing_only: bool,
}

/// Everything a sweep produced.
#[derive(Debug)]
pub struct SweepOutcome {
    /// One result per job, in job (grid) order.
    pub results: Vec<PointResult>,
    /// Pareto frontier over (scaled area, cycles); ids are job indices.
    pub front: ParetoFront,
    /// Points served from the cache without simulating.
    pub cached: usize,
    /// Points actually simulated in this run.
    pub simulated: usize,
    /// Layer-memo lookups served from the cache (0 when memo disabled).
    pub memo_hits: u64,
    /// Layer-memo misses, i.e. layers actually simulated.
    pub memo_misses: u64,
}

/// Spill-file path for the layer memo: `sweep_cache.jsonl` →
/// `sweep_cache.layers.jsonl`, always next to the result cache.
fn memo_spill_path(cache: &Path) -> PathBuf {
    let stem = cache
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "sweep_cache".to_string());
    cache.with_file_name(format!("{stem}.layers.jsonl"))
}

/// Run a sweep: shard pending points across workers, stream results to
/// the cache, and extract the Pareto frontier incrementally.
pub fn run(spec: &SweepSpec, opts: &SweepOptions) -> io::Result<SweepOutcome> {
    let jobs = spec.jobs();
    let mut cache = match &opts.cache_path {
        Some(path) => ResultCache::open(path, opts.resume)?,
        None => ResultCache::in_memory(),
    };

    let mut results: Vec<Option<PointResult>> = vec![None; jobs.len()];
    let mut front = ParetoFront::new();
    let mut pending = Vec::new();
    let mut cached = 0;
    for job in &jobs {
        match cache.get(job.cache_key()) {
            Some(hit) => {
                front.insert(hit.scaled_area, hit.cycles, job.index);
                results[job.index] = Some(hit.clone());
                cached += 1;
            }
            None => pending.push(job.index),
        }
    }
    let simulated = pending.len();

    // The shared layer memo (when enabled): one instance behind an Arc,
    // consulted by every worker, spilled next to the result cache.
    let memo: Option<Arc<LayerMemo>> = if opts.memo {
        Some(Arc::new(match &opts.cache_path {
            Some(path) => LayerMemo::open(&memo_spill_path(path), opts.resume)?,
            None => LayerMemo::in_memory(),
        }))
    } else {
        None
    };

    if !pending.is_empty() {
        let workers = effective_jobs(opts.jobs).min(pending.len());
        let job_queue = JobQueue::new(workers, &pending);
        // One graph per distinct workload, shared read-only by all
        // workers (weights depend only on the workload and the spec-wide
        // graph_seed — see `evaluate_with_graph`).
        let mut graphs: BTreeMap<String, Graph> = BTreeMap::new();
        for &j in &pending {
            let workload = &jobs[j].workload;
            graphs
                .entry(workload.id())
                .or_insert_with(|| workload.build(spec.graph_seed));
        }
        let (tx, rx) = mpsc::channel::<(usize, PointResult)>();
        let total = jobs.len();
        std::thread::scope(|scope| -> io::Result<()> {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let tx = tx.clone();
                let job_queue = &job_queue;
                let jobs = &jobs;
                let graphs = &graphs;
                let eval = EvalOptions { timing_only: opts.timing_only, memo: memo.clone() };
                handles.push(scope.spawn(move || {
                    while let Some(j) = job_queue.pop(w) {
                        let job = &jobs[j];
                        let result =
                            evaluate_with_graph_opts(job, &graphs[&job.workload.id()], &eval);
                        if tx.send((j, result)).is_err() {
                            break; // collector gone (I/O error); stop early
                        }
                    }
                }));
            }
            drop(tx);
            let mut done = cached;
            for (j, result) in rx {
                cache.insert(&result)?;
                let on_front = front.insert(result.scaled_area, result.cycles, j);
                done += 1;
                if opts.progress {
                    println!(
                        "[{done}/{total}] {:<22} {:<14} seed={} cycles={:>12} area={:>7.2}{}",
                        result.config.name,
                        result.workload,
                        result.seed,
                        result.cycles,
                        result.scaled_area,
                        if on_front { "  *pareto" } else { "" }
                    );
                }
                results[j] = Some(result);
            }
            Ok(())
        })?;
    }

    let results = results
        .into_iter()
        .map(|r| r.expect("every job either cached or simulated"))
        .collect();
    let (memo_hits, memo_misses) =
        memo.as_ref().map(|m| (m.hits(), m.misses())).unwrap_or((0, 0));
    Ok(SweepOutcome { results, front, cached, simulated, memo_hits, memo_misses })
}

/// Resolve `jobs = 0` to the core count.
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn stable_hash_is_stable_and_discriminating() {
        let cfg = presets::tiny_config();
        let a = stable_hash64(&key_string(&cfg, "micro@4", 7, 42));
        let b = stable_hash64(&key_string(&cfg, "micro@4", 7, 42));
        assert_eq!(a, b, "same point must hash identically");
        assert_ne!(a, stable_hash64(&key_string(&cfg, "micro@4", 8, 42)), "seed changes key");
        assert_ne!(
            a,
            stable_hash64(&key_string(&cfg, "micro@8", 7, 42)),
            "workload changes key"
        );
        let mut other = presets::tiny_config();
        other.axi_bytes = 16;
        assert_ne!(
            a,
            stable_hash64(&key_string(&other, "micro@4", 7, 42)),
            "config changes key"
        );
    }

    #[test]
    fn job_and_result_keys_agree() {
        let job = SweepJob {
            index: 0,
            cfg: presets::tiny_config(),
            workload: WorkloadSpec::Micro { block: 4 },
            seed: 7,
            graph_seed: 42,
        };
        let result = PointResult {
            config: job.cfg.clone(),
            workload: job.workload.id(),
            seed: job.seed,
            graph_seed: job.graph_seed,
            cycles: 1,
            macs: 2,
            dram_rd: 3,
            dram_wr: 4,
            insns: 5,
            scaled_area: 0.5,
        };
        assert_eq!(job.cache_key(), result.cache_key());
    }

    #[test]
    fn point_result_json_roundtrip() {
        let r = PointResult {
            config: presets::scaled_config(1, 32, 32, 2, 16),
            workload: "resnet18@56".to_string(),
            seed: 7,
            graph_seed: 1,
            cycles: 123_456_789,
            macs: 987_654_321,
            dram_rd: 11,
            dram_wr: 22,
            insns: 33,
            scaled_area: 3.141592653589793,
        };
        let text = r.to_json().to_string_compact();
        let back = PointResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r, "JSONL record must round-trip exactly");
    }

    #[test]
    fn old_schema_cache_records_rejected() {
        let r = PointResult {
            config: presets::tiny_config(),
            workload: "micro@4".to_string(),
            seed: 7,
            graph_seed: 42,
            cycles: 1,
            macs: 2,
            dram_rd: 3,
            dram_wr: 4,
            insns: 5,
            scaled_area: 0.5,
        };
        let mut j = r.to_json();
        if let Json::Object(map) = &mut j {
            map.insert("schema".into(), Json::Int(SIM_SCHEMA_VERSION as i64 - 1));
        }
        assert!(PointResult::from_json(&j).is_none(), "older schema must be rejected");
        // A PR-1-era record carries no schema field at all.
        if let Json::Object(map) = &mut j {
            map.remove("schema");
        }
        assert!(PointResult::from_json(&j).is_none(), "unversioned record must be rejected");
    }

    #[test]
    fn memo_spill_path_sits_next_to_cache() {
        assert_eq!(
            memo_spill_path(Path::new("results/sweep_cache.jsonl")),
            PathBuf::from("results/sweep_cache.layers.jsonl")
        );
    }

    #[test]
    fn spec_jobs_skip_invalid_configs() {
        let mut bad = presets::tiny_config();
        bad.axi_bytes = 128; // out of range
        let spec = SweepSpec {
            configs: vec![presets::tiny_config(), bad],
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            seeds: vec![7, 8],
            graph_seed: 1,
        };
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 2, "invalid config contributes no jobs");
        assert!(jobs.iter().all(|j| j.cfg.axi_bytes == 8));
        assert_eq!(jobs[0].index, 0);
        assert_eq!(jobs[1].index, 1);
    }

    #[test]
    fn effective_jobs_resolves_zero() {
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }
}
