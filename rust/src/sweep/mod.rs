//! Parallel design-space-exploration engine (the paper's Fig 13
//! workflow, generalized and made a first-class subsystem).
//!
//! A sweep is a (configuration × workload × seed) grid. The engine:
//!
//! * expands the grid into jobs and shards them across `std::thread`
//!   workers through a work-stealing deque ([`queue::JobQueue`]);
//! * evaluates each point through the unified evaluation API
//!   ([`crate::engine`]): one [`BackendKind`] selects the fidelity —
//!   cycle-accurate tsim (default), the timing-only fast path, or the
//!   analytical model — and the whole stack (graph build, compile,
//!   simulate) runs exactly as the serial drivers do, so a parallel
//!   sweep is bit-identical to a serial one;
//! * streams finished points into an on-disk resumable JSONL cache
//!   ([`cache::ResultCache`]) keyed by a stable hash of the point, so a
//!   killed sweep resumes where it stopped and warm re-runs are instant;
//! * maintains the (scaled area, cycles) Pareto frontier incrementally
//!   ([`pareto::ParetoFront`]) as results land;
//! * shares a [`LayerMemo`](crate::memo::LayerMemo) across all workers
//!   ([`SweepOptions::memo`]): a layer's cycle count is a pure function
//!   of (config, op, tiling), so repeated layer shapes — within one
//!   network, across ResNet depths, and across input seeds — simulate
//!   once per unique signature instead of once per grid cell. Combined
//!   with the timing-only backend this collapses the Fig 13 grid from
//!   O(cells × layers) simulations to O(unique (config, layer)) with
//!   bit-identical cycles and counters (see
//!   `rust/tests/sweep_engine.rs`).
//!
//! Determinism: simulation is seeded and single-threaded per point, the
//! result vector is indexed by job order (grid order), and the frontier
//! is an order-independent set — so the outcome is byte-identical
//! regardless of `--jobs`, of cache warmth, and of the memo/timing-only
//! fast paths (memo records are deterministic, so whichever worker
//! simulates a layer first records the same values).
//!
//! Backends that produce no cycles ([`BackendKind::Fsim`]) are rejected
//! with [`VtaError::Unsupported`] — the sweep's metrics are cycle
//! counts. An [`BackendKind::Analytical`] sweep is allowed (instant
//! whole-grid scoring); its results carry `measured: false` and are
//! kept out of the on-disk cache so model estimates can never
//! contaminate measured records.
//!
//! # Two-phase sweep (predict, then verify)
//!
//! With [`SweepOptions::two_phase`] set, the engine runs phase 1 first:
//! the whole grid is scored by the analytical backend (microseconds per
//! point, one shared prediction cache), and only the points inside an
//! epsilon-dominance band of the *predicted* Pareto front
//! ([`pareto::epsilon_band_survivors`]) proceed to phase 2 — real tsim,
//! with the memo and timing-only fast paths as usual. Properties:
//!
//! * the reported front contains **exclusively tsim-measured cycles** —
//!   pruned points are never measured, so pruning can drop a front
//!   point (if ε is below the model's error band) but can never
//!   *fabricate* one;
//! * survivors are a pure function of `(grid, model, ε)` — cached
//!   results of pruned points are deliberately ignored, so the outcome
//!   is independent of cache warmth, exactly as in single-phase mode;
//! * `results`/`front` use dense survivor indices;
//!   [`SweepOutcome::job_indices`] maps them back to grid order.
//!
//! See DESIGN.md §Two-phase sweep for the model equations and the
//! epsilon soundness argument, and `--no-prune` for when the full
//! measured grid is required (model calibration, full-cloud plots).

pub mod cache;
pub mod grid;
pub mod pareto;
pub mod queue;

pub use cache::ResultCache;
pub use grid::{GridSpec, WorkloadSpec};
pub use pareto::{ParetoFront, ParetoPoint};

use crate::analysis::area;
use crate::compiler::graph::Graph;
use crate::compiler::residency::{self, ResidencyMode};
use crate::config::{ConfigError, VtaConfig};
use crate::engine::backends::PredictionCache;
use crate::engine::{AnalyticalBackend, BackendKind, Engine, EvalRequest, VtaError};
use crate::memo::{LayerMemo, SIM_SCHEMA_VERSION};
use crate::store::{ArtifactKind, ArtifactStore};
use crate::util::json::{obj, Json};
use queue::JobQueue;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

/// Version of the sweep result-record format. Bumped independently of
/// [`SIM_SCHEMA_VERSION`] (which tracks *simulation semantics* and also
/// feeds the layer memo): v3 added the `predicted_cycles` field and the
/// two-phase engine. Both versions are baked into every cache key and
/// record, so a cache written by either an older record format or older
/// simulator semantics misses cleanly.
///
/// v1 = PR-1 records (implicit, unversioned); v2 = PR-2 versioned
/// records; v3 = the `predicted_cycles` field and the two-phase engine
/// (the optional `measured` flag added by the engine redesign defaults
/// to `true`); v4 = the residency mode became part of every key and
/// record (cycles depend on it), and records carry it explicitly;
/// v5 = this scheme: configurations serialize their accumulator
/// `precision`, so the config JSON inside every key grew a field (the
/// simulator bump to s4 rides along in the same release).
pub const SWEEP_SCHEMA_VERSION: u32 = 5;

/// Stable 64-bit cache-key hash. One canonical implementation lives in
/// [`crate::util::hash`] (FNV-1a — stable across processes, which
/// `std::hash` explicitly is not); this is that function, re-exported
/// under the sweep's historical name. The exact key of a known point is
/// pinned by a golden-value test in `rust/tests/sweep_engine.rs`.
pub fn stable_hash64(s: &str) -> u64 {
    crate::util::hash::fnv1a64(s)
}

/// Canonical identity string of a design point; its hash is the cache
/// key. The config's JSON form is deterministic (sorted keys). The
/// sweep record schema and simulator schema versions lead the string,
/// so caches written under older record formats or simulation semantics
/// miss cleanly instead of being silently mixed with new results (their
/// records are additionally rejected at load — see
/// [`PointResult::from_json`]).
fn key_string(
    cfg: &VtaConfig,
    workload: &str,
    seed: u64,
    graph_seed: u64,
    residency: ResidencyMode,
) -> String {
    format!(
        "v{SWEEP_SCHEMA_VERSION}|s{SIM_SCHEMA_VERSION}|r:{}|{}|{}|{}|{}",
        residency.cli_name(),
        cfg.to_json().to_string_compact(),
        workload,
        seed,
        graph_seed
    )
}

/// The grid a sweep covers: every valid config × workload × seed.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub configs: Vec<VtaConfig>,
    pub workloads: Vec<WorkloadSpec>,
    /// Input-data seeds; one job per seed.
    pub seeds: Vec<u64>,
    /// Synthetic-weight seed shared by all jobs.
    pub graph_seed: u64,
}

impl SweepSpec {
    /// Expand into the job list, skipping configurations that fail
    /// `validate()` (exactly as the serial Fig 13 loop did). Job index =
    /// position here = row order of every report.
    pub fn jobs(&self) -> Vec<SweepJob> {
        let mut jobs = Vec::new();
        for cfg in &self.configs {
            if cfg.validate().is_err() {
                continue;
            }
            for workload in &self.workloads {
                for &seed in &self.seeds {
                    jobs.push(SweepJob {
                        index: jobs.len(),
                        cfg: cfg.clone(),
                        workload: workload.clone(),
                        seed,
                        graph_seed: self.graph_seed,
                    });
                }
            }
        }
        jobs
    }
}

/// One design point to evaluate.
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub index: usize,
    pub cfg: VtaConfig,
    pub workload: WorkloadSpec,
    pub seed: u64,
    pub graph_seed: u64,
}

impl SweepJob {
    /// Cache key of this point when evaluated under `residency`. The
    /// mode is an evaluation option rather than a grid axis, but it
    /// changes measured cycles, so it is part of the key.
    pub fn cache_key(&self, residency: ResidencyMode) -> u64 {
        stable_hash64(&key_string(
            &self.cfg,
            &self.workload.id(),
            self.seed,
            self.graph_seed,
            residency,
        ))
    }

    /// Store key of this point's phase-1 prediction artifact
    /// ([`ArtifactKind::Prediction`]): the point key string under a
    /// `predict|` tag, so a prediction and a measurement of the same
    /// point never collide.
    pub fn prediction_key(&self, residency: ResidencyMode) -> u64 {
        stable_hash64(&format!(
            "predict|{}",
            key_string(&self.cfg, &self.workload.id(), self.seed, self.graph_seed, residency)
        ))
    }
}

/// Store key of a workload-graph artifact ([`ArtifactKind::Graph`]).
/// Graphs are identified by `(workload id, graph_seed)` alone — the
/// synthetic weights rebuild deterministically from that pair, so the
/// artifact records identity and provenance, not tensors.
pub fn graph_artifact_key(workload: &str, graph_seed: u64) -> u64 {
    stable_hash64(&format!("graph|{workload}|{graph_seed}"))
}

/// A completed design point: the full configuration plus the measured
/// metrics, self-contained so the cache file is the sweep's artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct PointResult {
    pub config: VtaConfig,
    /// Workload id (`WorkloadSpec::id`).
    pub workload: String,
    pub seed: u64,
    pub graph_seed: u64,
    /// Cycle count. Tsim-measured whenever `measured` is `true` (the
    /// two-phase engine's invariant: every cached/reported result is
    /// measured); a model prediction only when the sweep itself ran the
    /// analytical backend.
    pub cycles: u64,
    pub macs: u64,
    pub dram_rd: u64,
    pub dram_wr: u64,
    pub insns: u64,
    pub scaled_area: f64,
    /// Phase-1 analytical prediction for this point, when the two-phase
    /// engine scored it (`None` on single-phase runs and on records
    /// loaded from caches that predate the prediction). Kept alongside
    /// the measured value so sweep artifacts double as model-calibration
    /// data (predicted vs measured per point).
    pub predicted_cycles: Option<u64>,
    /// `true` when `cycles` came from simulation ([`BackendKind::Tsim`]
    /// or [`BackendKind::TsimTiming`] — bit-identical by construction);
    /// `false` for an analytical-backend sweep. Unmeasured results never
    /// enter the on-disk cache.
    pub measured: bool,
    /// Residency mode the point was evaluated under (part of the cache
    /// key: elided DMA changes cycle counts).
    pub residency: ResidencyMode,
}

impl PointResult {
    pub fn cache_key(&self) -> u64 {
        stable_hash64(&key_string(
            &self.config,
            &self.workload,
            self.seed,
            self.graph_seed,
            self.residency,
        ))
    }

    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("schema", Json::Int(SWEEP_SCHEMA_VERSION as i64)),
            ("config", self.config.to_json()),
            ("workload", Json::Str(self.workload.clone())),
            ("seed", Json::Int(self.seed as i64)),
            ("graph_seed", Json::Int(self.graph_seed as i64)),
            ("cycles", Json::Int(self.cycles as i64)),
            ("macs", Json::Int(self.macs as i64)),
            ("dram_rd", Json::Int(self.dram_rd as i64)),
            ("dram_wr", Json::Int(self.dram_wr as i64)),
            ("insns", Json::Int(self.insns as i64)),
            ("area", Json::Float(self.scaled_area)),
            ("measured", Json::Bool(self.measured)),
            ("residency", Json::Str(self.residency.cli_name().to_string())),
        ]);
        if let (Some(p), Json::Object(map)) = (self.predicted_cycles, &mut j) {
            map.insert("predicted_cycles".to_string(), Json::Int(p as i64));
        }
        j
    }

    /// Parse one cache line; `None` on any malformed field *or* a
    /// schema version other than [`SWEEP_SCHEMA_VERSION`] (records from
    /// an older record format or simulator semantics are rejected, not
    /// mixed in). `predicted_cycles` is optional; `measured` defaults to
    /// `true` (pre-redesign v3 records stored measured cycles only).
    /// Loaders that must *count* stale records separately use
    /// [`PointResult::classify`] instead.
    pub fn from_json(j: &Json) -> Option<PointResult> {
        match PointResult::classify(j) {
            RecordParse::Valid(r) => Some(*r),
            _ => None,
        }
    }

    /// Tri-state load classification: a well-formed record from an
    /// older schema is [`RecordParse::Stale`] (counted and surfaced by
    /// the cache loader and `vta cache stats`), distinct from a torn or
    /// corrupt [`RecordParse::Malformed`] line.
    pub fn classify(j: &Json) -> RecordParse {
        match j.get("schema").and_then(|v| v.as_i64()) {
            Some(v) if v == SWEEP_SCHEMA_VERSION as i64 => match PointResult::parse_fields(j) {
                Some(r) => RecordParse::Valid(Box::new(r)),
                None => RecordParse::Malformed,
            },
            Some(v) if v > 0 => RecordParse::Stale { schema: v as u32 },
            _ => RecordParse::Malformed,
        }
    }

    /// Field-level parse (schema already checked by the caller).
    fn parse_fields(j: &Json) -> Option<PointResult> {
        let int = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some(PointResult {
            config: VtaConfig::from_json(j.get("config")?).ok()?,
            workload: j.get("workload")?.as_str()?.to_string(),
            seed: int("seed")?,
            graph_seed: int("graph_seed")?,
            cycles: int("cycles")?,
            macs: int("macs")?,
            dram_rd: int("dram_rd")?,
            dram_wr: int("dram_wr")?,
            insns: int("insns")?,
            scaled_area: j.get("area")?.as_f64()?,
            predicted_cycles: int("predicted_cycles"),
            measured: j.get("measured").and_then(|v| v.as_bool()).unwrap_or(true),
            residency: ResidencyMode::parse(j.get("residency")?.as_str()?)?,
        })
    }
}

/// Result of classifying one cache line at load time
/// ([`PointResult::classify`]). The distinction between `Stale` and
/// `Malformed` is what lets the cache report "your cache predates the
/// current schema" instead of silently re-simulating everything.
#[derive(Debug, Clone, PartialEq)]
pub enum RecordParse {
    /// A current-schema record (boxed: a full config rides along).
    Valid(Box<PointResult>),
    /// A well-formed record written under a different schema version.
    Stale { schema: u32 },
    /// Not a recognizable record — a torn write or corruption.
    Malformed,
}

/// Per-point evaluation options (fidelity + the shared fast-path
/// plumbing), resolved into an [`Engine`] per evaluation.
#[derive(Debug, Clone, Default)]
pub struct EvalOptions {
    /// Fidelity of the evaluation. [`BackendKind::Fsim`] is rejected
    /// (no cycles); [`BackendKind::Tsim`] and [`BackendKind::TsimTiming`]
    /// produce bit-identical results.
    pub backend: BackendKind,
    /// Shared layer-memo cache (see [`crate::memo`]); tsim backends only.
    pub memo: Option<Arc<LayerMemo>>,
    /// Shared per-layer prediction cache for [`BackendKind::Analytical`]
    /// evaluations — the model-side analogue of `memo`: repeated layer
    /// signatures across a grid are estimated once. Ignored by the
    /// simulating backends.
    pub predictions: Option<PredictionCache>,
    /// Cross-layer residency heuristic every evaluation runs under
    /// (default LRU, matching the session default).
    pub residency: ResidencyMode,
}

/// Evaluate one design point by running the full stack — the same path
/// as the serial `repro` drivers (graph weights from `graph_seed`,
/// input data from `seed`), so results are comparable and cacheable
/// across entry points.
pub fn evaluate(job: &SweepJob) -> Result<PointResult, VtaError> {
    evaluate_with_graph(job, &job.workload.build(job.graph_seed))
}

/// [`evaluate`] against a pre-built graph. The engine builds each
/// distinct workload's graph once and shares it read-only across
/// workers — synthetic weights depend only on `(workload, graph_seed)`,
/// and regenerating ResNet-18's ~11M weights per design point (one copy
/// per concurrent worker) would dominate small-config sweeps.
pub fn evaluate_with_graph(job: &SweepJob, graph: &Graph) -> Result<PointResult, VtaError> {
    evaluate_with_graph_opts(job, graph, &EvalOptions::default())
}

/// [`evaluate_with_graph`] under explicit evaluation options — a thin
/// client of [`Engine`]. All simulating backends produce bit-identical
/// `PointResult`s (the memo/timing-only invariants, asserted by
/// `rust/tests/sweep_engine.rs`).
pub fn evaluate_with_graph_opts(
    job: &SweepJob,
    graph: &Graph,
    eval: &EvalOptions,
) -> Result<PointResult, VtaError> {
    let mut results = evaluate_batch_with_graph_opts(&[job], graph, eval)?;
    Ok(results.pop().expect("one job in, one result out"))
}

/// Evaluate a batch of jobs that share a `(config, workload)` pair —
/// one engine, one [`Engine::prepare`], one batched
/// [`Engine::eval_many`] call, so per-point session setup is paid once
/// per batch instead of once per seed. Results are bit-identical to
/// evaluating each job alone (the `eval_many` contract), in job order.
/// All jobs must carry the same config, workload and graph seed; the
/// batch must be non-empty.
pub fn evaluate_batch_with_graph_opts(
    batch: &[&SweepJob],
    graph: &Graph,
    eval: &EvalOptions,
) -> Result<Vec<PointResult>, VtaError> {
    let first = batch.first().expect("batched evaluation needs at least one job");
    debug_assert!(
        batch.iter().all(|j| j.workload.id() == first.workload.id()
            && j.graph_seed == first.graph_seed
            && j.cfg.name == first.cfg.name),
        "batched jobs must share their (config, workload) identity"
    );
    let mut builder = Engine::for_config(&first.cfg).residency(eval.residency);
    builder = match (&eval.backend, &eval.predictions) {
        (BackendKind::Analytical, Some(cache)) => {
            builder.backend(AnalyticalBackend::with_cache(cache.clone()))
        }
        _ => builder.backend_kind(eval.backend),
    };
    if let Some(memo) = &eval.memo {
        builder = builder.memo(memo.clone());
    }
    let engine = builder.build()?;
    let prepared = engine.prepare(graph)?;
    let requests: Vec<EvalRequest> =
        batch.iter().map(|j| EvalRequest::seeded(j.seed)).collect();
    let evaluations = engine.eval_many(&prepared, &requests)?;
    let measured = eval.backend != BackendKind::Analytical;
    let scaled_area = area::scaled_area(&first.cfg);
    batch
        .iter()
        .zip(evaluations)
        .map(|(job, evaluation)| {
            let cycles = evaluation.cycles.ok_or_else(|| {
                VtaError::Unsupported(format!(
                    "the sweep needs cycle counts and backend '{}' produces none \
                     (use tsim, timing, or model)",
                    evaluation.backend
                ))
            })?;
            Ok(PointResult {
                config: job.cfg.clone(),
                workload: job.workload.id(),
                seed: job.seed,
                graph_seed: job.graph_seed,
                cycles,
                macs: evaluation.counters.macs,
                dram_rd: evaluation.counters.load_bytes_total(),
                dram_wr: evaluation.counters.store_bytes,
                insns: evaluation.counters.insn_count,
                scaled_area,
                predicted_cycles: (!measured).then_some(cycles),
                measured,
                residency: eval.residency,
            })
        })
        .collect()
}

/// Phase-1 pruning options for the two-phase engine.
#[derive(Debug, Clone)]
pub struct TwoPhaseOptions {
    /// Epsilon-dominance band width over the *predicted* frontier: a
    /// point survives phase 1 iff its predicted cycles are within
    /// `(1 + epsilon)` of the best prediction at no-larger area. Sound
    /// (front-preserving) whenever `epsilon ≥ ρ² − 1` for the model's
    /// multiplicative error ratio ρ — see
    /// [`model::DEFAULT_PRUNE_EPSILON`](crate::model::DEFAULT_PRUNE_EPSILON)
    /// and DESIGN.md §Two-phase sweep.
    pub epsilon: f64,
}

impl Default for TwoPhaseOptions {
    fn default() -> Self {
        TwoPhaseOptions { epsilon: crate::model::DEFAULT_PRUNE_EPSILON }
    }
}

/// Execution options for a sweep run.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Worker-thread cap. The `Default` impl resolves this to the
    /// available parallelism *at construction* (regression-tested),
    /// and [`run`] additionally clamps to the pending-point count — a
    /// single-CPU container never spawns a worker per job. `0` still
    /// means "auto" for literal construction.
    pub jobs: usize,
    /// JSONL cache file; `None` keeps results in memory only. Ignored
    /// (forced in-memory) by analytical-backend sweeps so predictions
    /// never land in a measured-results cache.
    pub cache_path: Option<PathBuf>,
    /// Load existing cache records and append, instead of truncating.
    pub resume: bool,
    /// Print a line as each point completes.
    pub progress: bool,
    /// Share per-layer simulation results across all points and workers
    /// (see [`crate::memo`]). With a file-backed cache the memo spills
    /// to `<cache stem>.layers.jsonl` next to it, honoring `resume`.
    /// Results are bit-identical either way. Tsim backends only
    /// (silently off for the analytical backend, which has its own
    /// prediction cache).
    pub memo: bool,
    /// Per-point fidelity (see [`EvalOptions::backend`]).
    pub backend: BackendKind,
    /// Two-phase mode: score the grid with the analytical model and run
    /// the configured backend only on the epsilon-band survivors (see
    /// the module docs). `None` = single-phase: every grid point is
    /// evaluated.
    pub two_phase: Option<TwoPhaseOptions>,
    /// Cross-layer residency heuristic every evaluation (and every
    /// phase-1 prediction) runs under; part of every cache key.
    pub residency: ResidencyMode,
    /// Artifact store backing this sweep (see [`crate::store`]). When
    /// set, `cache_path`/`resume` are ignored — the store *is* the
    /// cache, always with resume semantics: point results load from and
    /// append to [`ArtifactKind::PointMeasurement`], the layer memo
    /// from [`ArtifactKind::Program`], phase-1 predictions become
    /// first-class [`ArtifactKind::Prediction`] artifacts, and the
    /// run's reuse counters land in the store manifest. Ignored (like
    /// `cache_path`) by analytical sweeps: model estimates never enter
    /// the measured store.
    pub store: Option<Arc<ArtifactStore>>,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            // Resolved once, here, instead of at spawn time (the
            // satellite fix for over-spawning on small machines).
            jobs: effective_jobs(0),
            cache_path: None,
            resume: false,
            progress: false,
            memo: false,
            backend: BackendKind::Tsim,
            two_phase: None,
            residency: ResidencyMode::default(),
            store: None,
        }
    }
}

/// A grid point rejected before any evaluation: the workload's minimal
/// tiling overflows the configuration's scratchpads (typed
/// [`ConfigError::Infeasible`]). Reported in
/// [`SweepOutcome::infeasible`] instead of silently dropped or failing
/// the whole sweep mid-run.
#[derive(Debug, Clone, PartialEq)]
pub struct InfeasiblePoint {
    /// Grid job index (`SweepSpec::jobs()` order).
    pub index: usize,
    /// Human-readable reason from the tiling search.
    pub reason: String,
}

/// A grid point eliminated by phase-1 pruning: never simulated, known
/// only by its model prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct PrunedPoint {
    /// Grid job index (`SweepSpec::jobs()` order).
    pub index: usize,
    /// Phase-1 analytical cycle prediction.
    pub predicted_cycles: u64,
    /// Exact scaled area (same model as measured points).
    pub scaled_area: f64,
}

/// Everything a sweep produced.
#[derive(Debug)]
pub struct SweepOutcome {
    /// One result per *evaluated* job, in job (grid) order. Infeasible
    /// points (see [`SweepOutcome::infeasible`]) are never evaluated;
    /// two-phase sweeps additionally hold only the phase-1 survivors —
    /// map positions back with [`SweepOutcome::job_indices`].
    pub results: Vec<PointResult>,
    /// Grid job index of each `results` entry (identity when no pruning).
    pub job_indices: Vec<usize>,
    /// Pareto frontier over (scaled area, cycles); ids index into
    /// `results`. Built exclusively from evaluated points, so two-phase
    /// pruning can never place a phase-1 estimate on the front.
    pub front: ParetoFront,
    /// Points eliminated by phase-1 pruning (empty when single-phase).
    pub pruned: Vec<PrunedPoint>,
    /// Points whose configuration cannot tile the workload at all:
    /// screened out with a typed reason, never evaluated, never cached.
    pub infeasible: Vec<InfeasiblePoint>,
    /// Points served from the cache without simulating.
    pub cached: usize,
    /// Points actually evaluated in this run.
    pub simulated: usize,
    /// Worker threads actually spawned (0 when everything was cached) —
    /// always ≤ min(available parallelism, pending points).
    pub workers: usize,
    /// Layer-memo lookups served from the cache (0 when memo disabled).
    pub memo_hits: u64,
    /// Layer-memo misses, i.e. layers actually simulated.
    pub memo_misses: u64,
    /// Well-formed point records skipped at cache load because they
    /// were written under an older schema version — surfaced so a
    /// `--resume` user learns the cache went stale (and everything
    /// re-simulates) instead of wondering where the warm start went.
    pub skipped_stale: usize,
}

impl SweepOutcome {
    /// tsim evaluations avoided by pruning, as a ratio: grid points per
    /// evaluated point (1.0 when nothing was pruned).
    pub fn prune_factor(&self) -> f64 {
        let total = self.results.len() + self.pruned.len();
        total as f64 / self.results.len().max(1) as f64
    }
}

/// Spill-file path for the layer memo: `sweep_cache.jsonl` →
/// `sweep_cache.layers.jsonl`, always next to the result cache.
fn memo_spill_path(cache: &Path) -> PathBuf {
    let stem = cache
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "sweep_cache".to_string());
    cache.with_file_name(format!("{stem}.layers.jsonl"))
}

/// Build (once) the graph of every distinct workload the given jobs
/// reference — shared read-only by phase 1 and the phase-2 workers.
fn ensure_graphs<'a>(
    graphs: &mut BTreeMap<String, Graph>,
    jobs: impl Iterator<Item = &'a SweepJob>,
    graph_seed: u64,
) {
    for job in jobs {
        graphs.entry(job.workload.id()).or_insert_with(|| job.workload.build(graph_seed));
    }
}

/// Feasibility screen: check each candidate job's tiling feasibility
/// (once per distinct `(config, workload)` pair — feasibility is
/// seed-independent), recording an [`InfeasiblePoint`] per rejected job.
/// Returns the per-grid-job feasibility mask; jobs outside `candidates`
/// stay marked feasible.
fn screen_feasibility(
    jobs: &[SweepJob],
    candidates: &[usize],
    graphs: &BTreeMap<String, Graph>,
    residency: ResidencyMode,
    infeasible: &mut Vec<InfeasiblePoint>,
) -> Vec<bool> {
    let mut feasible = vec![true; jobs.len()];
    let mut verdicts: std::collections::HashMap<u64, Option<String>> =
        std::collections::HashMap::new();
    for &j in candidates {
        let job = &jobs[j];
        let pair = stable_hash64(&format!(
            "{}|{}",
            job.cfg.to_json().to_string_compact(),
            job.workload.id()
        ));
        let verdict = verdicts.entry(pair).or_insert_with(|| {
            let graph = &graphs[&job.workload.id()];
            // The planner runs `check_feasible` in every mode (Off
            // included), under the sweep's fixed tiling policy
            // (tps = true, dbuf_reuse = true — the engine defaults).
            match residency::plan(&job.cfg, graph, &graph.shapes(), residency, true, true) {
                Ok(_) => None,
                Err(ConfigError::Infeasible { reason }) => Some(reason),
                Err(e) => Some(e.to_string()),
            }
        });
        if let Some(reason) = verdict.clone() {
            feasible[j] = false;
            infeasible.push(InfeasiblePoint { index: j, reason });
        }
    }
    feasible
}

/// Phase 1 of the two-phase engine: score every feasible job with the
/// analytical backend and keep the epsilon-band survivors of the
/// predicted frontier. Returns `(survivor job indices in grid order,
/// pruned points, per-job predictions)`. Deterministic and
/// cache-independent: the survivor set is a pure function of
/// `(jobs, model, epsilon)`.
fn phase1_prune(
    jobs: &[SweepJob],
    graphs: &BTreeMap<String, Graph>,
    tp: &TwoPhaseOptions,
    residency: ResidencyMode,
    feasible: &[bool],
    store: Option<&ArtifactStore>,
) -> Result<(Vec<usize>, Vec<PrunedPoint>, Vec<u64>), VtaError> {
    // One prediction cache (keyed by the layer-memo signature) shared
    // across every phase-1 engine: the grid repeats layer shapes
    // massively, so each unique (config, layer) is estimated once.
    let shared = PredictionCache::default();
    let feas_idx: Vec<usize> = (0..jobs.len()).filter(|&j| feasible[j]).collect();
    let mut predictions = vec![0u64; jobs.len()];
    for &j in &feas_idx {
        let job = &jobs[j];
        // A prior run's prediction artifact short-circuits the model
        // entirely — phase 1 on a warm store is pure lookup.
        let pkey = job.prediction_key(residency);
        if let Some(p) = store.and_then(|s| {
            s.get(ArtifactKind::Prediction, pkey)
                .and_then(|payload| payload.get("cycles").and_then(|c| c.as_i64()))
                .map(|v| v as u64)
        }) {
            predictions[j] = p;
            continue;
        }
        // Predict under the same residency mode phase 2 will measure —
        // pruning against a front the measurement can't reach would be
        // unsound.
        let engine = Engine::for_config(&job.cfg)
            .residency(residency)
            .backend(AnalyticalBackend::with_cache(shared.clone()))
            .build()?;
        let evaluation =
            engine.run(&graphs[&job.workload.id()], &EvalRequest::seeded(job.seed))?;
        predictions[j] = evaluation.cycles.unwrap_or(0);
        if let Some(s) = store {
            s.put(
                ArtifactKind::Prediction,
                pkey,
                obj([("cycles", Json::Int(predictions[j] as i64))]),
            )
            .map_err(VtaError::Io)?;
        }
    }
    // Area is exact (the identical `analysis::area` model both phases
    // use); only the cycle axis carries model error, so the band
    // applies to cycles alone.
    let points: Vec<(f64, u64)> =
        feas_idx.iter().map(|&j| (area::scaled_area(&jobs[j].cfg), predictions[j])).collect();
    let survive = pareto::epsilon_band_survivors(&points, tp.epsilon);
    let mut eval = Vec::new();
    let mut pruned = Vec::new();
    for (pos, &j) in feas_idx.iter().enumerate() {
        if survive[pos] {
            eval.push(j);
        } else {
            pruned.push(PrunedPoint {
                index: j,
                predicted_cycles: predictions[j],
                scaled_area: points[pos].0,
            });
        }
    }
    Ok((eval, pruned, predictions))
}

/// Run a sweep: optionally prune the grid against the analytical model
/// (phase 1), then shard the surviving points across workers, stream
/// results to the cache, and extract the Pareto frontier incrementally
/// from evaluated points only (phase 2). Fails fast with [`VtaError`]
/// on capability mismatches (e.g. an fsim backend) and propagates the
/// first worker/cache error instead of panicking.
pub fn run(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepOutcome, VtaError> {
    if opts.backend == BackendKind::Fsim {
        return Err(VtaError::Unsupported(
            "the sweep needs cycle counts and fsim produces none (use tsim, timing, or \
             model)"
                .into(),
        ));
    }
    let analytical = opts.backend == BackendKind::Analytical;
    let jobs = spec.jobs();
    // One graph per distinct workload (weights depend only on the
    // workload and the spec-wide graph_seed — see `evaluate_with_graph`).
    // Built lazily: single-phase warm-cache runs never need a graph.
    let mut graphs: BTreeMap<String, Graph> = BTreeMap::new();

    // Analytical sweeps never touch the on-disk cache or the artifact
    // store: their records are measured results, and predictions must
    // not masquerade as them.
    let store = if analytical { None } else { opts.store.clone() };
    let cache_path = if analytical || store.is_some() {
        None
    } else {
        opts.cache_path.clone()
    };
    let mut cache = match (&store, &cache_path) {
        (Some(s), _) => ResultCache::store_backed(s.clone()),
        (None, Some(path)) => ResultCache::open(path, opts.resume)?,
        (None, None) => ResultCache::in_memory(),
    };

    // Screen out grid points whose network cannot be tiled into the
    // config's scratchpads: they are *reported* ([`SweepOutcome::
    // infeasible`]) instead of silently dropped (the pre-v4 behavior was
    // a worker error that killed the whole sweep). Single-phase runs
    // screen only cache misses — an infeasible point can never have
    // produced a cached record, so warm runs still skip graph builds.
    let mut infeasible: Vec<InfeasiblePoint> = Vec::new();
    let (eval_jobs, pruned, predictions): (Vec<usize>, Vec<PrunedPoint>, Vec<Option<u64>>) =
        match &opts.two_phase {
            Some(tp) => {
                ensure_graphs(&mut graphs, jobs.iter(), spec.graph_seed);
                let feasible =
                    screen_feasibility(&jobs, &(0..jobs.len()).collect::<Vec<_>>(), &graphs,
                        opts.residency, &mut infeasible);
                let (eval, pruned, predictions) =
                    phase1_prune(&jobs, &graphs, tp, opts.residency, &feasible, store.as_deref())?;
                (eval, pruned, predictions.into_iter().map(Some).collect())
            }
            None => {
                let misses: Vec<usize> = (0..jobs.len())
                    .filter(|&j| cache.get(jobs[j].cache_key(opts.residency)).is_none())
                    .collect();
                ensure_graphs(&mut graphs, misses.iter().map(|&j| &jobs[j]), spec.graph_seed);
                let feasible =
                    screen_feasibility(&jobs, &misses, &graphs, opts.residency, &mut infeasible);
                let eval: Vec<usize> = (0..jobs.len()).filter(|&j| feasible[j]).collect();
                (eval, Vec::new(), vec![None; jobs.len()])
            }
        };

    let mut results: Vec<Option<PointResult>> = vec![None; eval_jobs.len()];
    let mut front = ParetoFront::new();
    let mut pending = Vec::new(); // dense indices into eval_jobs/results
    let mut cached = 0;
    for (d, &j) in eval_jobs.iter().enumerate() {
        match cache.get(jobs[j].cache_key(opts.residency)) {
            Some(hit) => {
                let mut hit = hit.clone();
                // Records from single-phase (or pre-v3-annotation) runs
                // carry no prediction; splice the phase-1 value in so
                // warm two-phase runs still report predicted-vs-measured.
                if hit.predicted_cycles.is_none() {
                    hit.predicted_cycles = predictions[j];
                }
                front.insert(hit.scaled_area, hit.cycles, d);
                results[d] = Some(hit);
                cached += 1;
            }
            None => pending.push(d),
        }
    }
    let simulated = pending.len();

    // The shared layer memo (when enabled): one instance behind an Arc,
    // consulted by every worker, spilled next to the result cache — or
    // into the artifact store's Program records when a store backs the
    // sweep. The analytical backend has its own prediction cache.
    let memo: Option<Arc<LayerMemo>> = if opts.memo && !analytical {
        Some(Arc::new(match (&store, &cache_path) {
            (Some(s), _) => LayerMemo::store_backed(s.clone()),
            (None, Some(path)) => LayerMemo::open(&memo_spill_path(path), opts.resume)?,
            (None, None) => LayerMemo::in_memory(),
        }))
    } else {
        None
    };

    // Batch adjacent pending points that share a `(config, workload)`
    // grid row: the grid is ordered configs → workloads → seeds, so
    // `grid index / seed count` identifies the row. Each group becomes
    // one work item evaluated through a single engine + `eval_many`
    // call (session setup paid once per row, not once per seed) with
    // bit-identical per-point results.
    let seeds_per_row = spec.seeds.len().max(1);
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for &d in &pending {
        let row = jobs[eval_jobs[d]].index / seeds_per_row;
        match groups.last_mut() {
            Some(g) if jobs[eval_jobs[*g.last().unwrap()]].index / seeds_per_row == row => {
                g.push(d)
            }
            _ => groups.push(vec![d]),
        }
    }

    // Worker count: clamped to the machine and to the (grouped) work.
    let workers = if pending.is_empty() {
        0
    } else {
        effective_jobs(opts.jobs).min(groups.len())
    };
    let mut failure: Option<VtaError> = None;
    if !pending.is_empty() {
        ensure_graphs(
            &mut graphs,
            pending.iter().map(|&d| &jobs[eval_jobs[d]]),
            spec.graph_seed,
        );
        let group_ids: Vec<usize> = (0..groups.len()).collect();
        let job_queue = JobQueue::new(workers, &group_ids);
        let (tx, rx) = mpsc::channel::<(usize, Result<PointResult, VtaError>)>();
        let total = eval_jobs.len();
        // Analytical sweeps share one prediction cache across workers
        // (the model-side analogue of the layer memo).
        let predictions_cache = analytical.then(PredictionCache::default);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let tx = tx.clone();
                let job_queue = &job_queue;
                let jobs = &jobs;
                let eval_jobs = &eval_jobs;
                let graphs = &graphs;
                let groups = &groups;
                let eval = EvalOptions {
                    backend: opts.backend,
                    memo: memo.clone(),
                    predictions: predictions_cache.clone(),
                    residency: opts.residency,
                };
                handles.push(scope.spawn(move || {
                    while let Some(g) = job_queue.pop(w) {
                        let group = &groups[g];
                        let batch: Vec<&SweepJob> =
                            group.iter().map(|&d| &jobs[eval_jobs[d]]).collect();
                        let graph = &graphs[&batch[0].workload.id()];
                        match evaluate_batch_with_graph_opts(&batch, graph, &eval) {
                            Ok(points) => {
                                for (&d, p) in group.iter().zip(points) {
                                    if tx.send((d, Ok(p))).is_err() {
                                        return; // collector gone (error)
                                    }
                                }
                            }
                            Err(e) => {
                                // One typed failure fails the sweep;
                                // attribute it to the group's first point.
                                if tx.send((group[0], Err(e))).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                }));
            }
            drop(tx);
            let mut done = cached;
            for (d, result) in rx {
                let mut result = match result {
                    Ok(r) => r,
                    Err(e) => {
                        failure = Some(e);
                        break; // dropping rx stops the workers
                    }
                };
                // Record the phase-1 prediction next to the measured
                // value (calibration data; never replaces `cycles`).
                if result.predicted_cycles.is_none() {
                    result.predicted_cycles = predictions[eval_jobs[d]];
                }
                if let Err(e) = cache.insert(&result) {
                    failure = Some(VtaError::Io(e));
                    break;
                }
                let on_front = front.insert(result.scaled_area, result.cycles, d);
                done += 1;
                if opts.progress {
                    println!(
                        "[{done}/{total}] {:<22} {:<14} seed={} cycles={:>12} area={:>7.2}{}",
                        result.config.name,
                        result.workload,
                        result.seed,
                        result.cycles,
                        result.scaled_area,
                        if on_front { "  *pareto" } else { "" }
                    );
                }
                results[d] = Some(result);
            }
        });
    }
    if let Some(e) = failure {
        return Err(e);
    }

    let results: Vec<PointResult> = results
        .into_iter()
        .map(|r| r.expect("every evaluated job either cached or simulated"))
        .collect();
    let (memo_hits, memo_misses) =
        memo.as_ref().map(|m| (m.hits(), m.misses())).unwrap_or((0, 0));
    if let Some(s) = &store {
        // Register every graph the sweep touched as a source artifact
        // (lightweight descriptor: graphs rebuild deterministically from
        // `(workload, graph_seed)`, so the payload documents rather than
        // serializes), stamp the run's reuse ratio, and persist the
        // manifest so `vta cache stats` reports this run.
        for (id, graph) in &graphs {
            let payload = obj([
                ("workload", Json::Str(id.clone())),
                ("graph_seed", Json::Int(spec.graph_seed as i64)),
                ("name", Json::Str(graph.name.clone())),
                ("nodes", Json::Int(graph.nodes.len() as i64)),
            ]);
            s.put(ArtifactKind::Graph, graph_artifact_key(id, spec.graph_seed), payload)
                .map_err(VtaError::Io)?;
        }
        s.record_reuse(cached as u64, simulated as u64);
        s.sync().map_err(VtaError::Io)?;
    }
    Ok(SweepOutcome {
        results,
        job_indices: eval_jobs,
        front,
        pruned,
        infeasible,
        cached,
        simulated,
        skipped_stale: cache.skipped_stale,
        workers,
        memo_hits,
        memo_misses,
    })
}

/// Resolve `jobs = 0` to the core count.
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn stable_hash_is_stable_and_discriminating() {
        let cfg = presets::tiny_config();
        let lru = ResidencyMode::Lru;
        let a = stable_hash64(&key_string(&cfg, "micro@4", 7, 42, lru));
        let b = stable_hash64(&key_string(&cfg, "micro@4", 7, 42, lru));
        assert_eq!(a, b, "same point must hash identically");
        assert_ne!(
            a,
            stable_hash64(&key_string(&cfg, "micro@4", 8, 42, lru)),
            "seed changes key"
        );
        assert_ne!(
            a,
            stable_hash64(&key_string(&cfg, "micro@8", 7, 42, lru)),
            "workload changes key"
        );
        assert_ne!(
            a,
            stable_hash64(&key_string(&cfg, "micro@4", 7, 42, ResidencyMode::Off)),
            "residency mode changes key (cycles depend on it)"
        );
        let mut other = presets::tiny_config();
        other.axi_bytes = 16;
        assert_ne!(
            a,
            stable_hash64(&key_string(&other, "micro@4", 7, 42, lru)),
            "config changes key"
        );
    }

    fn sample_result() -> PointResult {
        PointResult {
            config: presets::tiny_config(),
            workload: "micro@4".to_string(),
            seed: 7,
            graph_seed: 42,
            cycles: 1,
            macs: 2,
            dram_rd: 3,
            dram_wr: 4,
            insns: 5,
            scaled_area: 0.5,
            predicted_cycles: None,
            measured: true,
            residency: ResidencyMode::Lru,
        }
    }

    #[test]
    fn job_and_result_keys_agree() {
        let job = SweepJob {
            index: 0,
            cfg: presets::tiny_config(),
            workload: WorkloadSpec::Micro { block: 4 },
            seed: 7,
            graph_seed: 42,
        };
        let result = sample_result();
        assert_eq!(job.cache_key(ResidencyMode::Lru), result.cache_key());
        assert_ne!(
            job.cache_key(ResidencyMode::Off),
            result.cache_key(),
            "a record evaluated under one mode must not satisfy another"
        );
    }

    #[test]
    fn point_result_json_roundtrip() {
        for (predicted, measured, residency) in [
            (None, true, ResidencyMode::Off),
            (Some(120_000_000u64), true, ResidencyMode::Lru),
            (Some(99u64), false, ResidencyMode::Belady),
            (None, false, ResidencyMode::Dtr),
        ] {
            let r = PointResult {
                config: presets::scaled_config(1, 32, 32, 2, 16),
                workload: "resnet18@56".to_string(),
                seed: 7,
                graph_seed: 1,
                cycles: 123_456_789,
                macs: 987_654_321,
                dram_rd: 11,
                dram_wr: 22,
                insns: 33,
                scaled_area: 3.141592653589793,
                predicted_cycles: predicted,
                measured,
                residency,
            };
            let text = r.to_json().to_string_compact();
            let back = PointResult::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, r, "JSONL record must round-trip exactly");
        }
    }

    #[test]
    fn records_without_measured_flag_load_as_measured() {
        // Pre-redesign v3 records carry no `measured` field.
        let mut j = sample_result().to_json();
        if let Json::Object(map) = &mut j {
            map.remove("measured");
        }
        let back = PointResult::from_json(&j).unwrap();
        assert!(back.measured, "legacy records stored measured cycles only");
    }

    #[test]
    fn old_schema_cache_records_rejected() {
        let r = sample_result();
        let mut j = r.to_json();
        // A PR-2-era record carries the previous sweep schema version.
        if let Json::Object(map) = &mut j {
            map.insert("schema".into(), Json::Int(SWEEP_SCHEMA_VERSION as i64 - 1));
        }
        assert!(PointResult::from_json(&j).is_none(), "older schema must be rejected");
        // A PR-1-era record carries no schema field at all.
        if let Json::Object(map) = &mut j {
            map.remove("schema");
        }
        assert!(PointResult::from_json(&j).is_none(), "unversioned record must be rejected");
    }

    #[test]
    fn prune_factor_reports_grid_over_evaluated() {
        let outcome = SweepOutcome {
            results: vec![],
            job_indices: vec![],
            front: ParetoFront::new(),
            pruned: vec![],
            infeasible: vec![],
            cached: 0,
            simulated: 0,
            skipped_stale: 0,
            workers: 0,
            memo_hits: 0,
            memo_misses: 0,
        };
        assert_eq!(outcome.prune_factor(), 0.0);
        let r = PointResult {
            config: presets::tiny_config(),
            workload: "micro@4".into(),
            seed: 7,
            graph_seed: 42,
            cycles: 10,
            macs: 1,
            dram_rd: 1,
            dram_wr: 1,
            insns: 1,
            scaled_area: 1.0,
            predicted_cycles: Some(12),
            measured: true,
            residency: ResidencyMode::Lru,
        };
        let outcome = SweepOutcome {
            results: vec![r],
            job_indices: vec![0],
            front: ParetoFront::new(),
            pruned: vec![
                PrunedPoint { index: 1, predicted_cycles: 99, scaled_area: 2.0 },
                PrunedPoint { index: 2, predicted_cycles: 98, scaled_area: 2.0 },
                PrunedPoint { index: 3, predicted_cycles: 97, scaled_area: 2.0 },
                PrunedPoint { index: 4, predicted_cycles: 96, scaled_area: 2.0 },
            ],
            infeasible: vec![],
            cached: 0,
            simulated: 1,
            skipped_stale: 0,
            workers: 1,
            memo_hits: 0,
            memo_misses: 0,
        };
        assert_eq!(outcome.prune_factor(), 5.0);
    }

    #[test]
    fn memo_spill_path_sits_next_to_cache() {
        assert_eq!(
            memo_spill_path(Path::new("results/sweep_cache.jsonl")),
            PathBuf::from("results/sweep_cache.layers.jsonl")
        );
    }

    #[test]
    fn spec_jobs_skip_invalid_configs() {
        let mut bad = presets::tiny_config();
        bad.axi_bytes = 128; // out of range
        let spec = SweepSpec {
            configs: vec![presets::tiny_config(), bad],
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            seeds: vec![7, 8],
            graph_seed: 1,
        };
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 2, "invalid config contributes no jobs");
        assert!(jobs.iter().all(|j| j.cfg.axi_bytes == 8));
        assert_eq!(jobs[0].index, 0);
        assert_eq!(jobs[1].index, 1);
    }

    #[test]
    fn effective_jobs_resolves_zero() {
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }

    #[test]
    fn default_options_resolve_jobs_at_construction() {
        let opts = SweepOptions::default();
        assert_eq!(opts.jobs, effective_jobs(0), "jobs resolve when options are built");
        assert!(opts.jobs >= 1);
        assert_eq!(opts.backend, BackendKind::Tsim);
    }
}
