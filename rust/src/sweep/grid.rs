//! Grid specifications: which (configuration × workload × seed) points a
//! sweep covers.
//!
//! A [`GridSpec`] is the CLI-facing description (axis lists, mirroring
//! the paper's Fig 13 axes: MAC shape × memory width × scratchpad
//! scaling); it expands to the engine-facing [`super::SweepSpec`] — an
//! explicit configuration list — so callers can also sweep arbitrary
//! hand-built configurations.

use crate::compiler::graph::Graph;
use crate::config::{presets, Precision};
use crate::engine::VtaError;
use crate::workloads;

/// The workload names [`WorkloadSpec::parse`] understands (quoted by its
/// unknown-workload error so CLI typos are self-correcting).
pub const WORKLOAD_NAMES: [&str; 5] =
    ["resnet{18|34|50|101}", "mobilenet", "micro", "transformer_block", "lstm_cell"];

/// A workload the sweep can build, identified by a stable string id
/// (used in cache keys and result records): `resnet18@224`,
/// `mobilenet@56`, `micro@16`, `transformer_block@16`, `lstm_cell@16`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkloadSpec {
    /// `resnet{depth}@{hw}` — ResNet at an input resolution.
    Resnet { depth: usize, hw: usize },
    /// `mobilenet@{hw}` — MobileNet-1.0 at an input resolution.
    Mobilenet { hw: usize },
    /// `micro@{block}` — the fast micro-ResNet test network; `block`
    /// must match the configuration's BLOCK for accelerator execution.
    Micro { block: usize },
    /// `transformer_block@{seq}` — one d=64 h=4 encoder block at a
    /// sequence length.
    Transformer { seq: usize },
    /// `lstm_cell@{seq}` — an H=64 LSTM cell over `seq` state rows.
    Lstm { seq: usize },
}

impl WorkloadSpec {
    /// Parse an id like `resnet18@56`, `mobilenet`, `micro@4`,
    /// `transformer_block@16`. The part after `@` defaults to 224
    /// (image nets), 16 (micro block width), or 16 (sequence length).
    /// Failures are typed [`VtaError::InvalidRequest`] values quoting
    /// the offending id and listing the available names.
    pub fn parse(s: &str) -> Result<WorkloadSpec, VtaError> {
        let bad = VtaError::InvalidRequest;
        let (name, size) = match s.split_once('@') {
            Some((n, v)) => {
                let v = v
                    .parse::<usize>()
                    .map_err(|_| bad(format!("bad size in workload '{s}'")))?;
                (n, Some(v))
            }
            None => (s, None),
        };
        match name {
            "mobilenet" => Ok(WorkloadSpec::Mobilenet { hw: size.unwrap_or(224) }),
            "micro" => Ok(WorkloadSpec::Micro { block: size.unwrap_or(16) }),
            "transformer_block" => Ok(WorkloadSpec::Transformer { seq: size.unwrap_or(16) }),
            "lstm_cell" => Ok(WorkloadSpec::Lstm { seq: size.unwrap_or(16) }),
            _ => {
                let depth = name.strip_prefix("resnet").and_then(|d| d.parse::<usize>().ok());
                let depth = depth.ok_or_else(|| {
                    bad(format!(
                        "unknown workload '{s}' (available: {})",
                        WORKLOAD_NAMES.join(", ")
                    ))
                })?;
                if !workloads::RESNET_DEPTHS.contains(&depth) {
                    return Err(bad(format!("unsupported ResNet depth {depth} in '{s}'")));
                }
                Ok(WorkloadSpec::Resnet { depth, hw: size.unwrap_or(224) })
            }
        }
    }

    /// Stable identifier; `parse(id())` round-trips.
    pub fn id(&self) -> String {
        match self {
            WorkloadSpec::Resnet { depth, hw } => format!("resnet{depth}@{hw}"),
            WorkloadSpec::Mobilenet { hw } => format!("mobilenet@{hw}"),
            WorkloadSpec::Micro { block } => format!("micro@{block}"),
            WorkloadSpec::Transformer { seq } => format!("transformer_block@{seq}"),
            WorkloadSpec::Lstm { seq } => format!("lstm_cell@{seq}"),
        }
    }

    /// Build the graph with synthetic weights seeded by `graph_seed`.
    pub fn build(&self, graph_seed: u64) -> Graph {
        match self {
            WorkloadSpec::Resnet { depth, hw } => workloads::resnet(*depth, *hw, graph_seed),
            WorkloadSpec::Mobilenet { hw } => workloads::mobilenet(*hw, graph_seed),
            WorkloadSpec::Micro { block } => workloads::micro_resnet(*block, graph_seed),
            WorkloadSpec::Transformer { seq } => {
                workloads::transformer_block(64, 4, *seq, graph_seed)
            }
            WorkloadSpec::Lstm { seq } => workloads::lstm_cell(64, *seq, graph_seed),
        }
    }
}

/// Axis-product grid over `presets::scaled_config` points.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// GEMM tile batch dimension (square MAC arrays: BLOCK_IN=BLOCK_OUT).
    pub batch: usize,
    /// MAC-shape axis (BLOCK values).
    pub blocks: Vec<usize>,
    /// Memory-interface-width axis (AXI bytes/cycle).
    pub axi: Vec<usize>,
    /// Scratchpad-scaling axis.
    pub scales: Vec<usize>,
    /// Accumulator-precision axis (narrow 16-bit vs wide 32-bit
    /// accumulation; [`Precision`]). Narrow points get a `-narrow` name
    /// suffix so [`presets::by_name`] round-trips them.
    pub precisions: Vec<Precision>,
    pub workloads: Vec<WorkloadSpec>,
    /// Input-data seeds (one job per seed).
    pub seeds: Vec<u64>,
    /// Synthetic-weight seed, shared by all points.
    pub graph_seed: u64,
}

impl GridSpec {
    /// The paper's Fig 13 grid: ResNet-18 over MAC shape × memory width
    /// × scratchpad scaling, with the historical seeds of the serial
    /// `repro::fig13` driver (weights seed 1, input seed 7).
    pub fn fig13(quick: bool) -> GridSpec {
        GridSpec {
            batch: 1,
            blocks: vec![16, 32, 64],
            axi: if quick { vec![8, 64] } else { vec![8, 16, 32, 64] },
            scales: if quick { vec![2] } else { vec![1, 2, 4] },
            precisions: vec![Precision::Wide],
            workloads: vec![WorkloadSpec::Resnet { depth: 18, hw: if quick { 56 } else { 224 } }],
            seeds: vec![7],
            graph_seed: 1,
        }
    }

    /// A much denser Fig 13 grid — two-phase-sweep territory: every
    /// power-of-two MAC shape from 4×4 to 128×128 plus every AXI width
    /// and scratchpad scale (~2x the paper's 36 valid points; invalid
    /// corners, e.g. instruction-width overflows at the scale-8
    /// scratchpad depths, are skipped at job expansion as always). Run
    /// it with `vta sweep --dense --two-phase`: phase-1 pruning keeps
    /// the tsim bill near the sparse grid's while the front is resolved
    /// at the finer granularity.
    pub fn fig13_dense(quick: bool) -> GridSpec {
        GridSpec {
            batch: 1,
            blocks: vec![4, 8, 16, 32, 64, 128],
            axi: vec![8, 16, 32, 64],
            scales: if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8] },
            precisions: vec![Precision::Wide],
            workloads: vec![WorkloadSpec::Resnet { depth: 18, hw: if quick { 56 } else { 224 } }],
            seeds: vec![7],
            graph_seed: 1,
        }
    }

    /// Expand the axes into an explicit configuration list, in the same
    /// nested order (block, then axi, then scale, then precision) as the
    /// serial Fig 13 loop, so row order is stable across engine
    /// versions.
    pub fn to_sweep_spec(&self) -> super::SweepSpec {
        let mut configs = Vec::new();
        for &block in &self.blocks {
            for &axi in &self.axi {
                for &scale in &self.scales {
                    for &p in &self.precisions {
                        let mut cfg =
                            presets::scaled_config(self.batch, block, block, scale, axi);
                        if p == Precision::Narrow {
                            cfg.precision = p;
                            cfg.name = format!("{}-narrow", cfg.name);
                        }
                        configs.push(cfg);
                    }
                }
            }
        }
        super::SweepSpec {
            configs,
            workloads: self.workloads.clone(),
            seeds: self.seeds.clone(),
            graph_seed: self.graph_seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_id_parse_roundtrip() {
        for id in [
            "resnet18@224",
            "resnet50@56",
            "mobilenet@224",
            "micro@4",
            "transformer_block@16",
            "lstm_cell@8",
        ] {
            let w = WorkloadSpec::parse(id).unwrap();
            assert_eq!(w.id(), id);
        }
    }

    #[test]
    fn workload_parse_defaults() {
        assert_eq!(
            WorkloadSpec::parse("resnet34").unwrap(),
            WorkloadSpec::Resnet { depth: 34, hw: 224 }
        );
        assert_eq!(WorkloadSpec::parse("micro").unwrap(), WorkloadSpec::Micro { block: 16 });
        assert_eq!(
            WorkloadSpec::parse("transformer_block").unwrap(),
            WorkloadSpec::Transformer { seq: 16 }
        );
        assert_eq!(WorkloadSpec::parse("lstm_cell").unwrap(), WorkloadSpec::Lstm { seq: 16 });
    }

    #[test]
    fn workload_parse_rejects_garbage() {
        for bad in ["resnet19", "alexnet", "resnet18@big", "transformer_block@wide"] {
            let err = WorkloadSpec::parse(bad).unwrap_err();
            assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
            assert!(err.to_string().contains(bad), "must quote the offending id: {err}");
        }
    }

    #[test]
    fn unknown_workload_error_lists_available_names() {
        let err = WorkloadSpec::parse("alexnet").unwrap_err().to_string();
        for name in ["mobilenet", "micro", "transformer_block", "lstm_cell", "resnet"] {
            assert!(err.contains(name), "error must advertise '{name}': {err}");
        }
    }

    #[test]
    fn precision_axis_expands_and_names_narrow_points() {
        let mut g = GridSpec::fig13(true);
        let wide_only = g.to_sweep_spec().configs.len();
        g.precisions = vec![Precision::Wide, Precision::Narrow];
        let spec = g.to_sweep_spec();
        assert_eq!(spec.configs.len(), 2 * wide_only);
        let narrow: Vec<_> =
            spec.configs.iter().filter(|c| c.precision == Precision::Narrow).collect();
        assert_eq!(narrow.len(), wide_only);
        for cfg in &narrow {
            assert!(cfg.name.ends_with("-narrow"), "{}", cfg.name);
            // The suffixed name round-trips through the preset lookup,
            // so sweep rows can be fed back to --config / fleet CLIs.
            assert_eq!(presets::by_name(&cfg.name).as_ref(), Some(*cfg));
        }
    }

    #[test]
    fn fig13_grid_matches_serial_driver() {
        let quick = GridSpec::fig13(true);
        assert_eq!(quick.blocks, vec![16, 32, 64]);
        assert_eq!(quick.axi, vec![8, 64]);
        assert_eq!(quick.scales, vec![2]);
        assert_eq!(quick.workloads[0].id(), "resnet18@56");
        let full = GridSpec::fig13(false);
        assert_eq!(full.axi, vec![8, 16, 32, 64]);
        assert_eq!(full.scales, vec![1, 2, 4]);
        assert_eq!(full.workloads[0].id(), "resnet18@224");
    }

    #[test]
    fn dense_grid_strictly_contains_fig13_axes() {
        let sparse = GridSpec::fig13(false);
        let dense = GridSpec::fig13_dense(false);
        for b in &sparse.blocks {
            assert!(dense.blocks.contains(b));
        }
        for a in &sparse.axi {
            assert!(dense.axi.contains(a));
        }
        for s in &sparse.scales {
            assert!(dense.scales.contains(s));
        }
        let n_sparse = sparse.to_sweep_spec().jobs().len();
        let n_dense = dense.to_sweep_spec().jobs().len();
        assert!(
            n_dense >= 2 * n_sparse,
            "dense grid must be much bigger: {n_dense} vs {n_sparse}"
        );
    }

    #[test]
    fn grid_expansion_order_is_block_axi_scale() {
        let g = GridSpec {
            batch: 1,
            blocks: vec![16, 32],
            axi: vec![8, 16],
            scales: vec![1],
            precisions: vec![Precision::Wide],
            workloads: vec![WorkloadSpec::Micro { block: 16 }],
            seeds: vec![7],
            graph_seed: 1,
        };
        let spec = g.to_sweep_spec();
        let names: Vec<&str> = spec.configs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["b1-i16-o16-s1-m8", "b1-i16-o16-s1-m16", "b1-i32-o32-s1-m8", "b1-i32-o32-s1-m16"]
        );
    }

    #[test]
    fn micro_workload_builds() {
        let g = WorkloadSpec::Micro { block: 4 }.build(42);
        assert_eq!(g.name, "micro-resnet");
    }
}
