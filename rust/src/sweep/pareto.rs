//! Incremental Pareto-frontier extraction for the design-space sweep.
//!
//! The sweep engine streams `(scaled area, cycles)` points in whatever
//! order workers finish; the frontier is maintained online so progress
//! output can report it at any time without rescanning all results. The
//! maintained set is exactly the set of non-dominated points — identical
//! to a batch `repro::mark_pareto` pass over the same points (including
//! the tie convention: points equal on both axes do not dominate each
//! other, so duplicates are all kept).

/// One point on (or off) the frontier: minimize both `area` and `cycles`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParetoPoint {
    pub area: f64,
    pub cycles: u64,
    /// Caller-supplied identifier (the sweep uses the job index).
    pub id: usize,
}

/// `a` dominates `b` when it is no worse on both axes and strictly
/// better on at least one.
pub fn dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    a.area <= b.area && a.cycles <= b.cycles && (a.area < b.area || a.cycles < b.cycles)
}

/// Online Pareto frontier over `(area ↓, cycles ↓)`.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    points: Vec<ParetoPoint>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront::default()
    }

    /// Insert a point. Returns `true` if the point joins the frontier
    /// (dominated incumbents are evicted), `false` if it is dominated.
    pub fn insert(&mut self, area: f64, cycles: u64, id: usize) -> bool {
        let p = ParetoPoint { area, cycles, id };
        if self.points.iter().any(|q| dominates(q, &p)) {
            return false;
        }
        self.points.retain(|q| !dominates(&p, q));
        self.points.push(p);
        true
    }

    /// Frontier points sorted by `(area, cycles, id)` — a deterministic
    /// order regardless of insertion order (and thus of worker count).
    pub fn points(&self) -> Vec<ParetoPoint> {
        let mut out = self.points.clone();
        out.sort_by(|a, b| {
            a.area
                .total_cmp(&b.area)
                .then(a.cycles.cmp(&b.cycles))
                .then(a.id.cmp(&b.id))
        });
        out
    }

    /// Sorted ids of the frontier points.
    pub fn ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.points.iter().map(|p| p.id).collect();
        ids.sort_unstable();
        ids
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Whether the point with `id` is currently on the frontier.
    pub fn contains(&self, id: usize) -> bool {
        self.points.iter().any(|p| p.id == id)
    }
}

/// Epsilon-band survivor selection over `(area, predicted cycles)` —
/// the phase-1 pruning rule of the two-phase sweep.
///
/// Point `p` **survives** iff `p.cycles ≤ (1 + ε) · best`, where `best`
/// is the minimum predicted cycles over all points with area ≤ `p`'s
/// (area is exact — both phases compute it with the same
/// `analysis::area` model — so the band applies only to the predicted
/// axis). With ε = 0 this keeps exactly the points not strictly
/// dominated on the cycles axis; growing ε keeps a widening band above
/// the predicted frontier. Soundness: if every prediction is within a
/// multiplicative factor ρ of the measured value, `ε ≥ ρ² − 1`
/// guarantees no measured-front point is pruned (DESIGN.md §Two-phase
/// sweep). Survivors are always a superset of the predicted frontier,
/// and monotone in ε (property-tested).
pub fn epsilon_band_survivors(points: &[(f64, u64)], epsilon: f64) -> Vec<bool> {
    assert!(epsilon >= 0.0 && epsilon.is_finite(), "epsilon must be a finite non-negative band");
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| points[a].0.total_cmp(&points[b].0));
    let mut survive = vec![false; points.len()];
    let mut best = u64::MAX;
    let mut i = 0;
    while i < idx.len() {
        // Points of equal area form one group: each may prune the
        // others (`q.area <= p.area` includes ties), so fold the whole
        // group into `best` before judging any of its members.
        let mut j = i;
        while j < idx.len() && points[idx[j]].0 == points[idx[i]].0 {
            j += 1;
        }
        for &k in &idx[i..j] {
            best = best.min(points[k].1);
        }
        for &k in &idx[i..j] {
            survive[k] = (points[k].1 as f64) <= (1.0 + epsilon) * best as f64;
        }
        i = j;
    }
    survive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_non_dominated_points() {
        let mut f = ParetoFront::new();
        assert!(f.insert(1.0, 100, 0));
        assert!(f.insert(2.0, 50, 1)); // trades area for cycles
        assert!(!f.insert(1.5, 120, 2)); // dominated by id 0
        assert!(!f.insert(3.0, 50, 3)); // dominated by id 1
        assert_eq!(f.ids(), vec![0, 1]);
    }

    #[test]
    fn evicts_dominated_incumbents() {
        let mut f = ParetoFront::new();
        f.insert(2.0, 100, 0);
        f.insert(3.0, 90, 1);
        assert!(f.insert(1.0, 80, 2)); // dominates both
        assert_eq!(f.ids(), vec![2]);
    }

    #[test]
    fn ties_on_both_axes_are_kept() {
        // Matches `repro::mark_pareto`: equal points do not dominate each
        // other, so both stay on the frontier.
        let mut f = ParetoFront::new();
        assert!(f.insert(1.0, 100, 0));
        assert!(f.insert(1.0, 100, 1));
        assert_eq!(f.ids(), vec![0, 1]);
    }

    #[test]
    fn single_axis_tie_dominance() {
        let mut f = ParetoFront::new();
        f.insert(1.0, 100, 0);
        assert!(!f.insert(1.0, 110, 1)); // same area, more cycles
        assert!(f.insert(1.0, 90, 2)); // same area, fewer cycles: evicts 0
        assert_eq!(f.ids(), vec![2]);
    }

    #[test]
    fn epsilon_band_keeps_front_and_band() {
        // (area, cycles): id1 and id3 are the frontier; id0 is within a
        // 50% band of id1; id2 is far off.
        let pts = vec![(1.0, 140u64), (1.0, 100), (2.0, 300), (2.0, 50)];
        let s0 = epsilon_band_survivors(&pts, 0.0);
        assert_eq!(s0, vec![false, true, false, true]);
        let s50 = epsilon_band_survivors(&pts, 0.5);
        assert_eq!(s50, vec![true, true, false, true]);
        // Ties on both axes always co-survive.
        let ties = vec![(1.0, 100u64), (1.0, 100)];
        assert_eq!(epsilon_band_survivors(&ties, 0.0), vec![true, true]);
    }

    #[test]
    fn epsilon_band_survivors_superset_of_front_and_monotone() {
        // Deterministic pseudo-random cloud (no RNG in unit tests).
        let pts: Vec<(f64, u64)> = (0..60u64)
            .map(|i| (((i * 37) % 11) as f64, (i * 53) % 17))
            .collect();
        let mut front = ParetoFront::new();
        for (i, &(a, c)) in pts.iter().enumerate() {
            front.insert(a, c, i);
        }
        let tight = epsilon_band_survivors(&pts, 0.0);
        let wide = epsilon_band_survivors(&pts, 1.0);
        for id in front.ids() {
            assert!(tight[id], "frontier point {id} must survive at epsilon 0");
        }
        for i in 0..pts.len() {
            assert!(!tight[i] || wide[i], "survivors must be monotone in epsilon");
        }
        assert!(
            epsilon_band_survivors(&pts, 1e18).iter().all(|&s| s),
            "a huge band keeps everything"
        );
    }

    #[test]
    fn points_sorted_deterministically() {
        let mut f = ParetoFront::new();
        f.insert(3.0, 10, 5);
        f.insert(1.0, 100, 2);
        f.insert(2.0, 40, 9);
        let pts = f.points();
        let areas: Vec<f64> = pts.iter().map(|p| p.area).collect();
        assert_eq!(areas, vec![1.0, 2.0, 3.0]);
    }
}
