//! Incremental Pareto-frontier extraction for the design-space sweep.
//!
//! The sweep engine streams `(scaled area, cycles)` points in whatever
//! order workers finish; the frontier is maintained online so progress
//! output can report it at any time without rescanning all results. The
//! maintained set is exactly the set of non-dominated points — identical
//! to a batch `repro::mark_pareto` pass over the same points (including
//! the tie convention: points equal on both axes do not dominate each
//! other, so duplicates are all kept).

/// One point on (or off) the frontier: minimize both `area` and `cycles`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParetoPoint {
    pub area: f64,
    pub cycles: u64,
    /// Caller-supplied identifier (the sweep uses the job index).
    pub id: usize,
}

/// `a` dominates `b` when it is no worse on both axes and strictly
/// better on at least one.
pub fn dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    a.area <= b.area && a.cycles <= b.cycles && (a.area < b.area || a.cycles < b.cycles)
}

/// Online Pareto frontier over `(area ↓, cycles ↓)`.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    points: Vec<ParetoPoint>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront::default()
    }

    /// Insert a point. Returns `true` if the point joins the frontier
    /// (dominated incumbents are evicted), `false` if it is dominated.
    pub fn insert(&mut self, area: f64, cycles: u64, id: usize) -> bool {
        let p = ParetoPoint { area, cycles, id };
        if self.points.iter().any(|q| dominates(q, &p)) {
            return false;
        }
        self.points.retain(|q| !dominates(&p, q));
        self.points.push(p);
        true
    }

    /// Frontier points sorted by `(area, cycles, id)` — a deterministic
    /// order regardless of insertion order (and thus of worker count).
    pub fn points(&self) -> Vec<ParetoPoint> {
        let mut out = self.points.clone();
        out.sort_by(|a, b| {
            a.area
                .total_cmp(&b.area)
                .then(a.cycles.cmp(&b.cycles))
                .then(a.id.cmp(&b.id))
        });
        out
    }

    /// Sorted ids of the frontier points.
    pub fn ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.points.iter().map(|p| p.id).collect();
        ids.sort_unstable();
        ids
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Whether the point with `id` is currently on the frontier.
    pub fn contains(&self, id: usize) -> bool {
        self.points.iter().any(|p| p.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_non_dominated_points() {
        let mut f = ParetoFront::new();
        assert!(f.insert(1.0, 100, 0));
        assert!(f.insert(2.0, 50, 1)); // trades area for cycles
        assert!(!f.insert(1.5, 120, 2)); // dominated by id 0
        assert!(!f.insert(3.0, 50, 3)); // dominated by id 1
        assert_eq!(f.ids(), vec![0, 1]);
    }

    #[test]
    fn evicts_dominated_incumbents() {
        let mut f = ParetoFront::new();
        f.insert(2.0, 100, 0);
        f.insert(3.0, 90, 1);
        assert!(f.insert(1.0, 80, 2)); // dominates both
        assert_eq!(f.ids(), vec![2]);
    }

    #[test]
    fn ties_on_both_axes_are_kept() {
        // Matches `repro::mark_pareto`: equal points do not dominate each
        // other, so both stay on the frontier.
        let mut f = ParetoFront::new();
        assert!(f.insert(1.0, 100, 0));
        assert!(f.insert(1.0, 100, 1));
        assert_eq!(f.ids(), vec![0, 1]);
    }

    #[test]
    fn single_axis_tie_dominance() {
        let mut f = ParetoFront::new();
        f.insert(1.0, 100, 0);
        assert!(!f.insert(1.0, 110, 1)); // same area, more cycles
        assert!(f.insert(1.0, 90, 2)); // same area, fewer cycles: evicts 0
        assert_eq!(f.ids(), vec![2]);
    }

    #[test]
    fn points_sorted_deterministically() {
        let mut f = ParetoFront::new();
        f.insert(3.0, 10, 5);
        f.insert(1.0, 100, 2);
        f.insert(2.0, 40, 9);
        let pts = f.points();
        let areas: Vec<f64> = pts.iter().map(|p| p.area).collect();
        assert_eq!(areas, vec![1.0, 2.0, 3.0]);
    }
}
