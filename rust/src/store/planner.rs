//! The op-graph planner: fud2-style *states and operations* over the
//! artifact taxonomy.
//!
//! Each [`ArtifactKind`] is a state; each [`OpKind`] is an operation
//! consuming input states and producing exactly one output state:
//!
//! ```text
//!   Graph ──lower──> Program ──simulate──> PointMeasurement ─┐
//!     │                                        │             ├─serve──> ServeReport
//!     └───predict──> Prediction ──calibrate────┘   Trace ────┘
//!                         │            │
//!                         └────────────┴──────> Calibration
//! ```
//!
//! `Graph` and `Trace` are *source* states: no operation produces them
//! (graphs rebuild deterministically from `(workload, graph_seed)`;
//! traces come from synthesis or recording), so a plan that needs one
//! the caller doesn't have is unsatisfiable rather than guessed at.
//!
//! [`plan`] answers "what is the minimal op path from what I *have* to
//! what I *want*?" by deterministic backward chaining — every kind has
//! exactly one producer, so the minimal plan is unique and duplicate
//! work is structurally impossible. [`materialize_points`] is the
//! concrete batch driver: partition a key list against the store
//! ([`point_plan`]), evaluate only the missing points sharded across
//! [`util::pool`](crate::util::pool) workers, persist every fresh
//! artifact, and hand back payloads in input order.

use super::{ArtifactKind, ArtifactStore};
use crate::engine::VtaError;
use crate::util::json::Json;
use crate::util::pool::run_indexed;
use std::collections::BTreeSet;

/// The operations of the artifact DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Compile + simulate layers: `Graph` → `Program` (the layer memo's
    /// producer — lowering and per-layer simulation are fused in this
    /// stack, so one op covers both).
    Lower,
    /// Score with the analytical model: `Graph` → `Prediction`.
    Predict,
    /// Measure a design point end to end: `Program` → `PointMeasurement`.
    Simulate,
    /// Pair predictions with measurements into an error band:
    /// `Prediction` + `PointMeasurement` → `Calibration`.
    Calibrate,
    /// Run the serving scheduler: `PointMeasurement` + `Trace` →
    /// `ServeReport` (warm service costs come from measurements).
    Serve,
}

impl OpKind {
    pub const ALL: [OpKind; 5] =
        [OpKind::Lower, OpKind::Predict, OpKind::Simulate, OpKind::Calibrate, OpKind::Serve];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Lower => "lower",
            OpKind::Predict => "predict",
            OpKind::Simulate => "simulate",
            OpKind::Calibrate => "calibrate",
            OpKind::Serve => "serve",
        }
    }

    /// Input states the op consumes.
    pub fn inputs(self) -> &'static [ArtifactKind] {
        match self {
            OpKind::Lower | OpKind::Predict => &[ArtifactKind::Graph],
            OpKind::Simulate => &[ArtifactKind::Program],
            OpKind::Calibrate => &[ArtifactKind::Prediction, ArtifactKind::PointMeasurement],
            OpKind::Serve => &[ArtifactKind::PointMeasurement, ArtifactKind::Trace],
        }
    }

    /// The single state the op produces.
    pub fn output(self) -> ArtifactKind {
        match self {
            OpKind::Lower => ArtifactKind::Program,
            OpKind::Predict => ArtifactKind::Prediction,
            OpKind::Simulate => ArtifactKind::PointMeasurement,
            OpKind::Calibrate => ArtifactKind::Calibration,
            OpKind::Serve => ArtifactKind::ServeReport,
        }
    }
}

impl std::fmt::Display for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The op producing a kind (`None` for the source states).
fn producer(kind: ArtifactKind) -> Option<OpKind> {
    OpKind::ALL.into_iter().find(|op| op.output() == kind)
}

/// Minimal op path from `have` to `want`, in execution order. `Some(vec![])`
/// when `want` is already materialized; `None` when the path needs a
/// source state (`Graph`, `Trace`) the caller doesn't have.
pub fn plan(want: ArtifactKind, have: &BTreeSet<ArtifactKind>) -> Option<Vec<OpKind>> {
    let mut ops = Vec::new();
    let mut resolved = have.clone();
    resolve(want, &mut resolved, &mut ops).then_some(ops)
}

fn resolve(
    kind: ArtifactKind,
    resolved: &mut BTreeSet<ArtifactKind>,
    ops: &mut Vec<OpKind>,
) -> bool {
    if resolved.contains(&kind) {
        return true;
    }
    let Some(op) = producer(kind) else { return false };
    for &input in op.inputs() {
        if !resolve(input, resolved, ops) {
            return false;
        }
    }
    ops.push(op);
    resolved.insert(kind);
    true
}

/// A key list partitioned against the store: which positions reuse a
/// materialized artifact, which must run the producing op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PointPlan {
    /// Positions (into the caller's key list) already in the store.
    pub reused: Vec<usize>,
    /// Positions whose artifact must be produced.
    pub pending: Vec<usize>,
}

/// Partition `keys` by store membership for `kind` (no hit/miss
/// accounting — this is the planning probe, not the consumption).
pub fn point_plan(store: &ArtifactStore, kind: ArtifactKind, keys: &[u64]) -> PointPlan {
    let (reused, pending): (Vec<usize>, Vec<usize>) =
        (0..keys.len()).partition(|&i| store.contains(kind, keys[i]));
    PointPlan { reused, pending }
}

/// Materialize a batch of [`ArtifactKind::PointMeasurement`]s: reuse
/// what the store holds, evaluate the rest across up to `workers`
/// threads (`eval` receives the position in `keys` and returns the
/// payload), persist every fresh artifact, and return all payloads in
/// key order. One store hit is counted per returned artifact.
pub fn materialize_points(
    store: &ArtifactStore,
    keys: &[u64],
    workers: usize,
    eval: impl Fn(usize) -> Result<Json, VtaError> + Sync,
) -> Result<Vec<Json>, VtaError> {
    let plan = point_plan(store, ArtifactKind::PointMeasurement, keys);
    let fresh = run_indexed(workers, plan.pending.len(), |i| eval(plan.pending[i]));
    for (&pos, payload) in plan.pending.iter().zip(fresh) {
        store
            .put(ArtifactKind::PointMeasurement, keys[pos], payload?)
            .map_err(VtaError::Io)?;
    }
    keys.iter()
        .map(|&key| {
            store.get(ArtifactKind::PointMeasurement, key).ok_or_else(|| {
                VtaError::InvalidRequest(format!(
                    "artifact {key:016x} vanished during materialization"
                ))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn have(kinds: &[ArtifactKind]) -> BTreeSet<ArtifactKind> {
        kinds.iter().copied().collect()
    }

    #[test]
    fn every_kind_has_at_most_one_producer() {
        for kind in ArtifactKind::ALL {
            let producers: Vec<OpKind> =
                OpKind::ALL.into_iter().filter(|op| op.output() == kind).collect();
            assert!(producers.len() <= 1, "{kind}: {producers:?}");
        }
        assert_eq!(producer(ArtifactKind::Graph), None, "graphs are a source state");
        assert_eq!(producer(ArtifactKind::Trace), None, "traces are a source state");
    }

    #[test]
    fn plans_are_minimal_and_ordered() {
        assert_eq!(
            plan(ArtifactKind::PointMeasurement, &have(&[ArtifactKind::Graph])),
            Some(vec![OpKind::Lower, OpKind::Simulate])
        );
        assert_eq!(
            plan(ArtifactKind::ServeReport, &have(&[ArtifactKind::Graph, ArtifactKind::Trace])),
            Some(vec![OpKind::Lower, OpKind::Simulate, OpKind::Serve])
        );
        assert_eq!(
            plan(ArtifactKind::Calibration, &have(&[ArtifactKind::Graph])),
            Some(vec![OpKind::Predict, OpKind::Lower, OpKind::Simulate, OpKind::Calibrate])
        );
        // Materialized intermediates shrink the plan.
        assert_eq!(
            plan(
                ArtifactKind::ServeReport,
                &have(&[ArtifactKind::PointMeasurement, ArtifactKind::Trace])
            ),
            Some(vec![OpKind::Serve])
        );
        // Want what you have: empty plan.
        assert_eq!(
            plan(ArtifactKind::PointMeasurement, &have(&[ArtifactKind::PointMeasurement])),
            Some(vec![])
        );
    }

    #[test]
    fn missing_source_states_are_unsatisfiable() {
        assert_eq!(plan(ArtifactKind::Program, &have(&[])), None);
        assert_eq!(plan(ArtifactKind::Graph, &have(&[])), None);
        assert_eq!(
            plan(ArtifactKind::ServeReport, &have(&[ArtifactKind::Graph])),
            None,
            "serve needs a trace no op can fabricate"
        );
    }

    #[test]
    fn materialize_reuses_and_fills_gaps() {
        let store = ArtifactStore::in_memory();
        let keys = [10u64, 11, 12];
        store.put(ArtifactKind::PointMeasurement, 11, obj([("cycles", Json::Int(5))])).unwrap();
        let evals = AtomicUsize::new(0);
        let out = materialize_points(&store, &keys, 2, |pos| {
            evals.fetch_add(1, Ordering::Relaxed);
            Ok(obj([("cycles", Json::Int(keys[pos] as i64))]))
        })
        .unwrap();
        assert_eq!(evals.load(Ordering::Relaxed), 2, "the cached key must not re-evaluate");
        assert_eq!(out[0], obj([("cycles", Json::Int(10))]));
        assert_eq!(out[1], obj([("cycles", Json::Int(5))]), "reused payload, not re-derived");
        assert_eq!(out[2], obj([("cycles", Json::Int(12))]));
        assert_eq!(store.len(ArtifactKind::PointMeasurement), 3);
        // Planning probe agrees with what happened.
        let p = point_plan(&store, ArtifactKind::PointMeasurement, &keys);
        assert_eq!(p.pending, Vec::<usize>::new());
        assert_eq!(p.reused, vec![0, 1, 2]);
    }

    #[test]
    fn materialize_propagates_eval_errors() {
        let store = ArtifactStore::in_memory();
        let err = materialize_points(&store, &[1, 2], 1, |_| {
            Err(VtaError::InvalidRequest("boom".into()))
        })
        .unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)));
    }
}
