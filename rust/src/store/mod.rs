//! Content-addressed artifact store: one cache discipline for every
//! derived artifact in the stack.
//!
//! Before this module, four subsystems each hand-rolled persistence:
//! the sweep's [`ResultCache`](crate::sweep::ResultCache) JSONL, the
//! layer-memo spill (`<cache>.layers.jsonl`), the analytical model's
//! in-memory `PredictionCache`, and serve trace record/replay. None
//! could reuse another's work — a sweep's measured points were invisible
//! to `vta serve`, phase-1 predictions evaporated at process exit, and
//! only the sweep was resumable. The store unifies them: every derived
//! value is a typed, keyed **artifact** in one versioned on-disk
//! directory, and every subsystem reads and writes through the same
//! first-writer-wins, append-then-compact discipline.
//!
//! # Nodes (artifact kinds)
//!
//! | kind | payload | key derivation |
//! |---|---|---|
//! | [`ArtifactKind::Graph`] | workload identity (graphs rebuild deterministically from `(workload, graph_seed)`) | FNV of `graph\|workload\|graph_seed` |
//! | [`ArtifactKind::Program`] | lowered layer result: cycles, insn/uop counts, exec counters | [`LayerSig`](crate::memo::LayerSig): config × op × tiling × residency |
//! | [`ArtifactKind::Prediction`] | phase-1 analytical cycle estimate for a grid point | FNV of `predict\|` + the sweep key string |
//! | [`ArtifactKind::PointMeasurement`] | a full measured [`PointResult`](crate::sweep::PointResult) | the sweep cache key (config × workload × seed × graph seed × residency) |
//! | [`ArtifactKind::Calibration`] | predicted-vs-measured ρ table ([`CalibrationReport`](crate::model::calib::CalibrationReport)) | FNV of `calibrate\|` + config + graph identity |
//! | [`ArtifactKind::Trace`] | a serve request trace | FNV of the serialized request list (content hash) |
//! | [`ArtifactKind::ServeReport`] | a deterministic serve schedule report | FNV of `serve\|` + config + trace key + scheduler options |
//!
//! Every key bakes in the owning subsystem's schema version (the sweep
//! key string leads with `v{SWEEP}|s{SIM}`, layer signatures hash
//! [`SIM_SCHEMA_VERSION`](crate::memo::SIM_SCHEMA_VERSION)), so stale
//! artifacts miss by key as well as being rejected by payload schema.
//!
//! # On-disk layout
//!
//! One directory, one append-only JSONL file per kind
//! (`point.jsonl`, `program.jsonl`, …) plus a `manifest.json` summary.
//! A record line is an envelope around the payload:
//!
//! ```text
//! {"check":"<fnv64 of payload>","key":"<16-hex>","kind":"point",
//!  "payload":{…},"payload_schema":4,"schema":1}
//! ```
//!
//! * `schema` — the envelope format ([`STORE_SCHEMA_VERSION`]);
//! * `payload_schema` — the owning subsystem's version
//!   ([`ArtifactKind::payload_schema`]); records from an older version
//!   load as **stale**: counted ([`KindStats::skipped_stale`], surfaced
//!   by `vta cache stats` per version) but never returned by
//!   [`ArtifactStore::get`];
//! * `check` — FNV-1a of the compact payload, verified at load, by
//!   [`ArtifactStore::verify`], and by gc, so a torn or bit-rotted line
//!   is *corrupt* (skipped and re-derivable), never silently wrong.
//!
//! Appends are flushed per record (a killed run loses at most the
//! in-flight artifact; loaders tolerate a torn tail line). Whole-file
//! writes — the manifest and gc compaction — go through
//! [`atomic_write`](crate::util::fsx::atomic_write).
//!
//! # Ops and the planner
//!
//! [`planner`] declares the operation graph (`lower`, `predict`,
//! `simulate`, `calibrate`, `serve`) over these kinds and derives the
//! minimal op path from what a caller *wants* to what the store already
//! *has*; [`planner::materialize_points`] is the concrete driver,
//! sharding the missing evaluations across
//! [`util::pool`](crate::util::pool) workers.
//!
//! # Gc policy
//!
//! [`ArtifactStore::gc`] drops stale-schema and corrupt lines and
//! rewrites each kind file compacted (first record per key wins,
//! matching the in-memory discipline). Current-schema artifacts are
//! never dropped — they are immutable facts about a deterministic
//! stack, so there is nothing to invalidate but schema churn.

pub mod planner;

pub use planner::{materialize_points, plan, OpKind, PointPlan};

use crate::util::fsx::atomic_write;
use crate::util::hash::fnv1a64;
use crate::util::json::{obj, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{self, BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Version of the record *envelope* (the `schema` field of every line
/// and of the manifest). Payload versioning is per-kind — see
/// [`ArtifactKind::payload_schema`].
pub const STORE_SCHEMA_VERSION: u32 = 1;

/// Graph artifacts carry workload identity only (weights rebuild
/// deterministically), versioned independently of the simulator.
const GRAPH_PAYLOAD_SCHEMA: u32 = 1;

/// The typed artifact taxonomy (the planner's *states*).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// A workload graph's identity: `(workload id, graph_seed)`.
    Graph,
    /// A lowered + simulated layer (the layer-memo record).
    Program,
    /// A phase-1 analytical cycle estimate for one grid point.
    Prediction,
    /// A tsim-measured design point (the sweep cache record).
    PointMeasurement,
    /// A predicted-vs-measured calibration table (model error band ρ).
    Calibration,
    /// A serve request trace.
    Trace,
    /// A deterministic serve schedule report.
    ServeReport,
}

impl ArtifactKind {
    pub const ALL: [ArtifactKind; 7] = [
        ArtifactKind::Graph,
        ArtifactKind::Program,
        ArtifactKind::Prediction,
        ArtifactKind::PointMeasurement,
        ArtifactKind::Calibration,
        ArtifactKind::Trace,
        ArtifactKind::ServeReport,
    ];

    /// Stable short name: the `kind` field of every record, the CLI
    /// spelling, and the stem of the kind's JSONL file.
    pub fn cli_name(self) -> &'static str {
        match self {
            ArtifactKind::Graph => "graph",
            ArtifactKind::Program => "program",
            ArtifactKind::Prediction => "prediction",
            ArtifactKind::PointMeasurement => "point",
            ArtifactKind::Calibration => "calibration",
            ArtifactKind::Trace => "trace",
            ArtifactKind::ServeReport => "report",
        }
    }

    /// File this kind's records append to, inside the store directory.
    pub fn file_name(self) -> String {
        format!("{}.jsonl", self.cli_name())
    }

    pub fn parse(s: &str) -> Option<ArtifactKind> {
        ArtifactKind::ALL.into_iter().find(|k| k.cli_name() == s)
    }

    /// The *current* payload schema for this kind: the owning
    /// subsystem's version constant. A record whose `payload_schema`
    /// differs is stale — counted, reported, gc-able, never served.
    pub fn payload_schema(self) -> u32 {
        match self {
            ArtifactKind::Graph => GRAPH_PAYLOAD_SCHEMA,
            // Simulation-derived artifacts track simulator semantics.
            ArtifactKind::Program
            | ArtifactKind::Prediction
            | ArtifactKind::Calibration => crate::memo::SIM_SCHEMA_VERSION,
            ArtifactKind::PointMeasurement => crate::sweep::SWEEP_SCHEMA_VERSION,
            ArtifactKind::Trace | ArtifactKind::ServeReport => {
                crate::serve::SERVE_SCHEMA_VERSION
            }
        }
    }
}

impl std::fmt::Display for ArtifactKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.cli_name())
    }
}

/// One record line. `payload_schema` is a parameter (rather than always
/// the current version) so tests and migrations can fabricate stale
/// records.
fn record_line(kind: ArtifactKind, key: u64, payload_schema: u32, payload: &Json) -> String {
    let compact = payload.to_string_compact();
    obj([
        ("schema", Json::Int(STORE_SCHEMA_VERSION as i64)),
        ("kind", Json::Str(kind.cli_name().to_string())),
        ("key", Json::Str(format!("{key:016x}"))),
        ("payload_schema", Json::Int(payload_schema as i64)),
        ("check", Json::Str(format!("{:016x}", fnv1a64(&compact)))),
        ("payload", payload.clone()),
    ])
    .to_string_compact()
}

enum Parsed {
    Valid { key: u64, payload: Json },
    Stale { payload_schema: u32 },
    Corrupt,
}

/// Classify one line of a kind file: envelope schema, kind tag, key,
/// and checksum must all verify; a verified record from another payload
/// schema is stale rather than corrupt.
fn classify_line(line: &str, kind: ArtifactKind) -> Parsed {
    let Ok(j) = Json::parse(line) else { return Parsed::Corrupt };
    let envelope_ok = j.get("schema").and_then(|v| v.as_i64())
        == Some(STORE_SCHEMA_VERSION as i64)
        && j.get("kind").and_then(|v| v.as_str()) == Some(kind.cli_name());
    if !envelope_ok {
        return Parsed::Corrupt;
    }
    let Some(key) = j
        .get("key")
        .and_then(|v| v.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())
    else {
        return Parsed::Corrupt;
    };
    let Some(payload) = j.get("payload") else { return Parsed::Corrupt };
    let check = format!("{:016x}", fnv1a64(&payload.to_string_compact()));
    if j.get("check").and_then(|v| v.as_str()) != Some(check.as_str()) {
        return Parsed::Corrupt;
    }
    let Some(payload_schema) = j
        .get("payload_schema")
        .and_then(|v| v.as_i64())
        .and_then(|v| u32::try_from(v).ok())
    else {
        return Parsed::Corrupt;
    };
    if payload_schema != kind.payload_schema() {
        return Parsed::Stale { payload_schema };
    }
    Parsed::Valid { key, payload: payload.clone() }
}

#[derive(Debug, Default)]
struct KindState {
    /// Current-schema records, key → payload. BTreeMap so every scan
    /// ([`ArtifactStore::find_map`], `vta cache ls`) is deterministic.
    records: BTreeMap<u64, Json>,
    /// Lazily opened append handle (on-disk stores only). Dropped after
    /// a gc compaction so appends reopen the rewritten file.
    file: Option<File>,
    /// Valid current-schema records recovered at open.
    loaded: usize,
    /// Corrupt lines (torn writes, checksum failures) skipped at open.
    skipped: usize,
    /// Verified records from an older payload schema skipped at open.
    skipped_stale: usize,
    /// Record count per payload schema version (stale versions
    /// included) — the `vta cache stats` per-version breakdown.
    schema_counts: BTreeMap<u32, usize>,
}

/// Load-time and live statistics for one artifact kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KindStats {
    pub kind: ArtifactKind,
    /// Live current-schema records.
    pub records: usize,
    pub loaded: usize,
    pub skipped: usize,
    pub skipped_stale: usize,
    pub schema_counts: BTreeMap<u32, usize>,
}

/// Whole-store statistics snapshot ([`ArtifactStore::stats`]).
#[derive(Debug, Clone)]
pub struct StoreStats {
    /// One entry per kind with any activity, in kind order.
    pub kinds: Vec<KindStats>,
    /// Lookups served / missed by this process (adapter-level reuse
    /// recorded via [`ArtifactStore::record_reuse`] included).
    pub hits: u64,
    pub misses: u64,
    /// Hit/miss counters the previous run persisted to the manifest.
    pub last_run: Option<(u64, u64)>,
}

impl StoreStats {
    pub fn total_records(&self) -> usize {
        self.kinds.iter().map(|k| k.records).sum()
    }

    pub fn skipped_stale(&self) -> usize {
        self.kinds.iter().map(|k| k.skipped_stale).sum()
    }

    /// Reuse ratio of the previous run (`hits / (hits + misses)`), the
    /// number the warm-rerun acceptance gate reads.
    pub fn last_run_reuse(&self) -> Option<f64> {
        let (h, m) = self.last_run?;
        (h + m > 0).then(|| h as f64 / (h + m) as f64)
    }
}

/// Per-kind line verdicts from a [`ArtifactStore::verify`] pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KindVerify {
    pub valid: usize,
    pub stale: usize,
    pub corrupt: usize,
}

/// Result of a full on-disk re-read + checksum pass.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub kinds: Vec<(ArtifactKind, KindVerify)>,
}

impl VerifyReport {
    /// `true` when no line failed its checksum or envelope (stale
    /// records are allowed — they are valid history, gc's business).
    pub fn ok(&self) -> bool {
        self.kinds.iter().all(|(_, v)| v.corrupt == 0)
    }
}

/// Result of a [`ArtifactStore::gc`] pass (or its `--dry-run` preview).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Current-schema records kept (first per key).
    pub kept: usize,
    pub dropped_stale: usize,
    pub dropped_corrupt: usize,
    /// Duplicate current-schema lines merged away.
    pub dropped_duplicate: usize,
    pub dry_run: bool,
}

/// The content-addressed artifact store. Thread-safe: sweep workers,
/// the serve pool, and adapters share one instance behind an `Arc`.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: Option<PathBuf>,
    kinds: Mutex<BTreeMap<ArtifactKind, KindState>>,
    hits: AtomicU64,
    misses: AtomicU64,
    last_run: Option<(u64, u64)>,
}

impl ArtifactStore {
    /// Store without a backing directory (tests, analytical runs).
    pub fn in_memory() -> ArtifactStore {
        ArtifactStore {
            dir: None,
            kinds: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            last_run: None,
        }
    }

    /// Open (creating if needed) an on-disk store. Always resume
    /// semantics: every kind file is loaded, current-schema records
    /// become live, stale/corrupt lines are counted and skipped.
    pub fn open(dir: &Path) -> io::Result<ArtifactStore> {
        std::fs::create_dir_all(dir)?;
        let mut kinds = BTreeMap::new();
        for kind in ArtifactKind::ALL {
            let path = dir.join(kind.file_name());
            if !path.exists() {
                continue;
            }
            let mut state = KindState::default();
            for line in BufReader::new(File::open(&path)?).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match classify_line(&line, kind) {
                    Parsed::Valid { key, payload } => {
                        // First record per key wins, matching the
                        // in-memory first-writer-wins discipline.
                        if let std::collections::btree_map::Entry::Vacant(e) =
                            state.records.entry(key)
                        {
                            e.insert(payload);
                        }
                        state.loaded += 1;
                        *state.schema_counts.entry(kind.payload_schema()).or_insert(0) += 1;
                    }
                    Parsed::Stale { payload_schema } => {
                        state.skipped_stale += 1;
                        *state.schema_counts.entry(payload_schema).or_insert(0) += 1;
                    }
                    Parsed::Corrupt => state.skipped += 1,
                }
            }
            kinds.insert(kind, state);
        }
        let last_run = Self::read_manifest(dir);
        Ok(ArtifactStore {
            dir: Some(dir.to_path_buf()),
            kinds: Mutex::new(kinds),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            last_run,
        })
    }

    fn read_manifest(dir: &Path) -> Option<(u64, u64)> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
        let j = Json::parse(&text).ok()?;
        let run = j.get("last_run")?;
        let int = |name: &str| run.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        Some((int("hits")?, int("misses")?))
    }

    /// Backing directory (`None` for an in-memory store).
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Fetch an artifact; counts toward the hit/miss statistics. Only
    /// current-payload-schema artifacts are ever returned.
    pub fn get(&self, kind: ArtifactKind, key: u64) -> Option<Json> {
        let found = self
            .kinds
            .lock()
            .unwrap()
            .get(&kind)
            .and_then(|s| s.records.get(&key).cloned());
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Membership test without touching the hit/miss counters (the
    /// planner's partitioning probe).
    pub fn contains(&self, kind: ArtifactKind, key: u64) -> bool {
        self.kinds
            .lock()
            .unwrap()
            .get(&kind)
            .is_some_and(|s| s.records.contains_key(&key))
    }

    /// Store an artifact under the kind's current payload schema.
    /// First writer wins (deterministic producers make racing records
    /// identical); returns `Ok(false)` when the key already existed.
    /// The record is appended and flushed before this returns, so a
    /// kill after a successful `put` never loses the artifact.
    pub fn put(&self, kind: ArtifactKind, key: u64, payload: Json) -> io::Result<bool> {
        let mut kinds = self.kinds.lock().unwrap();
        let state = kinds.entry(kind).or_default();
        if state.records.contains_key(&key) {
            return Ok(false);
        }
        if let Some(dir) = &self.dir {
            if state.file.is_none() {
                state.file = Some(
                    OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(dir.join(kind.file_name()))?,
                );
            }
            let file = state.file.as_mut().expect("just opened");
            let mut line = record_line(kind, key, kind.payload_schema(), &payload);
            line.push('\n');
            file.write_all(line.as_bytes())?;
            file.flush()?;
        }
        *state.schema_counts.entry(kind.payload_schema()).or_insert(0) += 1;
        state.records.insert(key, payload);
        Ok(true)
    }

    /// Deterministic scan (ascending key order): first `Some` wins.
    /// Counts one hit on success, one miss on exhaustion — the
    /// cross-subsystem consumers (serve warmup scanning for any-seed
    /// point measurements) are reuse events worth accounting.
    pub fn find_map<T>(
        &self,
        kind: ArtifactKind,
        mut f: impl FnMut(u64, &Json) -> Option<T>,
    ) -> Option<T> {
        let kinds = self.kinds.lock().unwrap();
        let found = kinds
            .get(&kind)
            .and_then(|s| s.records.iter().find_map(|(&k, p)| f(k, p)));
        drop(kinds);
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// All live records of a kind, in key order (the `ls` view and the
    /// bulk-load path of the store-backed adapters).
    pub fn records(&self, kind: ArtifactKind) -> Vec<(u64, Json)> {
        self.kinds
            .lock()
            .unwrap()
            .get(&kind)
            .map(|s| s.records.iter().map(|(&k, p)| (k, p.clone())).collect())
            .unwrap_or_default()
    }

    /// Live record count for one kind.
    pub fn len(&self, kind: ArtifactKind) -> usize {
        self.kinds.lock().unwrap().get(&kind).map(|s| s.records.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.lock().unwrap().values().all(|s| s.records.is_empty())
    }

    /// `(loaded, skipped, skipped_stale)` counters from open time for
    /// one kind — what the store-backed adapters surface upward.
    pub fn kind_counts(&self, kind: ArtifactKind) -> (usize, usize, usize) {
        self.kinds
            .lock()
            .unwrap()
            .get(&kind)
            .map(|s| (s.loaded, s.skipped, s.skipped_stale))
            .unwrap_or((0, 0, 0))
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fold adapter-level reuse into the store's counters — e.g. the
    /// sweep reports grid points served from cache vs evaluated, which
    /// the adapters resolve without per-point `get` calls.
    pub fn record_reuse(&self, hits: u64, misses: u64) {
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.misses.fetch_add(misses, Ordering::Relaxed);
    }

    /// Statistics snapshot (kinds with any activity only).
    pub fn stats(&self) -> StoreStats {
        let kinds = self.kinds.lock().unwrap();
        let per_kind = kinds
            .iter()
            .map(|(&kind, s)| KindStats {
                kind,
                records: s.records.len(),
                loaded: s.loaded,
                skipped: s.skipped,
                skipped_stale: s.skipped_stale,
                schema_counts: s.schema_counts.clone(),
            })
            .collect();
        StoreStats {
            kinds: per_kind,
            hits: self.hits(),
            misses: self.misses(),
            last_run: self.last_run,
        }
    }

    /// Write the manifest: per-kind record counts and this process's
    /// hit/miss counters (read back as `last_run` by the next open —
    /// how `vta cache stats` reports a finished run's reuse ratio).
    /// No-op for in-memory stores.
    pub fn sync(&self) -> io::Result<()> {
        let Some(dir) = &self.dir else { return Ok(()) };
        let kinds = self.kinds.lock().unwrap();
        let mut kind_map = BTreeMap::new();
        for (&kind, s) in kinds.iter() {
            let counts: BTreeMap<String, Json> = s
                .schema_counts
                .iter()
                .map(|(&v, &n)| (v.to_string(), Json::Int(n as i64)))
                .collect();
            kind_map.insert(
                kind.cli_name().to_string(),
                obj([
                    ("records", Json::Int(s.records.len() as i64)),
                    ("schema_counts", Json::Object(counts)),
                ]),
            );
        }
        let manifest = obj([
            ("schema", Json::Int(STORE_SCHEMA_VERSION as i64)),
            ("kinds", Json::Object(kind_map)),
            (
                "last_run",
                obj([
                    ("hits", Json::Int(self.hits() as i64)),
                    ("misses", Json::Int(self.misses() as i64)),
                ]),
            ),
        ]);
        drop(kinds);
        atomic_write(&dir.join("manifest.json"), manifest.to_string_pretty().as_bytes())
    }

    /// Re-read every kind file from disk and re-verify every envelope
    /// and checksum. In-memory stores trivially verify.
    pub fn verify(&self) -> io::Result<VerifyReport> {
        let mut report = VerifyReport::default();
        let Some(dir) = &self.dir else { return Ok(report) };
        // Hold the lock so a concurrent put's partial flush can't be
        // misread as corruption.
        let _guard = self.kinds.lock().unwrap();
        for kind in ArtifactKind::ALL {
            let path = dir.join(kind.file_name());
            if !path.exists() {
                continue;
            }
            let mut v = KindVerify::default();
            for line in BufReader::new(File::open(&path)?).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match classify_line(&line, kind) {
                    Parsed::Valid { .. } => v.valid += 1,
                    Parsed::Stale { .. } => v.stale += 1,
                    Parsed::Corrupt => v.corrupt += 1,
                }
            }
            report.kinds.push((kind, v));
        }
        Ok(report)
    }

    /// Compact the store: drop stale-schema and corrupt lines, merge
    /// duplicate keys (first wins), and rewrite each kind file
    /// atomically. With `dry_run` nothing is written — the report
    /// previews what a real pass would do. In-memory stores are a no-op.
    pub fn gc(&self, dry_run: bool) -> io::Result<GcReport> {
        let mut report = GcReport { dry_run, ..GcReport::default() };
        let Some(dir) = &self.dir else { return Ok(report) };
        let mut kinds = self.kinds.lock().unwrap();
        for kind in ArtifactKind::ALL {
            let path = dir.join(kind.file_name());
            if !path.exists() {
                continue;
            }
            let mut kept_lines = String::new();
            let mut kept: BTreeMap<u64, Json> = BTreeMap::new();
            for line in BufReader::new(File::open(&path)?).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match classify_line(&line, kind) {
                    Parsed::Valid { key, payload } => {
                        if kept.contains_key(&key) {
                            report.dropped_duplicate += 1;
                        } else {
                            kept_lines.push_str(&line);
                            kept_lines.push('\n');
                            kept.insert(key, payload);
                            report.kept += 1;
                        }
                    }
                    Parsed::Stale { .. } => report.dropped_stale += 1,
                    Parsed::Corrupt => report.dropped_corrupt += 1,
                }
            }
            if !dry_run {
                atomic_write(&path, kept_lines.as_bytes())?;
                let state = kinds.entry(kind).or_default();
                // The old append handle points at the replaced inode;
                // drop it so the next put reopens the compacted file.
                state.file = None;
                state.loaded = kept.len();
                state.skipped = 0;
                state.skipped_stale = 0;
                state.schema_counts =
                    std::iter::once((kind.payload_schema(), kept.len())).collect();
                state.records = kept;
            }
        }
        Ok(report)
    }

    /// The kinds with at least one live artifact — the planner's
    /// `have` set for whole-pipeline questions.
    pub fn have(&self) -> BTreeSet<ArtifactKind> {
        self.kinds
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, s)| !s.records.is_empty())
            .map(|(&k, _)| k)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("vta_store_test_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn payload(n: i64) -> Json {
        obj([("cycles", Json::Int(n))])
    }

    #[test]
    fn kind_names_roundtrip() {
        for kind in ArtifactKind::ALL {
            assert_eq!(ArtifactKind::parse(kind.cli_name()), Some(kind));
            assert!(kind.file_name().ends_with(".jsonl"));
        }
        assert_eq!(ArtifactKind::parse("nope"), None);
    }

    #[test]
    fn in_memory_put_get_first_writer_wins() {
        let store = ArtifactStore::in_memory();
        assert!(store.put(ArtifactKind::Prediction, 7, payload(100)).unwrap());
        assert!(!store.put(ArtifactKind::Prediction, 7, payload(999)).unwrap());
        assert_eq!(store.get(ArtifactKind::Prediction, 7), Some(payload(100)));
        assert_eq!(store.get(ArtifactKind::Prediction, 8), None);
        assert_eq!((store.hits(), store.misses()), (1, 1));
        assert_eq!(store.len(ArtifactKind::Prediction), 1);
        assert!(store.contains(ArtifactKind::Prediction, 7));
        assert_eq!((store.hits(), store.misses()), (1, 1), "contains must not count");
    }

    #[test]
    fn on_disk_roundtrip_and_reopen() {
        let dir = temp_store("roundtrip");
        {
            let store = ArtifactStore::open(&dir).unwrap();
            store.put(ArtifactKind::PointMeasurement, 1, payload(10)).unwrap();
            store.put(ArtifactKind::PointMeasurement, 2, payload(20)).unwrap();
            store.put(ArtifactKind::Program, 3, payload(30)).unwrap();
            store.record_reuse(5, 1);
            store.sync().unwrap();
        }
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.get(ArtifactKind::PointMeasurement, 2), Some(payload(20)));
        assert_eq!(store.len(ArtifactKind::PointMeasurement), 2);
        assert_eq!(store.len(ArtifactKind::Program), 1);
        let stats = store.stats();
        assert_eq!(stats.last_run, Some((5, 1)), "manifest must carry last-run reuse");
        assert!((stats.last_run_reuse().unwrap() - 5.0 / 6.0).abs() < 1e-12);
        assert_eq!(
            store.have(),
            [ArtifactKind::Program, ArtifactKind::PointMeasurement].into_iter().collect()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_and_corrupt_lines_classified_at_open() {
        let dir = temp_store("stale");
        {
            let store = ArtifactStore::open(&dir).unwrap();
            store.put(ArtifactKind::PointMeasurement, 1, payload(10)).unwrap();
        }
        // Fabricate one stale record (older payload schema, valid
        // checksum) and one corrupt line.
        let path = dir.join(ArtifactKind::PointMeasurement.file_name());
        let mut text = std::fs::read_to_string(&path).unwrap();
        let old = ArtifactKind::PointMeasurement.payload_schema() - 1;
        text.push_str(&record_line(ArtifactKind::PointMeasurement, 2, old, &payload(20)));
        text.push('\n');
        text.push_str("{\"torn\":tru");
        std::fs::write(&path, &text).unwrap();

        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.kind_counts(ArtifactKind::PointMeasurement), (1, 1, 1));
        assert_eq!(store.get(ArtifactKind::PointMeasurement, 2), None, "stale never served");
        let stats = store.stats();
        let point = stats
            .kinds
            .iter()
            .find(|k| k.kind == ArtifactKind::PointMeasurement)
            .unwrap();
        assert_eq!(point.schema_counts.get(&old), Some(&1));
        assert_eq!(stats.skipped_stale(), 1);

        // verify() sees the same classification; gc drops both bad
        // lines and the store reloads clean.
        let verify = store.verify().unwrap();
        assert!(!verify.ok(), "the torn line is corruption");
        let gc = store.gc(true).unwrap();
        assert_eq!((gc.kept, gc.dropped_stale, gc.dropped_corrupt), (1, 1, 1));
        assert!(gc.dry_run);
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            text,
            "a dry run must not rewrite the file"
        );
        let gc = store.gc(false).unwrap();
        assert_eq!((gc.kept, gc.dropped_stale, gc.dropped_corrupt), (1, 1, 1));
        assert!(store.verify().unwrap().ok(), "gc leaves a fully valid store");
        assert_eq!(store.kind_counts(ArtifactKind::PointMeasurement), (1, 0, 0));
        // Appending after gc lands in the compacted file.
        store.put(ArtifactKind::PointMeasurement, 9, payload(90)).unwrap();
        let reopened = ArtifactStore::open(&dir).unwrap();
        assert_eq!(reopened.len(ArtifactKind::PointMeasurement), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checksum_mismatch_is_corrupt() {
        let schema = ArtifactKind::Trace.payload_schema();
        let line = record_line(ArtifactKind::Trace, 5, schema, &payload(1))
            .replace("\"cycles\":1", "\"cycles\":2");
        assert!(matches!(classify_line(&line, ArtifactKind::Trace), Parsed::Corrupt));
        let ok = record_line(ArtifactKind::Trace, 5, schema, &payload(1));
        assert!(matches!(classify_line(&ok, ArtifactKind::Trace), Parsed::Valid { key: 5, .. }));
        assert!(
            matches!(classify_line(&ok, ArtifactKind::Graph), Parsed::Corrupt),
            "a record in the wrong kind file must not load"
        );
    }

    #[test]
    fn find_map_scans_in_key_order_and_counts() {
        let store = ArtifactStore::in_memory();
        store.put(ArtifactKind::PointMeasurement, 20, payload(2)).unwrap();
        store.put(ArtifactKind::PointMeasurement, 10, payload(1)).unwrap();
        let first = store.find_map(ArtifactKind::PointMeasurement, |k, _| Some(k));
        assert_eq!(first, Some(10), "scan order is ascending key order");
        assert_eq!(store.find_map(ArtifactKind::Graph, |k, _| Some(k)), None);
        assert_eq!((store.hits(), store.misses()), (1, 1));
    }
}
