//! `vta` — the stack's command-line launcher.
//!
//! Subcommands:
//!   run        run a network end-to-end on a simulator target
//!   repro      regenerate a paper figure/table (pipelining, fig2, fig3,
//!              fig10, fig11, fig12, fig13, all)
//!   sweep      parallel design-space exploration over a config grid,
//!              with a resumable on-disk result cache (Fig 13 and beyond)
//!   serve      batch-serving runtime: dynamic request batching over the
//!              engine API, driven by a seeded open-loop load generator
//!              or a recorded request trace
//!   config     show or save a named configuration as JSON
//!   floorplan  generate + check the ACC-centric floorplan for a config
//!   isa        print the derived ISA field layout for a config
//!
//! The full flag reference lives in README.md §CLI reference.

use std::path::Path;
use std::sync::Arc;
use vta::analysis::area;
use vta::compiler::residency::ResidencyMode;
use vta::config::{presets, Precision, VtaConfig};
use vta::engine::{BackendKind, Engine, EvalRequest};
use vta::floorplan;
use vta::repro;
use vta::serve;
use vta::store::{ArtifactKind, ArtifactStore};
use vta::sweep::{self, GridSpec, SweepOptions, WorkloadSpec};
use vta::util::cli::Args;
use vta::util::fsx::atomic_write;
use vta::util::json::{obj, Json};
use vta::util::stats;
use vta::workloads;

fn usage() -> ! {
    eprintln!(
        "usage: vta <command> [options]\n\
         \n\
         commands:\n\
           run        --net resnet18|resnet34|resnet50|resnet101|mobilenet|micro\n\
                            |transformer_block|lstm_cell\n\
                      [--config default|original|tiny|large|wide32 | --config-file f.json]\n\
                      [--precision narrow|wide] (accumulator width; narrow wraps at 16 bits)\n\
                      [--backend fsim|tsim|timing|model] (the fidelity ladder: behavioral,\n\
                        cycle-accurate, timing-only, analytical estimate)\n\
                      [--hw 224] [--seed 1] [--no-tps] [--no-dbuf] [--trace]\n\
                        (--hw is the sequence length for transformer_block/lstm_cell;\n\
                         their default is 16)\n\
                      [--residency off|lru|belady|dtr] (cross-layer scratchpad residency\n\
                        planner; default lru — outputs are bit-identical at every setting)\n\
           repro      pipelining|ablation|fig2|fig3|fig10|fig11|fig12|fig13|all [--quick] [--out results]\n\
                      [--jobs N]  (fig13 runs on the parallel sweep engine)\n\
                      [--two-phase [--prune-epsilon E]]  (fig13: model-pruned grid, tsim-measured front)\n\
                      [--store vta_store]  (fig13: share measurements through the artifact store)\n\
           sweep      [--quick] [--jobs N] [--resume|--fresh] [--cache sweep_cache.jsonl]\n\
                      [--store vta_store] (content-addressed artifact store shared with serve\n\
                        and repro; replaces --cache/--resume — the store always resumes)\n\
                      [--out sweep_results.json] [--no-progress]\n\
                      [--backend tsim|timing|model] (fidelity per point: functional tsim,\n\
                        the timing-only fast path, or instant analytical estimates)\n\
                      [--no-memo] (disable the cross-point layer-result cache)\n\
                      [--two-phase] (analytical pre-model prunes the grid; tsim only on\n\
                        predicted-front survivors — the reported front stays 100% measured)\n\
                      [--prune-epsilon E] (band width; implies --two-phase; default 1.0)\n\
                      [--no-prune] (force full evaluation, e.g. for model calibration)\n\
                      [--residency off|lru|belady|dtr] (per-point residency mode; part of\n\
                        every cache key — infeasible points are reported, not dropped)\n\
                      grid: [--dense] [--blocks 16,32,64] [--axi 8,16,32,64] [--scales 1,2,4]\n\
                      [--precisions wide,narrow] (accumulator-precision axis)\n\
                      [--batch 1] [--net resnet18|...|mobilenet|micro] [--hw 224]\n\
                      [--workloads resnet18@224,transformer_block@16,lstm_cell@16,...]\n\
                      [--seeds 7,8] [--graph-seed 1]\n\
           serve      [--workload micro|resnet18@224,mobilenet@56,...] [--config <name>]\n\
                      [--backend tsim|timing|model] [--jobs N] (workers; report-invariant)\n\
                      [--max-batch 8] [--max-wait-us 2000] (dynamic batching window)\n\
                      [--queue 256] [--deadline-us D] (bounded queue + per-request deadline)\n\
                      [--requests 256] [--arrival poisson:500|uniform:1000] [--seed 42]\n\
                      [--replay trace.jsonl] [--save-trace trace.jsonl] (recorded traces)\n\
                      [--clock-mhz 100] [--overhead-us 50] [--no-memo] [--graph-seed 1]\n\
                      [--residency off|lru|belady|dtr] [--out serve_report.json]\n\
                      [--store vta_store] (reuse sweep measurements for warmup pricing)\n\
                      fleet: [--fleet] [--fleet-configs tiny,large,b1-i32-o32-s2-m32,...]\n\
                      [--fleet-from-sweep cache.jsonl [--fleet-max 4]] (Pareto-point devices)\n\
                      [--route earliest|least-loaded|cheapest] (deadline-aware routing)\n\
                      [--autoscale R [--autoscale-interval-us 5000] [--scale-up-depth 4]]\n\
                      (runs every single-device candidate + the combined fleet over the\n\
                       same trace and reports the cost-vs-SLO frontier)\n\
           cache      ls|stats|verify|gc [--store vta_store] [--dry-run]\n\
                      (inspect, check, and compact the artifact store)\n\
           config     show|save --config <name> [--out path.json]\n\
           floorplan  [--config <name>]\n\
           isa        [--config <name>]"
    );
    std::process::exit(2);
}

fn load_config(args: &Args) -> VtaConfig {
    let mut cfg = if let Some(path) = args.get("config-file") {
        VtaConfig::load(path).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        })
    } else {
        let name = args.get_or("config", "default");
        presets::by_name(name).unwrap_or_else(|| {
            eprintln!("unknown config preset '{name}'");
            std::process::exit(1);
        })
    };
    if let Some(p) = args.get("precision") {
        cfg.precision = Precision::parse(p).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
    }
    cfg
}

/// Default `--hw` per network: an image resolution for the CNNs, a
/// sequence length for the attention/recurrent families.
fn default_net_size(name: &str) -> usize {
    match name {
        "transformer_block" | "lstm_cell" => 16,
        _ => 224,
    }
}

fn build_net(name: &str, hw: usize, seed: u64) -> vta::compiler::graph::Graph {
    match name {
        "resnet18" => workloads::resnet(18, hw, seed),
        "resnet34" => workloads::resnet(34, hw, seed),
        "resnet50" => workloads::resnet(50, hw, seed),
        "resnet101" => workloads::resnet(101, hw, seed),
        "mobilenet" => workloads::mobilenet(hw, seed),
        "micro" => workloads::micro_resnet(16, seed),
        "transformer_block" => workloads::transformer_block(64, 4, hw, seed),
        "lstm_cell" => workloads::lstm_cell(64, hw, seed),
        _ => {
            eprintln!("unknown network '{name}'");
            std::process::exit(1);
        }
    }
}

fn parse_backend(args: &Args, default: &str) -> BackendKind {
    // Compatibility aliases for the pre-engine flags: `--target X`
    // (run) and `--timing-only` (sweep) map onto `--backend`, which
    // always wins when given explicitly.
    let name = match (args.get("backend"), args.get("target")) {
        (Some(b), _) => b,
        (None, Some(t)) => t,
        (None, None) if args.has_flag("timing-only") => "timing",
        (None, None) => default,
    };
    BackendKind::parse(name).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    })
}

/// Open the artifact store at `--store DIR` (creating the directory on
/// first use); `None` when the flag is absent.
fn open_store(args: &Args) -> Option<Arc<ArtifactStore>> {
    args.get("store").map(|dir| Arc::new(must_open_store(dir)))
}

fn must_open_store(dir: &str) -> ArtifactStore {
    ArtifactStore::open(Path::new(dir)).unwrap_or_else(|e| {
        eprintln!("error: cannot open artifact store '{dir}': {e}");
        std::process::exit(1);
    })
}

fn parse_residency(args: &Args) -> ResidencyMode {
    let name = args.get_or("residency", ResidencyMode::default().cli_name());
    ResidencyMode::parse(name).unwrap_or_else(|| {
        eprintln!("unknown residency mode '{name}' (expected off|lru|belady|dtr)");
        std::process::exit(2);
    })
}

fn cmd_run(args: &Args) {
    let cfg = load_config(args);
    let net = args.get_or("net", "resnet18");
    let hw = args.get_usize("hw", default_net_size(net));
    let seed = args.get_u64("seed", 1);
    let backend = parse_backend(args, "tsim");
    let residency = parse_residency(args);
    let graph = build_net(net, hw, seed);

    println!(
        "running {net} (input {hw}x{hw}) on {} / {backend} ({} fidelity, residency {})",
        cfg.tag(),
        backend.fidelity(),
        residency.cli_name()
    );
    let start = std::time::Instant::now();
    let engine = Engine::for_config(&cfg)
        .backend_kind(backend)
        .trace(args.has_flag("trace"))
        .dbuf_reuse(!args.has_flag("no-dbuf"))
        .tps(!args.has_flag("no-tps"))
        .residency(residency)
        .build()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });
    let eval = engine
        .run(&graph, &EvalRequest::seeded(seed.wrapping_add(100)))
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });
    let wall = start.elapsed();

    println!(
        "\n{:<26} {:>5} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "layer", "kind", "cycles", "macs", "dram rd", "dram wr", "insns"
    );
    for l in &eval.layer_stats {
        println!(
            "{:<26} {:>5} {:>12} {:>12} {:>12} {:>12} {:>8}{}",
            l.name.split(':').next_back().unwrap_or(&l.name),
            l.kind,
            l.cycles,
            l.macs,
            l.dram_rd,
            l.dram_wr,
            l.insns,
            if l.on_cpu { "  [cpu]" } else { "" }
        );
    }
    let predicted = if eval.fidelity == vta::engine::Fidelity::Analytical {
        " (predicted)"
    } else {
        ""
    };
    match eval.cycles {
        Some(cycles) => println!(
            "\ntotal cycles: {cycles}{predicted} ({} wall)",
            stats::fmt_ns(wall.as_nanos() as f64)
        ),
        None => println!(
            "\ntotal cycles: n/a (fsim has no timing model; {} wall)",
            stats::fmt_ns(wall.as_nanos() as f64)
        ),
    }
    if let Some(r) = &eval.report {
        println!(
            "macs: {}  macs/cycle: {:.1}  dram rd/wr: {} / {}",
            stats::si(r.exec.macs as f64),
            r.macs_per_cycle(),
            stats::si(r.vme.bytes_read as f64),
            stats::si(r.vme.bytes_written as f64),
        );
        // Raw integers on purpose: CI greps these to assert the planner
        // elides traffic without changing the functional digest.
        println!(
            "residency: resident tile hits {}  dma bytes elided {}",
            r.exec.resident_tile_hits, r.exec.dma_bytes_elided
        );
    }
    println!("scaled area: {:.2}", area::scaled_area(&cfg));
    match &eval.output {
        Some(out) => {
            println!("output head: {:?}", &out[..out.len().min(8)]);
            println!("output digest: {:#018x}", vta::util::hash::fnv1a64(&format!("{out:?}")));
        }
        None => println!("output: none (the {} backend computes no tensors)", eval.backend),
    }
}

fn cmd_repro(args: &Args) {
    let which = match args.positional.get(1) {
        Some(s) => s.as_str(),
        None => usage(),
    };
    let quick = args.has_flag("quick");
    let out = args.get_or("out", "results");
    match which {
        "pipelining" => {
            repro::pipelining(quick);
        }
        "fig2" => {
            repro::fig2(quick);
        }
        "fig3" | "fig4" => {
            repro::fig3(quick, out);
        }
        "fig10" => {
            repro::fig10();
        }
        "fig11" => {
            repro::fig11(quick);
        }
        "fig12" => {
            repro::fig12(quick);
        }
        "fig13" => {
            let jobs = args.get_usize("jobs", 0);
            if args.has_flag("two-phase") || args.get("prune-epsilon").is_some() {
                repro::fig13_two_phase(
                    quick,
                    jobs,
                    args.get_f64("prune-epsilon", vta::model::DEFAULT_PRUNE_EPSILON),
                );
            } else {
                repro::fig13_with_store(quick, jobs, open_store(args));
            }
        }
        "ablation" => {
            repro::ablation(quick);
            repro::ablation_compiler(quick);
        }
        "all" => {
            repro::pipelining(quick);
            repro::ablation(quick);
            repro::ablation_compiler(quick);
            repro::fig2(quick);
            repro::fig3(quick, out);
            repro::fig10();
            repro::fig11(quick);
            repro::fig12(quick);
            repro::fig13(quick);
        }
        _ => usage(),
    }
}

fn parse_workload(s: &str) -> WorkloadSpec {
    WorkloadSpec::parse(s).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    })
}

fn cmd_sweep(args: &Args) {
    let quick = args.has_flag("quick");
    let mut grid = if args.has_flag("dense") {
        GridSpec::fig13_dense(quick)
    } else {
        GridSpec::fig13(quick)
    };
    grid.batch = args.get_usize("batch", grid.batch);
    grid.blocks = args.get_usize_list("blocks", &grid.blocks);
    grid.axi = args.get_usize_list("axi", &grid.axi);
    grid.scales = args.get_usize_list("scales", &grid.scales);
    grid.seeds = args.get_u64_list("seeds", &grid.seeds);
    grid.graph_seed = args.get_u64("graph-seed", grid.graph_seed);
    if let Some(list) = args.get("precisions") {
        grid.precisions = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                Precision::parse(s).unwrap_or_else(|e| {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                })
            })
            .collect();
    }
    if args.get("net").is_some() || args.get("hw").is_some() {
        let net = args.get_or("net", "resnet18");
        // For `micro` the @-suffix is a channel-block width, and for the
        // sequence workloads it is a sequence length — never apply the
        // image-resolution default to those.
        let workload = match (args.get("hw"), net) {
            (Some(_), _) => parse_workload(&format!("{net}@{}", args.get_usize("hw", 224))),
            (None, "micro" | "transformer_block" | "lstm_cell") => parse_workload(net),
            (None, _) => {
                parse_workload(&format!("{net}@{}", if quick { 56 } else { 224 }))
            }
        };
        grid.workloads = vec![workload];
    }
    if let Some(list) = args.get("workloads") {
        grid.workloads = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(parse_workload)
            .collect();
    }

    let spec = grid.to_sweep_spec();
    // Expanded once; reused for the point count and pruned-point labels
    // (the engine's job_indices follow this same grid order).
    let jobs_list = spec.jobs();
    let n_points = jobs_list.len();
    if n_points == 0 {
        eprintln!("error: the grid contains no valid design points");
        std::process::exit(1);
    }
    // Resolved at option-construction time (0 = auto), so the engine
    // never spawns more workers than the machine has cores.
    let jobs = sweep::effective_jobs(args.get_usize("jobs", 0));
    let backend = parse_backend(args, "tsim");
    let analytical = backend == BackendKind::Analytical;
    let cache = args.get_or("cache", "sweep_cache.jsonl");
    let resume = args.has_flag("resume");
    let store = open_store(args);
    // Guard the cache: without --resume the engine truncates the file,
    // which would silently destroy a previous (possibly hours-long)
    // run's results. Require an explicit --fresh to overwrite. An
    // analytical sweep never touches the cache, and a store-backed
    // sweep never touches the cache file, so nothing to guard.
    if !resume && !args.has_flag("fresh") && !analytical && store.is_none() {
        if let Ok(meta) = std::fs::metadata(cache) {
            if meta.len() > 0 {
                eprintln!(
                    "error: cache '{cache}' already holds results; pass --resume to \
                     reuse them or --fresh to discard and start over"
                );
                std::process::exit(1);
            }
        }
    }
    // Two-phase pruning: opt in with --two-phase (or by setting a band
    // width explicitly); --no-prune always wins — required whenever the
    // run must measure every grid point (model calibration, full-cloud
    // plots, resuming a cache that should stay complete).
    let two_phase = (args.has_flag("two-phase") || args.get("prune-epsilon").is_some())
        && !args.has_flag("no-prune");
    let opts = SweepOptions {
        jobs,
        cache_path: Some(cache.into()),
        resume,
        progress: !args.has_flag("no-progress"),
        // The layer memo is on by default (results are bit-identical
        // with or without it — see rust/tests/sweep_engine.rs);
        // --backend timing additionally skips the functional datapath
        // when only cycles/counters are needed.
        memo: !args.has_flag("no-memo"),
        backend,
        two_phase: two_phase.then(|| sweep::TwoPhaseOptions {
            epsilon: args.get_f64("prune-epsilon", vta::model::DEFAULT_PRUNE_EPSILON),
        }),
        residency: parse_residency(args),
        store: store.clone(),
    };
    // "up to": the engine spawns min(workers, uncached points), which
    // is only known once the cache has been consulted.
    let cache_note = if analytical {
        " (analytical estimates; cache unused)".to_string()
    } else if let Some(dir) = args.get("store") {
        format!(", store {dir}")
    } else {
        format!(", cache {cache}")
    };
    let resume_note = if opts.resume && !analytical {
        " (resume)"
    } else {
        ""
    };
    println!(
        "sweep: {} design points, backend {backend}, up to {} workers{cache_note}{resume_note}",
        n_points,
        jobs.min(n_points),
    );
    let start = std::time::Instant::now();
    let outcome = sweep::run(&spec, &opts).unwrap_or_else(|e| {
        eprintln!("sweep error: {e}");
        std::process::exit(1);
    });
    let wall = start.elapsed();

    println!(
        "\n{:<22} {:<14} {:>6} {:>12} {:>10} {:>7}",
        "config", "workload", "seed", "cycles", "area", "pareto"
    );
    for (i, r) in outcome.results.iter().enumerate() {
        println!(
            "{:<22} {:<14} {:>6} {:>12} {:>10.2} {:>7}",
            r.config.tag(),
            r.workload,
            r.seed,
            r.cycles,
            r.scaled_area,
            if outcome.front.contains(i) { "*" } else { "" }
        );
    }
    println!("\npareto frontier ({} points):", outcome.front.len());
    for p in outcome.front.points() {
        let r = &outcome.results[p.id];
        println!("  {:<22} cycles={:<12} area={:.2}", r.config.tag(), r.cycles, r.scaled_area);
    }
    let estimate_note = if analytical {
        "  [analytical estimates, not measurements]"
    } else {
        ""
    };
    println!(
        "\n{} evaluated ({} workers), {} from cache in {}{estimate_note}",
        outcome.simulated,
        outcome.workers,
        outcome.cached,
        stats::fmt_ns(wall.as_nanos() as f64),
    );
    if outcome.skipped_stale > 0 {
        eprintln!(
            "warning: {} cached record(s) carry an older schema version and were ignored \
             (their points re-simulated); run `vta cache gc --store <dir>` to compact a \
             store, or pass --fresh to rewrite a cache file",
            outcome.skipped_stale
        );
    }
    if let Some(s) = &store {
        let st = s.stats();
        println!(
            "artifact store: {} record(s) across {} kind(s); this run reused {} / {} points",
            st.total_records(),
            st.kinds.iter().filter(|k| k.records > 0).count(),
            outcome.cached,
            outcome.cached + outcome.simulated,
        );
    }
    if !outcome.infeasible.is_empty() {
        println!(
            "{} infeasible point(s) screened out (config cannot tile the workload):",
            outcome.infeasible.len()
        );
        for p in &outcome.infeasible {
            println!(
                "  {:<22} {:<14} {}",
                jobs_list[p.index].cfg.tag(),
                jobs_list[p.index].workload.id(),
                p.reason
            );
        }
    }
    if let Some(tp) = &opts.two_phase {
        println!(
            "two-phase: {} grid points scored by the model, {} pruned, {} evaluated \
             ({:.1}x fewer tsim evaluations, epsilon {:.2}; front is 100% tsim-measured)",
            n_points,
            outcome.pruned.len(),
            outcome.results.len(),
            outcome.prune_factor(),
            tp.epsilon
        );
        // Predicted-vs-measured on the survivors: free calibration data.
        let worst = outcome
            .results
            .iter()
            .filter_map(|r| {
                let p = r.predicted_cycles? as f64;
                let m = r.cycles as f64;
                Some((p / m).max(m / p))
            })
            .fold(1.0f64, f64::max);
        if worst > 1.0 {
            println!(
                "model error on survivors: worst ratio {:.2} (sound epsilon >= {:.2})",
                worst,
                vta::model::epsilon_for_ratio(worst)
            );
        }
    }
    if opts.memo && outcome.memo_hits + outcome.memo_misses > 0 {
        println!(
            "layer memo: {} hits / {} layers simulated ({:.1}% reuse)",
            outcome.memo_hits,
            outcome.memo_misses,
            100.0 * outcome.memo_hits as f64
                / (outcome.memo_hits + outcome.memo_misses) as f64
        );
    }

    let out = args.get_or("out", "sweep_results.json");
    let points: Vec<Json> = outcome
        .results
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut j = r.to_json();
            if let Json::Object(map) = &mut j {
                map.insert("pareto".to_string(), Json::Bool(outcome.front.contains(i)));
            }
            j
        })
        .collect();
    let pruned: Vec<Json> = outcome
        .pruned
        .iter()
        .map(|p| {
            obj([
                ("job", Json::Int(p.index as i64)),
                ("config", Json::Str(jobs_list[p.index].cfg.tag())),
                ("workload", Json::Str(jobs_list[p.index].workload.id())),
                ("predicted_cycles", Json::Int(p.predicted_cycles as i64)),
                ("area", Json::Float(p.scaled_area)),
            ])
        })
        .collect();
    let infeasible: Vec<Json> = outcome
        .infeasible
        .iter()
        .map(|p| {
            obj([
                ("job", Json::Int(p.index as i64)),
                ("config", Json::Str(jobs_list[p.index].cfg.tag())),
                ("workload", Json::Str(jobs_list[p.index].workload.id())),
                ("reason", Json::Str(p.reason.clone())),
            ])
        })
        .collect();
    let summary = obj([
        ("points", Json::Array(points)),
        (
            "pareto_ids",
            Json::Array(outcome.front.ids().iter().map(|&i| Json::Int(i as i64)).collect()),
        ),
        (
            "job_indices",
            Json::Array(outcome.job_indices.iter().map(|&i| Json::Int(i as i64)).collect()),
        ),
        ("pruned_points", Json::Array(pruned)),
        ("infeasible_points", Json::Array(infeasible)),
        ("cached", Json::Int(outcome.cached as i64)),
        ("simulated", Json::Int(outcome.simulated as i64)),
        ("skipped_stale", Json::Int(outcome.skipped_stale as i64)),
    ]);
    match atomic_write(Path::new(out), summary.to_string_pretty().as_bytes()) {
        Ok(()) => println!("results written to {out}"),
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_serve(args: &Args) {
    let cfg = load_config(args);
    let backend = parse_backend(args, "timing");
    // `micro_resnet` is accepted as an alias for the `micro` workload id
    // (the name the test network goes by elsewhere in the docs).
    let workloads: Vec<WorkloadSpec> = args
        .get_or("workload", "micro")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| if s == "micro_resnet" { "micro" } else { s })
        .map(parse_workload)
        .collect();
    let deadline = args.get_u64("deadline-us", 0);
    let opts = serve::ServeOptions::builder()
        .cfg(cfg)
        .backend(backend)
        .workloads(workloads)
        .graph_seed(args.get_u64("graph-seed", 1))
        .memo(!args.has_flag("no-memo"))
        .jobs(args.get_usize("jobs", 0))
        .max_batch(args.get_usize("max-batch", 8))
        .max_wait_us(args.get_u64("max-wait-us", 2_000))
        .queue_depth(args.get_usize("queue", 256))
        .deadline_us((deadline > 0).then_some(deadline))
        .clock_mhz(args.get_u64("clock-mhz", 100))
        .dispatch_overhead_us(args.get_u64("overhead-us", 50))
        .residency(parse_residency(args))
        .store(open_store(args))
        .build()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });

    // Request trace: replay a recorded one, or generate a seeded
    // open-loop arrival stream over the pooled workloads.
    let trace = match args.get("replay") {
        Some(path) => serve::read_trace(Path::new(path)).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        }),
        None => {
            let spec = serve::ArrivalSpec::parse(args.get_or("arrival", "poisson:500"))
                .unwrap_or_else(|e| {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                });
            let ids: Vec<String> = opts.workloads.iter().map(|w| w.id()).collect();
            let n = args.get_usize("requests", 256);
            serve::synth_trace(&spec, &ids, n, args.get_u64("seed", 42)).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1);
            })
        }
    };
    if let Some(path) = args.get("save-trace") {
        serve::write_trace(Path::new(path), &trace).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });
        println!("request trace written to {path}");
    }

    // `--fleet` (or any fleet-shaping option) switches to the
    // heterogeneous frontier path; the single-device report below is
    // itself one of the frontier's candidates.
    let fleet_mode = args.has_flag("fleet")
        || args.get("fleet").is_some()
        || args.get("fleet-configs").is_some()
        || args.get("fleet-from-sweep").is_some();
    if fleet_mode {
        cmd_serve_fleet(args, opts, &trace);
        return;
    }

    println!(
        "serving {} requests across {} workload(s) on {} / {backend} ({} fidelity)",
        trace.len(),
        opts.workloads.len(),
        opts.cfg.tag(),
        backend.fidelity()
    );
    let outcome = serve::run(&opts, &trace).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1);
    });
    let r = &outcome.report;

    println!("\npooled workloads (warm per-request cost):");
    for (id, cost) in &r.workloads {
        println!(
            "  {:<16} {:>12} cycles  {:>8} virtual us",
            id, cost.cycles_per_request, cost.service_us
        );
    }
    println!(
        "\nrequests: {} submitted | {} completed | {} shed (queue full) | {} expired (deadline)",
        r.submitted, r.completed, r.rejected_queue_full, r.expired_deadline
    );
    println!(
        "batches:  {} dispatched, occupancy mean {:.2} max {} (max-batch {}, window {}us)",
        r.batches_dispatched,
        r.mean_batch_occupancy,
        r.max_batch_occupancy,
        opts.max_batch,
        opts.max_wait_us
    );
    println!(
        "queue:    depth mean {:.2} max {} (bound {})",
        r.mean_queue_depth, r.max_queue_depth, opts.queue_depth
    );
    println!(
        "latency:  p50 {:.0}us  p95 {:.0}us  p99 {:.0}us  max {}us (virtual, at {} MHz)",
        r.latency_p50_us, r.latency_p95_us, r.latency_p99_us, r.latency_max_us, r.clock_mhz
    );
    println!(
        "throughput: {:.1} req/s over {}us virtual makespan ({} cycles total)",
        r.throughput_rps,
        r.makespan_us,
        stats::si(r.total_cycles as f64)
    );
    if r.memo_hits + r.memo_misses > 0 {
        println!(
            "layer memo: {} hits / {} misses ({:.1}% reuse)",
            r.memo_hits,
            r.memo_misses,
            100.0 * r.memo_hits as f64 / (r.memo_hits + r.memo_misses) as f64
        );
    }
    println!(
        "wall clock: {} on {} worker(s) (report is worker-count invariant)",
        stats::fmt_ns(outcome.wall_ns as f64),
        outcome.workers
    );

    let out = args.get_or("out", "serve_report.json");
    match atomic_write(Path::new(out), r.to_json().to_string_pretty().as_bytes()) {
        Ok(()) => println!("report written to {out}"),
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// Resolve the fleet's device configs: a sweep cache's Pareto survivors
/// (`--fleet-from-sweep`), an explicit list of preset /
/// `bB-iI-oO-sS-mM` names (`--fleet-configs`), or the built-in
/// three-point default.
fn fleet_configs(args: &Args) -> Vec<VtaConfig> {
    if let Some(path) = args.get("fleet-from-sweep") {
        let max = args.get_usize("fleet-max", 4);
        return serve::configs_from_sweep(Path::new(path), max).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });
    }
    match args.get("fleet-configs") {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|name| {
                presets::by_name(name).unwrap_or_else(|| {
                    eprintln!(
                        "error: unknown fleet config '{name}' (expected a preset name or a \
                         bB-iI-oO-sS-mM scaled-config name)"
                    );
                    std::process::exit(2);
                })
            })
            .collect(),
        None => serve::fleet::default_fleet_configs(),
    }
}

fn cmd_serve_fleet(args: &Args, base: serve::ServeOptions, trace: &[serve::Request]) {
    let configs = fleet_configs(args);
    let policy = serve::RoutePolicyKind::parse(args.get_or("route", "earliest"))
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
    let auto_on = args.has_flag("autoscale") || args.get("autoscale").is_some();
    let autoscale = auto_on.then(|| {
        let d = serve::AutoscaleOptions::default();
        serve::AutoscaleOptions {
            interval_us: args.get_u64("autoscale-interval-us", d.interval_us),
            max_replicas: args.get_usize("autoscale", d.max_replicas),
            scale_up_depth: args.get_usize("scale-up-depth", d.scale_up_depth),
        }
    });
    let opts = serve::FleetOptions { base, configs, policy, autoscale };

    println!(
        "fleet frontier: {} device configs + combined fleet, policy {policy}, {} requests",
        opts.configs.len(),
        trace.len()
    );
    let outcome = serve::frontier(&opts, trace).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1);
    });

    println!(
        "\n{:<16} {:>9} {:>9} {:>6} {:>7} {:>9} {:>9} {:>10} {:>7}",
        "candidate",
        "peak_area",
        "completed",
        "shed",
        "expired",
        "p50_us",
        "p99_us",
        "thr_rps",
        "pareto"
    );
    for e in &outcome.entries {
        let r = &e.report;
        println!(
            "{:<16} {:>9.2} {:>9} {:>6} {:>7} {:>9.0} {:>9.0} {:>10.1} {:>7}",
            e.label,
            r.peak_area,
            r.completed,
            r.rejected_queue_full,
            r.expired_deadline,
            r.latency_p50_us,
            r.latency_p99_us,
            r.throughput_rps,
            if e.pareto { "*" } else { "" }
        );
    }

    if let Some(fleet) = outcome.entries.iter().find(|e| e.label.starts_with("fleet(")) {
        println!("\nfleet device detail ({}, routed by {policy}):", fleet.label);
        for d in &fleet.report.devices {
            println!(
                "  {:<16} area {:>6.2}  peak replicas {}  routed {:>5}  done {:>5}  batches {:>4}",
                d.config,
                d.scaled_area,
                d.peak_replicas,
                d.routed,
                d.completed,
                d.batches_dispatched
            );
        }
    }
    println!("\nwall clock: {}", stats::fmt_ns(outcome.wall_ns as f64));

    let out = args.get_or("out", "fleet_frontier.json");
    match atomic_write(Path::new(out), outcome.to_json().to_string_pretty().as_bytes()) {
        Ok(()) => println!("frontier written to {out}"),
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_cache(args: &Args) {
    let action = match args.positional.get(1) {
        Some(s) => s.as_str(),
        None => usage(),
    };
    let dir = args.get_or("store", "vta_store");
    let store = must_open_store(dir);
    match action {
        "ls" => {
            println!("{:<12} {:<16} payload", "kind", "key");
            for kind in ArtifactKind::ALL {
                for (key, payload) in store.records(kind) {
                    let text = payload.to_string_compact();
                    let head: String = text.chars().take(60).collect();
                    let ellipsis = if text.chars().count() > 60 { "…" } else { "" };
                    println!("{:<12} {key:016x} {head}{ellipsis}", kind.cli_name());
                }
            }
        }
        "stats" => {
            let st = store.stats();
            println!("artifact store '{dir}': {} record(s)", st.total_records());
            println!(
                "{:<12} {:>8} {:>8} {:>8}  schema versions",
                "kind", "records", "stale", "corrupt"
            );
            for k in &st.kinds {
                let versions = k
                    .schema_counts
                    .iter()
                    .map(|(v, n)| format!("v{v}:{n}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                println!(
                    "{:<12} {:>8} {:>8} {:>8}  {}",
                    k.kind.cli_name(),
                    k.records,
                    k.skipped_stale,
                    k.skipped,
                    if versions.is_empty() { "-".to_string() } else { versions }
                );
            }
            if st.skipped_stale() > 0 {
                println!(
                    "note: {} stale record(s) from older schema versions are retained on \
                     disk but never consumed; `vta cache gc` compacts them away",
                    st.skipped_stale()
                );
            }
            match st.last_run {
                Some((hits, misses)) => println!(
                    "last run: {} reused, {} computed (reuse {:.3})",
                    hits,
                    misses,
                    st.last_run_reuse().unwrap_or(0.0)
                ),
                None => println!("last run: none recorded"),
            }
        }
        "verify" => {
            let report = store.verify().unwrap_or_else(|e| {
                eprintln!("error: verify failed to read '{dir}': {e}");
                std::process::exit(1);
            });
            println!("{:<12} {:>8} {:>8} {:>8}", "kind", "valid", "stale", "corrupt");
            for (kind, v) in &report.kinds {
                println!(
                    "{:<12} {:>8} {:>8} {:>8}",
                    kind.cli_name(),
                    v.valid,
                    v.stale,
                    v.corrupt
                );
            }
            if report.ok() {
                println!("store verify: OK (checksums and keys match for every record)");
            } else {
                eprintln!("store verify: FAILED (corrupt records found)");
                std::process::exit(1);
            }
        }
        "gc" => {
            let dry_run = args.has_flag("dry-run");
            let r = store.gc(dry_run).unwrap_or_else(|e| {
                eprintln!("error: gc failed on '{dir}': {e}");
                std::process::exit(1);
            });
            println!(
                "gc{}: kept {} record(s); dropped {} stale, {} corrupt, {} duplicate",
                if r.dry_run { " (dry run, nothing rewritten)" } else { "" },
                r.kept,
                r.dropped_stale,
                r.dropped_corrupt,
                r.dropped_duplicate
            );
        }
        _ => usage(),
    }
}

fn cmd_config(args: &Args) {
    let cfg = load_config(args);
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("show") | None => println!("{}", cfg.to_json().to_string_pretty()),
        Some("save") => {
            let path = args.get_or("out", "vta_config.json");
            cfg.save(path).expect("write config");
            println!("wrote {path}");
        }
        _ => usage(),
    }
}

fn cmd_floorplan(args: &Args) {
    let cfg = load_config(args);
    let fp = floorplan::vta_floorplan(&cfg);
    match fp.check() {
        Ok(()) => println!("floorplan checks: OK (utilization {:.1}%)", fp.utilization() * 100.0),
        Err(e) => println!("floorplan checks: FAILED: {e}"),
    }
    print!("{}", fp.ascii(72, 24));
}

fn cmd_isa(args: &Args) {
    let cfg = load_config(args);
    let l = cfg.isa_layout();
    println!("ISA layout for {}:", cfg.tag());
    println!("  uop_idx {} (+1 end)  loop {}  imm {}", l.uop_idx_bits, l.loop_bits, l.imm_bits);
    println!(
        "  idx bits: acc {}  inp {}  wgt {}  sram {}  dram {}",
        l.acc_idx_bits, l.inp_idx_bits, l.wgt_idx_bits, l.sram_bits, l.dram_bits
    );
    println!(
        "  mem fields: size {}  pad {}  pad_val {}",
        l.mem_size_bits, l.pad_bits, l.pad_val_bits
    );
    println!(
        "  instruction bits: GEMM {}  ALU {}  LOAD/STORE {} (of {})",
        l.gemm_bits(),
        l.alu_bits(),
        l.mem_bits(),
        vta::config::INSN_BITS
    );
    println!("  uop width: {} bits ({} bytes)", l.uop_bits, l.uop_bytes());
}

fn main() {
    let args = Args::parse_env();
    match args.subcommand() {
        Some("run") => cmd_run(&args),
        Some("repro") => cmd_repro(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("cache") => cmd_cache(&args),
        Some("config") => cmd_config(&args),
        Some("floorplan") => cmd_floorplan(&args),
        Some("isa") => cmd_isa(&args),
        _ => usage(),
    }
}
