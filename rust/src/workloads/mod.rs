//! Evaluation workloads: the ResNet family and MobileNet-1.0 used
//! throughout the paper's results (§IV-E: "we are able to execute the
//! full ResNets from the 2nd convolution layer ... to the final
//! fully-connected layer", "the end-to-end MobileNet1.0 network").
//!
//! Weights are synthetic int8 (seeded PRNG) — the evaluation metrics
//! (cycles, DRAM bytes, area) are data-independent, and numeric
//! correctness is established against the bit-exact golden models (see
//! DESIGN.md §Substitutions).

use crate::compiler::cpu_ref::default_shift;
use crate::compiler::graph::{Graph, Op};
use crate::compiler::layout::Shape;
use crate::util::rng::Pcg32;

/// ResNet depths supported (the four networks of Figs 11/12).
pub const RESNET_DEPTHS: [usize; 4] = [18, 34, 50, 101];

fn conv_op(rng: &mut Pcg32, c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize, relu: bool) -> Op {
    Op::Conv {
        c_out,
        k,
        stride,
        pad,
        shift: default_shift(c_in * k * k),
        relu,
        weights: rng.i8_vec(c_out * c_in * k * k),
    }
}

/// Build a ResNet-{18,34,50,101} graph. `hw` is the input resolution
/// (224 for the paper's workloads; smaller values make fast tests).
pub fn resnet(depth: usize, hw: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let (blocks, bottleneck) = match depth {
        18 => (vec![2, 2, 2, 2], false),
        34 => (vec![3, 4, 6, 3], false),
        50 => (vec![3, 4, 6, 3], true),
        101 => (vec![3, 4, 23, 3], true),
        _ => panic!("unsupported ResNet depth {depth}"),
    };
    let mut g = Graph::new(&format!("resnet{depth}"), Shape::new(3, hw, hw));
    // Stem: 7x7/2 conv (CPU fallback: 3 input channels) + 3x3/2 maxpool.
    let mut x = g.add("conv1", conv_op(&mut rng, 3, 64, 7, 2, 3, true), vec![0]);
    x = g.add("pool1", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![x]);
    let mut c_in = 64;
    for (stage, &n_blocks) in blocks.iter().enumerate() {
        let width = 64 << stage;
        let stride = if stage == 0 { 1 } else { 2 };
        for blk in 0..n_blocks {
            let s = if blk == 0 { stride } else { 1 };
            let prefix = format!("s{}b{}", stage + 2, blk);
            if bottleneck {
                let c_out = width * 4;
                let skip = if s != 1 || c_in != c_out {
                    g.add(
                        &format!("{prefix}_down"),
                        conv_op(&mut rng, c_in, c_out, 1, s, 0, false),
                        vec![x],
                    )
                } else {
                    x
                };
                let c1 = g.add(
                    &format!("{prefix}_c1"),
                    conv_op(&mut rng, c_in, width, 1, 1, 0, true),
                    vec![x],
                );
                let c2 = g.add(
                    &format!("{prefix}_c2"),
                    conv_op(&mut rng, width, width, 3, s, 1, true),
                    vec![c1],
                );
                let c3 = g.add(
                    &format!("{prefix}_c3"),
                    conv_op(&mut rng, width, c_out, 1, 1, 0, false),
                    vec![c2],
                );
                x = g.add(&format!("{prefix}_add"), Op::Add { relu: true }, vec![c3, skip]);
                c_in = c_out;
            } else {
                let c_out = width;
                let skip = if s != 1 || c_in != c_out {
                    g.add(
                        &format!("{prefix}_down"),
                        conv_op(&mut rng, c_in, c_out, 1, s, 0, false),
                        vec![x],
                    )
                } else {
                    x
                };
                let c1 = g.add(
                    &format!("{prefix}_c1"),
                    conv_op(&mut rng, c_in, c_out, 3, s, 1, true),
                    vec![x],
                );
                let c2 = g.add(
                    &format!("{prefix}_c2"),
                    conv_op(&mut rng, c_out, c_out, 3, 1, 1, false),
                    vec![c1],
                );
                x = g.add(&format!("{prefix}_add"), Op::Add { relu: true }, vec![c2, skip]);
                c_in = c_out;
            }
        }
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense {
            units: 1000,
            shift: default_shift(c_in),
            relu: false,
            weights: rng.i8_vec(1000 * c_in),
        },
        vec![gap],
    );
    g
}

/// MobileNet-1.0 (width multiplier 1.0): depthwise-separable blocks.
pub fn mobilenet(hw: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let mut g = Graph::new("mobilenet1.0", Shape::new(3, hw, hw));
    // Stem conv (CPU fallback: 3 channels).
    let mut x = g.add("conv1", conv_op(&mut rng, 3, 32, 3, 2, 1, true), vec![0]);
    let mut c_in = 32;
    // (out channels, depthwise stride) per separable block.
    let cfg: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(c_out, s)) in cfg.iter().enumerate() {
        let dw = g.add(
            &format!("dw{}", i + 1),
            Op::Depthwise {
                k: 3,
                stride: s,
                pad: 1,
                shift: default_shift(9),
                relu: true,
                weights: rng.i8_vec(c_in * 9),
            },
            vec![x],
        );
        x = g.add(
            &format!("pw{}", i + 1),
            conv_op(&mut rng, c_in, c_out, 1, 1, 0, true),
            vec![dw],
        );
        c_in = c_out;
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense {
            units: 1000,
            shift: default_shift(c_in),
            relu: false,
            weights: rng.i8_vec(1000 * c_in),
        },
        vec![gap],
    );
    g
}

/// Small ResNet-like test network (fast in CI; exercises every operator
/// kind: CPU-fallback conv, VTA conv, maxpool, residual add, downsample,
/// global pool, dense).
pub fn micro_resnet(block: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let c = block; // one channel tile wide
    let mut g = Graph::new("micro-resnet", Shape::new(3, 16, 16));
    let conv1 = g.add("conv1", conv_op(&mut rng, 3, c, 3, 1, 1, true), vec![0]);
    let pool1 = g.add("pool1", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![conv1]);
    let c1 = g.add("b1_c1", conv_op(&mut rng, c, c, 3, 1, 1, true), vec![pool1]);
    let c2 = g.add("b1_c2", conv_op(&mut rng, c, c, 3, 1, 1, false), vec![c1]);
    let add1 = g.add("b1_add", Op::Add { relu: true }, vec![c2, pool1]);
    let down = g.add("b2_down", conv_op(&mut rng, c, 2 * c, 1, 2, 0, false), vec![add1]);
    let c3 = g.add("b2_c1", conv_op(&mut rng, c, 2 * c, 3, 2, 1, true), vec![add1]);
    let c4 = g.add("b2_c2", conv_op(&mut rng, 2 * c, 2 * c, 3, 1, 1, false), vec![c3]);
    let add2 = g.add("b2_add", Op::Add { relu: true }, vec![c4, down]);
    let gap = g.add("gap", Op::GlobalAvgPool, vec![add2]);
    g.add(
        "fc",
        Op::Dense {
            units: 10,
            shift: default_shift(2 * c),
            relu: false,
            weights: rng.i8_vec(10 * 2 * c),
        },
        vec![gap],
    );
    g
}

/// Small MobileNet-like test network (depthwise + pointwise).
pub fn micro_mobilenet(block: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let c = block;
    let mut g = Graph::new("micro-mobilenet", Shape::new(3, 16, 16));
    let mut x = g.add("conv1", conv_op(&mut rng, 3, c, 3, 2, 1, true), vec![0]);
    for (i, s) in [1usize, 2].into_iter().enumerate() {
        let dw = g.add(
            &format!("dw{}", i + 1),
            Op::Depthwise {
                k: 3,
                stride: s,
                pad: 1,
                shift: default_shift(9),
                relu: true,
                weights: rng.i8_vec(c * 9),
            },
            vec![x],
        );
        x = g.add(&format!("pw{}", i + 1), conv_op(&mut rng, c, c, 1, 1, 0, true), vec![dw]);
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense { units: 10, shift: default_shift(c), relu: false, weights: rng.i8_vec(10 * c) },
        vec![gap],
    );
    g
}

/// Single pre-norm-free transformer encoder block (§workload families):
/// multi-head self-attention (Q/K/V projections, shift-based
/// softmax-approx, per-head mix, output projection) with a residual add
/// and shift-based layernorm-approx, followed by a 2×-expansion FFN with
/// its own residual + norm. Sequence runs along `h`, model dim along `c`
/// (`w` is always 1), so every GEMM is a 1×1 conv the tiler already
/// handles.
///
/// `d_model` must be a power of two (layernorm-approx divides by shift)
/// and divisible by `heads`.
pub fn transformer_block(d_model: usize, heads: usize, seq: usize, seed: u64) -> Graph {
    assert!(d_model.is_power_of_two(), "d_model {d_model} must be a power of two");
    assert_eq!(d_model % heads, 0, "d_model {d_model} not divisible by heads {heads}");
    let mut rng = Pcg32::seeded(seed);
    let mut g = Graph::new(
        &format!("transformer-d{d_model}h{heads}s{seq}"),
        Shape::new(d_model, seq, 1),
    );
    let q = g.add("q", conv_op(&mut rng, d_model, d_model, 1, 1, 0, false), vec![0]);
    let k = g.add("k", conv_op(&mut rng, d_model, d_model, 1, 1, 0, false), vec![0]);
    let v = g.add("v", conv_op(&mut rng, d_model, d_model, 1, 1, 0, false), vec![0]);
    let scores = g.add(
        "scores",
        Op::AttnScores { heads, shift: default_shift(d_model / heads) },
        vec![q, k],
    );
    let probs = g.add("softmax", Op::SoftmaxApprox { shift: 2 }, vec![scores]);
    // AttnMix consumes probabilities key-major; scores come out query-major.
    let probs_t = g.add("probs_t", Op::HeadTranspose { heads }, vec![probs]);
    let mix = g.add(
        "mix",
        Op::AttnMix { heads, shift: default_shift(seq) },
        vec![probs_t, v],
    );
    let proj = g.add("proj", conv_op(&mut rng, d_model, d_model, 1, 1, 0, false), vec![mix]);
    let attn_add = g.add("attn_add", Op::Add { relu: false }, vec![proj, 0]);
    let ln1 = g.add("ln1", Op::LayerNormApprox, vec![attn_add]);
    let ffn1 = g.add("ffn1", conv_op(&mut rng, d_model, 2 * d_model, 1, 1, 0, true), vec![ln1]);
    let ffn2 = g.add("ffn2", conv_op(&mut rng, 2 * d_model, d_model, 1, 1, 0, false), vec![ffn1]);
    let ffn_add = g.add("ffn_add", Op::Add { relu: false }, vec![ffn2, ln1]);
    g.add("ln2", Op::LayerNormApprox, vec![ffn_add]);
    g
}

/// LSTM cell unrolled over the feature axis (§workload families): the
/// input tensor stacks `[x; h_prev; c_prev]` along channels (3·`hidden`),
/// each of the `seq` rows is one timestep's state. One fused gate GEMM
/// (3H→4H, with the `c_prev` weight block zeroed — the cell state only
/// enters through the elementwise path) feeds the i/f/g/o gate math:
/// hard-sigmoid/hard-tanh activations and shift-requantized elementwise
/// products producing `c_new` then `h_new`.
pub fn lstm_cell(hidden: usize, seq: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let h = hidden;
    let mut g = Graph::new(&format!("lstm-h{h}s{seq}"), Shape::new(3 * h, seq, 1));
    // Fused gate projection: weights against the c_prev block are zero so
    // the GEMM sees only [x; h_prev] (fan-in 2H sets the requant shift).
    let mut w = rng.i8_vec(4 * h * 3 * h);
    for o in 0..4 * h {
        for ci in 2 * h..3 * h {
            w[o * 3 * h + ci] = 0;
        }
    }
    let gates = g.add(
        "gates",
        Op::Conv {
            c_out: 4 * h,
            k: 1,
            stride: 1,
            pad: 0,
            shift: default_shift(2 * h),
            relu: false,
            weights: w,
        },
        vec![0],
    );
    let i_raw = g.add("i", Op::ChanSlice { start: 0, len: h }, vec![gates]);
    let f_raw = g.add("f", Op::ChanSlice { start: h, len: h }, vec![gates]);
    let g_raw = g.add("g", Op::ChanSlice { start: 2 * h, len: h }, vec![gates]);
    let o_raw = g.add("o", Op::ChanSlice { start: 3 * h, len: h }, vec![gates]);
    let c_prev = g.add("c_prev", Op::ChanSlice { start: 2 * h, len: h }, vec![0]);
    let i_s = g.add("i_sig", Op::HardSigmoid, vec![i_raw]);
    let f_s = g.add("f_sig", Op::HardSigmoid, vec![f_raw]);
    let g_t = g.add("g_tanh", Op::HardTanh, vec![g_raw]);
    let o_s = g.add("o_sig", Op::HardSigmoid, vec![o_raw]);
    let keep = g.add("keep", Op::EltMul { shift: 7, relu: false }, vec![f_s, c_prev]);
    let write = g.add("write", Op::EltMul { shift: 7, relu: false }, vec![i_s, g_t]);
    let c_new = g.add("c_new", Op::Add { relu: false }, vec![keep, write]);
    let c_tanh = g.add("c_tanh", Op::HardTanh, vec![c_new]);
    g.add("h_new", Op::EltMul { shift: 7, relu: false }, vec![o_s, c_tanh]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_shape_count() {
        let g = resnet(18, 224, 1);
        let shapes = g.shapes();
        // 4 stages of 2 basic blocks; final activation 512x7x7.
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!((pre_gap.c, pre_gap.h, pre_gap.w), (512, 7, 7));
        assert_eq!(shapes.last().unwrap().c, 1000);
    }

    #[test]
    fn resnet50_uses_bottleneck() {
        let g = resnet(50, 224, 1);
        let shapes = g.shapes();
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!(pre_gap.c, 2048);
    }

    #[test]
    fn resnet18_macs_near_published() {
        // ResNet-18 @224 is ~1.81 G MACs; VTA executes all but conv1
        // (~118M MACs), so ~1.70G (plus fc channel padding).
        let cfg = crate::config::presets::default_config();
        let g = resnet(18, 224, 1);
        let macs = g.vta_macs(&cfg) as f64;
        assert!(macs > 1.6e9 && macs < 1.8e9, "got {macs:e}");
    }

    #[test]
    fn mobilenet_structure() {
        let g = mobilenet(224, 1);
        let shapes = g.shapes();
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!((pre_gap.c, pre_gap.h, pre_gap.w), (1024, 7, 7));
        let n_dw = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::compiler::graph::Op::Depthwise { .. }))
            .count();
        assert_eq!(n_dw, 13);
    }

    #[test]
    fn micro_nets_run_on_cpu() {
        let mut rng = Pcg32::seeded(9);
        for g in [micro_resnet(4, 1), micro_mobilenet(4, 1)] {
            let input = rng.i8_vec(g.input_shape.elems());
            let out = g.run_cpu(&input, 1);
            assert_eq!(out.len(), 10);
        }
    }

    #[test]
    fn transformer_block_structure() {
        let g = transformer_block(64, 4, 16, 1);
        g.validate().unwrap();
        let shapes = g.shapes();
        let out = shapes.last().unwrap();
        assert_eq!((out.c, out.h, out.w), (64, 16, 1));
        // Attention scores fan out to one (seq x seq) map per head.
        let scores = g.nodes.iter().position(|n| n.name == "scores").unwrap();
        assert_eq!((shapes[scores].c, shapes[scores].h), (4 * 16, 16));
        let n_ln = g.nodes.iter().filter(|n| matches!(n.op, Op::LayerNormApprox)).count();
        assert_eq!(n_ln, 2);
    }

    #[test]
    fn lstm_cell_zeroes_cprev_gate_weights() {
        let h = 8;
        let g = lstm_cell(h, 4, 1);
        g.validate().unwrap();
        let out = *g.shapes().last().unwrap();
        assert_eq!((out.c, out.h, out.w), (h, 4, 1));
        let Op::Conv { weights, .. } = &g.nodes[1].op else { panic!("gate GEMM first") };
        for o in 0..4 * h {
            let row = &weights[o * 3 * h..(o + 1) * 3 * h];
            assert!(row[2 * h..].iter().all(|&w| w == 0), "c_prev block leaks into gate GEMM");
            assert!(row[..2 * h].iter().any(|&w| w != 0));
        }
    }

    #[test]
    fn new_families_run_on_cpu() {
        let mut rng = Pcg32::seeded(11);
        for g in [transformer_block(16, 4, 8, 1), lstm_cell(8, 4, 1)] {
            let input = rng.i8_vec(g.input_shape.elems());
            let out = g.run_cpu(&input, 1);
            assert_eq!(out.len(), g.shapes().last().unwrap().elems());
        }
    }
}
