//! Evaluation workloads: the ResNet family and MobileNet-1.0 used
//! throughout the paper's results (§IV-E: "we are able to execute the
//! full ResNets from the 2nd convolution layer ... to the final
//! fully-connected layer", "the end-to-end MobileNet1.0 network").
//!
//! Weights are synthetic int8 (seeded PRNG) — the evaluation metrics
//! (cycles, DRAM bytes, area) are data-independent, and numeric
//! correctness is established against the bit-exact golden models (see
//! DESIGN.md §Substitutions).

use crate::compiler::cpu_ref::default_shift;
use crate::compiler::graph::{Graph, Op};
use crate::compiler::layout::Shape;
use crate::util::rng::Pcg32;

/// ResNet depths supported (the four networks of Figs 11/12).
pub const RESNET_DEPTHS: [usize; 4] = [18, 34, 50, 101];

fn conv_op(rng: &mut Pcg32, c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize, relu: bool) -> Op {
    Op::Conv {
        c_out,
        k,
        stride,
        pad,
        shift: default_shift(c_in * k * k),
        relu,
        weights: rng.i8_vec(c_out * c_in * k * k),
    }
}

/// Build a ResNet-{18,34,50,101} graph. `hw` is the input resolution
/// (224 for the paper's workloads; smaller values make fast tests).
pub fn resnet(depth: usize, hw: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let (blocks, bottleneck) = match depth {
        18 => (vec![2, 2, 2, 2], false),
        34 => (vec![3, 4, 6, 3], false),
        50 => (vec![3, 4, 6, 3], true),
        101 => (vec![3, 4, 23, 3], true),
        _ => panic!("unsupported ResNet depth {depth}"),
    };
    let mut g = Graph::new(&format!("resnet{depth}"), Shape::new(3, hw, hw));
    // Stem: 7x7/2 conv (CPU fallback: 3 input channels) + 3x3/2 maxpool.
    let mut x = g.add("conv1", conv_op(&mut rng, 3, 64, 7, 2, 3, true), vec![0]);
    x = g.add("pool1", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![x]);
    let mut c_in = 64;
    for (stage, &n_blocks) in blocks.iter().enumerate() {
        let width = 64 << stage;
        let stride = if stage == 0 { 1 } else { 2 };
        for blk in 0..n_blocks {
            let s = if blk == 0 { stride } else { 1 };
            let prefix = format!("s{}b{}", stage + 2, blk);
            if bottleneck {
                let c_out = width * 4;
                let skip = if s != 1 || c_in != c_out {
                    g.add(
                        &format!("{prefix}_down"),
                        conv_op(&mut rng, c_in, c_out, 1, s, 0, false),
                        vec![x],
                    )
                } else {
                    x
                };
                let c1 = g.add(
                    &format!("{prefix}_c1"),
                    conv_op(&mut rng, c_in, width, 1, 1, 0, true),
                    vec![x],
                );
                let c2 = g.add(
                    &format!("{prefix}_c2"),
                    conv_op(&mut rng, width, width, 3, s, 1, true),
                    vec![c1],
                );
                let c3 = g.add(
                    &format!("{prefix}_c3"),
                    conv_op(&mut rng, width, c_out, 1, 1, 0, false),
                    vec![c2],
                );
                x = g.add(&format!("{prefix}_add"), Op::Add { relu: true }, vec![c3, skip]);
                c_in = c_out;
            } else {
                let c_out = width;
                let skip = if s != 1 || c_in != c_out {
                    g.add(
                        &format!("{prefix}_down"),
                        conv_op(&mut rng, c_in, c_out, 1, s, 0, false),
                        vec![x],
                    )
                } else {
                    x
                };
                let c1 = g.add(
                    &format!("{prefix}_c1"),
                    conv_op(&mut rng, c_in, c_out, 3, s, 1, true),
                    vec![x],
                );
                let c2 = g.add(
                    &format!("{prefix}_c2"),
                    conv_op(&mut rng, c_out, c_out, 3, 1, 1, false),
                    vec![c1],
                );
                x = g.add(&format!("{prefix}_add"), Op::Add { relu: true }, vec![c2, skip]);
                c_in = c_out;
            }
        }
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense {
            units: 1000,
            shift: default_shift(c_in),
            relu: false,
            weights: rng.i8_vec(1000 * c_in),
        },
        vec![gap],
    );
    g
}

/// MobileNet-1.0 (width multiplier 1.0): depthwise-separable blocks.
pub fn mobilenet(hw: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let mut g = Graph::new("mobilenet1.0", Shape::new(3, hw, hw));
    // Stem conv (CPU fallback: 3 channels).
    let mut x = g.add("conv1", conv_op(&mut rng, 3, 32, 3, 2, 1, true), vec![0]);
    let mut c_in = 32;
    // (out channels, depthwise stride) per separable block.
    let cfg: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(c_out, s)) in cfg.iter().enumerate() {
        let dw = g.add(
            &format!("dw{}", i + 1),
            Op::Depthwise {
                k: 3,
                stride: s,
                pad: 1,
                shift: default_shift(9),
                relu: true,
                weights: rng.i8_vec(c_in * 9),
            },
            vec![x],
        );
        x = g.add(
            &format!("pw{}", i + 1),
            conv_op(&mut rng, c_in, c_out, 1, 1, 0, true),
            vec![dw],
        );
        c_in = c_out;
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense {
            units: 1000,
            shift: default_shift(c_in),
            relu: false,
            weights: rng.i8_vec(1000 * c_in),
        },
        vec![gap],
    );
    g
}

/// Small ResNet-like test network (fast in CI; exercises every operator
/// kind: CPU-fallback conv, VTA conv, maxpool, residual add, downsample,
/// global pool, dense).
pub fn micro_resnet(block: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let c = block; // one channel tile wide
    let mut g = Graph::new("micro-resnet", Shape::new(3, 16, 16));
    let conv1 = g.add("conv1", conv_op(&mut rng, 3, c, 3, 1, 1, true), vec![0]);
    let pool1 = g.add("pool1", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![conv1]);
    let c1 = g.add("b1_c1", conv_op(&mut rng, c, c, 3, 1, 1, true), vec![pool1]);
    let c2 = g.add("b1_c2", conv_op(&mut rng, c, c, 3, 1, 1, false), vec![c1]);
    let add1 = g.add("b1_add", Op::Add { relu: true }, vec![c2, pool1]);
    let down = g.add("b2_down", conv_op(&mut rng, c, 2 * c, 1, 2, 0, false), vec![add1]);
    let c3 = g.add("b2_c1", conv_op(&mut rng, c, 2 * c, 3, 2, 1, true), vec![add1]);
    let c4 = g.add("b2_c2", conv_op(&mut rng, 2 * c, 2 * c, 3, 1, 1, false), vec![c3]);
    let add2 = g.add("b2_add", Op::Add { relu: true }, vec![c4, down]);
    let gap = g.add("gap", Op::GlobalAvgPool, vec![add2]);
    g.add(
        "fc",
        Op::Dense {
            units: 10,
            shift: default_shift(2 * c),
            relu: false,
            weights: rng.i8_vec(10 * 2 * c),
        },
        vec![gap],
    );
    g
}

/// Small MobileNet-like test network (depthwise + pointwise).
pub fn micro_mobilenet(block: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    let c = block;
    let mut g = Graph::new("micro-mobilenet", Shape::new(3, 16, 16));
    let mut x = g.add("conv1", conv_op(&mut rng, 3, c, 3, 2, 1, true), vec![0]);
    for (i, s) in [1usize, 2].into_iter().enumerate() {
        let dw = g.add(
            &format!("dw{}", i + 1),
            Op::Depthwise {
                k: 3,
                stride: s,
                pad: 1,
                shift: default_shift(9),
                relu: true,
                weights: rng.i8_vec(c * 9),
            },
            vec![x],
        );
        x = g.add(&format!("pw{}", i + 1), conv_op(&mut rng, c, c, 1, 1, 0, true), vec![dw]);
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add(
        "fc",
        Op::Dense { units: 10, shift: default_shift(c), relu: false, weights: rng.i8_vec(10 * c) },
        vec![gap],
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_shape_count() {
        let g = resnet(18, 224, 1);
        let shapes = g.shapes();
        // 4 stages of 2 basic blocks; final activation 512x7x7.
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!((pre_gap.c, pre_gap.h, pre_gap.w), (512, 7, 7));
        assert_eq!(shapes.last().unwrap().c, 1000);
    }

    #[test]
    fn resnet50_uses_bottleneck() {
        let g = resnet(50, 224, 1);
        let shapes = g.shapes();
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!(pre_gap.c, 2048);
    }

    #[test]
    fn resnet18_macs_near_published() {
        // ResNet-18 @224 is ~1.81 G MACs; VTA executes all but conv1
        // (~118M MACs), so ~1.70G (plus fc channel padding).
        let cfg = crate::config::presets::default_config();
        let g = resnet(18, 224, 1);
        let macs = g.vta_macs(&cfg) as f64;
        assert!(macs > 1.6e9 && macs < 1.8e9, "got {macs:e}");
    }

    #[test]
    fn mobilenet_structure() {
        let g = mobilenet(224, 1);
        let shapes = g.shapes();
        let pre_gap = shapes[shapes.len() - 3];
        assert_eq!((pre_gap.c, pre_gap.h, pre_gap.w), (1024, 7, 7));
        let n_dw = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::compiler::graph::Op::Depthwise { .. }))
            .count();
        assert_eq!(n_dw, 13);
    }

    #[test]
    fn micro_nets_run_on_cpu() {
        let mut rng = Pcg32::seeded(9);
        for g in [micro_resnet(4, 1), micro_mobilenet(4, 1)] {
            let input = rng.i8_vec(g.input_shape.elems());
            let out = g.run_cpu(&input, 1);
            assert_eq!(out.len(), 10);
        }
    }
}
