//! *fsim* — the behavioral simulator target (§III-C).
//!
//! Executes an instruction stream back-to-back with no timing model.
//! Its value in the paper's methodology is "its relative simplicity":
//! functional discrepancies introduced by the micro-architectural model
//! (*tsim*) are debugged against this reference via dynamic trace-based
//! validation (see [`crate::trace`]).

use crate::config::VtaConfig;
use crate::exec::{CoreState, ExecCounters};
use crate::isa::{Insn, Opcode};
use crate::mem::Dram;

#[derive(Debug, Clone, Default)]
pub struct FsimReport {
    pub insns_executed: u64,
    pub finished: bool,
    pub counters: ExecCounters,
}

pub struct Fsim {
    pub state: CoreState,
    /// Optional per-instruction observer (trace manager hook). Called
    /// *after* each instruction's architectural effect.
    pub observer: Option<Box<dyn FnMut(u64, &Insn, &CoreState)>>,
}

impl Fsim {
    pub fn new(cfg: &VtaConfig) -> Fsim {
        Fsim { state: CoreState::new(cfg), observer: None }
    }

    /// Execute instructions in program order until FINISH (or the end of
    /// the stream). Returns the execution report; counters accumulate
    /// across calls (use [`Fsim::reset_counters`] between runs).
    pub fn run(&mut self, insns: &[Insn], dram: &mut Dram) -> FsimReport {
        let mut report = FsimReport::default();
        for (i, insn) in insns.iter().enumerate() {
            self.state.execute(insn, dram);
            report.insns_executed += 1;
            if let Some(obs) = &mut self.observer {
                obs(i as u64, insn, &self.state);
            }
            if insn.opcode() == Opcode::Finish {
                report.finished = true;
                break;
            }
        }
        report.counters = self.state.counters;
        report
    }

    pub fn reset_counters(&mut self) {
        self.state.counters = ExecCounters::default();
    }

    /// Restore the simulator to its just-constructed state (buffers
    /// zeroed, counters cleared, observer detached) without reallocating
    /// the scratchpads. Used by batched evaluation
    /// ([`crate::runtime::Session::reset_for_reuse`]) so every request
    /// in a batch sees a bit-identical fresh core.
    pub fn reset_for_reuse(&mut self) {
        self.state.reset();
        self.observer = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::isa::DepFlags;

    #[test]
    fn runs_to_finish() {
        let cfg = presets::tiny_config();
        let mut sim = Fsim::new(&cfg);
        let mut dram = Dram::new(1 << 16);
        let insns = vec![Insn::Finish(DepFlags::NONE), Insn::Finish(DepFlags::NONE)];
        let report = sim.run(&insns, &mut dram);
        assert!(report.finished);
        assert_eq!(report.insns_executed, 1);
    }

    #[test]
    fn stops_without_finish() {
        let cfg = presets::tiny_config();
        let mut sim = Fsim::new(&cfg);
        let mut dram = Dram::new(1 << 16);
        let report = sim.run(&[], &mut dram);
        assert!(!report.finished);
    }

    #[test]
    fn observer_sees_each_insn() {
        let cfg = presets::tiny_config();
        let mut sim = Fsim::new(&cfg);
        let mut dram = Dram::new(1 << 16);
        let count = std::rc::Rc::new(std::cell::Cell::new(0u64));
        let c2 = count.clone();
        sim.observer = Some(Box::new(move |_, _, _| c2.set(c2.get() + 1)));
        sim.run(&[Insn::Finish(DepFlags::NONE)], &mut dram);
        assert_eq!(count.get(), 1);
    }
}
