//! Dynamic trace-based validation (§III-C).
//!
//! "When a test failed ... the test was rerun in trace mode ... producing
//! a configurable dump of architectural states. The trace produced by the
//! failing target was then compared to the trace produced by another
//! passing target. A detailed comparison pinpointed the location in the
//! trace where the behavior of the failing target diverged."
//!
//! The trace manager records per-instruction digests of selected
//! architectural state (scratchpad contents) from any target — here fsim
//! and tsim — and [`first_divergence`] finds the earliest instruction at
//! which two traces disagree, the starting point for defect localization.

use crate::config::VtaConfig;
use crate::fsim::Fsim;
use crate::isa::{BufferId, Insn, Opcode};
use crate::mem::Dram;

/// Which architectural states to record ("user selectable trace modes
/// allowing the generation of traces with different levels of
/// granularity").
#[derive(Debug, Clone)]
pub struct TraceMode {
    pub buffers: Vec<BufferId>,
    /// Record only every Nth instruction (1 = every instruction).
    pub stride: usize,
}

impl Default for TraceMode {
    fn default() -> Self {
        TraceMode { buffers: vec![BufferId::Acc, BufferId::Out], stride: 1 }
    }
}

impl TraceMode {
    pub fn full() -> TraceMode {
        TraceMode { buffers: BufferId::ALL.to_vec(), stride: 1 }
    }
}

/// One trace record: instruction index + digests of the selected buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRecord {
    pub insn_index: u64,
    pub opcode: Opcode,
    pub digests: Vec<(BufferId, u64)>,
}

/// An architectural-state trace from one target.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub target: String,
    pub records: Vec<TraceRecord>,
}

/// Run a program on fsim in trace mode.
pub fn trace_fsim(cfg: &VtaConfig, insns: &[Insn], dram: &mut Dram, mode: &TraceMode) -> Trace {
    use std::cell::RefCell;
    use std::rc::Rc;
    let mut sim = Fsim::new(cfg);
    let records: Rc<RefCell<Vec<TraceRecord>>> = Rc::new(RefCell::new(Vec::new()));
    let sink = records.clone();
    let mode2 = mode.clone();
    sim.observer = Some(Box::new(move |idx, insn, state| {
        if idx as usize % mode2.stride != 0 {
            return;
        }
        let digests =
            mode2.buffers.iter().map(|&b| (b, state.buffer_digest(b))).collect();
        sink.borrow_mut().push(TraceRecord {
            insn_index: idx,
            opcode: insn.opcode(),
            digests,
        });
    }));
    sim.run(insns, dram);
    sim.observer = None;
    let records = Rc::try_unwrap(records).expect("observer dropped").into_inner();
    Trace { target: "fsim".into(), records }
}

/// Run a program on tsim in trace mode. tsim has no per-instruction
/// observer (instructions complete out of program order across modules),
/// so the comparable trace is reconstructed by replaying the instruction
/// stream on the *architectural* state after the full run would be
/// meaningless; instead we step tsim one *program* at a time. For
/// fsim-vs-tsim localization the practical granularity is per-program
/// (per-layer) digests, which is how the CI harness uses it; within a
/// program, fsim-vs-fsim(stride) narrows further.
pub fn trace_tsim_programs(
    cfg: &VtaConfig,
    programs: &[Vec<Insn>],
    dram: &mut Dram,
    mode: &TraceMode,
) -> Trace {
    let mut sim = crate::sim::Tsim::new(cfg);
    let mut records = Vec::new();
    for (i, prog) in programs.iter().enumerate() {
        sim.run(prog, dram, &format!("p{i}"));
        let digests = mode.buffers.iter().map(|&b| (b, sim.core.buffer_digest(b))).collect();
        records.push(TraceRecord {
            insn_index: i as u64,
            opcode: Opcode::Finish,
            digests,
        });
    }
    Trace { target: "tsim".into(), records }
}

/// Per-program fsim trace with the same granularity as
/// [`trace_tsim_programs`].
pub fn trace_fsim_programs(
    cfg: &VtaConfig,
    programs: &[Vec<Insn>],
    dram: &mut Dram,
    mode: &TraceMode,
) -> Trace {
    let mut sim = Fsim::new(cfg);
    let mut records = Vec::new();
    for (i, prog) in programs.iter().enumerate() {
        sim.run(prog, dram);
        let digests =
            mode.buffers.iter().map(|&b| (b, sim.state.buffer_digest(b))).collect();
        records.push(TraceRecord { insn_index: i as u64, opcode: Opcode::Finish, digests });
    }
    Trace { target: "fsim".into(), records }
}

/// The earliest record index at which the two traces diverge, plus the
/// buffer that first differs — "the divergence point was then used to
/// cross-reference the failing target code and find ... the defect".
pub fn first_divergence(a: &Trace, b: &Trace) -> Option<(usize, BufferId)> {
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        for ((buf_a, da), (_, db)) in ra.digests.iter().zip(&rb.digests) {
            if da != db {
                return Some((i, *buf_a));
            }
        }
    }
    if a.records.len() != b.records.len() {
        return Some((a.records.len().min(b.records.len()), BufferId::Acc));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::isa::{AluInsn, AluOp, DepFlags, Uop};

    fn alu_program(imm: i32) -> Vec<Insn> {
        vec![
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE,
                reset: false,
                op: AluOp::Mov,
                uop_bgn: 0,
                uop_end: 1,
                lp_out: 1,
                lp_in: 1,
                dst_f0: 0,
                dst_f1: 0,
                src_f0: 0,
                src_f1: 0,
                use_imm: true,
                imm,
            }),
            Insn::Finish(DepFlags::NONE),
        ]
    }

    fn with_uop(cfg: &VtaConfig, dram: &mut Dram) -> Vec<Insn> {
        // uop[0] defaults to (0,0,0) — usable without a load.
        let _ = (cfg, dram);
        vec![]
    }

    #[test]
    fn identical_runs_do_not_diverge() {
        let cfg = presets::tiny_config();
        let mode = TraceMode::default();
        let mut d1 = Dram::new(1 << 16);
        let mut d2 = Dram::new(1 << 16);
        let _ = with_uop(&cfg, &mut d1);
        let t1 = trace_fsim(&cfg, &alu_program(5), &mut d1, &mode);
        let t2 = trace_fsim(&cfg, &alu_program(5), &mut d2, &mode);
        assert_eq!(first_divergence(&t1, &t2), None);
        assert_eq!(t1.records.len(), 2);
    }

    #[test]
    fn injected_defect_localized_at_first_bad_insn() {
        // Two programs identical except instruction 0's immediate — the
        // divergence must be reported at record 0, in the ACC buffer.
        let cfg = presets::tiny_config();
        let mode = TraceMode::default();
        let mut d1 = Dram::new(1 << 16);
        let mut d2 = Dram::new(1 << 16);
        let t1 = trace_fsim(&cfg, &alu_program(5), &mut d1, &mode);
        let t2 = trace_fsim(&cfg, &alu_program(6), &mut d2, &mode);
        assert_eq!(first_divergence(&t1, &t2), Some((0, BufferId::Acc)));
    }

    #[test]
    fn stride_reduces_granularity() {
        let cfg = presets::tiny_config();
        let mode = TraceMode { buffers: vec![BufferId::Acc], stride: 2 };
        let mut d = Dram::new(1 << 16);
        let t = trace_fsim(&cfg, &alu_program(5), &mut d, &mode);
        assert_eq!(t.records.len(), 1); // records only insn 0
    }

    #[test]
    fn per_program_tsim_vs_fsim_traces_agree() {
        let cfg = presets::tiny_config();
        let mode = TraceMode::full();
        let programs = vec![alu_program(3), alu_program(-7)];
        let mut d1 = Dram::new(1 << 16);
        let mut d2 = Dram::new(1 << 16);
        let tf = trace_fsim_programs(&cfg, &programs, &mut d1, &mode);
        let tt = trace_tsim_programs(&cfg, &programs, &mut d2, &mode);
        assert_eq!(first_divergence(&tf, &tt), None);
    }
}
