//! Error taxonomy of the unified evaluation API.
//!
//! Every public entry point of `engine`, `runtime` and `sweep` returns
//! `Result<_, VtaError>` instead of panicking on malformed input, so a
//! serving layer built on top can shed bad requests instead of dying.
//! The variants partition by *who* got it wrong:
//!
//! * [`VtaError::Config`] — the hardware description is invalid
//!   (delegates to [`ConfigError`], the config subsystem's own taxonomy);
//! * [`VtaError::Graph`] — the workload graph is structurally malformed;
//! * [`VtaError::InvalidRequest`] — the per-evaluation request does not
//!   fit the prepared `(config, graph)` pair (e.g. wrong input length);
//! * [`VtaError::Unsupported`] — a coherent request that the *chosen
//!   backend* cannot satisfy (capability mismatch: memo on a
//!   memo-less backend, a sweep over a backend that produces no cycles);
//! * [`VtaError::Io`] — cache/spill filesystem failures.
//!
//! Panics remain reserved for internal invariant violations (simulator
//! deadlock detection, broken program images) — states a well-formed
//! request can never reach.

use crate::config::ConfigError;
use std::fmt;
use std::io;

/// Unified error type of the `Engine`/`Backend` evaluation surface.
#[derive(Debug)]
pub enum VtaError {
    /// The hardware configuration failed validation.
    Config(ConfigError),
    /// The graph is structurally malformed (bad arity, dangling edges,
    /// shape-inconsistent operators, wrong weight-tensor sizes).
    Graph(String),
    /// The request does not fit the prepared `(config, graph)` pair.
    InvalidRequest(String),
    /// The chosen backend cannot satisfy this (otherwise coherent)
    /// request — a capability mismatch, not a malformed input.
    Unsupported(String),
    /// Result-cache / memo-spill I/O failure.
    Io(io::Error),
}

impl fmt::Display for VtaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VtaError::Config(e) => write!(f, "invalid configuration: {e}"),
            VtaError::Graph(msg) => write!(f, "malformed graph: {msg}"),
            VtaError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            VtaError::Unsupported(msg) => write!(f, "unsupported: {msg}"),
            VtaError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for VtaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            VtaError::Config(e) => Some(e),
            VtaError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for VtaError {
    fn from(e: ConfigError) -> VtaError {
        VtaError::Config(e)
    }
}

impl From<io::Error> for VtaError {
    fn from(e: io::Error) -> VtaError {
        VtaError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_prefixed_by_category() {
        assert!(VtaError::Graph("x".into()).to_string().starts_with("malformed graph"));
        assert!(VtaError::InvalidRequest("x".into()).to_string().starts_with("invalid request"));
        assert!(VtaError::Unsupported("x".into()).to_string().starts_with("unsupported"));
    }

    #[test]
    fn config_errors_convert_and_chain() {
        let err: VtaError = ConfigError::NotPow2 { field: "batch", value: 3 }.into();
        assert!(matches!(err, VtaError::Config(_)));
        assert!(std::error::Error::source(&err).is_some());
    }
}
