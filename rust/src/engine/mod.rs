//! Unified evaluation API: one [`Engine`], many [`Backend`]s, a single
//! fidelity ladder.
//!
//! The stack grew four ways to evaluate a workload on a configuration —
//! functional *fsim*, cycle-accurate *tsim*, the timing-only tsim fast
//! path, and the analytical cycle model — and they used to be reached
//! through four inconsistent entry points stitched together with boolean
//! flags. This module makes the fidelity level a first-class choice: a
//! [`Backend`] declares where it sits on the [`Fidelity`] ladder and
//! what it can produce ([`Capabilities`]), and the [`Engine`] builder
//! owns the plumbing (layer memo, tuning knobs, perf reports) that used
//! to be distributed across `SessionOptions`, `EvalOptions` and ad-hoc
//! flags. Swapping fidelity is swapping a backend — nothing else about
//! the client changes:
//!
//! ```no_run
//! use vta::engine::{BackendKind, Engine, EvalRequest};
//! use vta::config::presets;
//! use vta::workloads;
//!
//! let cfg = presets::default_config();
//! let graph = workloads::micro_resnet(16, 1);
//! for kind in BackendKind::ALL {
//!     let engine = Engine::for_config(&cfg).backend_kind(kind).build().unwrap();
//!     let eval = engine.run(&graph, &EvalRequest::seeded(7)).unwrap();
//!     println!("{kind}: fidelity {} cycles {:?}", eval.fidelity, eval.cycles);
//! }
//! ```
//!
//! The built-in backends and where they sit:
//!
//! | backend | fidelity | outputs | cycles | memo |
//! |---|---|---|---|---|
//! | [`AnalyticalBackend`] | `Analytical` | – | predicted | – |
//! | [`TsimBackend::timing_only`] | `TimingOnly` | – | exact | yes |
//! | [`TsimBackend::functional`] | `CycleAccurate` | exact | exact | yes |
//! | [`FsimBackend`] | `Functional` | exact | – | – |
//!
//! The ladder ranks how much of the machine each backend exercises on
//! the way to its numbers: the analytical model touches none of it,
//! timing-only tsim runs the real timing wheel, cycle-accurate tsim adds
//! the full datapath, and fsim is the pure behavioral reference the
//! others are validated against. Two invariants connect the rungs
//! (pinned by `rust/tests/backend_parity.rs`): every rung that produces
//! outputs produces *bit-identical* outputs, and every tsim rung
//! produces *bit-identical* cycles.
//!
//! Every entry point returns a `Result` with the [`VtaError`] taxonomy,
//! so layers above (the sweep service today, a serving tier tomorrow)
//! can reject bad requests without dying. The memo fast path is
//! composed in as a wrapper backend ([`MemoBackend`]) rather than a
//! flag; the builder's [`EngineBuilder::memo`] applies the wrapper for
//! you.

pub mod backends;
mod error;

pub use backends::{AnalyticalBackend, FsimBackend, MemoBackend, TsimBackend};
pub use error::VtaError;

use crate::compiler::graph::Graph;
use crate::compiler::layout::Shape;
use crate::config::VtaConfig;
use crate::exec::ExecCounters;
use crate::memo::LayerMemo;
use crate::runtime::LayerStat;
use crate::sim::activity::ActivityTrace;
use crate::sim::PerfReport;
use std::fmt;
use std::sync::Arc;

/// The fidelity ladder, ordered by how much of the simulated machine a
/// backend exercises: `Analytical < TimingOnly < CycleAccurate <
/// Functional`. `Ord` follows declaration order, so clients can demand
/// a floor (`backend.fidelity() >= Fidelity::TimingOnly`) instead of
/// naming backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Fidelity {
    /// Closed-form cycle model; microseconds per network, no simulation.
    Analytical,
    /// Real timing wheel, datapath skipped: exact cycles, no tensors.
    TimingOnly,
    /// Full cycle-accurate simulation: exact cycles and exact tensors.
    CycleAccurate,
    /// Pure behavioral reference: exact tensors, no timing model.
    Functional,
}

impl Fidelity {
    /// Every rung, lowest fidelity first.
    pub const LADDER: [Fidelity; 4] =
        [Fidelity::Analytical, Fidelity::TimingOnly, Fidelity::CycleAccurate, Fidelity::Functional];

    pub fn name(self) -> &'static str {
        match self {
            Fidelity::Analytical => "analytical",
            Fidelity::TimingOnly => "timing-only",
            Fidelity::CycleAccurate => "cycle-accurate",
            Fidelity::Functional => "functional",
        }
    }
}

impl fmt::Display for Fidelity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.name())
    }
}

/// What a backend can produce. Declared up front so clients (and the
/// [`EngineBuilder`]) can reject capability mismatches before any work
/// happens, instead of discovering a `None` mid-pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// [`Evaluation::output`] carries the network's output tensor.
    pub produces_outputs: bool,
    /// [`Evaluation::cycles`] carries a cycle count (measured or
    /// predicted, per the backend's [`Fidelity`]).
    pub produces_cycles: bool,
    /// The backend honors a shared [`LayerMemo`] (see [`MemoBackend`]).
    pub supports_memo: bool,
}

/// The built-in backends, as a closed enum for CLI parsing and plumbing
/// through options structs. [`BackendKind::instantiate`] turns a kind
/// into the live [`Backend`]; custom backends skip the enum and go
/// straight to [`EngineBuilder::backend`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Behavioral simulator ([`FsimBackend`]).
    Fsim,
    /// Cycle-accurate simulator, functional datapath on ([`TsimBackend`]).
    Tsim,
    /// Cycle-accurate simulator, timing only ([`TsimBackend`]).
    TsimTiming,
    /// Analytical cycle model ([`AnalyticalBackend`]).
    Analytical,
}

impl BackendKind {
    /// Every built-in backend, lowest fidelity first.
    pub const ALL: [BackendKind; 4] =
        [BackendKind::Analytical, BackendKind::TsimTiming, BackendKind::Tsim, BackendKind::Fsim];

    /// Parse a CLI name: `fsim`, `tsim`, `timing` (alias `timing-only`),
    /// `model` (alias `analytical`).
    pub fn parse(s: &str) -> Result<BackendKind, VtaError> {
        match s {
            "fsim" => Ok(BackendKind::Fsim),
            "tsim" | "functional" => Ok(BackendKind::Tsim),
            "timing" | "timing-only" => Ok(BackendKind::TsimTiming),
            "model" | "analytical" => Ok(BackendKind::Analytical),
            other => Err(VtaError::InvalidRequest(format!(
                "unknown backend '{other}' (expected fsim, tsim, timing, or model)"
            ))),
        }
    }

    /// The canonical CLI name ([`BackendKind::parse`] round-trips it).
    pub fn cli_name(self) -> &'static str {
        match self {
            BackendKind::Fsim => "fsim",
            BackendKind::Tsim => "tsim",
            BackendKind::TsimTiming => "timing",
            BackendKind::Analytical => "model",
        }
    }

    /// Where this backend sits on the ladder.
    pub fn fidelity(self) -> Fidelity {
        match self {
            BackendKind::Fsim => Fidelity::Functional,
            BackendKind::Tsim => Fidelity::CycleAccurate,
            BackendKind::TsimTiming => Fidelity::TimingOnly,
            BackendKind::Analytical => Fidelity::Analytical,
        }
    }

    /// Build the live backend for this kind.
    pub fn instantiate(self) -> Box<dyn Backend> {
        match self {
            BackendKind::Fsim => Box::new(FsimBackend),
            BackendKind::Tsim => Box::new(TsimBackend::functional()),
            BackendKind::TsimTiming => Box::new(TsimBackend::timing_only()),
            BackendKind::Analytical => Box::new(AnalyticalBackend::new()),
        }
    }
}

impl Default for BackendKind {
    /// Cycle-accurate functional tsim — the historical default target.
    fn default() -> BackendKind {
        BackendKind::Tsim
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.cli_name())
    }
}

/// Session tuning knobs shared by every simulating backend; orthogonal
/// to the fidelity choice (they select *which* program is compiled and
/// whether activity is traced, not how faithfully it runs).
#[derive(Debug, Clone)]
pub struct Tuning {
    /// Record per-cycle activity intervals (Figs 3/4).
    pub trace: bool,
    /// TPS-optimized tilings; `false` uses the fallback schedule.
    pub tps: bool,
    /// Improved double buffering (eliminate redundant input loads).
    pub dbuf_reuse: bool,
    /// Cross-layer scratchpad residency heuristic (DESIGN.md §Residency
    /// planner). Purely a timing/counter optimization: outputs are
    /// bit-identical at every setting.
    pub residency: crate::compiler::residency::ResidencyMode,
}

impl Default for Tuning {
    fn default() -> Tuning {
        Tuning {
            trace: false,
            tps: true,
            dbuf_reuse: true,
            residency: crate::compiler::residency::ResidencyMode::default(),
        }
    }
}

/// How the evaluation's input activation is supplied.
#[derive(Debug, Clone)]
pub enum InputSpec {
    /// Explicit `[batch][c][h][w]` int8 data; the length must match the
    /// prepared graph or the evaluation fails with
    /// [`VtaError::InvalidRequest`].
    Data(Vec<i8>),
    /// Seeded random data (`Pcg32`), materialized only by backends that
    /// actually read tensors — timing-only and analytical evaluations
    /// never pay for input generation.
    Seeded(u64),
}

/// One evaluation request against a prepared `(config, graph)` pair.
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub input: InputSpec,
}

impl EvalRequest {
    /// Evaluate with explicit input data.
    pub fn with_data(data: Vec<i8>) -> EvalRequest {
        EvalRequest { input: InputSpec::Data(data) }
    }

    /// Evaluate with seeded random input (the sweep's convention: the
    /// seed is part of the design point's identity).
    pub fn seeded(seed: u64) -> EvalRequest {
        EvalRequest { input: InputSpec::Seeded(seed) }
    }
}

/// A `(config, graph)` pair validated and bound for evaluation by
/// [`Backend::prepare`]. Holds everything an [`Backend::eval`] call
/// needs; build once, evaluate many times.
pub struct Prepared<'g> {
    pub cfg: VtaConfig,
    pub graph: &'g Graph,
    pub tuning: Tuning,
    /// Shared layer memo injected by [`MemoBackend`] (`None` otherwise).
    pub memo: Option<Arc<LayerMemo>>,
    /// Per-node output shapes, computed once during validation
    /// ([`prepare_common`]) so repeated evaluations (and every clone of
    /// a [`PreparedShared`]) never re-run shape propagation.
    pub shapes: Arc<Vec<Shape>>,
}

/// Everything one evaluation produced. Fields gated by the backend's
/// [`Capabilities`] are `Option`/empty rather than garbage.
#[derive(Debug)]
pub struct Evaluation {
    /// Rung of the backend that produced this evaluation.
    pub fidelity: Fidelity,
    /// Name of the producing backend (diagnostics).
    pub backend: &'static str,
    /// Cycle count: tsim-measured at `TimingOnly`/`CycleAccurate`
    /// fidelity, model-predicted at `Analytical`, `None` from fsim.
    pub cycles: Option<u64>,
    /// Final network output, `[batch][c][h][w]` int8 (`None` when the
    /// backend does not compute tensors).
    pub output: Option<Vec<i8>>,
    /// Execution counters (zeroed at `Analytical` fidelity, which runs
    /// nothing).
    pub counters: ExecCounters,
    /// Per-layer breakdown (cycle-only at `Analytical` fidelity).
    pub layer_stats: Vec<LayerStat>,
    /// Per-module performance report (tsim backends only).
    pub report: Option<PerfReport>,
    /// Activity trace, when [`Tuning::trace`] was set on a tsim backend.
    pub trace: Option<ActivityTrace>,
}

/// An evaluation strategy at a declared fidelity. Implementations are
/// stateless or internally synchronized (`Send + Sync`): one backend
/// instance may serve many engines and threads.
pub trait Backend: Send + Sync {
    /// Short stable name (CLI/report label).
    fn name(&self) -> &'static str;

    /// Rung on the [`Fidelity`] ladder.
    fn fidelity(&self) -> Fidelity;

    /// What this backend produces and supports.
    fn capabilities(&self) -> Capabilities;

    /// Validate `(cfg, graph)` and bind them for evaluation. The default
    /// performs the shared checks ([`prepare_common`]); backends with
    /// extra constraints override and extend.
    fn prepare<'g>(
        &self,
        cfg: &VtaConfig,
        graph: &'g Graph,
        tuning: &Tuning,
    ) -> Result<Prepared<'g>, VtaError> {
        prepare_common(cfg, graph, tuning)
    }

    /// Evaluate one request against a prepared pair.
    fn eval(&self, prepared: &Prepared<'_>, request: &EvalRequest) -> Result<Evaluation, VtaError>;

    /// Evaluate a batch of requests against one prepared pair. The
    /// default is the per-request loop; simulating backends override it
    /// to reuse one session across the batch (validation, DRAM arena and
    /// scratchpad setup paid once). Results are bit-identical to calling
    /// [`Backend::eval`] once per request, in order; the first failing
    /// request fails the whole batch (requests against one prepared pair
    /// share their validity).
    fn eval_many(
        &self,
        prepared: &Prepared<'_>,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        requests.iter().map(|r| self.eval(prepared, r)).collect()
    }

    /// The shared layer memo this backend injects at prepare time
    /// (`Some` only for [`MemoBackend`]). Lets shape-reusing prepare
    /// paths ([`Engine::prepare_shared_with_shapes`]) attach the memo
    /// without re-running the graph-structural half of
    /// [`Backend::prepare`].
    fn layer_memo(&self) -> Option<Arc<LayerMemo>> {
        None
    }
}

/// The shared half of [`Backend::prepare`]: configuration validity, the
/// square-block constraint of graph execution, and graph structure.
/// Shape propagation *is* the structural validation
/// ([`Graph::try_shapes`]), so the shapes it produces are kept in the
/// [`Prepared`] instead of being recomputed per evaluation.
pub fn prepare_common<'g>(
    cfg: &VtaConfig,
    graph: &'g Graph,
    tuning: &Tuning,
) -> Result<Prepared<'g>, VtaError> {
    check_exec_config(cfg)?;
    let shapes = graph.try_shapes().map_err(VtaError::Graph)?;
    Ok(Prepared {
        cfg: cfg.clone(),
        graph,
        tuning: tuning.clone(),
        memo: None,
        shapes: Arc::new(shapes),
    })
}

/// The config-only half of [`prepare_common`]: configuration validity
/// plus the square-block constraint of graph execution. Factored out so
/// shape-reusing prepare paths run exactly the same checks.
fn check_exec_config(cfg: &VtaConfig) -> Result<(), VtaError> {
    cfg.validate()?;
    if cfg.block_in != cfg.block_out {
        return Err(VtaError::Unsupported(format!(
            "network execution requires BLOCK_IN == BLOCK_OUT (activation tiles feed both \
             GEMM operands); got {}x{}",
            cfg.block_in, cfg.block_out
        )));
    }
    Ok(())
}

/// An owned, shareable [`Prepared`]: the `(config, graph)` pair bound
/// by [`Engine::prepare_shared`], holding the graph behind an `Arc`
/// instead of a borrow so it can outlive the call site, cross threads,
/// and serve many concurrent evaluations. This is the warm artifact the
/// serving runtime's session pool keeps per
/// `(config, workload, backend)` key: validation, shape propagation and
/// memo injection happened once at prepare time, so each request pays
/// only for its own evaluation ([`Engine::eval_shared`]).
pub struct PreparedShared {
    cfg: VtaConfig,
    graph: Arc<Graph>,
    tuning: Tuning,
    memo: Option<Arc<LayerMemo>>,
    shapes: Arc<Vec<Shape>>,
}

impl PreparedShared {
    pub fn cfg(&self) -> &VtaConfig {
        &self.cfg
    }

    pub fn graph(&self) -> &Arc<Graph> {
        &self.graph
    }

    /// Per-node output shapes (computed once at prepare time).
    pub fn shapes(&self) -> &Arc<Vec<Shape>> {
        &self.shapes
    }

    /// View as a borrow-based [`Prepared`] for one [`Backend::eval`]
    /// call. Cheap: the config and tuning are small plain data, the
    /// graph/shapes/memo are `Arc` bumps.
    pub fn as_prepared(&self) -> Prepared<'_> {
        Prepared {
            cfg: self.cfg.clone(),
            graph: &self.graph,
            tuning: self.tuning.clone(),
            memo: self.memo.clone(),
            shapes: self.shapes.clone(),
        }
    }
}

/// The evaluation front door: one configuration, one backend, the memo
/// and tuning plumbing owned in one place. Build with
/// [`Engine::for_config`]; evaluate with [`Engine::run`] (or
/// [`Engine::prepare`] + [`Engine::eval`] to amortize validation over
/// many requests against the same graph — [`Engine::prepare_shared`]
/// for the owned, thread-crossing variant).
pub struct Engine {
    cfg: VtaConfig,
    backend: Box<dyn Backend>,
    tuning: Tuning,
}

impl Engine {
    /// Start building an engine bound to `cfg`.
    pub fn for_config(cfg: &VtaConfig) -> EngineBuilder {
        EngineBuilder { cfg: cfg.clone(), backend: None, memo: None, tuning: Tuning::default() }
    }

    pub fn cfg(&self) -> &VtaConfig {
        &self.cfg
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn fidelity(&self) -> Fidelity {
        self.backend.fidelity()
    }

    pub fn capabilities(&self) -> Capabilities {
        self.backend.capabilities()
    }

    /// Validate and bind a graph for repeated evaluation.
    pub fn prepare<'g>(&self, graph: &'g Graph) -> Result<Prepared<'g>, VtaError> {
        self.backend.prepare(&self.cfg, graph, &self.tuning)
    }

    /// [`Engine::prepare`] with shared ownership: validate once, then
    /// evaluate the returned [`PreparedShared`] any number of times —
    /// from any thread — via [`Engine::eval_shared`]. The serving
    /// runtime keeps these warm in its session pool.
    pub fn prepare_shared(&self, graph: Arc<Graph>) -> Result<PreparedShared, VtaError> {
        let prepared = self.backend.prepare(&self.cfg, &graph, &self.tuning)?;
        let (cfg, tuning, memo, shapes) =
            (prepared.cfg, prepared.tuning, prepared.memo, prepared.shapes);
        Ok(PreparedShared { cfg, graph, tuning, memo, shapes })
    }

    /// [`Engine::prepare_shared`] for callers that already ran the
    /// graph-structural pass: reuses precomputed per-node `shapes`
    /// instead of re-propagating them. Shapes depend only on the graph
    /// — never on the config — so a serving fleet shares one graph
    /// build + shape pass across N device configs and pays only the
    /// config-level checks per device. The memo this engine's backend
    /// would inject at prepare time is attached exactly as
    /// [`Engine::prepare_shared`] would ([`Backend::layer_memo`]).
    pub fn prepare_shared_with_shapes(
        &self,
        graph: Arc<Graph>,
        shapes: Arc<Vec<Shape>>,
    ) -> Result<PreparedShared, VtaError> {
        check_exec_config(&self.cfg)?;
        if shapes.len() != graph.nodes.len() {
            return Err(VtaError::Graph(format!(
                "shape vector holds {} entries for a {}-node graph (stale shapes?)",
                shapes.len(),
                graph.nodes.len()
            )));
        }
        Ok(PreparedShared {
            cfg: self.cfg.clone(),
            graph,
            tuning: self.tuning.clone(),
            memo: self.backend.layer_memo(),
            shapes,
        })
    }

    /// Evaluate one request against a shared prepared graph.
    pub fn eval_shared(
        &self,
        prepared: &PreparedShared,
        request: &EvalRequest,
    ) -> Result<Evaluation, VtaError> {
        self.backend.eval(&prepared.as_prepared(), request)
    }

    /// Evaluate one request against a prepared graph.
    pub fn eval(
        &self,
        prepared: &Prepared<'_>,
        request: &EvalRequest,
    ) -> Result<Evaluation, VtaError> {
        self.backend.eval(prepared, request)
    }

    /// Evaluate a batch of requests against one prepared graph,
    /// amortizing session setup across the batch (see
    /// [`Backend::eval_many`]). Results are bit-identical to calling
    /// [`Engine::eval`] once per request, in order.
    pub fn eval_many(
        &self,
        prepared: &Prepared<'_>,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        self.backend.eval_many(prepared, requests)
    }

    /// [`Engine::eval_many`] against a shared prepared graph — the
    /// batched request path of the serving runtime.
    pub fn eval_many_shared(
        &self,
        prepared: &PreparedShared,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        self.backend.eval_many(&prepared.as_prepared(), requests)
    }

    /// Prepare + evaluate in one call (the common single-shot path).
    pub fn run(&self, graph: &Graph, request: &EvalRequest) -> Result<Evaluation, VtaError> {
        self.eval(&self.prepare(graph)?, request)
    }
}

/// Builder for [`Engine`]; see [`Engine::for_config`].
pub struct EngineBuilder {
    cfg: VtaConfig,
    backend: Option<Box<dyn Backend>>,
    memo: Option<Arc<LayerMemo>>,
    tuning: Tuning,
}

impl EngineBuilder {
    /// Select a custom backend (replaces any earlier selection).
    pub fn backend(mut self, backend: impl Backend + 'static) -> EngineBuilder {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Select a built-in backend by kind.
    pub fn backend_kind(mut self, kind: BackendKind) -> EngineBuilder {
        self.backend = Some(kind.instantiate());
        self
    }

    /// Share a layer memo across evaluations: the backend is wrapped in
    /// [`MemoBackend`] at [`EngineBuilder::build`]. Fails the build if
    /// the backend does not support memoization.
    pub fn memo(mut self, memo: Arc<LayerMemo>) -> EngineBuilder {
        self.memo = Some(memo);
        self
    }

    /// Record per-cycle activity intervals (tsim backends).
    pub fn trace(mut self, on: bool) -> EngineBuilder {
        self.tuning.trace = on;
        self
    }

    /// TPS-optimized tilings (`false` = fallback schedule).
    pub fn tps(mut self, on: bool) -> EngineBuilder {
        self.tuning.tps = on;
        self
    }

    /// Improved double buffering (`false` = original TVM behaviour).
    pub fn dbuf_reuse(mut self, on: bool) -> EngineBuilder {
        self.tuning.dbuf_reuse = on;
        self
    }

    /// Cross-layer scratchpad residency heuristic (default LRU).
    pub fn residency(mut self, mode: crate::compiler::residency::ResidencyMode) -> EngineBuilder {
        self.tuning.residency = mode;
        self
    }

    /// Validate the configuration and capability choices; returns the
    /// ready engine. The default backend (when none was selected) is
    /// cycle-accurate functional tsim.
    pub fn build(self) -> Result<Engine, VtaError> {
        self.cfg.validate()?;
        let mut backend = self.backend.unwrap_or_else(|| BackendKind::default().instantiate());
        if let Some(memo) = self.memo {
            if !backend.capabilities().supports_memo {
                return Err(VtaError::Unsupported(format!(
                    "backend '{}' does not support the layer memo",
                    backend.name()
                )));
            }
            if self.tuning.trace {
                return Err(VtaError::Unsupported(
                    "activity tracing requires unmemoized simulation (memo hits record no \
                     activity intervals)"
                        .into(),
                ));
            }
            backend = Box::new(MemoBackend::new(backend, memo));
        }
        Ok(Engine { cfg: self.cfg, backend, tuning: self.tuning })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workloads;

    #[test]
    fn fidelity_ladder_is_ordered() {
        assert!(Fidelity::Analytical < Fidelity::TimingOnly);
        assert!(Fidelity::TimingOnly < Fidelity::CycleAccurate);
        assert!(Fidelity::CycleAccurate < Fidelity::Functional);
        let mut sorted = Fidelity::LADDER;
        sorted.sort();
        assert_eq!(sorted, Fidelity::LADDER, "LADDER lists rungs in order");
    }

    #[test]
    fn backend_kind_parse_roundtrips_and_rejects() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.cli_name()).unwrap(), kind);
        }
        assert_eq!(BackendKind::parse("timing-only").unwrap(), BackendKind::TsimTiming);
        assert_eq!(BackendKind::parse("analytical").unwrap(), BackendKind::Analytical);
        assert!(matches!(BackendKind::parse("rtl"), Err(VtaError::InvalidRequest(_))));
    }

    #[test]
    fn kinds_declare_coherent_capabilities() {
        for kind in BackendKind::ALL {
            let b = kind.instantiate();
            assert_eq!(b.fidelity(), kind.fidelity());
            let caps = b.capabilities();
            // Only the simulating-with-datapath rungs produce outputs.
            assert_eq!(
                caps.produces_outputs,
                matches!(kind, BackendKind::Fsim | BackendKind::Tsim)
            );
            assert_eq!(caps.produces_cycles, kind != BackendKind::Fsim);
            assert_eq!(
                caps.supports_memo,
                matches!(kind, BackendKind::Tsim | BackendKind::TsimTiming)
            );
        }
    }

    #[test]
    fn build_rejects_invalid_config() {
        let mut cfg = presets::tiny_config();
        cfg.axi_bytes = 3;
        assert!(matches!(Engine::for_config(&cfg).build(), Err(VtaError::Config(_))));
    }

    #[test]
    fn build_rejects_memo_on_memoless_backends() {
        let cfg = presets::tiny_config();
        let memo = Arc::new(LayerMemo::in_memory());
        for kind in [BackendKind::Fsim, BackendKind::Analytical] {
            let result = Engine::for_config(&cfg).backend_kind(kind).memo(memo.clone()).build();
            let err = match result {
                Ok(_) => panic!("memo-less backend {kind} must reject the memo"),
                Err(e) => e,
            };
            assert!(matches!(err, VtaError::Unsupported(_)));
        }
    }

    #[test]
    fn build_rejects_trace_plus_memo() {
        let cfg = presets::tiny_config();
        let memo = Arc::new(LayerMemo::in_memory());
        assert!(matches!(
            Engine::for_config(&cfg).memo(memo).trace(true).build(),
            Err(VtaError::Unsupported(_))
        ));
    }

    #[test]
    fn prepare_rejects_non_square_blocks() {
        let mut cfg = presets::tiny_config();
        cfg.block_out = cfg.block_in * 2;
        let graph = workloads::micro_resnet(cfg.block_in, 1);
        let engine = Engine::for_config(&cfg).build().unwrap();
        assert!(matches!(engine.prepare(&graph), Err(VtaError::Unsupported(_))));
    }

    #[test]
    fn prepare_shared_is_rerunnable_and_thread_crossing() {
        let cfg = presets::tiny_config();
        let graph = Arc::new(workloads::micro_resnet(cfg.block_in, 1));
        let engine =
            Engine::for_config(&cfg).backend_kind(BackendKind::TsimTiming).build().unwrap();
        let shared = engine.prepare_shared(graph.clone()).unwrap();
        assert_eq!(shared.shapes().len(), graph.nodes.len());
        let a = engine.eval_shared(&shared, &EvalRequest::seeded(7)).unwrap();
        let b = engine.eval_shared(&shared, &EvalRequest::seeded(7)).unwrap();
        assert_eq!(a.cycles, b.cycles, "shared prepared must be re-runnable");
        // Cross a thread boundary: PreparedShared owns its graph.
        let cycles = std::thread::scope(|s| {
            s.spawn(|| {
                engine.eval_shared(&shared, &EvalRequest::seeded(7)).unwrap().cycles
            })
            .join()
            .unwrap()
        });
        assert_eq!(cycles, a.cycles);
    }

    #[test]
    fn prepare_shared_rejects_bad_graphs() {
        let cfg = presets::tiny_config();
        let engine = Engine::for_config(&cfg).build().unwrap();
        let mut bad = crate::compiler::graph::Graph::new(
            "bad",
            crate::compiler::layout::Shape::new(cfg.block_in, 4, 4),
        );
        bad.add("add", crate::compiler::graph::Op::Add { relu: false }, vec![0]);
        assert!(matches!(engine.prepare_shared(Arc::new(bad)), Err(VtaError::Graph(_))));
    }

    #[test]
    fn run_rejects_wrong_input_length() {
        let cfg = presets::tiny_config();
        let graph = workloads::micro_resnet(cfg.block_in, 1);
        let engine = Engine::for_config(&cfg).build().unwrap();
        let err = engine.run(&graph, &EvalRequest::with_data(vec![0; 3])).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)));
    }
}
