//! The built-in [`Backend`] implementations: the two simulators (each
//! tsim mode is its own backend instance — no mode flags), the
//! analytical model, and the memo wrapper.

use super::{
    Backend, BackendKind, Capabilities, EvalRequest, Evaluation, Fidelity, InputSpec, Prepared,
    Tuning, VtaError,
};
use crate::compiler::graph::Graph;
use crate::config::VtaConfig;
use crate::exec::ExecCounters;
use crate::memo::LayerMemo;
use crate::model;
use crate::runtime::{LayerStat, Session, SessionOptions};
use crate::util::rng::Pcg32;
use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Resolve a request's input against the prepared graph. Explicit data
/// is always length-checked (catching client bugs even on backends that
/// never read it); seeded input is materialized only when the backend
/// actually consumes tensors.
fn resolve_input<'r>(
    prepared: &Prepared<'_>,
    request: &'r EvalRequest,
    wants_data: bool,
) -> Result<Cow<'r, [i8]>, VtaError> {
    let want = prepared.cfg.batch * prepared.graph.input_shape.elems();
    match &request.input {
        InputSpec::Data(data) => {
            if data.len() != want {
                return Err(VtaError::InvalidRequest(format!(
                    "input holds {} values but batch {} x input shape {:?} needs {}",
                    data.len(),
                    prepared.cfg.batch,
                    prepared.graph.input_shape,
                    want
                )));
            }
            Ok(Cow::Borrowed(&data[..]))
        }
        InputSpec::Seeded(seed) => {
            if wants_data {
                Ok(Cow::Owned(Pcg32::seeded(*seed).i8_vec(want)))
            } else {
                Ok(Cow::Borrowed(&[][..]))
            }
        }
    }
}

/// Build the [`Session`] a simulating backend evaluates on.
fn sim_session(kind: BackendKind, prepared: &Prepared<'_>) -> Result<Session, VtaError> {
    let opts = SessionOptions {
        backend: kind,
        trace: prepared.tuning.trace,
        tps: prepared.tuning.tps,
        dbuf_reuse: prepared.tuning.dbuf_reuse,
        residency: prepared.tuning.residency,
        memo: prepared.memo.clone(),
    };
    Session::new(&prepared.cfg, opts)
}

/// Evaluate one request on an existing session (which must be fresh or
/// freshly [`Session::reset_for_reuse`]d) and collect its products into
/// an [`Evaluation`].
fn sim_eval_with_session(
    kind: BackendKind,
    name: &'static str,
    prepared: &Prepared<'_>,
    request: &EvalRequest,
    session: &mut Session,
) -> Result<Evaluation, VtaError> {
    let input = resolve_input(prepared, request, kind != BackendKind::TsimTiming)?;
    // Shapes were computed (= the graph validated) at prepare time, so
    // repeated evaluations of one Prepared skip shape propagation.
    let output = session.run_graph_shaped(prepared.graph, &prepared.shapes, &input)?;
    Ok(Evaluation {
        fidelity: kind.fidelity(),
        backend: name,
        cycles: (kind != BackendKind::Fsim).then(|| session.cycles()),
        output: (kind != BackendKind::TsimTiming).then_some(output),
        counters: session.exec_counters(),
        report: session.perf_report(),
        trace: session.take_trace(),
        layer_stats: std::mem::take(&mut session.layer_stats),
    })
}

/// Shared simulator evaluation: drive a [`Session`] on the chosen
/// simulator and collect its products into an [`Evaluation`].
fn sim_eval(
    kind: BackendKind,
    name: &'static str,
    prepared: &Prepared<'_>,
    request: &EvalRequest,
) -> Result<Evaluation, VtaError> {
    let mut session = sim_session(kind, prepared)?;
    sim_eval_with_session(kind, name, prepared, request, &mut session)
}

/// Batched simulator evaluation: one session serves the whole batch,
/// [`Session::reset_for_reuse`]d between requests, so session
/// construction (a 256 MiB DRAM arena, scratchpad allocation, queue
/// setup) is paid once instead of per request. The reset restores
/// bit-identical fresh-session state, so every [`Evaluation`] equals
/// what [`sim_eval`] would have produced for the same request
/// (`rust/tests/backend_parity.rs::eval_many_matches_per_request_eval`).
fn sim_eval_many(
    kind: BackendKind,
    name: &'static str,
    prepared: &Prepared<'_>,
    requests: &[EvalRequest],
) -> Result<Vec<Evaluation>, VtaError> {
    let mut session = sim_session(kind, prepared)?;
    let mut out = Vec::with_capacity(requests.len());
    for (i, request) in requests.iter().enumerate() {
        if i > 0 {
            session.reset_for_reuse();
        }
        out.push(sim_eval_with_session(kind, name, prepared, request, &mut session)?);
    }
    Ok(out)
}

/// Behavioral simulation: exact tensors, no timing model. The top of
/// the fidelity ladder — the reference every other backend's outputs
/// are validated against.
#[derive(Debug, Clone, Copy, Default)]
pub struct FsimBackend;

impl Backend for FsimBackend {
    fn name(&self) -> &'static str {
        "fsim"
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::Functional
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { produces_outputs: true, produces_cycles: false, supports_memo: false }
    }

    fn eval(&self, prepared: &Prepared<'_>, request: &EvalRequest) -> Result<Evaluation, VtaError> {
        sim_eval(BackendKind::Fsim, self.name(), prepared, request)
    }

    fn eval_many(
        &self,
        prepared: &Prepared<'_>,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        sim_eval_many(BackendKind::Fsim, self.name(), prepared, requests)
    }
}

/// Cycle-accurate simulation. The two tsim modes are two backend
/// *instances* of this type — functional (full datapath, exact outputs)
/// and timing-only (identical cycles and counters, datapath skipped) —
/// rather than a runtime flag, so the fidelity choice is visible in the
/// type of the evaluation pipeline.
#[derive(Debug, Clone, Copy)]
pub struct TsimBackend {
    timing_only: bool,
}

impl TsimBackend {
    /// Full cycle-accurate simulation ([`Fidelity::CycleAccurate`]).
    pub fn functional() -> TsimBackend {
        TsimBackend { timing_only: false }
    }

    /// Timing-only simulation ([`Fidelity::TimingOnly`]): the timing
    /// wheel runs exactly as in functional mode — cycles, per-layer
    /// stats and execution counters are bit-identical — but all
    /// datapath effects (and the input staging that feeds them) are
    /// skipped, so no outputs are produced.
    pub fn timing_only() -> TsimBackend {
        TsimBackend { timing_only: true }
    }

    fn kind(&self) -> BackendKind {
        if self.timing_only {
            BackendKind::TsimTiming
        } else {
            BackendKind::Tsim
        }
    }
}

impl Backend for TsimBackend {
    fn name(&self) -> &'static str {
        if self.timing_only { "timing" } else { "tsim" }
    }

    fn fidelity(&self) -> Fidelity {
        self.kind().fidelity()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            produces_outputs: !self.timing_only,
            produces_cycles: true,
            supports_memo: true,
        }
    }

    fn eval(&self, prepared: &Prepared<'_>, request: &EvalRequest) -> Result<Evaluation, VtaError> {
        sim_eval(self.kind(), self.name(), prepared, request)
    }

    fn eval_many(
        &self,
        prepared: &Prepared<'_>,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        sim_eval_many(self.kind(), self.name(), prepared, requests)
    }
}

/// Per-layer prediction cache shared between [`AnalyticalBackend`]
/// instances: layer-memo signature → predicted cycles. The signature
/// hashes the configuration's perf fields, so one cache safely spans a
/// whole design-space grid (the two-phase sweep shares one across every
/// phase-1 engine).
pub type PredictionCache = Arc<Mutex<HashMap<u64, u64>>>;

/// The analytical cycle model as a backend: closed-form per-layer
/// estimates, microseconds per network, no compilation or simulation.
/// Cycle counts are *predictions* ([`Fidelity::Analytical`]) — never
/// mix them with measured results (the sweep keeps them out of its
/// on-disk cache and flags them via `PointResult::measured`).
pub struct AnalyticalBackend {
    cache: PredictionCache,
}

impl AnalyticalBackend {
    pub fn new() -> AnalyticalBackend {
        AnalyticalBackend { cache: PredictionCache::default() }
    }

    /// Share a prediction cache with other engines (one estimate per
    /// unique `(config, layer)` across a whole grid).
    pub fn with_cache(cache: PredictionCache) -> AnalyticalBackend {
        AnalyticalBackend { cache }
    }

    /// Handle to this backend's prediction cache.
    pub fn cache(&self) -> PredictionCache {
        self.cache.clone()
    }
}

impl Default for AnalyticalBackend {
    fn default() -> AnalyticalBackend {
        AnalyticalBackend::new()
    }
}

impl Backend for AnalyticalBackend {
    fn name(&self) -> &'static str {
        "model"
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::Analytical
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { produces_outputs: false, produces_cycles: true, supports_memo: false }
    }

    fn eval(&self, prepared: &Prepared<'_>, request: &EvalRequest) -> Result<Evaluation, VtaError> {
        // Input is never read, but explicit data is still validated so a
        // malformed request fails identically at every fidelity.
        resolve_input(prepared, request, false)?;
        let mut cache = self.cache.lock().unwrap();
        // Same residency mode as the simulating backends, and the same
        // typed rejection of infeasible configurations — phase 1 of the
        // sweep screens grid points through this path.
        let prediction = model::try_predict_graph_cached(
            &prepared.cfg,
            prepared.graph,
            prepared.tuning.residency,
            &mut cache,
        )
        .map_err(VtaError::Config)?;
        drop(cache);
        let layer_stats = prediction
            .layers
            .iter()
            .map(|l| LayerStat {
                name: format!("{}:{}", prepared.graph.name, l.name),
                kind: l.kind,
                cycles: l.cycles,
                insns: 0,
                uops: 0,
                macs: 0,
                dram_rd: 0,
                dram_wr: 0,
                on_cpu: false,
            })
            .collect();
        Ok(Evaluation {
            fidelity: Fidelity::Analytical,
            backend: self.name(),
            cycles: Some(prediction.cycles),
            output: None,
            counters: ExecCounters::default(),
            layer_stats,
            report: None,
            trace: None,
        })
    }
}

/// Memo-replay as a wrapper backend: injects a shared [`LayerMemo`]
/// into the inner backend's prepared state, so memo hits splice cached
/// per-layer results (timing-only) or replay programs through the exec
/// core (functional) instead of re-simulating. Compose via
/// [`EngineBuilder::memo`](super::EngineBuilder::memo); results are
/// bit-identical with or without the wrapper.
pub struct MemoBackend {
    inner: Box<dyn Backend>,
    memo: Arc<LayerMemo>,
}

impl MemoBackend {
    pub fn new(inner: Box<dyn Backend>, memo: Arc<LayerMemo>) -> MemoBackend {
        MemoBackend { inner, memo }
    }

    pub fn memo(&self) -> &Arc<LayerMemo> {
        &self.memo
    }
}

impl Backend for MemoBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn fidelity(&self) -> Fidelity {
        self.inner.fidelity()
    }

    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities()
    }

    fn prepare<'g>(
        &self,
        cfg: &VtaConfig,
        graph: &'g Graph,
        tuning: &Tuning,
    ) -> Result<Prepared<'g>, VtaError> {
        if !self.inner.capabilities().supports_memo {
            return Err(VtaError::Unsupported(format!(
                "backend '{}' does not support the layer memo",
                self.inner.name()
            )));
        }
        let mut prepared = self.inner.prepare(cfg, graph, tuning)?;
        prepared.memo = Some(self.memo.clone());
        Ok(prepared)
    }

    fn eval(&self, prepared: &Prepared<'_>, request: &EvalRequest) -> Result<Evaluation, VtaError> {
        self.inner.eval(prepared, request)
    }

    fn eval_many(
        &self,
        prepared: &Prepared<'_>,
        requests: &[EvalRequest],
    ) -> Result<Vec<Evaluation>, VtaError> {
        self.inner.eval_many(prepared, requests)
    }

    fn layer_memo(&self) -> Option<Arc<LayerMemo>> {
        Some(self.memo.clone())
    }
}

// Backend evaluations need a graph + config; keep the unit tests here
// lightweight (trait wiring) and the cross-backend parity invariants in
// `rust/tests/backend_parity.rs`.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::workloads;

    #[test]
    fn analytical_matches_predict_graph() {
        let cfg = presets::tiny_config();
        let graph = workloads::micro_resnet(cfg.block_in, 42);
        let engine = Engine::for_config(&cfg).backend(AnalyticalBackend::new()).build().unwrap();
        let eval = engine.run(&graph, &EvalRequest::seeded(7)).unwrap();
        let direct = model::predict_graph(&cfg, &graph);
        assert_eq!(eval.cycles, Some(direct.cycles));
        assert_eq!(eval.layer_stats.len(), direct.layers.len());
        assert!(eval.output.is_none());
        assert_eq!(eval.counters, ExecCounters::default());
    }

    #[test]
    fn analytical_prediction_cache_is_shared() {
        let cfg = presets::tiny_config();
        let graph = workloads::micro_resnet(cfg.block_in, 42);
        let shared = PredictionCache::default();
        let first = AnalyticalBackend::with_cache(shared.clone());
        let engine = Engine::for_config(&cfg).backend(first).build().unwrap();
        engine.run(&graph, &EvalRequest::seeded(7)).unwrap();
        let filled = shared.lock().unwrap().len();
        assert!(filled > 0, "predictions must land in the shared cache");
        let second = AnalyticalBackend::with_cache(shared.clone());
        let engine2 = Engine::for_config(&cfg).backend(second).build().unwrap();
        engine2.run(&graph, &EvalRequest::seeded(8)).unwrap();
        assert_eq!(shared.lock().unwrap().len(), filled, "same layers, no new entries");
    }

    #[test]
    fn memo_wrapper_reports_inner_identity() {
        let memo = Arc::new(LayerMemo::in_memory());
        let wrapped = MemoBackend::new(Box::new(TsimBackend::timing_only()), memo);
        assert_eq!(wrapped.name(), "timing");
        assert_eq!(wrapped.fidelity(), Fidelity::TimingOnly);
        assert!(wrapped.capabilities().supports_memo);
    }
}
