//! VTA hardware configuration (§II, §IV-F).
//!
//! A single JSON file drives the compiler, runtime, and both simulator
//! targets — exactly the paper's "JSON configuration file is the only
//! compile-time construct consumed by the compiler, runtime, as well as
//! all hardware targets". This module owns:
//!
//! * the fundamental parameters (BATCH / BLOCK_IN / BLOCK_OUT, scratchpad
//!   depths, AXI memory-interface width, pipelining flags),
//! * the *derived* ISA field widths ([`IsaLayout`]), including the paper's
//!   shrink-to-fit policy for keeping instructions at 128 bits
//!   ("After exhausting available spare bits, we resorted to shrinking
//!   other field widths"),
//! * compile-time-style validation ([`VtaConfig::validate`]).

pub mod presets;

use crate::util::bitfield::addr_bits;
use crate::util::json::Json;
use std::fmt;

/// Instruction width is a fixed architectural constant (§II-B: "we
/// retained the 128-bit width as a constant").
pub const INSN_BITS: u32 = 128;
pub const INSN_BYTES: usize = 16;

/// Dependency-flag bit count (pop_prev, pop_next, push_prev, push_next).
pub const DEP_BITS: u32 = 4;
pub const OPCODE_BITS: u32 = 3;

/// Data type widths — VTA is an int8 inference machine with int32
/// accumulation; these are architectural, not configurable.
pub const INP_DTYPE_BITS: usize = 8;
pub const WGT_DTYPE_BITS: usize = 8;
pub const ACC_DTYPE_BITS: usize = 32;
pub const OUT_DTYPE_BITS: usize = 8;

/// GEMM accumulation precision (the representation-adaptive axis): the
/// hardware either carries the full 32-bit accumulator or a narrow
/// 16-bit one that wraps per MAC-tile update. Narrow costs accuracy on
/// deep reductions but prices cheaper in [`crate::analysis::area`] —
/// a sweepable area/fidelity tradeoff in the style of
/// representation-adaptive ISAs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// 16-bit accumulation: each GEMM tile update wraps to i16.
    Narrow,
    /// Full 32-bit accumulation (the classic VTA datapath).
    #[default]
    Wide,
}

impl Precision {
    pub fn cli_name(self) -> &'static str {
        match self {
            Precision::Narrow => "narrow",
            Precision::Wide => "wide",
        }
    }

    pub fn parse(s: &str) -> Result<Precision, String> {
        match s {
            "narrow" => Ok(Precision::Narrow),
            "wide" => Ok(Precision::Wide),
            other => Err(format!("unknown precision '{other}' (expected narrow|wide)")),
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.cli_name())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct VtaConfig {
    /// Configuration name (used in reports and artifact paths).
    pub name: String,
    /// GEMM tile batch dimension (rows of the input tile).
    pub batch: usize,
    /// GEMM tile reduction dimension (input channels per tile).
    pub block_in: usize,
    /// GEMM tile output dimension (output channels per tile).
    pub block_out: usize,
    /// Micro-op buffer depth (number of uops).
    pub uop_depth: usize,
    /// Input scratchpad depth in tiles of `batch x block_in` int8.
    pub inp_depth: usize,
    /// Weight scratchpad depth in tiles of `block_out x block_in` int8.
    pub wgt_depth: usize,
    /// Accumulator scratchpad depth in tiles of `batch x block_out` int32.
    /// The 8-bit OUT scratchpad mirrors this depth (store path).
    pub acc_depth: usize,
    /// AXI memory interface width in bytes/cycle (8..=64 per the paper).
    pub axi_bytes: usize,
    /// DRAM request latency in cycles (first data beat after request).
    pub dram_latency: u64,
    /// Maximum outstanding VME requests (Fig 6 tag buffer size).
    pub vme_inflight: usize,
    /// Fully pipelined GEMM core (II=1) vs original II=4 (§IV-A1).
    pub gemm_pipelined: bool,
    /// Fully pipelined ALU (II=1 imm / II=2 two-operand) vs original
    /// II=4/5 (§IV-A2).
    pub alu_pipelined: bool,
    /// Command-queue depth between fetch and the execution modules.
    pub cmd_queue_depth: usize,
    /// Dependency-token queue depth.
    pub dep_queue_depth: usize,
    /// GEMM accumulation precision (narrow 16-bit / wide 32-bit).
    pub precision: Precision,
}

/// Field layout for the three instruction formats plus uops, derived from
/// the configuration. All widths in bits.
#[derive(Debug, Clone, PartialEq)]
pub struct IsaLayout {
    // -- scratchpad index widths --
    pub uop_idx_bits: u32,
    pub inp_idx_bits: u32,
    pub wgt_idx_bits: u32,
    pub acc_idx_bits: u32,
    /// sram_base field width in memory instructions (max over buffers).
    pub sram_bits: u32,
    /// dram_base field width (tile-granular address).
    pub dram_bits: u32,
    /// y_size/x_size/x_stride width in memory instructions.
    pub mem_size_bits: u32,
    /// Padding field widths (y_pad0/1, x_pad0/1).
    pub pad_bits: u32,
    /// Pad fill value width (new instruction feature: "load with a choice
    /// of pad values to support max pooling").
    pub pad_val_bits: u32,
    /// Loop-extent field width in GEMM/ALU instructions.
    pub loop_bits: u32,
    /// ALU immediate width.
    pub imm_bits: u32,
    /// ALU opcode field width (extended: MUL/CLIP/MOV are new).
    pub alu_op_bits: u32,
    /// Total uop width in bits (multiple of 8; paper: "we also extended
    /// the size of uops since not enough spare bits were available").
    pub uop_bits: u32,
}

impl IsaLayout {
    pub fn uop_bytes(&self) -> usize {
        (self.uop_bits / 8) as usize
    }

    /// Width of the `uop_end` field: one bit wider than `uop_bgn` since
    /// the exclusive end bound can equal the buffer depth (upstream VTA
    /// does the same: 13-bit bgn, 14-bit end).
    pub fn uop_end_bits(&self) -> u32 {
        self.uop_idx_bits + 1
    }

    /// Bits used by a GEMM instruction under this layout.
    pub fn gemm_bits(&self) -> u32 {
        OPCODE_BITS
            + DEP_BITS
            + 1 // reset flag
            + self.uop_idx_bits
            + self.uop_end_bits()
            + 2 * self.loop_bits
            + 2 * self.acc_idx_bits
            + 2 * self.inp_idx_bits
            + 2 * self.wgt_idx_bits
    }

    /// Bits used by an ALU instruction under this layout.
    pub fn alu_bits(&self) -> u32 {
        OPCODE_BITS
            + DEP_BITS
            + 1 // reset flag
            + self.uop_idx_bits
            + self.uop_end_bits()
            + 2 * self.loop_bits
            + 4 * self.acc_idx_bits // dst/src factor out/in
            + self.alu_op_bits
            + 1 // use_imm
            + self.imm_bits
    }

    /// Bits used by a LOAD/STORE instruction under this layout.
    pub fn mem_bits(&self) -> u32 {
        OPCODE_BITS
            + DEP_BITS
            + 3 // buffer id
            + self.sram_bits
            + self.dram_bits
            + 3 * self.mem_size_bits // y_size, x_size, x_stride
            + 4 * self.pad_bits
            + self.pad_val_bits
    }

    /// Bits needed by a GEMM uop (acc, inp, wgt indices).
    pub fn gemm_uop_bits(&self) -> u32 {
        self.acc_idx_bits + self.inp_idx_bits + self.wgt_idx_bits
    }

    /// Bits needed by an ALU uop (dst, src indices — both accumulator).
    pub fn alu_uop_bits(&self) -> u32 {
        2 * self.acc_idx_bits
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    NotPow2 { field: &'static str, value: usize },
    OutOfRange { field: &'static str, value: usize, lo: usize, hi: usize },
    InsnOverflow { insn: &'static str, bits: u32 },
    /// The configuration validates structurally but cannot execute a
    /// given workload: even the minimal (fallback) tiling overflows the
    /// scratchpads. Sweeps record these points (`measured: false`) so
    /// grid coverage stays accountable.
    Infeasible { reason: String },
    Json(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::NotPow2 { field, value } => {
                write!(f, "config field '{field}' must be a power of two, got {value}")
            }
            ConfigError::OutOfRange { field, value, lo, hi } => {
                write!(f, "config field '{field}' = {value} outside [{lo}, {hi}]")
            }
            ConfigError::InsnOverflow { insn, bits } => write!(
                f,
                "{insn} instruction needs {bits} bits > {INSN_BITS} even after \
                 field shrinking — reduce scratchpad depths"
            ),
            ConfigError::Infeasible { reason } => write!(f, "infeasible: {reason}"),
            ConfigError::Json(msg) => write!(f, "config json: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl VtaConfig {
    // ---- derived tile geometry ----

    /// Bytes per input-scratchpad tile.
    pub fn inp_tile_bytes(&self) -> usize {
        self.batch * self.block_in * INP_DTYPE_BITS / 8
    }

    /// Bytes per weight-scratchpad tile.
    pub fn wgt_tile_bytes(&self) -> usize {
        self.block_out * self.block_in * WGT_DTYPE_BITS / 8
    }

    /// Bytes per accumulator tile (int32).
    pub fn acc_tile_bytes(&self) -> usize {
        self.batch * self.block_out * ACC_DTYPE_BITS / 8
    }

    /// Bytes per output tile (int8).
    pub fn out_tile_bytes(&self) -> usize {
        self.batch * self.block_out * OUT_DTYPE_BITS / 8
    }

    /// Elements in one input tile.
    pub fn inp_tile_elems(&self) -> usize {
        self.batch * self.block_in
    }

    pub fn wgt_tile_elems(&self) -> usize {
        self.block_out * self.block_in
    }

    pub fn acc_tile_elems(&self) -> usize {
        self.batch * self.block_out
    }

    /// MACs performed by one GEMM uop execution (one tile matmul).
    pub fn macs_per_gemm_op(&self) -> usize {
        self.batch * self.block_in * self.block_out
    }

    /// Total scratchpad capacity in bytes (area-model input).
    pub fn scratchpad_bytes(&self) -> usize {
        self.uop_depth * self.isa_layout().uop_bytes()
            + self.inp_depth * self.inp_tile_bytes()
            + self.wgt_depth * self.wgt_tile_bytes()
            + self.acc_depth * self.acc_tile_bytes()
            + self.acc_depth * self.out_tile_bytes() // OUT mirrors ACC depth
    }

    // ---- ISA layout derivation ----

    /// Derive field widths from the configuration, applying the paper's
    /// shrink-to-fit policy to stay within the 128-bit instruction.
    /// The unshrunk defaults mirror upstream VTA (loop 14, sizes 14/16).
    pub fn isa_layout(&self) -> IsaLayout {
        let uop_idx_bits = addr_bits(self.uop_depth as u64);
        let inp_idx_bits = addr_bits(self.inp_depth as u64);
        let wgt_idx_bits = addr_bits(self.wgt_depth as u64);
        let acc_idx_bits = addr_bits(self.acc_depth as u64);
        let sram_bits = [uop_idx_bits, inp_idx_bits, wgt_idx_bits, acc_idx_bits]
            .into_iter()
            .max()
            .unwrap();
        let mut layout = IsaLayout {
            uop_idx_bits,
            inp_idx_bits,
            wgt_idx_bits,
            acc_idx_bits,
            sram_bits,
            dram_bits: 32,
            mem_size_bits: 14,
            pad_bits: 4,
            pad_val_bits: 8,
            loop_bits: 14,
            imm_bits: 16,
            alu_op_bits: 4,
            uop_bits: 0,
        };
        // Shrink loop extents first (few schedules need >2^10 iterations
        // in one instruction), then immediates, to fit compute insns.
        while layout.gemm_bits() > INSN_BITS || layout.alu_bits() > INSN_BITS {
            if layout.loop_bits > 10 {
                layout.loop_bits -= 1;
            } else if layout.imm_bits > 12 {
                layout.imm_bits -= 1;
            } else {
                break; // validate() will report the overflow
            }
        }
        // Shrink memory-size fields for the (rare) huge-scratchpad case.
        while layout.mem_bits() > INSN_BITS && layout.mem_size_bits > 10 {
            layout.mem_size_bits -= 1;
        }
        // Uop width: 32 bits as upstream when the indices fit, else the
        // paper's extended 64-bit uops ("we also extended the size of
        // uops since not enough spare bits were available"). Power-of-two
        // widths keep DRAM tile alignment trivial.
        let needed = layout.gemm_uop_bits().max(layout.alu_uop_bits());
        layout.uop_bits = if needed <= 32 { 32 } else { 64 };
        layout
    }

    /// Validate the full configuration: power-of-two shape/depth fields,
    /// ranges from the paper (AXI 8..=64 bytes), and instruction-width
    /// fit. Mirrors the paper's "compile-time checks — such as ensuring
    /// instruction width constraints are not violated".
    pub fn validate(&self) -> Result<(), ConfigError> {
        let pow2_fields: [(&'static str, usize); 7] = [
            ("batch", self.batch),
            ("block_in", self.block_in),
            ("block_out", self.block_out),
            ("uop_depth", self.uop_depth),
            ("inp_depth", self.inp_depth),
            ("wgt_depth", self.wgt_depth),
            ("acc_depth", self.acc_depth),
        ];
        for (field, value) in pow2_fields {
            if !value.is_power_of_two() {
                return Err(ConfigError::NotPow2 { field, value });
            }
        }
        let ranges: [(&'static str, usize, usize, usize); 8] = [
            ("batch", self.batch, 1, 16),
            ("block_in", self.block_in, 4, 128),
            ("block_out", self.block_out, 4, 128),
            ("axi_bytes", self.axi_bytes, 8, 64),
            ("vme_inflight", self.vme_inflight, 1, 64),
            ("cmd_queue_depth", self.cmd_queue_depth, 2, 4096),
            ("dep_queue_depth", self.dep_queue_depth, 1, 4096),
            ("uop_depth", self.uop_depth, 64, 1 << 20),
        ];
        for (field, value, lo, hi) in ranges {
            if value < lo || value > hi {
                return Err(ConfigError::OutOfRange { field, value, lo, hi });
            }
        }
        if !self.axi_bytes.is_power_of_two() {
            return Err(ConfigError::NotPow2 { field: "axi_bytes", value: self.axi_bytes });
        }
        let layout = self.isa_layout();
        if layout.gemm_bits() > INSN_BITS {
            return Err(ConfigError::InsnOverflow { insn: "GEMM", bits: layout.gemm_bits() });
        }
        if layout.alu_bits() > INSN_BITS {
            return Err(ConfigError::InsnOverflow { insn: "ALU", bits: layout.alu_bits() });
        }
        if layout.mem_bits() > INSN_BITS {
            return Err(ConfigError::InsnOverflow { insn: "LOAD/STORE", bits: layout.mem_bits() });
        }
        Ok(())
    }

    // ---- JSON (the cross-layer interchange format, §II-B) ----

    pub fn to_json(&self) -> Json {
        crate::util::json::obj([
            ("name", Json::Str(self.name.clone())),
            ("batch", Json::Int(self.batch as i64)),
            ("block_in", Json::Int(self.block_in as i64)),
            ("block_out", Json::Int(self.block_out as i64)),
            ("uop_depth", Json::Int(self.uop_depth as i64)),
            ("inp_depth", Json::Int(self.inp_depth as i64)),
            ("wgt_depth", Json::Int(self.wgt_depth as i64)),
            ("acc_depth", Json::Int(self.acc_depth as i64)),
            ("axi_bytes", Json::Int(self.axi_bytes as i64)),
            ("dram_latency", Json::Int(self.dram_latency as i64)),
            ("vme_inflight", Json::Int(self.vme_inflight as i64)),
            ("gemm_pipelined", Json::Bool(self.gemm_pipelined)),
            ("alu_pipelined", Json::Bool(self.alu_pipelined)),
            ("cmd_queue_depth", Json::Int(self.cmd_queue_depth as i64)),
            ("dep_queue_depth", Json::Int(self.dep_queue_depth as i64)),
            ("precision", Json::Str(self.precision.cli_name().to_string())),
        ])
    }

    pub fn from_json(json: &Json) -> Result<VtaConfig, ConfigError> {
        let field = |name: &str| -> Result<i64, ConfigError> {
            json.get(name)
                .and_then(|v| v.as_i64())
                .ok_or_else(|| ConfigError::Json(format!("missing integer field '{name}'")))
        };
        let flag = |name: &str, default: bool| -> bool {
            json.get(name).and_then(|v| v.as_bool()).unwrap_or(default)
        };
        let cfg = VtaConfig {
            name: json
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("unnamed")
                .to_string(),
            batch: field("batch")? as usize,
            block_in: field("block_in")? as usize,
            block_out: field("block_out")? as usize,
            uop_depth: field("uop_depth")? as usize,
            inp_depth: field("inp_depth")? as usize,
            wgt_depth: field("wgt_depth")? as usize,
            acc_depth: field("acc_depth")? as usize,
            axi_bytes: field("axi_bytes")? as usize,
            dram_latency: json.get("dram_latency").and_then(|v| v.as_i64()).unwrap_or(32)
                as u64,
            vme_inflight: json.get("vme_inflight").and_then(|v| v.as_i64()).unwrap_or(8)
                as usize,
            gemm_pipelined: flag("gemm_pipelined", true),
            alu_pipelined: flag("alu_pipelined", true),
            cmd_queue_depth: json
                .get("cmd_queue_depth")
                .and_then(|v| v.as_i64())
                .unwrap_or(512) as usize,
            dep_queue_depth: json
                .get("dep_queue_depth")
                .and_then(|v| v.as_i64())
                .unwrap_or(128) as usize,
            precision: match json.get("precision").and_then(|v| v.as_str()) {
                Some(s) => Precision::parse(s).map_err(ConfigError::Json)?,
                None => Precision::Wide,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(text: &str) -> Result<VtaConfig, ConfigError> {
        let json = Json::parse(text).map_err(|e| ConfigError::Json(e.to_string()))?;
        Self::from_json(&json)
    }

    pub fn load(path: &str) -> Result<VtaConfig, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Json(format!("read {path}: {e}")))?;
        Self::from_json_str(&text)
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        crate::util::fsx::atomic_write(
            std::path::Path::new(path),
            self.to_json().to_string_pretty().as_bytes(),
        )
    }

    /// Short human-readable identifier, e.g. `1x16x16-axi8`.
    pub fn tag(&self) -> String {
        format!(
            "{}x{}x{}-axi{}{}{}",
            self.batch,
            self.block_in,
            self.block_out,
            self.axi_bytes,
            if self.gemm_pipelined { "" } else { "-nopipe" },
            if self.precision == Precision::Narrow { "-narrow" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn default_config_valid_and_fits() {
        let cfg = presets::default_config();
        cfg.validate().unwrap();
        let l = cfg.isa_layout();
        assert!(l.gemm_bits() <= INSN_BITS, "gemm {}", l.gemm_bits());
        assert!(l.alu_bits() <= INSN_BITS, "alu {}", l.alu_bits());
        assert!(l.mem_bits() <= INSN_BITS, "mem {}", l.mem_bits());
        assert_eq!(l.uop_bits % 8, 0);
    }

    #[test]
    fn default_matches_upstream_vta_geometry() {
        // Upstream VTA default: 1x16x16, 32KB uop / 32KB inp / 256KB wgt /
        // 128KB acc scratchpads, 64-bit AXI.
        let cfg = presets::default_config();
        assert_eq!(cfg.inp_tile_bytes(), 16);
        assert_eq!(cfg.wgt_tile_bytes(), 256);
        assert_eq!(cfg.acc_tile_bytes(), 64);
        assert_eq!(cfg.macs_per_gemm_op(), 256);
        let l = cfg.isa_layout();
        // acc 2048 entries -> 11 bits, inp 2048 -> 11, wgt 1024 -> 10:
        // identical to upstream VTA's 32-bit uop split.
        assert_eq!((l.acc_idx_bits, l.inp_idx_bits, l.wgt_idx_bits), (11, 11, 10));
        assert_eq!(l.uop_bits, 32);
    }

    #[test]
    fn big_config_shrinks_loop_bits_to_fit() {
        let cfg = presets::scaled_config(1, 64, 64, 4, 64);
        cfg.validate().unwrap();
        let l = cfg.isa_layout();
        assert!(l.gemm_bits() <= INSN_BITS);
        assert!(l.loop_bits < 14, "expected shrink, got {}", l.loop_bits);
    }

    #[test]
    fn wider_uops_for_large_scratchpads() {
        let cfg = presets::scaled_config(1, 64, 64, 8, 64);
        let l = cfg.isa_layout();
        assert!(l.uop_bits > 32, "expected extended uop, got {}", l.uop_bits);
    }

    #[test]
    fn rejects_non_pow2() {
        let mut cfg = presets::default_config();
        cfg.block_in = 24;
        assert!(matches!(cfg.validate(), Err(ConfigError::NotPow2 { field: "block_in", .. })));
    }

    #[test]
    fn rejects_axi_out_of_range() {
        let mut cfg = presets::default_config();
        cfg.axi_bytes = 128;
        assert!(matches!(
            cfg.validate(),
            Err(ConfigError::OutOfRange { field: "axi_bytes", .. })
        ));
    }

    #[test]
    fn json_roundtrip() {
        let cfg = presets::scaled_config(2, 32, 32, 2, 32);
        let text = cfg.to_json().to_string_pretty();
        let back = VtaConfig::from_json_str(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn json_missing_field_errors() {
        let err = VtaConfig::from_json_str(r#"{"batch": 1}"#).unwrap_err();
        assert!(matches!(err, ConfigError::Json(_)));
    }

    #[test]
    fn scratchpad_bytes_counts_all_buffers() {
        let cfg = presets::default_config();
        let expected = 8192 * 4 // uop
            + 2048 * 16 // inp
            + 1024 * 256 // wgt
            + 2048 * 64 // acc
            + 2048 * 16; // out
        assert_eq!(cfg.scratchpad_bytes(), expected);
    }

    #[test]
    fn tag_format() {
        let cfg = presets::default_config();
        assert_eq!(cfg.tag(), "1x16x16-axi8");
        let mut un = cfg;
        un.gemm_pipelined = false;
        assert_eq!(un.tag(), "1x16x16-axi8-nopipe");
    }
}
