//! Named hardware configurations used throughout the paper's evaluation.
//!
//! * [`default_config`] — upstream VTA default (1×16×16, 64-bit AXI),
//!   fully pipelined: the baseline for the ~4.9× pipelining comparison.
//! * [`original_config`] — same geometry with the *unpipelined* GEMM
//!   (II=4) and ALU (II=4/5) of the published VTA.
//! * [`scaled_config`] — the Fig 13 design-space generator: MAC shape
//!   (BLOCK), scratchpad scaling, AXI width.
//! * [`tiny_config`] — small geometry for fast unit tests.

use super::{Precision, VtaConfig};

/// Upstream VTA default configuration: BATCH=1, BLOCK_IN=BLOCK_OUT=16,
/// 32KB uop / 32KB inp / 256KB wgt / 128KB acc buffers, 64-bit (8-byte)
/// AXI, both execution units fully pipelined (this work's enhancement).
pub fn default_config() -> VtaConfig {
    VtaConfig {
        name: "default".into(),
        batch: 1,
        block_in: 16,
        block_out: 16,
        uop_depth: 8192,  // 32 KiB / 4 B
        inp_depth: 2048,  // 32 KiB / 16 B
        wgt_depth: 1024,  // 256 KiB / 256 B
        acc_depth: 2048,  // 128 KiB / 64 B
        axi_bytes: 8,
        dram_latency: 32,
        vme_inflight: 8,
        gemm_pipelined: true,
        alu_pipelined: true,
        cmd_queue_depth: 512,
        dep_queue_depth: 128,
        precision: Precision::Wide,
    }
}

/// The VTA as published: same geometry as [`default_config`] but with the
/// original unpipelined execution units (GEMM II=4, ALU II=4/5) and a
/// single-outstanding-request memory engine.
pub fn original_config() -> VtaConfig {
    VtaConfig {
        name: "original".into(),
        gemm_pipelined: false,
        alu_pipelined: false,
        vme_inflight: 1,
        ..default_config()
    }
}

/// Design-space point for the Fig 13 sweep.
///
/// * `batch`, `block` — MAC array shape (`block`×`block`, so the paper's
///   "4x4 / 5x5 / 6x6 MAC shapes" are `block` = 16 / 32 / 64).
/// * `spad_scale` — multiplies all scratchpad depths relative to a
///   geometry-proportional baseline.
/// * `axi_bytes` — memory interface width (8..=64).
pub fn scaled_config(
    batch: usize,
    block_in: usize,
    block_out: usize,
    spad_scale: usize,
    axi_bytes: usize,
) -> VtaConfig {
    // Baseline depths keep tile *counts* constant as BLOCK grows, so
    // scratchpad bytes grow with the MAC shape (as in the paper, where
    // scratchpad size dominates scaled area).
    let base_inp = 1024;
    let base_wgt = 512;
    let base_acc = 1024;
    VtaConfig {
        name: format!("b{batch}-i{block_in}-o{block_out}-s{spad_scale}-m{axi_bytes}"),
        batch,
        block_in,
        block_out,
        uop_depth: 8192,
        inp_depth: base_inp * spad_scale,
        wgt_depth: base_wgt * spad_scale,
        acc_depth: base_acc * spad_scale,
        axi_bytes,
        dram_latency: 32,
        vme_inflight: 8,
        gemm_pipelined: true,
        alu_pipelined: true,
        cmd_queue_depth: 512,
        dep_queue_depth: 128,
        precision: Precision::Wide,
    }
}

/// Small geometry for fast unit tests: 1×4×4 tiles, shallow buffers.
pub fn tiny_config() -> VtaConfig {
    VtaConfig {
        name: "tiny".into(),
        batch: 1,
        block_in: 4,
        block_out: 4,
        uop_depth: 512,
        inp_depth: 256,
        wgt_depth: 256,
        acc_depth: 256,
        axi_bytes: 8,
        dram_latency: 8,
        vme_inflight: 4,
        gemm_pipelined: true,
        alu_pipelined: true,
        cmd_queue_depth: 64,
        dep_queue_depth: 32,
        precision: Precision::Wide,
    }
}

/// Parse a [`scaled_config`] name — the
/// `b{batch}-i{in}-o{out}-s{scale}-m{axi}` format `scaled_config`
/// itself stamps — back into its configuration, so sweep-result names
/// round-trip through the CLI (`--config`, `--fleet-configs`).
pub fn parse_scaled_name(s: &str) -> Option<VtaConfig> {
    let parts: Vec<&str> = s.split('-').collect();
    if parts.len() != 5 {
        return None;
    }
    let mut vals = [0usize; 5];
    for (slot, (part, prefix)) in vals.iter_mut().zip(parts.iter().zip(["b", "i", "o", "s", "m"]))
    {
        *slot = part.strip_prefix(prefix)?.parse().ok()?;
    }
    let [batch, block_in, block_out, spad_scale, axi_bytes] = vals;
    Some(scaled_config(batch, block_in, block_out, spad_scale, axi_bytes))
}

/// Look a preset up by name (CLI `--config <name>` path). Falls back to
/// [`parse_scaled_name`] so any design point a sweep names is reachable
/// directly. A `-narrow` suffix selects narrow (16-bit) accumulation on
/// any base name — the spelling the sweep's precision axis stamps.
pub fn by_name(name: &str) -> Option<VtaConfig> {
    if let Some(base) = name.strip_suffix("-narrow") {
        let mut cfg = by_name(base)?;
        cfg.precision = Precision::Narrow;
        cfg.name = name.to_string();
        return Some(cfg);
    }
    match name {
        "default" => Some(default_config()),
        "original" => Some(original_config()),
        "tiny" => Some(tiny_config()),
        "large" => Some(scaled_config(1, 64, 64, 2, 64)),
        "wide32" => Some(scaled_config(1, 32, 32, 2, 32)),
        _ => parse_scaled_name(name),
    }
}

/// All stable presets (used by config round-trip tests and docs).
pub fn all() -> Vec<VtaConfig> {
    vec![
        default_config(),
        original_config(),
        tiny_config(),
        scaled_config(1, 32, 32, 2, 32),
        scaled_config(1, 64, 64, 2, 64),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for cfg in all() {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn original_differs_only_in_pipelining_and_vme() {
        let d = default_config();
        let o = original_config();
        assert!(!o.gemm_pipelined && !o.alu_pipelined);
        assert_eq!(o.vme_inflight, 1);
        assert_eq!((o.batch, o.block_in, o.block_out), (d.batch, d.block_in, d.block_out));
        assert_eq!(o.scratchpad_bytes(), d.scratchpad_bytes());
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("default").is_some());
        assert!(by_name("original").is_some());
        assert!(by_name("nonsense").is_none());
    }

    #[test]
    fn narrow_suffix_selects_narrow_accumulation() {
        let cfg = by_name("default-narrow").unwrap();
        assert_eq!(cfg.precision, Precision::Narrow);
        assert_eq!(cfg.name, "default-narrow");
        let scaled = by_name("b1-i32-o32-s2-m32-narrow").unwrap();
        assert_eq!(scaled.precision, Precision::Narrow);
        assert_eq!(scaled.block_in, 32);
        assert!(by_name("nonsense-narrow").is_none());
    }

    #[test]
    fn scaled_names_parse_back() {
        let cfg = scaled_config(1, 32, 32, 2, 16);
        assert_eq!(parse_scaled_name(&cfg.name), Some(cfg.clone()));
        assert_eq!(by_name(&cfg.name), Some(cfg));
        assert!(parse_scaled_name("b1-i16-o16").is_none(), "too few parts");
        assert!(parse_scaled_name("b1-i16-o16-s1-mx").is_none(), "non-numeric field");
        assert!(parse_scaled_name("x1-i16-o16-s1-m8").is_none(), "wrong prefix");
    }
}
