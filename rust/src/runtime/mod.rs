//! The software-defined runtime (§II-C): stages tensors into DRAM in the
//! accelerator layouts, JIT-lowers each graph node to an instruction
//! stream (one kernel launch per layer, as TVM/VTA does), runs it on the
//! selected target (*fsim* or *tsim*), and manages CPU fallback for
//! layers the accelerator does not execute (the channel-light first
//! convolution) — "thus ensuring that a DNN can be executed on VTA even
//! if the accelerator doesn't support all layers".

pub mod pjrt;

use crate::compiler::builder::ProgramBuilder;
use crate::compiler::conv::{lower_conv, ConvBases, ConvParams};
use crate::compiler::depthwise::{lower_depthwise, DepthwiseParams};
use crate::compiler::eltwise::{lower_add, lower_pool, PoolParams};
use crate::compiler::graph::{Graph, Op};
use crate::compiler::layout::{
    pack_activation, pack_conv_weights, pack_depthwise_weights, unpack_activation, Shape,
};
use crate::compiler::tps::{self, Tiling};
use crate::config::VtaConfig;
use crate::exec::ExecCounters;
use crate::fsim::Fsim;
use crate::mem::{Dram, DramRegion};
use crate::sim::{PerfReport, Tsim};
use crate::util::bitfield::clog2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Behavioral simulation (no timing).
    Fsim,
    /// Cycle-accurate simulation.
    Tsim,
}

#[derive(Debug, Clone)]
pub struct SessionOptions {
    pub target: Target,
    /// Record per-cycle activity intervals (Figs 3/4).
    pub trace: bool,
    /// Improved double buffering: eliminate redundant input loads
    /// (§IV-D2). `false` reproduces the original TVM behaviour.
    pub dbuf_reuse: bool,
    /// Use TPS-optimized tilings; `false` uses the fallback schedule
    /// (the Fig 10 baseline).
    pub tps: bool,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions { target: Target::Tsim, trace: false, dbuf_reuse: true, tps: true }
    }
}

/// Per-layer execution record.
#[derive(Debug, Clone)]
pub struct LayerStat {
    pub name: String,
    pub kind: &'static str,
    pub cycles: u64,
    pub insns: usize,
    pub uops: usize,
    pub macs: u64,
    pub dram_rd: u64,
    pub dram_wr: u64,
    pub on_cpu: bool,
}

enum Backend {
    F(Box<Fsim>),
    T(Box<Tsim>),
}

pub struct Session {
    pub cfg: VtaConfig,
    pub opts: SessionOptions,
    pub dram: Dram,
    backend: Backend,
    pub layer_stats: Vec<LayerStat>,
}

impl Session {
    pub fn new(cfg: &VtaConfig, opts: SessionOptions) -> Session {
        assert_eq!(
            cfg.block_in, cfg.block_out,
            "network execution requires BLOCK_IN == BLOCK_OUT (activation \
             tiles feed both GEMM operands); the paper's swept configs are square"
        );
        let backend = match opts.target {
            Target::Fsim => Backend::F(Box::new(Fsim::new(cfg))),
            Target::Tsim => {
                let mut t = Tsim::new(cfg);
                if opts.trace {
                    t.enable_trace();
                }
                Backend::T(Box::new(t))
            }
        };
        Session {
            cfg: cfg.clone(),
            opts,
            dram: Dram::with_default_capacity(),
            backend,
            layer_stats: Vec::new(),
        }
    }

    /// Cumulative execution counters of the active backend.
    pub fn exec_counters(&self) -> ExecCounters {
        match &self.backend {
            Backend::F(f) => f.state.counters,
            Backend::T(t) => t.core.counters,
        }
    }

    /// Total simulated cycles (tsim target only; 0 under fsim).
    pub fn cycles(&self) -> u64 {
        match &self.backend {
            Backend::F(_) => 0,
            Backend::T(t) => t.cycle(),
        }
    }

    pub fn perf_report(&self) -> Option<PerfReport> {
        match &self.backend {
            Backend::F(_) => None,
            Backend::T(t) => Some(t.report()),
        }
    }

    pub fn tsim(&self) -> Option<&Tsim> {
        match &self.backend {
            Backend::F(_) => None,
            Backend::T(t) => Some(t),
        }
    }

    fn run_program(&mut self, insns: &[crate::isa::Insn], label: &str) -> u64 {
        match &mut self.backend {
            Backend::F(f) => {
                let report = f.run(insns, &mut self.dram);
                assert!(report.finished, "fsim program did not reach FINISH");
                0
            }
            Backend::T(t) => t.run(insns, &mut self.dram, label),
        }
    }

    /// Allocate a DRAM region for a tiled activation of `shape`.
    fn alloc_activation(&mut self, shape: Shape) -> DramRegion {
        let block = self.cfg.block_in;
        let tile = self.cfg.inp_tile_bytes();
        self.dram.alloc(shape.tiles(block) * tile, tile)
    }

    /// Run a graph end-to-end. `input` is `[batch][c][h][w]` int8 with
    /// `batch == cfg.batch`; returns the final node's output in the same
    /// layout. Per-layer statistics accumulate in `layer_stats`.
    pub fn run_graph(&mut self, graph: &Graph, input: &[i8]) -> Vec<i8> {
        let cfg = self.cfg.clone();
        let block = cfg.block_in;
        let batch = cfg.batch;
        let shapes = graph.shapes();
        assert_eq!(input.len(), batch * graph.input_shape.elems(), "input size mismatch");

        // Stage the input activation.
        let mut regions: Vec<Option<DramRegion>> = vec![None; graph.nodes.len()];
        let r0 = self.alloc_activation(graph.input_shape);
        let tiled = pack_activation(input, batch, graph.input_shape, block);
        self.dram.write_i8(r0, &tiled);
        regions[0] = Some(r0);

        for (i, node) in graph.nodes.iter().enumerate().skip(1) {
            let in_shape = shapes[node.inputs[0]];
            let out_shape = shapes[i];
            let out_region = self.alloc_activation(out_shape);
            regions[i] = Some(out_region);
            let in_region = regions[node.inputs[0]].expect("producer region");
            let before = self.exec_counters();
            let label = format!("{}:{}", graph.name, node.name);

            let (cycles, insns, uops, on_cpu) = match &node.op {
                Op::Input => unreachable!(),
                Op::Conv { shift, relu, weights, .. } => {
                    let spec = graph.conv_spec(i, &shapes);
                    if spec.c_in < block {
                        // Channel-light layer: CPU fallback (§IV-E).
                        self.run_conv_on_cpu(
                            graph, i, &shapes, weights, *shift, *relu, in_region, out_region,
                        );
                        (0, 0, 0, true)
                    } else {
                        let n = self.run_conv_on_vta(
                            &spec, weights, *shift, *relu, in_region, out_region, &label,
                        );
                        (n.0, n.1, n.2, false)
                    }
                }
                Op::Dense { shift, relu, weights, .. } => {
                    let spec = graph.conv_spec(i, &shapes);
                    let n = self.run_conv_on_vta(
                        &spec, weights, *shift, *relu, in_region, out_region, &label,
                    );
                    (n.0, n.1, n.2, false)
                }
                Op::Depthwise { k, stride, pad, shift, relu, weights } => {
                    let wgt =
                        pack_depthwise_weights(weights, in_shape.c, *k, *k, batch, block);
                    let tileb = cfg.acc_tile_elems(); // Acc8 tile bytes
                    let wr = self.dram.alloc(wgt.len(), tileb);
                    self.dram.write_i8(wr, &wgt);
                    let p = DepthwiseParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        shift: *shift,
                        relu: *relu,
                    };
                    let mut b = ProgramBuilder::new(&cfg);
                    lower_depthwise(
                        &mut b,
                        &p,
                        in_region.tile_base(cfg.acc_tile_elems()),
                        wr.tile_base(tileb),
                        out_region.tile_base(cfg.out_tile_bytes()),
                    );
                    let prog = b.finish(&label, &mut self.dram);
                    let c = self.run_program(&prog.insns, &label);
                    (c, prog.insns.len(), prog.uop_count, false)
                }
                Op::MaxPool { k, stride, pad } => {
                    let p = PoolParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        is_max: true,
                        shift: 0,
                    };
                    self.run_pool(&p, in_region, out_region, &label)
                }
                Op::GlobalAvgPool => {
                    assert_eq!(in_shape.h, in_shape.w, "global pool expects square input");
                    let p = PoolParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: in_shape.h,
                        stride: 1,
                        pad: 0,
                        is_max: false,
                        shift: clog2((in_shape.h * in_shape.w) as u64),
                    };
                    self.run_pool(&p, in_region, out_region, &label)
                }
                Op::Add { relu } => {
                    let b_region = regions[node.inputs[1]].expect("skip region");
                    let mut b = ProgramBuilder::new(&cfg);
                    lower_add(
                        &mut b,
                        out_shape.tiles(block),
                        in_region.tile_base(cfg.acc_tile_elems()),
                        b_region.tile_base(cfg.acc_tile_elems()),
                        out_region.tile_base(cfg.out_tile_bytes()),
                        *relu,
                    );
                    let prog = b.finish(&label, &mut self.dram);
                    let c = self.run_program(&prog.insns, &label);
                    (c, prog.insns.len(), prog.uop_count, false)
                }
            };

            let after = self.exec_counters();
            self.layer_stats.push(LayerStat {
                name: label,
                kind: node.op.kind(),
                cycles,
                insns,
                uops,
                macs: after.macs - before.macs,
                dram_rd: after.load_bytes_total() - before.load_bytes_total(),
                dram_wr: after.store_bytes - before.store_bytes,
                on_cpu,
            });
        }

        let out_shape = *shapes.last().unwrap();
        let out_region = regions.last().unwrap().unwrap();
        let tiled = self.dram.read_i8(out_region);
        unpack_activation(&tiled, batch, out_shape, block)
    }

    /// Choose the tiling for a conv per session options.
    ///
    /// The *tiling* is always searched under the improved-reuse cost
    /// model; `dbuf_reuse` then controls only the thread-injection
    /// behaviour — matching the paper's Fig 11/12 experiment, which
    /// flips the IR pass while keeping the schedule.
    pub fn tiling_for(&self, spec: &tps::ConvSpec) -> Tiling {
        let mut t = if self.opts.tps {
            tps::search(spec, &self.cfg, true)
        } else {
            tps::fallback(spec, &self.cfg)
        };
        t.reuse_inp = self.opts.dbuf_reuse;
        t
    }

    #[allow(clippy::too_many_arguments)]
    fn run_conv_on_vta(
        &mut self,
        spec: &tps::ConvSpec,
        weights: &[i8],
        shift: u32,
        relu: bool,
        in_region: DramRegion,
        out_region: DramRegion,
        label: &str,
    ) -> (u64, usize, usize) {
        let cfg = self.cfg.clone();
        let wgt = pack_conv_weights(
            weights,
            spec.c_out,
            spec.c_in,
            spec.kh,
            spec.kw,
            cfg.block_out,
            cfg.block_in,
        );
        let wr = self.dram.alloc(wgt.len(), cfg.wgt_tile_bytes());
        self.dram.write_i8(wr, &wgt);
        let tiling = self.tiling_for(spec);
        let mut b = ProgramBuilder::new(&cfg);
        lower_conv(
            &mut b,
            &ConvParams { spec: *spec, shift, relu },
            &tiling,
            ConvBases {
                inp: in_region.tile_base(cfg.inp_tile_bytes()),
                wgt: wr.tile_base(cfg.wgt_tile_bytes()),
                out: out_region.tile_base(cfg.out_tile_bytes()),
            },
        );
        let prog = b.finish(label, &mut self.dram);
        let c = self.run_program(&prog.insns, label);
        (c, prog.insns.len(), prog.uop_count)
    }

    fn run_pool(
        &mut self,
        p: &PoolParams,
        in_region: DramRegion,
        out_region: DramRegion,
        label: &str,
    ) -> (u64, usize, usize, bool) {
        let cfg = self.cfg.clone();
        let mut b = ProgramBuilder::new(&cfg);
        lower_pool(
            &mut b,
            p,
            in_region.tile_base(cfg.acc_tile_elems()),
            out_region.tile_base(cfg.out_tile_bytes()),
        );
        let prog = b.finish(label, &mut self.dram);
        let c = self.run_program(&prog.insns, label);
        (c, prog.insns.len(), prog.uop_count, false)
    }

    /// CPU fallback: unpack, run the reference op, repack.
    #[allow(clippy::too_many_arguments)]
    fn run_conv_on_cpu(
        &mut self,
        graph: &Graph,
        idx: usize,
        shapes: &[Shape],
        weights: &[i8],
        shift: u32,
        relu: bool,
        in_region: DramRegion,
        out_region: DramRegion,
    ) {
        let cfg = &self.cfg;
        let spec = graph.conv_spec(idx, shapes);
        let in_shape = shapes[graph.nodes[idx].inputs[0]];
        let out_shape = shapes[idx];
        let tiled = self.dram.read_i8(in_region);
        let nchw = unpack_activation(&tiled, cfg.batch, in_shape, cfg.block_in);
        let out =
            crate::compiler::cpu_ref::conv2d(&nchw, weights, cfg.batch, &spec, shift, relu);
        let packed = pack_activation(&out, cfg.batch, out_shape, cfg.block_in);
        self.dram.write_i8(out_region, &packed);
    }
}
