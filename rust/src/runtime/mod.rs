//! The software-defined runtime (§II-C): stages tensors into DRAM in the
//! accelerator layouts, JIT-lowers each graph node to an instruction
//! stream (one kernel launch per layer, as TVM/VTA does), runs it on the
//! selected target (*fsim* or *tsim*), and manages CPU fallback for
//! layers the accelerator does not execute (the channel-light first
//! convolution) — "thus ensuring that a DNN can be executed on VTA even
//! if the accelerator doesn't support all layers".
//!
//! The session is the low-level graph executor behind the simulating
//! backends of [`crate::engine`] — pick a fidelity by picking a
//! [`BackendKind`] (the preferred front door is
//! [`Engine`](crate::engine::Engine), which owns the memo and report
//! plumbing). Two sweep fast paths thread through here (see
//! `crate::memo` and DESIGN.md §Layer memo):
//!
//! * **timing-only** ([`BackendKind::TsimTiming`]): tsim computes
//!   cycles and execution counters bit-identically but skips all
//!   functional datapath effects (and the data staging that feeds them);
//! * **layer memo** ([`SessionOptions::memo`]): per-layer results are
//!   keyed by a [`LayerSig`] and spliced from a shared [`LayerMemo`]
//!   instead of re-simulated — in timing-only mode a hit skips the layer
//!   entirely; in functional mode a hit replays the program through the
//!   exec core (outputs stay bit-exact) and only the timing wheel is
//!   skipped.
//!
//! All public entry points here return [`VtaError`] on malformed input
//! instead of panicking.

pub mod pjrt;

use crate::compiler::builder::{Program, ProgramBuilder};
use crate::compiler::conv::{lower_conv, ConvBases, ConvParams};
use crate::compiler::depthwise::{lower_depthwise, DepthwiseParams};
use crate::compiler::eltwise::{
    lower_add, lower_eltmul, lower_pool, lower_softmax, lower_sub, lower_unary, PoolParams,
    HARD_SIGMOID_OPS, HARD_TANH_OPS,
};
use crate::compiler::graph::{
    attn_on_vta, layernorm_mean_spec, softmax_on_vta, Graph, Op,
};
use crate::compiler::layout::{
    pack_activation, pack_conv_weights_into, pack_depthwise_weights_into, unpack_activation,
    Shape,
};
use crate::compiler::residency::{self, ResidencyMode, ResidencyPlan, RECOMPUTE_SIG_BITS};
use crate::compiler::tps::{self, Tiling};
use crate::config::VtaConfig;
use crate::engine::{BackendKind, VtaError};
use crate::exec::ExecCounters;
use crate::fsim::Fsim;
use crate::mem::{Dram, DramRegion};
use crate::memo::{sig, LayerMemo, LayerRecord, LayerSig};
use crate::sim::activity::ActivityTrace;
use crate::sim::{PerfReport, Tsim};
use crate::util::bitfield::clog2;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct SessionOptions {
    /// Which simulator executes the graph. [`BackendKind::Analytical`]
    /// is rejected by [`Session::new`]: the analytical model needs no
    /// session (use [`Engine`](crate::engine::Engine) instead).
    pub backend: BackendKind,
    /// Record per-cycle activity intervals (Figs 3/4).
    pub trace: bool,
    /// Improved double buffering: eliminate redundant input loads
    /// (§IV-D2). `false` reproduces the original TVM behaviour.
    pub dbuf_reuse: bool,
    /// Use TPS-optimized tilings; `false` uses the fallback schedule
    /// (the Fig 10 baseline).
    pub tps: bool,
    /// Layer-memo cache consulted before compiling/simulating each
    /// accelerator layer; shared (via `Arc`) across sessions and sweep
    /// worker threads. Tsim only; incompatible with `trace` (memo hits
    /// record no activity intervals).
    pub memo: Option<Arc<LayerMemo>>,
    /// Cross-layer scratchpad residency planning (§ DESIGN.md
    /// Residency planner): which producer→consumer activations stay
    /// hot across layer boundaries, eliding the store+load DMA pair.
    /// Purely a timing/counter optimization — outputs are bit-identical
    /// in every mode.
    pub residency: ResidencyMode,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            backend: BackendKind::Tsim,
            trace: false,
            dbuf_reuse: true,
            tps: true,
            memo: None,
            residency: ResidencyMode::default(),
        }
    }
}

/// Per-layer execution record.
#[derive(Debug, Clone)]
pub struct LayerStat {
    pub name: String,
    pub kind: &'static str,
    pub cycles: u64,
    pub insns: usize,
    pub uops: usize,
    pub macs: u64,
    pub dram_rd: u64,
    pub dram_wr: u64,
    pub on_cpu: bool,
}

enum Sim {
    F(Box<Fsim>),
    T(Box<Tsim>),
}

pub struct Session {
    pub cfg: VtaConfig,
    pub opts: SessionOptions,
    pub dram: Dram,
    sim: Sim,
    pub layer_stats: Vec<LayerStat>,
    /// Cycles spliced in from memoized layers (absent from the
    /// simulator's own cycle counter).
    memo_cycles: u64,
    /// Counter deltas spliced in from memoized timing-only hits
    /// (functional-mode hits replay and accrue counters naturally).
    memo_extra: ExecCounters,
    /// Weight-staging arena reused across layers (and across batched
    /// requests): the packed-weight image is built here and copied into
    /// DRAM, so repeated layers stop allocating a fresh `Vec` per pack.
    wgt_scratch: Vec<i8>,
}

impl Session {
    pub fn new(cfg: &VtaConfig, opts: SessionOptions) -> Result<Session, VtaError> {
        cfg.validate()?;
        if cfg.block_in != cfg.block_out {
            return Err(VtaError::Unsupported(format!(
                "network execution requires BLOCK_IN == BLOCK_OUT (activation tiles feed \
                 both GEMM operands); got {}x{}",
                cfg.block_in, cfg.block_out
            )));
        }
        if opts.memo.is_some()
            && !matches!(opts.backend, BackendKind::Tsim | BackendKind::TsimTiming)
        {
            return Err(VtaError::Unsupported(format!(
                "the layer memo is a tsim fast path; backend '{}' does not support it",
                opts.backend
            )));
        }
        if opts.trace && opts.memo.is_some() {
            return Err(VtaError::Unsupported(
                "activity tracing requires unmemoized simulation (memo hits record no \
                 activity intervals)"
                    .into(),
            ));
        }
        let sim = match opts.backend {
            BackendKind::Fsim => Sim::F(Box::new(Fsim::new(cfg))),
            BackendKind::Tsim | BackendKind::TsimTiming => {
                let mut t = if opts.backend == BackendKind::TsimTiming {
                    Tsim::timing_only(cfg)
                } else {
                    Tsim::new(cfg)
                };
                if opts.trace {
                    t.enable_trace();
                }
                Sim::T(Box::new(t))
            }
            BackendKind::Analytical => {
                return Err(VtaError::Unsupported(
                    "the analytical backend runs no simulation and needs no session; \
                     evaluate it through the engine"
                        .into(),
                ))
            }
        };
        Ok(Session {
            cfg: cfg.clone(),
            opts,
            dram: Dram::with_default_capacity(),
            sim,
            layer_stats: Vec::new(),
            memo_cycles: 0,
            memo_extra: ExecCounters::default(),
            wgt_scratch: Vec::new(),
        })
    }

    /// Restore the session to its just-constructed state without
    /// releasing any allocation: DRAM's allocated prefix is zeroed, the
    /// simulator core is wiped in place, and per-run bookkeeping is
    /// cleared. Post-reset state is bit-identical to a fresh
    /// `Session::new` with the same config and options, which is what
    /// makes batched evaluation ([`crate::engine::Engine::eval_many`])
    /// return the same bytes as one session per request. The layer memo
    /// (shared, content-addressed) deliberately persists.
    pub fn reset_for_reuse(&mut self) {
        self.dram.reset_zeroed();
        match &mut self.sim {
            Sim::F(f) => f.reset_for_reuse(),
            Sim::T(t) => {
                t.reset_for_reuse();
                if self.opts.trace {
                    t.enable_trace();
                }
            }
        }
        self.layer_stats.clear();
        self.memo_cycles = 0;
        self.memo_extra = ExecCounters::default();
    }

    /// Timing-only fast path active (see [`BackendKind::TsimTiming`]).
    fn timing_only(&self) -> bool {
        self.opts.backend == BackendKind::TsimTiming
    }

    /// Cumulative execution counters of the session: the active
    /// simulator's counters plus everything spliced in from memoized
    /// layers — bit-identical to what an unmemoized run accumulates.
    pub fn exec_counters(&self) -> ExecCounters {
        let mut c = match &self.sim {
            Sim::F(f) => f.state.counters,
            Sim::T(t) => t.core.counters,
        };
        c.accumulate(&self.memo_extra);
        c
    }

    /// Total simulated cycles including memo-spliced layers (tsim
    /// backends only; 0 under fsim).
    pub fn cycles(&self) -> u64 {
        match &self.sim {
            Sim::F(_) => 0,
            Sim::T(t) => t.cycle() + self.memo_cycles,
        }
    }

    /// Performance report. Cycle and execution-counter totals include
    /// memo-spliced layers; the per-module busy/stall and VME breakdowns
    /// cover only the layers this session actually simulated (memoized
    /// layers produce no module activity).
    pub fn perf_report(&self) -> Option<PerfReport> {
        match &self.sim {
            Sim::F(_) => None,
            Sim::T(t) => {
                let mut r = t.report();
                r.cycles += self.memo_cycles;
                r.exec.accumulate(&self.memo_extra);
                Some(r)
            }
        }
    }

    pub fn tsim(&self) -> Option<&Tsim> {
        match &self.sim {
            Sim::F(_) => None,
            Sim::T(t) => Some(t),
        }
    }

    /// Move the recorded activity trace out of the session (`None`
    /// unless [`SessionOptions::trace`] was set on a tsim backend).
    pub fn take_trace(&mut self) -> Option<ActivityTrace> {
        if !self.opts.trace {
            return None;
        }
        match &mut self.sim {
            Sim::F(_) => None,
            Sim::T(t) => Some(std::mem::replace(&mut t.trace, ActivityTrace::new(false))),
        }
    }

    /// Install the residency-elided DRAM byte ranges on the simulator
    /// core (fsim and tsim share the predicate through
    /// [`crate::exec::CoreState`], which is what keeps backend counter
    /// parity). Set per node, cleared after the graph.
    fn set_elided(&mut self, ranges: Vec<(u64, u64)>) {
        match &mut self.sim {
            Sim::F(f) => f.state.set_elided_ranges(ranges),
            Sim::T(t) => t.core.set_elided_ranges(ranges),
        }
    }

    fn run_program(&mut self, insns: &[crate::isa::Insn], label: &str) -> u64 {
        match &mut self.sim {
            Sim::F(f) => {
                let report = f.run(insns, &mut self.dram);
                assert!(report.finished, "fsim program did not reach FINISH");
                0
            }
            Sim::T(t) => t.run(insns, &mut self.dram, label),
        }
    }

    /// Apply a program's architectural effects in program order without
    /// timing simulation — the functional half of a memo hit. Program
    /// order and tsim's time-ordered completion produce bit-identical
    /// architectural state (the tsim/fsim equivalence invariant, which
    /// `rust/tests/stack_integration.rs` pins down).
    fn replay_program(&mut self, insns: &[crate::isa::Insn]) {
        match &mut self.sim {
            Sim::F(_) => unreachable!("memoization is tsim-only (rejected in Session::new)"),
            Sim::T(t) => {
                for insn in insns {
                    t.core.execute(insn, &mut self.dram);
                }
            }
        }
    }

    /// Execute one layer program through the memo (see `crate::memo`):
    ///
    /// * memo disabled → compile and simulate as always;
    /// * miss → compile, simulate, record cycles + the counter delta;
    /// * hit, timing-only → splice the record; nothing compiles or runs;
    /// * hit, functional → compile and replay through the exec core
    ///   (outputs bit-exact), splicing the recorded cycles.
    ///
    /// Returns `(cycles, program insns, program uops)`.
    fn memo_run(
        &mut self,
        sig: LayerSig,
        label: &str,
        build: impl FnOnce(&mut Session) -> Program,
    ) -> (u64, usize, usize) {
        let Some(memo) = self.opts.memo.clone() else {
            let prog = build(self);
            let cycles = self.run_program(&prog.insns, label);
            return (cycles, prog.insns.len(), prog.uop_count);
        };
        if let Some(rec) = memo.get(sig) {
            if self.timing_only() {
                self.memo_cycles += rec.cycles;
                self.memo_extra.accumulate(&rec.exec);
                return (rec.cycles, rec.prog_insns as usize, rec.prog_uops as usize);
            }
            let prog = build(self);
            debug_assert_eq!(
                prog.insns.len(),
                rec.prog_insns as usize,
                "memo record does not match the compiled program for {label}"
            );
            self.replay_program(&prog.insns);
            self.memo_cycles += rec.cycles;
            return (rec.cycles, prog.insns.len(), prog.uop_count);
        }
        let before = self.exec_counters();
        let prog = build(self);
        let cycles = self.run_program(&prog.insns, label);
        memo.insert(
            sig,
            LayerRecord {
                cycles,
                prog_insns: prog.insns.len() as u32,
                prog_uops: prog.uop_count as u32,
                exec: self.exec_counters().minus(&before),
            },
        );
        (cycles, prog.insns.len(), prog.uop_count)
    }

    /// Allocate a DRAM region for a tiled activation of `shape`.
    fn alloc_activation(&mut self, shape: Shape) -> DramRegion {
        let block = self.cfg.block_in;
        let tile = self.cfg.inp_tile_bytes();
        self.dram.alloc(shape.tiles(block) * tile, tile)
    }

    /// Run a graph end-to-end. `input` is `[batch][c][h][w]` int8 with
    /// `batch == cfg.batch`; returns the final node's output in the same
    /// layout (all zeros in timing-only mode, where outputs are not
    /// computed by contract — timing-only sessions also accept an empty
    /// `input`, since tensor data is never read). Per-layer statistics
    /// accumulate in `layer_stats`. Malformed graphs and mis-sized
    /// inputs return [`VtaError`] instead of panicking.
    pub fn run_graph(&mut self, graph: &Graph, input: &[i8]) -> Result<Vec<i8>, VtaError> {
        // One pass validates the graph and yields the shapes.
        let shapes = graph.try_shapes().map_err(VtaError::Graph)?;
        self.run_graph_shaped(graph, &shapes, input)
    }

    /// [`Session::run_graph`] against pre-validated shapes. `shapes`
    /// must be `graph.try_shapes()?` — the engine's `Prepared` carries
    /// exactly that, so serving-style callers that evaluate one graph
    /// many times (sessions are cheap; validation need not be repeated
    /// per request) skip shape propagation here. Passing shapes from a
    /// different graph is a caller bug with panic-level consequences,
    /// the same contract as [`Graph::shapes`].
    pub fn run_graph_shaped(
        &mut self,
        graph: &Graph,
        shapes: &[Shape],
        input: &[i8],
    ) -> Result<Vec<i8>, VtaError> {
        let cfg = self.cfg.clone();
        let block = cfg.block_in;
        let batch = cfg.batch;
        // The cross-layer residency plan (pure: the memoizer and the
        // analytical model derive the identical plan independently).
        // Infeasible tilings surface here as typed config errors.
        let plan = residency::plan(
            &cfg,
            graph,
            shapes,
            self.opts.residency,
            self.opts.tps,
            self.opts.dbuf_reuse,
        )
        .map_err(VtaError::Config)?;
        let want = batch * graph.input_shape.elems();
        if input.len() != want && !(self.timing_only() && input.is_empty()) {
            return Err(VtaError::InvalidRequest(format!(
                "input holds {} values but batch {batch} x input shape {:?} needs {want}",
                input.len(),
                graph.input_shape
            )));
        }

        // Stage the input activation. Timing-only runs never read tensor
        // data, so only the allocation (which fixes downstream DRAM
        // addresses) happens — packing 224x224 inputs is pure overhead.
        let mut regions: Vec<Option<DramRegion>> = vec![None; graph.nodes.len()];
        let r0 = self.alloc_activation(graph.input_shape);
        if !self.timing_only() {
            let tiled = pack_activation(input, batch, graph.input_shape, block);
            self.dram.write_i8(r0, &tiled);
        }
        regions[0] = Some(r0);

        for (i, node) in graph.nodes.iter().enumerate().skip(1) {
            let in_shape = shapes[node.inputs[0]];
            let out_shape = shapes[i];
            let out_region = self.alloc_activation(out_shape);
            regions[i] = Some(out_region);
            let in_region = regions[node.inputs[0]].expect("producer region");
            let before = self.exec_counters();
            let label = format!("{}:{}", graph.name, node.name);

            // Rematerialize evicted producers scheduled before this node
            // (DTR). Their cycles and counters fold into this layer's
            // stats — recompute is a cost this consumer pays.
            let mut remat = (0u64, 0usize, 0usize);
            for p in plan.nodes[i].recompute.clone() {
                let n = self.rerun_producer(graph, shapes, &regions, p, &label);
                remat = (remat.0 + n.0, remat.1 + n.1, remat.2 + n.2);
            }
            let res_bits = plan.sig_bits(i);
            self.set_elided(Self::elided_ranges_for(&plan, i, node, &regions, out_region));

            let (cycles, insns, uops, on_cpu) = match &node.op {
                Op::Input => unreachable!(),
                Op::Conv { shift, relu, weights, .. } => {
                    let spec = graph.conv_spec(i, shapes);
                    if spec.c_in < block {
                        // Channel-light layer: CPU fallback (§IV-E).
                        // Contributes zero cycles and no counters, so
                        // timing-only runs skip it entirely (its output
                        // is never consumed there).
                        if !self.timing_only() {
                            self.run_conv_on_cpu(
                                graph, i, shapes, weights, *shift, *relu, in_region, out_region,
                            );
                        }
                        (0, 0, 0, true)
                    } else {
                        let n = self.run_conv_on_vta(
                            &spec, weights, *shift, *relu, in_region, out_region, &label,
                            res_bits,
                        )?;
                        (n.0, n.1, n.2, false)
                    }
                }
                Op::Dense { shift, relu, weights, .. } => {
                    let spec = graph.conv_spec(i, shapes);
                    let n = self.run_conv_on_vta(
                        &spec, weights, *shift, *relu, in_region, out_region, &label, res_bits,
                    )?;
                    (n.0, n.1, n.2, false)
                }
                Op::Depthwise { k, stride, pad, shift, relu, weights } => {
                    let p = DepthwiseParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        shift: *shift,
                        relu: *relu,
                    };
                    let layer_sig = sig::depthwise_sig(&cfg, &p, res_bits);
                    let tileb = cfg.acc_tile_elems(); // Acc8 tile bytes
                    let in_base = in_region.tile_base(tileb);
                    let out_base = out_region.tile_base(cfg.out_tile_bytes());
                    // Packed image size without packing (timing-only
                    // skips the data, not the allocation).
                    let wgt_len = in_shape.c.div_ceil(block) * p.k * p.k * batch * block;
                    let n = self.memo_run(layer_sig, &label, |s| {
                        let wr = s.dram.alloc(wgt_len, tileb);
                        if !s.timing_only() {
                            pack_depthwise_weights_into(
                                &mut s.wgt_scratch, weights, in_shape.c, p.k, p.k, batch, block,
                            );
                            debug_assert_eq!(s.wgt_scratch.len(), wgt_len);
                            s.dram.write_i8(wr, &s.wgt_scratch);
                        }
                        let mut b = ProgramBuilder::new(&s.cfg);
                        lower_depthwise(&mut b, &p, in_base, wr.tile_base(tileb), out_base);
                        b.finish(&label, &mut s.dram)
                    });
                    (n.0, n.1, n.2, false)
                }
                Op::MaxPool { k, stride, pad } => {
                    let p = PoolParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        is_max: true,
                        shift: 0,
                    };
                    self.run_pool(&p, in_region, out_region, &label, res_bits)
                }
                Op::GlobalAvgPool => {
                    assert_eq!(in_shape.h, in_shape.w, "global pool expects square input");
                    let p = PoolParams {
                        c_tiles: in_shape.c_tiles(block),
                        h: in_shape.h,
                        w: in_shape.w,
                        k: in_shape.h,
                        stride: 1,
                        pad: 0,
                        is_max: false,
                        shift: clog2((in_shape.h * in_shape.w) as u64),
                    };
                    self.run_pool(&p, in_region, out_region, &label, res_bits)
                }
                Op::Add { relu } => {
                    let b_region = regions[node.inputs[1]].expect("skip region");
                    let tiles = out_shape.tiles(block);
                    let layer_sig = sig::add_sig(&cfg, tiles, *relu, res_bits);
                    let in_base = in_region.tile_base(cfg.acc_tile_elems());
                    let b_base = b_region.tile_base(cfg.acc_tile_elems());
                    let out_base = out_region.tile_base(cfg.out_tile_bytes());
                    let relu = *relu;
                    let n = self.memo_run(layer_sig, &label, |s| {
                        let mut b = ProgramBuilder::new(&s.cfg);
                        lower_add(&mut b, tiles, in_base, b_base, out_base, relu);
                        b.finish(&label, &mut s.dram)
                    });
                    (n.0, n.1, n.2, false)
                }
                Op::AttnScores { heads, shift } => {
                    let spec = graph.attn_head_spec(i, shapes);
                    let k_region = regions[node.inputs[1]].expect("producer region");
                    if attn_on_vta(&cfg, &spec) {
                        // Q is read back as per-head weights; K streams
                        // as the per-head GEMM activation.
                        let n = self.run_attn_on_vta(
                            &spec, *heads, *shift, in_region, in_shape, true, k_region,
                            out_region, &label, res_bits,
                        )?;
                        (n.0, n.1, n.2, false)
                    } else {
                        if !self.timing_only() {
                            let (heads, shift) = (*heads, *shift);
                            let q_sh = in_shape;
                            self.run_on_cpu(
                                &[(in_region, in_shape), (k_region, shapes[node.inputs[1]])],
                                out_region,
                                out_shape,
                                move |ins, n| {
                                    crate::compiler::cpu_ref::attn_scores(
                                        &ins[0], &ins[1], n, q_sh.c, q_sh.h, heads, shift,
                                    )
                                },
                            );
                        }
                        (0, 0, 0, true)
                    }
                }
                Op::SoftmaxApprox { shift } => {
                    let sh = in_shape;
                    if softmax_on_vta(&cfg, sh) {
                        let c_tiles = sh.c_tiles(block);
                        let layer_sig =
                            sig::softmax_sig(&cfg, c_tiles, sh.h, sh.w, *shift, res_bits);
                        let in_base = in_region.tile_base(cfg.acc_tile_elems());
                        let out_base = out_region.tile_base(cfg.out_tile_bytes());
                        let shift = *shift;
                        let n = self.memo_run(layer_sig, &label, |s| {
                            let mut b = ProgramBuilder::new(&s.cfg);
                            lower_softmax(&mut b, c_tiles, sh.h, sh.w, shift, in_base, out_base);
                            b.finish(&label, &mut s.dram)
                        });
                        (n.0, n.1, n.2, false)
                    } else {
                        if !self.timing_only() {
                            let shift = *shift;
                            self.run_on_cpu(
                                &[(in_region, sh)],
                                out_region,
                                out_shape,
                                move |ins, n| {
                                    crate::compiler::cpu_ref::softmax_approx(
                                        &ins[0], n, sh.c, sh.h, sh.w, shift,
                                    )
                                },
                            );
                        }
                        (0, 0, 0, true)
                    }
                }
                Op::HeadTranspose { heads } => {
                    // Pure data marshal between the two attention GEMMs
                    // (the scratchpads have no transposed access path):
                    // zero cycles, like every CPU-side layer.
                    if !self.timing_only() {
                        let heads = *heads;
                        let sh = in_shape;
                        self.run_on_cpu(&[(in_region, sh)], out_region, out_shape, move |ins, n| {
                            crate::compiler::cpu_ref::head_transpose(&ins[0], n, sh.c, sh.h, heads)
                        });
                    }
                    (0, 0, 0, true)
                }
                Op::AttnMix { heads, shift } => {
                    let spec = graph.attn_head_spec(i, shapes);
                    let v_region = regions[node.inputs[1]].expect("producer region");
                    let v_shape = shapes[node.inputs[1]];
                    if attn_on_vta(&cfg, &spec) {
                        // V is read back as per-head weights; the
                        // transposed probabilities stream as the
                        // per-head GEMM activation.
                        let n = self.run_attn_on_vta(
                            &spec, *heads, *shift, v_region, v_shape, false, in_region,
                            out_region, &label, res_bits,
                        )?;
                        (n.0, n.1, n.2, false)
                    } else {
                        if !self.timing_only() {
                            let (heads, shift) = (*heads, *shift);
                            let p_sh = in_shape;
                            self.run_on_cpu(
                                &[(in_region, in_shape), (v_region, v_shape)],
                                out_region,
                                out_shape,
                                move |ins, n| {
                                    crate::compiler::cpu_ref::attn_mix(
                                        &ins[0], &ins[1], n, v_shape.c, v_shape.h, p_sh.h,
                                        heads, shift,
                                    )
                                },
                            );
                        }
                        (0, 0, 0, true)
                    }
                }
                Op::LayerNormApprox => {
                    let sh = in_shape;
                    if sh.c >= block {
                        // Stage 1: all-ones GEMM broadcasts the channel
                        // mean into every lane of a fresh activation;
                        // stage 2 subtracts it on the ALU.
                        let spec = layernorm_mean_spec(sh);
                        let mu_region = self.alloc_activation(sh);
                        let ones =
                            if self.timing_only() { Vec::new() } else { vec![1i8; sh.c * sh.c] };
                        let mean_label = format!("{label}:mean");
                        let m = self.run_conv_on_vta(
                            &spec,
                            &ones,
                            clog2(sh.c as u64),
                            false,
                            in_region,
                            mu_region,
                            &mean_label,
                            res_bits,
                        )?;
                        let tiles = out_shape.tiles(block);
                        let layer_sig = sig::sub_sig(&cfg, tiles, res_bits);
                        let in_base = in_region.tile_base(cfg.acc_tile_elems());
                        let mu_base = mu_region.tile_base(cfg.acc_tile_elems());
                        let out_base = out_region.tile_base(cfg.out_tile_bytes());
                        let n = self.memo_run(layer_sig, &label, |s| {
                            let mut b = ProgramBuilder::new(&s.cfg);
                            lower_sub(&mut b, tiles, in_base, mu_base, out_base);
                            b.finish(&label, &mut s.dram)
                        });
                        (m.0 + n.0, m.1 + n.1, m.2 + n.2, false)
                    } else {
                        if !self.timing_only() {
                            self.run_on_cpu(
                                &[(in_region, sh)],
                                out_region,
                                out_shape,
                                move |ins, n| {
                                    crate::compiler::cpu_ref::layernorm_approx(
                                        &ins[0], n, sh.c, sh.h, sh.w,
                                    )
                                },
                            );
                        }
                        (0, 0, 0, true)
                    }
                }
                Op::ChanSlice { start, len } => {
                    if !self.timing_only() {
                        let (start, len) = (*start, *len);
                        let sh = in_shape;
                        self.run_on_cpu(&[(in_region, sh)], out_region, out_shape, move |ins, n| {
                            crate::compiler::cpu_ref::chan_slice(
                                &ins[0], n, sh.c, sh.h, sh.w, start, len,
                            )
                        });
                    }
                    (0, 0, 0, true)
                }
                Op::EltMul { shift, relu } => {
                    let b_region = regions[node.inputs[1]].expect("producer region");
                    let tiles = out_shape.tiles(block);
                    let layer_sig = sig::eltmul_sig(&cfg, tiles, *shift, *relu, res_bits);
                    let in_base = in_region.tile_base(cfg.acc_tile_elems());
                    let b_base = b_region.tile_base(cfg.acc_tile_elems());
                    let out_base = out_region.tile_base(cfg.out_tile_bytes());
                    let (shift, relu) = (*shift, *relu);
                    let n = self.memo_run(layer_sig, &label, |s| {
                        let mut b = ProgramBuilder::new(&s.cfg);
                        lower_eltmul(&mut b, tiles, in_base, b_base, out_base, shift, relu);
                        b.finish(&label, &mut s.dram)
                    });
                    (n.0, n.1, n.2, false)
                }
                Op::HardSigmoid | Op::HardTanh => {
                    let ops: &'static [(crate::isa::AluOp, i32)] =
                        if matches!(node.op, Op::HardSigmoid) {
                            &HARD_SIGMOID_OPS
                        } else {
                            &HARD_TANH_OPS
                        };
                    let tiles = out_shape.tiles(block);
                    let layer_sig = sig::unary_sig(&cfg, tiles, ops, res_bits);
                    let in_base = in_region.tile_base(cfg.acc_tile_elems());
                    let out_base = out_region.tile_base(cfg.out_tile_bytes());
                    let n = self.memo_run(layer_sig, &label, |s| {
                        let mut b = ProgramBuilder::new(&s.cfg);
                        lower_unary(&mut b, tiles, in_base, out_base, ops);
                        b.finish(&label, &mut s.dram)
                    });
                    (n.0, n.1, n.2, false)
                }
            };

            let after = self.exec_counters();
            self.layer_stats.push(LayerStat {
                name: label,
                kind: node.op.kind(),
                cycles: cycles + remat.0,
                insns: insns + remat.1,
                uops: uops + remat.2,
                macs: after.macs - before.macs,
                dram_rd: after.load_bytes_total() - before.load_bytes_total(),
                dram_wr: after.store_bytes - before.store_bytes,
                on_cpu,
            });
        }
        self.set_elided(Vec::new());

        let out_shape = *shapes.last().unwrap();
        let out_region = regions.last().unwrap().unwrap();
        if self.timing_only() {
            return Ok(vec![0; batch * out_shape.elems()]);
        }
        let tiled = self.dram.read_i8(out_region);
        Ok(unpack_activation(&tiled, batch, out_shape, block))
    }

    /// Choose the tiling for a conv per session options.
    ///
    /// The *tiling* is always searched under the improved-reuse cost
    /// model; `dbuf_reuse` then controls only the thread-injection
    /// behaviour — matching the paper's Fig 11/12 experiment, which
    /// flips the IR pass while keeping the schedule.
    ///
    /// Configurations on which even the fallback schedule overflows a
    /// scratchpad return [`VtaError::Config`] with
    /// [`ConfigError::Infeasible`](crate::config::ConfigError::Infeasible)
    /// instead of panicking, so sweeps record such points as infeasible
    /// rather than silently dropping them.
    pub fn tiling_for(&self, spec: &tps::ConvSpec) -> Result<Tiling, VtaError> {
        tps::select_tiling(spec, &self.cfg, self.opts.tps, self.opts.dbuf_reuse)
            .map_err(VtaError::Config)
    }

    /// The DRAM byte ranges elided for node `i`: hot input activations
    /// plus the node's own output when every consumer takes it hot.
    fn elided_ranges_for(
        plan: &ResidencyPlan,
        i: usize,
        node: &crate::compiler::graph::Node,
        regions: &[Option<DramRegion>],
        out_region: DramRegion,
    ) -> Vec<(u64, u64)> {
        let mut ranges = Vec::new();
        for (slot, &p) in node.inputs.iter().enumerate() {
            if plan.nodes[i].resident_inputs[slot] {
                let r = regions[p].expect("producer region");
                ranges.push((r.addr as u64, (r.addr + r.len) as u64));
            }
        }
        if plan.nodes[i].output_elided {
            ranges.push((out_region.addr as u64, (out_region.addr + out_region.len) as u64));
        }
        ranges
    }

    /// Re-run an evicted residual-add producer right before a consumer
    /// (DTR rematerialization). The rerun is the fixed
    /// [`RECOMPUTE_SIG_BITS`] program variant: its inputs are re-loaded
    /// from DRAM (cold — elided stores still write through
    /// functionally, so the data is always there), and its output is
    /// left hot for the consumer (store elided).
    fn rerun_producer(
        &mut self,
        graph: &Graph,
        shapes: &[Shape],
        regions: &[Option<DramRegion>],
        p: usize,
        consumer_label: &str,
    ) -> (u64, usize, usize) {
        let Op::Add { relu } = &graph.nodes[p].op else {
            unreachable!("the planner only rematerializes residual adds");
        };
        let relu = *relu;
        let cfg = self.cfg.clone();
        let tiles = shapes[p].tiles(cfg.block_in);
        let a_region = regions[graph.nodes[p].inputs[0]].expect("producer region");
        let b_region = regions[graph.nodes[p].inputs[1]].expect("producer region");
        let out_region = regions[p].expect("rematerialized producer region");
        self.set_elided(vec![(
            out_region.addr as u64,
            (out_region.addr + out_region.len) as u64,
        )]);
        let layer_sig = sig::add_sig(&cfg, tiles, relu, RECOMPUTE_SIG_BITS);
        let in_base = a_region.tile_base(cfg.acc_tile_elems());
        let b_base = b_region.tile_base(cfg.acc_tile_elems());
        let out_base = out_region.tile_base(cfg.out_tile_bytes());
        let label = format!("{consumer_label}:remat:{}", graph.nodes[p].name);
        self.memo_run(layer_sig, &label, |s| {
            let mut b = ProgramBuilder::new(&s.cfg);
            lower_add(&mut b, tiles, in_base, b_base, out_base, relu);
            b.finish(&label, &mut s.dram)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_conv_on_vta(
        &mut self,
        spec: &tps::ConvSpec,
        weights: &[i8],
        shift: u32,
        relu: bool,
        in_region: DramRegion,
        out_region: DramRegion,
        label: &str,
        res_bits: u8,
    ) -> Result<(u64, usize, usize), VtaError> {
        let cfg = self.cfg.clone();
        let tiling = self.tiling_for(spec)?;
        let layer_sig = sig::conv_sig(&cfg, spec, shift, relu, &tiling, res_bits);
        // Packed-weight image size (pack_conv_weights zero-pads both
        // channel dimensions up to the block), computable without
        // packing.
        let wgt_len = spec.c_out.div_ceil(cfg.block_out)
            * spec.c_in.div_ceil(cfg.block_in)
            * spec.kh
            * spec.kw
            * cfg.block_out
            * cfg.block_in;
        let spec = *spec;
        Ok(self.memo_run(layer_sig, label, |s| {
            let wr = s.dram.alloc(wgt_len, cfg.wgt_tile_bytes());
            if !s.timing_only() {
                pack_conv_weights_into(
                    &mut s.wgt_scratch,
                    weights,
                    spec.c_out,
                    spec.c_in,
                    spec.kh,
                    spec.kw,
                    cfg.block_out,
                    cfg.block_in,
                );
                debug_assert_eq!(s.wgt_scratch.len(), wgt_len);
                s.dram.write_i8(wr, &s.wgt_scratch);
            }
            let mut b = ProgramBuilder::new(&cfg);
            lower_conv(
                &mut b,
                &ConvParams { spec, shift, relu },
                &tiling,
                ConvBases {
                    inp: in_region.tile_base(cfg.inp_tile_bytes()),
                    wgt: wr.tile_base(cfg.wgt_tile_bytes()),
                    out: out_region.tile_base(cfg.out_tile_bytes()),
                },
            );
            b.finish(label, &mut s.dram)
        }))
    }

    fn run_pool(
        &mut self,
        p: &PoolParams,
        in_region: DramRegion,
        out_region: DramRegion,
        label: &str,
        res_bits: u8,
    ) -> (u64, usize, usize, bool) {
        let cfg = self.cfg.clone();
        let layer_sig = sig::pool_sig(&cfg, p, res_bits);
        let p = *p;
        let in_base = in_region.tile_base(cfg.acc_tile_elems());
        let out_base = out_region.tile_base(cfg.out_tile_bytes());
        let n = self.memo_run(layer_sig, label, |s| {
            let mut b = ProgramBuilder::new(&cfg);
            lower_pool(&mut b, &p, in_base, out_base);
            b.finish(label, &mut s.dram)
        });
        (n.0, n.1, n.2, false)
    }

    /// Generic CPU marshal/fallback: unpack each producer activation to
    /// NCHW, run `f` over them, repack the result into `out_region`.
    /// Callers guard with `!timing_only()` — timing-only sessions have
    /// no tensor data in DRAM and CPU layers contribute zero cycles.
    fn run_on_cpu(
        &mut self,
        ins: &[(DramRegion, Shape)],
        out_region: DramRegion,
        out_shape: Shape,
        f: impl FnOnce(&[Vec<i8>], usize) -> Vec<i8>,
    ) {
        let cfg = self.cfg.clone();
        let nchw: Vec<Vec<i8>> = ins
            .iter()
            .map(|&(r, s)| {
                let tiled = self.dram.read_i8(r);
                unpack_activation(&tiled, cfg.batch, s, cfg.block_in)
            })
            .collect();
        let out = f(&nchw, cfg.batch);
        let packed = pack_activation(&out, cfg.batch, out_shape, cfg.block_in);
        self.dram.write_i8(out_region, &packed);
    }

    /// One attention GEMM (scores or mix) as `heads` per-head
    /// convolutions on the GEMM core. The tensor in `wgt_region` is
    /// read back and re-staged as per-head *weights* (Q for scores —
    /// transposed to `[s1][d]` — V for mix, whose rows are already the
    /// `[d][s2]` weight layout), while `act_region` streams per-head
    /// channel-tile slices as the GEMM activation (K for scores,
    /// transposed probabilities for mix). Eligibility
    /// ([`attn_on_vta`]) guarantees batch 1 and tile-aligned head
    /// slices, so each head's input and output sub-ranges are whole
    /// tile runs of the parent activation regions. Timing-only
    /// sessions skip the readback (DRAM holds no data); timing is
    /// data-independent, so the memo lets head 2..N splice head 1's
    /// simulation.
    #[allow(clippy::too_many_arguments)]
    fn run_attn_on_vta(
        &mut self,
        spec: &tps::ConvSpec,
        heads: usize,
        shift: u32,
        wgt_region: DramRegion,
        wgt_shape: Shape,
        scores: bool,
        act_region: DramRegion,
        out_region: DramRegion,
        label: &str,
        res_bits: u8,
    ) -> Result<(u64, usize, usize), VtaError> {
        let cfg = self.cfg.clone();
        let tile = cfg.inp_tile_bytes();
        let in_tiles = (spec.c_in / cfg.block_in) * spec.h;
        let out_tiles = (spec.c_out / cfg.block_in) * spec.h;
        let wgt_data = if self.timing_only() {
            Vec::new()
        } else {
            let tiled = self.dram.read_i8(wgt_region);
            unpack_activation(&tiled, cfg.batch, wgt_shape, cfg.block_in)
        };
        let seq = wgt_shape.h;
        let mut total = (0u64, 0usize, 0usize);
        for hd in 0..heads {
            let w: Vec<i8> = if self.timing_only() {
                Vec::new()
            } else if scores {
                // w[s1][d] = q[(hd*Dh + d), s1]
                let mut w = vec![0i8; spec.c_out * spec.c_in];
                for s1 in 0..spec.c_out {
                    for d in 0..spec.c_in {
                        w[s1 * spec.c_in + d] = wgt_data[(hd * spec.c_in + d) * seq + s1];
                    }
                }
                w
            } else {
                // w[d][s2] = v[(hd*Dh + d), s2] — contiguous V rows.
                wgt_data[hd * spec.c_out * seq..(hd + 1) * spec.c_out * seq].to_vec()
            };
            let in_sub = DramRegion {
                addr: act_region.addr + hd * in_tiles * tile,
                len: in_tiles * tile,
            };
            let out_sub = DramRegion {
                addr: out_region.addr + hd * out_tiles * tile,
                len: out_tiles * tile,
            };
            let head_label = format!("{label}:h{hd}");
            let n = self.run_conv_on_vta(
                spec, &w, shift, false, in_sub, out_sub, &head_label, res_bits,
            )?;
            total = (total.0 + n.0, total.1 + n.1, total.2 + n.2);
        }
        Ok(total)
    }

    /// CPU fallback: unpack, run the reference op, repack.
    #[allow(clippy::too_many_arguments)]
    fn run_conv_on_cpu(
        &mut self,
        graph: &Graph,
        idx: usize,
        shapes: &[Shape],
        weights: &[i8],
        shift: u32,
        relu: bool,
        in_region: DramRegion,
        out_region: DramRegion,
    ) {
        let cfg = &self.cfg;
        let spec = graph.conv_spec(idx, shapes);
        let in_shape = shapes[graph.nodes[idx].inputs[0]];
        let out_shape = shapes[idx];
        let tiled = self.dram.read_i8(in_region);
        let nchw = unpack_activation(&tiled, cfg.batch, in_shape, cfg.block_in);
        let out =
            crate::compiler::cpu_ref::conv2d(&nchw, weights, cfg.batch, &spec, shift, relu);
        let packed = pack_activation(&out, cfg.batch, out_shape, cfg.block_in);
        self.dram.write_i8(out_region, &packed);
    }
}
