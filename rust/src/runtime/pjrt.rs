//! PJRT golden-model runtime.
//!
//! Loads the AOT-compiled JAX/Pallas golden computations
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and runs
//! them on the PJRT CPU client via the `xla` crate. This is the
//! cross-language verification gate: the simulated accelerator's outputs
//! must match the golden model bit-for-bit. Python is never on this
//! path — only the HLO text artifact is.
//!
//! The real implementation needs the external `xla` and `anyhow` crates,
//! which are not vendored in the offline build environment, so it is
//! gated behind the non-default `pjrt` cargo feature. Without the
//! feature, a std-only stub with the same API reports every artifact as
//! missing, so the golden tests and the quickstart example skip the PJRT
//! comparison instead of failing to build. Enabling the feature only
//! works after adding `anyhow` and `xla` to `[dependencies]` by hand —
//! they are deliberately absent from Cargo.toml (even optional deps
//! enter resolution, which the offline environment cannot do); see the
//! `[features]` note in Cargo.toml for the exact lines.

use std::path::PathBuf;

/// Default artifact directory relative to the crate root.
pub fn default_artifact_dir() -> PathBuf {
    // CARGO_MANIFEST_DIR points at the repo root (Cargo.toml location).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::default_artifact_dir;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A PJRT CPU client with a cache of compiled golden executables.
    pub struct Golden {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        dir: PathBuf,
    }

    impl Golden {
        /// Create a CPU PJRT client over an artifact directory.
        pub fn new(dir: impl AsRef<Path>) -> Result<Golden> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Golden { client, exes: HashMap::new(), dir: dir.as_ref().to_path_buf() })
        }

        pub fn with_default_dir() -> Result<Golden> {
            Self::new(default_artifact_dir())
        }

        /// The artifact directory this client resolves names against.
        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Whether the artifact exists (lets tests skip gracefully when
        /// `make artifacts` has not been run).
        pub fn has_artifact(&self, name: &str) -> bool {
            self.dir.join(format!("{name}.hlo.txt")).exists()
        }

        /// Load + compile an artifact (cached).
        pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.exes.contains_key(name) {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact '{name}'"))?;
                self.exes.insert(name.to_string(), exe);
            }
            Ok(self.exes.get(name).unwrap())
        }

        /// Run a two-input artifact on int8 tensors, returning the int8
        /// result (artifacts are lowered with `return_tuple=True`, so the
        /// output is a 1-tuple).
        pub fn run_i8(
            &mut self,
            name: &str,
            x: &[i8],
            x_dims: &[i64],
            w: &[i8],
            w_dims: &[i64],
        ) -> Result<Vec<i8>> {
            let result = self.run_raw(name, x, x_dims, w, w_dims)?;
            result.to_vec::<i8>().context("reading i8 output")
        }

        /// Same, but for artifacts producing int32 (the raw GEMM kernel).
        pub fn run_i8_to_i32(
            &mut self,
            name: &str,
            x: &[i8],
            x_dims: &[i64],
            w: &[i8],
            w_dims: &[i64],
        ) -> Result<Vec<i32>> {
            let result = self.run_raw(name, x, x_dims, w, w_dims)?;
            result.to_vec::<i32>().context("reading i32 output")
        }

        fn run_raw(
            &mut self,
            name: &str,
            x: &[i8],
            x_dims: &[i64],
            w: &[i8],
            w_dims: &[i64],
        ) -> Result<xla::Literal> {
            let xl = i8_literal(x, x_dims).context("creating x literal")?;
            let wl = i8_literal(w, w_dims).context("creating w literal")?;
            let exe = self.load(name)?;
            let out = exe.execute::<xla::Literal>(&[xl, wl]).context("executing golden")?[0]
                [0]
                .to_literal_sync()
                .context("fetching result")?;
            out.to_tuple1().context("unwrapping 1-tuple")
        }
    }

    /// Build an s8 literal from raw int8 data (the crate's `NativeType`
    /// constructors do not cover i8; the untyped-data path does).
    fn i8_literal(data: &[i8], dims: &[i64]) -> Result<xla::Literal> {
        let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let raw: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &dims_usize,
            raw,
        )
        .context("creating s8 literal")
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::default_artifact_dir;
    use std::fmt;
    use std::path::{Path, PathBuf};

    /// Error produced by the stub: the `pjrt` feature is off.
    #[derive(Debug, Clone)]
    pub struct GoldenUnavailable(pub String);

    impl fmt::Display for GoldenUnavailable {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for GoldenUnavailable {}

    pub type Result<T> = std::result::Result<T, GoldenUnavailable>;

    /// Stub golden client: same surface as the real PJRT-backed one, but
    /// every artifact is reported missing so callers take their skip
    /// paths. Running an artifact is an error, never a wrong answer.
    pub struct Golden {
        dir: PathBuf,
    }

    impl Golden {
        pub fn new(dir: impl AsRef<Path>) -> Result<Golden> {
            Ok(Golden { dir: dir.as_ref().to_path_buf() })
        }

        pub fn with_default_dir() -> Result<Golden> {
            Self::new(default_artifact_dir())
        }

        /// The artifact directory this client resolves names against.
        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Always `false`: without the `pjrt` feature no artifact can be
        /// compiled, so callers must skip the golden comparison.
        pub fn has_artifact(&self, _name: &str) -> bool {
            false
        }

        pub fn run_i8(
            &mut self,
            name: &str,
            _x: &[i8],
            _x_dims: &[i64],
            _w: &[i8],
            _w_dims: &[i64],
        ) -> Result<Vec<i8>> {
            Err(self.unavailable(name))
        }

        pub fn run_i8_to_i32(
            &mut self,
            name: &str,
            _x: &[i8],
            _x_dims: &[i64],
            _w: &[i8],
            _w_dims: &[i64],
        ) -> Result<Vec<i32>> {
            Err(self.unavailable(name))
        }

        fn unavailable(&self, name: &str) -> GoldenUnavailable {
            GoldenUnavailable(format!(
                "golden artifact '{name}' unavailable: built without the `pjrt` \
                 cargo feature (needs the external xla crate)"
            ))
        }
    }
}

pub use backend::*;
