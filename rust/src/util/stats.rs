//! Small statistics helpers used by the bench harness and experiment
//! reporting (mean/median/stddev/percentiles, geometric mean for speedup
//! aggregation, pretty SI formatting of cycle/byte counts).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// [`percentile`] over an already-sorted slice — no copy, no re-sort.
/// Callers extracting several percentiles from one sample (the serve
/// report takes p50/p95/p99) sort once and interpolate per rank.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean — the right way to aggregate speedup ratios across
/// layers/workloads (used for the Fig 10/12 summary rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format a count with SI suffix: 38_000_000 -> "38.0M".
pub fn si(x: f64) -> String {
    let (val, suffix) = if x.abs() >= 1e12 {
        (x / 1e12, "T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    if suffix.is_empty() {
        format!("{val:.0}")
    } else {
        format!("{val:.2}{suffix}")
    }
}

/// Format a duration in nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        // The no-copy path agrees with the sorting one bit-for-bit.
        let unsorted = [3.0, 1.0, 4.0, 2.0];
        for p in [0.0, 37.5, 50.0, 95.0, 100.0] {
            assert_eq!(percentile_sorted(&xs, p), percentile(&unsorted, p));
        }
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(38_000_000.0), "38.00M");
        assert_eq!(si(1_500.0), "1.50K");
        assert_eq!(si(12.0), "12");
        assert_eq!(si(2.5e9), "2.50G");
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(1.5e9), "1.500s");
        assert_eq!(fmt_ns(2.5e6), "2.500ms");
        assert_eq!(fmt_ns(900.0), "900ns");
    }

    #[test]
    fn empty_inputs() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
